"""Splice generated tables into EXPERIMENTS.md at the marker comments."""
import io, sys, contextlib
sys.path.insert(0, "src")
from repro.roofline import aggregate

buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    cells = aggregate.load("results/dryrun")
    print(aggregate.dryrun_table(cells))
dry = buf.getvalue()

buf = io.StringIO()
with contextlib.redirect_stdout(buf):
    print(aggregate.roofline_table(cells))
roof = buf.getvalue()

src = open("EXPERIMENTS.md").read()
src = src.replace("<!-- DRYRUN_TABLE -->", dry)
src = src.replace("<!-- ROOFLINE_TABLE -->", roof)
perf = open("results/perf_log.md").read() if __import__("os").path.exists("results/perf_log.md") else ""
src = src.replace("<!-- PERF_LOG -->", perf)
open("EXPERIMENTS.md", "w").write(src)
print("EXPERIMENTS.md rendered:",
      len(dry.splitlines()), "dryrun rows;",
      len(roof.splitlines()), "roofline rows")
