"""Stats-surface drift gate: every dataclass field of ``SolveStats`` and
``SchedulerStats`` must appear in its serialized dict form. A field added
without a matching ``as_dict`` entry silently vanishes from sinks, logs,
and benchmark JSON — this test makes that a loud failure instead."""
from __future__ import annotations

import dataclasses

from repro.core.solution import SolveStats
from repro.serve.scheduler import SchedulerStats


def _field_names(cls):
    return {f.name for f in dataclasses.fields(cls)}


def test_solve_stats_as_dict_is_complete():
    st = SolveStats(mode="compact", batch=4, bucket=(8, 8),
                    occupancy=((8, 4), (8, 2)))
    d = st.as_dict()
    missing = _field_names(SolveStats) - set(d)
    assert not missing, f"SolveStats.as_dict() dropped {sorted(missing)}"


def test_scheduler_stats_as_dict_is_complete():
    st = SchedulerStats()
    d = st.as_dict()
    missing = _field_names(SchedulerStats) - set(d)
    assert not missing, (
        f"SchedulerStats.as_dict() dropped {sorted(missing)}")


def test_scheduler_stats_dict_matches_snapshot_surface():
    """``stats_dict()`` (the public serving surface) exposes the same
    keys as a snapshot's ``as_dict()`` — the view cannot drift from the
    dataclass."""
    from repro.serve.scheduler import AsyncOTScheduler

    with AsyncOTScheduler(eps=0.25) as sched:
        d = sched.stats_dict()
        keys = set(sched.stats.as_dict())
    assert set(d) == keys
    assert _field_names(SchedulerStats) <= keys


def test_counter_fields_map_to_registry_instruments():
    """Each counter-backed SchedulerStats field names a real registry
    instrument on a live scheduler (the from_registry contract)."""
    from repro.serve.scheduler import AsyncOTScheduler

    with AsyncOTScheduler(eps=0.25) as sched:
        snap = sched.metrics.snapshot()
    for f in SchedulerStats._COUNTERS:
        assert f"scheduler.{f}" in snap, f
