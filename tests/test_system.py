"""Full-stack system test: train -> checkpoint -> serve -> OT diagnostics,
all through the public APIs."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, reduced
from repro.serve.engine import Engine, OTService, Request
from repro.train.trainer import Trainer


@pytest.mark.slow
def test_train_then_serve_then_ot(tmp_path):
    cfg = reduced(ARCHS["deepseek-moe-16b"]).with_(
        num_layers=2, router="pushrelabel", remat=False
    )
    tr = Trainer(cfg, str(tmp_path / "w"), seq_len=32, batch_size=4,
                 lr=1e-3, ckpt_every=10)
    hist = tr.run(12)
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.5  # training is sane

    eng = Engine(cfg, tr.params, max_len=64)
    rng = np.random.default_rng(0)
    eng.submit(Request(prompt=rng.integers(0, 400, 10).astype(np.int32),
                       max_new_tokens=4))
    outs = eng.run_batch()
    assert outs[0].tokens.shape == (4,)

    # OT distance between two batches of hidden-ish features (the paper's
    # solver as a training diagnostic)
    svc = OTService(eps=0.1)
    d = svc.distance(rng.standard_normal((32, 8)).astype(np.float32),
                     rng.standard_normal((32, 8)).astype(np.float32))
    assert np.isfinite(d["cost"])


def test_roofline_collective_parser():
    from repro.roofline.analysis import collective_bytes

    hlo = """
  %ar = f32[1024,256]{1,0} all-reduce(f32[1024,256]{1,0} %x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[4096,128]{1,0} all-gather(bf16[256,128]{1,0} %y), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(f32[1024,64]{1,0} %z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[32]{0} collective-permute(f32[32]{0} %w), source_target_pairs={{0,1}}
  %while.1 = s32[] while(s32[] %c), condition=%cond, body=%body
"""
    out = collective_bytes(hlo)
    c = out["counts"]
    assert c["all-reduce"] == 1 and c["all-gather"] == 1
    assert c["reduce-scatter"] == 1 and c["collective-permute"] == 1
    # all-reduce: 2*(15/16)*1024*256*4
    expect_ar = 2 * 15 / 16 * 1024 * 256 * 4
    assert abs(out["by_op"]["all-reduce"] - expect_ar) < 1.0
    # all-gather result bytes: 4096*128*2 * (15/16)
    expect_ag = 15 / 16 * 4096 * 128 * 2
    assert abs(out["by_op"]["all-gather"] - expect_ag) < 1.0
    # reduce-scatter: (N-1)*result, N=4 from brace groups
    expect_rs = 3 * 64 * 64 * 4
    assert abs(out["by_op"]["reduce-scatter"] - expect_rs) < 1.0
    assert out["while_ops"] == 1


def test_model_flops_accounting():
    from repro.roofline.analysis import model_flops
    from repro.configs.base import SHAPES

    cfg = ARCHS["deepseek-moe-16b"]
    mf = model_flops(cfg, SHAPES["train_4k"], 256)
    # deepseek-moe-16b: ~16B total, ~2.8B active (64e top-6 + 2 shared + dense)
    assert 1.4e10 < mf["n_params_total"] < 2.2e10
    assert mf["n_params_active"] < 0.35 * mf["n_params_total"]
    assert mf["model_flops_total"] == 6 * mf["n_params_active"] * mf["tokens"]


def test_sinkhorn_kernel_in_solver_loop():
    """Pallas sinkhorn_row_update drops into the log-domain loop."""
    from repro.kernels import ops
    from repro.core.costs import build_cost_matrix

    rng = np.random.default_rng(0)
    n = 96
    c = build_cost_matrix(jnp.asarray(rng.uniform(size=(n, 2))),
                          jnp.asarray(rng.uniform(size=(n, 2))), "euclidean")
    nu = jnp.full((n,), 1.0 / n)
    log_nu = jnp.log(nu)
    reg = 0.05
    f = jnp.zeros((n,))
    g = jnp.zeros((n,))
    for _ in range(80):
        f = ops.sinkhorn_row_update(c, g, log_nu, reg)
        g = ops.sinkhorn_row_update(c.T, f, log_nu, reg)
    plan = jnp.exp((f[:, None] + g[None, :] - c) / reg)
    assert float(jnp.abs(plan.sum(1) - nu).sum()) < 2e-2
