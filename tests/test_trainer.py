"""Fault tolerance: loss goes down; kill/resume reproduces the uninterrupted
run exactly (deterministic data + CRC-checked atomic checkpoints); corrupted
checkpoints are skipped; serving engine decodes batches."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduced
from repro.checkpoint import checkpointing as ckpt
from repro.data.pipeline import synthetic_batch
from repro.models import model as M
from repro.train.trainer import Trainer


CFG = reduced(ARCHS["llama3.2-3b"]).with_(num_layers=2, remat=False)


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    tr = Trainer(CFG, str(tmp_path / "w"), seq_len=32, batch_size=4,
                 lr=2e-3, warmup=5, ckpt_every=1000)
    hist = tr.run(40)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_kill_and_resume_bitwise(tmp_path):
    w1, w2 = str(tmp_path / "a"), str(tmp_path / "b")
    # uninterrupted run: 8 steps
    t_full = Trainer(CFG, w1, seq_len=16, batch_size=2, ckpt_every=4)
    h_full = t_full.run(8)
    # interrupted: 4 steps, "crash" (drop object), new Trainer resumes
    t_half = Trainer(CFG, w2, seq_len=16, batch_size=2, ckpt_every=4)
    t_half.run(4)
    del t_half
    t_resumed = Trainer(CFG, w2, seq_len=16, batch_size=2, ckpt_every=4)
    assert t_resumed.step == 4
    h_rest = t_resumed.run(4)
    np.testing.assert_allclose(
        [h["loss"] for h in h_full[4:]],
        [h["loss"] for h in h_rest],
        rtol=1e-5,
    )


def test_corrupt_checkpoint_is_skipped(tmp_path):
    d = str(tmp_path / "c")
    tree = {"x": jnp.arange(10, dtype=jnp.float32)}
    ckpt.save(d, 1, tree)
    ckpt.save(d, 2, tree)
    # corrupt the newest
    with open(os.path.join(d, "step_0000000002", "arrays.npz"), "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 32)
    assert ckpt.latest_step(d) == 1


def test_checkpoint_roundtrip_preserves_dtypes(tmp_path):
    d = str(tmp_path / "d")
    tree = {
        "a": jnp.ones((3, 4), jnp.bfloat16),
        "b": {"c": jnp.arange(5, dtype=jnp.int32)},
    }
    ckpt.save(d, 7, tree)
    out = ckpt.restore(d, 7, tree)
    assert out["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.arange(5))


def test_data_pipeline_deterministic():
    a = synthetic_batch(CFG, 32, 4, seed=1, step=17)
    b = synthetic_batch(CFG, 32, 4, seed=1, step=17)
    c = synthetic_batch(CFG, 32, 4, seed=1, step=18)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


@pytest.mark.slow
def test_serving_engine_batched(tmp_path):
    from repro.serve.engine import Engine, Request

    params = M.init_params(CFG, jax.random.key(0))
    eng = Engine(CFG, params, max_len=64)
    rng = np.random.default_rng(0)
    for L, n in [(9, 5), (14, 3)]:
        eng.submit(Request(prompt=rng.integers(0, 400, L).astype(np.int32),
                           max_new_tokens=n))
    outs = eng.run_batch()
    assert len(outs) == 2
    assert outs[0].tokens.shape[0] == 5
    assert outs[1].tokens.shape[0] == 3
    assert (outs[0].tokens < CFG.vocab_padded).all()


def test_ot_service_endpoint():
    from repro.serve.engine import OTService

    rng = np.random.default_rng(1)
    svc = OTService(eps=0.1)
    out = svc.distance(rng.uniform(size=(64, 2)).astype(np.float32),
                       rng.uniform(size=(64, 2)).astype(np.float32))
    assert out["cost"] >= out["dual_lower_bound"] - 0.35  # weak duality + eps
    assert len(np.unique(out["matching"])) == 64
