"""Batched fixed-shape solvers: padded/bucketed batch results must equal
independent unbatched solves (bit-identical matchings, f32-tolerance costs),
the batched Pallas kernel must match the per-instance kernel, and the
reworked OTService must bucket a mixed queue correctly."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.batched import (
    bucket_instances,
    next_bucket,
    pad_stack,
    solve_assignment_batched,
    solve_assignment_ragged,
    solve_ot_batched,
    solve_ot_ragged,
)
from repro.core.costs import build_cost_matrix
from repro.core.pushrelabel import solve_assignment
from repro.core.transport import solve_ot


def _ragged_ot_instances(b, lo, hi, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(b):
        m, n = int(rng.integers(lo, hi)), int(rng.integers(lo, hi))
        x = rng.uniform(size=(m, 2))
        y = rng.uniform(size=(n, 2))
        c = np.asarray(build_cost_matrix(x, y, "euclidean"))
        nu = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu = rng.dirichlet(np.ones(n)).astype(np.float32)
        out.append((c, nu, mu))
    return out


def _pad_batch(insts, mb, nb):
    b = len(insts)
    c = np.zeros((b, mb, nb), np.float32)
    nu = np.zeros((b, mb), np.float32)
    mu = np.zeros((b, nb), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i, (ci, nui, mui) in enumerate(insts):
        m, n = ci.shape
        c[i, :m, :n] = ci
        nu[i, :m] = nui
        mu[i, :n] = mui
        sizes[i] = (m, n)
    return c, nu, mu, sizes


def test_solve_ot_batched_matches_unbatched():
    """Acceptance: B=8 padded instances == 8 independent solve_ot calls."""
    insts = _ragged_ot_instances(8, 24, 64, seed=3)
    c, nu, mu, sizes = _pad_batch(insts, 64, 64)
    r = solve_ot_batched(c, nu, mu, 0.1, sizes=sizes)
    for i, (ci, nui, mui) in enumerate(insts):
        s = solve_ot(jnp.asarray(ci), jnp.asarray(nui), jnp.asarray(mui), 0.1)
        assert float(r.cost[i]) == pytest.approx(float(s.cost), abs=2e-6)
        m, n = ci.shape
        np.testing.assert_allclose(
            np.asarray(r.plan)[i, :m, :n], np.asarray(s.plan), atol=1e-6
        )
        # padding carries no mass
        assert float(np.abs(np.asarray(r.plan)[i, m:, :]).sum()) == 0.0
        assert float(np.abs(np.asarray(r.plan)[i, :, n:]).sum()) == 0.0
        assert int(r.phases[i]) == int(s.phases)


def test_solve_ot_batched_marginals_exact():
    insts = _ragged_ot_instances(4, 16, 40, seed=11)
    c, nu, mu, sizes = _pad_batch(insts, 40, 40)
    r = solve_ot_batched(c, nu, mu, 0.05, sizes=sizes)
    plan = np.asarray(r.plan)
    np.testing.assert_allclose(plan.sum(2), nu, atol=2e-6)
    np.testing.assert_allclose(plan.sum(1), mu, atol=2e-6)


def test_solve_assignment_batched_matches_unbatched():
    rng = np.random.default_rng(7)
    cs = []
    for _ in range(6):
        m = int(rng.integers(20, 96))
        n = int(rng.integers(m, 96))          # m <= n
        x = rng.uniform(size=(m, 2))
        y = rng.uniform(size=(n, 2))
        cs.append(np.asarray(build_cost_matrix(x, y, "euclidean")))
    mb = max(c.shape[0] for c in cs)
    nb = max(c.shape[1] for c in cs)
    c = np.zeros((len(cs), mb, nb), np.float32)
    sizes = np.zeros((len(cs), 2), np.int32)
    for i, ci in enumerate(cs):
        c[i, :ci.shape[0], :ci.shape[1]] = ci
        sizes[i] = ci.shape
    r = solve_assignment_batched(c, 0.05, sizes=sizes)
    for i, ci in enumerate(cs):
        s = solve_assignment(jnp.asarray(ci), 0.05)
        m = ci.shape[0]
        np.testing.assert_array_equal(
            np.asarray(r.matching)[i, :m], np.asarray(s.matching)
        )
        # padded rows stay unmatched
        assert (np.asarray(r.matching)[i, m:] == -1).all()
        assert float(r.cost[i]) == pytest.approx(float(s.cost), abs=1e-5)
        assert int(r.phases[i]) == int(s.phases)


def test_solve_assignment_batched_full_shape_no_sizes():
    rng = np.random.default_rng(2)
    c = rng.uniform(size=(3, 48, 48)).astype(np.float32)
    r = solve_assignment_batched(c, 0.1)
    for i in range(3):
        s = solve_assignment(jnp.asarray(c[i]), 0.1)
        np.testing.assert_array_equal(
            np.asarray(r.matching)[i], np.asarray(s.matching)
        )


def test_bucketing_utilities():
    assert next_bucket(1) == 16
    assert next_bucket(16) == 16
    assert next_bucket(17) == 32
    # beyond the largest table entry: mint a ceil-pow2 bucket (one shared
    # compiled program per pow2 size) instead of a per-shape exact bucket
    assert next_bucket(5000) == 8192
    assert next_bucket(2049) == 4096
    assert next_bucket(4096) == 4096
    groups = bucket_instances([(20, 20), (30, 10), (100, 100), (31, 9)])
    keys = {g.key for g in groups}
    assert keys == {(32, 32), (32, 16), (128, 128)}
    covered = sorted(i for g in groups for i in g.indices)
    assert covered == [0, 1, 2, 3]
    padded = pad_stack([np.ones((2, 3)), np.ones((1, 2))], (4, 4))
    assert padded.shape == (2, 4, 4)
    assert float(padded.sum()) == 8.0


def test_solve_ot_ragged_roundtrip():
    insts = _ragged_ot_instances(5, 10, 70, seed=5)
    rs = solve_ot_ragged(insts, 0.1)
    for (ci, nui, mui), r in zip(insts, rs):
        s = solve_ot(jnp.asarray(ci), jnp.asarray(nui), jnp.asarray(mui), 0.1)
        assert r["plan"].shape == ci.shape
        assert r["cost"] == pytest.approx(float(s.cost), abs=2e-6)


def test_solve_assignment_ragged_roundtrip():
    rng = np.random.default_rng(9)
    cs = [np.asarray(build_cost_matrix(rng.uniform(size=(m, 2)),
                                       rng.uniform(size=(m, 2)), "euclidean"))
          for m in (18, 33, 64, 40)]
    rs = solve_assignment_ragged(cs, 0.1)
    for ci, r in zip(cs, rs):
        s = solve_assignment(jnp.asarray(ci), 0.1)
        np.testing.assert_array_equal(r["matching"], np.asarray(s.matching))
        assert r["cost"] == pytest.approx(float(s.cost), abs=1e-5)


def test_slack_propose_batched_matches_single():
    """Batched kernel (leading batch dim in the grid) == per-instance kernel,
    bit for bit, including per-instance salts and padded tiles."""
    from repro.kernels import ops
    from repro.kernels import slack_propose as sp

    rng = np.random.default_rng(13)
    b, m, n = 4, 70, 130
    c = rng.integers(0, 6, size=(b, m, n)).astype(np.int32)
    y_b = rng.integers(0, 4, size=(b, m)).astype(np.int32)
    y_a = -rng.integers(0, 4, size=(b, n)).astype(np.int32)
    avail = rng.uniform(size=(b, n)) < 0.6
    salts = rng.integers(0, 10_000, size=b).astype(np.int32)

    bc, bk = ops.slack_propose_batched(
        jnp.asarray(c), jnp.asarray(y_b), jnp.asarray(y_a),
        jnp.asarray(avail), jnp.asarray(salts),
    )
    for i in range(b):
        sc, sk = sp.slack_propose(
            jnp.asarray(c[i]), jnp.asarray(y_b[i]), jnp.asarray(y_a[i]),
            jnp.asarray(avail[i]), int(salts[i]), interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(bc)[i], np.asarray(sc))
        np.testing.assert_array_equal(np.asarray(bk)[i], np.asarray(sk))

    # block-size invariance of the batched accumulator pattern
    bc2, _ = ops.slack_propose_batched(
        jnp.asarray(c), jnp.asarray(y_b), jnp.asarray(y_a),
        jnp.asarray(avail), jnp.asarray(salts), block_m=32, block_n=32,
    )
    np.testing.assert_array_equal(np.asarray(bc), np.asarray(bc2))


def test_ot_service_bucketed_queue():
    """Mixed-size queue: results come back in submission order, grouped into
    shape buckets, and match one-at-a-time unbatched solves."""
    from repro.serve.engine import OTService

    rng = np.random.default_rng(1)
    svc = OTService(eps=0.1)
    refs = []
    for m in (20, 60, 20, 90):
        x = rng.uniform(size=(m, 2)).astype(np.float32)
        y = rng.uniform(size=(m, 2)).astype(np.float32)
        ticket = svc.submit(x, y)
        assert ticket == len(refs)
        c = build_cost_matrix(jnp.asarray(x), jnp.asarray(y), "euclidean")
        refs.append(float(solve_assignment(c, 0.1).cost) / m)
    # one general-OT request rides in the same dispatch
    x = rng.uniform(size=(25, 2)).astype(np.float32)
    y = rng.uniform(size=(35, 2)).astype(np.float32)
    nu = rng.dirichlet(np.ones(25)).astype(np.float32)
    mu = rng.dirichlet(np.ones(35)).astype(np.float32)
    svc.submit(x, y, nu=nu, mu=mu)

    res = svc.run_batch()
    assert len(res) == 5
    assert svc.queue == []
    for i, ref in enumerate(refs):
        assert res[i]["cost"] == pytest.approx(ref, abs=1e-5)
    assert res[0]["bucket"] == (32, 32) and res[0]["batch_size"] == 2
    assert res[3]["bucket"] == (128, 128)
    assert res[4]["plan"].shape == (25, 35)
    c = build_cost_matrix(jnp.asarray(x), jnp.asarray(y), "euclidean")
    s = solve_ot(c, jnp.asarray(nu), jnp.asarray(mu), 0.1)
    assert res[4]["cost"] == pytest.approx(float(s.cost), abs=2e-6)
