"""Tests for the repro.analysis static-audit layer.

The centerpiece fixtures RE-INTRODUCE the repo's two historical bug
classes in tiny throwaway functions and assert the analyzer flags them:

  * PR-3 shipped ``init_ot_state`` aliasing ``s_int`` into a
    donated-buffer state — the first chunk dispatch then overwrote the
    retained supply vector (donation-safety rule);
  * PR-2 shipped the OT termination threshold computed in on-device f32
    (int -> f32 arithmetic -> int round trip), rounding differently from
    the host-f64 contract (dtype-drift rule).
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import registry
from repro.analysis.baseline import apply_baseline, load_baseline
from repro.analysis.rules import audit_entry
from repro.analysis.syncaudit import SyncTarget, audit_function_source


def _keys(findings):
    return {f.key for f in findings}


# --------------------------------------------------------------------------
# Seeded regression fixture 1: the PR-3 donated-buffer aliasing bug
# --------------------------------------------------------------------------

def _buggy_ot_chain():
    """init_ot_state as PR 3 shipped it: the state's supply vector IS the
    retained d_int buffer (no copy). chunk donates the state, so the first
    dispatch frees/overwrites the buffer the epilogue still reads."""

    def chain(c, mu):
        c_int = jnp.floor(c * 64.0).astype(jnp.int32)
        d_int = jnp.ceil(mu * 32.0).astype(jnp.int32)
        # BUG (seeded): free_a aliases d_int instead of copying it
        state = {"free_a": d_int.astype(jnp.int32),
                 "y_a": jnp.zeros_like(d_int)}
        return {"state": state, "retained": {"c_int": c_int,
                                             "d_int": d_int}}

    args = {"c": jnp.zeros((4, 4), jnp.float32),
            "mu": jnp.full((4,), 0.25, jnp.float32)}
    return registry.trace_entry(
        name="fixture.buggy_ot_chain", fn=chain, args=args,
        retained={"c", "mu"}, tags={"state-init-chain"}, source=__name__)


def _fixed_ot_chain():
    def chain(c, mu):
        c_int = jnp.floor(c * 64.0).astype(jnp.int32)
        d_int = jnp.ceil(mu * 32.0).astype(jnp.int32)
        state = {"free_a": jnp.array(d_int, copy=True),
                 "y_a": jnp.zeros_like(d_int)}
        return {"state": state, "retained": {"c_int": c_int,
                                             "d_int": d_int}}

    args = {"c": jnp.zeros((4, 4), jnp.float32),
            "mu": jnp.full((4,), 0.25, jnp.float32)}
    return registry.trace_entry(
        name="fixture.fixed_ot_chain", fn=chain, args=args,
        retained={"c", "mu"}, tags={"state-init-chain"}, source=__name__)


def test_seeded_donation_alias_flagged():
    findings = audit_entry(_buggy_ot_chain())
    keys = _keys(findings)
    assert any(k.startswith("donation-safety:fixture.buggy_ot_chain:alias")
               for k in keys), keys


def test_fixed_donation_chain_clean():
    findings = audit_entry(_fixed_ot_chain())
    assert not any(f.rule == "donation-safety" for f in findings), findings


def test_donated_and_retained_root_flagged():
    entry = registry.trace_entry(
        name="fixture.donated_retained",
        fn=lambda x: x * 2,
        args={"x": jnp.zeros((4,), jnp.float32)},
        donated={"x"}, retained={"x"}, source=__name__)
    keys = _keys(audit_entry(entry))
    assert "donation-safety:fixture.donated_retained:donated-retained:x" \
        in keys


# --------------------------------------------------------------------------
# Seeded regression fixture 2: the PR-2 f32 termination-threshold bug
# --------------------------------------------------------------------------

def _buggy_threshold():
    """The OT termination threshold as PR 2 shipped it: computed on
    device from integer operands via f32 arithmetic, then floored back to
    int32 — rounds differently from the host-f64 contract."""

    def threshold(d_int):
        m = jnp.sum(d_int)                       # int32
        # BUG (seeded): int -> f32 arithmetic -> int round trip
        t = jnp.float32(0.12) * m.astype(jnp.float32)
        return jnp.floor(t).astype(jnp.int32)

    return registry.trace_entry(
        name="fixture.buggy_threshold", fn=threshold,
        args={"d_int": jnp.ones((8,), jnp.int32)}, source=__name__)


def _fixed_threshold():
    """Threshold passed in as traced data (computed host-side in f64)."""

    def threshold(d_int, t):
        return jnp.minimum(t, jnp.sum(d_int))

    return registry.trace_entry(
        name="fixture.fixed_threshold", fn=threshold,
        args={"d_int": jnp.ones((8,), jnp.int32), "t": jnp.int32(3)},
        must_trace={"t"}, source=__name__)


def test_seeded_f32_roundtrip_flagged():
    keys = _keys(audit_entry(_buggy_threshold()))
    assert ("dtype-drift:fixture.buggy_threshold:f32-int-roundtrip"
            in keys), keys


def test_fixed_threshold_clean():
    findings = audit_entry(_fixed_threshold())
    assert not any(f.rule == "dtype-drift" for f in findings), findings


def test_pure_float_rounding_not_flagged():
    """floor(c / eps).astype(int32) is the rounding prologue's legitimate
    pattern — float arithmetic floored to int, with no int origin."""
    entry = registry.trace_entry(
        name="fixture.rounding", fn=lambda c: jnp.floor(c / 0.25).astype(
            jnp.int32),
        args={"c": jnp.zeros((4, 4), jnp.float32)}, source=__name__)
    findings = audit_entry(entry)
    assert not any("f32-int-roundtrip" in f.key for f in findings), findings


# --------------------------------------------------------------------------
# Recompile-hazard rule
# --------------------------------------------------------------------------

def test_baked_operand_flagged():
    """eps captured as a Python float is baked into the program — every
    new eps would recompile."""
    eps = 0.25

    def f(c):
        return jnp.floor(c / eps).astype(jnp.int32)

    entry = registry.trace_entry(
        name="fixture.baked_eps", fn=f,
        args={"c": jnp.zeros((4, 4), jnp.float32)},
        must_trace={"eps"}, source=__name__)
    keys = _keys(audit_entry(entry))
    assert "recompile-hazard:fixture.baked_eps:baked:eps" in keys


def test_traced_operand_clean():
    entry = registry.trace_entry(
        name="fixture.traced_eps",
        fn=lambda c, eps: jnp.floor(c / eps).astype(jnp.int32),
        args={"c": jnp.zeros((4, 4), jnp.float32),
              "eps": jnp.float32(0.25)},
        must_trace={"eps"}, source=__name__)
    findings = audit_entry(entry)
    assert not any(f.rule == "recompile-hazard" for f in findings), findings


def test_unused_must_trace_flagged():
    """A must-trace operand that reaches the jaxpr but feeds nothing is a
    silently-dead knob (the value changes, the program doesn't)."""
    entry = registry.trace_entry(
        name="fixture.dead_knob",
        fn=lambda c, eps: jnp.floor(c * 4.0).astype(jnp.int32),
        args={"c": jnp.zeros((4, 4), jnp.float32),
              "eps": jnp.float32(0.25)},
        must_trace={"eps"}, source=__name__)
    keys = _keys(audit_entry(entry))
    assert "recompile-hazard:fixture.dead_knob:unused:eps" in keys


# --------------------------------------------------------------------------
# Hot-loop sync audit (AST fixtures)
# --------------------------------------------------------------------------

_LOOP_WITH_EXTRA_SYNC = '''
def drive(run_fn, conv_fn, data, state, n):
    for _ in range(n):
        state = run_fn(data, state)
        conv, ph = jax.device_get(conv_fn(data, state))
        extra = np.asarray(state.phases)
        if conv.all():
            break
    return state
'''

_LOOP_CLEAN = '''
def drive(run_fn, conv_fn, data, state, n):
    for _ in range(n):
        state = run_fn(data, state)
        conv, ph = jax.device_get(conv_fn(data, state))
        if conv.all():
            break
    return state
'''


def test_syncaudit_flags_second_fetch():
    fs = audit_function_source(_LOOP_WITH_EXTRA_SYNC, "drive", "fixture")
    assert any("np.asarray" in f.detail for f in fs), fs


def test_syncaudit_whitelists_conv_fetch():
    assert audit_function_source(_LOOP_CLEAN, "drive", "fixture") == []


def test_syncaudit_default_targets_clean():
    from repro.analysis.syncaudit import audit_targets, default_targets
    assert audit_targets(default_targets()) == []


def test_syncaudit_missing_function():
    fs = audit_function_source("x = 1", "drive", "fixture")
    assert any(f.detail.startswith("missing") for f in fs)


def test_synctarget_paths_exist():
    import os

    from repro.analysis.syncaudit import default_targets
    for t in default_targets():
        assert os.path.exists(str(t.path)), t


# --------------------------------------------------------------------------
# Registry mechanics over the real entry set
# --------------------------------------------------------------------------

def test_builtin_entries_trace():
    registry.load_all()
    entries = registry.build_entries()
    names = {e.name for e in entries}
    assert "core.pushrelabel.run_assignment_phases" in names
    assert "core.transport.run_ot_phases" in names
    assert "core.compaction.chunk[assignment]" in names
    assert "core.distributed.mesh_chunk[ot]" in names
    assert "kernels.ops.slack_propose" in names
    for e in entries:
        assert e.jaxpr.jaxpr.eqns, f"{e.name} traced to an empty jaxpr"


def test_repo_strict_audit_passes():
    """The repo's own entry points pass --strict with the checked-in
    baseline (this is the same gate CI runs)."""
    from repro.analysis.cli import main
    assert main(["--strict", "--no-dynamic"]) == 0


# --------------------------------------------------------------------------
# Baseline machinery
# --------------------------------------------------------------------------

def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "base.txt"
    p.write_text("some-rule:entry:detail\n")
    with pytest.raises(ValueError, match="justification"):
        load_baseline(p)


def test_baseline_suppresses_and_reports_stale(tmp_path):
    from repro.analysis.rules import Finding
    p = tmp_path / "base.txt"
    p.write_text("r:e:d -- accepted for reasons\n"
                 "r:gone:d -- entry was removed\n")
    base = load_baseline(p)
    f = Finding(rule="r", entry="e", detail="d", message="m")
    g = Finding(rule="r", entry="e", detail="other", message="m")
    active, suppressed, stale = apply_baseline([f, g], base)
    assert active == [g]
    assert suppressed == [(f, "accepted for reasons")]
    assert stale == ["r:gone:d"]


# --------------------------------------------------------------------------
# Bucket-ladder compile audit (dynamic; exercises the real driver)
# --------------------------------------------------------------------------

def test_bucket_ladder_one_program_per_bucket():
    from repro.analysis.cli import audit_bucket_ladder
    findings = audit_bucket_ladder()
    assert findings == [], [f.key for f in findings]


def test_leaves_of_prefix_matching():
    lo = registry.TracedEntry.leaves_of
    assert lo(None, "state",
              ["state.y_b", "state.y_a", "stateful"]) == [0, 1]
    assert lo(None, "x", ["x"]) == [0]
    assert lo(None, "ops", ["ops['c']", "ops['nu']", "out"]) == [0, 1]
