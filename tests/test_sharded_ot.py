"""Distributed push-relabel == single-device push-relabel, bit for bit.

Runs in a subprocess with XLA_FLAGS forcing 8 host devices (the parent
test process must keep seeing 1 device)."""
import json
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from functools import partial
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.feasibility import check_invariants, check_ot_invariants
from repro.core.pushrelabel import round_costs, solve_assignment, \
    solve_assignment_int
from repro.core.sharded import (
    solve_assignment_sharded, solve_assignment_shardmap, solve_ot_sharded,
    lower_sharded_solver,
)
from repro.core.transport import ot_prologue, solve_ot
from repro.launch.mesh import make_small_mesh

rng = np.random.default_rng(0)
n = 96
c = rng.uniform(size=(n, n)).astype(np.float32)
mesh = make_small_mesh((2, 4), ("data", "model"))

r_single = solve_assignment(jnp.asarray(c), 0.05)
r_shard = solve_assignment_sharded(jnp.asarray(c), 0.05, mesh)
r_manual = solve_assignment_shardmap(jnp.asarray(c), 0.05, mesh)

out = {
    "match_equal": bool(
        (np.asarray(r_single.matching) == np.asarray(r_shard.matching)).all()
    ),
    "manual_equal": bool(
        (np.asarray(r_single.matching)
         == np.asarray(r_manual.matching)).all()
    ) and int(r_manual.phases) == int(r_single.phases),
    "cost_single": float(r_single.cost),
    "cost_shard": float(r_shard.cost),
    "phases_equal": int(r_single.phases) == int(r_shard.phases),
}

# feasibility certificates (Lemma 3.2 etc.) on the MESH-SOLVED integer
# state - the same jit + in_shardings program solve_assignment_sharded runs
scale = float(jnp.max(jnp.asarray(c)))
c_int = round_costs(jnp.asarray(c) / scale, 0.05)
sh = NamedSharding(mesh, P("data", "model"))
state = jax.jit(partial(solve_assignment_int, eps=0.05),
                in_shardings=(sh,))(jax.device_put(c_int, sh))
inv = check_invariants(np.asarray(c_int), np.asarray(state.y_b),
                       np.asarray(state.y_a), np.asarray(state.match_ba),
                       0.05)
out["assign_certificates"] = bool(all(inv.values()))

# sharded general-OT solve: bit-identical to eager solve_ot + certificates
m2 = 48
c2 = rng.uniform(size=(m2, m2)).astype(np.float32)
nu = rng.dirichlet(np.ones(m2)).astype(np.float32)
mu = rng.dirichlet(np.ones(m2)).astype(np.float32)
s_ot = solve_ot(jnp.asarray(c2), jnp.asarray(nu), jnp.asarray(mu), 0.1)
r_ot = solve_ot_sharded(jnp.asarray(c2), jnp.asarray(nu), jnp.asarray(mu),
                        0.1, mesh)
out["ot_equal"] = bool(
    np.array_equal(np.asarray(s_ot.plan), np.asarray(r_ot.plan))
    and float(s_ot.cost) == float(r_ot.cost)
    and int(s_ot.phases) == int(r_ot.phases)
)
c2_int, _, _, _ = ot_prologue(jnp.asarray(c2), jnp.asarray(nu),
                              jnp.asarray(mu), r_ot.theta, 0.1)
inv2 = check_ot_invariants(np.asarray(c2_int), r_ot.state,
                           np.asarray(r_ot.s_int), np.asarray(r_ot.d_int),
                           0.1)
out["ot_certificates"] = bool(all(inv2.values()))

# AOT path: the solver lowers + compiles on the mesh without allocating C
lowered = lower_sharded_solver(1024, 0.05, mesh)
compiled = lowered.compile()
hlo = compiled.as_text()
out["has_collectives"] = any(
    op in hlo for op in ("all-reduce", "all-gather", "collective-permute")
)
from repro.compat import cost_analysis_dict
out["flops"] = cost_analysis_dict(compiled).get("flops", 0)
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_solver_matches_single_device():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # skip the TPU-backend probe (60s timeout in this image)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["match_equal"], out
    assert out["manual_equal"], out   # explicit shard_map schedule too
    assert out["phases_equal"], out
    assert out["cost_single"] == pytest.approx(out["cost_shard"], rel=1e-6)
    assert out["assign_certificates"], out  # Lemma 3.2 etc. on mesh state
    assert out["ot_equal"], out             # sharded OT == eager solve_ot
    assert out["ot_certificates"], out
    assert out["has_collectives"], "SPMD partition produced no collectives"


@pytest.mark.slow
def test_elastic_checkpoint_reshard(tmp_path):
    """Checkpoint written on 1 device restores sharded onto an 8-device
    mesh (elastic rescale) with identical values."""
    import jax.numpy as jnp
    from repro.checkpoint import checkpointing as ckpt

    tree = {"w": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
            "b": jnp.ones((16,), jnp.bfloat16)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree)

    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "import json\n"
        "import numpy as np\n"
        "import jax, jax.numpy as jnp\n"
        "from jax.sharding import NamedSharding, PartitionSpec as P\n"
        "from repro.checkpoint import checkpointing as ckpt\n"
        "from repro.launch.mesh import make_small_mesh\n"
        "mesh = make_small_mesh((2, 4), ('data', 'model'))\n"
        "like = {'w': jnp.zeros((64, 32), jnp.float32),\n"
        "        'b': jnp.zeros((16,), jnp.bfloat16)}\n"
        "sh = {'w': NamedSharding(mesh, P('data', 'model')),\n"
        "      'b': NamedSharding(mesh, P('model'))}\n"
        f"out = ckpt.restore({d!r}, 3, like, shardings=sh)\n"
        "ok_val = bool((np.asarray(out['w']) == "
        "np.arange(64*32, dtype=np.float32).reshape(64, 32)).all())\n"
        "n_shards = len(out['w'].sharding.device_set)\n"
        "print('RESULT:' + json.dumps({'ok': ok_val, "
        "'n_shards': n_shards}))\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # skip the TPU-backend probe (60s timeout in this image)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    out = json.loads(line[0][len("RESULT:"):])
    assert out["ok"] and out["n_shards"] == 8, out


@pytest.mark.slow
def test_dryrun_small_mesh_cells():
    """CI-scale dry-run: reduced configs on a 2x4 mesh must lower+compile
    for one representative arch per family x kind."""
    cells = [
        ("qwen3-4b", "train_4k"),
        ("deepseek-moe-16b", "train_4k"),
        ("mamba2-2.7b", "decode_32k"),
        ("seamless-m4t-medium", "prefill_32k"),
        ("jamba-1.5-large-398b", "decode_32k"),
        ("llava-next-mistral-7b", "train_4k"),
    ]
    script = (
        "import json\n"
        "from repro.launch.dryrun import run_cell\n"
        f"cells = {cells!r}\n"
        "outs = [run_cell(a, s, small=True, smoke=True, unroll=False)"
        " for a, s in cells]\n"
        "print('RESULT:' + json.dumps("
        "[{'arch': o['arch'], 'ok': o['ok'], 'err': o.get('error')}"
        " for o in outs]))\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # skip the TPU-backend probe (60s timeout in this image)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    outs = json.loads(line[0][len("RESULT:"):])
    for o in outs:
        assert o["ok"], o
