"""Distributed push-relabel == single-device push-relabel, bit for bit.

Runs in a subprocess with XLA_FLAGS forcing 8 host devices (the parent
test process must keep seeing 1 device)."""
import json
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core.pushrelabel import solve_assignment
from repro.core.sharded import (
    solve_assignment_sharded, solve_assignment_shardmap, lower_sharded_solver,
)
from repro.launch.mesh import make_small_mesh

rng = np.random.default_rng(0)
n = 96
c = rng.uniform(size=(n, n)).astype(np.float32)
mesh = make_small_mesh((2, 4), ("data", "model"))

r_single = solve_assignment(jnp.asarray(c), 0.05)
r_shard = solve_assignment_sharded(jnp.asarray(c), 0.05, mesh)
r_manual = solve_assignment_shardmap(jnp.asarray(c), 0.05, mesh)

out = {
    "match_equal": bool(
        (np.asarray(r_single.matching) == np.asarray(r_shard.matching)).all()
    ),
    "manual_equal": bool(
        (np.asarray(r_single.matching)
         == np.asarray(r_manual.matching)).all()
    ) and int(r_manual.phases) == int(r_single.phases),
    "cost_single": float(r_single.cost),
    "cost_shard": float(r_shard.cost),
    "phases_equal": int(r_single.phases) == int(r_shard.phases),
}

# AOT path: the solver lowers + compiles on the mesh without allocating C
lowered = lower_sharded_solver(1024, 0.05, mesh)
compiled = lowered.compile()
hlo = compiled.as_text()
out["has_collectives"] = any(
    op in hlo for op in ("all-reduce", "all-gather", "collective-permute")
)
out["flops"] = compiled.cost_analysis().get("flops", 0)
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_solver_matches_single_device():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["match_equal"], out
    assert out["manual_equal"], out   # explicit shard_map schedule too
    assert out["phases_equal"], out
    assert out["cost_single"] == pytest.approx(out["cost_shard"], rel=1e-6)
    assert out["has_collectives"], "SPMD partition produced no collectives"


@pytest.mark.slow
def test_elastic_checkpoint_reshard(tmp_path):
    """Checkpoint written on 1 device restores sharded onto an 8-device
    mesh (elastic rescale) with identical values."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import checkpointing as ckpt

    tree = {"w": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
            "b": jnp.ones((16,), jnp.bfloat16)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree)

    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "import json\n"
        "import numpy as np\n"
        "import jax, jax.numpy as jnp\n"
        "from jax.sharding import NamedSharding, PartitionSpec as P\n"
        "from repro.checkpoint import checkpointing as ckpt\n"
        "from repro.launch.mesh import make_small_mesh\n"
        "mesh = make_small_mesh((2, 4), ('data', 'model'))\n"
        "like = {'w': jnp.zeros((64, 32), jnp.float32),\n"
        "        'b': jnp.zeros((16,), jnp.bfloat16)}\n"
        "sh = {'w': NamedSharding(mesh, P('data', 'model')),\n"
        "      'b': NamedSharding(mesh, P('model'))}\n"
        f"out = ckpt.restore({d!r}, 3, like, shardings=sh)\n"
        "ok_val = bool((np.asarray(out['w']) == "
        "np.arange(64*32, dtype=np.float32).reshape(64, 32)).all())\n"
        "n_shards = len(out['w'].sharding.device_set)\n"
        "print('RESULT:' + json.dumps({'ok': ok_val, "
        "'n_shards': n_shards}))\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    out = json.loads(line[0][len("RESULT:"):])
    assert out["ok"] and out["n_shards"] == 8, out


@pytest.mark.slow
def test_dryrun_small_mesh_cells():
    """CI-scale dry-run: reduced configs on a 2x4 mesh must lower+compile
    for one representative arch per family x kind."""
    cells = [
        ("qwen3-4b", "train_4k"),
        ("deepseek-moe-16b", "train_4k"),
        ("mamba2-2.7b", "decode_32k"),
        ("seamless-m4t-medium", "prefill_32k"),
        ("jamba-1.5-large-398b", "decode_32k"),
        ("llava-next-mistral-7b", "train_4k"),
    ]
    script = (
        "import json\n"
        "from repro.launch.dryrun import run_cell\n"
        f"cells = {cells!r}\n"
        "outs = [run_cell(a, s, small=True, smoke=True, unroll=False)"
        " for a, s in cells]\n"
        "print('RESULT:' + json.dumps("
        "[{'arch': o['arch'], 'ok': o['ok'], 'err': o.get('error')}"
        " for o in outs]))\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    outs = json.loads(line[0][len("RESULT:"):])
    for o in outs:
        assert o["ok"], o
