"""Solver portfolio: Sinkhorn-as-a-spec, the measured auto-policy, and
the hybrid warm start.

Certificate parity is the load-bearing contract: a Solution produced by
ANY portfolio solver must certify the same additive-eps bound through
the same ``additive_gap()``/``dual_feasible()`` surface the push-relabel
solver uses. The hybrid solver additionally must be exactly as feasible
as a cold-start push-relabel solve (its warm initial state satisfies
every paper invariant by construction — ``round_duals`` clips into the
invariant polytope, so a garbage warm start can cost phases but never
correctness).

Float tolerances, documented once here: the Pallas row kernel and the
pure-jnp f-update evaluate the same online logsumexp with different
reduction orders; on f32 that is reassociation-level noise, bounded in
practice well under 1e-5 absolute on O(1)-magnitude potentials. The
chunked-vs-one-shot resumability contract, by contrast, is BIT-exact
(same programs, same order, only the dispatch boundary moves).
"""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.api import OT, DispatchPolicy, dispatch, solve
from repro.core.compaction import solve_compacting, spec_fns
from repro.core.feasibility import check_ot_invariants
from repro.core.problem import eps_array
from repro.portfolio import (
    SINKHORN,
    SINKHORN_KERNEL,
    WARM_OT,
    CostModel,
    dispatch_hybrid,
    fit,
    round_duals,
    set_model,
)
from repro.portfolio.hybrid import _COARSE_EPS, _WARM_ITERS
from repro.portfolio.sinkhorn_spec import (
    SinkhornState,
    _row_update_jnp,
    run_sinkhorn_phases,
    sinkhorn_schedule,
)


@pytest.fixture(scope="module", autouse=True)
def _release_compiler_state():
    # This module compiles three solver families' worth of programs; on
    # single-core CI the XLA compiler segfaults partway into the NEXT
    # test module once that much compiler state has accumulated in the
    # process. Dropping the executable caches when the module finishes
    # keeps the suite under the cliff; later modules just recompile.
    yield
    jax.clear_caches()


def _ot_batch(b, m, n, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.uniform(0.1, 1.0, (b, m, n)).astype(np.float32)
    nu = rng.uniform(0.5, 1.5, (b, m)).astype(np.float32)
    nu /= nu.sum(1, keepdims=True)
    mu = rng.uniform(0.5, 1.5, (b, n)).astype(np.float32)
    mu /= mu.sum(1, keepdims=True)
    return {"c": c, "nu": nu, "mu": mu}


class _Events:
    """Minimal obs stand-in: records every event kind."""

    def __init__(self):
        self.kinds = []

    def event(self, kind, **attrs):
        self.kinds.append((kind, attrs))


# ---------------------------------------------------------------------------
# cross-solver certificate parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eps", [0.3, 0.1])
@pytest.mark.parametrize("mn", [8, 16])
@pytest.mark.parametrize("solver", ["pushrelabel", "sinkhorn", "hybrid"])
def test_certificate_grid(solver, mn, eps):
    inputs = _ot_batch(2, mn, mn, seed=mn)
    pol = DispatchPolicy(mode="compact", solver=solver, guaranteed=True)
    sols = solve(OT, inputs, eps, pol, want=("cost", "duals", "stats"))
    assert sols.stats.solver == solver
    for i in range(2):
        s = sols[i]
        assert bool(s.dual_feasible())
        assert float(s.additive_gap()) <= float(s.additive_gap_bound()) \
            + 1e-6


def test_sinkhorn_marginals_exact():
    # AWR Algorithm 2 rounding: the returned plan sits ON the transport
    # polytope (marginals exact to f32), not merely near it
    inputs = _ot_batch(2, 12, 12, seed=5)
    r, _ = solve_compacting(SINKHORN, inputs, 0.3)
    plan = np.asarray(r.plan, np.float64)
    np.testing.assert_allclose(plan.sum(2), inputs["nu"], atol=2e-6)
    np.testing.assert_allclose(plan.sum(1), inputs["mu"], atol=2e-6)


def test_sinkhorn_padded_lane_regression():
    # padded rows/cols (ragged sizes) once produced -inf potentials via a
    # subnormal log floor that FTZ backends flush to zero -> NaN cost
    b, mb, nb, m, n = 1, 16, 16, 10, 12
    rng = np.random.default_rng(2)
    c = np.zeros((b, mb, nb), np.float32)
    c[0, :m, :n] = rng.uniform(0.1, 1.0, (m, n))
    nu = np.zeros((b, mb), np.float32)
    nu[0, :m] = 1.0 / m
    mu = np.zeros((b, nb), np.float32)
    mu[0, :n] = 1.0 / n
    r, _ = solve_compacting(SINKHORN, {"c": c, "nu": nu, "mu": mu}, 0.3,
                            sizes=np.array([[m, n]], np.int32))
    assert np.isfinite(np.asarray(r.cost)).all()
    plan = np.asarray(r.plan[0], np.float64)
    assert plan[m:, :].sum() + plan[:, n:].sum() < 1e-6
    np.testing.assert_allclose(plan.sum(1)[:m], nu[0, :m], atol=2e-6)


# ---------------------------------------------------------------------------
# resumability + kernel parity
# ---------------------------------------------------------------------------


def test_sinkhorn_chunk_resumable_bit_identical():
    inputs = _ot_batch(3, 12, 12, seed=7)
    r_small, _ = solve_compacting(SINKHORN, inputs, 0.3, k=3)
    r_big, _ = solve_compacting(SINKHORN, inputs, 0.3, k=512)
    for f, a, b in zip(r_small._fields, r_small, r_big):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"field {f}")


def test_kernel_row_update_parity():
    # Pallas flash-style row update vs pure jnp: same online logsumexp,
    # different reduction order -> reassociation-level f32 noise only
    rng = np.random.default_rng(11)
    m, n = 24, 40
    c_hat = rng.uniform(0.0, 1.0, (m, n)).astype(np.float32)
    g = rng.normal(0.0, 0.2, n).astype(np.float32)
    log_nu = np.full(m, -np.log(m), np.float32)
    reg = jnp.float32(0.05)
    from repro.kernels import ops

    ref = _row_update_jnp(jnp.asarray(c_hat), jnp.asarray(g),
                          jnp.asarray(log_nu), reg)
    out = ops.sinkhorn_row_update(jnp.asarray(c_hat), jnp.asarray(g),
                                  jnp.asarray(log_nu), reg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_kernel_spec_matches_stepped_spec():
    inputs = _ot_batch(2, 16, 16, seed=9)
    r_jnp, _ = solve_compacting(SINKHORN, inputs, 0.3)
    r_krn, _ = solve_compacting(SINKHORN_KERNEL, inputs, 0.3)
    np.testing.assert_allclose(np.asarray(r_krn.cost),
                               np.asarray(r_jnp.cost), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_krn.y_b),
                               np.asarray(r_jnp.y_b), atol=1e-5)


def test_fused_policy_resolves_kernel_spec():
    from repro.core.problem import fused_variant

    assert fused_variant(SINKHORN) is SINKHORN_KERNEL
    assert SINKHORN_KERNEL.stepped is SINKHORN


# ---------------------------------------------------------------------------
# schedule (host-f64 thresholds)
# ---------------------------------------------------------------------------


def test_schedule_host_f64():
    eps = np.asarray([0.3, 0.1])
    reg, tol, cap = sinkhorn_schedule(eps, np.array([16, 16]),
                                      np.array([16, 16]))
    assert reg.dtype == np.float64 and tol.dtype == np.float64
    assert cap.dtype == np.int32
    np.testing.assert_allclose(tol, eps / 8.0)
    np.testing.assert_allclose(reg, eps / (4.0 * np.log(16.0)))
    # tiny eps must clip, not overflow, the int32 cap
    _, _, cap2 = sinkhorn_schedule(np.asarray([1e-6]), np.array([16]),
                                   np.array([16]))
    assert cap2[0] == np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# hybrid: warm-start feasibility == cold-start feasibility
# ---------------------------------------------------------------------------


def _warm_initial_state(inputs, eps, seed=1):
    b = inputs["c"].shape[0]
    eps_coarse = np.maximum(np.full(b, eps), _COARSE_EPS)
    _, st1 = solve_compacting(SINKHORN, inputs, eps_coarse,
                              keep_state=True, max_iters=_WARM_ITERS)
    warm = st1.final_state
    eps_int = jnp.asarray(eps_array(eps, b, False), jnp.float32)
    y_b0 = round_duals(jnp.asarray(inputs["c"]), jnp.asarray(inputs["mu"]),
                       warm.f, warm.g, eps_int)
    p = WARM_OT.prepare(WARM_OT.canonicalize(inputs), eps,
                        y_b0=np.asarray(y_b0))
    prologue, init, _, _, _ = spec_fns(WARM_OT, 1)
    ops = {kk: jnp.asarray(v) for kk, v in p.ops.items()}
    data, ctx = prologue(ops)
    ctx = {**ctx, **{kk: ops[kk] for kk in WARM_OT.ctx_ops}}
    return data, ctx, init(data, ctx), p


def test_hybrid_warm_state_invariants():
    inputs = _ot_batch(2, 12, 12, seed=1)
    data, ctx, state0, p = _warm_initial_state(inputs, 0.1)
    for i in range(2):
        one = jax.tree_util.tree_map(lambda a: a[i], state0)
        rep = check_ot_invariants(
            np.asarray(data["c_int"])[i], one,
            np.asarray(ctx["s_int"])[i], np.asarray(ctx["d_int"])[i],
            float(p.eps_arr[i]))
        assert all(rep.values()), rep


def test_hybrid_feasibility_parity_with_cold_start():
    inputs = _ot_batch(2, 12, 12, seed=3)
    eps = 0.1
    pol_h = DispatchPolicy(mode="compact", solver="hybrid",
                           guaranteed=True)
    pol_c = DispatchPolicy(mode="compact", solver="pushrelabel",
                           guaranteed=True)
    sh = solve(OT, inputs, eps, pol_h, want=("cost", "duals", "stats"))
    sc = solve(OT, inputs, eps, pol_c, want=("cost", "duals", "stats"))
    for i in range(2):
        # identical certificate surface: both feasible, both within the
        # same bound (plans may differ — both are eps-optimal)
        assert bool(sh[i].dual_feasible()) == bool(sc[i].dual_feasible()) \
            == True  # noqa: E712
        bound = float(sc[i].additive_gap_bound())
        assert float(sh[i].additive_gap()) <= bound + 1e-6
        assert float(sc[i].additive_gap()) <= bound + 1e-6


def test_hybrid_stats_fold_stage1_dispatches():
    inputs = _ot_batch(2, 12, 12, seed=4)
    r, stats = dispatch_hybrid(inputs, 0.1,
                               policy=DispatchPolicy(mode="compact"))
    # at least one Sinkhorn chunk + one push-relabel chunk
    assert stats.dispatches >= 2
    assert np.isfinite(np.asarray(r.cost)).all()


def test_warm_ot_defaults_to_cold_start():
    # y_b0 omitted -> WARM_OT degrades to plain OT, bit for bit
    inputs = _ot_batch(2, 10, 10, seed=6)
    r_warm, _ = solve_compacting(WARM_OT, inputs, 0.2)
    r_cold, _ = solve_compacting(OT, inputs, 0.2)
    np.testing.assert_array_equal(np.asarray(r_warm.cost),
                                  np.asarray(r_cold.cost))
    np.testing.assert_array_equal(np.asarray(r_warm.y_b),
                                  np.asarray(r_cold.y_b))


# ---------------------------------------------------------------------------
# cost model + auto policy
# ---------------------------------------------------------------------------


def _toy_model(cheap="sinkhorn"):
    rows = []
    for solver in ("pushrelabel", "sinkhorn", "hybrid"):
        rows.append({"solver": solver, "n": 16, "eps": 0.1,
                     "per_instance_s": 0.001 if solver == cheap else 0.5})
    return fit(rows, mode="interpret", backend="cpu")


def test_costmodel_roundtrip(tmp_path):
    model = _toy_model()
    path = str(tmp_path / "cm.json")
    model.save(path)
    loaded = CostModel.load(path)
    assert loaded == model
    payload = json.loads(open(path).read())
    assert payload["mode"] == "interpret"  # honest-labeling survives disk
    # log-nearest snapping: n=20 -> bucket 16, eps=0.12 -> band 0.1
    assert loaded.predict("sinkhorn", 20, 0.12) == \
        loaded.predict("sinkhorn", 16, 0.1)
    assert loaded.choose(16, 0.1)[0] == "sinkhorn"


def test_auto_bit_identical_to_named_choice():
    inputs = _ot_batch(2, 14, 14, seed=8)
    set_model(_toy_model(cheap="sinkhorn"))
    try:
        sa = solve(OT, inputs, 0.1,
                   DispatchPolicy(mode="compact", solver="auto"),
                   want=("cost", "duals", "stats"))
        sn = solve(OT, inputs, 0.1,
                   DispatchPolicy(mode="compact", solver="sinkhorn"),
                   want=("cost", "duals", "stats"))
        assert sa.stats.solver == "sinkhorn"
        assert sa.stats.predicted_s is not None
        for i in range(2):
            np.testing.assert_array_equal(np.asarray(sa[i].cost),
                                          np.asarray(sn[i].cost))
    finally:
        set_model(None)


def test_auto_without_model_falls_back_to_pushrelabel():
    set_model(CostModel(mode="interpret", backend="cpu", entries={}))
    try:
        inputs = _ot_batch(1, 8, 8, seed=10)
        s = solve(OT, inputs, 0.3,
                  DispatchPolicy(mode="compact", solver="auto"),
                  want=("cost", "stats"))
        assert s.stats.solver == "pushrelabel"
    finally:
        set_model(None)


def test_assignment_ignores_solver_knob():
    from repro.core.api import ASSIGNMENT

    rng = np.random.default_rng(12)
    c = rng.uniform(0.1, 1.0, (2, 8, 8)).astype(np.float32)
    s = solve(ASSIGNMENT, {"c": c}, 0.3,
              DispatchPolicy(mode="compact", solver="sinkhorn"),
              want=("cost", "stats"))
    assert s.stats.solver == "pushrelabel"


def test_policy_rejects_unknown_solver():
    with pytest.raises(ValueError, match="unknown solver"):
        DispatchPolicy(solver="simplex")


def test_solver_choice_obs_event_and_stats_surface():
    inputs = _ot_batch(2, 10, 10, seed=13)
    obs = _Events()
    _, stats = dispatch(OT, inputs, 0.3,
                        policy=DispatchPolicy(mode="compact",
                                              solver="sinkhorn"),
                        obs=obs)
    kinds = [k for k, _ in obs.kinds]
    assert "solver-choice" in kinds
    ev = dict(obs.kinds)["solver-choice"]
    assert ev["solver"] == "sinkhorn"
    assert stats.solver == "sinkhorn"
    assert stats.solve_s > 0
    # SolveStats surface carries the portfolio fields through as_dict
    from repro.core.solution import SolveStats

    d = SolveStats.from_driver(stats, mode="compact", batch=2,
                               solver="sinkhorn",
                               predicted_s=0.5).as_dict()
    assert d["solver"] == "sinkhorn"
    assert d["predicted_s"] == 0.5
    assert d["actual_s"] == stats.solve_s


# ---------------------------------------------------------------------------
# serving layers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("solver", ["sinkhorn", "hybrid"])
def test_otservice_portfolio_end_to_end(solver):
    from repro.serve.engine import OTService

    rng = np.random.default_rng(14)
    svc = OTService(eps=0.3, compact=True, solver=solver,
                    want=("cost", "duals", "stats"))
    for _ in range(2):
        x = rng.normal(size=(10, 2))
        y = rng.normal(size=(12, 2))
        nu = np.abs(rng.normal(size=10)) + 0.1
        mu = np.abs(rng.normal(size=12)) + 0.1
        svc.submit(x, y, nu=nu / nu.sum(), mu=mu / mu.sum())
    for s in svc.run_batch():
        assert s.stats.solver == solver
        assert bool(s.dual_feasible())
        assert float(s.additive_gap()) <= float(s.additive_gap_bound()) \
            + 1e-6


def test_scheduler_portfolio_end_to_end():
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(15)
    sched = AsyncOTScheduler(eps=0.3, solver="sinkhorn",
                             want=("cost", "duals", "stats"),
                             linger_ms=5.0)
    try:
        futs = []
        for _ in range(2):
            x = rng.normal(size=(8, 2))
            y = rng.normal(size=(8, 2))
            nu = np.abs(rng.normal(size=8)) + 0.1
            mu = np.abs(rng.normal(size=8)) + 0.1
            futs.append(sched.submit(x, y, nu=nu / nu.sum(),
                                     mu=mu / mu.sum()))
        for f in futs:
            s = f.result(timeout=120)
            assert s.stats.solver == "sinkhorn"
            assert float(s.additive_gap()) <= \
                float(s.additive_gap_bound()) + 1e-6
    finally:
        sched.close()


# ---------------------------------------------------------------------------
# stepped-core unit: the run_phases loop honors caps and chunk budgets
# ---------------------------------------------------------------------------


def test_run_phases_respects_k_and_cap():
    m = n = 8
    rng = np.random.default_rng(16)
    c_hat = jnp.asarray(rng.uniform(0, 1, (m, n)), jnp.float32)
    log_nu = jnp.full((m,), -np.log(m), jnp.float32)
    log_mu = jnp.full((n,), -np.log(n), jnp.float32)
    nu_hat = jnp.full((m,), 1.0 / m, jnp.float32)
    st = SinkhornState(f=jnp.zeros(m), g=jnp.zeros(n),
                       err=jnp.asarray(jnp.inf, jnp.float32),
                       phases=jnp.zeros((), jnp.int32))
    out = run_sinkhorn_phases(c_hat, log_nu, log_mu, nu_hat,
                              jnp.float32(0.05), jnp.float32(1e-9),
                              jnp.int32(1000), st, 4)
    assert int(out.phases) == 4           # chunk budget
    out2 = run_sinkhorn_phases(c_hat, log_nu, log_mu, nu_hat,
                               jnp.float32(0.05), jnp.float32(1e-9),
                               jnp.int32(6), out, 100)
    assert int(out2.phases) == 6          # AWR cap wins over k
