"""Sanitizer-mode (checkify) tests: poisoned inputs and corrupted solver
states must raise USEFUL errors under ``set_debug_checks(True)`` instead
of silently converging to garbage (the production path is numerically
silent by design)."""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis import set_debug_checks
from repro.analysis.checkified import checkified_spec_fns
from repro.core.compaction import (
    _tiny_batch,
    solve_assignment_batched_compacting,
    solve_ot_batched_compacting,
)
from repro.core.problem import ASSIGNMENT, OT


@pytest.fixture
def debug_checks():
    set_debug_checks(True)
    yield
    set_debug_checks(None)


def _rand(b=4, mn=8, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.random((b, mn, mn)).astype(np.float32)
    nu = np.full((b, mn), 1.0 / mn, np.float32)
    mu = np.full((b, mn), 1.0 / mn, np.float32)
    return c, nu, mu


# --------------------------------------------------------------------------
# Clean inputs: debug mode must be a pure no-op on results
# --------------------------------------------------------------------------

def test_debug_mode_bit_identical_assignment(debug_checks):
    c, _, _ = _rand()
    set_debug_checks(None)
    plain, _ = solve_assignment_batched_compacting(c, 0.1, k=3)
    set_debug_checks(True)
    dbg, _ = solve_assignment_batched_compacting(c, 0.1, k=3)
    np.testing.assert_array_equal(np.asarray(plain.cost),
                                  np.asarray(dbg.cost))
    np.testing.assert_array_equal(np.asarray(plain.matching),
                                  np.asarray(dbg.matching))


def test_debug_mode_bit_identical_ot(debug_checks):
    c, nu, mu = _rand()
    set_debug_checks(None)
    plain, _ = solve_ot_batched_compacting(c, nu, mu, 0.25, k=3)
    set_debug_checks(True)
    dbg, _ = solve_ot_batched_compacting(c, nu, mu, 0.25, k=3)
    np.testing.assert_array_equal(np.asarray(plain.cost),
                                  np.asarray(dbg.cost))


# --------------------------------------------------------------------------
# NaN-poisoned cost matrices
# --------------------------------------------------------------------------

def test_nan_cost_raises_assignment(debug_checks):
    c, _, _ = _rand()
    c[1, 2, 3] = np.nan
    with pytest.raises(Exception, match="nan"):
        solve_assignment_batched_compacting(c, 0.1, k=3)


def test_nan_cost_raises_ot(debug_checks):
    c, nu, mu = _rand()
    c[0, 0, 0] = np.nan
    with pytest.raises(Exception, match="nan"):
        solve_ot_batched_compacting(c, nu, mu, 0.25, k=3)


def test_nan_cost_silent_without_debug():
    """The production path stays numerically silent — that asymmetry is
    the reason the sanitizer layer exists.  Pin the checks OFF (not the
    env default) so the test still targets the plain path when the whole
    suite runs under ``REPRO_DEBUG_CHECKS=1`` (the CI chaos job)."""
    c, _, _ = _rand()
    c[1, 2, 3] = np.nan
    set_debug_checks(False)
    try:
        r, _ = solve_assignment_batched_compacting(c, 0.1, k=3)
    finally:
        set_debug_checks(None)
    assert np.asarray(r.cost).shape == (4,)   # no exception


# --------------------------------------------------------------------------
# Corrupted solver state (the invariant checks)
# --------------------------------------------------------------------------

def test_out_of_range_matching_index_raises():
    _, _, data, state = _tiny_batch("assignment")
    bad = state._replace(
        match_ba=jnp.full_like(state.match_ba, 99))
    _, _, chunk, _, _ = checkified_spec_fns(ASSIGNMENT, 2)
    with pytest.raises(Exception, match="matching index out of range"):
        chunk(data, bad)


def test_negative_free_mass_raises():
    _, _, data, state = _tiny_batch("ot")
    bad = state._replace(free_b=jnp.full_like(state.free_b, -5))
    _, _, chunk, _, _ = checkified_spec_fns(OT, 2)
    with pytest.raises(Exception, match="negative free mass"):
        chunk(data, bad)


def test_clean_state_passes_invariants():
    for name, spec in (("assignment", ASSIGNMENT), ("ot", OT)):
        _, _, data, state = _tiny_batch(name)
        _, _, chunk, _, _ = checkified_spec_fns(spec, 2)
        out = chunk(data, state)      # must not raise
        assert out.phases.shape == state.phases.shape


# --------------------------------------------------------------------------
# The env-var switch
# --------------------------------------------------------------------------

def test_env_var_enables_debug(monkeypatch):
    from repro.analysis import debug_checks_enabled
    monkeypatch.setenv("REPRO_DEBUG_CHECKS", "1")
    assert debug_checks_enabled()
    monkeypatch.setenv("REPRO_DEBUG_CHECKS", "0")
    assert not debug_checks_enabled()
    monkeypatch.setenv("REPRO_DEBUG_CHECKS", "off")
    assert not debug_checks_enabled()
