"""Fault-tolerant serving: admission gate, quarantine/bisection, the
degradation ladder, deadlines, and the chaos harness (serve/faults.py).

The three system-level properties every scenario re-asserts:
  * no Future is ever stranded — every accepted request resolves
    (normally, degraded, or exceptionally), whatever fails around it;
  * no healthy request is lost to a neighbor's fault: survivors of a
    poisoned bucket resolve BIT-IDENTICAL to a clean run (composition
    invariance of the batched drivers is what makes quarantine sound);
  * every degraded (deadline-cut) answer still carries a valid
    a-posteriori certificate: ``dual_feasible()`` holds and the larger
    ``additive_gap()`` is reported honestly.

The slow test replays the poisoned-bucket scenario on 8 forced host CPU
devices (subprocess, same harness as tests/test_distributed.py) so the
mesh path's quarantine is exercised with real sharding.
"""
import json
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import validate as V
from repro.core.api import ASSIGNMENT, OT, DispatchPolicy, dispatch, solve
from repro.serve.engine import OTService
from repro.serve.faults import (
    FaultInjector,
    FaultPlan,
    PoisonedDispatchError,
    WorkerDeath,
)
from repro.serve.ft import (
    RequestRejected,
    TransientDispatchError,
    degradation_ladder,
    is_poison,
    is_transient,
    require_mass_pair,
    run_with_recovery,
)
from repro.serve.scheduler import AsyncOTScheduler


def _pts(rng, m, d=2):
    return rng.standard_normal((int(m), d)).astype(np.float32)


def _cloud_batch(seed, n_req, m=10):
    """Deterministic list of (x, y) point-cloud requests."""
    rng = np.random.default_rng(seed)
    return [(_pts(rng, m), _pts(rng, m)) for _ in range(n_req)]


# --------------------------------------------------------------------------
# admission gate (core/validate.py)
# --------------------------------------------------------------------------

def test_admission_codes_bitmask():
    b, m, n = 4, 6, 6
    rng = np.random.default_rng(0)
    c = np.abs(rng.standard_normal((b, m, n))).astype(np.float32)
    nu = np.full((b, m), 1.0 / m, np.float32)
    mu = np.full((b, n), 1.0 / n, np.float32)
    c[1, 2, 3] = np.nan                      # lane 1: poisoned cost
    nu[2] *= 3.0                             # lane 2: imbalanced marginals
    mu[3, 0] = -0.5                          # lane 3: negative mass (and
    #                                          the removed mass imbalances)
    codes = V.admission_codes({"c": c, "nu": nu, "mu": mu})
    assert codes.dtype == np.int32
    assert codes[0] == V.OK
    assert codes[1] == V.NONFINITE_COST
    assert codes[2] == V.MASS_IMBALANCE
    assert codes[3] & V.NEGATIVE_MASS
    assert "negative" in V.describe(int(codes[3]))
    with pytest.raises(RequestRejected) as ei:
        V.check_admission({"c": c, "nu": nu, "mu": mu})
    assert ei.value.code != 0
    # assignment mode only checks cost finiteness
    codes_a = V.admission_codes({"c": c})
    assert list(codes_a) == [V.OK, V.NONFINITE_COST, V.OK, V.OK]


def test_admission_respects_sizes_padding():
    """NaN in the PADDING region of a lane must not reject it."""
    c = np.zeros((2, 4, 4), np.float32)
    c[0, 3, 3] = np.nan                      # outside lane 0's 2x2 block
    c[1, 1, 1] = np.nan                      # inside lane 1's block
    sizes = np.array([[2, 2], [3, 3]], np.int32)
    codes = V.admission_codes({"c": c}, sizes=sizes)
    assert list(codes) == [V.OK, V.NONFINITE_COST]


def test_dispatch_policy_validate_gate():
    """DispatchPolicy(validate=True) is all-or-nothing at the direct API."""
    c = np.abs(np.random.default_rng(1).standard_normal((2, 5, 5)))
    c = c.astype(np.float32)
    bad = c.copy()
    bad[1, 0, 0] = np.inf
    pol = DispatchPolicy(mode="compact", validate=True)
    sol = solve(ASSIGNMENT, {"c": c}, 0.1, pol, want=("cost",))
    assert np.isfinite(np.asarray(sol.cost())).all()
    with pytest.raises(RequestRejected):
        solve(ASSIGNMENT, {"c": bad}, 0.1, pol, want=("cost",))


# --------------------------------------------------------------------------
# request validation naming (ft.require_mass_pair — the one home)
# --------------------------------------------------------------------------

def test_mass_pair_rule_names_the_offender():
    with pytest.raises(ValueError, match="tenant 'acme'.*only nu"):
        with AsyncOTScheduler(eps=0.2) as sched:
            sched.submit(np.ones((4, 2)), np.ones((4, 2)),
                         nu=np.ones(4), tenant="acme")
    svc = OTService(eps=0.2)
    with pytest.raises(ValueError, match="ticket #0.*only mu"):
        svc.submit(np.ones((4, 2)), np.ones((4, 2)), mu=np.ones(4))
    assert require_mass_pair(np.ones(3), np.ones(3)) is True
    assert require_mass_pair(None, None) is False


# --------------------------------------------------------------------------
# failure classification + ladder (ft.py unit behavior)
# --------------------------------------------------------------------------

def test_failure_taxonomy():
    assert is_transient(TransientDispatchError("x"))
    assert not is_transient(PoisonedDispatchError("x"))
    assert is_poison(PoisonedDispatchError("x"))
    assert is_poison(FloatingPointError("nan"))
    assert not is_poison(TransientDispatchError("x"))
    assert not is_poison(ValueError("x"))


def test_run_with_recovery_walks_ladder_and_backoff():
    ladder = [("mesh", "P0", None), ("compact", "P1", None),
              ("cpu", "P2", "dev")]
    calls, naps = [], []

    def attempt(name, pol, dev):
        calls.append((name, pol, dev))
        if len(calls) < 4:
            raise TransientDispatchError("boom")
        return "ok"

    out, level, total = run_with_recovery(
        attempt, ladder, retries_per_level=2, backoff_s=0.01,
        sleep=naps.append)
    assert (out, level, total) == ("ok", 1, 4)
    assert [c[0] for c in calls] == ["mesh", "mesh", "compact", "compact"]
    assert naps == [0.01, 0.02, 0.01]        # exponential per rung

    # poison propagates immediately — never retried
    def poisoned(name, pol, dev):
        raise PoisonedDispatchError("data")

    with pytest.raises(PoisonedDispatchError):
        run_with_recovery(poisoned, ladder, transient=is_transient,
                          sleep=naps.append)

    # exhausted ladder re-raises the last transient error
    def always(name, pol, dev):
        raise TransientDispatchError("always")

    with pytest.raises(TransientDispatchError):
        run_with_recovery(always, ladder, retries_per_level=1,
                          backoff_s=0.0)


def test_degradation_ladder_shape():
    mesh_pol = DispatchPolicy(mode="mesh")
    rungs = degradation_ladder(mesh_pol)
    assert [r[0] for r in rungs][:2] == ["mesh", "compact"]
    assert rungs[-1][0] == "cpu" and rungs[-1][2] is not None
    compact_pol = DispatchPolicy(mode="compact")
    names = [r[0] for r in degradation_ladder(compact_pol)]
    assert names[0] == "compact" and "mesh" not in names


# --------------------------------------------------------------------------
# scheduler: quarantine, bisection, retries, deadlines (in-process)
# --------------------------------------------------------------------------

def test_scheduler_quarantine_survivors_bit_identical():
    reqs = _cloud_batch(seed=7, n_req=5)
    with AsyncOTScheduler(eps=0.2, linger_ms=100) as clean:
        clean_costs = [f.result(timeout=300)["cost"]
                       for f in [clean.submit(x, y) for x, y in reqs]]

    inj = FaultInjector(FaultPlan(poison_submits=(2,)))
    with AsyncOTScheduler(eps=0.2, linger_ms=100, faults=inj) as sched:
        futs = [sched.submit(x, y) for x, y in reqs]
        sched.flush(timeout=300)
        assert all(f.done() for f in futs)             # nobody stranded
        with pytest.raises(RequestRejected, match="request #2"):
            futs[2].result(timeout=0)
        for i in (0, 1, 3, 4):                         # healthy neighbors
            assert futs[i].result(timeout=0)["cost"] == clean_costs[i]
        sd = sched.stats_dict()
        assert sd["rejected"] == 1 and sd["requests"] == 4
    assert inj.log == [("poison", 2)]


def test_scheduler_bisection_isolates_dispatch_poison():
    """Dispatch-time poison (survives admission) is isolated by halving:
    only the offender is quarantined, every survivor matches clean."""
    reqs = _cloud_batch(seed=8, n_req=6)
    with AsyncOTScheduler(eps=0.2, linger_ms=100) as clean:
        clean_costs = [f.result(timeout=300)["cost"]
                       for f in [clean.submit(x, y) for x, y in reqs]]

    inj = FaultInjector(FaultPlan(poison_dispatch_of=(3,)))
    with AsyncOTScheduler(eps=0.2, linger_ms=100, faults=inj,
                          validate=False) as sched:
        futs = [sched.submit(x, y) for x, y in reqs]
        sched.flush(timeout=300)
        with pytest.raises(RequestRejected, match="bisection"):
            futs[3].result(timeout=0)
        for i in (0, 1, 2, 4, 5):
            assert futs[i].result(timeout=0)["cost"] == clean_costs[i]
        sd = sched.stats_dict()
        assert sd["quarantined"] == 1
        # typed surface carries the accounting too
        f = sched.submit(*reqs[0], want=("cost",))
        assert f.result(timeout=300).stats.quarantined == 0
    assert ("poison-dispatch", 0) in inj.log


def test_scheduler_checkify_triggered_bisection():
    """With validation OFF and the checkify sanitizer ON, a NaN input is
    caught mid-dispatch (JaxRuntimeError) and bisection still isolates it
    — the detection path the admission gate normally short-circuits."""
    from repro.analysis import set_debug_checks

    reqs = _cloud_batch(seed=9, n_req=4)
    inj = FaultInjector(FaultPlan(poison_submits=(1,)))
    set_debug_checks(True)
    try:
        # compact policy: the checkified stepped cores are dispatched by
        # the single-device compacting driver
        with AsyncOTScheduler(
                eps=0.2, linger_ms=100, faults=inj, validate=False,
                policy=DispatchPolicy(mode="compact")) as sched:
            futs = [sched.submit(x, y) for x, y in reqs]
            sched.flush(timeout=600)
            assert all(f.done() for f in futs)
            with pytest.raises(RequestRejected, match="request #1"):
                futs[1].result(timeout=0)
            for i in (0, 2, 3):
                assert np.isfinite(futs[i].result(timeout=0)["cost"])
            assert sched.stats_dict()["quarantined"] == 1
    finally:
        set_debug_checks(None)


def test_scheduler_transient_retries_down_ladder():
    reqs = _cloud_batch(seed=10, n_req=3)
    with AsyncOTScheduler(eps=0.2, linger_ms=100) as clean:
        clean_costs = [f.result(timeout=300).cost
                       for f in [clean.submit(x, y, want=("cost",))
                                 for x, y in reqs]]

    # 2 transient failures with retries_per_level=2: attempt 1+2 fail on
    # the configured rung, attempt 3 succeeds one rung down
    inj = FaultInjector(FaultPlan(transient_dispatches=2))
    with AsyncOTScheduler(eps=0.2, linger_ms=100, faults=inj,
                          retries_per_level=2,
                          retry_backoff_s=0.001) as sched:
        futs = [sched.submit(x, y, want=("cost",)) for x, y in reqs]
        sols = [f.result(timeout=300) for f in futs]
        st = sols[0].stats
        assert (st.attempts, st.ladder_level) == (3, 1)
        # bit-identical results despite landing on a different rung (the
        # distributed driver equals the compacting driver lane-for-lane)
        for sol, ref in zip(sols, clean_costs):
            assert sol.cost == ref
        assert sched.stats_dict()["retries"] == 2
    assert inj.log == [("transient", 0), ("transient", 1)]


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_death_strands_no_future():
    """WorkerDeath derives from SystemExit: no recovery path catches it,
    the dispatch thread dies mid-item (hence the ignored thread-exception
    warning) — flush() must detect the dead worker and fail, not strand,
    the in-flight Futures."""
    reqs = _cloud_batch(seed=11, n_req=3)
    inj = FaultInjector(FaultPlan(kill_worker_at_dispatch=0))
    sched = AsyncOTScheduler(eps=0.2, linger_ms=50, faults=inj,
                             join_timeout_s=5)
    futs = [sched.submit(x, y) for x, y in reqs]
    assert sched.flush(timeout=120)
    for f in futs:                            # failed, not stranded
        assert f.done()
        with pytest.raises(RuntimeError):
            f.result(timeout=0)
    sched.close()                             # dead (joined) worker: no raise
    assert not sched._pending
    assert inj.log == [("kill", 0)]


def test_chaos_combined_latency_transient_poison():
    """Everything at once: latency on every attempt, transient failures,
    an admission-poisoned lane AND a dispatch-poisoned lane. Every Future
    resolves; the healthy ones match a clean run bit-identically."""
    reqs = _cloud_batch(seed=12, n_req=6)
    with AsyncOTScheduler(eps=0.2, linger_ms=100) as clean:
        clean_costs = [f.result(timeout=300)["cost"]
                       for f in [clean.submit(x, y) for x, y in reqs]]

    inj = FaultInjector(FaultPlan(
        poison_submits=(1,), poison_dispatch_of=(4,),
        transient_dispatches=1, dispatch_latency_s=0.01))
    with AsyncOTScheduler(eps=0.2, linger_ms=100, faults=inj,
                          retries_per_level=2,
                          retry_backoff_s=0.001) as sched:
        futs = [sched.submit(x, y) for x, y in reqs]
        sched.flush(timeout=600)
        assert all(f.done() for f in futs)
        for i in (1, 4):
            with pytest.raises(RequestRejected):
                futs[i].result(timeout=0)
        for i in (0, 2, 3, 5):
            assert futs[i].result(timeout=0)["cost"] == clean_costs[i]
        sd = sched.stats_dict()
        assert sd["rejected"] == 1 and sd["quarantined"] == 1
        assert sd["retries"] >= 1
    kinds = [k for k, _ in inj.log]
    assert "poison" in kinds and "poison-dispatch" in kinds
    assert "transient" in kinds


# --------------------------------------------------------------------------
# deadlines and degraded Solutions
# --------------------------------------------------------------------------

def test_deadline_degraded_certificate_direct_api():
    """An already-expired budget cuts after the mandatory first chunk:
    the answer is flagged degraded, its duals are still eps-feasible
    (invariant I2 holds at every phase), and its reported gap honestly
    dominates the converged run's."""
    rng = np.random.default_rng(13)
    b, m = 3, 48
    c = np.abs(rng.standard_normal((b, m, m))).astype(np.float32)
    nu = np.float32(rng.dirichlet(np.ones(m), size=b))
    mu = np.float32(rng.dirichlet(np.ones(m), size=b))
    ins = {"c": c, "nu": nu, "mu": mu}
    pol = DispatchPolicy(mode="compact", chunk=1)
    want = ("cost", "duals", "plan")
    cut = solve(OT, ins, 0.02, pol, want=want, deadline=time.monotonic())
    full = solve(OT, ins, 0.02, pol, want=want)
    assert cut.degraded().all()
    assert not full.degraded().any()
    assert cut.stats.deadline_hit and not full.stats.deadline_hit
    assert cut.stats.dispatches < full.stats.dispatches
    for i in range(b):
        assert bool(cut[i].dual_feasible())
        assert bool(full[i].dual_feasible())
        assert float(cut[i].additive_gap()) >= float(full[i].additive_gap())
        assert np.isfinite(float(cut[i].additive_gap()))
    # legacy dicts only grow the key when actually degraded
    assert cut[0].legacy_dict()["degraded"] is True
    assert "degraded" not in full[0].legacy_dict()


def test_deadline_requires_chunked_driver():
    c = np.abs(np.random.default_rng(2).standard_normal((2, 6, 6)))
    with pytest.raises(ValueError, match="deadline"):
        dispatch(ASSIGNMENT, {"c": np.float32(c)}, 0.1,
                 policy=DispatchPolicy(mode="lockstep"),
                 deadline=time.monotonic() + 9.0)


def test_deadline_via_scheduler_degrades_not_fails():
    rng = np.random.default_rng(14)
    with AsyncOTScheduler(
            eps=0.02, linger_ms=100,
            policy=DispatchPolicy(mode="compact", chunk=1)) as sched:
        futs = [sched.submit(_pts(rng, 48), _pts(rng, 48),
                             want=("cost", "duals"), deadline=0.0)
                for _ in range(2)]
        sols = [f.result(timeout=600) for f in futs]
        assert all(s.degraded for s in sols)
        assert all(bool(s.dual_feasible()) for s in sols)
        assert all(np.isfinite(float(s.additive_gap())) for s in sols)
        sd = sched.stats_dict()
        assert sd["degraded"] == 2 and sd["deadline_hits"] >= 1
    # a generous budget converges normally
    with AsyncOTScheduler(eps=0.2, linger_ms=0) as sched:
        f = sched.submit(_pts(rng, 10), _pts(rng, 10), want=("cost",),
                         deadline=600.0)
        assert f.result(timeout=600).degraded is False


# --------------------------------------------------------------------------
# synchronous service quarantine
# --------------------------------------------------------------------------

def test_service_quarantine_survivors_bit_identical():
    reqs = _cloud_batch(seed=15, n_req=4)
    clean = OTService(eps=0.2)
    for x, y in reqs:
        clean.submit(x, y)
    clean_costs = [r["cost"] for r in clean.run_batch()]

    svc = OTService(eps=0.2)
    for i, (x, y) in enumerate(reqs):
        if i == 2:
            x = x.copy()
            x[0, 0] = np.nan
        svc.submit(x, y)
    res = svc.run_batch()
    assert isinstance(res[2], RequestRejected) and res[2].code != 0
    for i in (0, 1, 3):
        assert res[i]["cost"] == clean_costs[i]
    # one-shot convenience raises instead of returning the exception
    bad = reqs[0][0].copy()
    bad[0, 0] = np.inf
    with pytest.raises(RequestRejected):
        OTService(eps=0.2).distance(bad, reqs[0][1])


# --------------------------------------------------------------------------
# 8-device mesh quarantine (subprocess, slow)
# --------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax

from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.ft import RequestRejected
from repro.serve.scheduler import AsyncOTScheduler

out = {"devices": jax.device_count()}
rng = np.random.default_rng(42)
reqs = [(np.float32(rng.standard_normal((12, 2))),
         np.float32(rng.standard_normal((12, 2)))) for _ in range(16)]

with AsyncOTScheduler(eps=0.2, linger_ms=200) as clean:
    clean_costs = [f.result(timeout=900)["cost"]
                   for f in [clean.submit(x, y) for x, y in reqs]]

inj = FaultInjector(FaultPlan(poison_submits=(5,), poison_dispatch_of=(9,)))
with AsyncOTScheduler(eps=0.2, linger_ms=200, faults=inj,
                      join_timeout_s=60) as sched:
    futs = [sched.submit(x, y) for x, y in reqs]
    sched.flush(timeout=900)
    out["all_done"] = all(f.done() for f in futs)
    rejected = sorted(i for i, f in enumerate(futs)
                      if isinstance(f.exception(timeout=0), RequestRejected))
    out["rejected"] = rejected
    out["survivors_identical"] = all(
        futs[i].result(timeout=0)["cost"] == clean_costs[i]
        for i in range(16) if i not in (5, 9))
    sd = sched.stats_dict()
    out["stats"] = {k: sd[k] for k in ("rejected", "quarantined")}
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh_quarantine_eight_devices():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # skip the TPU-backend probe (60s timeout in this image)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["devices"] == 8, out
    assert out["all_done"], out
    assert out["rejected"] == [5, 9], out
    assert out["survivors_identical"], out
    assert out["stats"] == {"rejected": 1, "quarantined": 1}, out
