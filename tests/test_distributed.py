"""Mesh-distributed dispatch subsystem (core/distributed.py) + the async
bucket scheduler (serve/scheduler.py).

Fast (in-process, 1 device — the distributed driver degrades gracefully):
  * distributed results bit-identical to the single-device compacting
    driver, including mixed per-instance eps;
  * placement policy unit behavior; pow2 mesh validation;
  * ragged front end + OTService mesh routing;
  * scheduler end-to-end: futures resolve to the synchronous service's
    results, wait/occupancy stats attached;
  * feasibility certificates (Lemma 3.2 etc.) on the distributed final
    states.

Multi-device (subprocess with 8 forced host CPU devices, same harness as
tests/test_sharded_ot.py, marked slow):
  * batch placement bit-identical to the single-device compacting solve
    across re-bucketing boundaries (occupancy descends through several
    bucket sizes and collapses below the device floor) and with mixed
    per-instance eps;
  * matrix placement integer-exact vs unbatched solves (float epilogue to
    1e-6, the documented shape-reassociation caveat);
  * certificates on the mesh-sharded outputs.
"""
import json
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.compaction import (
    solve_assignment_batched_compacting,
    solve_ot_batched_compacting,
)
from repro.core.distributed import (
    _require_pow2,
    choose_placement,
    solve_assignment_distributed,
    solve_ot_distributed,
)
from repro.core.feasibility import check_invariants, check_ot_invariants
from repro.core.pushrelabel import assignment_prologue
from repro.core.transport import ot_prologue


def _skewed_batch(b, mb, nb, seed, n_slow=2):
    rng = np.random.default_rng(seed)
    c = np.zeros((b, mb, nb), np.float32)
    nu = np.zeros((b, mb), np.float32)
    mu = np.zeros((b, nb), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i in range(b):
        m = int(rng.integers(mb // 2 + 1, mb + 1))
        n = int(rng.integers(m, nb + 1))
        x = rng.uniform(size=(m, 2))
        if i < n_slow:
            y = np.where(np.arange(n)[:, None] % 2 == 0,
                         x[np.arange(n) % m] * 0.02,
                         1.0 - 0.02 * rng.uniform(size=(n, 2)))
        else:
            y = rng.uniform(size=(n, 2))
        d = x[:, None, :] - y[None, :, :]
        c[i, :m, :n] = np.sqrt((d * d).sum(-1) + 1e-30)
        nu[i, :m] = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu[i, :n] = rng.dirichlet(np.ones(n)).astype(np.float32)
        sizes[i] = (m, n)
    return c, nu, mu, sizes


# --------------------------------------------------------------------------
# Fast in-process coverage (1 device)
# --------------------------------------------------------------------------

def test_distributed_equals_compacting_ot():
    c, nu, mu, sizes = _skewed_batch(5, 32, 32, seed=3)
    r0, s0 = solve_ot_batched_compacting(c, nu, mu, 0.1, sizes=sizes, k=3)
    r1, s1 = solve_ot_distributed(c, nu, mu, 0.1, sizes=sizes, k=3)
    np.testing.assert_array_equal(np.asarray(r0.plan), np.asarray(r1.plan))
    np.testing.assert_array_equal(np.asarray(r0.cost), np.asarray(r1.cost))
    np.testing.assert_array_equal(np.asarray(r0.phases),
                                  np.asarray(r1.phases))
    assert s1.placement == "batch"
    assert s1.occupancy[-1][1] == 0
    assert s1.as_dict()["devices"] == s1.devices


def test_distributed_equals_compacting_assignment_mixed_eps():
    eps = np.asarray([0.2, 0.05, 0.1, 0.05, 0.1])
    c, _, _, sizes = _skewed_batch(5, 32, 32, seed=7)
    r0, _ = solve_assignment_batched_compacting(c, eps, sizes=sizes, k=2)
    r1, _ = solve_assignment_distributed(c, eps, sizes=sizes, k=2)
    np.testing.assert_array_equal(np.asarray(r0.matching),
                                  np.asarray(r1.matching))
    np.testing.assert_array_equal(np.asarray(r0.cost), np.asarray(r1.cost))
    np.testing.assert_array_equal(np.asarray(r0.y_b), np.asarray(r1.y_b))


def test_placement_policy():
    # many small instances -> batch; few large -> matrix; 1 device -> batch
    assert choose_placement(32, 64, 64, 8) == "batch"
    assert choose_placement(8, 256, 256, 8) == "batch"
    assert choose_placement(2, 256, 256, 8) == "matrix"
    assert choose_placement(2, 32, 32, 8) == "batch"
    assert choose_placement(2, 256, 256, 1) == "batch"
    with pytest.raises(ValueError):
        _require_pow2(6)
    _require_pow2(8)


def test_ragged_and_service_mesh_routing():
    from repro.core.batched import solve_ot_ragged
    from repro.launch.mesh import make_batch_mesh

    rng = np.random.default_rng(11)
    insts = []
    for m in (12, 20, 18):
        x = rng.uniform(size=(m, 2))
        y = rng.uniform(size=(m, 2))
        d = x[:, None, :] - y[None, :, :]
        ci = np.sqrt((d * d).sum(-1) + 1e-30).astype(np.float32)
        nu = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu = rng.dirichlet(np.ones(m)).astype(np.float32)
        insts.append((ci, nu, mu))
    mesh = make_batch_mesh()
    r_plain = solve_ot_ragged(insts, 0.1)
    r_mesh = solve_ot_ragged(insts, 0.1, mesh=mesh)
    for a, b in zip(r_plain, r_mesh):
        np.testing.assert_array_equal(a["plan"], b["plan"])
        assert b["devices"] >= 1
    with pytest.raises(ValueError):
        solve_ot_ragged(insts, 0.1, mesh=mesh, compact=False)


def test_scheduler_end_to_end():
    from repro.core.costs import build_cost_matrix
    from repro.core.pushrelabel import solve_assignment
    from repro.core.transport import solve_ot
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(1)
    with AsyncOTScheduler(eps=0.1, linger_ms=20) as sched:
        futs, refs = [], []
        for m in (14, 30, 14):
            x = rng.uniform(size=(m, 2)).astype(np.float32)
            y = rng.uniform(size=(m, 2)).astype(np.float32)
            futs.append(sched.submit(x, y))
            cm = build_cost_matrix(jnp.asarray(x), jnp.asarray(y),
                                   "euclidean")
            refs.append(float(solve_assignment(cm, 0.1).cost) / m)
        x = rng.uniform(size=(10, 2)).astype(np.float32)
        y = rng.uniform(size=(12, 2)).astype(np.float32)
        nu = rng.dirichlet(np.ones(10)).astype(np.float32)
        mu = rng.dirichlet(np.ones(12)).astype(np.float32)
        f_ot = sched.submit(x, y, nu=nu, mu=mu, eps=0.05)  # per-request eps
        assert sched.flush(timeout=300)
        for f, ref in zip(futs, refs):
            r = f.result(timeout=5)
            assert r["cost"] == pytest.approx(ref, abs=1e-5)
            assert r["wait_s"] >= 0 and r["solve_s"] > 0
            assert r["devices"] >= 1 and len(r["occupancy"]) >= 1
        cm = build_cost_matrix(jnp.asarray(x), jnp.asarray(y), "euclidean")
        s = solve_ot(cm, jnp.asarray(nu), jnp.asarray(mu), 0.05)
        r = f_ot.result(timeout=5)
        assert r["cost"] == pytest.approx(float(s.cost), abs=2e-6)
        assert r["plan"].shape == (10, 12)
        assert sched.stats.requests == 4
    with pytest.raises(RuntimeError):
        sched.submit(np.ones((4, 2)), np.ones((4, 2)))


# --------------------------------------------------------------------------
# Feasibility certificates on the batched/distributed code paths
# --------------------------------------------------------------------------

def test_certificates_distributed_assignment():
    """Lemma 3.2 / I1 / I2 certificates on the exact pre-completion integer
    state of every instance of a distributed (compacting) batch solve."""
    eps = 0.1
    c, _, _, sizes = _skewed_batch(4, 24, 28, seed=19)
    r, st = solve_assignment_distributed(c, eps, sizes=sizes, k=2,
                                          keep_state=True)
    assert st.final_state is not None
    for i in range(4):
        mi, ni = int(sizes[i][0]), int(sizes[i][1])
        _, c_int, _, _, _ = assignment_prologue(
            jnp.asarray(c[i]), eps, jnp.int32(mi), jnp.int32(ni)
        )
        import jax

        state_i = jax.tree_util.tree_map(lambda a, i=i: a[i], st.final_state)
        out = check_invariants(np.asarray(c_int),
                               np.asarray(state_i.y_b),
                               np.asarray(state_i.y_a),
                               np.asarray(state_i.match_ba), eps)
        assert all(out.values()), (i, out)


def test_certificates_distributed_ot():
    """check_ot_invariants (I1/I2, Lemma 4.1, Lemma 3.2 bound) on every
    instance of a distributed OT batch solve."""
    import jax

    eps = 0.1
    c, nu, mu, sizes = _skewed_batch(4, 24, 24, seed=23)
    r, st = solve_ot_distributed(c, nu, mu, eps, sizes=sizes, k=3)
    theta = np.asarray(r.theta)
    for i in range(4):
        c_int, s_int, d_int, _ = ot_prologue(
            jnp.asarray(c[i]), jnp.asarray(nu[i]), jnp.asarray(mu[i]),
            float(theta[i]), eps
        )
        np.testing.assert_array_equal(np.asarray(s_int),
                                      np.asarray(r.s_int)[i])
        state_i = jax.tree_util.tree_map(lambda a, i=i: a[i], r.state)
        out = check_ot_invariants(np.asarray(c_int), state_i,
                                  np.asarray(r.s_int)[i],
                                  np.asarray(r.d_int)[i], eps)
        assert all(out.values()), (i, out)


def test_certificates_lockstep_batched_ot():
    """The certificates also hold on the PR-1 lockstep batched path."""
    import jax

    from repro.core.batched import solve_ot_batched

    eps = 0.1
    c, nu, mu, sizes = _skewed_batch(3, 20, 20, seed=29)
    r = solve_ot_batched(c, nu, mu, eps, sizes=sizes)
    theta = np.asarray(r.theta)
    for i in range(3):
        c_int, _, _, _ = ot_prologue(
            jnp.asarray(c[i]), jnp.asarray(nu[i]), jnp.asarray(mu[i]),
            float(theta[i]), eps
        )
        state_i = jax.tree_util.tree_map(lambda a, i=i: a[i], r.state)
        out = check_ot_invariants(np.asarray(c_int), state_i,
                                  np.asarray(r.s_int)[i],
                                  np.asarray(r.d_int)[i], eps)
        assert all(out.values()), (i, out)


# --------------------------------------------------------------------------
# Forced 8-device mesh (subprocess, same harness as test_sharded_ot.py)
# --------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core.compaction import (
    solve_assignment_batched_compacting, solve_ot_batched_compacting,
)
from repro.core.distributed import (
    solve_assignment_distributed, solve_ot_distributed,
)
from repro.core.feasibility import check_invariants, check_ot_invariants
from repro.core.pushrelabel import assignment_prologue, solve_assignment
from repro.core.transport import ot_prologue, solve_ot
from repro.launch.mesh import make_batch_mesh

def skewed(b, mb, nb, seed, n_slow=4):
    rng = np.random.default_rng(seed)
    c = np.zeros((b, mb, nb), np.float32)
    nu = np.zeros((b, mb), np.float32)
    mu = np.zeros((b, nb), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i in range(b):
        m = int(rng.integers(mb // 2 + 1, mb + 1))
        n = int(rng.integers(m, nb + 1))
        x = rng.uniform(size=(m, 2))
        if i < n_slow:
            y = np.where(np.arange(n)[:, None] % 2 == 0,
                         x[np.arange(n) % m] * 0.02,
                         1.0 - 0.02 * rng.uniform(size=(n, 2)))
        else:
            y = rng.uniform(size=(n, 2))
        d = x[:, None, :] - y[None, :, :]
        c[i, :m, :n] = np.sqrt((d * d).sum(-1) + 1e-30)
        nu[i, :m] = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu[i, :n] = rng.dirichlet(np.ones(n)).astype(np.float32)
        sizes[i] = (m, n)
    perm = rng.permutation(b)
    return c[perm], nu[perm], mu[perm], sizes[perm]

out = {}
mesh = make_batch_mesh()
out["devices"] = int(mesh.shape["data"])

# -- batch placement: bit-identical across re-bucketing boundaries --------
c, nu, mu, sizes = skewed(32, 48, 48, seed=5)
r0, s0 = solve_ot_batched_compacting(c, nu, mu, 0.1, sizes=sizes, k=4)
r1, s1 = solve_ot_distributed(c, nu, mu, 0.1, mesh, sizes=sizes, k=4)
out["ot_identical"] = bool(
    np.array_equal(np.asarray(r0.plan), np.asarray(r1.plan))
    and np.array_equal(np.asarray(r0.cost), np.asarray(r1.cost))
    and np.array_equal(np.asarray(r0.phases), np.asarray(r1.phases))
)
buckets = sorted({bb for bb, _ in s1.occupancy}, reverse=True)
out["rebucketed"] = len(buckets) >= 3          # descent crossed >= 2 edges
out["collapsed"] = s1.collapsed_at is not None  # and below the mesh floor
out["final_live"] = s1.occupancy[-1][1]

a0, t0 = solve_assignment_batched_compacting(c, 0.1, sizes=sizes, k=4)
a1, t1 = solve_assignment_distributed(c, 0.1, mesh, sizes=sizes, k=4,
                                      keep_state=True)
out["assign_identical"] = bool(
    np.array_equal(np.asarray(a0.matching), np.asarray(a1.matching))
    and np.array_equal(np.asarray(a0.cost), np.asarray(a1.cost))
    and np.array_equal(np.asarray(a0.y_b), np.asarray(a1.y_b))
)

# -- mixed per-instance eps across re-bucketing boundaries ----------------
eps = np.where(np.arange(32) % 2 == 0, 0.1, 0.05)
m0, _ = solve_ot_batched_compacting(c, nu, mu, eps, sizes=sizes, k=2)
m1, sm = solve_ot_distributed(c, nu, mu, eps, mesh, sizes=sizes, k=2)
out["mixed_eps_identical"] = bool(
    np.array_equal(np.asarray(m0.plan), np.asarray(m1.plan))
    and np.array_equal(np.asarray(m0.phases), np.asarray(m1.phases))
)

# -- certificates on the mesh-solved states -------------------------------
ok = True
theta = np.asarray(r1.theta)
for i in range(4):
    mi, ni = int(sizes[i][0]), int(sizes[i][1])
    c_int, _, _, _ = ot_prologue(
        jnp.asarray(c[i]), jnp.asarray(nu[i]), jnp.asarray(mu[i]),
        float(theta[i]), 0.1)
    st_i = jax.tree_util.tree_map(lambda a: a[i], r1.state)
    res = check_ot_invariants(np.asarray(c_int), st_i,
                              np.asarray(r1.s_int)[i],
                              np.asarray(r1.d_int)[i], 0.1)
    ok = ok and all(res.values())
for i in range(4):
    mi, ni = int(sizes[i][0]), int(sizes[i][1])
    _, c_int, _, _, _ = assignment_prologue(
        jnp.asarray(c[i]), 0.1, jnp.int32(mi), jnp.int32(ni))
    st_i = jax.tree_util.tree_map(lambda a: a[i], t1.final_state)
    res = check_invariants(np.asarray(c_int), np.asarray(st_i.y_b),
                           np.asarray(st_i.y_a),
                           np.asarray(st_i.match_ba), 0.1)
    ok = ok and all(res.values())
out["certificates"] = bool(ok)

# -- matrix placement: few large instances, integer-exact -----------------
c2, nu2, mu2, sizes2 = skewed(2, 150, 150, seed=9, n_slow=0)
rm, sm2 = solve_ot_distributed(c2, nu2, mu2, 0.1, mesh, sizes=sizes2)
out["matrix_used"] = sm2.placement == "matrix"
mok = True
for i in range(2):
    m, n = int(sizes2[i][0]), int(sizes2[i][1])
    s = solve_ot(jnp.asarray(c2[i, :m, :n]), jnp.asarray(nu2[i, :m]),
                 jnp.asarray(mu2[i, :n]), 0.1)
    mok = mok and int(rm.phases[i]) == int(s.phases)
    mok = mok and bool(np.allclose(np.asarray(rm.plan)[i, :m, :n],
                                   np.asarray(s.plan), atol=1e-6))
    mok = mok and bool(np.array_equal(
        np.asarray(jax.tree_util.tree_map(lambda a: a[i], rm.state).f_hi
                   )[:m, :n],
        np.asarray(s.state.f_hi)))
out["matrix_identical"] = bool(mok)
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_distributed_eight_devices():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # skip the TPU-backend probe (60s timeout in this image)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["devices"] == 8, out
    assert out["ot_identical"], out
    assert out["assign_identical"], out
    assert out["mixed_eps_identical"], out
    assert out["rebucketed"] and out["collapsed"], out
    assert out["final_live"] == 0, out
    assert out["certificates"], out
    assert out["matrix_used"] and out["matrix_identical"], out
