"""Greedy maximal matching: validity, maximality, admissibility (hypothesis)."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.matching import greedy_maximal_matching


def _run(c_int, y_b, y_a, bprime, salt=0):
    mm = greedy_maximal_matching(
        jnp.asarray(c_int, jnp.int32),
        jnp.asarray(y_b, jnp.int32),
        jnp.asarray(y_a, jnp.int32),
        jnp.asarray(bprime, bool),
        jnp.int32(salt),
    )
    return np.asarray(mm.mprime_b), np.asarray(mm.mprime_a), int(mm.rounds)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 20),
    n=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
    density=st.sampled_from([0.0, 0.1, 0.5, 1.0]),
)
def test_maximal_matching_properties(m, n, seed, density):
    rng = np.random.default_rng(seed)
    # Build admissibility directly: c = y_b + y_a - 1 on admissible edges.
    y_b = rng.integers(0, 5, size=m).astype(np.int32)
    y_a = -rng.integers(0, 5, size=n).astype(np.int32)
    adm = rng.uniform(size=(m, n)) < density
    c = y_b[:, None] + y_a[None, :] - 1 + 10 * (~adm).astype(np.int32)
    bprime = rng.uniform(size=m) < 0.7
    mb, ma, rounds = _run(c, y_b, y_a, bprime)

    # 1. valid matching, consistent two-sided pointers
    matched_rows = np.where(mb >= 0)[0]
    cols = mb[matched_rows]
    assert len(np.unique(cols)) == len(cols)
    for r_, c_ in zip(matched_rows, cols):
        assert ma[c_] == r_
    # 2. only B' rows matched, only admissible edges used
    assert all(bprime[r_] for r_ in matched_rows)
    assert all(adm[r_, c_] for r_, c_ in zip(matched_rows, cols))
    # 3. maximality: no admissible edge between unmatched B' row & unmatched col
    free_rows = bprime & (mb < 0)
    free_cols = ma < 0
    assert not adm[np.ix_(free_rows, free_cols)].any()
    # 4. parallel depth sanity
    assert rounds <= min(m, n) + 1


def test_empty_bprime():
    mb, ma, rounds = _run(np.zeros((4, 4)), np.ones(4), np.zeros(4),
                          np.zeros(4, bool))
    assert (mb == -1).all() and (ma == -1).all()


def test_full_bipartite_logarithmic_rounds():
    """Complete admissible graph: randomized proposals resolve contention in
    far fewer than n rounds (the deterministic first-available strategy
    would need n)."""
    n = 64
    y_b = np.ones(n, np.int32)
    y_a = np.zeros(n, np.int32)
    c = np.zeros((n, n), np.int32)  # all edges admissible: 1 + 0 == 0 + 1
    mb, ma, rounds = _run(c, y_b, y_a, np.ones(n, bool))
    assert (mb >= 0).all()
    assert rounds <= 16  # expected O(log n)
