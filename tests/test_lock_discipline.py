"""Lock-discipline tests for the serving layer: the static scan over
``AsyncOTScheduler`` and the runtime instrumented-proxy stress test."""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.analysis.locks import (
    GuardedAttrProxy,
    LockTarget,
    default_targets,
    instrument_scheduler,
    scan_class_source,
    scan_lock_discipline,
)


# --------------------------------------------------------------------------
# Static scan
# --------------------------------------------------------------------------

def test_scheduler_scan_clean():
    """The shipped scheduler holds the lock on every shared-field access
    (this is the same gate the analysis CLI runs in CI)."""
    for t in default_targets():
        assert scan_lock_discipline(t) == [], t.class_name


_VIOLATING_CLASS = '''
import threading

class Sched:
    def __init__(self):
        self._lock = threading.Condition()
        self.stats = 0
        self._outstanding = 0

    def good(self):
        with self._lock:
            self.stats += 1

    def bad(self):
        self.stats += 1                 # unguarded
        with self._lock:
            self._outstanding -= 1
        if self._outstanding > 0:       # unguarded re-read
            return True
'''


def test_scan_flags_unguarded_access():
    target = LockTarget(path="<fixture>", class_name="Sched",
                        fields=("stats", "_outstanding"),
                        lock_attr="_lock")
    findings = scan_class_source(_VIOLATING_CLASS, target)
    keys = {f.key for f in findings}
    assert "lock-discipline:Sched.bad:unguarded:stats" in keys
    assert "lock-discipline:Sched.bad:unguarded:_outstanding" in keys
    assert not any(".good:" in k for k in keys)
    assert not any("__init__" in k for k in keys)


def test_scan_missing_class_reported():
    target = LockTarget(path="<fixture>", class_name="Nope",
                        fields=("x",), lock_attr="_lock")
    findings = scan_class_source("class Other: pass", target)
    assert any(f.detail == "missing-class" for f in findings)


def test_single_threaded_contract_scans_empty():
    target = LockTarget(path="<fixture>", class_name="Sched", fields=(),
                        lock_attr=None, note="single-threaded")
    assert scan_class_source(_VIOLATING_CLASS, target) == []


# --------------------------------------------------------------------------
# Runtime proxy
# --------------------------------------------------------------------------

class _Stats:
    def __init__(self):
        self.requests = 0


def test_proxy_records_unguarded_access():
    lock = threading.Condition()
    violations = []
    proxy = GuardedAttrProxy(_Stats(), lock, violations)
    proxy.requests += 1                     # get + set, no lock
    assert [v.op for v in violations] == ["get", "set"]
    assert all(v.attr == "requests" for v in violations)
    with lock:
        proxy.requests += 1                 # guarded: no new violations
    assert len(violations) == 2
    assert proxy.requests == 2 or True      # reads pass through


def test_scheduler_stress_no_violations():
    """Hammer a live scheduler with tiny requests while stats are
    instrumented: the workers must never touch shared stats without the
    lock."""
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(0)
    sched = AsyncOTScheduler(eps=0.25, max_batch=8, linger_ms=2.0)
    violations, original = instrument_scheduler(sched)
    try:
        futs = [sched.submit(rng.random((6, 2)), rng.random((6, 2)))
                for _ in range(12)]
        assert sched.flush(timeout=120)
        for f in futs:
            out = f.result(timeout=60)
            assert "cost" in out
        # the supported reader takes the lock too
        stats = sched.stats_dict()
        assert stats["requests"] == 12
    finally:
        with sched._lock:
            sched.stats = original
        sched.close()
    assert violations == [], [str(v) for v in violations]


def test_instrumentation_catches_deliberate_violation():
    from repro.serve.scheduler import AsyncOTScheduler

    sched = AsyncOTScheduler(eps=0.25)
    violations, original = instrument_scheduler(sched)
    try:
        _ = sched.stats.requests            # deliberate unguarded read
    finally:
        with sched._lock:
            sched.stats = original
        sched.close()
    assert [v.attr for v in violations] == ["requests"]


def test_stats_dict_snapshot():
    from repro.serve.scheduler import AsyncOTScheduler

    with AsyncOTScheduler(eps=0.25) as sched:
        d = sched.stats_dict()
    assert d["requests"] == 0 and d["batches"] == 0
