"""Lock-discipline tests for the serving layer: the static scan over
``AsyncOTScheduler`` (and, since the observability rework, the locked
pieces of ``repro.obs``), the ``GuardedAttrProxy`` runtime guard, and
the registry-backed stats surface."""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.analysis.locks import (
    GuardedAttrProxy,
    LockTarget,
    default_targets,
    scan_class_source,
    scan_lock_discipline,
)


# --------------------------------------------------------------------------
# Static scan
# --------------------------------------------------------------------------

def test_scheduler_scan_clean():
    """The shipped scheduler holds the lock on every shared-field access
    (this is the same gate the analysis CLI runs in CI)."""
    for t in default_targets():
        assert scan_lock_discipline(t) == [], t.class_name


def test_default_targets_cover_obs():
    """The observability layer's locked pieces are in the default scan,
    and its deliberately lock-free pieces are recorded as exemptions
    (empty field set + a note saying why)."""
    by_class = {t.class_name: t for t in default_targets()}
    for cls in ("MetricsRegistry", "JSONLSink", "History", "TraceCapture"):
        assert by_class[cls].lock_attr == "_lock", cls
        assert by_class[cls].fields, cls
    for cls in ("Counter", "Gauge", "Histogram", "InMemorySink",
                "Tracer", "Span"):
        assert by_class[cls].lock_attr is None, cls
        assert by_class[cls].note, cls
    # stats moved off the scheduler's locked-field list: they are
    # lock-free registry instruments now
    assert "stats" not in by_class["AsyncOTScheduler"].fields


_VIOLATING_CLASS = '''
import threading

class Sched:
    def __init__(self):
        self._lock = threading.Condition()
        self.stats = 0
        self._outstanding = 0

    def good(self):
        with self._lock:
            self.stats += 1

    def bad(self):
        self.stats += 1                 # unguarded
        with self._lock:
            self._outstanding -= 1
        if self._outstanding > 0:       # unguarded re-read
            return True
'''


def test_scan_flags_unguarded_access():
    target = LockTarget(path="<fixture>", class_name="Sched",
                        fields=("stats", "_outstanding"),
                        lock_attr="_lock")
    findings = scan_class_source(_VIOLATING_CLASS, target)
    keys = {f.key for f in findings}
    assert "lock-discipline:Sched.bad:unguarded:stats" in keys
    assert "lock-discipline:Sched.bad:unguarded:_outstanding" in keys
    assert not any(".good:" in k for k in keys)
    assert not any("__init__" in k for k in keys)


def test_scan_missing_class_reported():
    target = LockTarget(path="<fixture>", class_name="Nope",
                        fields=("x",), lock_attr="_lock")
    findings = scan_class_source("class Other: pass", target)
    assert any(f.detail == "missing-class" for f in findings)


def test_single_threaded_contract_scans_empty():
    target = LockTarget(path="<fixture>", class_name="Sched", fields=(),
                        lock_attr=None, note="single-threaded")
    assert scan_class_source(_VIOLATING_CLASS, target) == []


# --------------------------------------------------------------------------
# Runtime proxy
# --------------------------------------------------------------------------

class _Stats:
    def __init__(self):
        self.requests = 0


def test_proxy_records_unguarded_access():
    lock = threading.Condition()
    violations = []
    proxy = GuardedAttrProxy(_Stats(), lock, violations)
    proxy.requests += 1                     # get + set, no lock
    assert [v.op for v in violations] == ["get", "set"]
    assert all(v.attr == "requests" for v in violations)
    with lock:
        proxy.requests += 1                 # guarded: no new violations
    assert len(violations) == 2
    assert proxy.requests == 2 or True      # reads pass through


def test_scheduler_stress_stats_consistent():
    """Hammer a live scheduler: the registry-backed stats view must come
    out exactly consistent (stats are lock-free per-thread cells now, so
    there is no proxy to instrument — consistency IS the contract)."""
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(0)
    with AsyncOTScheduler(eps=0.25, max_batch=8, linger_ms=2.0) as sched:
        futs = [sched.submit(rng.random((6, 2)), rng.random((6, 2)))
                for _ in range(12)]
        assert sched.flush(timeout=120)
        for f in futs:
            out = f.result(timeout=60)
            assert "cost" in out
        stats = sched.stats_dict()
        assert stats["requests"] == 12
        assert stats["batches"] >= 1
        # derived view is self-consistent
        if stats["requests"]:
            assert stats["mean_wait_s"] == pytest.approx(
                stats["total_wait_s"] / stats["requests"])


def test_scheduler_stats_is_read_only_view():
    """``sched.stats`` is a snapshot property over the registry — not
    shared mutable state — so assigning it is an error, and two reads
    give independent snapshots."""
    from repro.serve.scheduler import AsyncOTScheduler

    with AsyncOTScheduler(eps=0.25) as sched:
        with pytest.raises(AttributeError):
            sched.stats = None
        a, b = sched.stats, sched.stats
        assert a is not b
        assert a.requests == b.requests == 0


def test_stats_dict_snapshot():
    from repro.serve.scheduler import AsyncOTScheduler

    with AsyncOTScheduler(eps=0.25) as sched:
        d = sched.stats_dict()
    assert d["requests"] == 0 and d["batches"] == 0
    assert d["occupancy_window"] == 64      # default window documented
