"""Fused Pallas phase kernel vs the stepped cores: bit-parity contract.

The fused kernel (``kernels/fused_phase``) replays the EXACT stepped
trajectory — same hash schedule, same first-min tie-breaking, same
FIFO grant order — so every integer state field must match the stepped
cores bit for bit, per chunk, for any k, any tile padding, and any
m_valid row masking. The float result surfaces (cost, duals) go through
the identical epilogue on identical integer states; the policy tests
allow 1e-6 on them because the stepped and fused LOCKSTEP paths compile
the epilogue into differently-partitioned programs (core/batched's
single fused program vs the compacting driver's chunked one), and XLA
reassociates the float pricing math across that boundary — ulp-level,
same caveat as mesh/matrix placement. Under identical program
structure (compact vs compact) the integer parity makes floats equal
too, but we assert the documented tolerance, not the accident.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.api import (
    ASSIGNMENT,
    FUSED_ASSIGNMENT,
    FUSED_OT,
    OT,
    DispatchPolicy,
    fused_variant,
    solve,
)
from repro.core.pushrelabel import (
    _max_phases,
    assignment_prologue,
    init_assignment_state,
    run_assignment_phases,
)
from repro.core.transport import (
    init_ot_state,
    ot_phase_cap,
    ot_prologue,
    ot_termination_threshold,
    run_ot_phases,
)
from repro.kernels import ops


def _assert_states_equal(ref, out, tag=""):
    for f, a, b in zip(ref._fields, ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{tag} field {f}")


# ---------------------------------------------------------------------------
# core-level chunk parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 5, 64])
@pytest.mark.parametrize("m,n,m_valid", [(24, 24, None), (33, 47, None),
                                         (40, 28, 31)])
def test_fused_assignment_chunks_match_stepped(k, m, n, m_valid):
    """Chained fused k-phase chunks == chained stepped chunks, bit for
    bit on every state field, through convergence — including padded
    rows (m_valid < m) and tile-edge shapes."""
    rng = np.random.default_rng(k * 1000 + m + n)
    eps = 0.1
    c = rng.uniform(size=(m, n)).astype(np.float32)
    mv = None
    if m_valid is not None:
        c[m_valid:, :] = 0.0
        mv = jnp.int32(m_valid)
    _, c_int, _, _, _ = assignment_prologue(
        jnp.asarray(c), eps, mv, None if m_valid is None else jnp.int32(n))
    thr = jnp.int32(int(eps * (m if m_valid is None else m_valid)))
    cap = jnp.int32(_max_phases(eps, m))
    s_ref = init_assignment_state(m, n)
    s_fus = init_assignment_state(m, n)
    for _ in range(4):
        s_ref = run_assignment_phases(c_int, s_ref, thr, cap, k, m_valid=mv)
        s_fus = ops.fused_run_assignment_phases(c_int, s_fus, thr, cap, k,
                                                m_valid=mv)
        _assert_states_equal(s_ref, s_fus, f"k={k}")


@pytest.mark.parametrize("k", [1, 3, 32])
@pytest.mark.parametrize("nb,na", [(16, 16), (21, 13), (9, 30)])
def test_fused_ot_chunks_match_stepped(k, nb, na):
    rng = np.random.default_rng(k * 100 + nb * na)
    eps = 0.2
    c = rng.uniform(size=(nb, na)).astype(np.float32)
    nu = rng.dirichlet(np.ones(nb)).astype(np.float32)
    mu = rng.dirichlet(np.ones(na)).astype(np.float32)
    theta = np.float32(4.0 * max(nb, na) / eps)
    c_int, s_int, d_int, _ = ot_prologue(
        jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), theta, eps)
    thr = jnp.int32(ot_termination_threshold(nu, theta, eps))
    cap = jnp.int32(ot_phase_cap(eps))
    mr = int(nb + na + 2)
    s_ref = init_ot_state(s_int, d_int)
    s_fus = init_ot_state(s_int, d_int)
    for _ in range(3):
        s_ref = run_ot_phases(c_int, s_ref, thr, cap, k, mr)
        s_fus = ops.fused_run_ot_phases(c_int, s_fus, thr, cap, k, mr)
        _assert_states_equal(s_ref, s_fus, f"k={k}")


def test_fused_kernels_are_resumable_across_k():
    """One k=8 fused chunk == four chained k=2 fused chunks (the stepped
    cores' resumability contract carries over to the fused kernel)."""
    rng = np.random.default_rng(7)
    n = 20
    c_int = jnp.asarray(rng.integers(0, 100, size=(n, n)), jnp.int32)
    thr, cap = jnp.int32(1), jnp.int32(64)
    one = ops.fused_run_assignment_phases(
        c_int, init_assignment_state(n, n), thr, cap, 8)
    many = init_assignment_state(n, n)
    for _ in range(4):
        many = ops.fused_run_assignment_phases(c_int, many, thr, cap, 2)
    _assert_states_equal(one, many)


# ---------------------------------------------------------------------------
# policy-level parity: fused specs through the solve() front door
# ---------------------------------------------------------------------------


def _batch(seed=0, b=5, m=20, n=26):
    rng = np.random.default_rng(seed)
    c = rng.uniform(size=(b, m, n)).astype(np.float32)
    nu = rng.uniform(size=(b, m)).astype(np.float32)
    nu /= nu.sum(1, keepdims=True)
    mu = rng.uniform(size=(b, n)).astype(np.float32)
    mu /= mu.sum(1, keepdims=True)
    sizes = np.asarray([[m, n], [15, 22], [m, n], [11, n], [m, 17]],
                       np.int32)[:b]
    return c, nu, mu, sizes


def _assert_results_match(rs, rf, tag, float_tol=0.0):
    for a, b in zip(jax.tree_util.tree_leaves(rs),
                    jax.tree_util.tree_leaves(rf)):
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(a.dtype, np.integer) or float_tol == 0.0:
            np.testing.assert_array_equal(a, b, err_msg=tag)
        else:
            np.testing.assert_allclose(a, b, rtol=float_tol,
                                       atol=float_tol, err_msg=tag)


@pytest.mark.parametrize("mode", ["lockstep", "compact"])
def test_fused_policy_assignment_matches_stepped(mode):
    """DispatchPolicy(fused=True) == the stepped policy, bit for bit:
    results AND retained integer state, across padded lanes and (under
    compact) mixed per-instance eps."""
    c, _, _, sizes = _batch()
    eps = 0.1 if mode == "lockstep" else np.asarray(
        [0.1, 0.2, 0.1, 0.15, 0.1])
    rs, ss = solve(ASSIGNMENT, {"c": c}, eps,
                   DispatchPolicy(mode=mode, chunk=3), sizes=sizes,
                   keep_state=True)
    rf, sf = solve(ASSIGNMENT, {"c": c}, eps,
                   DispatchPolicy(mode=mode, chunk=3, fused=True),
                   sizes=sizes, keep_state=True)
    _assert_results_match(rs, rf, f"assignment/{mode}", float_tol=1e-6)
    _assert_states_equal(ss.final_state, sf.final_state,
                         f"assignment/{mode}")


@pytest.mark.parametrize("mode", ["lockstep", "compact"])
def test_fused_policy_ot_matches_stepped(mode):
    c, nu, mu, sizes = _batch(seed=3)
    eps = 0.15 if mode == "lockstep" else np.asarray(
        [0.15, 0.25, 0.15, 0.2, 0.15])
    inputs = {"c": c, "nu": nu, "mu": mu}
    rs, ss = solve(OT, inputs, eps, DispatchPolicy(mode=mode, chunk=3),
                   sizes=sizes, keep_state=True)
    rf, sf = solve(OT, inputs, eps,
                   DispatchPolicy(mode=mode, chunk=3, fused=True),
                   sizes=sizes, keep_state=True)
    _assert_results_match(rs, rf, f"ot/{mode}", float_tol=1e-6)
    _assert_states_equal(ss.final_state, sf.final_state, f"ot/{mode}")


def test_fused_policy_mesh_matches_stepped():
    """Batch-sharded mesh dispatch through the fused kernel (pallas_call
    under shard_map) == the stepped mesh dispatch."""
    from repro.launch.mesh import make_batch_mesh

    mesh = make_batch_mesh()
    c, nu, mu, sizes = _batch(seed=5)
    pol_s = DispatchPolicy(mode="mesh", mesh=mesh, chunk=2,
                           placement="batch")
    pol_f = DispatchPolicy(mode="mesh", mesh=mesh, chunk=2,
                           placement="batch", fused=True)
    rs, _ = solve(ASSIGNMENT, {"c": c}, 0.12, pol_s, sizes=sizes)
    rf, _ = solve(ASSIGNMENT, {"c": c}, 0.12, pol_f, sizes=sizes)
    _assert_results_match(rs, rf, "assignment/mesh", float_tol=1e-6)
    inputs = {"c": c, "nu": nu, "mu": mu}
    rs, _ = solve(OT, inputs, 0.2, pol_s, sizes=sizes)
    rf, _ = solve(OT, inputs, 0.2, pol_f, sizes=sizes)
    _assert_results_match(rs, rf, "ot/mesh", float_tol=1e-6)


def test_fused_variant_mapping():
    assert fused_variant(ASSIGNMENT) is FUSED_ASSIGNMENT
    assert fused_variant(OT) is FUSED_OT
    assert fused_variant(FUSED_ASSIGNMENT) is FUSED_ASSIGNMENT
    assert FUSED_ASSIGNMENT.stepped is ASSIGNMENT
    assert FUSED_OT.stepped is OT
    assert FUSED_ASSIGNMENT.name == "assignment"  # same problem, same
    assert FUSED_OT.name == "ot"                  # result shaping
    with pytest.raises(ValueError):
        fused_variant(object())


def test_fused_specs_share_jit_cache_by_identity():
    """The compacting driver's program cache is keyed on spec identity:
    fused and stepped specs must get DISTINCT program families (a shared
    entry would silently run one kernel under the other's name)."""
    from repro.core.compaction import spec_fns

    assert spec_fns(ASSIGNMENT, 4) is not spec_fns(FUSED_ASSIGNMENT, 4)
    assert spec_fns(FUSED_ASSIGNMENT, 4) is spec_fns(FUSED_ASSIGNMENT, 4)


def test_fused_debug_checks_route_through_stepped():
    """REPRO_DEBUG_CHECKS instruments the stepped core for fused specs
    (checkify cannot see inside a Pallas kernel); the checkified run
    must still match the production fused run bit for bit."""
    from repro.analysis.checkified import checkified_spec_fns

    fns = checkified_spec_fns(FUSED_ASSIGNMENT, 3)
    assert fns is checkified_spec_fns(ASSIGNMENT, 3)

    import repro.analysis as analysis

    c, _, _, sizes = _batch(seed=9)
    pol = DispatchPolicy(mode="compact", chunk=3, fused=True)
    r_prod, _ = solve(ASSIGNMENT, {"c": c}, 0.1, pol, sizes=sizes)
    analysis.set_debug_checks(True)
    try:
        r_dbg, _ = solve(ASSIGNMENT, {"c": c}, 0.1, pol, sizes=sizes)
    finally:
        analysis.set_debug_checks(False)
    _assert_results_match(r_prod, r_dbg, "debug-checks")
