"""Clustered OT solver: accuracy vs LP, equivalence-class vs explicit copies,
marginal exactness, Lemma 4.1 invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.transport import solve_ot, northwest_corner
from repro.core.copies import solve_ot_via_copies
from repro.core.exact import exact_ot_cost
from repro.core.costs import build_cost_matrix
from repro.core.feasibility import check_ot_invariants


def _instance(n, seed=0, na=None):
    rng = np.random.default_rng(seed)
    na = na or n
    x = rng.uniform(size=(n, 2))
    y = rng.uniform(size=(na, 2))
    c = np.asarray(build_cost_matrix(x, y, "euclidean"))
    nu = rng.dirichlet(np.ones(n))
    mu = rng.dirichlet(np.ones(na))
    return c, nu, mu


@pytest.mark.parametrize("n,eps", [(10, 0.1), (40, 0.1), (40, 0.03), (80, 0.05)])
def test_additive_bound_vs_lp(n, eps):
    c, nu, mu = _instance(n, seed=n)
    opt = exact_ot_cost(c, nu, mu)
    r = solve_ot(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), eps)
    assert float(r.cost) <= opt + 3 * eps * c.max() + 1e-4


@pytest.mark.parametrize("n", [10, 50])
def test_exact_marginals(n):
    c, nu, mu = _instance(n, seed=n + 1)
    r = solve_ot(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), 0.05)
    p = np.asarray(r.plan)
    assert (p >= -1e-9).all()
    np.testing.assert_allclose(p.sum(1), nu, atol=2e-6)
    np.testing.assert_allclose(p.sum(0), mu, atol=2e-6)


def test_matches_explicit_copies_reduction():
    """The clustered solver and the literal Section-4 copies reduction must
    both land within the same additive envelope of the LP optimum."""
    c, nu, mu = _instance(12, seed=5)
    eps, theta = 0.1, 160.0
    opt = exact_ot_cost(c, nu, mu)
    r = solve_ot(
        jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), eps, theta=theta
    )
    plan_cp, cost_cp, _, _, _ = solve_ot_via_copies(c, nu, mu, eps, theta)
    env = 3 * eps * c.max() + 2 * 12 / theta * c.max()
    assert float(r.cost) <= opt + env + 1e-4
    assert cost_cp <= opt + env + 1e-4


@pytest.mark.parametrize("eps", [0.1, 0.03])
def test_ot_invariants_at_termination(eps):
    c, nu, mu = _instance(30, seed=23)
    r = solve_ot(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), eps)
    scale = c.max()
    c_int = np.floor(c / scale / eps).astype(np.int32)
    checks = check_ot_invariants(c_int, r.state, r.s_int, r.d_int, eps)
    assert all(checks.values()), checks


def test_unbalanced_supports():
    c, nu, mu = _instance(20, seed=31, na=35)
    opt = exact_ot_cost(c, nu, mu)
    r = solve_ot(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), 0.05)
    assert float(r.cost) <= opt + 3 * 0.05 * c.max() + 1e-4
    np.testing.assert_allclose(np.asarray(r.plan).sum(1), nu, atol=2e-6)
    np.testing.assert_allclose(np.asarray(r.plan).sum(0), mu, atol=2e-6)


def test_assignment_special_case_through_ot():
    """Uniform masses 1/n: OT == assignment/n."""
    n = 25
    c, _, _ = _instance(n, seed=41)
    u = np.full(n, 1.0 / n)
    opt = exact_ot_cost(c, u, u)
    r = solve_ot(jnp.asarray(c), jnp.asarray(u), jnp.asarray(u), 0.05)
    assert float(r.cost) <= opt + 3 * 0.05 * c.max() + 1e-4


def test_northwest_corner_marginals():
    rng = np.random.default_rng(0)
    r = rng.dirichlet(np.ones(17))
    c = rng.dirichlet(np.ones(9))
    p = np.asarray(northwest_corner(jnp.asarray(r), jnp.asarray(c)))
    np.testing.assert_allclose(p.sum(1), r, atol=1e-6)
    np.testing.assert_allclose(p.sum(0), c, atol=1e-6)
    assert (p >= -1e-9).all()


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 16),
    eps=st.sampled_from([0.2, 0.08]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_random_ot(n, eps, seed):
    rng = np.random.default_rng(seed)
    c = rng.uniform(size=(n, n)).astype(np.float32)
    nu = rng.dirichlet(np.ones(n))
    mu = rng.dirichlet(np.ones(n))
    opt = exact_ot_cost(c, nu, mu)
    r = solve_ot(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), eps)
    assert float(r.cost) <= opt + 3 * eps * c.max() + 1e-4
    p = np.asarray(r.plan)
    np.testing.assert_allclose(p.sum(1), nu, atol=3e-6)
    np.testing.assert_allclose(p.sum(0), mu, atol=3e-6)
    scale = c.max()
    c_int = np.floor(c / scale / eps).astype(np.int32)
    checks = check_ot_invariants(c_int, r.state, r.s_int, r.d_int, eps)
    assert all(checks.values()), checks
