"""The ProblemSpec protocol + the unified core/api.solve front door.

Invariants under test:
  * cross-policy parity (the property the refactor must preserve): a
    random mixed-shape, mixed-eps instance set solved via EVERY
    DispatchPolicy — lockstep, compact, mesh/batch (and forced
    mesh/matrix) — yields identical costs/plans/matchings per instance
    (matrix to the documented float-epilogue ulp caveat), and the duals/
    states pass the paper's feasibility certificates
    (check_invariants / check_ot_invariants);
  * the front door's two input forms (ragged list, pre-batched dict)
    agree with each other and with the legacy entry points;
  * ``buckets=`` plumbing: custom bucket tables reach bucket_instances
    through solve_*_ragged / OTService / AsyncOTScheduler, and shapes
    beyond the biggest bucket mint ceil-pow2 buckets instead of
    per-shape exact buckets.

The 8-device variant (subprocess, forced host devices, marked slow) runs
the same parity property across a real mesh with re-bucketing and the
matrix placement engaged.
"""
import json
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.api import ASSIGNMENT, OT, DispatchPolicy, dispatch, solve
from repro.core.feasibility import check_invariants, check_ot_invariants
from repro.core.pushrelabel import assignment_prologue
from repro.core.transport import ot_prologue


def _mixed_instances(b, lo, hi, seed):
    """Ragged OT instances ((c, nu, mu) triples) + assignment costs with a
    shape mix that spans several buckets."""
    rng = np.random.default_rng(seed)
    ot, cs = [], []
    for _ in range(b):
        m = int(rng.integers(lo, hi))
        n = int(rng.integers(m, hi + 4))
        x = rng.uniform(size=(m, 2))
        y = rng.uniform(size=(n, 2))
        d = x[:, None, :] - y[None, :, :]
        ci = np.sqrt((d * d).sum(-1) + 1e-30).astype(np.float32)
        nu = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu = rng.dirichlet(np.ones(n)).astype(np.float32)
        ot.append((ci, nu, mu))
        cs.append(ci)
    eps = np.where(np.arange(b) % 2 == 0, 0.1, 0.05)
    return ot, cs, eps


POLICIES = {
    "lockstep": DispatchPolicy(mode="lockstep"),
    "compact": DispatchPolicy(mode="compact", chunk=3),
    "mesh": DispatchPolicy(mode="mesh"),       # default host mesh
}


def test_cross_policy_parity_ot():
    """Every policy produces identical per-instance OT plans/costs on a
    mixed-shape, mixed-eps set (lockstep sub-groups by eps)."""
    ot, _, eps = _mixed_instances(7, 10, 30, seed=0)
    outs = {name: solve(OT, ot, eps, pol) for name, pol in POLICIES.items()}
    ref = outs["compact"]
    for name, rs in outs.items():
        for i, (r, r0) in enumerate(zip(rs, ref)):
            np.testing.assert_array_equal(r["plan"], r0["plan"],
                                          err_msg=f"{name}[{i}]")
            assert r["cost"] == r0["cost"], (name, i)
            assert r["phases"] == r0["phases"], (name, i)


def test_cross_policy_parity_assignment():
    _, cs, eps = _mixed_instances(6, 10, 30, seed=1)
    outs = {name: solve(ASSIGNMENT, cs, eps, pol)
            for name, pol in POLICIES.items()}
    ref = outs["compact"]
    for name, rs in outs.items():
        for i, (r, r0) in enumerate(zip(rs, ref)):
            np.testing.assert_array_equal(r["matching"], r0["matching"],
                                          err_msg=f"{name}[{i}]")
            assert r["cost"] == r0["cost"], (name, i)
            # duals: traced-eps vs static-eps f32 multiply, ulp-level
            np.testing.assert_allclose(r["y_b"], r0["y_b"], atol=1e-6)


def test_parity_duals_pass_certificates():
    """The duals/states behind every policy satisfy the paper's
    feasibility certificates (bucket-level dispatch, mixed eps)."""
    rng = np.random.default_rng(3)
    b, m, n = 4, 20, 24
    c = np.zeros((b, m, n), np.float32)
    nu = np.zeros((b, m), np.float32)
    mu = np.zeros((b, n), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i in range(b):
        mi = int(rng.integers(12, m + 1))
        ni = int(rng.integers(mi, n + 1))
        c[i, :mi, :ni] = rng.uniform(size=(mi, ni))
        nu[i, :mi] = rng.dirichlet(np.ones(mi)).astype(np.float32)
        mu[i, :ni] = rng.dirichlet(np.ones(ni)).astype(np.float32)
        sizes[i] = (mi, ni)
    eps = np.where(np.arange(b) % 2 == 0, 0.1, 0.2)

    for pol in (POLICIES["compact"], POLICIES["mesh"]):
        r, st = dispatch(ASSIGNMENT, {"c": c}, eps, sizes=sizes,
                         policy=pol, keep_state=True)
        assert st.final_state is not None
        for i in range(b):
            _, c_int, _, _, _ = assignment_prologue(
                jnp.asarray(c[i]), float(eps[i]),
                jnp.int32(sizes[i][0]), jnp.int32(sizes[i][1]))
            s_i = jax.tree_util.tree_map(lambda a, i=i: a[i], st.final_state)
            out = check_invariants(np.asarray(c_int),
                                   np.asarray(s_i.y_b),
                                   np.asarray(s_i.y_a),
                                   np.asarray(s_i.match_ba),
                                   float(eps[i]))
            assert all(out.values()), (pol.resolved_mode(), i, out)

        ro, _ = dispatch(OT, {"c": c, "nu": nu, "mu": mu}, eps,
                         sizes=sizes, policy=pol)
        theta = np.asarray(ro.theta)
        for i in range(b):
            c_int, _, _, _ = ot_prologue(
                jnp.asarray(c[i]), jnp.asarray(nu[i]), jnp.asarray(mu[i]),
                float(theta[i]), float(eps[i]))
            s_i = jax.tree_util.tree_map(lambda a, i=i: a[i], ro.state)
            out = check_ot_invariants(np.asarray(c_int), s_i,
                                      np.asarray(ro.s_int)[i],
                                      np.asarray(ro.d_int)[i],
                                      float(eps[i]))
            assert all(out.values()), (pol.resolved_mode(), i, out)


def test_front_door_dict_form_matches_legacy():
    """solve(spec, {batched dict}) == the legacy per-problem entry point."""
    from repro.core.compaction import solve_ot_batched_compacting

    ot, _, _ = _mixed_instances(4, 12, 16, seed=5)
    mb = max(c.shape[0] for c, _, _ in ot)
    nb = max(c.shape[1] for c, _, _ in ot)
    from repro.core.batched import pad_stack

    c = pad_stack([c for c, _, _ in ot], (mb, nb))
    nu = pad_stack([v for _, v, _ in ot], (mb,))
    mu = pad_stack([v for _, _, v in ot], (nb,))
    sizes = np.asarray([c0.shape for c0, _, _ in ot], np.int32)
    r0, s0 = solve_ot_batched_compacting(c, nu, mu, 0.1, sizes=sizes, k=4)
    r1, s1 = solve(OT, {"c": c, "nu": nu, "mu": mu}, 0.1, sizes=sizes,
                   policy=DispatchPolicy(mode="compact", chunk=4))
    np.testing.assert_array_equal(np.asarray(r0.plan), np.asarray(r1.plan))
    assert s0.dispatches == s1.dispatches


def test_policy_validation():
    with pytest.raises(ValueError):
        DispatchPolicy(mode="warp")
    with pytest.raises(ValueError):
        DispatchPolicy(mode="lockstep", mesh=object())
    assert DispatchPolicy().resolved_mode() == "compact"
    assert DispatchPolicy(mesh=None, mode="mesh").resolved_mode() == "mesh"


# --------------------------------------------------------------------------
# buckets= plumbing + ceil-pow2 minting for oversized shapes
# --------------------------------------------------------------------------

def test_oversized_shapes_mint_pow2_buckets():
    from repro.core.batched import bucket_instances, solve_ot_ragged

    # 20 > the biggest custom bucket (16): minted ceil-pow2 bucket of 32,
    # shared by both oversized instances (one compiled program, not two)
    groups = bucket_instances([(20, 20), (6, 6), (25, 31)], buckets=(8, 16))
    assert {g.key for g in groups} == {(32, 32), (8, 8)}

    rng = np.random.default_rng(7)
    insts = []
    for m in (20, 6, 25):
        x = rng.uniform(size=(m, 2))
        y = rng.uniform(size=(m, 2))
        d = x[:, None, :] - y[None, :, :]
        ci = np.sqrt((d * d).sum(-1) + 1e-30).astype(np.float32)
        insts.append((ci, rng.dirichlet(np.ones(m)).astype(np.float32),
                      rng.dirichlet(np.ones(m)).astype(np.float32)))
    rs = solve_ot_ragged(insts, 0.1, buckets=(8, 16))
    assert rs[0]["bucket"] == (32, 32)
    assert rs[1]["bucket"] == (8, 8)
    assert rs[2]["bucket"] == (32, 32)
    # and the minted-bucket solves still equal unbatched solves
    from repro.core.transport import solve_ot

    for (ci, nui, mui), r in zip(insts, rs):
        s = solve_ot(jnp.asarray(ci), jnp.asarray(nui), jnp.asarray(mui),
                     0.1)
        assert r["cost"] == pytest.approx(float(s.cost), abs=2e-6)


def test_buckets_plumb_through_service_and_scheduler():
    from repro.serve.engine import OTService
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(9)
    x = rng.uniform(size=(20, 2)).astype(np.float32)
    y = rng.uniform(size=(20, 2)).astype(np.float32)

    svc = OTService(eps=0.1, buckets=(8, 16))
    svc.submit(x, y)
    out = svc.run_batch()
    assert out[0]["bucket"] == (32, 32)     # minted, not a failure

    with AsyncOTScheduler(eps=0.1, buckets=(8, 16)) as sched:
        fut = sched.submit(x, y)
        assert sched.flush(timeout=300)
        assert fut.result(timeout=5)["bucket"] == (32, 32)


# --------------------------------------------------------------------------
# Forced 8-device mesh parity (subprocess, same harness as
# tests/test_distributed.py)
# --------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core.api import ASSIGNMENT, OT, DispatchPolicy, dispatch, solve
from repro.core.feasibility import check_invariants, check_ot_invariants
from repro.core.pushrelabel import assignment_prologue
from repro.core.transport import ot_prologue
from repro.launch.mesh import make_batch_mesh

rng = np.random.default_rng(13)
b = 24
ot, cs, shapes = [], [], []
for _ in range(b):
    m = int(rng.integers(16, 40))
    n = int(rng.integers(m, 44))
    x = rng.uniform(size=(m, 2))
    y = rng.uniform(size=(n, 2))
    d = x[:, None, :] - y[None, :, :]
    ci = np.sqrt((d * d).sum(-1) + 1e-30).astype(np.float32)
    ot.append((ci, rng.dirichlet(np.ones(m)).astype(np.float32),
               rng.dirichlet(np.ones(n)).astype(np.float32)))
    cs.append(ci)
    shapes.append((m, n))
eps = np.where(np.arange(b) % 3 == 0, 0.05, 0.1)

mesh = make_batch_mesh()
out = {"devices": int(mesh.shape["data"])}
policies = {
    "lockstep": DispatchPolicy(mode="lockstep"),
    "compact": DispatchPolicy(mode="compact", chunk=4),
    "mesh": DispatchPolicy(mode="mesh", mesh=mesh, chunk=4),
}

res_ot = {k: solve(OT, ot, eps, p) for k, p in policies.items()}
res_as = {k: solve(ASSIGNMENT, cs, eps, p) for k, p in policies.items()}
ok = True
for k in policies:
    for i in range(b):
        ok = ok and np.array_equal(res_ot[k][i]["plan"],
                                   res_ot["compact"][i]["plan"])
        ok = ok and res_ot[k][i]["cost"] == res_ot["compact"][i]["cost"]
        ok = ok and np.array_equal(res_as[k][i]["matching"],
                                   res_as["compact"][i]["matching"])
        ok = ok and res_as[k][i]["cost"] == res_as["compact"][i]["cost"]
out["parity"] = bool(ok)
out["mesh_used"] = any(r.get("devices", 1) > 1 for r in res_ot["mesh"])

# certificates on the mesh-policy states (bucket-level dispatch)
mb = max(m for m, _ in shapes); nb = max(n for _, n in shapes)
from repro.core.batched import pad_stack
c_b = pad_stack(cs, (mb, nb))
nu_b = pad_stack([v for _, v, _ in ot], (mb,))
mu_b = pad_stack([v for _, _, v in ot], (nb,))
sizes = np.asarray(shapes, np.int32)
cert = True
r_a, st_a = dispatch(ASSIGNMENT, {"c": c_b}, eps, sizes=sizes,
                     policy=policies["mesh"], keep_state=True)
for i in range(4):
    _, c_int, _, _, _ = assignment_prologue(
        jnp.asarray(c_b[i]), float(eps[i]),
        jnp.int32(sizes[i][0]), jnp.int32(sizes[i][1]))
    s_i = jax.tree_util.tree_map(lambda a: a[i], st_a.final_state)
    res = check_invariants(np.asarray(c_int), np.asarray(s_i.y_b),
                           np.asarray(s_i.y_a), np.asarray(s_i.match_ba),
                           float(eps[i]))
    cert = cert and all(res.values())
r_o, _ = dispatch(OT, {"c": c_b, "nu": nu_b, "mu": mu_b}, eps,
                  sizes=sizes, policy=policies["mesh"])
theta = np.asarray(r_o.theta)
for i in range(4):
    c_int, _, _, _ = ot_prologue(
        jnp.asarray(c_b[i]), jnp.asarray(nu_b[i]), jnp.asarray(mu_b[i]),
        float(theta[i]), float(eps[i]))
    s_i = jax.tree_util.tree_map(lambda a: a[i], r_o.state)
    res = check_ot_invariants(np.asarray(c_int), s_i,
                              np.asarray(r_o.s_int)[i],
                              np.asarray(r_o.d_int)[i], float(eps[i]))
    cert = cert and all(res.values())
out["certificates"] = bool(cert)

# matrix placement vs compact: integer-exact, float epilogue to 1e-6
b2 = 2
c2 = np.zeros((b2, 150, 150), np.float32)
nu2 = np.zeros((b2, 150), np.float32)
mu2 = np.zeros((b2, 150), np.float32)
for i in range(b2):
    x = rng.uniform(size=(150, 2)); y = rng.uniform(size=(150, 2))
    d = x[:, None, :] - y[None, :, :]
    c2[i] = np.sqrt((d * d).sum(-1) + 1e-30)
    nu2[i] = rng.dirichlet(np.ones(150)).astype(np.float32)
    mu2[i] = rng.dirichlet(np.ones(150)).astype(np.float32)
rm, sm = dispatch(OT, {"c": c2, "nu": nu2, "mu": mu2}, 0.1,
                  policy=DispatchPolicy(mode="mesh", mesh=mesh,
                                        placement="matrix"))
rc, _ = dispatch(OT, {"c": c2, "nu": nu2, "mu": mu2}, 0.1,
                 policy=policies["compact"])
out["matrix_used"] = sm.placement == "matrix"
out["matrix_phases_exact"] = bool(np.array_equal(
    np.asarray(rm.phases), np.asarray(rc.phases)))
out["matrix_plan_close"] = bool(np.allclose(
    np.asarray(rm.plan), np.asarray(rc.plan), atol=1e-6))
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_cross_policy_parity_eight_devices():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # skip the TPU-backend probe (60s timeout in this image)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["devices"] == 8, out
    assert out["parity"], out
    assert out["mesh_used"], out
    assert out["certificates"], out
    assert out["matrix_used"], out
    assert out["matrix_phases_exact"], out
    assert out["matrix_plan_close"], out
