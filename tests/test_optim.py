"""Optimizer unit tests: convergence on quadratics, factored-state shapes,
int8 error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizer import (
    adamw_init, adamw_update, adafactor_init, adafactor_update,
    clip_by_global_norm, cosine_schedule, compress_int8, decompress_int8,
)


def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array([[1.0, -1.0]] * 2)}


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_converges_on_quadratic(opt):
    params = _quadratic_params()
    if opt == "adamw":
        state = adamw_init(params)
        upd = lambda p, g, s: adamw_update(p, g, s, lr=0.05, wd=0.0)
    else:
        state = adafactor_init(params)
        upd = lambda p, g, s: adafactor_update(p, g, s, lr=0.05, wd=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = upd(params, g, state)
    assert float(loss(params)) < 1e-2


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "v": jnp.zeros((7,))}
    st = adafactor_init(params)
    assert st.v["w"][0].shape == (64,)
    assert st.v["w"][1].shape == (32,)
    assert st.v["v"][0].shape == (7,)
    # factored state is ~ (m+n) instead of m*n
    n_state = sum(x.size for x in jax.tree.leaves(st.v))
    assert n_state == 64 + 32 + 7


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) < 1e-3 / 5
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(lr(jnp.int32(100))) < 1e-5 + 1e-9


def test_int8_error_feedback_is_unbiased_over_steps():
    """Error feedback: accumulated quantization error stays bounded and the
    running sum of decompressed grads tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    err = jnp.zeros_like(g_true)
    acc_true = np.zeros(512)
    acc_deq = np.zeros(512)
    for step in range(50):
        g = g_true * (1.0 + 0.1 * step)
        q, scale, err = compress_int8(g, err)
        acc_true += np.asarray(g)
        acc_deq += np.asarray(decompress_int8(q, scale))
    # residual error is bounded by one quantization step, not O(steps)
    resid = np.abs(acc_true - acc_deq).max()
    assert resid <= float(scale) * 2.0
