"""The typed Solution result surface (core/solution.py + solve(want=...)).

Invariants under test:
  * sparse/dense round trip: ``Solution.plan_sparse().to_dense()`` equals
    the dense plan BIT FOR BIT, across every DispatchPolicy mode, and the
    COO support is compact (O(m + n), the paper's "readily provides a
    compact transport plan");
  * lazy fetch: a cost-only ``want=`` never materializes the dense
    (B, M, N) plan on host — asserted on ``fetched_bytes`` — and
    un-requested accessors raise ``ArtifactNotRequested``;
  * certificates as API: ``dual_feasible()`` and ``additive_gap() <=
    eps * m * max(c)`` under ``guaranteed=True`` for BOTH specs across
    lockstep/compact/mesh (the paper's Theorem 1.2/1.3 bound validated
    a-posteriori from the approximate duals alone);
  * the keep_state asymmetry is gone: lockstep and ragged-list dispatch
    retain the pre-completion state when asked (want=("state",)), where
    they previously raised, and the state passes the integer
    certificates;
  * legacy adapters: solve_*_ragged / OTService.run_batch /
    AsyncOTScheduler emit values bit-identical to the Solution surface,
    with the historical conditional ``dispatches``/``devices`` keys;
  * ``Solution.stats`` is uniform (devices/dispatches/placement exist
    with explicit defaults on every path).

The slow 8-device variant reruns round-trip + certificates + cost-only
fetch accounting across a real mesh (subprocess, forced host devices).
"""
import json
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.api import ASSIGNMENT, OT, DispatchPolicy, dispatch, solve
from repro.core.feasibility import check_invariants
from repro.core.pushrelabel import assignment_prologue
from repro.core.solution import (
    ArtifactNotRequested,
    Solution,
    SolutionBatch,
    SolveStats,
)


def _mixed_instances(b, lo, hi, seed):
    rng = np.random.default_rng(seed)
    ot, cs = [], []
    for _ in range(b):
        m = int(rng.integers(lo, hi))
        n = int(rng.integers(m, hi + 4))
        x = rng.uniform(size=(m, 2))
        y = rng.uniform(size=(n, 2))
        d = x[:, None, :] - y[None, :, :]
        ci = np.sqrt((d * d).sum(-1) + 1e-30).astype(np.float32)
        nu = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu = rng.dirichlet(np.ones(n)).astype(np.float32)
        ot.append((ci, nu, mu))
        cs.append(ci)
    eps = np.where(np.arange(b) % 2 == 0, 0.1, 0.05)
    return ot, cs, eps


def _bucket(b, m, n, seed):
    """One pre-batched OT bucket (padded dict inputs + sizes)."""
    rng = np.random.default_rng(seed)
    c = np.zeros((b, m, n), np.float32)
    nu = np.zeros((b, m), np.float32)
    mu = np.zeros((b, n), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i in range(b):
        mi = int(rng.integers(m // 2, m + 1))
        ni = int(rng.integers(mi, n + 1))
        c[i, :mi, :ni] = rng.uniform(size=(mi, ni))
        nu[i, :mi] = rng.dirichlet(np.ones(mi)).astype(np.float32)
        mu[i, :ni] = rng.dirichlet(np.ones(ni)).astype(np.float32)
        sizes[i] = (mi, ni)
    return {"c": c, "nu": nu, "mu": mu}, sizes


POLICIES = {
    "lockstep": DispatchPolicy(mode="lockstep"),
    "compact": DispatchPolicy(mode="compact", chunk=3),
    "mesh": DispatchPolicy(mode="mesh"),       # default host mesh
}


# --------------------------------------------------------------------------
# Sparse plans: bit-identical round trip, compact support
# --------------------------------------------------------------------------

def test_sparse_plan_roundtrip_every_policy():
    ot, _, eps = _mixed_instances(6, 10, 30, seed=0)
    for name, pol in POLICIES.items():
        sols = solve(OT, ot, eps, pol,
                     want=("cost", "plan", "plan_sparse"))
        for i, s in enumerate(sols):
            dense = s.plan()
            sp = s.plan_sparse()
            assert np.array_equal(sp.to_dense(), dense), (name, i)
            mi, ni = s.shape
            # compact support (the paper's claim): way below dense m*n
            assert sp.nnz <= 4 * (mi + ni), (name, i, sp.nnz)
            # and cheaper to ship than the dense plan
            assert sp.nbytes < dense.nbytes, (name, i)


def test_sparse_plan_roundtrip_assignment():
    _, cs, eps = _mixed_instances(5, 10, 24, seed=1)
    sols = solve(ASSIGNMENT, cs, eps, POLICIES["compact"],
                 want=("cost", "matching", "plan", "plan_sparse"))
    for s in sols:
        dense = s.plan()
        sp = s.plan_sparse()
        assert np.array_equal(sp.to_dense(), dense)
        mi, _ = s.shape
        assert sp.nnz <= mi
        # the unit plan agrees with the matching
        matching = s.matching()
        rows = np.flatnonzero(matching >= 0)
        assert np.array_equal(sp.rows, rows)
        assert np.array_equal(sp.cols, matching[rows])


# --------------------------------------------------------------------------
# Lazy fetch: cost-only traffic never ships dense plans
# --------------------------------------------------------------------------

def test_cost_only_want_fetches_scalars_not_plans():
    inputs, sizes = _bucket(8, 24, 28, seed=2)
    b, m, n = inputs["c"].shape
    dense_bytes = b * m * n * 4
    batch = solve(OT, inputs, 0.1, DispatchPolicy(mode="compact"),
                  sizes=sizes, want=("cost",))
    assert isinstance(batch, SolutionBatch)
    cost = batch.cost()
    assert cost.shape == (b,)
    # O(B) scalars, not O(B * m * n) plans
    assert batch.fetched_bytes <= 16 * b
    assert batch.fetched_bytes < dense_bytes / 100
    with pytest.raises(ArtifactNotRequested):
        batch.plan()
    with pytest.raises(ArtifactNotRequested):
        batch.plan_sparse()
    with pytest.raises(ArtifactNotRequested):
        batch[0].duals()
    # sparse fetch moves less than a dense fetch even on tiny instances
    sp_batch = solve(OT, inputs, 0.1, DispatchPolicy(mode="compact"),
                     sizes=sizes, want=("cost", "plan_sparse"))
    sp = sp_batch.plan_sparse()
    assert sp_batch.fetched_bytes < dense_bytes
    # ... and the O(nnz) vs O(m * n) gap opens with the instance size
    big, big_sizes = _bucket(2, 64, 64, seed=11)
    big_dense = 2 * 64 * 64 * 4
    bb = solve(OT, big, 0.1, DispatchPolicy(mode="compact"),
               sizes=big_sizes, want=("plan_sparse",))
    bb.plan_sparse()
    assert bb.fetched_bytes < big_dense / 4


def test_want_validation():
    inputs, sizes = _bucket(2, 12, 12, seed=3)
    with pytest.raises(ValueError, match="unknown artifact"):
        solve(OT, inputs, 0.1, sizes=sizes, want=("cost", "warp"))
    with pytest.raises(ValueError, match="unknown artifact"):
        solve(ASSIGNMENT, {"c": inputs["c"]}, 0.1, sizes=sizes,
              want=("plan", "theta"))


# --------------------------------------------------------------------------
# Certificates: the paper's guarantees as API
# --------------------------------------------------------------------------

def test_additive_gap_bound_guaranteed_every_policy():
    """Under guaranteed=True the a-posteriori primal-dual gap respects the
    paper's <= eps * m * max(c) bound, and the approximate duals are
    eps-feasible — for BOTH specs, across every policy."""
    ot, cs, _ = _mixed_instances(5, 10, 28, seed=4)
    eps = 0.1
    for name, pol in POLICIES.items():
        pol = DispatchPolicy(mode=pol.mode, mesh=pol.mesh,
                             chunk=pol.chunk, guaranteed=True)
        for spec, insts in ((OT, ot), (ASSIGNMENT, cs)):
            sols = solve(spec, insts, eps, pol, want=("cost", "duals"))
            for i, s in enumerate(sols):
                assert s.dual_feasible(), (name, spec.name, i)
                gap = s.additive_gap()
                bound = s.additive_gap_bound()
                assert gap <= bound, (name, spec.name, i, gap, bound)
                # the bound is the paper's eps * m * scale
                mi, _ = s.shape
                mass = mi if spec is ASSIGNMENT else 1.0
                assert bound <= eps * mass * 1.5 + 1e-6
                # ... and the dual objective is a lower bound on OPT up
                # to the eps-feasibility slack: it can exceed the primal
                # cost by at most eps * m * scale (gap >= -bound)
                assert gap >= -bound - 1e-6


def test_certificates_on_lockstep_state():
    """want=("state",) retains the pre-completion state on the LOCKSTEP
    path (which used to raise), and it passes the integer invariants."""
    _, cs, _ = _mixed_instances(4, 10, 20, seed=5)
    eps = 0.1
    sols = solve(ASSIGNMENT, cs, eps, DispatchPolicy(mode="lockstep"),
                 want=("cost", "state"))
    for idx, s in enumerate(sols):
        st = s.state()
        mi, ni = s.shape
        mb, nb = s.stats.bucket
        # rebuild the padded instance the bucket dispatched
        ci = np.zeros((mb, nb), np.float32)
        ci[:mi, :ni] = cs[idx]
        _, c_int, _, _, _ = assignment_prologue(
            jnp.asarray(ci), eps, jnp.int32(mi), jnp.int32(ni))
        out = check_invariants(np.asarray(c_int), np.asarray(st.y_b),
                               np.asarray(st.y_a), np.asarray(st.match_ba),
                               eps)
        assert all(out.values()), out


def test_keep_state_asymmetry_fixed():
    """dispatch(keep_state=True) now works under lockstep, and the ragged
    legacy surface carries a per-instance state instead of raising."""
    inputs, sizes = _bucket(3, 14, 16, seed=6)
    r, st = dispatch(OT, inputs, 0.1, sizes=sizes,
                     policy=DispatchPolicy(mode="lockstep"),
                     keep_state=True)
    assert st is not None and st.final_state is not None
    assert st.dispatches == 1
    # lockstep state equals the compact driver's state bit for bit
    _, st_c = dispatch(OT, inputs, 0.1, sizes=sizes,
                       policy=DispatchPolicy(mode="compact"),
                       keep_state=True)
    for a, b in zip(jax.tree_util.tree_leaves(st.final_state),
                    jax.tree_util.tree_leaves(st_c.final_state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # ragged + keep_state: per-instance "state" key (used to raise)
    ot, _, _ = _mixed_instances(3, 10, 16, seed=7)
    outs = solve(OT, ot, 0.1, DispatchPolicy(mode="lockstep"),
                 keep_state=True)
    assert all("state" in o for o in outs)

    # explicit keep_state with a want that forgot "state": the flag is
    # promoted into the declaration instead of retaining a state the
    # gating would then refuse to hand over
    sb = solve(OT, inputs, 0.1, sizes=sizes, keep_state=True,
               want=("cost",))
    assert sb.state() is not None


# --------------------------------------------------------------------------
# Legacy adapters: bit-identical values, uniform Solution.stats
# --------------------------------------------------------------------------

def test_legacy_ragged_dicts_match_solution_surface():
    ot, cs, eps = _mixed_instances(6, 10, 26, seed=8)
    for name, pol in POLICIES.items():
        legacy = solve(OT, ot, eps, pol)
        sols = solve(OT, ot, eps, pol,
                     want=("cost", "plan", "duals", "plan_sparse"))
        for d, s in zip(legacy, sols):
            assert d["cost"] == s.cost, name
            assert d["phases"] == s.phases, name
            assert d["theta"] == s.theta, name
            assert np.array_equal(d["plan"], s.plan()), name
            assert np.array_equal(d["plan"], s.plan_sparse().to_dense())
            assert d["batch_size"] == s.stats.batch, name
            assert d["bucket"] == s.stats.bucket, name
            # conditional legacy keys preserved for one release
            if name == "lockstep":
                assert "dispatches" not in d, name
            else:
                assert d["dispatches"] == s.stats.dispatches, name
            if name == "mesh":
                assert d["devices"] == s.stats.devices, name
            else:
                assert "devices" not in d, name
        la = solve(ASSIGNMENT, cs, eps, pol)
        sa = solve(ASSIGNMENT, cs, eps, pol,
                   want=("cost", "matching", "duals"))
        for d, s in zip(la, sa):
            assert d["cost"] == s.cost, name
            assert np.array_equal(d["matching"], s.matching()), name
            y_b, y_a = s.duals()
            assert np.array_equal(d["y_b"], y_b), name
            assert np.array_equal(d["y_a"], y_a), name


def test_solution_stats_uniform_defaults():
    ot, _, eps = _mixed_instances(4, 10, 18, seed=9)
    for pol in POLICIES.values():
        s = solve(OT, ot, eps, pol, want=("cost",))[0]
        st = s.stats
        assert isinstance(st, SolveStats)
        assert st.mode == pol.resolved_mode()
        assert st.dispatches >= 1
        assert st.devices >= 1
        assert st.placement in ("batch", "matrix")
        d = st.as_dict()
        assert {"mode", "devices", "dispatches", "placement"} <= set(d)


def test_serve_layers_want_roundtrip():
    from repro.serve.engine import OTService
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(10)
    x = rng.uniform(size=(18, 2)).astype(np.float32)
    y = rng.uniform(size=(20, 2)).astype(np.float32)
    nu = rng.dirichlet(np.ones(18)).astype(np.float32)
    mu = rng.dirichlet(np.ones(20)).astype(np.float32)

    legacy = OTService(eps=0.1).distance(x, y, nu=nu, mu=mu)
    typed = OTService(eps=0.1, want=("cost", "plan_sparse"))
    typed.submit(x, y, nu=nu, mu=mu)
    s = typed.run_batch()[0]
    assert isinstance(s, Solution)
    assert s.cost == legacy["cost"]
    assert np.array_equal(s.plan_sparse().to_dense(), legacy["plan"])

    with AsyncOTScheduler(eps=0.1) as sched:
        f_legacy = sched.submit(x, y, nu=nu, mu=mu)
        f_typed = sched.submit(x, y, nu=nu, mu=mu,
                               want=("cost", "duals"))
        assert sched.flush(timeout=300)
        rl = f_legacy.result(timeout=5)
        rt = f_typed.result(timeout=5)
        assert isinstance(rt, Solution)
        assert rt.cost == rl["cost"]
        assert rt.stats.devices == rl["devices"]


def test_serve_layers_want_without_cost():
    """A declared want that excludes 'cost' must not crash the serving
    layers (their completion sync is ungated) nor poison co-tenants."""
    from repro.serve.engine import OTService
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(12)
    x = rng.uniform(size=(14, 2)).astype(np.float32)
    y = rng.uniform(size=(16, 2)).astype(np.float32)
    nu = rng.dirichlet(np.ones(14)).astype(np.float32)
    mu = rng.dirichlet(np.ones(16)).astype(np.float32)

    svc = OTService(eps=0.1, want=("plan_sparse",))
    svc.submit(x, y, nu=nu, mu=mu)
    s = svc.run_batch()[0]
    assert s.plan_sparse().nnz > 0
    with pytest.raises(ArtifactNotRequested):
        _ = s.cost

    with AsyncOTScheduler(eps=0.1) as sched:
        f = sched.submit(x, y, nu=nu, mu=mu, want=("duals",))
        assert sched.flush(timeout=300)
        rs = f.result(timeout=5)
        y_b, y_a = rs.duals()
        assert y_b.shape == (14,) and y_a.shape == (16,)


# --------------------------------------------------------------------------
# Forced 8-device mesh (subprocess, same harness as test_problem_api.py)
# --------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
from repro.core.api import ASSIGNMENT, OT, DispatchPolicy, solve
from repro.launch.mesh import make_batch_mesh

rng = np.random.default_rng(21)
b = 20
ot = []
for _ in range(b):
    m = int(rng.integers(16, 40))
    n = int(rng.integers(m, 44))
    x = rng.uniform(size=(m, 2))
    y = rng.uniform(size=(n, 2))
    d = x[:, None, :] - y[None, :, :]
    ci = np.sqrt((d * d).sum(-1) + 1e-30).astype(np.float32)
    ot.append((ci, rng.dirichlet(np.ones(m)).astype(np.float32),
               rng.dirichlet(np.ones(n)).astype(np.float32)))
eps = np.where(np.arange(b) % 3 == 0, 0.05, 0.1)

mesh = make_batch_mesh()
out = {"devices": int(mesh.shape["data"])}
pol_mesh = DispatchPolicy(mode="mesh", mesh=mesh, chunk=4)
pol_cmp = DispatchPolicy(mode="compact", chunk=4)

legacy = solve(OT, ot, eps, pol_mesh)
sols = solve(OT, ot, eps, pol_mesh,
             want=("cost", "plan", "plan_sparse", "duals"))
cmp_sols = solve(OT, ot, eps, pol_cmp, want=("cost", "plan_sparse"))
ok_rt = ok_par = ok_stats = True
for d, s, sc in zip(legacy, sols, cmp_sols):
    ok_rt = ok_rt and np.array_equal(s.plan_sparse().to_dense(), s.plan())
    ok_rt = ok_rt and np.array_equal(d["plan"], s.plan())
    ok_par = ok_par and s.cost == sc.cost
    ok_par = ok_par and np.array_equal(
        s.plan_sparse().to_dense(), sc.plan_sparse().to_dense())
    ok_stats = ok_stats and s.stats.mode == "mesh"
out["roundtrip"] = bool(ok_rt)
out["parity"] = bool(ok_par)
out["stats_mode"] = bool(ok_stats)
out["mesh_used"] = any(s.stats.devices > 1 for s in sols)

# cost-only fetch accounting across the mesh
from repro.core.batched import pad_stack
mb = max(c.shape[0] for c, _, _ in ot)
nb = max(c.shape[1] for c, _, _ in ot)
inputs = {"c": pad_stack([c for c, _, _ in ot], (mb, nb)),
          "nu": pad_stack([v for _, v, _ in ot], (mb,)),
          "mu": pad_stack([v for _, _, v in ot], (nb,))}
sizes = np.asarray([c.shape for c, _, _ in ot], np.int32)
batch = solve(OT, inputs, eps, pol_mesh, sizes=sizes, want=("cost",))
batch.cost()
out["cost_only_bytes"] = int(batch.fetched_bytes)
out["dense_bytes"] = int(b * mb * nb * 4)

# certificates across the mesh (guaranteed bound)
gsols = solve(OT, ot, 0.1,
              DispatchPolicy(mode="mesh", mesh=mesh, chunk=4,
                             guaranteed=True),
              want=("cost", "duals"))
out["certificates"] = bool(all(
    s.dual_feasible() and s.additive_gap() <= s.additive_gap_bound()
    for s in gsols))
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_solution_surface_eight_devices():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # skip the TPU-backend probe (60s timeout in this image)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT:"):])
    assert out["devices"] == 8, out
    assert out["roundtrip"], out
    assert out["parity"], out
    assert out["stats_mode"], out
    assert out["mesh_used"], out
    assert out["certificates"], out
    assert out["cost_only_bytes"] < out["dense_bytes"] / 100, out
