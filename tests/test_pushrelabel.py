"""Assignment solver: accuracy vs exact oracle + paper invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.pushrelabel import (
    solve_assignment,
    solve_assignment_int,
    complete_matching,
    round_costs,
)
from repro.core.feasibility import check_invariants
from repro.core.exact import exact_assignment_cost
from repro.core.costs import build_cost_matrix


def _points_cost(n, m=None, seed=0):
    rng = np.random.default_rng(seed)
    m = m or n
    x = rng.uniform(size=(m, 2))
    y = rng.uniform(size=(n, 2))
    return np.asarray(build_cost_matrix(x, y, "euclidean"))


@pytest.mark.parametrize("n", [5, 40, 150])
@pytest.mark.parametrize("eps", [0.2, 0.05, 0.01])
def test_additive_bound_vs_exact(n, eps):
    c = _points_cost(n, seed=n)
    r = solve_assignment(jnp.asarray(c), eps)
    opt = exact_assignment_cost(c)
    assert float(r.cost) <= opt + 3.0 * eps * n * c.max() + 1e-5
    # perfect matching
    m = np.asarray(r.matching)
    assert (m >= 0).all() and len(np.unique(m)) == n


def test_guaranteed_flag_tightens():
    c = _points_cost(80, seed=3)
    opt = exact_assignment_cost(c)
    r = solve_assignment(jnp.asarray(c), 0.09, guaranteed=True)
    assert float(r.cost) <= opt + 0.09 * 80 * c.max() + 1e-5


@pytest.mark.parametrize("eps", [0.1, 0.02])
def test_invariants_hold_at_termination(eps):
    c = _points_cost(60, seed=7)
    scale = c.max()
    c_int = round_costs(jnp.asarray(c / scale), eps)
    st_ = solve_assignment_int(c_int, eps)
    checks = check_invariants(c_int, st_.y_b, st_.y_a, st_.match_ba, eps)
    assert all(checks.values()), checks


def test_unbalanced_rows_less_than_cols():
    c = _points_cost(90, m=40, seed=9)
    r = solve_assignment(jnp.asarray(c), 0.05)
    m = np.asarray(r.matching)
    assert (m >= 0).all() and len(np.unique(m)) == 40  # all supplies matched
    opt = exact_assignment_cost(c)  # scipy matches all rows of min side
    assert float(r.cost) <= opt + 3 * 0.05 * 40 * c.max() + 1e-5


def test_phase_and_sum_ni_bounds():
    """Eq. (4): sum n_i <= n(1+2e)/e ; t <= (1+2e)/e^2."""
    n, eps = 120, 0.05
    c = _points_cost(n, seed=11)
    c_int = round_costs(jnp.asarray(c / c.max()), eps)
    st_ = solve_assignment_int(c_int, eps)
    assert int(st_.sum_ni) <= n * (1 + 2 * eps) / eps + 1
    assert int(st_.phases) <= (1 + 2 * eps) / eps**2 + 1


def test_matching_cardinality_at_termination():
    n, eps = 100, 0.1
    c = _points_cost(n, seed=13)
    c_int = round_costs(jnp.asarray(c / c.max()), eps)
    st_ = solve_assignment_int(c_int, eps)
    assert int(jnp.sum(st_.match_ba >= 0)) >= (1 - eps) * n - 1


def test_zero_cost_matrix():
    c = jnp.zeros((12, 12))
    r = solve_assignment(c, 0.1)
    assert float(r.cost) == 0.0
    assert len(np.unique(np.asarray(r.matching))) == 12


def test_complete_matching_fills_all_rows():
    match_ba = jnp.array([2, -1, 0, -1], dtype=jnp.int32)
    match_ab = jnp.array([2, -1, 0, -1, -1], dtype=jnp.int32)
    full = np.asarray(complete_matching(match_ba, match_ab))
    assert (full >= 0).all()
    assert len(np.unique(full)) == 4


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 24),
    eps=st.sampled_from([0.3, 0.1, 0.05]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_random_costs(n, eps, seed):
    """Bound + invariants + perfect matching on arbitrary random costs."""
    rng = np.random.default_rng(seed)
    c = rng.uniform(size=(n, n)).astype(np.float32)
    r = solve_assignment(jnp.asarray(c), eps)
    opt = exact_assignment_cost(c)
    assert float(r.cost) <= opt + 3 * eps * n * c.max() + 1e-4
    m = np.asarray(r.matching)
    assert (m >= 0).all() and len(np.unique(m)) == n
    c_int = round_costs(jnp.asarray(c / c.max()), eps)
    st_ = solve_assignment_int(c_int, eps)
    checks = check_invariants(c_int, st_.y_b, st_.y_a, st_.match_ba, eps)
    assert all(checks.values()), checks


def test_duals_certify_weak_lower_bound():
    """sum(y) - eps*n is a certified lower bound on OPT (rounded costs)."""
    n, eps = 80, 0.05
    c = _points_cost(n, seed=17)
    scale = float(c.max())
    c_int = round_costs(jnp.asarray(c / scale), eps)
    st_ = solve_assignment_int(c_int, eps)
    # Lemma 3.1 internals: sum of duals <= c_int(M_opt) + n (int units)
    total_dual = int(jnp.sum(st_.y_b) + jnp.sum(st_.y_a))
    opt_int = exact_assignment_cost(np.asarray(c_int))
    assert total_dual <= opt_int + n
