"""Resumable stepped cores + convergence-compacting batch driver.

Invariants under test:
  * chunked ``run_phases`` with ANY chunk size k reproduces the one-shot
    while_loop solve bit for bit (assignment and OT, padded and unpadded);
  * the compacting driver's per-instance results equal the PR-1 lockstep
    batched path (and hence unbatched solves) on convergence-skewed batches;
  * retiring an instance never perturbs a survivor (result hashes are
    invariant to batch composition);
  * the OT termination threshold is computed host-side in float64
    (f32(eps) * total rounds the wrong way for some (eps, total) pairs).
"""
import hashlib

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.batched import solve_assignment_batched, solve_ot_batched, \
    solve_assignment_ragged, solve_ot_ragged
from repro.core.compaction import (
    pow2_at_least,
    solve_assignment_batched_compacting,
    solve_ot_batched_compacting,
)
from repro.core.costs import build_cost_matrix
from repro.core.pushrelabel import (
    _max_phases,
    assignment_converged,
    assignment_prologue,
    init_assignment_state,
    run_assignment_phases,
    solve_assignment,
    solve_assignment_int,
)
from repro.core.transport import (
    init_ot_state,
    ot_converged,
    ot_phase_cap,
    ot_prologue,
    ot_termination_threshold,
    run_ot_phases,
    solve_ot,
    solve_ot_int,
)


def _points_cost(m, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(m, 2))
    y = rng.uniform(size=(n, 2))
    return np.asarray(build_cost_matrix(x, y, "euclidean"))


def _skewed_batch(b, mb, nb, seed, n_slow=2):
    """Padded batch with a convergence-skewed phase profile: most instances
    are near-diagonal (few phases), ``n_slow`` have an expensive far
    cluster (duals must climb ~1/eps steps)."""
    rng = np.random.default_rng(seed)
    c = np.zeros((b, mb, nb), np.float32)
    nu = np.zeros((b, mb), np.float32)
    mu = np.zeros((b, nb), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    insts = []
    for i in range(b):
        m = int(rng.integers(mb // 2 + 1, mb + 1))
        n = int(rng.integers(m, nb + 1))
        x = rng.uniform(size=(m, 2))
        if i < n_slow:
            # adversarial slow tail: half the demands sit across the square
            y = np.where(np.arange(n)[:, None] % 2 == 0,
                         x[np.arange(n) % m] * 0.02,
                         1.0 - 0.02 * rng.uniform(size=(n, 2)))
        else:
            y = rng.uniform(size=(n, 2))
        ci = np.asarray(build_cost_matrix(x, y, "euclidean"),
                        np.float32)
        c[i, :m, :n] = ci
        nu[i, :m] = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu[i, :n] = rng.dirichlet(np.ones(n)).astype(np.float32)
        sizes[i] = (m, n)
        insts.append((ci, nu[i, :m].copy(), mu[i, :n].copy()))
    return c, nu, mu, sizes, insts


def _state_equal(a, b):
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# --------------------------------------------------------------------------
# Resumability: chunked == one-shot, bit for bit, for every k
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 3, 7, 1000])
def test_assignment_chunked_equals_one_shot(k):
    eps = 0.1
    c = _points_cost(40, 56, seed=2)
    cm, c_int, scale, _, _ = assignment_prologue(jnp.asarray(c), eps)
    ref = solve_assignment_int(c_int, eps)

    m, n = c.shape
    threshold = int(eps * m)
    cap = _max_phases(eps, m)
    state = init_assignment_state(m, n)
    steps = 0
    while not bool(assignment_converged(state, threshold, cap)):
        state = run_assignment_phases(c_int, state, threshold, cap, k)
        steps += 1
        assert steps < 1000
    _state_equal(state, ref)
    if k == 1:
        assert steps == int(ref.phases)  # one dispatch per phase


@pytest.mark.parametrize("k", [1, 5, 64])
def test_assignment_chunked_equals_one_shot_padded(k):
    """Padded instance (m_valid/n_valid masks) through the chunked core."""
    eps = 0.05
    mi, ni, mb, nb = 30, 37, 48, 48
    c = np.zeros((mb, nb), np.float32)
    c[:mi, :ni] = _points_cost(mi, ni, seed=5)
    threshold = int(eps * mi)
    cm, c_int, scale, row_ok, col_ok = assignment_prologue(
        jnp.asarray(c), eps, jnp.int32(mi), jnp.int32(ni)
    )
    ref = solve_assignment_int(c_int, eps, m_valid=jnp.int32(mi),
                               threshold=jnp.int32(threshold))
    cap = _max_phases(eps, mb)
    state = init_assignment_state(mb, nb)
    while not bool(assignment_converged(state, threshold, cap,
                                        m_valid=jnp.int32(mi))):
        state = run_assignment_phases(c_int, state, threshold, cap, k,
                                      m_valid=jnp.int32(mi))
    _state_equal(state, ref)


@pytest.mark.parametrize("k", [1, 4, 1000])
def test_ot_chunked_equals_one_shot(k):
    eps = 0.1
    rng = np.random.default_rng(7)
    m, n = 28, 35
    c = _points_cost(m, n, seed=7)
    nu = rng.dirichlet(np.ones(m)).astype(np.float32)
    mu = rng.dirichlet(np.ones(n)).astype(np.float32)
    theta = 4.0 * max(m, n) / eps
    c_int, s_int, d_int, scale = ot_prologue(
        jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), theta, eps
    )
    threshold = ot_termination_threshold(nu, theta, eps)
    cap = ot_phase_cap(eps)
    max_rounds = int(m + n + 2)
    ref = solve_ot_int(c_int, s_int, d_int, eps, cap, max_rounds,
                       threshold=jnp.int32(threshold))

    state = init_ot_state(s_int, d_int)
    while not bool(ot_converged(state, threshold, cap)):
        state = run_ot_phases(c_int, state, threshold, cap, k, max_rounds)
    _state_equal(state, ref)


# --------------------------------------------------------------------------
# Compaction: driver results == lockstep results on skewed batches
# --------------------------------------------------------------------------

def test_compacting_assignment_matches_lockstep_skewed():
    eps = 0.1
    c, _, _, sizes, _ = _skewed_batch(6, 48, 64, seed=11)
    r0 = solve_assignment_batched(c, eps, sizes=sizes)
    r1, stats = solve_assignment_batched_compacting(c, eps, sizes=sizes,
                                                    k=3)
    np.testing.assert_array_equal(np.asarray(r0.matching),
                                  np.asarray(r1.matching))
    np.testing.assert_array_equal(np.asarray(r0.phases),
                                  np.asarray(r1.phases))
    np.testing.assert_array_equal(np.asarray(r0.cost), np.asarray(r1.cost))
    # scaled duals: same integer state, but the standalone epilogue program
    # may reassociate the f32 (y * eps * scale) product -> 1-ulp tolerance
    np.testing.assert_allclose(np.asarray(r0.y_b), np.asarray(r1.y_b),
                               rtol=2e-7, atol=0)
    assert stats.dispatches >= 2
    assert stats.occupancy[-1][1] == 0          # everyone terminated
    # the skew is real: compaction executed fewer phase-slots than lockstep
    assert stats.phases_needed < stats.lockstep_slot_phases


def test_compacting_ot_matches_lockstep_skewed():
    eps = 0.1
    c, nu, mu, sizes, _ = _skewed_batch(6, 48, 48, seed=13)
    r0 = solve_ot_batched(c, nu, mu, eps, sizes=sizes)
    r1, stats = solve_ot_batched_compacting(c, nu, mu, eps, sizes=sizes,
                                            k=4)
    np.testing.assert_array_equal(np.asarray(r0.phases),
                                  np.asarray(r1.phases))
    np.testing.assert_array_equal(np.asarray(r0.plan), np.asarray(r1.plan))
    np.testing.assert_array_equal(np.asarray(r0.cost), np.asarray(r1.cost))
    np.testing.assert_array_equal(np.asarray(r0.state.f_hi),
                                  np.asarray(r1.state.f_hi))
    assert stats.occupancy[-1][1] == 0


@pytest.mark.parametrize("k", [2, 8])
def test_chunk_size_invariance_of_driver(k):
    """Any k yields the same results — only the dispatch count changes."""
    eps = 0.05
    c, nu, mu, sizes, _ = _skewed_batch(5, 32, 32, seed=17)
    r1, s1 = solve_ot_batched_compacting(c, nu, mu, eps, sizes=sizes, k=k)
    r2, s2 = solve_ot_batched_compacting(c, nu, mu, eps, sizes=sizes, k=16)
    np.testing.assert_array_equal(np.asarray(r1.plan), np.asarray(r2.plan))
    np.testing.assert_array_equal(np.asarray(r1.phases),
                                  np.asarray(r2.phases))
    assert s1.dispatches >= s2.dispatches


def test_ragged_compact_matches_lockstep():
    rng = np.random.default_rng(19)
    insts = []
    for _ in range(5):
        m = int(rng.integers(12, 60))
        n = int(rng.integers(m, 60))
        c = _points_cost(m, n, seed=m + n)
        nu = rng.dirichlet(np.ones(m)).astype(np.float32)
        mu = rng.dirichlet(np.ones(n)).astype(np.float32)
        insts.append((c, nu, mu))
    r_lock = solve_ot_ragged(insts, 0.1, compact=False)
    r_comp = solve_ot_ragged(insts, 0.1, compact=True)
    for a, b in zip(r_lock, r_comp):
        np.testing.assert_array_equal(a["plan"], b["plan"])
        assert a["cost"] == b["cost"]
        assert a["phases"] == b["phases"]
        assert "dispatches" in b and "dispatches" not in a

    cs = [c for c, _, _ in insts]
    a_lock = solve_assignment_ragged(cs, 0.1, compact=False)
    a_comp = solve_assignment_ragged(cs, 0.1)
    for a, b in zip(a_lock, a_comp):
        np.testing.assert_array_equal(a["matching"], b["matching"])
        assert a["cost"] == b["cost"]


def test_mixed_eps_compacting_matches_solo():
    """Per-instance eps (inexpressible in the lockstep path) must equal a
    solo solve of each instance at its own eps."""
    eps = np.asarray([0.2, 0.05, 0.1, 0.05])
    c, nu, mu, sizes, insts = _skewed_batch(4, 40, 40, seed=23, n_slow=1)
    r, _ = solve_ot_batched_compacting(c, nu, mu, eps, sizes=sizes, k=5)
    for i, (ci, nui, mui) in enumerate(insts):
        s = solve_ot(jnp.asarray(ci), jnp.asarray(nui), jnp.asarray(mui),
                     float(eps[i]))
        assert int(r.phases[i]) == int(s.phases)
        m, n = ci.shape
        np.testing.assert_allclose(np.asarray(r.plan)[i, :m, :n],
                                   np.asarray(s.plan), atol=1e-6)
        assert float(r.cost[i]) == pytest.approx(float(s.cost), abs=2e-6)


# --------------------------------------------------------------------------
# Retirement property: survivors' results are composition-invariant
# --------------------------------------------------------------------------

def _result_hash(matching, y_b, y_a):
    h = hashlib.sha256()
    for a in (matching, y_b, y_a):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


@pytest.mark.parametrize("seed", [29, 31, 37])
def test_retiring_never_perturbs_survivors(seed):
    """Property: each instance's result hash from a compacting batch equals
    its hash from (a) a batch with different neighbors and (b) a solo
    unbatched solve — i.e. retirement/compaction of other instances never
    leaks into a survivor."""
    eps = 0.1
    c, _, _, sizes, insts = _skewed_batch(6, 32, 32, seed=seed)
    r_full, _ = solve_assignment_batched_compacting(c, eps, sizes=sizes,
                                                    k=2)
    # drop the slow tail (indices 0..1): survivors must hash identically
    keep = np.arange(2, 6)
    r_sub, _ = solve_assignment_batched_compacting(
        c[keep], eps, sizes=sizes[keep], k=2
    )
    for j, i in enumerate(keep):
        m, n = sizes[i]
        h_full = _result_hash(np.asarray(r_full.matching)[i, :m],
                              np.asarray(r_full.y_b)[i, :m],
                              np.asarray(r_full.y_a)[i, :n])
        h_sub = _result_hash(np.asarray(r_sub.matching)[j, :m],
                             np.asarray(r_sub.y_b)[j, :m],
                             np.asarray(r_sub.y_a)[j, :n])
        assert h_full == h_sub
        # and equals the solo solve of the same instance
        s = solve_assignment(jnp.asarray(insts[i][0]), eps)
        np.testing.assert_array_equal(np.asarray(r_full.matching)[i, :m],
                                      np.asarray(s.matching))


# --------------------------------------------------------------------------
# OT termination threshold (host float64)
# --------------------------------------------------------------------------

def test_ot_threshold_host_float64():
    """eps=0.3 guaranteed (-> eps/3 = 0.0999...), total mass 10: the exact
    threshold is int(0.0999... * 10) = 0, but the old on-device computation
    f32(eps) * f32(total) = f32(0.1) * 10 = 1.0000000149 -> 1 terminated a
    full free unit too early. The host float64 threshold must be 0, and
    batched must agree with unbatched on exactly such an instance."""
    eps3 = 0.3 / 3.0
    nu = np.asarray([0.5, 0.5], np.float32)
    assert ot_termination_threshold(nu, 10.0, eps3) == 0
    assert int(np.float32(eps3) * np.float32(10.0)) == 1  # the replaced bug

    rng = np.random.default_rng(41)
    c = rng.uniform(0.2, 1.0, size=(2, 2)).astype(np.float32)
    mu = np.asarray([0.25, 0.75], np.float32)
    s = solve_ot(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), 0.3,
                 theta=10.0, guaranteed=True)
    r = solve_ot_batched(c[None], nu[None], mu[None], 0.3, theta=10.0,
                         guaranteed=True)
    assert int(r.phases[0]) == int(s.phases)
    np.testing.assert_array_equal(np.asarray(r.plan)[0],
                                  np.asarray(s.plan))


def test_pow2_descent_padding():
    """B=5 pads to 8 with born-converged empties; results unaffected."""
    assert pow2_at_least(5) == 8
    assert pow2_at_least(8) == 8
    assert pow2_at_least(1) == 1
    eps = 0.1
    c, _, _, sizes, insts = _skewed_batch(5, 24, 24, seed=43, n_slow=1)
    r, stats = solve_assignment_batched_compacting(c, eps, sizes=sizes, k=2)
    assert stats.dispatched_batch == 8 and stats.batch == 5
    assert r.matching.shape[0] == 5
    for i, (ci, _, _) in enumerate(insts):
        s = solve_assignment(jnp.asarray(ci), eps)
        m = ci.shape[0]
        np.testing.assert_array_equal(np.asarray(r.matching)[i, :m],
                                      np.asarray(s.matching))
