"""Sinkhorn baseline sanity: feasible-ish plans, log vs kernel agreement,
and the small-reg underflow the paper points out for the kernel variant."""
import numpy as np
import jax.numpy as jnp

from repro.core.sinkhorn import sinkhorn, reg_for_additive_eps
from repro.core.exact import exact_ot_cost
from repro.core.costs import build_cost_matrix


def _instance(n, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, 2))
    y = rng.uniform(size=(n, 2))
    c = np.asarray(build_cost_matrix(x, y, "euclidean"))
    nu = rng.dirichlet(np.ones(n))
    mu = rng.dirichlet(np.ones(n))
    return c, nu, mu


def test_log_domain_converges_and_bounds():
    c, nu, mu = _instance(40, 1)
    r = sinkhorn(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu),
                 reg=0.02, tol=1e-7)
    assert float(r.marginal_err) < 1e-6
    p = np.asarray(r.plan)
    np.testing.assert_allclose(p.sum(0), mu, atol=1e-4)
    opt = exact_ot_cost(c, nu, mu)
    # entropic plan cost is close to opt for small reg; must exceed opt - tiny
    assert float(r.cost) >= opt - 1e-5


def test_log_and_kernel_variants_agree_at_moderate_reg():
    c, nu, mu = _instance(25, 2)
    a = sinkhorn(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu),
                 reg=0.1, tol=1e-8)
    b = sinkhorn(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu),
                 reg=0.1, tol=1e-8, use_log=False)
    assert abs(float(a.cost) - float(b.cost)) < 1e-4


def test_kernel_variant_underflows_at_small_reg():
    """The paper's Section 5 observation: exp(-c/reg) underflows -> the
    kernel-matrix iteration degrades or stalls while log-domain stays fine."""
    c, nu, mu = _instance(30, 3)
    reg = 0.002
    k = np.exp(-c / reg)
    assert (k.sum(1) == 0).any()  # rows fully underflow in fp64 even
    rlog = sinkhorn(jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu),
                    reg=reg, tol=1e-7, max_iters=4000)
    assert np.isfinite(float(rlog.cost))


def test_reg_heuristic_monotone():
    assert reg_for_additive_eps(0.1, 100) > reg_for_additive_eps(0.01, 100)
