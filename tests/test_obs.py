"""Observability layer tests: registry/instrument semantics, sink
round-trips, span trees, the profiler hook, and — the contract that
matters — EXACT reconciliation between the scheduler's registry-backed
stats and the event stream an attached sink saw, under a 12-thread
submit stress with injected faults."""
from __future__ import annotations

import json
import logging
import threading

import numpy as np
import pytest

from repro.obs import (
    InMemorySink,
    JSONLSink,
    LoggingSink,
    MetricsRegistry,
    MetricsSink,
    NullSink,
    Tracer,
    now,
    profiler,
    span_tree,
)


# --------------------------------------------------------------------------
# Instruments + registry
# --------------------------------------------------------------------------

def test_counter_exact_under_threads():
    reg = MetricsRegistry()
    c = reg.counter("t.c")
    N, T = 5000, 8

    def work():
        for _ in range(N):
            c.add(1)

    ts = [threading.Thread(target=work) for _ in range(T)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == N * T
    assert reg.snapshot()["t.c"] == N * T


def test_counter_get_or_create_is_same_instrument():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")          # name already taken by a Counter


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("t.g")
    g.set(3.5)
    g.set(7.0)
    assert g.value == 7.0
    assert reg.snapshot()["t.g"] == 7.0


def test_histogram_explicit_bounds_placement():
    reg = MetricsRegistry()
    h = reg.histogram("t.h", bounds=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    agg = h.aggregate()
    assert agg["buckets"] == [1, 1, 1, 1]      # one per bucket + overflow
    assert agg["count"] == 4
    assert agg["sum"] == pytest.approx(55.55)
    assert agg["bounds"] == [0.1, 1.0, 10.0]


def test_histogram_rejects_bad_bounds():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("bad", bounds=())
    with pytest.raises(ValueError):
        reg.histogram("bad2", bounds=(1.0, 1.0, 2.0))
    reg.histogram("ok", bounds=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("ok", bounds=(1.0, 3.0))   # re-register, new bounds


def test_history_is_bounded():
    reg = MetricsRegistry()
    ring = reg.history("t.occ", maxlen=3)
    for i in range(10):
        ring.append(i)
    assert ring.snapshot() == [7, 8, 9]
    assert ring.maxlen == 3


def test_sinks_satisfy_protocol():
    for s in (NullSink(), InMemorySink(), LoggingSink()):
        assert isinstance(s, MetricsSink)


def test_attach_streams_to_sink():
    sink = InMemorySink()
    reg = MetricsRegistry()
    reg.counter("a").add(1)              # before attach: not streamed
    reg.attach(sink)
    reg.counter("a").add(2)
    reg.gauge("g").set(4.0)
    reg.histogram("h", bounds=(1.0,)).observe(0.5)
    assert sink.counter_total("a") == 2  # only post-attach observations
    assert reg.snapshot()["a"] == 3      # aggregate view has both
    kinds = {r[0] for r in sink.records}
    assert kinds == {"counter", "gauge", "histogram"}


# --------------------------------------------------------------------------
# JSONL / logging sinks
# --------------------------------------------------------------------------

def test_jsonl_sink_round_trip(tmp_path):
    path = tmp_path / "obs.jsonl"
    sink = JSONLSink(str(path))
    reg = MetricsRegistry(sinks=(sink,))
    reg.counter("c").add(3)
    tr = Tracer(reg)
    with tr.span("outer", trace_id="t-1") as sp:
        tr.event("ping", trace_id="t-1", parent_id=sp.span_id,
                 value=np.float32(1.5))       # numpy must serialize
    sink.close()
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    kinds = [r["kind"] for r in rows]
    assert kinds.count("counter") == 1
    evs = [r for r in rows if r["kind"] == "event"]
    assert {e["event"] for e in evs} == {"ping", "span"}
    ping = next(e for e in evs if e["event"] == "ping")
    assert ping["data"]["value"] == 1.5
    span = next(e for e in evs if e["event"] == "span")
    assert span["data"]["name"] == "outer"
    assert span["data"]["dur_s"] >= 0.0
    sink.close()                               # idempotent


def test_logging_sink(caplog):
    logger = logging.getLogger("test.obs.sink")
    reg = MetricsRegistry(sinks=(LoggingSink(logger),))
    with caplog.at_level(logging.INFO, logger="test.obs.sink"):
        reg.counter("c").add(1)
        reg.emit("boom", {"t": now()})
    assert any("counter c" in r.message for r in caplog.records)
    assert any("event boom" in r.message for r in caplog.records)


# --------------------------------------------------------------------------
# Tracing
# --------------------------------------------------------------------------

def test_span_tree_renders_hierarchy():
    sink = InMemorySink()
    tr = Tracer(MetricsRegistry(sinks=(sink,)))
    root = tr.start("root", trace_id="t-9")
    with tr.span("child", trace_id="t-9", parent=root.span_id):
        with tr.span("other-trace", trace_id="t-10"):
            pass
    root.end()
    tree = span_tree(sink.spans(), "t-9")
    lines = tree.splitlines()
    assert lines[0].startswith("root")
    assert lines[1].startswith("  child")
    assert "other-trace" not in tree


def test_span_end_is_idempotent_and_error_annotated():
    sink = InMemorySink()
    tr = Tracer(MetricsRegistry(sinks=(sink,)))
    with pytest.raises(ValueError):
        with tr.span("will-fail", trace_id="t-1"):
            raise ValueError("boom")
    (sp,) = sink.spans("will-fail")
    assert sp["error"] == "ValueError"
    sink2 = InMemorySink()
    tr2 = Tracer(MetricsRegistry(sinks=(sink2,)))
    s = tr2.start("once", trace_id="t-2")
    s.end(k=1)
    s.end(k=2)                     # ignored: first end wins
    (sp2,) = sink2.spans("once")
    assert sp2["k"] == 1


# --------------------------------------------------------------------------
# Profiler hook
# --------------------------------------------------------------------------

def test_profiler_claim_match_and_exhaustion(tmp_path):
    cap = profiler.TraceCapture()
    cap.arm(str(tmp_path), match="64x64", captures=1)
    assert cap.armed()
    assert cap.claim("dispatch:32x32:mesh") is None     # no match
    d = cap.claim("dispatch:64x64:mesh")
    assert d is not None and d.startswith(str(tmp_path))
    assert cap.claim("dispatch:64x64:mesh") is None     # slots exhausted
    assert not cap.armed()


def test_profiler_env_arming(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_PROFILE_CAPTURES", "2")
    cap = profiler.TraceCapture()
    assert cap.armed()
    assert cap.claim("anything") is not None
    cap.disarm()
    assert not cap.armed()          # disarm beats the env


def test_profiler_capture_writes_trace(tmp_path):
    import jax.numpy as jnp

    cap = profiler.TraceCapture()
    cap.arm(str(tmp_path), captures=1)
    with cap.capture("dispatch:tiny") as live:
        assert live
        jnp.ones((4, 4)).sum().block_until_ready()
    files = list(tmp_path.rglob("*"))
    assert any(f.is_file() for f in files)      # a capture was written
    with cap.capture("dispatch:tiny") as live:
        assert not live                         # disarmed: body still ran


# --------------------------------------------------------------------------
# Scheduler integration: exact reconciliation under thread stress
# --------------------------------------------------------------------------

def test_scheduler_stress_events_reconcile_with_stats():
    """12 submitting threads against a live scheduler with an in-memory
    sink and an injected fault plan; afterwards, every SchedulerStats
    counter must reconcile EXACTLY with the event stream the sink saw:
    the registry and the stream are one source of truth, not two."""
    from repro.serve.faults import FaultInjector, FaultPlan
    from repro.serve.scheduler import AsyncOTScheduler

    T, PER = 12, 3                  # 36 submits total
    total = T * PER
    plan = FaultPlan(
        poison_submits=(5, 17),     # NaN -> admission rejection
        poison_dispatch_of=(11,),   # dispatch-time poison -> quarantine
        transient_dispatches=2,     # first attempts retry down the ladder
    )
    inj = FaultInjector(plan=plan)
    sink = InMemorySink()
    rng = np.random.default_rng(0)
    xs = [rng.random((6, 2)) for _ in range(total)]
    ys = [rng.random((6, 2)) for _ in range(total)]
    with AsyncOTScheduler(eps=0.25, max_batch=8, linger_ms=2.0,
                          faults=inj, sinks=(sink,)) as sched:
        futs: list = []
        flock = threading.Lock()

        def client(k):
            for i in range(PER):
                f = sched.submit(xs[k * PER + i], ys[k * PER + i])
                with flock:
                    futs.append(f)

        threads = [threading.Thread(target=client, args=(k,))
                   for k in range(T)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sched.flush(timeout=180)
        stats = sched.stats
        resolved = rejected = quarantined = 0
        for f in futs:
            try:
                out = f.result(timeout=60)
                assert "cost" in out
                resolved += 1
            except Exception as e:
                name = type(e).__name__
                assert name == "RequestRejected", name
                if "poison" in str(e):
                    quarantined += 1
                else:
                    rejected += 1
    # Futures vs plan
    assert rejected == len(plan.poison_submits)
    assert quarantined == len(plan.poison_dispatch_of)
    assert resolved == total - rejected - quarantined
    # SchedulerStats vs the event stream — exact, field by field
    assert stats.requests == resolved
    assert stats.rejected == rejected == sink.count("rejected")
    assert stats.quarantined == quarantined == sink.count("quarantine")
    assert stats.retries == sum(e["n"] for e in sink.events("retry"))
    assert stats.retries >= plan.transient_dispatches
    assert stats.dispatches == sink.count("chunk")
    assert sink.count("submit") == total
    spans = sink.spans("request")
    assert len(spans) == total      # every root span ended, exactly once
    outcomes = [s["outcome"] for s in spans]
    assert outcomes.count("resolved") == resolved
    assert outcomes.count("rejected") == rejected
    assert outcomes.count("quarantined") == quarantined
    # the streamed counter increments sum to the aggregate view
    assert sink.counter_total("scheduler.requests") == stats.requests
    assert sink.counter_total("scheduler.rejected") == stats.rejected
    # resolved dispatch spans == batches (bisection halves included)
    dspans = [s for s in sink.spans("dispatch")
              if s.get("outcome") == "resolved"]
    assert len(dspans) == stats.batches


def test_scheduler_results_bit_identical_with_and_without_sink():
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(7)
    pairs = [(rng.random((6, 2)), rng.random((6, 2))) for _ in range(4)]

    def run(sinks):
        with AsyncOTScheduler(eps=0.25, max_batch=4,
                              linger_ms=5.0, sinks=sinks) as sched:
            futs = [sched.submit(x, y) for x, y in pairs]
            assert sched.flush(timeout=120)
            return [f.result(timeout=60) for f in futs]

    a = run(())
    b = run((InMemorySink(),))
    for ra, rb in zip(a, b):
        assert ra["cost"] == rb["cost"]
        assert np.array_equal(ra["matching"], rb["matching"])
        assert ra["phases"] == rb["phases"]


def test_occupancy_window_knob():
    from repro.serve.scheduler import AsyncOTScheduler

    rng = np.random.default_rng(1)
    with AsyncOTScheduler(eps=0.25, max_batch=1,
                          occupancy_window=2) as sched:
        futs = [sched.submit(rng.random((6, 2)), rng.random((6, 2)))
                for _ in range(5)]
        assert sched.flush(timeout=120)
        for f in futs:
            f.result(timeout=60)
        d = sched.stats_dict()
    assert d["batches"] == 5
    assert d["occupancy_window"] == 2
    assert len(d["occupancy"]) <= 2     # truncated to the window


def test_service_stats_dict_is_registry_view():
    from repro.serve.engine import OTService

    rng = np.random.default_rng(2)
    sink = InMemorySink()
    svc = OTService(eps=0.25, sinks=(sink,))
    for _ in range(3):
        svc.submit(rng.random((6, 2)), rng.random((6, 2)))
    res = svc.run_batch()
    assert len(res) == 3
    d = svc.stats_dict()
    assert d["requests"] == 3
    assert d["batches"] >= 1
    assert d["dispatches"] == sink.count("chunk")
    assert sink.counter_total("service.requests") == d["requests"]
    names = {s["name"] for s in sink.spans()}
    assert {"bucket", "admission", "solve", "artifact-fetch"} <= names


def test_driver_chunk_events_carry_phase_and_compile_delta():
    """The chunked driver's per-chunk events expose bucket occupancy,
    phase progress, and the compile-cache delta — all host scalars."""
    from repro.core.api import ASSIGNMENT, DispatchPolicy, solve

    rng = np.random.default_rng(3)
    c = rng.random((3, 8, 8))
    sink = InMemorySink()
    tr = Tracer(MetricsRegistry(sinks=(sink,)))
    pol = DispatchPolicy(mode="compact", chunk=2)
    sols = solve(ASSIGNMENT, {"c": c}, 0.25, pol, want=("cost",),
                 obs=tr.bind(trace_id="drv-1"))
    chunks = sink.events("chunk")
    assert len(chunks) == sols.stats.dispatches
    for e in chunks:
        assert e["trace_id"] == "drv-1"
        # live = unconverged lanes AFTER the chunk (occupancy semantics:
        # the final chunk of a bucket reports 0)
        assert 0 <= e["live"] <= e["bucket"]
        assert e["phases"] >= 0
        assert e["chunk_s"] >= 0.0
        assert "compiled" in e


def test_obs_scans_clean():
    """Both static gates stay clean over the observability layer: the
    lock-discipline scan (repro.obs targets included) and the host-sync
    audit over the instrumented driver loops."""
    from repro.analysis import locks, syncaudit

    assert [f for t in locks.default_targets()
            for f in locks.scan_lock_discipline(t)] == []
    assert syncaudit.audit_targets(syncaudit.default_targets()) == []
