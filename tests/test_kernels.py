"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.core.pushrelabel import solve_assignment


@pytest.mark.parametrize("m,n", [(7, 9), (128, 128), (130, 257), (64, 300)])
@pytest.mark.parametrize("salt", [0, 12345])
def test_slack_propose_matches_ref(m, n, salt):
    rng = np.random.default_rng(m * n + salt)
    c = rng.integers(0, 6, size=(m, n)).astype(np.int32)
    y_b = rng.integers(0, 4, size=m).astype(np.int32)
    y_a = -rng.integers(0, 4, size=n).astype(np.int32)
    avail = (rng.uniform(size=n) < 0.6)
    col, key = ops.slack_propose(
        jnp.asarray(c), jnp.asarray(y_b), jnp.asarray(y_a),
        jnp.asarray(avail), salt,
    )
    rcol, rkey = ref.slack_propose_ref(
        jnp.asarray(c), jnp.asarray(y_b), jnp.asarray(y_a),
        jnp.asarray(avail), jnp.int32(salt),
    )
    np.testing.assert_array_equal(np.asarray(col), np.asarray(rcol))
    np.testing.assert_array_equal(np.asarray(key), np.asarray(rkey))


@pytest.mark.parametrize("block", [32, 128])
def test_slack_propose_block_size_invariance(block):
    rng = np.random.default_rng(0)
    m, n = 100, 150
    c = rng.integers(0, 5, size=(m, n)).astype(np.int32)
    y_b = np.ones(m, np.int32)
    y_a = np.zeros(n, np.int32)
    avail = np.ones(n, bool)
    col, key = ops.slack_propose(
        jnp.asarray(c), jnp.asarray(y_b), jnp.asarray(y_a),
        jnp.asarray(avail), 7, block_m=block, block_n=block,
    )
    rcol, rkey = ref.slack_propose_ref(
        jnp.asarray(c), jnp.asarray(y_b), jnp.asarray(y_a),
        jnp.asarray(avail), jnp.int32(7),
    )
    np.testing.assert_array_equal(np.asarray(col), np.asarray(rcol))


@pytest.mark.parametrize("metric", ["sqeuclidean", "euclidean", "l1"])
@pytest.mark.parametrize("m,n,d", [(5, 7, 2), (130, 70, 3), (64, 64, 784),
                                   (200, 130, 33)])
def test_cost_matrix_matches_ref(metric, m, n, d):
    rng = np.random.default_rng(d)
    x = rng.uniform(size=(m, d)).astype(np.float32)
    y = rng.uniform(size=(n, d)).astype(np.float32)
    out = ops.cost_matrix(jnp.asarray(x), jnp.asarray(y), metric)
    expect = ref.cost_matrix_ref(jnp.asarray(x), jnp.asarray(y), metric)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("metric", ["sqeuclidean", "euclidean", "l1"])
def test_cost_matrix_batched_matches_single(metric):
    """Batched cost kernel (leading batch dim in the grid) == per-instance
    kernel, bit for bit, including padded tiles and the L1 feature chunks."""
    from repro.kernels import cost_matrix as cm

    rng = np.random.default_rng(17)
    b, m, n, d = 4, 70, 130, 33 if metric == "l1" else 3
    x = rng.uniform(size=(b, m, d)).astype(np.float32)
    y = rng.uniform(size=(b, n, d)).astype(np.float32)
    out = ops.cost_matrix_batched(jnp.asarray(x), jnp.asarray(y), metric)
    for i in range(b):
        single = cm.cost_matrix(jnp.asarray(x[i]), jnp.asarray(y[i]),
                                metric, interpret=True)
        np.testing.assert_array_equal(np.asarray(out)[i],
                                      np.asarray(single))
    # block-size invariance of the batched tiling
    out2 = ops.cost_matrix_batched(jnp.asarray(x), jnp.asarray(y), metric,
                                   block_m=32, block_n=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize("m,n", [(40, 60), (128, 384), (257, 129)])
def test_sinkhorn_row_update_matches_ref(m, n, dtype):
    rng = np.random.default_rng(m + n)
    c = rng.uniform(size=(m, n)).astype(dtype)
    g = (0.1 * rng.standard_normal(n)).astype(dtype)
    nu = rng.dirichlet(np.ones(m)).astype(dtype)
    reg = 0.05
    out = ops.sinkhorn_row_update(jnp.asarray(c), jnp.asarray(g),
                                  jnp.log(jnp.asarray(nu)), reg)
    expect = ref.sinkhorn_row_ref(jnp.asarray(c), jnp.asarray(g),
                                  jnp.log(jnp.asarray(nu)), reg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("block_m,block_n",
                         [(8, 32), (16, 128), (32, 64), (64, 256),
                          (128, 128)])
def test_slack_propose_tiling_invariance(block_m, block_n):
    """slack_propose output is invariant across (block_m, block_n)
    tilings, including non-divisible m/n edge tiles (100x150 divides
    none of the swept blocks evenly on at least one axis)."""
    rng = np.random.default_rng(42)
    m, n = 100, 150
    c = rng.integers(0, 5, size=(m, n)).astype(np.int32)
    y_b = rng.integers(0, 3, size=m).astype(np.int32)
    y_a = -rng.integers(0, 3, size=n).astype(np.int32)
    avail = (rng.uniform(size=n) < 0.7)
    args = (jnp.asarray(c), jnp.asarray(y_b), jnp.asarray(y_a),
            jnp.asarray(avail), 7)
    col0, key0 = ops.slack_propose(*args)
    col, key = ops.slack_propose(*args, block_m=block_m, block_n=block_n)
    np.testing.assert_array_equal(np.asarray(col), np.asarray(col0))
    np.testing.assert_array_equal(np.asarray(key), np.asarray(key0))


@pytest.mark.parametrize("block_m,block_n",
                         [(4, 16), (8, 32), (16, 64), (32, 256)])
def test_fused_phase_tiling_invariance(block_m, block_n):
    """The fused phase kernels' trajectories are invariant across
    (block_m, block_n) tilings — the tile padding (PAD_COST cols, zero
    supply rows) must be inert at every granularity, including edge
    tiles ((37, 53) divides none of the swept blocks)."""
    from repro.core.pushrelabel import init_assignment_state
    from repro.core.transport import init_ot_state

    rng = np.random.default_rng(3)
    m, n = 37, 53
    c_int = jnp.asarray(rng.integers(0, 200, size=(m, n)), jnp.int32)
    thr, cap = jnp.int32(2), jnp.int32(50)
    ref_st = ops.fused_run_assignment_phases(
        c_int, init_assignment_state(m, n), thr, cap, 4)
    out = ops.fused_run_assignment_phases(
        c_int, init_assignment_state(m, n), thr, cap, 4,
        block_m=block_m, block_n=block_n)
    for f, a, b in zip(ref_st._fields, ref_st, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"assignment {f}")

    s_int = jnp.asarray(rng.integers(1, 40, size=(m,)), jnp.int32)
    d_int = jnp.asarray(rng.integers(1, 40, size=(n,)), jnp.int32)
    c_ot = jnp.asarray(rng.integers(0, 60, size=(m, n)), jnp.int32)
    ref_ot = ops.fused_run_ot_phases(
        c_ot, init_ot_state(s_int, d_int), jnp.int32(3), jnp.int32(60),
        4, int(m + n + 2))
    out_ot = ops.fused_run_ot_phases(
        c_ot, init_ot_state(s_int, d_int), jnp.int32(3), jnp.int32(60),
        4, int(m + n + 2), block_m=block_m, block_n=block_n)
    for f, a, b in zip(ref_ot._fields, ref_ot, out_ot):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"ot {f}")


def test_kernel_blocks_backend_table():
    """Block sizes resolve per backend, with a cpu fallback for unknown
    backends, and the op wrappers accept explicit overrides."""
    for kernel in ("slack_propose", "cost_matrix", "sinkhorn_row_update",
                   "fused_phase"):
        for backend in ("tpu", "gpu", "cpu", "rocm-or-future"):
            blocks = ops.kernel_blocks(kernel, backend)
            assert all(isinstance(b, int) and b > 0 for b in blocks)
    assert len(ops.kernel_blocks("cost_matrix")) == 3  # (bm, bn, bk)
    assert len(ops.kernel_blocks("fused_phase")) == 2
    with pytest.raises(KeyError):
        ops.kernel_blocks("no_such_kernel")


def test_solver_with_pallas_propose_agrees_end_to_end():
    """Full push-relabel solve with the fused kernel as propose step must be
    bit-identical to the dense reference path (same hash, same argmin)."""
    rng = np.random.default_rng(5)
    n = 96
    c = rng.uniform(size=(n, n)).astype(np.float32)
    r_ref = solve_assignment(jnp.asarray(c), 0.05)
    r_pal = solve_assignment(jnp.asarray(c), 0.05,
                             propose_fn=ops.make_pallas_propose_fn())
    np.testing.assert_array_equal(np.asarray(r_ref.matching),
                                  np.asarray(r_pal.matching))
    assert float(r_ref.cost) == pytest.approx(float(r_pal.cost))
    assert int(r_ref.phases) == int(r_pal.phases)
