"""AsyncOTScheduler shutdown semantics: pending Futures are resolved or
cancelled — NEVER stranded — when ``close()`` races in-flight collate/
dispatch work, caller-side cancellation, bad requests, or a dead worker
thread.

What the hardening covers (each scenario below was a potential hang or
poisoned-batch before):
  * close() racing live submitter threads: every accepted Future resolves,
    late submits raise RuntimeError, close returns;
  * a tenant cancelling its Future must not poison the rest of its batch
    (set_result on a cancelled Future raises InvalidStateError; the old
    loop re-raised into the batch error path, failing innocent
    neighbors);
  * a request that blows up in collate fails only that batch, and the
    scheduler keeps serving afterwards;
  * a dead worker thread: flush()/close() detect it, fail the stranded
    Futures with RuntimeError, and return instead of waiting forever.
"""
import threading
import time

import numpy as np
import pytest

from repro.serve.scheduler import AsyncOTScheduler


def _pts(rng, m):
    return rng.uniform(size=(int(m), 2)).astype(np.float32)


def test_close_races_live_submitters():
    rng = np.random.default_rng(0)
    sched = AsyncOTScheduler(eps=0.2, linger_ms=2)
    # warm the compile cache so the race window isn't all XLA compile time
    sched.submit(_pts(rng, 12), _pts(rng, 12)).result(timeout=300)

    futs: list = []
    rejected = threading.Event()

    def spam(seed):
        r = np.random.default_rng(seed)
        while True:
            try:
                futs.append(sched.submit(_pts(r, r.integers(8, 16)),
                                         _pts(r, r.integers(8, 16))))
            except RuntimeError:
                rejected.set()
                return
            time.sleep(0.005)

    threads = [threading.Thread(target=spam, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    time.sleep(0.25)
    sched.close()                       # races the submitters
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    assert rejected.is_set()            # post-close submits were refused
    assert len(futs) > 0
    for f in futs:                      # every ACCEPTED future resolved
        assert f.done()
        assert "cost" in f.result(timeout=0)
    assert not sched._pending


def test_cancelled_future_does_not_poison_batch():
    rng = np.random.default_rng(1)
    with AsyncOTScheduler(eps=0.2, linger_ms=100) as sched:
        f1 = sched.submit(_pts(rng, 10), _pts(rng, 10))
        f2 = sched.submit(_pts(rng, 11), _pts(rng, 11))
        cancelled = f1.cancel()         # before collate drains (100ms linger)
        assert sched.flush(timeout=300)
        assert f2.done()
        assert "cost" in f2.result(timeout=0)   # neighbor unharmed
        assert f1.done()
        if cancelled:
            assert f1.cancelled()


def test_collate_error_fails_batch_but_scheduler_survives():
    rng = np.random.default_rng(2)
    with AsyncOTScheduler(eps=0.2, linger_ms=0) as sched:
        bad = sched.submit(np.ones((7,), np.float32),     # 1-D x: no dim
                           np.ones((7,), np.float32))
        with pytest.raises(Exception):
            bad.result(timeout=300)
        ok = sched.submit(_pts(rng, 9), _pts(rng, 9))
        assert "cost" in ok.result(timeout=300)


def test_dead_dispatch_worker_never_hangs():
    rng = np.random.default_rng(3)
    sched = AsyncOTScheduler(eps=0.2, linger_ms=0)
    try:
        # kill the dispatch worker out from under the scheduler
        sched._work_q.put(None)
        sched._dispatch_t.join(timeout=10)
        assert not sched._dispatch_t.is_alive()

        fut = sched.submit(_pts(rng, 8), _pts(rng, 8))
        t0 = time.monotonic()
        assert sched.flush(timeout=60)          # must NOT hang
        assert time.monotonic() - t0 < 60
        assert fut.done()
        with pytest.raises(RuntimeError):
            fut.result(timeout=0)
        # the broken pipeline refuses new work — an accepted submit with
        # no live worker would strand its Future
        with pytest.raises(RuntimeError):
            sched.submit(_pts(rng, 8), _pts(rng, 8))
    finally:
        sched.close()
    assert not sched._pending


def test_dead_dispatcher_full_work_queue_never_wedges_collate():
    """Dispatcher dies while collate still has batches to hand off: the
    bounded handoff queue fills, the collate worker must detect the dead
    consumer (bounded-wait put) and fail the batch instead of blocking
    forever — and close() must still join both workers promptly."""
    rng = np.random.default_rng(4)
    sched = AsyncOTScheduler(eps=0.2, linger_ms=50)
    try:
        sched._work_q.put(None)                 # kill the dispatcher
        sched._dispatch_t.join(timeout=10)
        assert not sched._dispatch_t.is_alive()

        # several shape buckets in one collate round -> several handoffs;
        # with maxsize=2 and no consumer the third put would block forever
        # without the liveness-checking handoff
        futs = [sched.submit(_pts(rng, m), _pts(rng, m))
                for m in (6, 18, 40, 7, 19, 41)]
        t0 = time.monotonic()
        assert sched.flush(timeout=120)
        assert time.monotonic() - t0 < 120
        for f in futs:
            assert f.done()
            with pytest.raises(RuntimeError):
                f.result(timeout=0)
    finally:
        sched.close()
    assert not sched._collate_t.is_alive()
    assert not sched._pending


def test_close_raises_on_hung_worker():
    """A worker that is still ALIVE after the join timeout (hung, not
    dead) must not be silently leaked: close() fails the pending Futures
    and raises a RuntimeError naming the hung worker."""
    rng = np.random.default_rng(5)
    sched = AsyncOTScheduler(eps=0.2, linger_ms=0, join_timeout_s=0.3)
    sched.submit(_pts(rng, 8), _pts(rng, 8)).result(timeout=300)

    # retire the real dispatch worker, then swap in a stand-in that never
    # exits: close()'s join times out with the thread still alive — the
    # hung-worker case (vs the DEAD-worker case covered above)
    sched._work_q.put(None)
    sched._dispatch_t.join(timeout=10)
    assert not sched._dispatch_t.is_alive()
    hang = threading.Event()
    dummy = threading.Thread(target=hang.wait, name="ot-dispatch",
                             daemon=True)
    dummy.start()
    sched._dispatch_t = dummy
    try:
        fut = sched.submit(_pts(rng, 9), _pts(rng, 9))
        # parked in the handoff queue where only the "hung" dispatcher
        # would ever see it
        time.sleep(0.2)
        with pytest.raises(RuntimeError, match="ot-dispatch"):
            sched.close()
        assert fut.done()                   # failed, not stranded
        with pytest.raises(RuntimeError):
            fut.result(timeout=0)
        assert not sched._pending
        sched.close()                       # second close is a no-op
    finally:
        hang.set()


def test_close_idempotent_and_reentrant():
    sched = AsyncOTScheduler(eps=0.2)
    sched.close()
    sched.close()                               # second close is a no-op
    with pytest.raises(RuntimeError):
        sched.submit(np.ones((4, 2)), np.ones((4, 2)))
