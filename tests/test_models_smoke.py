"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + train-grad step, prefill + decode; asserts shapes and finiteness.
(Full configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduced
from repro.models import model as M

ALL_ARCHS = sorted(ARCHS.keys())

# Minutes of compile+run across every architecture: out of the default
# tier-1 loop (-m "not slow").
pytestmark = pytest.mark.slow


def _concretize(specs, seed=0):
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32 and v.shape:
            out[k] = jax.random.randint(jax.random.key(seed), v.shape, 0, 500)
        elif not v.shape:
            out[k] = jnp.int32(0)
        else:
            out[k] = jax.random.normal(
                jax.random.key(seed + 1), v.shape, jnp.float32
            ).astype(v.dtype)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_forward_and_grad(arch):
    cfg = reduced(ARCHS[arch])
    params = M.init_params(cfg, jax.random.key(0))
    batch = _concretize(M.input_specs(cfg, 64, 2, "train"))
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch)
    )(params)
    assert np.isfinite(float(loss))
    assert 2.0 < float(loss) < 12.0  # ~ln(vocab) at init
    gn = sum(
        float(jnp.sum(g.astype(jnp.float32) ** 2))
        for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduced(ARCHS[arch])
    params = M.init_params(cfg, jax.random.key(0))
    batch = _concretize(M.input_specs(cfg, 32, 2, "prefill"))
    caches, logits0 = M.prefill(params, cfg, batch)
    assert logits0.shape == (2, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits0).all())
    caches = M.pad_caches(cfg, caches, 48)
    tok = jnp.ones((2, 1), jnp.int32)
    for step in range(2):
        logits, caches = M.decode_step(
            params, cfg, caches, tok, jnp.int32(32 + step)
        )
        assert logits.shape == (2, cfg.vocab_padded)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_decode_matches_forward_logits():
    """Greedy parity: decode-step logits must match teacher-forced forward
    logits position by position (dense arch)."""
    cfg = reduced(ARCHS["qwen3-4b"])
    params = M.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(9), (1, 12), 0, 400)
    caches, lg_prefill = M.prefill(params, cfg, {"tokens": toks})
    caches = M.pad_caches(cfg, caches, 16)
    lg_step, _ = M.decode_step(
        params, cfg, M.pad_caches(
            cfg, M.prefill(params, cfg, {"tokens": toks[:, :-1]})[0], 16
        ),
        toks[:, -1:], jnp.int32(11),
    )
    np.testing.assert_allclose(
        np.asarray(lg_prefill, np.float32),
        np.asarray(lg_step, np.float32),
        rtol=0.15, atol=0.15,  # bf16 accumulation differences
    )


def test_ssm_decode_matches_forward():
    """Mamba2: recurrent single-step decode must track the chunked SSD scan."""
    cfg = reduced(ARCHS["mamba2-2.7b"])
    params = M.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (1, 9), 0, 400)
    _, lg_full = M.prefill(params, cfg, {"tokens": toks})
    caches, _ = M.prefill(params, cfg, {"tokens": toks[:, :-1]})
    lg_step, _ = M.decode_step(params, cfg, caches, toks[:, -1:],
                               jnp.int32(8))
    np.testing.assert_allclose(
        np.asarray(lg_full, np.float32), np.asarray(lg_step, np.float32),
        rtol=0.15, atol=0.15,
    )


@pytest.mark.parametrize("router", ["topk", "sinkhorn", "pushrelabel"])
def test_moe_routers_in_model(router):
    cfg = reduced(ARCHS["deepseek-moe-16b"]).with_(router=router)
    params = M.init_params(cfg, jax.random.key(0))
    batch = _concretize(M.input_specs(cfg, 64, 2, "train"))
    loss = float(M.loss_fn(params, cfg, batch))
    assert np.isfinite(loss)


def test_pushrelabel_router_balances_skewed_logits():
    """On adversarially skewed logits top-k collapses onto one expert;
    the paper's balanced-assignment router caps every expert at capacity."""
    from repro.models.moe import route_topk, route_pushrelabel

    t, e, k = 512, 8, 1
    logits = jnp.concatenate(
        [jnp.full((t, 1), 5.0), jax.random.normal(jax.random.key(0), (t, e - 1))],
        axis=1,
    )
    sel_t, _ = route_topk(logits, k)
    sel_p, _ = route_pushrelabel(logits, k)
    load_t = np.bincount(np.asarray(sel_t).ravel(), minlength=e)
    load_p = np.bincount(np.asarray(sel_p).ravel(), minlength=e)
    assert load_t.max() > 0.9 * t          # collapse
    assert load_p.max() <= t / e + 1       # balanced to capacity


def test_full_configs_construct_abstractly():
    """Full production configs build abstract param trees (no allocation)."""
    for arch in ALL_ARCHS:
        cfg = ARCHS[arch]
        tree = M.abstract_params(cfg)
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        assert n > 1e8, (arch, n)
