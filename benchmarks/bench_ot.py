"""General OT (Section 4, clustered solver): runtime + accuracy vs LP and
vs Sinkhorn on non-uniform masses."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.transport import solve_ot
from repro.core.sinkhorn import sinkhorn, reg_for_additive_eps
from repro.core.costs import build_cost_matrix
from repro.core.exact import exact_ot_cost
from .common import emit, time_call, uniform_square_points


def run(full: bool = False, tiny: bool = False):
    ns = [128, 256] if not full else [256, 512, 1024]
    if tiny:
        ns = [64]      # CI smoke: one small grid, seconds on a CPU runner
    for n in ns:
        x, y = uniform_square_points(n, seed=n + 7)
        rng = np.random.default_rng(n)
        nu = jnp.asarray(rng.dirichlet(np.ones(n)).astype(np.float32))
        mu = jnp.asarray(rng.dirichlet(np.ones(n)).astype(np.float32))
        c = build_cost_matrix(jnp.asarray(x), jnp.asarray(y), "euclidean")
        opt = exact_ot_cost(np.asarray(c), np.asarray(nu), np.asarray(mu)) \
            if n <= 512 else None
        for eps in [0.1, 0.05]:
            t = time_call(lambda eps=eps: solve_ot(c, nu, mu, eps), repeats=2)
            r = solve_ot(c, nu, mu, eps)
            gap = (float(r.cost) - opt) / float(np.asarray(c).max()) \
                if opt else float("nan")
            emit(f"ot/pushrelabel/n={n}/eps={eps}", t,
                 f"phases={int(r.phases)};gap={gap:.5f};theta={r.theta:.0f}")
            reg = reg_for_additive_eps(eps, n)
            t_sk = time_call(
                lambda reg=reg, eps=eps: sinkhorn(c, nu, mu, reg=reg,
                                                  tol=eps / 8.0,
                                                  max_iters=2000), repeats=2)
            rs = sinkhorn(c, nu, mu, reg=reg, tol=eps / 8.0, max_iters=2000)
            gap_s = (float(rs.cost) - opt) / float(np.asarray(c).max()) \
                if opt else float("nan")
            emit(f"ot/sinkhorn/n={n}/eps={eps}", t_sk,
                 f"iters={int(rs.iters)};gap={gap_s:.5f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: single n=64 grid")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, tiny=args.tiny)
