"""Serving saturation: latency/throughput vs offered load through
``AsyncOTScheduler``, and what the observability layer costs when no
sink is attached.

  * saturation — a paced open-loop client submits point-set requests at
    a fixed offered rate (a fraction of the scheduler's measured burst
    capacity); per-request latency is taken submit -> Future-done on the
    one monotonic clock (``repro.obs.now``). Reported per load level:
    p50/p99 latency and achieved throughput (``instances_per_s``, the
    row benchmarks/run.py --diff gates at >20% regressions). Past
    saturation (offered > capacity) achieved throughput flattens while
    p99 grows with queue depth — the committed BENCH_serve.json keeps
    one sub-capacity, one near-capacity, and one past-capacity row.
  * obs overhead — the no-sink observability budget (<2%, asserted).
    Like bench_faults.py's admission budget, the asserted number is a
    DETERMINISTIC ratio: the per-request observability work (spans,
    events, counter/histogram updates against a sink-less registry) is
    replayed in isolation and timed, then divided by the healthy
    per-request wall time. End-to-end on-vs-off wall clock is recorded
    as context only — on a shared runner its noise exceeds the
    microseconds under test.

    PYTHONPATH=src python -m benchmarks.bench_serve [--full|--tiny]

``--json OUT`` (and benchmarks/run.py) writes BENCH_serve.json.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.obs import InMemorySink, MetricsRegistry, Tracer
from repro.obs import now as _now
from repro.serve.scheduler import AsyncOTScheduler
from .common import emit

RECORDS: list = []

#: the no-sink observability layer may cost at most this fraction of the
#: healthy per-request wall time (asserted on every run, incl. --tiny)
OVERHEAD_BUDGET = 0.02


def record(name, seconds, derived="", **extra):
    emit(name, seconds, derived)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived, **extra})


def write_json(path="BENCH_serve.json"):
    payload = {
        "schema": 1,
        "bench": "serve",
        "backend": jax.default_backend(),
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)
    return path


def _pairs(count, n, seed=0):
    rng = np.random.default_rng(seed)
    return [(np.float32(rng.standard_normal((n, 2))),
             np.float32(rng.standard_normal((n, 2))))
            for _ in range(count)]


def _paced_run(pairs, rate, eps, sinks=(), max_batch=32, linger_ms=2.0):
    """Open-loop paced client: request i is submitted at ``t0 + i/rate``
    regardless of completions (so queueing delay shows up in latency,
    which is what saturation means). Returns (wall_s, latencies[])."""
    lats: dict = {}
    with AsyncOTScheduler(eps=eps, max_batch=max_batch,
                          linger_ms=linger_ms, sinks=sinks) as sched:
        t0 = _now()
        futs = []
        for i, (x, y) in enumerate(pairs):
            target = t0 + i / rate
            while True:
                dt = target - _now()
                if dt <= 0:
                    break
                time.sleep(min(dt, 0.01))
            t_sub = _now()
            fut = sched.submit(x, y)
            fut.add_done_callback(
                lambda _f, i=i, t=t_sub: lats.__setitem__(i, _now() - t))
            futs.append(fut)
        assert sched.flush(timeout=600)
        for f in futs:
            f.result(timeout=60)
        wall = _now() - t0
    lat = np.array([lats[i] for i in range(len(pairs))])
    return wall, lat


def _warm_all_batch_sizes(n, eps, max_batch):
    """Compile every program a paced run can hit: the collate worker
    drains ARBITRARY batch sizes 1..max_batch depending on arrival
    phasing, and each novel batch size is a novel compiled shape — an
    unwarmed one would bill its compile to whichever load level hits it
    first. A long linger makes each warm group collate as one batch."""
    pairs = _pairs(max_batch, n, seed=97 * n + max_batch)
    with AsyncOTScheduler(eps=eps, max_batch=max_batch,
                          linger_ms=100.0) as sched:
        for b in range(1, max_batch + 1):
            futs = [sched.submit(x, y) for x, y in pairs[:b]]
            assert sched.flush(timeout=600)
            for f in futs:
                f.result(timeout=60)


def run_saturation(count, n, eps, fracs=(0.5, 0.9, 1.5), max_batch=32):
    """Latency/throughput at ``fracs`` of measured burst capacity."""
    pairs = _pairs(count, n, seed=count + n)
    _warm_all_batch_sizes(n, eps, max_batch)
    # burst capacity: all requests offered at once -> the service rate
    wall, _ = _paced_run(pairs, 1e9, eps, max_batch=max_batch)
    capacity = count / wall
    record(f"serve/capacity/B={count}/n={n}/eps={eps}", wall / count,
           f"inst_per_s={capacity:.1f}", instances_per_s=capacity)
    for frac in fracs:
        rate = capacity * frac
        wall, lat = _paced_run(pairs, rate, eps, max_batch=max_batch)
        p50, p99 = np.percentile(lat, [50, 99])
        achieved = count / wall
        extra = dict(offered_per_s=rate, offered_fraction=frac,
                     p50_latency_s=float(p50), p99_latency_s=float(p99),
                     achieved_per_s=achieved)
        if frac <= 1.0:
            # only sub-capacity rows enter the --diff throughput gate:
            # past saturation, achieved throughput is queue-dynamics
            # noise (the capacity row above gates the service rate; the
            # past-capacity row's information is its latency curve)
            extra["instances_per_s"] = achieved
        record(
            f"serve/load/B={count}/n={n}/eps={eps}/offered={frac:.1f}x",
            float(lat.mean()),
            f"offered_per_s={rate:.1f};achieved_per_s={achieved:.1f};"
            f"p50_ms={p50 * 1e3:.1f};p99_ms={p99 * 1e3:.1f}",
            **extra,
        )
    return capacity


def _obs_ops_once(tr, c_req, h_wait, h_solve):
    """The per-request observability work on the serving path, replayed
    against a sink-less registry: root span + submit event (submit side),
    wait/solve observations + counters + span end (resolve side), and
    one shared solve-span + chunk event amortized per request."""
    root = tr.start("request", trace_id="req-bench", seq=0, tenant=None)
    tr.event("submit", trace_id="req-bench", parent_id=root.span_id,
             seq=0, tenant=None)
    with tr.span("solve", trace_id="bucket-bench"):
        tr.event("chunk", trace_id="bucket-bench", bucket=32, live=1,
                 chunk_s=0.0, phases=1, compiled=0)
    c_req.add(1)
    h_wait.observe(0.001)
    h_solve.observe(0.01)
    root.end(outcome="resolved", bucket_trace="bucket-bench",
             wait_s=0.001, solve_s=0.01, degraded=False)


def run_obs_overhead(count, n, eps, reps=2000):
    """Assert the no-sink observability budget: replayed per-request obs
    ops cost / healthy per-request wall time < OVERHEAD_BUDGET."""
    pairs = _pairs(count, n, seed=7 * n + count)
    _paced_run(pairs, 1e9, eps)                 # warm compile
    wall, _ = _paced_run(pairs, 1e9, eps)       # healthy path (no sink)
    healthy_per_req = wall / count
    wall_sink, _ = _paced_run(pairs, 1e9, eps,
                              sinks=(InMemorySink(),))   # context only

    reg = MetricsRegistry()                     # no sinks: the hot path
    tr = Tracer(reg)
    c_req = reg.counter("scheduler.requests")
    h_wait = reg.histogram("scheduler.wait_s")
    h_solve = reg.histogram("scheduler.solve_s")
    _obs_ops_once(tr, c_req, h_wait, h_solve)   # warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            _obs_ops_once(tr, c_req, h_wait, h_solve)
        best = min(best, (time.perf_counter() - t0) / reps)
    overhead = best / healthy_per_req
    assert overhead < OVERHEAD_BUDGET, (
        f"no-sink observability costs {overhead:.2%} of the healthy "
        f"per-request time (budget {OVERHEAD_BUDGET:.0%}) at "
        f"B={count} n={n}")
    record(
        f"serve/obs_overhead/B={count}/n={n}/eps={eps}", best,
        f"obs_us_per_req={best * 1e6:.1f};"
        f"healthy_us_per_req={healthy_per_req * 1e6:.1f};"
        f"overhead={overhead:.3%};budget={OVERHEAD_BUDGET:.0%};"
        f"inmem_sink_wall_ratio={wall_sink / wall:.2f}x",
        obs_s_per_request=best,
        healthy_s_per_request=healthy_per_req,
        overhead_fraction=overhead,
        sink_wall_ratio=wall_sink / wall,
    )
    return overhead


def run(full: bool = False, tiny: bool = False):
    """Returns the record list (also kept in RECORDS for write_json)."""
    if tiny:
        # CI smoke: 3 load levels + the asserted overhead budget in
        # seconds on a CPU runner
        run_saturation(12, 6, 0.25, fracs=(0.5, 1.0, 2.0), max_batch=8)
        run_obs_overhead(8, 6, 0.25, reps=500)
        return RECORDS
    run_saturation(32, 12, 0.2, fracs=(0.5, 0.9, 1.5))
    run_obs_overhead(16, 12, 0.2)
    if full:
        run_saturation(64, 16, 0.1, fracs=(0.5, 0.9, 1.5))
    return RECORDS


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: seconds on a CPU runner")
    ap.add_argument("--json", default="",
                    help="machine-readable output path (off by default so "
                         "ad-hoc/tiny runs don't overwrite the committed "
                         "BENCH_serve.json baseline; benchmarks/run.py "
                         "writes the canonical one)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, tiny=args.tiny)
    if args.json:
        write_json(args.json)
