"""Fault-tolerance subsystem: what robustness costs on the healthy path,
and what recovery costs when a bucket is actually poisoned.

  * admission - the pre-dispatch validation gate (core/validate.py): one
    jitted O(B*M*N) reduction per collated bucket. Measured as end-to-end
    batched solves with the gate on vs off — the healthy-path overhead
    budget is <5% instances/sec (asserted here, and diffable against the
    committed BENCH_batched.json throughput rows).
  * recovery - a 1-poisoned-in-256 bucket through OTService: wall time
    for detect + quarantine + solve-the-survivors, vs the same 256
    requests clean. The gate catches the NaN pre-dispatch; the dominant
    recovery cost is the survivors' one-off program compile (slicing the
    bucket to B-1 is a novel batch shape), which later poisoned buckets
    of the same size reuse.

    PYTHONPATH=src python -m benchmarks.bench_faults [--full|--tiny]

``--json OUT`` (and benchmarks/run.py) writes BENCH_faults.json:
instances/sec with/without the gate, overhead fraction, and recovery
latency for the poisoned bucket.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core.api import OT, DispatchPolicy, solve
from repro.core.validate import admission_codes
from .bench_batched import _skewed_batch
from .common import emit

RECORDS: list = []

#: healthy-path budget: the admission gate may cost at most this fraction
#: of instances/sec (asserted per record; run.py --diff also compares
#: against the committed baseline rows)
OVERHEAD_BUDGET = 0.05


def record(name, seconds, derived="", **extra):
    emit(name, seconds, derived)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived, **extra})


def write_json(path="BENCH_faults.json"):
    payload = {
        "schema": 1,
        "bench": "faults",
        "backend": jax.default_backend(),
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)
    return path


def _once(fn):
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def _best(fn, repeats=3):
    _once(fn)  # warm / compile
    return min(_once(fn) for _ in range(repeats))


def run_admission_overhead(b, n, eps, k=4, repeats=3):
    """Healthy-path cost of the gate: one O(B*M*N) jitted scan (plus its
    O(B) int32 host fetch) in front of a solve that runs many phases over
    the same operands.

    The asserted budget uses the deterministic ratio ``gate time /
    ungated solve time`` — the end-to-end on-vs-off difference is also
    recorded, but on a shared CPU runner its run-to-run noise exceeds the
    ~1-2ms gate itself, so it is context, not the gate."""
    c, nu, mu, sizes = _skewed_batch(b, n, seed=5 * n + b, n_slow=2)
    ins = {"c": c, "nu": nu, "mu": mu}
    off = DispatchPolicy(mode="compact", chunk=k, validate=False)
    on = DispatchPolicy(mode="compact", chunk=k, validate=True)

    t_off = _best(lambda: solve(OT, ins, eps, off, sizes=sizes,
                                want=("cost",)).cost(), repeats)
    t_on = _best(lambda: solve(OT, ins, eps, on, sizes=sizes,
                               want=("cost",)).cost(), repeats)
    t_gate = _best(lambda: admission_codes(ins, sizes=sizes), repeats)
    overhead = t_gate / t_off
    assert overhead < OVERHEAD_BUDGET, (
        f"admission gate costs {overhead:.1%} of the healthy-path solve "
        f"(budget {OVERHEAD_BUDGET:.0%}) at B={b} n={n}")
    record(
        f"faults/admission_overhead/B={b}/n={n}/eps={eps}", t_on / b,
        f"inst_per_s={b / t_on:.1f};ungated_inst_per_s={b / t_off:.1f};"
        f"gate_ms={t_gate * 1e3:.2f};overhead={overhead:.2%};"
        f"budget={OVERHEAD_BUDGET:.0%}",
        instances_per_s=b / t_on,
        ungated_instances_per_s=b / t_off,
        gate_s=t_gate,
        overhead_fraction=overhead,
    )
    return overhead


def run_poisoned_recovery(b, n, eps, n_poison=1):
    """1-poisoned-in-``b`` bucket through OTService: detect + quarantine
    + solve the survivors, vs the same bucket clean. Reported as recovery
    latency (absolute) and the poisoned/clean wall-time ratio."""
    from repro.core.validate import RequestRejected
    from repro.serve.engine import OTService

    rng = np.random.default_rng(n + b)
    reqs = [(np.float32(rng.standard_normal((n, 2))),
             np.float32(rng.standard_normal((n, 2)))) for _ in range(b)]

    def run_service(poison: bool):
        svc = OTService(eps=eps)
        for i, (x, y) in enumerate(reqs):
            if poison and i < n_poison:
                x = x.copy()
                x[0, 0] = np.nan
            svc.submit(x, y)
        t0 = time.perf_counter()
        res = svc.run_batch()
        return time.perf_counter() - t0, res

    run_service(False)                        # warm the bucket's programs
    t_clean, _ = run_service(False)
    t_poisoned, res = run_service(True)
    rejected = sum(isinstance(r, RequestRejected) for r in res)
    assert rejected == n_poison, (rejected, n_poison)
    survivors = b - n_poison
    record(
        f"faults/poisoned_recovery/B={b}/n={n}/poisoned={n_poison}",
        t_poisoned / survivors,
        f"recovery_s={t_poisoned:.3f};clean_s={t_clean:.3f};"
        f"ratio={t_poisoned / t_clean:.2f}x;quarantined={rejected}",
        instances_per_s=survivors / t_poisoned,
        clean_instances_per_s=b / t_clean,
        recovery_ratio=t_poisoned / t_clean,
        quarantined=rejected,
    )


def run(full: bool = False, tiny: bool = False):
    """Returns the record list (also kept in RECORDS for write_json)."""
    if tiny:
        # CI smoke: gate + quarantine end to end in seconds on a CPU
        # runner, overhead budget asserted (the solve must be big enough
        # to amortize the gate's ~1ms, hence n=48/eps=0.05 not 32/0.1)
        run_admission_overhead(16, 48, 0.05, k=2, repeats=2)
        run_poisoned_recovery(16, 16, 0.2)
        return RECORDS
    run_admission_overhead(32, 64, 0.1)
    run_admission_overhead(32, 128, 0.1)
    run_poisoned_recovery(256, 16, 0.2)
    if full:
        run_admission_overhead(64, 128, 0.05)
        run_poisoned_recovery(256, 32, 0.2)
    return RECORDS


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: seconds on a CPU runner")
    ap.add_argument("--json", default="",
                    help="machine-readable output path (off by default so "
                         "ad-hoc/tiny runs don't overwrite the committed "
                         "BENCH_faults.json baseline; benchmarks/run.py "
                         "writes the canonical one)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, tiny=args.tiny)
    if args.json:
        write_json(args.json)
