"""MoE routing: throughput + balance of topk / sinkhorn / pushrelabel
routers on realistic (skewed) router logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import route_topk, route_sinkhorn, route_pushrelabel
from .common import emit, time_call


def run(full: bool = False):
    t_tokens = 8192 if full else 4096
    e, k = 64, 6
    rng = np.random.default_rng(0)
    # skew: a few "hot" experts, like real router logits mid-training
    bias = np.zeros(e)
    bias[:4] = 3.0
    logits = jnp.asarray(
        rng.standard_normal((t_tokens, e)).astype(np.float32) + bias
    )
    routers = {
        "topk": jax.jit(lambda l: route_topk(l, k)),
        "sinkhorn": jax.jit(lambda l: route_sinkhorn(l, k)),
        "pushrelabel": jax.jit(lambda l: route_pushrelabel(l, k)),
    }
    for name, fn in routers.items():
        t = time_call(fn, logits, repeats=3)
        sel, gates = fn(logits)
        counts = np.bincount(np.asarray(sel).ravel(), minlength=e)
        imbalance = counts.max() / counts.mean()
        emit(f"routing/{name}/T={t_tokens}/E={e}/k={k}", t,
             f"imbalance={imbalance:.3f};tokens_per_s={t_tokens / t:.0f}")
