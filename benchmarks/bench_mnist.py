"""Paper Figure 2: runtime on MNIST-style image inputs (L1 cost between
L1-normalized 28x28 images; max cost <= 2) across eps - push-relabel vs
Sinkhorn. The container is offline, so images are procedural MNIST
stand-ins with the same normalization and cost structure."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.pushrelabel import solve_assignment
from repro.core.sinkhorn import sinkhorn, reg_for_additive_eps
from repro.core.costs import build_cost_matrix
from .common import emit, time_call, mnist_like_images


def run(full: bool = False):
    n = 2048 if full else 384
    epss = [0.75, 0.5, 0.25, 0.1]
    a = mnist_like_images(n, seed=0)
    b = mnist_like_images(n, seed=1)
    c = build_cost_matrix(jnp.asarray(a), jnp.asarray(b), "l1")
    nu = jnp.full((n,), 1.0 / n)
    rows = []
    for eps in epss:
        t_pr = time_call(lambda eps=eps: solve_assignment(c, eps), repeats=3)
        r = solve_assignment(c, eps)
        emit(f"mnist/pushrelabel/n={n}/eps={eps}", t_pr,
             f"phases={int(r.phases)};cost={float(r.cost)/n:.4f}")
        reg = reg_for_additive_eps(eps, n)
        t_sk = time_call(
            lambda reg=reg, eps=eps: sinkhorn(c, nu, nu, reg=reg,
                                              tol=eps / 8.0,
                                              max_iters=2000),
            repeats=3,
        )
        rs = sinkhorn(c, nu, nu, reg=reg, tol=eps / 8.0, max_iters=2000)
        emit(f"mnist/sinkhorn/n={n}/eps={eps}", t_sk,
             f"iters={int(rs.iters)};cost={float(rs.cost):.4f}")
        rows.append((n, eps, t_pr, t_sk))
    return rows
