"""Solution-surface fetch economics: host bytes + wall time per artifact.

The typed result surface (core/solution.py) lets serving traffic declare
the artifacts it will read (``solve(..., want=...)``); this bench measures
what that declaration is worth on one dispatched OT bucket:

  * cost_only  - ``want=("cost",)``: O(B) scalars cross device->host.
  * sparse     - ``want=("cost", "plan_sparse")``: COO triplets, O(B*nnz)
    bytes (the paper's compact-plan claim, support ~O(m + n)).
  * dense      - ``want=("cost", "plan")``: the O(B * m * n) dense plans
    the legacy surface always shipped.

Each row reports ``fetch_bytes`` (audited by ``SolutionBatch.
fetched_bytes``) and instances/sec for solve + fetch, so
``benchmarks/run.py --diff`` gates refactors against the committed
BENCH_solution.json. The dense/sparse byte ratio is the headline.

    PYTHONPATH=src python -m benchmarks.bench_solution [--full|--tiny]
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core.api import OT, DispatchPolicy, solve
from .common import emit

RECORDS: list = []


def record(name, seconds, derived="", **extra):
    emit(name, seconds, derived)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived, **extra})


def write_json(path="BENCH_solution.json"):
    payload = {
        "schema": 1,
        "bench": "solution",
        "backend": jax.default_backend(),
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)
    return path


def _bucket(b, n, seed):
    rng = np.random.default_rng(seed)
    c = np.zeros((b, n, n), np.float32)
    nu = np.zeros((b, n), np.float32)
    mu = np.zeros((b, n), np.float32)
    for i in range(b):
        x = rng.uniform(size=(n, 2))
        y = rng.uniform(size=(n, 2))
        d = x[:, None, :] - y[None, :, :]
        c[i] = np.sqrt((d * d).sum(-1) + 1e-30)
        nu[i] = rng.dirichlet(np.ones(n)).astype(np.float32)
        mu[i] = rng.dirichlet(np.ones(n)).astype(np.float32)
    return {"c": c, "nu": nu, "mu": mu}


_WANTS = {
    "cost_only": ("cost",),
    "sparse": ("cost", "plan_sparse"),
    "dense": ("cost", "plan"),
}


def _fetch(batch, kind):
    if kind == "cost_only":
        return batch.cost()
    if kind == "sparse":
        batch.cost()
        return batch.plan_sparse()
    batch.cost()
    return batch.plan()


def run(full: bool = False, tiny: bool = False, eps: float = 0.1,
        repeats: int = 3):
    if tiny:
        grids = [(8, 32)]
    elif full:
        grids = [(32, 128), (64, 256)]
    else:
        grids = [(32, 128)]
    policy = DispatchPolicy(mode="compact")
    for b, n in grids:
        inputs = _bucket(b, n, seed=7)
        dense_bytes = b * n * n * 4
        baseline = None
        for kind, want in _WANTS.items():
            # warm the (shape, k, B) program family + extraction kernels
            _fetch(solve(OT, inputs, eps, policy, want=want), kind)
            ts, bytes_moved = [], 0
            for _ in range(repeats):
                t0 = time.perf_counter()
                batch = solve(OT, inputs, eps, policy, want=want)
                _fetch(batch, kind)
                ts.append(time.perf_counter() - t0)
                bytes_moved = batch.fetched_bytes
            sec = float(np.median(ts))
            name = f"solution_fetch_{kind}_B{b}_n{n}"
            if kind == "cost_only":
                baseline = bytes_moved
            derived = (f"fetch={bytes_moved}B dense={dense_bytes}B "
                       f"({bytes_moved / dense_bytes:.4f}x)")
            record(name, sec, derived,
                   instances_per_s=b / sec,
                   fetch_bytes=int(bytes_moved),
                   dense_plan_bytes=int(dense_bytes),
                   batch=b, n=n, eps=eps)
        # headline: what declaring want= saves vs always shipping plans
        record(f"solution_bytes_saved_B{b}_n{n}", 0.0,
               f"cost-only {baseline}B vs dense {dense_bytes}B "
               f"({dense_bytes / max(baseline, 1):.0f}x less host traffic)",
               fetch_bytes=int(baseline),
               dense_plan_bytes=int(dense_bytes))


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one small bucket, asserts the "
                         "cost-only fetch never ships dense plans")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, tiny=args.tiny)
    if args.tiny:
        by_name = {r["name"]: r for r in RECORDS}
        r = by_name["solution_fetch_cost_only_B8_n32"]
        assert r["fetch_bytes"] < r["dense_plan_bytes"] / 100, r
        print("# tiny smoke ok: cost-only fetch "
              f"{r['fetch_bytes']}B << dense {r['dense_plan_bytes']}B",
              flush=True)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
