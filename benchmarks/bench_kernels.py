"""Kernel-level perf family: fused vs unfused phase loop, per backend.

The fused Pallas phase kernel (``kernels/fused_phase``) runs slack +
propose/accept + push + relabel for k phases in ONE kernel with the
solver state resident in VMEM, where the stepped cores
(``core/pushrelabel`` / ``core/transport``) round-trip the state through
XLA/HBM between the ``slack_propose`` kernel and the push/relabel
updates. This bench times both on identical trajectories (the fused
kernel is bit-identical to the stepped core, asserted here per row) and
records us/phase + phases/sec per kernel per backend:

  * kernels/assignment_phase/{stepped,stepped_pallas_propose,fused}
  * kernels/ot_phase/{stepped,fused}
  * kernels/{slack_propose,cost_matrix,sinkhorn_row_update} micro rows
  * kernels/phase_bounds/* — the Section 3.2 theory check formerly in
    bench_phases.py: phase count t <= (1+2e)/e^2 and sum_i n_i <=
    n(1+2e)/e (eq. 4) across eps.

Honesty note on backends: off-TPU the Pallas kernels run in interpret
mode (``_resolve_interpret(None)``) — the kernel body is inlined as
plain XLA ops rather than lowered through Mosaic/Triton. Every record
carries ``mode=interpret|compiled`` so committed CPU numbers are never
mistaken for accelerator kernel numbers; the measured fused-vs-stepped
speedup on CPU comes from the fused single-program dense formulation
(no per-round scatter dispatches), not from VMEM residency.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--full|--tiny]

``benchmarks/run.py`` writes the canonical BENCH_kernels.json and
``run.py --diff`` gates the phases/sec (``instances_per_s``) of every
row against it.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import build_cost_matrix
from repro.core.pushrelabel import (
    _max_phases,
    assignment_prologue,
    init_assignment_state,
    round_costs,
    run_assignment_phases,
    solve_assignment_int,
)
from repro.core.transport import (
    init_ot_state,
    ot_phase_cap,
    ot_prologue,
    ot_termination_threshold,
    run_ot_phases,
)
from repro.kernels import ops
from repro.kernels.slack_propose import _resolve_interpret, slack_propose
from .common import emit, time_call, uniform_square_points

RECORDS: list = []


def _mode() -> str:
    return "interpret" if _resolve_interpret(None) else "compiled"


def record(name, seconds, derived="", **extra):
    emit(name, seconds, derived)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived, **extra})


def write_json(path="BENCH_kernels.json"):
    payload = {
        "schema": 1,
        "bench": "kernels",
        "backend": jax.default_backend(),
        "pallas_mode": _mode(),
        "blocks": {k: list(ops.kernel_blocks(k))
                   for k in ("slack_propose", "cost_matrix",
                             "sinkhorn_row_update", "fused_phase")},
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)
    return path


def _assert_state_equal(a, b, tag):
    for f, x, y in zip(a._fields, a, b):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            raise AssertionError(f"{tag}: fused/stepped diverge on {f}")


def run_assignment_phase(n: int, eps: float, k: int, seed: int = 0):
    """Fused vs stepped assignment k-phase chunk on one trajectory."""
    rng = np.random.default_rng(seed)
    c = rng.uniform(size=(n, n)).astype(np.float32)
    _, c_int, _, _, _ = assignment_prologue(jnp.asarray(c), eps, None, None)
    thr = jnp.int32(int(eps * n))
    cap = jnp.int32(_max_phases(eps, n))
    pf = ops.make_pallas_propose_fn()
    variants = {
        "stepped": lambda: run_assignment_phases(
            c_int, init_assignment_state(n, n), thr, cap, k),
        "stepped_pallas_propose": lambda: run_assignment_phases(
            c_int, init_assignment_state(n, n), thr, cap, k, propose_fn=pf),
        "fused": lambda: ops.fused_run_assignment_phases(
            c_int, init_assignment_state(n, n), thr, cap, k),
    }
    ref = variants["stepped"]()
    phases = max(int(ref.phases), 1)
    for name, fn in variants.items():
        _assert_state_equal(ref, fn(), f"assignment n={n} {name}")
        t = time_call(fn, repeats=7)
        record(f"kernels/assignment_phase/{name}/n={n}/eps={eps}/k={k}",
               t / phases,
               f"phases={phases};rounds={int(ref.rounds)};"
               f"phases_per_s={phases / t:.1f};mode={_mode()}",
               instances_per_s=phases / t, mode=_mode())


def run_ot_phase(n: int, eps: float, k: int, seed: int = 0):
    """Fused vs stepped OT k-phase chunk on one trajectory."""
    rng = np.random.default_rng(seed)
    c = rng.uniform(size=(n, n)).astype(np.float32)
    nu = rng.dirichlet(np.ones(n)).astype(np.float32)
    mu = rng.dirichlet(np.ones(n)).astype(np.float32)
    theta = np.float32(4.0 * n / eps)
    c_int, s_int, d_int, _ = ot_prologue(
        jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu), theta, eps)
    thr = jnp.int32(ot_termination_threshold(nu, theta, eps))
    cap = jnp.int32(ot_phase_cap(eps))
    mr = int(2 * n + 2)
    variants = {
        "stepped": lambda: run_ot_phases(
            c_int, init_ot_state(s_int, d_int), thr, cap, k, mr),
        "fused": lambda: ops.fused_run_ot_phases(
            c_int, init_ot_state(s_int, d_int), thr, cap, k, mr),
    }
    ref = variants["stepped"]()
    phases = max(int(ref.phases), 1)
    for name, fn in variants.items():
        _assert_state_equal(ref, fn(), f"ot n={n} {name}")
        t = time_call(fn, repeats=7)
        record(f"kernels/ot_phase/{name}/n={n}/eps={eps}/k={k}",
               t / phases,
               f"phases={phases};rounds={int(ref.rounds)};"
               f"phases_per_s={phases / t:.1f};mode={_mode()}",
               instances_per_s=phases / t, mode=_mode())


def run_micro(n: int, seed: int = 0):
    """Single-kernel us/call rows at the backend-table block sizes."""
    from repro.kernels.cost_matrix import cost_matrix
    from repro.kernels.sinkhorn_step import sinkhorn_row_update

    rng = np.random.default_rng(seed)
    c_int = jnp.asarray(rng.integers(0, 1 << 20, size=(n, n)), jnp.int32)
    y_b = jnp.ones((n,), jnp.int32)
    y_a = jnp.zeros((n,), jnp.int32)
    avail = jnp.ones((n,), bool)
    sp = jax.jit(lambda: slack_propose(c_int, y_b, y_a, avail,
                                       jnp.int32(0)))
    t = time_call(sp, repeats=7)
    record(f"kernels/slack_propose/n={n}", t,
           f"calls_per_s={1.0 / t:.1f};mode={_mode()}",
           instances_per_s=1.0 / t, mode=_mode())

    x = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    y = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    cm = jax.jit(lambda: cost_matrix(x, y, "euclidean"))
    t = time_call(cm, repeats=7)
    record(f"kernels/cost_matrix/n={n}", t,
           f"calls_per_s={1.0 / t:.1f};mode={_mode()}",
           instances_per_s=1.0 / t, mode=_mode())

    cf = jnp.asarray(rng.uniform(size=(n, n)), jnp.float32)
    g = jnp.zeros((n,), jnp.float32)
    lognu = jnp.full((n,), -np.log(n), jnp.float32)
    sk = jax.jit(lambda: sinkhorn_row_update(cf, g, lognu, 0.05))
    t = time_call(sk, repeats=7)
    record(f"kernels/sinkhorn_row_update/n={n}", t,
           f"calls_per_s={1.0 / t:.1f};mode={_mode()}",
           instances_per_s=1.0 / t, mode=_mode())


def run_phase_bounds(n: int):
    """Section 3.2 theory check (formerly bench_phases.py): phase count
    t <= (1+2e)/e^2 and sum_i n_i <= n(1+2e)/e (eq. 4) across eps.
    Ungated (no instances_per_s): these rows verify bounds, not speed."""
    x, y = uniform_square_points(n, seed=3)
    c = np.asarray(build_cost_matrix(jnp.asarray(x), jnp.asarray(y),
                                     "euclidean"))
    scale = c.max()
    for eps in [0.2, 0.1, 0.05, 0.02, 0.01]:
        c_int = round_costs(jnp.asarray(c / scale), eps)
        t = time_call(lambda eps=eps, c_int=c_int:
                      solve_assignment_int(c_int, eps), repeats=2)
        st = solve_assignment_int(c_int, eps)
        bound_t = (1 + 2 * eps) / eps ** 2
        bound_ni = n * (1 + 2 * eps) / eps
        record(
            f"kernels/phase_bounds/n={n}/eps={eps}", t,
            f"phases={int(st.phases)};bound={bound_t:.0f};"
            f"sum_ni={int(st.sum_ni)};ni_bound={bound_ni:.0f};"
            f"rounds={int(st.rounds)}",
        )


def run(full: bool = False, tiny: bool = False):
    """Returns the record list (also kept in RECORDS for write_json)."""
    if tiny:
        # CI smoke: fused-vs-stepped parity asserts + timing in seconds
        # on a CPU runner.
        run_assignment_phase(48, 0.05, 4)
        run_ot_phase(32, 0.1, 4)
        return RECORDS
    run_assignment_phase(256, 0.1, 8)
    run_assignment_phase(256, 0.01, 16)
    run_assignment_phase(512, 0.01, 16)
    run_ot_phase(128, 0.05, 8)
    run_ot_phase(256, 0.05, 8)
    run_micro(256)
    run_phase_bounds(1024 if full else 512)
    if full:
        run_assignment_phase(1024, 0.01, 16)
        run_ot_phase(512, 0.05, 8)
        run_micro(1024)
    return RECORDS


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: parity asserts + seconds on CPU")
    ap.add_argument("--json", default="",
                    help="machine-readable output path (off by default so "
                         "ad-hoc/tiny runs don't overwrite the committed "
                         "BENCH_kernels.json baseline; benchmarks/run.py "
                         "writes the canonical one)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, tiny=args.tiny)
    if args.json:
        write_json(args.json)
