"""Theory check (Section 3.2): phase count t <= (1+2e)/e^2 and
sum_i n_i <= n(1+2e)/e (eq. 4), measured across eps."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.pushrelabel import solve_assignment_int, round_costs
from repro.core.costs import build_cost_matrix
from .common import emit, time_call, uniform_square_points


def run(full: bool = False):
    n = 1024 if full else 512
    x, y = uniform_square_points(n, seed=3)
    c = np.asarray(build_cost_matrix(jnp.asarray(x), jnp.asarray(y),
                                     "euclidean"))
    scale = c.max()
    for eps in [0.2, 0.1, 0.05, 0.02, 0.01]:
        c_int = round_costs(jnp.asarray(c / scale), eps)
        t = time_call(lambda eps=eps, c_int=c_int: solve_assignment_int(c_int, eps), repeats=2)
        st = solve_assignment_int(c_int, eps)
        bound_t = (1 + 2 * eps) / eps ** 2
        bound_ni = n * (1 + 2 * eps) / eps
        emit(
            f"phases/n={n}/eps={eps}", t,
            f"phases={int(st.phases)};bound={bound_t:.0f};"
            f"sum_ni={int(st.sum_ni)};ni_bound={bound_ni:.0f};"
            f"rounds={int(st.rounds)}",
        )
