"""Paper Figure 1: runtime on synthetic inputs (n uniform 2-D points per
side, Euclidean costs) - push-relabel vs Sinkhorn at matched accuracy.

CPU-scaled defaults (n up to 1024); pass full=True for the paper's grid
(n up to 10000, eps down to 0.005)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.pushrelabel import solve_assignment
from repro.core.sinkhorn import sinkhorn, reg_for_additive_eps
from repro.core.costs import build_cost_matrix
from repro.core.exact import exact_assignment_cost
from .common import emit, time_call, uniform_square_points


def run(full: bool = False):
    ns = [500, 1000, 2000, 4000, 8000, 10000] if full else [256, 512, 1024]
    epss = [0.1, 0.01, 0.005] if full else [0.1, 0.02]
    rows = []
    for n in ns:
        x, y = uniform_square_points(n, seed=n)
        c = build_cost_matrix(jnp.asarray(x), jnp.asarray(y), "euclidean")
        c_np = np.asarray(c)
        opt = exact_assignment_cost(c_np) if n <= 2048 else None
        scale = float(c_np.max())
        for eps in epss:
            t_pr = time_call(lambda eps=eps: solve_assignment(c, eps), repeats=3)
            r = solve_assignment(c, eps)
            gap = ((float(r.cost) - opt) / (n * scale)) if opt else float("nan")
            emit(f"synthetic/pushrelabel/n={n}/eps={eps}", t_pr,
                 f"phases={int(r.phases)};gap_per_n={gap:.5f}")
            reg = reg_for_additive_eps(eps, n)
            nu = jnp.full((n,), 1.0 / n)
            t_sk = time_call(
                lambda reg=reg, eps=eps: sinkhorn(c, nu, nu, reg=reg,
                                                  tol=eps / 8.0,
                                                  max_iters=2000),
                repeats=3,
            )
            rs = sinkhorn(c, nu, nu, reg=reg, tol=eps / 8.0, max_iters=2000)
            gap_s = ((float(rs.cost) * n - opt) / (n * scale)) if opt \
                else float("nan")
            emit(f"synthetic/sinkhorn/n={n}/eps={eps}", t_sk,
                 f"iters={int(rs.iters)};gap_per_n={gap_s:.5f}")
            rows.append((n, eps, t_pr, t_sk))
    return rows
