"""Shared benchmark helpers: timing, CSV emission, synthetic inputs."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_call(fn: Callable, *args, warmup: int = 1, repeats: int = 3,
              **kw) -> float:
    """Median wall seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def uniform_square_points(n: int, seed: int):
    rng = np.random.default_rng(seed)
    return (rng.uniform(size=(n, 2)).astype(np.float32),
            rng.uniform(size=(n, 2)).astype(np.float32))


def mnist_like_images(n: int, seed: int):
    """Procedural stand-in for MNIST (offline container): sparse blobs on a
    28x28 grid, L1-normalized like the paper's preprocessing."""
    rng = np.random.default_rng(seed)
    imgs = np.zeros((n, 28, 28), np.float32)
    for i in range(n):
        k = rng.integers(2, 5)
        for _ in range(k):
            cx, cy = rng.uniform(4, 24, size=2)
            sx, sy = rng.uniform(1.0, 3.0, size=2)
            yy, xx = np.mgrid[0:28, 0:28]
            imgs[i] += np.exp(-(((xx - cx) / sx) ** 2
                                + ((yy - cy) / sy) ** 2))
    flat = imgs.reshape(n, 784)
    flat /= np.maximum(flat.sum(1, keepdims=True), 1e-9)
    return flat
