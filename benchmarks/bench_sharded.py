"""Mesh-distributed batch dispatch: instances/sec vs device count.

The distributed compacting driver (core/distributed.py) shards the batch
axis of a convergence-skewed OT/assignment bucket across a 1-D device
mesh; this bench measures throughput against the single-device compacting
dispatch (the PR-2 baseline) at matched chunk size, asserting bit-identical
results along the way. Rows:

  * ot_skewed / assignment_skewed - the headline: one skewed bucket solved
    at devices = 1 (plain compacting driver), 2, 4, 8 (distributed).
    Derived fields carry instances/sec, speedup vs the 1-device dispatch,
    the occupancy (re-bucketing) curve, and the per-device slot-phase
    accounting.
  * ot_skewed with a larger chunk k - fewer converged-mask syncs per
    solve; the distributed path benefits disproportionately (each sync is
    a cross-mesh gather), at the cost of coarser retirement.

Always runs in a SUBPROCESS with ``--xla_force_host_platform_device_count
=8`` (the same forced-CPU harness as tests/test_sharded_ot.py), so it
works from any parent process that already initialized jax on 1 device.

CPU-noise caveats (same as BENCH_batched.json): the forced 8-device mesh
multiplexes the host's physical cores (2 in CI), so absolute numbers are
noisy run to run and device-count scaling saturates at the physical core
count; the speedup floor asserted in CI (tiny mode) is only the
equality/plumbing check, not a perf gate. The committed BENCH_sharded.json
records one full run on the 2-core container for future PRs to diff
against.

    PYTHONPATH=src python -m benchmarks.bench_sharded [--full|--tiny]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

RECORDS: list = []
_META: dict = {}

FORCED_DEVICES = 8


# --------------------------------------------------------------------------
# Outer wrapper: re-exec under a forced multi-device CPU
# --------------------------------------------------------------------------

def run(full: bool = False, tiny: bool = False):
    """Spawn the inner benchmark under XLA_FLAGS forcing 8 host devices,
    stream its CSV output, and collect its records into RECORDS."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        tmp = f.name
    args = [sys.executable, "-m", "benchmarks.bench_sharded", "--inner",
            "--json", tmp]
    if full:
        args.append("--full")
    if tiny:
        args.append("--tiny")
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{FORCED_DEVICES}").strip()
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(args, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"inner bench_sharded failed with {proc.returncode}")
    with open(tmp) as fh:
        payload = json.load(fh)
    os.unlink(tmp)
    RECORDS.extend(payload["records"])
    _META.update(payload.get("meta", {}))
    return RECORDS


def write_json(path="BENCH_sharded.json"):
    payload = {
        "schema": 1,
        "bench": "sharded",
        "meta": _META,
        "caveats": (
            "forced multi-device CPU: 8 XLA host devices multiplexed onto "
            f"{os.cpu_count()} physical cores, so absolute numbers are "
            "noisy run to run and scaling saturates at the core count; "
            "results are asserted bit-identical to the single-device "
            "compacting dispatch inside the bench"
        ),
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)
    return path


# --------------------------------------------------------------------------
# Inner benchmark (runs with 8 forced devices)
# --------------------------------------------------------------------------

def _skewed_batch(b, nb, seed, n_slow):
    """Convergence-skewed OT batch (mixed sizes, adversarial slow tail),
    shuffled so slow lanes spread across mesh shards - as real bucketed
    traffic would arrive."""
    import numpy as np

    rng = np.random.default_rng(seed)
    c = np.zeros((b, nb, nb), np.float32)
    nu = np.zeros((b, nb), np.float32)
    mu = np.zeros((b, nb), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i in range(b):
        m = int(rng.integers(nb // 2 + 1, nb + 1))
        x = rng.uniform(size=(m, 2))
        nui = rng.dirichlet(np.ones(m)).astype(np.float32)
        if i < n_slow:
            y = rng.uniform(size=(m, 2))
            mui = rng.dirichlet(np.ones(m)).astype(np.float32)
        else:
            perm = rng.permutation(m)
            y = x[perm] + rng.normal(0.0, 0.003, size=(m, 2))
            mui = nui[perm]
        d = x[:, None, :] - y[None, :, :]
        c[i, :m, :m] = np.sqrt((d * d).sum(-1) + 1e-30)
        nu[i, :m] = nui
        mu[i, :m] = mui
        sizes[i] = (m, m)
    perm = rng.permutation(b)
    return c[perm], nu[perm], mu[perm], sizes[perm]


def _inner(full: bool, tiny: bool, json_path: str):
    import time

    import jax
    import numpy as np

    from repro.core.compaction import (
        solve_assignment_batched_compacting,
        solve_ot_batched_compacting,
    )
    from repro.core.distributed import (
        solve_assignment_distributed,
        solve_ot_distributed,
    )
    from repro.launch.mesh import make_batch_mesh

    from .common import emit

    records = []
    n_dev = len(jax.devices())

    def record(name, seconds, derived="", **extra):
        emit(name, seconds, derived)
        records.append({"name": name, "us_per_call": seconds * 1e6,
                        "derived": derived, **extra})

    def best(fn, repeats):
        """(min seconds, last (result, stats)) — reuses the final timed
        run's output instead of paying an extra solve for it."""
        out = fn()  # warm / compile
        jax.block_until_ready(out[0].cost)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out[0].cost)
            ts.append(time.perf_counter() - t0)
        return min(ts), out

    def row(kind, b, n, eps, k, n_slow, devices_list, repeats=2):
        c, nu, mu, sizes = _skewed_batch(b, n, seed=b + n, n_slow=n_slow)
        if kind == "ot":
            base_fn = lambda: solve_ot_batched_compacting(
                c, nu, mu, eps, sizes=sizes, k=k)
        else:
            base_fn = lambda: solve_assignment_batched_compacting(
                c, eps, sizes=sizes, k=k)
        t1, (r_base, _) = best(base_fn, repeats)
        base_ips = b / t1
        record(
            f"sharded/{kind}_skewed/B={b}/n={n}/eps={eps}/k={k}/devices=1",
            t1 / b, f"inst_per_s={base_ips:.1f};single_device_compacting",
            instances_per_s=base_ips, devices=1, speedup_vs_1dev=1.0,
            results_identical=True,
        )
        for d in devices_list:
            mesh = make_batch_mesh(d)
            if kind == "ot":
                fn = lambda: solve_ot_distributed(
                    c, nu, mu, eps, mesh, sizes=sizes, k=k)
            else:
                fn = lambda: solve_assignment_distributed(
                    c, eps, mesh, sizes=sizes, k=k)
            t, (r, st) = best(fn, repeats)
            if kind == "ot":
                ident = (np.array_equal(np.asarray(r_base.plan),
                                        np.asarray(r.plan))
                         and np.array_equal(np.asarray(r_base.cost),
                                            np.asarray(r.cost))
                         and np.array_equal(np.asarray(r_base.phases),
                                            np.asarray(r.phases)))
            else:
                ident = (np.array_equal(np.asarray(r_base.matching),
                                        np.asarray(r.matching))
                         and np.array_equal(np.asarray(r_base.cost),
                                            np.asarray(r.cost)))
            assert ident, ("distributed dispatch must reproduce the "
                           "single-device compacting results exactly")
            ips = b / t
            record(
                f"sharded/{kind}_skewed/B={b}/n={n}/eps={eps}/k={k}"
                f"/devices={d}",
                t / b,
                f"inst_per_s={ips:.1f};speedup_vs_1dev={t1 / t:.2f}x;"
                f"collapsed_at={st.collapsed_at}",
                instances_per_s=ips, devices=d,
                speedup_vs_1dev=t1 / t, results_identical=True,
                occupancy=[list(o) for o in st.occupancy],
                devices_per_dispatch=list(st.devices_per_dispatch),
                slot_phases=st.slot_phases,
                phases_needed=st.phases_needed,
                collapsed_at=st.collapsed_at,
            )
        return records[-1]

    if tiny:
        # CI smoke: plumbing + bit-identity across the mesh in seconds
        row("ot", 8, 32, 0.1, 2, 2, [n_dev], repeats=1)
        row("assignment", 8, 32, 0.1, 2, 2, [n_dev], repeats=1)
    else:
        # headline: device-count scaling on the skewed OT bucket
        row("ot", 32, 128, 0.1, 8, 8, [2, 4, 8])
        # larger chunk: fewer cross-mesh syncs, better parallel grain
        row("ot", 32, 128, 0.1, 16, 8, [8])
        # tighter accuracy: k=8 is sync-bound on 2 cores (honest row),
        # k=16 recovers the scaling
        row("ot", 32, 128, 0.05, 8, 8, [8])
        row("ot", 32, 128, 0.05, 16, 8, [8])
        # assignment phases are lighter than OT (no flow matrices), so
        # the mesh needs bigger instances to amortize dispatch overhead
        row("assignment", 32, 192, 0.05, 16, 8, [8])
        if full:
            row("ot", 64, 128, 0.1, 16, 16, [2, 4, 8])
            row("ot", 64, 96, 0.05, 8, 16, [8])

    meta = {
        "backend": jax.default_backend(),
        "forced_host_devices": n_dev,
        "physical_cores": os.cpu_count(),
        "mesh": {"axes": ["data"], "shape": [n_dev],
                 "builder": "launch.mesh.make_batch_mesh"},
    }
    with open(json_path, "w") as f:
        json.dump({"records": records, "meta": meta}, f, indent=2)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: seconds on a CPU runner")
    ap.add_argument("--inner", action="store_true",
                    help="internal: already running under forced devices")
    ap.add_argument("--json", default="",
                    help="records output path (inner mode: raw records; "
                         "outer mode: BENCH_sharded.json payload)")
    args = ap.parse_args()
    if args.inner:
        _inner(args.full, args.tiny, args.json)
        return
    print("name,us_per_call,derived")
    run(full=args.full, tiny=args.tiny)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
