"""Batched solver subsystem: throughput of B instances per dispatch.

Three comparisons, honestly separated:

  * ragged  - the serving scenario the subsystem exists for: B requests with
    long-tail (m, n) shapes. The pre-PR path solves each at its native shape,
    so every novel shape pays an XLA compile (~0.5 s for the solver loop);
    the bucketed batched path pads to one bucket shape compiled once ever.
    Loop timing INCLUDES its per-novel-shape compiles (that is its steady
    state - fresh shapes keep arriving); batch timing is reported both warm
    (bucket program already cached, the amortized steady state) and cold.
  * fixed   - B identical-shape instances with a hot jit cache: isolates the
    lockstep cost of vmapping the while_loop solver. On CPU this is ~parity
    at best (finished instances ride along until the slowest converges); on
    an accelerator the batch fills idle lanes instead.
  * sinkhorn - batched log-domain Sinkhorn reference at matched accuracy.

    PYTHONPATH=src python -m benchmarks.bench_batched [--full]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched import solve_assignment_batched, solve_ot_batched
from repro.core.pushrelabel import solve_assignment
from repro.core.sinkhorn import reg_for_additive_eps, sinkhorn
from repro.core.transport import solve_ot
from .common import emit, time_call, uniform_square_points


def _instance(m, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(m, 2)).astype(np.float32)
    y = rng.uniform(size=(n, 2)).astype(np.float32)
    d = x[:, None, :] - y[None, :, :]
    c = np.sqrt((d * d).sum(-1) + 1e-30)
    nu = rng.dirichlet(np.ones(m)).astype(np.float32)
    mu = rng.dirichlet(np.ones(n)).astype(np.float32)
    return c, nu, mu


def _fixed_batch(b, n, seed):
    c = np.zeros((b, n, n), np.float32)
    nu = np.zeros((b, n), np.float32)
    mu = np.zeros((b, n), np.float32)
    for i in range(b):
        c[i], nu[i], mu[i] = _instance(n, n, seed + 17 * i)
    return jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu)


def _once(fn):
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def run_ragged(b, n, eps):
    """Long-tail shapes in (n/2, n]: native-shape loop (per-shape compile)
    vs one padded bucket dispatch."""
    rng = np.random.default_rng(n * b)
    insts = []
    while len(insts) < b:
        m1 = int(rng.integers(n // 2 + 1, n + 1))
        n1 = int(rng.integers(n // 2 + 1, n + 1))
        insts.append(_instance(m1, n1, seed=len(insts)))
    c = np.zeros((b, n, n), np.float32)
    nu = np.zeros((b, n), np.float32)
    mu = np.zeros((b, n), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i, (ci, nui, mui) in enumerate(insts):
        mi, ni = ci.shape
        c[i, :mi, :ni] = ci
        nu[i, :mi] = nui
        mu[i, :ni] = mui
        sizes[i] = (mi, ni)

    # batched: cold (includes the one-off bucket compile), then warm
    t_cold = _once(lambda: solve_ot_batched(c, nu, mu, eps, sizes=sizes).cost)
    t_warm = _once(lambda: solve_ot_batched(c, nu, mu, eps, sizes=sizes).cost)

    # looped at native shapes: every novel (m, n) pays its compile, exactly
    # like the pre-batching service did on long-tail traffic
    t_loop = _once(lambda: [
        solve_ot(jnp.asarray(ci), jnp.asarray(nui), jnp.asarray(mui), eps).cost
        for ci, nui, mui in insts
    ])

    emit(f"batched/ot_ragged/B={b}/bucket={n}", t_warm / b,
         f"inst_per_s={b / t_warm:.1f};loop_native_inst_per_s={b / t_loop:.2f};"
         f"speedup_vs_native_loop={t_loop / t_warm:.1f}x;"
         f"cold_batch_s={t_cold:.2f}")
    return t_loop / t_warm


def run_fixed(b, n, eps):
    c, nu, mu = _fixed_batch(b, n, seed=n + b)

    t_batch = time_call(lambda: solve_assignment_batched(c, eps), repeats=2)
    t_loop = time_call(
        lambda: [solve_assignment(c[i], eps).cost for i in range(b)],
        repeats=2,
    )
    emit(f"batched/assignment_fixed/B={b}/n={n}", t_batch / b,
         f"inst_per_s={b / t_batch:.1f};loop_inst_per_s={b / t_loop:.1f};"
         f"lockstep_ratio={t_loop / t_batch:.2f}x")

    t_batch = time_call(lambda: solve_ot_batched(c, nu, mu, eps), repeats=2)
    t_loop = time_call(
        lambda: [solve_ot(c[i], nu[i], mu[i], eps).cost for i in range(b)],
        repeats=2,
    )
    emit(f"batched/ot_fixed/B={b}/n={n}", t_batch / b,
         f"inst_per_s={b / t_batch:.1f};loop_inst_per_s={b / t_loop:.1f};"
         f"lockstep_ratio={t_loop / t_batch:.2f}x")

    reg = reg_for_additive_eps(eps, n)
    sk_batched = jax.jit(jax.vmap(
        lambda ci, nui, mui: sinkhorn(ci, nui, mui, reg=reg,
                                      tol=eps / 8.0, max_iters=2000).cost
    ))
    t_sk = time_call(lambda: sk_batched(c, nu, mu), repeats=2)
    emit(f"batched/sinkhorn/B={b}/n={n}", t_sk / b,
         f"inst_per_s={b / t_sk:.1f}")


def run(full: bool = False):
    eps = 0.1
    run_ragged(8, 128, eps)
    run_ragged(32, 256, eps)
    for b, n in ([(8, 128), (32, 256)] if not full
                 else [(8, 128), (32, 256), (64, 256), (32, 512)]):
        run_fixed(b, n, eps)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full)
