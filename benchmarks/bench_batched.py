"""Batched solver subsystem: throughput of B instances per dispatch.

Comparisons, honestly separated:

  * skewed  - the headline for PR 2: a convergence-skewed batch (mixed
    sizes, an adversarial slow tail whose duals must climb ~1/eps steps
    while the bulk converges in a phase or two). The lockstep vmapped
    while_loop runs every instance until the slowest converges; the
    compacting driver (core/compaction.py) retires converged instances
    between k-phase dispatches. Same results, fewer executed phase-slots.
  * mixed_eps - per-instance eps in ONE compacted dispatch (eps is data to
    the chunked solver) vs the lockstep path's only option: one dispatch
    per eps value (eps is a static jit argument there, so every new eps
    also recompiles).
  * ragged  - the PR-1 serving scenario: B requests with long-tail (m, n)
    shapes; bucketed batch dispatch vs per-novel-shape compiles.
  * fixed   - B identical-shape instances with a hot jit cache: isolates
    lockstep cost of vmapping the while_loop solver.
  * sinkhorn - batched log-domain Sinkhorn reference at matched accuracy.

    PYTHONPATH=src python -m benchmarks.bench_batched [--full|--tiny]

``--json OUT`` (and benchmarks/run.py) also writes the records to a
machine-readable BENCH_batched.json: instances/sec, phases executed vs
phases needed (lockstep-waste metric), and the compaction occupancy curve.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched import solve_assignment_batched, solve_ot_batched
from repro.core.compaction import (
    solve_assignment_batched_compacting,
    solve_ot_batched_compacting,
)
from repro.core.pushrelabel import solve_assignment
from repro.core.sinkhorn import reg_for_additive_eps, sinkhorn
from repro.core.transport import solve_ot
from .common import emit, time_call

RECORDS: list = []


def record(name, seconds, derived="", **extra):
    emit(name, seconds, derived)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived, **extra})


def write_json(path="BENCH_batched.json"):
    payload = {
        "schema": 1,
        "bench": "batched",
        "backend": jax.default_backend(),
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)
    return path


def _instance(m, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(m, 2)).astype(np.float32)
    y = rng.uniform(size=(n, 2)).astype(np.float32)
    d = x[:, None, :] - y[None, :, :]
    c = np.sqrt((d * d).sum(-1) + 1e-30)
    nu = rng.dirichlet(np.ones(m)).astype(np.float32)
    mu = rng.dirichlet(np.ones(n)).astype(np.float32)
    return c, nu, mu


def _fixed_batch(b, n, seed):
    c = np.zeros((b, n, n), np.float32)
    nu = np.zeros((b, n), np.float32)
    mu = np.zeros((b, n), np.float32)
    for i in range(b):
        c[i], nu[i], mu[i] = _instance(n, n, seed + 17 * i)
    return jnp.asarray(c), jnp.asarray(nu), jnp.asarray(mu)


def _skewed_batch(b, nb, seed, n_slow):
    """Convergence-skewed OT batch: ``n_slow`` adversarial instances
    (uniform-random clouds + mismatched masses -> duals climb ~1/eps
    steps) among a bulk of near-identity instances (demands are jittered
    twins of the supplies carrying exactly the twin's mass -> one or two
    phases). Sizes are mixed within the bucket."""
    rng = np.random.default_rng(seed)
    c = np.zeros((b, nb, nb), np.float32)
    nu = np.zeros((b, nb), np.float32)
    mu = np.zeros((b, nb), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i in range(b):
        m = int(rng.integers(nb // 2 + 1, nb + 1))
        x = rng.uniform(size=(m, 2))
        nui = rng.dirichlet(np.ones(m)).astype(np.float32)
        if i < n_slow:
            y = rng.uniform(size=(m, 2))
            mui = rng.dirichlet(np.ones(m)).astype(np.float32)
        else:
            perm = rng.permutation(m)
            y = x[perm] + rng.normal(0.0, 0.003, size=(m, 2))
            mui = nui[perm]
        d = x[:, None, :] - y[None, :, :]
        c[i, :m, :m] = np.sqrt((d * d).sum(-1) + 1e-30)
        nu[i, :m] = nui
        mu[i, :m] = mui
        sizes[i] = (m, m)
    return c, nu, mu, sizes


def _once(fn):
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def _best(fn, repeats=2):
    _once(fn)  # warm / compile
    return min(_once(fn) for _ in range(repeats))


def run_skewed(b, n, eps, k=4, n_slow=3):
    """Lockstep vs compaction on a convergence-skewed batch; results must
    be identical (same plans, same phase counts)."""
    c, nu, mu, sizes = _skewed_batch(b, n, seed=n + b, n_slow=n_slow)
    t_lock = _best(lambda: solve_ot_batched(c, nu, mu, eps,
                                            sizes=sizes).cost)
    t_comp = _best(lambda: solve_ot_batched_compacting(
        c, nu, mu, eps, sizes=sizes, k=k)[0].cost)

    r0 = solve_ot_batched(c, nu, mu, eps, sizes=sizes)
    r1, st = solve_ot_batched_compacting(c, nu, mu, eps, sizes=sizes, k=k)
    assert np.array_equal(np.asarray(r0.plan), np.asarray(r1.plan)), \
        "compaction must reproduce lockstep plans exactly"
    assert np.array_equal(np.asarray(r0.phases), np.asarray(r1.phases))
    ph = np.asarray(r0.phases)

    speedup = t_lock / t_comp
    waste = st.lockstep_slot_phases / max(st.phases_needed, 1)
    record(
        f"batched/ot_skewed/B={b}/n={n}/eps={eps}", t_comp / b,
        f"inst_per_s={b / t_comp:.1f};lockstep_inst_per_s={b / t_lock:.1f};"
        f"speedup_vs_lockstep={speedup:.2f}x;"
        f"phase_skew={ph.max() / max(ph.min(), 1):.1f}x;"
        f"slot_phases={st.slot_phases}/{st.lockstep_slot_phases}",
        instances_per_s=b / t_comp,
        lockstep_instances_per_s=b / t_lock,
        speedup_vs_lockstep=speedup,
        lockstep_waste=waste,
        results_identical=True,
        **st.as_dict(),
    )
    return speedup


def run_skewed_assignment(b, n, eps, k=4, n_slow=3):
    c, _, _, sizes = _skewed_batch(b, n, seed=3 * n + b, n_slow=n_slow)
    t_lock = _best(lambda: solve_assignment_batched(c, eps,
                                                    sizes=sizes).cost)
    t_comp = _best(lambda: solve_assignment_batched_compacting(
        c, eps, sizes=sizes, k=k)[0].cost)
    r0 = solve_assignment_batched(c, eps, sizes=sizes)
    r1, st = solve_assignment_batched_compacting(c, eps, sizes=sizes, k=k)
    assert np.array_equal(np.asarray(r0.matching), np.asarray(r1.matching))
    speedup = t_lock / t_comp
    record(
        f"batched/assignment_skewed/B={b}/n={n}/eps={eps}", t_comp / b,
        f"inst_per_s={b / t_comp:.1f};lockstep_inst_per_s={b / t_lock:.1f};"
        f"speedup_vs_lockstep={speedup:.2f}x",
        instances_per_s=b / t_comp,
        lockstep_instances_per_s=b / t_lock,
        speedup_vs_lockstep=speedup,
        results_identical=True,
        **st.as_dict(),
    )
    return speedup


def run_mixed_eps(b, n, eps_bulk=0.1, eps_tail=0.02, n_tail=3, k=4):
    """Per-instance eps: one compacted dispatch vs the lockstep path's only
    option, one dispatch per eps group (eps is a static jit arg there, so
    novel eps values also recompile; compaction takes eps as data). The
    fine-eps tail rides on adversarial instances, the realistic case of a
    few high-accuracy stragglers in a bulk queue."""
    c, nu, mu, sizes = _skewed_batch(b, n, seed=7 * n + b, n_slow=n_tail)
    eps_arr = np.full((b,), eps_bulk)
    eps_arr[:n_tail] = eps_tail

    t_comp = _best(lambda: solve_ot_batched_compacting(
        c, nu, mu, eps_arr, sizes=sizes, k=k)[0].cost)

    groups = [(e, np.flatnonzero(eps_arr == e))
              for e in np.unique(eps_arr)]

    def lockstep_groups():
        return [solve_ot_batched(c[idx], nu[idx], mu[idx], float(e),
                                 sizes=sizes[idx]).cost
                for e, idx in groups]

    t_lock = _best(lockstep_groups)

    # equality: each instance against its own-eps lockstep group result
    r1, st = solve_ot_batched_compacting(c, nu, mu, eps_arr, sizes=sizes,
                                         k=k)
    for e, idx in groups:
        r0 = solve_ot_batched(c[idx], nu[idx], mu[idx], float(e),
                              sizes=sizes[idx])
        np.testing.assert_allclose(np.asarray(r1.plan)[idx],
                                   np.asarray(r0.plan), atol=1e-6)
        assert np.array_equal(np.asarray(r1.phases)[idx],
                              np.asarray(r0.phases))

    # the serving reality: requests carry NOVEL eps values. eps is data to
    # the compacted solver (programs reused); the lockstep path jits eps
    # statically, so each fresh value pays a full solver compile.
    novel = np.full((b,), eps_bulk * 0.93)
    novel[:n_tail] = eps_tail * 1.7
    t_comp_novel = _once(lambda: solve_ot_batched_compacting(
        c, nu, mu, novel, sizes=sizes, k=k)[0].cost)
    novel_groups = [(e, np.flatnonzero(novel == e))
                    for e in np.unique(novel)]
    t_lock_novel = _once(lambda: [
        solve_ot_batched(c[idx], nu[idx], mu[idx], float(e),
                         sizes=sizes[idx]).cost
        for e, idx in novel_groups
    ])

    record(
        f"batched/ot_mixed_eps/B={b}/n={n}/eps={eps_bulk}+{eps_tail}",
        t_comp / b,
        f"inst_per_s={b / t_comp:.1f};"
        f"per_eps_lockstep_inst_per_s={b / t_lock:.1f};"
        f"speedup_vs_eps_grouped_lockstep={t_lock / t_comp:.2f}x;"
        f"novel_eps_dispatch_s={t_comp_novel:.2f}_vs_lockstep_"
        f"{t_lock_novel:.2f}_(recompiles);"
        f"dispatches={st.dispatches}",
        instances_per_s=b / t_comp,
        lockstep_instances_per_s=b / t_lock,
        speedup_vs_lockstep=t_lock / t_comp,
        novel_eps_dispatch_s=t_comp_novel,
        novel_eps_lockstep_s=t_lock_novel,
        results_identical=True,
        **st.as_dict(),
    )


def run_ragged(b, n, eps):
    """Long-tail shapes in (n/2, n]: native-shape loop (per-shape compile)
    vs one padded bucket dispatch (compacting driver)."""
    rng = np.random.default_rng(n * b)
    insts = []
    while len(insts) < b:
        m1 = int(rng.integers(n // 2 + 1, n + 1))
        n1 = int(rng.integers(n // 2 + 1, n + 1))
        insts.append(_instance(m1, n1, seed=len(insts)))
    c = np.zeros((b, n, n), np.float32)
    nu = np.zeros((b, n), np.float32)
    mu = np.zeros((b, n), np.float32)
    sizes = np.zeros((b, 2), np.int32)
    for i, (ci, nui, mui) in enumerate(insts):
        mi, ni = ci.shape
        c[i, :mi, :ni] = ci
        nu[i, :mi] = nui
        mu[i, :ni] = mui
        sizes[i] = (mi, ni)

    # batched: cold (includes the one-off bucket compile), then warm
    t_cold = _once(lambda: solve_ot_batched_compacting(
        c, nu, mu, eps, sizes=sizes)[0].cost)
    t_warm = _once(lambda: solve_ot_batched_compacting(
        c, nu, mu, eps, sizes=sizes)[0].cost)

    # looped at native shapes: every novel (m, n) pays its compile, exactly
    # like the pre-batching service did on long-tail traffic
    t_loop = _once(lambda: [
        solve_ot(jnp.asarray(ci), jnp.asarray(nui), jnp.asarray(mui), eps).cost
        for ci, nui, mui in insts
    ])

    record(f"batched/ot_ragged/B={b}/bucket={n}", t_warm / b,
           f"inst_per_s={b / t_warm:.1f};loop_native_inst_per_s={b / t_loop:.2f};"
           f"speedup_vs_native_loop={t_loop / t_warm:.1f}x;"
           f"cold_batch_s={t_cold:.2f}",
           instances_per_s=b / t_warm)
    return t_loop / t_warm


def run_fixed(b, n, eps):
    c, nu, mu = _fixed_batch(b, n, seed=n + b)

    t_batch = time_call(lambda: solve_assignment_batched(c, eps), repeats=2)
    t_loop = time_call(
        lambda: [solve_assignment(c[i], eps).cost for i in range(b)],
        repeats=2,
    )
    record(f"batched/assignment_fixed/B={b}/n={n}", t_batch / b,
           f"inst_per_s={b / t_batch:.1f};loop_inst_per_s={b / t_loop:.1f};"
           f"lockstep_ratio={t_loop / t_batch:.2f}x",
           instances_per_s=b / t_batch)

    t_batch = time_call(lambda: solve_ot_batched(c, nu, mu, eps), repeats=2)
    t_loop = time_call(
        lambda: [solve_ot(c[i], nu[i], mu[i], eps).cost for i in range(b)],
        repeats=2,
    )
    record(f"batched/ot_fixed/B={b}/n={n}", t_batch / b,
           f"inst_per_s={b / t_batch:.1f};loop_inst_per_s={b / t_loop:.1f};"
           f"lockstep_ratio={t_loop / t_batch:.2f}x",
           instances_per_s=b / t_batch)

    reg = reg_for_additive_eps(eps, n)
    sk_batched = jax.jit(jax.vmap(
        lambda ci, nui, mui: sinkhorn(ci, nui, mui, reg=reg,
                                      tol=eps / 8.0, max_iters=2000).cost
    ))
    t_sk = time_call(lambda: sk_batched(c, nu, mu), repeats=2)
    record(f"batched/sinkhorn/B={b}/n={n}", t_sk / b,
           f"inst_per_s={b / t_sk:.1f}",
           instances_per_s=b / t_sk)


def run(full: bool = False, tiny: bool = False):
    """Returns the record list (also kept in RECORDS for write_json)."""
    if tiny:
        # CI smoke: the compaction path end to end in seconds on a CPU
        # runner, equality asserts included.
        run_skewed(8, 32, 0.1, k=2, n_slow=1)
        run_skewed_assignment(8, 32, 0.1, k=2, n_slow=1)
        run_mixed_eps(8, 32, eps_bulk=0.2, eps_tail=0.1, n_tail=2, k=2)
        return RECORDS
    eps = 0.1
    # headline: convergence-skewed batches, lockstep vs compaction
    run_skewed(32, 64, 0.05, k=4)
    run_skewed(32, 128, 0.05, k=4)
    run_skewed(32, 64, 0.1, k=4)
    run_skewed_assignment(32, 64, 0.05, k=4)
    run_mixed_eps(32, 64)
    run_ragged(8, 128, eps)
    run_ragged(32, 256, eps)
    for b, n in ([(8, 128), (32, 256)] if not full
                 else [(8, 128), (32, 256), (64, 256), (32, 512)]):
        run_fixed(b, n, eps)
    if full:
        run_skewed(64, 64, 0.05, k=4)
        run_skewed(64, 128, 0.05, k=8)
    return RECORDS


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: seconds on a CPU runner")
    ap.add_argument("--json", default="",
                    help="machine-readable output path (off by default so "
                         "ad-hoc/tiny runs don't overwrite the committed "
                         "BENCH_batched.json baseline; benchmarks/run.py "
                         "writes the canonical one)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(full=args.full, tiny=args.tiny)
    if args.json:
        write_json(args.json)
