"""One function per paper table/figure. Prints ``name,us_per_call,derived``
CSV. ``python -m benchmarks.run [--full]`` (full = paper-scale grids).

``--diff`` compares a fresh run of the JSON-emitting families (batched,
sharded, solution, faults, serve, kernels, portfolio) against the committed
``BENCH_*.json`` instead of overwriting them, flags any >20%
instances/sec regression, and exits nonzero if one is found — the perf
gate for driver AND kernel refactors.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DIFF_THRESHOLD = 0.2     # flag >20% instances/sec regressions

# (name, old, new, ratio, tag) rows accumulated across diff_records calls,
# rendered as a markdown table into GITHUB_STEP_SUMMARY when CI sets it
_DIFF_ROWS: list = []


def diff_records(fresh: list, committed_path: str,
                 threshold: float = DIFF_THRESHOLD) -> list:
    """Compare ``instances_per_s`` between fresh records and the committed
    baseline (matched by record name). Prints one line per comparable
    record; returns the names that regressed by more than ``threshold``."""
    if not os.path.exists(committed_path):
        print(f"# no committed {committed_path}; nothing to diff",
              file=sys.stderr, flush=True)
        return []
    with open(committed_path) as f:
        base = {r["name"]: r for r in json.load(f)["records"]}
    regressions = []
    print(f"# --- diff vs {committed_path} "
          f"(flagging >{threshold:.0%} instances/sec regressions) ---",
          file=sys.stderr, flush=True)
    for r in fresh:
        b = base.get(r["name"])
        if (b is None or "instances_per_s" not in r
                or "instances_per_s" not in b):
            continue
        new, old = float(r["instances_per_s"]), float(b["instances_per_s"])
        ratio = new / old if old > 0 else float("inf")
        regressed = ratio < 1.0 - threshold
        tag = "REGRESSION" if regressed else "ok"
        print(f"# {r['name']}: {old:.1f} -> {new:.1f} inst/s "
              f"({ratio - 1.0:+.1%}) {tag}", file=sys.stderr, flush=True)
        _DIFF_ROWS.append((r["name"], old, new, ratio, tag))
        if regressed:
            regressions.append(r["name"])
    return regressions


def write_step_summary(regressions: list,
                       path: str = "") -> None:
    """Render the accumulated diff rows as a markdown table into the CI
    step summary (``GITHUB_STEP_SUMMARY``); no-op outside CI."""
    path = path or os.environ.get("GITHUB_STEP_SUMMARY", "")
    if not path or not _DIFF_ROWS:
        return
    lines = ["## Benchmark diff vs committed baselines", "",
             f"Threshold: >{DIFF_THRESHOLD:.0%} instances/sec regression "
             f"fails the job.", "",
             "| bench | baseline inst/s | fresh inst/s | delta | status |",
             "|---|---:|---:|---:|---|"]
    for name, old, new, ratio, tag in _DIFF_ROWS:
        status = ":x: REGRESSION" if tag == "REGRESSION" else ":white_check_mark: ok"
        lines.append(f"| `{name}` | {old:.1f} | {new:.1f} | "
                     f"{ratio - 1.0:+.1%} | {status} |")
    lines.append("")
    lines.append(f"**{len(regressions)} regression(s)**" if regressions
                 else "**diff clean**")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: synthetic,mnist,"
                         "routing,ot,batched,sharded,solution,faults,"
                         "serve,kernels,portfolio")
    ap.add_argument("--diff", action="store_true",
                    help="compare fresh batched/sharded results against "
                         "the committed BENCH_*.json (no overwrite); exit "
                         "1 on a >20%% instances/sec regression")
    args = ap.parse_args()

    from . import bench_synthetic, bench_mnist, \
        bench_routing, bench_ot, bench_batched, bench_sharded, \
        bench_solution, bench_faults, bench_serve, bench_kernels, \
        bench_portfolio

    benches = {
        "synthetic": bench_synthetic.run,   # paper Fig. 1
        "mnist": bench_mnist.run,           # paper Fig. 2
        "ot": bench_ot.run,                 # Section 4 clustered solver
        "routing": bench_routing.run,       # framework integration
        "batched": bench_batched.run,       # batched serving subsystem
        "sharded": bench_sharded.run,       # mesh-distributed dispatch
        "solution": bench_solution.run,     # typed result surface fetch
        "faults": bench_faults.run,         # admission gate + recovery
        "serve": bench_serve.run,           # saturation + obs overhead
        "kernels": bench_kernels.run,       # fused vs stepped phase loop
        #   (also carries the Section 3.2 phase-bound rows that lived in
        #   the retired bench_phases family)
        "portfolio": bench_portfolio.run,   # solver crossover sweep
    }
    if args.diff and args.only is None:
        # diff mode only makes sense for the JSON-emitting families
        args.only = "batched,sharded,solution,faults,serve,kernels,portfolio"
    only = set(args.only.split(",")) if args.only else set(benches)
    if args.diff and not ({"batched", "sharded", "solution",
                           "faults", "serve", "kernels",
                           "portfolio"} & only):
        ap.error("--diff compares the JSON-emitting families; include "
                 "batched, sharded, solution, faults, serve, kernels "
                 "and/or portfolio in --only")
    regressions: list = []
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        fn(full=args.full)
        if name == "batched":
            # machine-readable perf trajectory: instances/sec, the
            # lockstep-waste metric (phases executed vs needed), and the
            # compaction occupancy curve, for future PRs to diff against
            if args.diff:
                regressions += diff_records(bench_batched.RECORDS,
                                            "BENCH_batched.json")
            else:
                bench_batched.write_json("BENCH_batched.json")
        if name == "sharded":
            # instances/sec vs device count + occupancy + mesh topology
            # (the bench re-execs itself under a forced 8-device CPU)
            if args.diff:
                regressions += diff_records(bench_sharded.RECORDS,
                                            "BENCH_sharded.json")
            else:
                bench_sharded.write_json("BENCH_sharded.json")
        if name == "solution":
            # host-fetch bytes + wall time per declared artifact set
            # (cost-only vs sparse vs dense plans)
            if args.diff:
                regressions += diff_records(bench_solution.RECORDS,
                                            "BENCH_solution.json")
            else:
                bench_solution.write_json("BENCH_solution.json")
        if name == "faults":
            # healthy-path admission overhead (<5% budget asserted) +
            # poisoned-bucket recovery latency
            if args.diff:
                regressions += diff_records(bench_faults.RECORDS,
                                            "BENCH_faults.json")
            else:
                bench_faults.write_json("BENCH_faults.json")
        if name == "serve":
            # p50/p99 latency + throughput vs offered load through the
            # async scheduler, and the asserted <2% no-sink obs budget
            if args.diff:
                regressions += diff_records(bench_serve.RECORDS,
                                            "BENCH_serve.json")
            else:
                bench_serve.write_json("BENCH_serve.json")
        if name == "kernels":
            # us/phase + phases/sec per kernel per backend, fused vs
            # unfused phase loop (parity-asserted per row)
            if args.diff:
                regressions += diff_records(bench_kernels.RECORDS,
                                            "BENCH_kernels.json")
            else:
                bench_kernels.write_json("BENCH_kernels.json")
        if name == "portfolio":
            # per-instance seconds per (solver, n, eps) across the
            # paper's crossover sweep: pushrelabel vs sinkhorn vs hybrid
            if args.diff:
                regressions += diff_records(bench_portfolio.RECORDS,
                                            "BENCH_portfolio.json")
            else:
                bench_portfolio.write_json("BENCH_portfolio.json")
    if args.diff:
        write_step_summary(regressions)
        if regressions:
            print(f"# PERF REGRESSIONS ({len(regressions)}): "
                  + ", ".join(regressions), file=sys.stderr, flush=True)
            sys.exit(1)
        print("# diff clean: no instances/sec regression beyond "
              f"{DIFF_THRESHOLD:.0%}", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
