"""One function per paper table/figure. Prints ``name,us_per_call,derived``
CSV. ``python -m benchmarks.run [--full]`` (full = paper-scale grids)."""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: synthetic,mnist,phases,"
                         "routing,ot,batched,sharded")
    args = ap.parse_args()

    from . import bench_synthetic, bench_mnist, bench_phases, \
        bench_routing, bench_ot, bench_batched, bench_sharded

    benches = {
        "synthetic": bench_synthetic.run,   # paper Fig. 1
        "mnist": bench_mnist.run,           # paper Fig. 2
        "phases": bench_phases.run,         # Section 3.2 bounds
        "ot": bench_ot.run,                 # Section 4 clustered solver
        "routing": bench_routing.run,       # framework integration
        "batched": bench_batched.run,       # batched serving subsystem
        "sharded": bench_sharded.run,       # mesh-distributed dispatch
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        fn(full=args.full)
        if name == "batched":
            # machine-readable perf trajectory: instances/sec, the
            # lockstep-waste metric (phases executed vs needed), and the
            # compaction occupancy curve, for future PRs to diff against
            bench_batched.write_json("BENCH_batched.json")
        if name == "sharded":
            # instances/sec vs device count + occupancy + mesh topology
            # (the bench re-execs itself under a forced 8-device CPU)
            bench_sharded.write_json("BENCH_sharded.json")


if __name__ == "__main__":
    main()
