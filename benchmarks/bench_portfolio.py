"""Solver-portfolio perf family: push-relabel vs Sinkhorn vs hybrid
across the paper's accuracy sweep.

The paper's headline experiment is a CROSSOVER story: Sinkhorn's
iteration count grows ~1/eps^2 (AWR bound) while push-relabel's phase
count grows ~1/eps, so Sinkhorn wins at loose eps and loses as eps
tightens. This family measures that crossover end to end through the
SAME dispatch surface serving traffic uses (``solve_compacting`` /
``dispatch_hybrid``), at eps in {0.3, 0.1, 0.03, 0.01}, and records
per-instance wall seconds + instances/sec per (solver, n, eps) cell.

Two consumers:

  * ``benchmarks/run.py`` writes the canonical ``BENCH_portfolio.json``
    and ``run.py --diff`` gates every row's instances/sec against it.
  * ``--calibrate`` refits the measured cost model behind
    ``DispatchPolicy(solver="auto")`` (``repro.portfolio.costmodel``)
    from the same records and writes it where ``--json`` points —
    refresh ``src/repro/portfolio/costmodel_default.json`` on real
    hardware with exactly this entry point.

Honesty notes: off-TPU the Pallas kernels run in interpret mode and
every record (and the fitted cost model) carries ``mode=interpret`` so
CPU numbers are never mistaken for accelerator numbers. Sinkhorn rows
carry ``converged`` (the fraction of lanes that hit the AWR marginal
tolerance within the iteration budget) — a row measured against an
iteration cap says so instead of silently timing a partial solve.

    PYTHONPATH=src python -m benchmarks.bench_portfolio [--full|--tiny]
    PYTHONPATH=src python -m benchmarks.bench_portfolio --calibrate \
        --json src/repro/portfolio/costmodel_default.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core.api import OT, DispatchPolicy
from repro.core.compaction import solve_compacting
from repro.kernels.slack_propose import _resolve_interpret
from repro.portfolio import SINKHORN, dispatch_hybrid, fit
from .common import emit, time_call

RECORDS: list = []

EPS_GRID = (0.3, 0.1, 0.03, 0.01)   # the paper's crossover sweep
# Sinkhorn iteration budget per tier: covers full convergence at every
# grid eps for the default sizes (measured: eps=0.01, n=32 needs ~1.7k
# sweeps); the `converged` field reports honestly if a cell caps out.
MAX_ITERS = {"tiny": 400, "default": 3000, "full": 20000}


def _mode() -> str:
    return "interpret" if _resolve_interpret(None) else "compiled"


def record(name, seconds, derived="", **extra):
    emit(name, seconds, derived)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived, **extra})


def write_json(path="BENCH_portfolio.json"):
    payload = {
        "schema": 1,
        "bench": "portfolio",
        "backend": jax.default_backend(),
        "pallas_mode": _mode(),
        "records": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(RECORDS)} records)", flush=True)
    return path


def _ot_batch(b, n, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.uniform(0.1, 1.0, (b, n, n)).astype(np.float32)
    nu = rng.uniform(0.5, 1.5, (b, n)).astype(np.float32)
    nu /= nu.sum(1, keepdims=True)
    mu = rng.uniform(0.5, 1.5, (b, n)).astype(np.float32)
    mu /= mu.sum(1, keepdims=True)
    return {"c": c, "nu": nu, "mu": mu}


def bench_cell(solver: str, n: int, eps: float, b: int, max_iters: int,
               repeats: int = 3):
    """One (solver, n, eps) cell: median wall seconds over the full
    dispatch (prepare + chunk loop + epilogue), per instance."""
    inputs = _ot_batch(b, n, seed=n)

    if solver == "pushrelabel":
        fn = lambda: solve_compacting(OT, inputs, eps)[0].cost
        r, _ = solve_compacting(OT, inputs, eps)
        conv = 1.0
        phases = int(np.asarray(r.phases).max())
    elif solver == "sinkhorn":
        fn = lambda: solve_compacting(SINKHORN, inputs, eps, k=256,
                                      max_iters=max_iters)[0].cost
        r, _ = solve_compacting(SINKHORN, inputs, eps, k=256,
                                max_iters=max_iters)
        # honest convergence: fraction of lanes at the AWR marginal
        # tolerance (eps/8 in normalized mass units) within the budget
        conv = float(np.mean(np.asarray(r.err) <= eps / 8.0))
        phases = int(np.asarray(r.phases).max())
    elif solver == "hybrid":
        pol = DispatchPolicy(mode="compact")
        fn = lambda: dispatch_hybrid(inputs, eps, policy=pol)[0].cost
        r, _ = dispatch_hybrid(inputs, eps, policy=pol)
        conv = 1.0
        phases = int(np.asarray(r.phases).max())
    else:
        raise ValueError(solver)

    t = time_call(fn, repeats=repeats)
    per_inst = t / b
    record(f"portfolio/{solver}/n={n}/eps={eps}", per_inst,
           f"phases={phases};converged={conv:.2f};mode={_mode()}",
           instances_per_s=b / t, solver=solver, n=n, eps=eps,
           per_instance_s=per_inst, converged=conv, mode=_mode())
    return {"solver": solver, "n": n, "eps": eps,
            "per_instance_s": per_inst}


def run(full: bool = False, tiny: bool = False):
    """The sweep; returns calibration rows for ``--calibrate``/``fit``."""
    if tiny:
        sizes, b, eps_grid, iters = [16], 2, (0.3, 0.1), MAX_ITERS["tiny"]
    elif full:
        sizes, b, eps_grid, iters = [32, 64], 4, EPS_GRID, \
            MAX_ITERS["full"]
    else:
        sizes, b, eps_grid, iters = [32], 4, EPS_GRID, \
            MAX_ITERS["default"]
    rows = []
    for n in sizes:
        for eps in eps_grid:
            for solver in ("pushrelabel", "sinkhorn", "hybrid"):
                rows.append(bench_cell(solver, n, eps, b, iters))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid (n=16, loose eps only)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the measured cost model from this sweep "
                         "and write it to --json")
    ap.add_argument("--json", default=None,
                    help="write BENCH json (or, with --calibrate, the "
                         "cost-model json) here; off by default so ad-hoc "
                         "runs never clobber the committed baselines "
                         "(run.py writes the canonical file)")
    args = ap.parse_args()
    rows = run(full=args.full, tiny=args.tiny)
    if args.calibrate:
        model = fit(rows, mode=_mode(), backend=jax.default_backend())
        path = args.json or "costmodel.json"
        model.save(path)
        print(f"# wrote cost model {path} ({len(model.entries)} cells, "
              f"mode={model.mode})", flush=True)
    elif args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
