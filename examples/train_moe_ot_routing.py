"""End-to-end training driver: train a small deepseek-style MoE LM with the
paper's push-relabel balanced routing vs top-k, with checkpoint/restart.
Reports loss curves and expert load balance.

    PYTHONPATH=src python examples/train_moe_ot_routing.py [--steps 60]
    (--steps 300 --width 512 for a ~100M-param run)
"""
import argparse
import shutil

import numpy as np

from repro.configs.registry import ARCHS, reduced
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workdir", default="/tmp/repro_moe_train")
    args = ap.parse_args()

    base = reduced(ARCHS["deepseek-moe-16b"]).with_(
        d_model=args.width, num_layers=args.layers,
        d_ff=args.width * 2, d_ff_expert=args.width // 2,
        num_experts=16, top_k=2, vocab_size=2048,
    )
    results = {}
    for router in ["topk", "pushrelabel"]:
        cfg = base.with_(router=router, name=f"moe-{router}")
        wd = f"{args.workdir}/{router}"
        shutil.rmtree(wd, ignore_errors=True)
        n_params = None
        tr = Trainer(cfg, wd, seq_len=args.seq_len,
                     batch_size=args.batch, lr=1e-3, ckpt_every=25,
                     total_steps=args.steps)
        import jax
        n_params = sum(x.size for x in jax.tree.leaves(tr.params))
        hist = tr.run(args.steps)
        losses = [h["loss"] for h in hist]
        results[router] = losses
        print(f"[{router}] params={n_params/1e6:.1f}M "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"(mean step {np.mean([h['time_s'] for h in hist[2:]]):.2f}s, "
              f"stragglers={tr.straggler_events})")

    print("\nstep | topk     | pushrelabel")
    for i in range(0, args.steps, max(args.steps // 10, 1)):
        print(f"{i:4d} | {results['topk'][i]:.4f}   | "
              f"{results['pushrelabel'][i]:.4f}")


if __name__ == "__main__":
    main()
