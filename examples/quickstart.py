"""Quickstart: epsilon-approximate optimal transport in three calls.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import build_cost_matrix, solve_assignment, solve_ot, sinkhorn
from repro.core.exact import exact_assignment_cost


def main():
    rng = np.random.default_rng(0)
    n = 256
    x = rng.uniform(size=(n, 2)).astype(np.float32)
    y = rng.uniform(size=(n, 2)).astype(np.float32)

    # 1. cost matrix (use kernel="pallas" on TPU)
    c = build_cost_matrix(jnp.asarray(x), jnp.asarray(y), "euclidean")

    # 2. assignment (paper Section 2): eps-approximate matching + duals
    r = solve_assignment(c, eps=0.05)
    opt = exact_assignment_cost(np.asarray(c))
    print(f"assignment: cost={float(r.cost):.4f} exact={opt:.4f} "
          f"phases={int(r.phases)} propose_rounds={int(r.rounds)}")
    print(f"  additive gap per point: "
          f"{(float(r.cost) - opt) / n:.5f}  (guarantee: 3*eps*max_c)")
    print(f"  dual certificate (lower bound): "
          f"{float(jnp.sum(r.y_b) + jnp.sum(r.y_a)):.4f}")

    # 3. general OT (paper Section 4): arbitrary masses, compact plan
    nu = rng.dirichlet(np.ones(n)).astype(np.float32)
    mu = rng.dirichlet(np.ones(n)).astype(np.float32)
    ot = solve_ot(c, jnp.asarray(nu), jnp.asarray(mu), eps=0.05)
    plan = np.asarray(ot.plan)
    print(f"OT: cost={float(ot.cost):.5f} phases={int(ot.phases)} "
          f"plan_nnz={(plan > 1e-12).sum()} (compact: <= 2n + n)")
    print(f"  marginal error: row={np.abs(plan.sum(1) - nu).max():.2e} "
          f"col={np.abs(plan.sum(0) - mu).max():.2e}")

    # 4. the baseline the paper compares against
    sk = sinkhorn(c, jnp.asarray(nu), jnp.asarray(mu), reg=0.01, tol=1e-6)
    print(f"sinkhorn: cost={float(sk.cost):.5f} iters={int(sk.iters)}")

    # 5. batched API: B instances as ONE XLA program. Ragged shapes are
    #    bucketed + padded (padding is masked, so each result equals its
    #    unbatched solve); one compiled program per bucket serves every
    #    future batch of that bucket - no per-shape recompiles.
    from repro.core import solve_ot_ragged

    insts = []
    for _ in range(6):
        m = int(rng.integers(40, 120))
        xb = rng.uniform(size=(m, 2)).astype(np.float32)
        yb = rng.uniform(size=(m, 2)).astype(np.float32)
        cb = build_cost_matrix(jnp.asarray(xb), jnp.asarray(yb), "euclidean")
        nub = rng.dirichlet(np.ones(m)).astype(np.float32)
        mub = rng.dirichlet(np.ones(m)).astype(np.float32)
        insts.append((np.asarray(cb), nub, mub))
    outs = solve_ot_ragged(insts, eps=0.05)   # compact=True by default
    for i, o in enumerate(outs):
        print(f"batched[{i}]: cost={o['cost']:.5f} bucket={o['bucket']} "
              f"batch_size={o['batch_size']} plan={o['plan'].shape} "
              f"dispatches={o['dispatches']}")

    # 6. convergence compaction: each bucket above was actually solved as a
    #    sequence of k-phase dispatches; converged instances retire between
    #    dispatches instead of running lockstep until the bucket's slowest
    #    instance finishes. The driver is available directly - it returns
    #    occupancy/waste stats, and eps may be per-instance (mixed-accuracy
    #    batches, inexpressible in the lockstep path):
    from repro.core import solve_ot_batched_compacting
    from repro.core.batched import pad_stack

    b, nmax = len(insts), max(c.shape[0] for c, _, _ in insts)
    cb = pad_stack([ci for ci, _, _ in insts], (nmax, nmax))
    nub = pad_stack([nui for _, nui, _ in insts], (nmax,))
    mub = pad_stack([mui for _, _, mui in insts], (nmax,))
    sizes = np.asarray([ci.shape for ci, _, _ in insts], np.int32)
    eps_each = np.where(np.arange(b) % 2 == 0, 0.05, 0.1)  # per-instance!
    res, stats = solve_ot_batched_compacting(cb, nub, mub, eps_each,
                                             sizes=sizes, k=4)
    print(f"compaction: dispatches={stats.dispatches} "
          f"occupancy={stats.occupancy} "
          f"phases_needed={stats.phases_needed} vs "
          f"lockstep_slot_phases={stats.lockstep_slot_phases}")

    # 7. resumable stepped core underneath it all: a solve is just
    #    init_state -> run_phases(k) until converged, bit-identical to the
    #    one-shot solver for every chunk size k (see core/pushrelabel.py
    #    and core/transport.py for the assignment/OT stepped APIs).

    # 8. distributed dispatch: the same compacting driver with the BATCH
    #    axis sharded across a device mesh (core/distributed.py). On a
    #    multi-device host (or under XLA_FLAGS=--xla_force_host_platform_
    #    device_count=8) each k-phase dispatch runs shard_map'ed over the
    #    mesh and re-bucketing re-shards the survivors; on this host it
    #    degrades gracefully to the single-device driver. Results are
    #    bit-identical either way. A placement policy routes a few LARGE
    #    instances to row/col matrix sharding (core/sharded.py) instead.
    from repro.core import solve_ot_distributed
    from repro.launch.mesh import make_batch_mesh

    mesh = make_batch_mesh()   # 1-D pow2 batch mesh over the host devices
    res_d, dstats = solve_ot_distributed(cb, nub, mub, eps_each,
                                         sizes=sizes, k=4, mesh=mesh)
    assert np.array_equal(np.asarray(res_d.plan), np.asarray(res.plan))
    print(f"distributed: devices={dstats.devices} "
          f"placement={dstats.placement} dispatches={dstats.dispatches} "
          f"(bit-identical to the single-device compacting solve)")

    # 9. async multi-tenant serving front end (serve/scheduler.py): submit
    #    from any thread -> Future; a collate worker buckets/pads/builds
    #    cost matrices for the NEXT batch while the dispatch worker's
    #    current batch is in flight on the mesh; per-request stats report
    #    queue wait, solve time, phase counts, and the occupancy curve.
    from repro.serve.scheduler import AsyncOTScheduler

    with AsyncOTScheduler(eps=0.05, mesh=mesh, linger_ms=5) as sched:
        futs = []
        for i in range(4):
            m = int(rng.integers(30, 80))
            xs = rng.uniform(size=(m, 2)).astype(np.float32)
            ys = rng.uniform(size=(m, 2)).astype(np.float32)
            # per-request eps: mixed-accuracy tenants share dispatches
            futs.append(sched.submit(xs, ys, eps=0.05 if i % 2 else 0.1))
        sched.flush()
        for i, f in enumerate(futs):
            r = f.result()
            print(f"scheduler[{i}]: cost={r['cost']:.4f} "
                  f"eps={r['eps']} wait={r['wait_s'] * 1e3:.1f}ms "
                  f"batch={r['batch_size']} devices={r['devices']}")

    # 10. the unified solve() front door (core/api.py). Everything above —
    #     lockstep batches, compaction, mesh dispatch, the serving layers —
    #     routes through ONE entry point: a ProblemSpec (core/problem.py)
    #     captures the paper's stepped-core contract (prepare -> prologue
    #     -> init_state -> run_phases(k) -> converged -> epilogue, i.e.
    #     Algorithm 1/2), and a DispatchPolicy picks the driver. The same
    #     call solves a ragged list under any policy, with identical
    #     results:
    from repro.core import ASSIGNMENT, OT, DispatchPolicy, solve

    ragged = [c for c, _, _ in insts]
    for mode in ("lockstep", "compact", "mesh"):
        pol = DispatchPolicy(mode=mode,
                             mesh=mesh if mode == "mesh" else None)
        outs10 = solve(ASSIGNMENT, ragged, eps_each, pol)
        print(f"solve(ASSIGNMENT, policy={mode}): "
              f"costs={[round(o['cost'], 4) for o in outs10[:3]]}...")
    # pre-batched buckets dispatch through the same door (this is what
    # OTService / AsyncOTScheduler call per bucket):
    r10, st10 = solve(OT, {"c": cb, "nu": nub, "mu": mub}, eps_each,
                      DispatchPolicy(mode="compact", chunk=4), sizes=sizes)
    assert np.array_equal(np.asarray(r10.plan), np.asarray(res.plan))
    print(f"solve(OT, bucket): dispatches={st10.dispatches} "
          f"(identical to section 6's driver call)")

    # 11. the typed Solution surface (core/solution.py): declare the
    #     artifacts you will read with want=, and only those ever cross
    #     device->host. A cost-only request fetches O(B) scalars instead
    #     of the O(B*n^2) dense plans (the byte win is the point: on an
    #     accelerator that fetch is interconnect traffic); the plan ships
    #     as compact COO triplets (the paper's sparse-support claim) that
    #     reconstruct the dense plan bit for bit; and the approximate
    #     DUAL solution yields an a-posteriori certificate: additive_gap()
    #     <= eps * m * max(c) under guaranteed=True (paper Thm 1.2/1.3).
    cost_only = solve(OT, {"c": cb, "nu": nub, "mu": mub}, eps_each,
                      DispatchPolicy(mode="compact", chunk=4), sizes=sizes,
                      want=("cost",))
    dense_bytes = int(np.prod(cb.shape)) * 4
    print(f"solve(want=('cost',)): costs={np.round(cost_only.cost(), 4)} "
          f"fetched {cost_only.fetched_bytes}B (dense plans would move "
          f"{dense_bytes}B — {dense_bytes // cost_only.fetched_bytes}x)")
    sols = solve(OT, insts, 0.05,
                 DispatchPolicy(mode="compact", guaranteed=True),
                 want=("cost", "duals", "plan_sparse"))
    s0 = sols[0]
    sp = s0.plan_sparse()
    assert np.array_equal(
        sp.to_dense(),
        solve(OT, insts, 0.05,
              DispatchPolicy(mode="compact", guaranteed=True),
              want=("plan",))[0].plan())
    print(f"Solution[0]: cost={s0.cost:.5f} plan_nnz={sp.nnz} "
          f"({sp.nbytes}B sparse vs {4 * sp.shape[0] * sp.shape[1]}B "
          f"dense, to_dense() bit-identical)")
    print(f"  certificate: additive_gap={s0.additive_gap():.5f} <= "
          f"eps*m*max(c)={s0.additive_gap_bound():.5f} "
          f"dual_feasible={s0.dual_feasible()} "
          f"(stats: {s0.stats.mode}, {s0.stats.dispatches} dispatches on "
          f"{s0.stats.devices} device(s))")

    # 12. auditing your own ProblemSpec (repro.analysis): every jitted
    #     entry point used above — the stepped cores, the compaction and
    #     mesh chunk dispatches, the kernel wrappers, the certificate
    #     reductions — self-registers with repro.analysis and is traced
    #     to a jaxpr, then audited for the bug classes this repo has
    #     actually shipped: donated-buffer aliasing, f32 threshold drift,
    #     baked-operand recompiles, hot-loop host syncs.
    #     `python -m repro.analysis --strict` is the CI gate. A custom
    #     spec's chunk dispatch is audited the same way — trace it with
    #     its donation contract and run the rules:
    from repro.analysis import registry, rules

    def my_init_chain(cost, demand):
        # BUG (on purpose): same-dtype astype is elided by jax, so the
        # state's supply vector ALIASES the retained demand buffer — the
        # chunk dispatch donates the state, freeing the buffer the
        # epilogue still reads. This is the bug class
        # rule_donation_safety exists to catch (fix: jnp.array(...,
        # copy=True), as in init_ot_state).
        d_int = jnp.ceil(demand * 32.0).astype(jnp.int32)
        state = {"free": d_int.astype(jnp.int32),
                 "y": jnp.zeros_like(d_int)}
        return {"state": state, "retained": {"d_int": d_int}}

    ent = registry.trace_entry(
        "quickstart.my_init_chain", my_init_chain,
        {"cost": jnp.zeros((8, 8), jnp.float32),
         "demand": jnp.full((8,), 0.125, jnp.float32)},
        retained={"cost", "demand"}, tags={"state-init-chain"})
    flagged = rules.audit_entry(ent)
    print(f"analysis: my_init_chain -> {len(flagged)} finding(s) "
          f"{[f.key for f in flagged]}")
    assert any(f.rule == "donation-safety" for f in flagged)
    repo_findings, n_entries = rules.audit_entries(registry.build_entries())
    print(f"analysis: repo audit traced {n_entries} entries, "
          f"{len(repo_findings)} finding(s) (each carries a justification "
          f"in repro/analysis/baseline_suppressions.txt; debug-mode "
          f"sanitizers: REPRO_DEBUG_CHECKS=1)")

    # 13. fault-tolerant serving: deadlines, degraded answers you can
    #     re-validate, and poisoned-instance quarantine.
    #     solve(..., deadline=) gives the chunked drivers an absolute
    #     wall-clock budget: the chunk loop stops dispatching when the
    #     budget is at risk and returns best-so-far Solutions flagged
    #     degraded=True. The duals stay eps-feasible at EVERY phase
    #     (invariant I2), so a degraded answer still carries a valid
    #     a-posteriori certificate — its additive_gap() is honestly
    #     larger, not wrong.
    import time as _time

    budget = solve(OT, insts, 0.05, DispatchPolicy(mode="compact", chunk=1),
                   want=("cost", "duals"), deadline=_time.monotonic())
    d0 = budget[0]
    print(f"deadline: degraded={d0.degraded} "
          f"dual_feasible={d0.dual_feasible()} "
          f"gap={float(d0.additive_gap()):.4f} "
          f"(vs converged {float(s0.additive_gap()):.4f})")
    assert d0.degraded and bool(d0.dual_feasible())

    #     Poisoned inputs never take down a batch: the serving layers
    #     (OTService / AsyncOTScheduler) run a vectorized admission gate
    #     per collated bucket — a NaN-poisoned request is rejected with
    #     RequestRejected while its healthy neighbors solve, bit-identical
    #     to a clean run. Dispatch-time poison (with validation off and
    #     REPRO_DEBUG_CHECKS=1, the checkify sanitizer trips mid-solve)
    #     is isolated by bisection; transient dispatch failures retry
    #     down a mesh -> compact -> host-CPU degradation ladder. The
    #     chaos harness (serve/faults.py) injects all of it
    #     deterministically:
    from repro.serve.faults import FaultInjector, FaultPlan
    from repro.serve.ft import RequestRejected
    from repro.serve.scheduler import AsyncOTScheduler

    inj = FaultInjector(FaultPlan(poison_submits=(1,)))
    pts = [np.random.default_rng(s).standard_normal((12, 2)).astype(
        np.float32) for s in range(8)]
    with AsyncOTScheduler(eps=0.1, linger_ms=50, faults=inj) as sched:
        futs = [sched.submit(pts[2 * i], pts[2 * i + 1],
                             tenant=f"tenant-{i}") for i in range(4)]
        outcomes = []
        for f in futs:
            try:
                outcomes.append(f"{f.result(timeout=300)['cost']:.4f}")
            except RequestRejected as e:
                outcomes.append(f"rejected({e.reason})")
        sd = sched.stats_dict()
    print(f"chaos: {outcomes} "
          f"(rejected={sd['rejected']} quarantined={sd['quarantined']} "
          f"retries={sd['retries']})")
    assert sum(o.startswith("rejected") for o in outcomes) == 1

    # 14. live observability (repro.obs): attach a sink and the whole
    #     request path streams out as structured events — per-request
    #     root spans (submit -> resolve), per-bucket collate/admission/
    #     dispatch/solve/artifact-fetch spans, the chunked drivers'
    #     per-chunk events, and the fault events from section 13
    #     (rejected/retry/ladder/quarantine/deadline-cut/degraded).
    #     stats_dict() is a VIEW over the same registry the sink streams
    #     from, so the numbers can never disagree; with no sink attached
    #     the whole layer costs <2% (benchmarks/bench_serve.py asserts
    #     the budget). JSONLSink writes one JSON object per line —
    #     here we demo the in-memory sink and render a span tree.
    import json as _json
    import tempfile as _tempfile

    from repro.obs import InMemorySink, JSONLSink, span_tree

    mem = InMemorySink()
    with _tempfile.TemporaryDirectory() as tmp:
        jpath = f"{tmp}/serve.jsonl"
        jsink = JSONLSink(jpath)
        with AsyncOTScheduler(eps=0.1, linger_ms=50,
                              sinks=(mem, jsink)) as sched:
            fut = sched.submit(pts[0], pts[1], tenant="healthy")
            fut.result(timeout=300)
            sched.flush()
        jsink.close()
        rows = [_json.loads(ln) for ln in open(jpath)]
    print(f"obs: JSONL sink wrote {len(rows)} rows "
          f"({sum(r['kind'] == 'event' for r in rows)} events, "
          f"{sum(r['kind'] == 'counter' for r in rows)} counter "
          f"increments)")
    (root,) = mem.spans("request")
    print("obs: healthy request span tree (one monotonic clock):")
    for ln in span_tree(mem.spans(), "req-0").splitlines():
        print(f"  {ln}")
    for ln in span_tree(mem.spans(), root["bucket_trace"]).splitlines():
        print(f"  {ln}")
    chunk = mem.events("chunk")
    print(f"obs: {len(chunk)} driver chunk event(s), e.g. live={{"
          f"{', '.join(str(e['live']) for e in chunk)}}} "
          f"compiled_delta={chunk[0]['compiled']}")

    #     the same stream captures faults: re-run section 13's poisoned
    #     tenant with a sink attached and the rejection (plus any
    #     retries/ladder drops) appears as events alongside the spans.
    mem2 = InMemorySink()
    inj2 = FaultInjector(FaultPlan(poison_submits=(0,),
                                   transient_dispatches=1))
    with AsyncOTScheduler(eps=0.1, linger_ms=50, faults=inj2,
                          sinks=(mem2,)) as sched:
        bad = sched.submit(pts[0], pts[1], tenant="poisoned")
        ok = sched.submit(pts[2], pts[3], tenant="healthy")
        try:
            bad.result(timeout=300)
        except RequestRejected:
            pass
        ok.result(timeout=300)
        sched.flush()
    rej = mem2.events("rejected")
    ret = mem2.events("retry")
    outcomes14 = sorted(s["outcome"] for s in mem2.spans("request"))
    print(f"obs: fault run streamed {len(rej)} rejected event(s), "
          f"{sum(e['n'] for e in ret)} retry(ies); "
          f"request outcomes={outcomes14}")
    assert outcomes14 == ["rejected", "resolved"]

    # 15. the fused phase kernel: DispatchPolicy(fused=True) swaps the
    #     k-phase inner loop for ONE Pallas kernel per chunk — slack +
    #     propose/accept + push + relabel with the solver state resident
    #     in VMEM across all k phases, instead of round-tripping through
    #     XLA/HBM between the slack_propose kernel and the state
    #     updates. Results are BIT-IDENTICAL to the stepped cores
    #     (tests/test_fused_phase.py asserts it across k, padded lanes,
    #     mixed per-instance eps, and every dispatch mode), so it is a
    #     pure perf knob. Block sizes resolve per backend from the table
    #     in kernels/ops.py (kernel_blocks); off-TPU the kernel runs in
    #     interpret mode — the committed BENCH_kernels.json rows carry
    #     mode=interpret|compiled so CPU numbers are never mistaken for
    #     accelerator numbers.
    from repro.kernels.ops import kernel_blocks

    pol_fused = DispatchPolicy(mode="compact", chunk=4, fused=True)
    r_f, _ = solve(OT, {"c": cb, "nu": nub, "mu": mub}, 0.1, pol_fused)
    r_s, _ = solve(OT, {"c": cb, "nu": nub, "mu": mub}, 0.1,
                   DispatchPolicy(mode="compact", chunk=4))
    assert np.array_equal(np.asarray(r_f.plan), np.asarray(r_s.plan))
    print(f"fused: compact dispatch through the fused kernel matches the "
          f"stepped core exactly (cost {float(r_f.cost[0]):.4f}); "
          f"fused_phase blocks for this backend = "
          f"{kernel_blocks('fused_phase')}")

    #     benchmarks/bench_kernels.py writes BENCH_kernels.json
    #     (us/phase + phases/sec per kernel, fused vs stepped, parity-
    #     asserted per row; gated by benchmarks/run.py --diff in CI):
    #
    #         {"name": "kernels/assignment_phase/fused/n=256/...",
    #          "us_per_call": ..., "instances_per_s": ...,
    #          "mode": "interpret"}
    #
    #     for GPU launches, launch/platform.py pins the backend and
    #     installs the latency-hiding/async-stream XLA flags BEFORE the
    #     first jax computation (after backend init they are ignored):
    #
    #         from repro.launch.platform import set_platform
    #         set_platform("gpu")   # jax_platform_name + XLA_FLAGS

    # 16. the solver portfolio: DispatchPolicy(solver=...) picks HOW an
    #     OT batch is solved without changing what comes back — every
    #     solver certifies the same additive-eps target through the same
    #     Solution surface (additive_gap() <= additive_gap_bound(),
    #     dual_feasible()).
    #       "pushrelabel"  the paper's solver (default, exact phases)
    #       "sinkhorn"     log-domain entropic solver at the AWR schedule
    #                      (reg = eps/(4 ln n), marginal tol = eps/8),
    #                      rounded onto the transport polytope with
    #                      feasible duals in the epilogue
    #       "hybrid"       coarse Sinkhorn first, its duals rounded into
    #                      a feasible push-relabel start (all paper
    #                      invariants hold), push-relabel finishes — so
    #                      the guarantee is push-relabel's own
    #       "auto"         the measured cost model picks per batch
    from repro.portfolio import get_model

    batch16 = {"c": cb, "nu": nub, "mu": mub}
    for solver in ("pushrelabel", "sinkhorn", "hybrid"):
        pol16 = DispatchPolicy(mode="compact", solver=solver,
                               guaranteed=True)
        sols = solve(OT, batch16, 0.1, pol16,
                     want=("cost", "duals", "stats"))
        s0 = sols[0]
        assert bool(s0.dual_feasible())
        assert float(s0.additive_gap()) <= float(s0.additive_gap_bound())
        print(f"portfolio[{solver}]: cost={float(s0.cost):.4f} "
              f"gap={float(s0.additive_gap()):.5f} "
              f"<= bound={float(s0.additive_gap_bound()):.5f} "
              f"(certified, solve {sols.stats.actual_s * 1e3:.0f} ms)")

    #     solver="auto" consults the measured cost model committed at
    #     src/repro/portfolio/costmodel_default.json (per-instance
    #     seconds per (solver, n-bucket, eps-band), honest mode=
    #     interpret labels off-TPU). Refit it for YOUR hardware with
    #         PYTHONPATH=src python -m benchmarks.bench_portfolio \
    #             --calibrate --json mymodel.json
    #     then repro.portfolio.set_model(CostModel.load("mymodel.json")).
    #     The chosen solver and predicted-vs-actual seconds land in
    #     stats and in the "solver-choice" obs event.
    pol_auto = DispatchPolicy(mode="compact", solver="auto")
    sols_a = solve(OT, batch16, 0.1, pol_auto, want=("cost", "stats"))
    model = get_model()
    print(f"portfolio[auto]: model={'loaded' if model else 'none'} "
          f"chose {sols_a.stats.solver!r} "
          f"(predicted {sols_a.stats.predicted_s} s, "
          f"actual {sols_a.stats.actual_s:.3f} s)")


if __name__ == "__main__":
    main()
