"""Paper Figure-2 style experiment: L1 optimal matching between batches of
(procedurally generated) MNIST-like images, push-relabel vs Sinkhorn across
eps, with the numerical-stability failure mode of kernel-space Sinkhorn.

    PYTHONPATH=src python examples/mnist_matching.py [--n 256]
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import mnist_like_images
from repro.core import build_cost_matrix, solve_assignment, sinkhorn
from repro.core.sinkhorn import reg_for_additive_eps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    args = ap.parse_args()
    n = args.n

    a = mnist_like_images(n, seed=0)
    b = mnist_like_images(n, seed=1)
    c = build_cost_matrix(jnp.asarray(a), jnp.asarray(b), "l1")
    print(f"n={n} images; max L1 cost={float(jnp.max(c)):.3f} (paper: <= 2)")
    nu = jnp.full((n,), 1.0 / n)

    for eps in [0.75, 0.5, 0.25, 0.1]:
        t0 = time.perf_counter()
        r = solve_assignment(c, eps)
        t_pr = time.perf_counter() - t0
        reg = reg_for_additive_eps(eps, n)
        t0 = time.perf_counter()
        s = sinkhorn(c, nu, nu, reg=reg, tol=eps / 8, max_iters=2000)
        t_sk = time.perf_counter() - t0
        # kernel-space variant underflow check (paper Section 5 observation)
        k = np.exp(-np.asarray(c) / reg)
        dead = int((k.sum(1) == 0).sum())
        print(f"eps={eps:4}: pushrelabel {t_pr*1e3:8.1f} ms "
              f"(cost/n {float(r.cost)/n:.4f}, {int(r.phases)} phases) | "
              f"sinkhorn {t_sk*1e3:8.1f} ms ({int(s.iters)} iters, "
              f"{dead} rows underflow in exp(-C/reg))")


if __name__ == "__main__":
    main()
