"""End-to-end serving driver: bring up the engine on a small model, submit a
batch of requests, decode with KV caches, and report latency/throughput.
Also exercises the OT-distance service endpoint.

    PYTHONPATH=src python examples/serve_batched.py [--arch qwen3-4b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, reduced
from repro.models import model as M
from repro.serve.engine import Engine, OTService, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    print(f"serving {cfg.name} ({cfg.family}), vocab={cfg.vocab_size}")
    params = M.init_params(cfg, jax.random.key(0))
    engine = Engine(cfg, params, max_len=128)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        plen = int(rng.integers(8, 24))
        engine.submit(Request(
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.new_tokens,
        ))
    t0 = time.perf_counter()
    outs = engine.run_batch()
    dt = time.perf_counter() - t0
    total_new = sum(len(o.tokens) for o in outs)
    print(f"batch of {len(outs)} served in {dt*1e3:.0f} ms "
          f"({total_new / dt:.1f} tok/s aggregate)")
    for i, o in enumerate(outs):
        print(f"  req{i}: prefill={o.prefill_len} "
              f"completion={o.tokens[:8]}...")

    # OT endpoint, same submit/run_batch shape as the token engine: mixed-
    # size distance requests are bucketed and each bucket dispatched as one
    # XLA program through the batched solver subsystem.
    svc = OTService(eps=0.1)
    for _ in range(args.requests):
        m = int(rng.integers(40, 160))
        svc.submit(rng.uniform(size=(m, 2)).astype(np.float32),
                   rng.uniform(size=(m, 2)).astype(np.float32))
    t0 = time.perf_counter()
    res = svc.run_batch()
    dt = time.perf_counter() - t0
    print(f"OT batch of {len(res)} served in {dt*1e3:.0f} ms "
          f"({len(res) / dt:.1f} inst/s)")
    for i, r in enumerate(res):
        print(f"  ot{i}: cost={r['cost']:.4f} bucket={r['bucket']} "
              f"batch_size={r['batch_size']} phases={r['phases']}")

    # one-shot convenience path is unchanged
    x = rng.uniform(size=(128, 2)).astype(np.float32)
    y = rng.uniform(size=(128, 2)).astype(np.float32)
    t0 = time.perf_counter()
    res1 = svc.distance(x, y)
    print(f"OT service: distance={res1['cost']:.4f} "
          f"(dual lb={res1['dual_lower_bound']:.4f}) "
          f"in {(time.perf_counter()-t0)*1e3:.0f} ms, "
          f"{res1['phases']} phases")


if __name__ == "__main__":
    main()
