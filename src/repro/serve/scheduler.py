"""Async multi-tenant front end for the OT service: queues -> shape
buckets -> mesh dispatch, with host-side batch preparation overlapping
in-flight device work.

``OTService`` (serve/engine.py) is synchronous: callers submit, then one
``run_batch()`` call blocks while it buckets, pads, builds cost matrices,
and solves. ``AsyncOTScheduler`` splits that into a two-stage pipeline:

  submit(x, y[, nu, mu][, eps]) -> Future     (any thread, any tenant)
      |
  [collate worker]  drains the request queue (draining whatever is queued,
      up to ``max_batch``, after an optional ``linger_ms`` batching
      window), groups by (point-dim, solver mode) and shape bucket, pads,
      and computes the batched cost matrices
      |
  [dispatch worker] feeds prepared buckets to the mesh through the
      unified front door (``core/api.solve`` under a mesh-mode
      DispatchPolicy -> the distributed compacting driver,
      core/distributed.py) and resolves the per-request Futures

with a bounded handoff queue between the stages: while the dispatch
worker is blocked inside a solve (device work + the driver's per-chunk
converged-mask syncs), the collate worker is already padding/bucketing
the NEXT batch — host-side compaction/bucketing overlaps with in-flight
device dispatches. (The overlap is thread-level: numpy padding and jax
dispatch release the GIL while device work runs.)

Each resolved Future carries the same result dict as
``OTService.run_batch`` plus scheduling stats: ``wait_s`` (submit ->
dispatch start), ``solve_s`` (bucket solve wall time), ``devices``,
``dispatches``, ``occupancy`` (the compaction curve of its bucket), and
``batch_size``/``bucket``. Per-request ``eps`` is supported (eps is data
to the compacting driver — mixed-accuracy tenants share one dispatch).

Per-request ``want=`` (or the scheduler-level default) switches a tenant
onto the typed Solution surface (core/solution.py): its Future resolves
to a :class:`~repro.core.solution.Solution` view and each bucket
dispatch declares only the UNION of its tenants' artifacts — a batch of
cost-only tenants fetches O(B) scalars from the mesh, never the dense
(B, M, N) plans.

Results are identical to the synchronous service regardless of how
requests happen to be batched: the distributed driver's per-lane results
are composition-invariant (retiring or re-sharding a neighbor never
perturbs a survivor — the property tests in tests/test_compaction.py).
That composition invariance is also what makes the FAULT-TOLERANCE layer
sound: quarantining a poisoned lane (admission gate or checkify-triggered
bisection) or retrying the survivors on a lower ladder rung returns the
healthy requests bit-identical results to a clean run.

Fault tolerance (serve/ft.py, serve/faults.py):

  * every collated bucket passes the vectorized admission gate
    (core/validate.py); poisoned lanes fail their own Future with
    ``RequestRejected`` while the rest of the bucket dispatches;
  * a dispatch that trips the checkify sanitizer (or any other
    data-dependent poison) is BISECTED: contiguous halves re-dispatch
    until the offending request(s) are isolated and quarantined;
  * transient dispatch failures (device OOM, collective errors) retry
    with exponential backoff down the degradation ladder ``mesh ->
    compact single-device -> host CPU`` (recorded on ``SolveStats``:
    attempts / ladder_level / quarantined);
  * ``submit(..., deadline=)`` gives a request a wall-clock budget; its
    bucket stops dispatching k-phase chunks when the earliest budget is
    at risk and resolves best-so-far ``Solution``s flagged
    ``degraded=True`` — re-validated per request by their a-posteriori
    certificates (``dual_feasible()`` / ``additive_gap()``).

Observability (repro.obs): every scheduler carries a
:class:`~repro.obs.MetricsRegistry`; pass ``sinks=[JSONLSink(...)]`` (or
any :class:`~repro.obs.MetricsSink`) to stream counters, wait/solve
histograms, and structured events live. ``stats``/``stats_dict()`` are
VIEWS over that registry — there is no parallel hand-maintained tally.
Each request gets a root ``"request"`` span (trace id ``req-<seq>``)
from submit to resolution; each collated bucket gets its own trace
(``bucket-<n>``) with ``collate`` -> ``admission`` -> ``dispatch`` ->
``solve`` (one per ladder attempt) -> ``artifact-fetch`` spans, per-chunk
``"chunk"`` events from the drivers parented under the solve span, and
fault events (``rejected``, ``retry``, ``ladder``, ``quarantine``,
``deadline-cut``, ``degraded``). All timestamps share the one monotonic
clock ``repro.obs.now``. The opt-in ``repro.obs.profiler`` hook captures
a ``jax.profiler`` trace around a named dispatch when armed.
"""
from __future__ import annotations

import contextlib
import dataclasses
import queue
import threading
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..obs import MetricsRegistry, Tracer, new_id, profiler as _profiler
from ..obs import now as _now
from . import ft as _ft


def _fulfil(fut: Future, result) -> bool:
    """set_result tolerating caller-side cancellation: a tenant cancelling
    its Future must not poison the rest of the batch."""
    try:
        fut.set_result(result)
        return True
    except Exception:          # cancelled / already resolved
        return False


def _fail(fut: Future, exc: BaseException) -> bool:
    try:
        if not fut.done():
            fut.set_exception(exc)
            return True
    except Exception:
        pass
    return False


@dataclass
class _Pending:
    x: np.ndarray
    y: np.ndarray
    nu: Optional[np.ndarray]
    mu: Optional[np.ndarray]
    eps: float
    future: Future
    t_submit: float
    want: Optional[tuple] = None    # None -> legacy result dict
    deadline: Optional[float] = None  # absolute repro.obs.now() budget
    tenant: Optional[str] = None
    seq: int = -1                   # submit ordinal (fault plans key on it)
    span: Any = None                # root "request" span (submit->resolve)


def _who(req: _Pending) -> str:
    """Name a request for exception messages: its tenant if it gave one,
    its submit ordinal otherwise."""
    return (f"tenant {req.tenant!r}" if req.tenant is not None
            else f"request #{req.seq}")


@dataclass
class _WorkItem:
    has_mass: bool
    c: Any                      # (B, M, N) batched cost matrix (device)
    nu: Any                     # (B, M) or None
    mu: Any                     # (B, N) or None
    sizes: np.ndarray           # (B, 2)
    eps: np.ndarray             # (B,) per-request eps
    reqs: List[_Pending]
    bucket: tuple
    t_prepared: float
    # mutable accounting shared across bisection halves of one original
    # bucket (the dispatch worker processes halves sequentially, so no
    # lock is needed): requests quarantined from the bucket so far
    shared: dict = field(default_factory=dict)
    tid: str = ""                   # bucket trace id ("bucket-<n>")


def _split_item(item: _WorkItem):
    """Bisect a work item into contiguous halves (shared accounting dict
    rides along). Lane-sliced operands keep per-lane results bit-identical
    — batched solves are composition-invariant."""
    h = len(item.reqs) // 2

    def sub(lo: int, hi: int) -> _WorkItem:
        sel = np.arange(lo, hi)
        return _WorkItem(
            has_mass=item.has_mass, c=item.c[sel],
            nu=None if item.nu is None else item.nu[sel],
            mu=None if item.mu is None else item.mu[sel],
            sizes=item.sizes[sel], eps=item.eps[sel],
            reqs=item.reqs[lo:hi], bucket=item.bucket,
            t_prepared=item.t_prepared, shared=item.shared, tid=item.tid)

    return sub(0, h), sub(h, len(item.reqs))


@dataclass
class SchedulerStats:
    """Point-in-time SNAPSHOT of the scheduler's metrics registry.

    Since the observability refactor this is no longer a mutable tally
    the workers write into: ``AsyncOTScheduler.stats`` builds one from
    the lock-free registry instruments on every read
    (:meth:`from_registry`), so there is exactly one source of truth and
    ``stats``/``stats_dict()``/attached sinks can never drift apart.

    ``occupancy`` keeps only the most recent ``occupancy_window`` curves
    (bounded: a long-lived scheduler must not grow a list forever)."""
    requests: int = 0
    batches: int = 0
    total_wait_s: float = 0.0
    total_solve_s: float = 0.0
    dispatches: int = 0
    occupancy: "deque" = field(
        default_factory=lambda: deque(maxlen=64))
    # fault-tolerance accounting
    rejected: int = 0        # failed the admission gate (pre-dispatch)
    quarantined: int = 0     # isolated by dispatch-time bisection
    retries: int = 0         # extra dispatch attempts (ladder/backoff)
    degraded: int = 0        # requests resolved best-so-far on deadline
    deadline_hits: int = 0   # buckets cut by a wall-clock budget
    occupancy_window: int = 64   # the bound on len(occupancy)

    #: registry instrument names backing each counter field
    _COUNTERS = ("requests", "batches", "dispatches", "rejected",
                 "quarantined", "retries", "degraded", "deadline_hits")

    @classmethod
    def from_registry(cls, reg, window: int = 64) -> "SchedulerStats":
        snap = reg.snapshot()
        kw = {f: int(snap.get(f"scheduler.{f}", 0)) for f in cls._COUNTERS}
        wait = snap.get("scheduler.wait_s") or {}
        solve = snap.get("scheduler.solve_s") or {}
        return cls(
            total_wait_s=float(wait.get("sum", 0.0)),
            total_solve_s=float(solve.get("sum", 0.0)),
            occupancy=deque(snap.get("scheduler.occupancy", ()),
                            maxlen=window),
            occupancy_window=int(window),
            **kw,
        )

    def as_dict(self) -> dict:
        """Every field of the dataclass, JSON-serializably (the
        stats-surface drift test holds this to completeness).
        ``occupancy`` is TRUNCATED to the most recent
        ``occupancy_window`` bucket curves (the constructor knob on
        ``AsyncOTScheduler``) — older curves are dropped, not summarized;
        ``occupancy_window`` is included so consumers can tell a short
        history from a truncated one."""
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_wait_s": (self.total_wait_s / self.requests
                            if self.requests else 0.0),
            "total_wait_s": self.total_wait_s,
            "total_solve_s": self.total_solve_s,
            "dispatches": self.dispatches,
            "occupancy": [[list(p) for p in curve]
                          for curve in self.occupancy],
            "occupancy_window": self.occupancy_window,
            "rejected": self.rejected,
            "quarantined": self.quarantined,
            "retries": self.retries,
            "degraded": self.degraded,
            "deadline_hits": self.deadline_hits,
        }


class AsyncOTScheduler:
    """Asynchronous bucket scheduler over the distributed OT solvers.

    Args:
      eps: default additive error (per-request override via ``submit``).
      metric: point-cloud cost metric.
      mesh: 1-D batch mesh (``launch.mesh.make_batch_mesh()`` when None);
        on a single-device host this degrades gracefully to the plain
        compacting driver.
      buckets: shape-bucket boundaries (core/batched.py defaults).
      chunk: k, phases per dispatch of the compacting driver.
      max_batch: max requests drained into one collate round.
      linger_ms: optional batching window — after the first request of a
        round arrives, keep draining for this long so co-tenant requests
        share a dispatch. 0 dispatches whatever is instantaneously queued.
      placement: "auto" | "batch" | "matrix" (core/distributed.py policy).
      validate: run the vectorized admission gate on every collated
        bucket; poisoned lanes fail their own Future with
        ``RequestRejected``, the rest dispatch.
      admission_tol: relative mass-imbalance tolerance of the gate.
      faults: optional :class:`~repro.serve.faults.FaultInjector` (chaos
        harness; tests only).
      retries_per_level / retry_backoff_s: transient-failure retry policy
        per degradation-ladder rung.
      join_timeout_s: how long close() waits for each worker to exit
        before declaring it hung, failing pending Futures, and raising.
      policy: override the dispatch policy wholesale (e.g. a compact-mode
        policy so the checkify sanitizer path is exercised); default is
        the mesh-mode policy built from ``mesh``/``placement``/``chunk``.
      sinks: metrics sinks (:class:`~repro.obs.MetricsSink`) to stream
        counters/histograms/spans/events to, live. Empty (the default)
        costs one tuple check per observation — the measured no-sink
        overhead budget in benchmarks/bench_serve.py is <2% of the
        healthy path.
      occupancy_window: how many recent per-bucket occupancy curves the
        ``stats`` view retains (the ``SchedulerStats.occupancy`` bound,
        historically hardcoded to 64). ``stats_dict()`` reports the
        window alongside the truncated history.
    """

    def __init__(self, eps: float = 0.05, metric: str = "euclidean",
                 mesh=None, buckets=None, chunk: Optional[int] = None,
                 max_batch: int = 256, linger_ms: float = 0.0,
                 use_pallas: bool = True, placement: str = "auto",
                 want: Optional[tuple] = None, validate: bool = True,
                 admission_tol: Optional[float] = None, faults=None,
                 retries_per_level: int = 2, retry_backoff_s: float = 0.05,
                 join_timeout_s: float = 30.0,
                 policy=None, sinks=(), occupancy_window: int = 64,
                 solver: str = "pushrelabel"):
        from repro.core import batched as B
        from repro.core import compaction as C
        from repro.core import validate as V
        from repro.core.api import DispatchPolicy
        from repro.core.costs import COSTS

        if mesh is None:
            from repro.launch.mesh import make_batch_mesh

            mesh = make_batch_mesh()
        self.eps = float(eps)
        self.metric = metric
        self.mesh = mesh
        self.buckets = tuple(buckets) if buckets else B.DEFAULT_BUCKETS
        self.chunk = C.DEFAULT_CHUNK if chunk is None else int(chunk)
        # every bucket dispatch goes through the unified core/api.solve
        # front door under this one policy
        # ``solver`` routes OT buckets through the solver portfolio
        # (ignored when an explicit ``policy`` object is passed)
        self._policy = policy if policy is not None else DispatchPolicy(
            mode="mesh", mesh=mesh,
            placement=placement, chunk=self.chunk,
            buckets=self.buckets, solver=solver)
        self.validate = bool(validate)
        self.admission_tol = (V.DEFAULT_TOL if admission_tol is None
                              else float(admission_tol))
        self._faults = faults
        self._retries_per_level = int(retries_per_level)
        self._retry_backoff_s = float(retry_backoff_s)
        self._join_timeout_s = float(join_timeout_s)
        # transient dispatch failures walk this ladder (mesh -> compact
        # single-device -> host CPU), never re-raising past the last rung
        # until every retry is spent
        self._ladder = _ft.degradation_ladder(self._policy)
        self.max_batch = int(max_batch)
        self.linger_s = float(linger_ms) / 1e3
        self.placement = placement
        # default artifact declaration for submits that don't pass their
        # own ``want``; None -> legacy result dicts
        self.want = None if want is None else tuple(want)
        self.kernel = ("pallas" if use_pallas
                       and jax.default_backend() == "tpu" else "jnp")
        self._B = B
        self._cost_batched = jax.jit(jax.vmap(COSTS[metric]))
        # ONE metrics registry: stats/stats_dict() are views over it and
        # attached sinks stream the same observations — no parallel tally
        self.metrics = MetricsRegistry(sinks=sinks)
        self._tracer = Tracer(self.metrics)
        self.occupancy_window = int(occupancy_window)
        reg = self.metrics
        self._c_requests = reg.counter("scheduler.requests")
        self._c_batches = reg.counter("scheduler.batches")
        self._c_dispatches = reg.counter("scheduler.dispatches")
        self._c_rejected = reg.counter("scheduler.rejected")
        self._c_quarantined = reg.counter("scheduler.quarantined")
        self._c_retries = reg.counter("scheduler.retries")
        self._c_degraded = reg.counter("scheduler.degraded")
        self._c_deadline_hits = reg.counter("scheduler.deadline_hits")
        self._h_wait = reg.histogram("scheduler.wait_s",
                                     MetricsRegistry.LATENCY_BOUNDS)
        self._h_solve = reg.histogram("scheduler.solve_s",
                                      MetricsRegistry.LATENCY_BOUNDS)
        self._occ = reg.history("scheduler.occupancy",
                                maxlen=self.occupancy_window)

        self._submit_seq = 0          # next submit ordinal (under _lock)
        self._submit_q: "queue.Queue" = queue.Queue()
        # bounded handoff: collate may run at most this many batches ahead
        # of the dispatcher (backpressure, and the overlap window)
        self._work_q: "queue.Queue" = queue.Queue(maxsize=2)
        self._outstanding = 0
        # every un-resolved Future, so shutdown can always account for
        # in-flight work even if a worker dies mid-batch (futures are
        # resolved or failed, never silently stranded)
        self._pending: set = set()
        self._lock = threading.Condition()
        self._closed = False          # no new submits (close() or abort)
        self._close_called = False    # close() ran (joins done once)
        self._collate_t = threading.Thread(target=self._collate_loop,
                                           name="ot-collate", daemon=True)
        self._dispatch_t = threading.Thread(target=self._dispatch_loop,
                                            name="ot-dispatch", daemon=True)
        self._collate_t.start()
        self._dispatch_t.start()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, x, y, nu=None, mu=None,
               eps: Optional[float] = None,
               want: Optional[tuple] = None,
               deadline: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        """Queue one distance request; returns a Future. (nu, mu) both
        present -> general OT; both absent -> assignment distance.

        ``want`` (per-request, defaulting to the scheduler-level setting)
        declares the artifacts this tenant will read: the Future then
        resolves to a typed :class:`~repro.core.solution.Solution`
        instead of the legacy dict, and only the batch's UNION of
        declared artifacts is ever fetched from device — a bucket of
        cost-only tenants moves O(B) scalars, no dense plans. With
        ``want=None`` the Future resolves to the historical result dict
        (bit-identical adapter).

        ``deadline`` is a RELATIVE wall-clock budget in seconds. The
        request's bucket stops dispatching solver chunks when the
        earliest co-batched budget is at risk; any request still
        unconverged resolves best-so-far with ``degraded=True`` and an
        honestly larger ``additive_gap()`` (duals stay eps-feasible at
        every phase, so the certificate remains valid). ``tenant`` is an
        optional label used in rejection/validation messages."""
        with self._lock:
            who = (f"tenant {tenant!r}" if tenant is not None
                   else f"request #{self._submit_seq}")
        has_mass = _ft.require_mass_pair(nu, mu, who=who)
        fut: Future = Future()
        # closed-check, ordinal reservation, and outstanding-increment
        # share the lock close() takes to flip _closed, so a submit can
        # never slip in after the shutdown sentinel and strand its Future
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            seq = self._submit_seq
            self._submit_seq += 1
            self._outstanding += 1
            self._pending.add(fut)
        # the injector hook runs only after the reservation succeeded, so
        # its submit ordinals stay aligned with ours
        if self._faults is not None:
            x, _ = self._faults.on_submit(np.asarray(x))
        # one monotonic clock (repro.obs.now) for the submit timestamp,
        # the absolute deadline, and every span: the drivers compare the
        # deadline against the same clock inside the chunk loop
        root = self._tracer.start("request", trace_id=f"req-{seq}",
                                  seq=seq, tenant=tenant)
        req = _Pending(x=np.asarray(x), y=np.asarray(y),
                       nu=None if not has_mass else np.asarray(nu),
                       mu=None if not has_mass else np.asarray(mu),
                       eps=self.eps if eps is None else float(eps),
                       future=fut, t_submit=root.t_start,
                       want=(self.want if want is None else tuple(want)),
                       deadline=(None if deadline is None
                                 else root.t_start + float(deadline)),
                       tenant=tenant, seq=seq, span=root)
        self._tracer.event("submit", trace_id=f"req-{seq}",
                           parent_id=root.span_id, seq=seq, tenant=tenant)
        self._submit_q.put(req)
        return fut

    def _workers_alive(self) -> bool:
        return self._collate_t.is_alive() and self._dispatch_t.is_alive()

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has resolved (normally,
        exceptionally, or — if a worker thread died — by having its Future
        failed here rather than stranded). Returns False on timeout."""
        deadline = None if timeout is None else _now() + timeout
        with self._lock:
            while self._outstanding > 0:
                if not self._workers_alive():
                    break               # fall through to the abort path
                remaining = (None if deadline is None
                             else deadline - _now())
                if remaining is not None and remaining <= 0:
                    return False
                # wake periodically to re-check worker liveness
                self._lock.wait(timeout=0.2 if remaining is None
                                else min(0.2, remaining))
            # read the verdict while still holding the lock — a bare
            # re-read outside it races _done()/_abort_pending (the
            # lock-discipline scan in repro.analysis flags that pattern)
            stranded = self._outstanding > 0
        if stranded:
            self._abort_pending(RuntimeError(
                "scheduler worker thread died; request abandoned"))
        return True

    def _abort_pending(self, exc: BaseException):
        """Resolve every still-pending Future with ``exc`` (last-resort
        shutdown path: a worker died or close() found undrained work).
        Queued work items are discarded."""
        with self._lock:
            # the pipeline is broken (a worker died or close() found
            # stragglers): refuse further submits — an accepted request
            # with no live worker would strand its Future
            self._closed = True
        for q in (self._submit_q, self._work_q):
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            # re-seed the shutdown sentinel: draining may have swallowed
            # one a still-live worker was waiting for, and a broken
            # pipeline (one worker dead) should wind the other down too
            try:
                q.put_nowait(None)
            except queue.Full:
                pass
        with self._lock:
            for fut in list(self._pending):
                _fail(fut, exc)
            self._pending.clear()
            self._outstanding = 0
            self._lock.notify_all()

    def close(self):
        """Stop accepting work, drain what was submitted, stop workers.
        Every accepted Future is resolved (or failed) before this returns
        — shutdown never strands a pending Future, even racing in-flight
        collate/dispatch work or a dead worker thread. If a worker is
        still ALIVE after ``join_timeout_s`` (hung, not dead), pending
        Futures are failed and a ``RuntimeError`` naming the hung
        worker(s) is raised — silently returning with live threads would
        leak them and whatever device state they hold."""
        with self._lock:
            if self._close_called:
                return
            self._close_called = True
            self._closed = True          # no new submits past this point
        # bounded: a hung worker must not wedge close() before it even
        # reaches the joins (the timeout only fires when a worker exceeds
        # it — a draining pipeline returns as soon as it's empty)
        self.flush(timeout=self._join_timeout_s)
        self._submit_q.put(None)          # collate sentinel
        self._collate_t.join(timeout=self._join_timeout_s)
        self._dispatch_t.join(timeout=self._join_timeout_s)
        hung = [t.name for t in (self._collate_t, self._dispatch_t)
                if t.is_alive()]
        with self._lock:
            stranded = bool(self._pending)
        if stranded or hung:
            # a worker hung past the join timeout (or died with futures
            # unaccounted): fail everything still pending, loudly
            self._abort_pending(RuntimeError(
                "scheduler closed with hung worker(s): "
                f"{', '.join(hung)}" if hung
                else "scheduler closed"))
        if hung:
            raise RuntimeError(
                f"scheduler worker(s) {', '.join(hung)} still alive "
                f"after join(timeout={self._join_timeout_s}); pending "
                "futures were failed")

    @property
    def stats(self) -> SchedulerStats:
        """A point-in-time :class:`SchedulerStats` snapshot built from
        the metrics registry. Reading it while the workers run is always
        safe (each instrument aggregates its lock-free cells); after
        ``flush()`` it is exact."""
        return SchedulerStats.from_registry(self.metrics,
                                            window=self.occupancy_window)

    def stats_dict(self) -> dict:
        """Serializable snapshot of the aggregate stats — a VIEW over the
        same metrics registry the sinks stream from, not a parallel
        tally. ``occupancy`` holds only the most recent
        ``occupancy_window`` bucket curves (older history is truncated;
        the window rides along under ``"occupancy_window"``). Safe from
        any thread; each value is exact, though distinct counters read
        while the workers are mid-bucket may straddle an update."""
        return self.stats.as_dict()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # workers
    # ------------------------------------------------------------------

    def _drain(self) -> Optional[List[_Pending]]:
        """Block for the first request, then drain whatever else is queued
        (up to max_batch, within the linger window). None on shutdown."""
        first = self._submit_q.get()
        if first is None:
            return None
        batch = [first]
        deadline = _now() + self.linger_s
        while len(batch) < self.max_batch:
            timeout = deadline - _now()
            try:
                nxt = (self._submit_q.get_nowait() if timeout <= 0
                       else self._submit_q.get(timeout=timeout))
            except queue.Empty:
                break
            if nxt is None:               # propagate shutdown after batch
                self._submit_q.put(None)
                break
            batch.append(nxt)
        return batch

    def _batched_cost(self, xs, ys):
        if self.kernel == "pallas":
            from repro.kernels import ops

            return ops.cost_matrix_batched(xs, ys, metric=self.metric)
        return self._cost_batched(xs, ys)

    def _handoff(self, item) -> None:
        """Backpressure put that cannot block forever: if the dispatch
        worker died, the queue never drains — raise so the batch's
        futures are failed instead of wedging the collate thread."""
        while True:
            try:
                self._work_q.put(item, timeout=1.0)
                return
            except queue.Full:
                if not self._dispatch_t.is_alive():
                    raise RuntimeError("dispatch worker died; work "
                                       "item abandoned") from None

    def _collate_loop(self):
        B = self._B
        while True:
            batch = self._drain()
            if batch is None:
                try:
                    self._handoff(None)     # dispatch shutdown sentinel
                except RuntimeError:
                    pass                    # dispatcher already gone
                return
            packaged: set = set()
            try:
                modes: Dict[tuple, List[_Pending]] = {}
                for r in batch:
                    key = (r.x.shape[1], r.nu is not None)
                    modes.setdefault(key, []).append(r)
                for (dim, has_mass), sub in sorted(modes.items()):
                    shapes = [(r.x.shape[0], r.y.shape[0]) for r in sub]
                    for grp in B.bucket_instances(shapes, self.buckets):
                        reqs = [sub[j] for j in grp.indices]
                        tid = new_id("bucket")
                        csp = self._tracer.start(
                            "collate", trace_id=tid, bucket=list(grp.key),
                            batch=len(reqs),
                            seqs=[r.seq for r in reqs])
                        (mb, nb) = grp.key
                        xs = B.pad_stack([r.x for r in reqs], (mb, dim))
                        ys = B.pad_stack([r.y for r in reqs], (nb, dim))
                        c = self._batched_cost(xs, ys)
                        nu = mu = None
                        if has_mass:
                            nu = B.pad_stack([r.nu for r in reqs], (mb,))
                            mu = B.pad_stack([r.mu for r in reqs], (nb,))
                        sizes = grp.sizes
                        quarantined = 0
                        if self.validate:
                            from repro.core.validate import (
                                RequestRejected, admission_codes)

                            ins = ({"c": c, "nu": nu, "mu": mu}
                                   if has_mass else {"c": c})
                            asp = self._tracer.start(
                                "admission", trace_id=tid,
                                parent=csp.span_id, batch=len(reqs))
                            codes = admission_codes(
                                ins, sizes=sizes, tol=self.admission_tol)
                            bad = np.flatnonzero(codes != 0)
                            asp.end(rejected=int(bad.size))
                            if bad.size:
                                # poisoned lanes fail their own Future;
                                # the healthy rest of the bucket proceeds
                                rejected = [reqs[j] for j in bad]
                                for j in bad:
                                    _fail(reqs[j].future, RequestRejected(
                                        _who(reqs[j]), int(codes[j])))
                                    self._tracer.event(
                                        "rejected", trace_id=tid,
                                        seq=reqs[j].seq,
                                        code=int(codes[j]))
                                    if reqs[j].span is not None:
                                        reqs[j].span.end(
                                            outcome="rejected",
                                            code=int(codes[j]))
                                self._done(rejected)
                                self._c_rejected.add(int(bad.size))
                                packaged.update(id(r) for r in rejected)
                                keep = np.flatnonzero(codes == 0)
                                if keep.size == 0:
                                    csp.end(kept=0)
                                    continue
                                c = c[keep]
                                if has_mass:
                                    nu, mu = nu[keep], mu[keep]
                                sizes = sizes[keep]
                                reqs = [reqs[j] for j in keep]
                                quarantined = int(bad.size)
                        csp.end(kept=len(reqs))
                        item = _WorkItem(
                            has_mass=has_mass, c=c, nu=nu, mu=mu,
                            sizes=sizes,
                            eps=np.asarray([r.eps for r in reqs]),
                            reqs=reqs, bucket=grp.key,
                            t_prepared=_now(),
                            shared={"quarantined": quarantined},
                            tid=tid,
                        )
                        self._handoff(item)      # blocks: backpressure
                        packaged.update(id(r) for r in reqs)
            except Exception as e:
                # fail only the requests that never made it into a work
                # item; packaged ones are resolved by the dispatcher
                missed = [r for r in batch if id(r) not in packaged]
                for r in missed:
                    _fail(r.future, e)
                    if r.span is not None:
                        r.span.end(outcome="error",
                                   error=type(e).__name__)
                self._done(missed)

    @staticmethod
    def _union_want(item) -> tuple:
        """The batch-level artifact declaration: the union of every
        co-batched tenant's ``want`` (legacy-dict tenants need the full
        legacy artifact set). Only this union is ever fetchable — a
        bucket of cost-only tenants never ships a dense plan."""
        legacy = (("cost", "plan") if item.has_mass
                  else ("cost", "matching", "duals"))
        union: set = set()
        for r in item.reqs:
            union |= set(legacy if r.want is None else r.want)
        return tuple(sorted(union))

    def _dispatch_loop(self):
        while True:
            item = self._work_q.get()
            if item is None:
                return
            self._dispatch_item(item)

    def _solve_with_ladder(self, item, dspan=None):
        """One bucket solve through the unified front door, with
        transient failures retrying down the degradation ladder. Returns
        ``(SolutionBatch, ladder_level, total_attempts)``; poison and
        programming errors propagate to the caller's bisection/quarantine
        logic untouched.

        Each attempt runs under its own ``"solve"`` span (named with the
        ladder rung) parented under ``dspan``, with the chunked drivers'
        per-chunk events parented under the attempt's span; the opt-in
        profiler hook (repro.obs.profiler) can capture one
        ``jax.profiler`` trace around a named dispatch."""
        from repro.core.api import ASSIGNMENT, OT, solve

        if item.has_mass:
            spec = OT
            inputs = {"c": item.c, "nu": item.nu, "mu": item.mu}
        else:
            spec = ASSIGNMENT
            inputs = {"c": item.c}
        want = self._union_want(item)
        budgets = [r.deadline for r in item.reqs if r.deadline is not None]
        deadline = min(budgets) if budgets else None
        seqs = tuple(r.seq for r in item.reqs)
        parent = None if dspan is None else dspan.span_id

        tried = [0]

        def attempt(name, pol, dev):
            tried[0] += 1
            if self._faults is not None:
                self._faults.on_dispatch(seqs)
            ctx = (jax.default_device(dev) if dev is not None
                   else contextlib.nullcontext())
            cap = f"dispatch:{item.bucket[0]}x{item.bucket[1]}:{name}"
            with self._tracer.span("solve", trace_id=item.tid,
                                   parent=parent, level=name,
                                   attempt=tried[0]) as sp, \
                    _profiler.capture(cap), ctx:
                return solve(spec, inputs, item.eps, pol,
                             sizes=item.sizes, want=want,
                             deadline=deadline,
                             obs=self._tracer.bind(trace_id=item.tid,
                                                   parent=sp.span_id))

        try:
            return _ft.run_with_recovery(
                attempt, self._ladder,
                retries_per_level=self._retries_per_level,
                backoff_s=self._retry_backoff_s)
        finally:
            # count retries even when the run ends in a poison raise —
            # the transient retries before it still happened
            if tried[0] > 1:
                self._c_retries.add(tried[0] - 1)
                self._tracer.event("retry", trace_id=item.tid,
                                   n=tried[0] - 1)

    def _dispatch_item(self, item):
        """Solve one work item and resolve its Futures; on data-dependent
        poison (checkify NaN trip, injected poisoned dispatch) BISECT into
        contiguous halves until the offender(s) are isolated and
        quarantined — composition invariance guarantees the survivors'
        results are bit-identical to a clean run."""
        t0 = _now()
        dspan = self._tracer.start("dispatch", trace_id=item.tid,
                                   bucket=list(item.bucket),
                                   batch=len(item.reqs))
        try:
            batch, level, attempts = self._solve_with_ladder(item, dspan)
        except Exception as e:
            if _ft.is_poison(e) and len(item.reqs) > 1:
                dspan.end(outcome="poison-bisect",
                          error=type(e).__name__)
                left, right = _split_item(item)
                self._dispatch_item(left)
                self._dispatch_item(right)
                return
            if _ft.is_poison(e):
                # singleton: this IS the offender — quarantine it
                req = item.reqs[0]
                item.shared["quarantined"] = (
                    item.shared.get("quarantined", 0) + 1)
                self._c_quarantined.add(1)
                self._tracer.event("quarantine", trace_id=item.tid,
                                   seq=req.seq)
                dspan.end(outcome="quarantined")
                _fail(req.future, _ft.RequestRejected(
                    _who(req), 0,
                    reason=("dispatch-time poison isolated by "
                            f"bisection: {e}")))
                if req.span is not None:
                    req.span.end(outcome="quarantined")
                self._done(item.reqs)
                return
            dspan.end(outcome="error", error=type(e).__name__)
            for req in item.reqs:
                _fail(req.future, e)
                if req.span is not None:
                    req.span.end(outcome="error",
                                 error=type(e).__name__)
            self._done(item.reqs)
            return
        if level:
            # the bucket resolved below the primary rung: record which
            # one (the fault events contract: retries, ladder level,
            # quarantine, deadline cuts, degraded are all in the stream)
            self._tracer.event("ladder", trace_id=item.tid, level=level,
                              attempts=attempts)
        dspan.end(outcome="resolved", level=level, attempts=attempts)
        try:
            self._resolve_item(item, batch, t0, level, attempts)
        except Exception as e:
            for req in item.reqs:
                _fail(req.future, e)
                if req.span is not None:
                    req.span.end(outcome="error",
                                 error=type(e).__name__)
            self._done(item.reqs)

    def _resolve_item(self, item, batch, t0, level, attempts):
        """Fetch the batch's declared artifacts and resolve every Future
        (typed Solution views or legacy dicts)."""
        with self._tracer.span("artifact-fetch", trace_id=item.tid,
                               batch=len(item.reqs)):
            # O(B)-scalar UNGATED fetch: blocks until the bucket is
            # solved whatever the tenants' want union declares,
            # without materializing any big artifact on host
            batch.phases()
            if any(r.want is None for r in item.reqs):
                # legacy solve_s includes the legacy artifact
                # device->host fetches, as the pre-Solution surface
                # measured it
                batch.cost()
                if item.has_mass:
                    batch.plan()
                else:
                    batch.matching()
                    batch.duals()
        solve_s = _now() - t0
        # graft the fault-tolerance accounting onto the batch's stats so
        # every Solution view (and legacy dict) reports it uniformly
        batch.stats = dataclasses.replace(
            batch.stats, attempts=attempts, ladder_level=level,
            quarantined=int(item.shared.get("quarantined", 0)))
        st = batch.stats
        deg = batch.degraded()
        # one shared (read-only) occupancy curve for the whole
        # batch, not a copy per request
        occupancy = st.occupancy
        waits = [t0 - req.t_submit for req in item.reqs]
        # aggregate accounting goes to the lock-free registry instruments
        # (stats/stats_dict() are views over them); no scheduler lock on
        # this path — the registry's per-thread cells make the updates
        # race-free by construction
        self._c_batches.add(1)
        self._h_solve.observe(solve_s)
        self._c_dispatches.add(st.dispatches)
        self._occ.append(occupancy)
        self._c_requests.add(len(item.reqs))
        for w in waits:
            self._h_wait.observe(w)
        ndeg = int(deg.sum())
        if ndeg:
            self._c_degraded.add(ndeg)
            self._tracer.event("degraded", trace_id=item.tid, n=ndeg)
        if st.deadline_hit:
            self._c_deadline_hits.add(1)
        for i, req in enumerate(item.reqs):
            wait_s = waits[i]
            if req.span is not None:
                req.span.end(outcome="resolved", bucket_trace=item.tid,
                             wait_s=wait_s, solve_s=solve_s,
                             degraded=bool(deg[i]))
            if req.want is not None:
                # typed surface: the Future resolves to the
                # per-request Solution view (lazy artifacts,
                # uniform Solution.stats)
                _fulfil(req.future, batch[i])
                continue
            m, n = item.sizes[i]
            sol = batch[i]
            out: Dict[str, Any] = {
                "phases": sol.phases,
                "batch_size": len(item.reqs),
                "bucket": item.bucket,
                "wait_s": wait_s,
                "solve_s": solve_s,
                "devices": st.devices,
                "dispatches": st.dispatches,
                "occupancy": occupancy,
                "eps": float(item.eps[i]),
            }
            if deg[i]:
                # new-surface-only key (absent on every converged
                # result, so pre-deadline consumers see identical dicts)
                out["degraded"] = True
            if item.has_mass:
                out["cost"] = sol.cost
                out["plan"] = sol.plan()
            else:
                y_b, y_a = sol.duals()
                out["cost"] = sol.cost / m
                out["matching"] = sol.matching()
                out["dual_lower_bound"] = float(
                    (y_b.sum() + y_a.sum()) / m
                )
            _fulfil(req.future, out)
        self._done(item.reqs)

    def _done(self, reqs):
        with self._lock:
            for r in reqs:
                # only decrement for futures still tracked: a worker
                # finishing an in-flight item AFTER _abort_pending already
                # accounted for it must not drive the counter negative
                # (that would let a later flush() return early)
                if r.future in self._pending:
                    self._pending.discard(r.future)
                    self._outstanding -= 1
            self._lock.notify_all()
