"""Async multi-tenant front end for the OT service: queues -> shape
buckets -> mesh dispatch, with host-side batch preparation overlapping
in-flight device work.

``OTService`` (serve/engine.py) is synchronous: callers submit, then one
``run_batch()`` call blocks while it buckets, pads, builds cost matrices,
and solves. ``AsyncOTScheduler`` splits that into a two-stage pipeline:

  submit(x, y[, nu, mu][, eps]) -> Future     (any thread, any tenant)
      |
  [collate worker]  drains the request queue (draining whatever is queued,
      up to ``max_batch``, after an optional ``linger_ms`` batching
      window), groups by (point-dim, solver mode) and shape bucket, pads,
      and computes the batched cost matrices
      |
  [dispatch worker] feeds prepared buckets to the mesh through the
      unified front door (``core/api.solve`` under a mesh-mode
      DispatchPolicy -> the distributed compacting driver,
      core/distributed.py) and resolves the per-request Futures

with a bounded handoff queue between the stages: while the dispatch
worker is blocked inside a solve (device work + the driver's per-chunk
converged-mask syncs), the collate worker is already padding/bucketing
the NEXT batch — host-side compaction/bucketing overlaps with in-flight
device dispatches. (The overlap is thread-level: numpy padding and jax
dispatch release the GIL while device work runs.)

Each resolved Future carries the same result dict as
``OTService.run_batch`` plus scheduling stats: ``wait_s`` (submit ->
dispatch start), ``solve_s`` (bucket solve wall time), ``devices``,
``dispatches``, ``occupancy`` (the compaction curve of its bucket), and
``batch_size``/``bucket``. Per-request ``eps`` is supported (eps is data
to the compacting driver — mixed-accuracy tenants share one dispatch).

Per-request ``want=`` (or the scheduler-level default) switches a tenant
onto the typed Solution surface (core/solution.py): its Future resolves
to a :class:`~repro.core.solution.Solution` view and each bucket
dispatch declares only the UNION of its tenants' artifacts — a batch of
cost-only tenants fetches O(B) scalars from the mesh, never the dense
(B, M, N) plans.

Results are identical to the synchronous service regardless of how
requests happen to be batched: the distributed driver's per-lane results
are composition-invariant (retiring or re-sharding a neighbor never
perturbs a survivor — the property tests in tests/test_compaction.py).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _fulfil(fut: Future, result) -> bool:
    """set_result tolerating caller-side cancellation: a tenant cancelling
    its Future must not poison the rest of the batch."""
    try:
        fut.set_result(result)
        return True
    except Exception:          # cancelled / already resolved
        return False


def _fail(fut: Future, exc: BaseException) -> bool:
    try:
        if not fut.done():
            fut.set_exception(exc)
            return True
    except Exception:
        pass
    return False


@dataclass
class _Pending:
    x: np.ndarray
    y: np.ndarray
    nu: Optional[np.ndarray]
    mu: Optional[np.ndarray]
    eps: float
    future: Future
    t_submit: float
    want: Optional[tuple] = None    # None -> legacy result dict


@dataclass
class _WorkItem:
    has_mass: bool
    c: Any                      # (B, M, N) batched cost matrix (device)
    nu: Any                     # (B, M) or None
    mu: Any                     # (B, N) or None
    sizes: np.ndarray           # (B, 2)
    eps: np.ndarray             # (B,) per-request eps
    reqs: List[_Pending]
    bucket: tuple
    t_prepared: float


@dataclass
class SchedulerStats:
    """Aggregate accounting across all dispatched buckets. ``occupancy``
    keeps only the most recent curves (bounded: a long-lived scheduler
    must not grow a list forever)."""
    requests: int = 0
    batches: int = 0
    total_wait_s: float = 0.0
    total_solve_s: float = 0.0
    dispatches: int = 0
    occupancy: "deque" = field(
        default_factory=lambda: deque(maxlen=64))

    def as_dict(self) -> dict:
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_wait_s": (self.total_wait_s / self.requests
                            if self.requests else 0.0),
            "total_solve_s": self.total_solve_s,
            "dispatches": self.dispatches,
        }


class AsyncOTScheduler:
    """Asynchronous bucket scheduler over the distributed OT solvers.

    Args:
      eps: default additive error (per-request override via ``submit``).
      metric: point-cloud cost metric.
      mesh: 1-D batch mesh (``launch.mesh.make_batch_mesh()`` when None);
        on a single-device host this degrades gracefully to the plain
        compacting driver.
      buckets: shape-bucket boundaries (core/batched.py defaults).
      chunk: k, phases per dispatch of the compacting driver.
      max_batch: max requests drained into one collate round.
      linger_ms: optional batching window — after the first request of a
        round arrives, keep draining for this long so co-tenant requests
        share a dispatch. 0 dispatches whatever is instantaneously queued.
      placement: "auto" | "batch" | "matrix" (core/distributed.py policy).
    """

    def __init__(self, eps: float = 0.05, metric: str = "euclidean",
                 mesh=None, buckets=None, chunk: Optional[int] = None,
                 max_batch: int = 256, linger_ms: float = 0.0,
                 use_pallas: bool = True, placement: str = "auto",
                 want: Optional[tuple] = None):
        from repro.core import batched as B
        from repro.core import compaction as C
        from repro.core.api import DispatchPolicy
        from repro.core.costs import COSTS

        if mesh is None:
            from repro.launch.mesh import make_batch_mesh

            mesh = make_batch_mesh()
        self.eps = float(eps)
        self.metric = metric
        self.mesh = mesh
        self.buckets = tuple(buckets) if buckets else B.DEFAULT_BUCKETS
        self.chunk = C.DEFAULT_CHUNK if chunk is None else int(chunk)
        # every bucket dispatch goes through the unified core/api.solve
        # front door under this one policy
        self._policy = DispatchPolicy(mode="mesh", mesh=mesh,
                                      placement=placement, chunk=self.chunk,
                                      buckets=self.buckets)
        self.max_batch = int(max_batch)
        self.linger_s = float(linger_ms) / 1e3
        self.placement = placement
        # default artifact declaration for submits that don't pass their
        # own ``want``; None -> legacy result dicts
        self.want = None if want is None else tuple(want)
        self.kernel = ("pallas" if use_pallas
                       and jax.default_backend() == "tpu" else "jnp")
        self._B = B
        self._cost_batched = jax.jit(jax.vmap(COSTS[metric]))
        self.stats = SchedulerStats()

        self._submit_q: "queue.Queue" = queue.Queue()
        # bounded handoff: collate may run at most this many batches ahead
        # of the dispatcher (backpressure, and the overlap window)
        self._work_q: "queue.Queue" = queue.Queue(maxsize=2)
        self._outstanding = 0
        # every un-resolved Future, so shutdown can always account for
        # in-flight work even if a worker dies mid-batch (futures are
        # resolved or failed, never silently stranded)
        self._pending: set = set()
        self._lock = threading.Condition()
        self._closed = False          # no new submits (close() or abort)
        self._close_called = False    # close() ran (joins done once)
        self._collate_t = threading.Thread(target=self._collate_loop,
                                           name="ot-collate", daemon=True)
        self._dispatch_t = threading.Thread(target=self._dispatch_loop,
                                            name="ot-dispatch", daemon=True)
        self._collate_t.start()
        self._dispatch_t.start()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, x, y, nu=None, mu=None,
               eps: Optional[float] = None,
               want: Optional[tuple] = None) -> Future:
        """Queue one distance request; returns a Future. (nu, mu) both
        present -> general OT; both absent -> assignment distance.

        ``want`` (per-request, defaulting to the scheduler-level setting)
        declares the artifacts this tenant will read: the Future then
        resolves to a typed :class:`~repro.core.solution.Solution`
        instead of the legacy dict, and only the batch's UNION of
        declared artifacts is ever fetched from device — a bucket of
        cost-only tenants moves O(B) scalars, no dense plans. With
        ``want=None`` the Future resolves to the historical result dict
        (bit-identical adapter)."""
        if (nu is None) != (mu is None):
            raise ValueError("provide both nu and mu (general OT) or "
                             "neither (assignment distance)")
        fut: Future = Future()
        req = _Pending(x=np.asarray(x), y=np.asarray(y),
                       nu=None if nu is None else np.asarray(nu),
                       mu=None if mu is None else np.asarray(mu),
                       eps=self.eps if eps is None else float(eps),
                       future=fut, t_submit=time.perf_counter(),
                       want=(self.want if want is None else tuple(want)))
        # closed-check and outstanding-increment share the lock close()
        # takes to flip _closed, so a submit can never slip in after the
        # shutdown sentinel and strand its Future
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._outstanding += 1
            self._pending.add(fut)
        self._submit_q.put(req)
        return fut

    def _workers_alive(self) -> bool:
        return self._collate_t.is_alive() and self._dispatch_t.is_alive()

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has resolved (normally,
        exceptionally, or — if a worker thread died — by having its Future
        failed here rather than stranded). Returns False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._outstanding > 0:
                if not self._workers_alive():
                    break               # fall through to the abort path
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return False
                # wake periodically to re-check worker liveness
                self._lock.wait(timeout=0.2 if remaining is None
                                else min(0.2, remaining))
            # read the verdict while still holding the lock — a bare
            # re-read outside it races _done()/_abort_pending (the
            # lock-discipline scan in repro.analysis flags that pattern)
            stranded = self._outstanding > 0
        if stranded:
            self._abort_pending(RuntimeError(
                "scheduler worker thread died; request abandoned"))
        return True

    def _abort_pending(self, exc: BaseException):
        """Resolve every still-pending Future with ``exc`` (last-resort
        shutdown path: a worker died or close() found undrained work).
        Queued work items are discarded."""
        with self._lock:
            # the pipeline is broken (a worker died or close() found
            # stragglers): refuse further submits — an accepted request
            # with no live worker would strand its Future
            self._closed = True
        for q in (self._submit_q, self._work_q):
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            # re-seed the shutdown sentinel: draining may have swallowed
            # one a still-live worker was waiting for, and a broken
            # pipeline (one worker dead) should wind the other down too
            try:
                q.put_nowait(None)
            except queue.Full:
                pass
        with self._lock:
            for fut in list(self._pending):
                _fail(fut, exc)
            self._pending.clear()
            self._outstanding = 0
            self._lock.notify_all()

    def close(self):
        """Stop accepting work, drain what was submitted, stop workers.
        Every accepted Future is resolved (or failed) before this returns
        — shutdown never strands a pending Future, even racing in-flight
        collate/dispatch work or a dead worker thread."""
        with self._lock:
            if self._close_called:
                return
            self._close_called = True
            self._closed = True          # no new submits past this point
        self.flush()
        self._submit_q.put(None)          # collate sentinel
        self._collate_t.join(timeout=30)
        self._dispatch_t.join(timeout=30)
        with self._lock:
            stranded = bool(self._pending)
        if stranded:
            # belt-and-braces: a worker hung past the join timeout
            self._abort_pending(RuntimeError("scheduler closed"))

    def stats_dict(self) -> dict:
        """Locked snapshot of the aggregate stats — the supported way to
        read ``stats`` from a caller thread while the workers run (direct
        field reads race the dispatch worker's updates)."""
        with self._lock:
            return self.stats.as_dict()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # workers
    # ------------------------------------------------------------------

    def _drain(self) -> Optional[List[_Pending]]:
        """Block for the first request, then drain whatever else is queued
        (up to max_batch, within the linger window). None on shutdown."""
        first = self._submit_q.get()
        if first is None:
            return None
        batch = [first]
        deadline = time.monotonic() + self.linger_s
        while len(batch) < self.max_batch:
            timeout = deadline - time.monotonic()
            try:
                nxt = (self._submit_q.get_nowait() if timeout <= 0
                       else self._submit_q.get(timeout=timeout))
            except queue.Empty:
                break
            if nxt is None:               # propagate shutdown after batch
                self._submit_q.put(None)
                break
            batch.append(nxt)
        return batch

    def _batched_cost(self, xs, ys):
        if self.kernel == "pallas":
            from repro.kernels import ops

            return ops.cost_matrix_batched(xs, ys, metric=self.metric)
        return self._cost_batched(xs, ys)

    def _handoff(self, item) -> None:
        """Backpressure put that cannot block forever: if the dispatch
        worker died, the queue never drains — raise so the batch's
        futures are failed instead of wedging the collate thread."""
        while True:
            try:
                self._work_q.put(item, timeout=1.0)
                return
            except queue.Full:
                if not self._dispatch_t.is_alive():
                    raise RuntimeError("dispatch worker died; work "
                                       "item abandoned") from None

    def _collate_loop(self):
        B = self._B
        while True:
            batch = self._drain()
            if batch is None:
                try:
                    self._handoff(None)     # dispatch shutdown sentinel
                except RuntimeError:
                    pass                    # dispatcher already gone
                return
            packaged: set = set()
            try:
                modes: Dict[tuple, List[_Pending]] = {}
                for r in batch:
                    key = (r.x.shape[1], r.nu is not None)
                    modes.setdefault(key, []).append(r)
                for (dim, has_mass), sub in sorted(modes.items()):
                    shapes = [(r.x.shape[0], r.y.shape[0]) for r in sub]
                    for grp in B.bucket_instances(shapes, self.buckets):
                        reqs = [sub[j] for j in grp.indices]
                        (mb, nb) = grp.key
                        xs = B.pad_stack([r.x for r in reqs], (mb, dim))
                        ys = B.pad_stack([r.y for r in reqs], (nb, dim))
                        c = self._batched_cost(xs, ys)
                        nu = mu = None
                        if has_mass:
                            nu = B.pad_stack([r.nu for r in reqs], (mb,))
                            mu = B.pad_stack([r.mu for r in reqs], (nb,))
                        item = _WorkItem(
                            has_mass=has_mass, c=c, nu=nu, mu=mu,
                            sizes=grp.sizes,
                            eps=np.asarray([r.eps for r in reqs]),
                            reqs=reqs, bucket=grp.key,
                            t_prepared=time.perf_counter(),
                        )
                        self._handoff(item)      # blocks: backpressure
                        packaged.update(id(r) for r in reqs)
            except Exception as e:
                # fail only the requests that never made it into a work
                # item; packaged ones are resolved by the dispatcher
                missed = [r for r in batch if id(r) not in packaged]
                for r in missed:
                    _fail(r.future, e)
                self._done(missed)

    @staticmethod
    def _union_want(item) -> tuple:
        """The batch-level artifact declaration: the union of every
        co-batched tenant's ``want`` (legacy-dict tenants need the full
        legacy artifact set). Only this union is ever fetchable — a
        bucket of cost-only tenants never ships a dense plan."""
        legacy = (("cost", "plan") if item.has_mass
                  else ("cost", "matching", "duals"))
        union: set = set()
        for r in item.reqs:
            union |= set(legacy if r.want is None else r.want)
        return tuple(sorted(union))

    def _dispatch_loop(self):
        from repro.core.api import ASSIGNMENT, OT, solve

        while True:
            item = self._work_q.get()
            if item is None:
                return
            t0 = time.perf_counter()
            try:
                if item.has_mass:
                    spec = OT
                    inputs = {"c": item.c, "nu": item.nu, "mu": item.mu}
                else:
                    spec = ASSIGNMENT
                    inputs = {"c": item.c}
                batch = solve(spec, inputs, item.eps, self._policy,
                              sizes=item.sizes, want=self._union_want(item))
                # O(B)-scalar UNGATED fetch: blocks until the bucket is
                # solved whatever the tenants' want union declares,
                # without materializing any big artifact on host
                batch.phases()
                if any(r.want is None for r in item.reqs):
                    # legacy solve_s includes the legacy artifact
                    # device->host fetches, as the pre-Solution surface
                    # measured it
                    batch.cost()
                    if item.has_mass:
                        batch.plan()
                    else:
                        batch.matching()
                        batch.duals()
                solve_s = time.perf_counter() - t0
                st = batch.stats
                # one shared (read-only) occupancy curve for the whole
                # batch, not a copy per request
                occupancy = st.occupancy
                waits = [t0 - req.t_submit for req in item.reqs]
                # all SchedulerStats mutation under the scheduler lock:
                # stats_dict() readers run concurrently on caller threads,
                # and the dataclass's += read-modify-writes are not atomic
                # (the lock-discipline scan in repro.analysis pins this)
                with self._lock:
                    self.stats.batches += 1
                    self.stats.total_solve_s += solve_s
                    self.stats.dispatches += st.dispatches
                    self.stats.occupancy.append(occupancy)
                    self.stats.requests += len(item.reqs)
                    self.stats.total_wait_s += sum(waits)
                for i, req in enumerate(item.reqs):
                    wait_s = waits[i]
                    if req.want is not None:
                        # typed surface: the Future resolves to the
                        # per-request Solution view (lazy artifacts,
                        # uniform Solution.stats)
                        _fulfil(req.future, batch[i])
                        continue
                    m, n = item.sizes[i]
                    sol = batch[i]
                    out: Dict[str, Any] = {
                        "phases": sol.phases,
                        "batch_size": len(item.reqs),
                        "bucket": item.bucket,
                        "wait_s": wait_s,
                        "solve_s": solve_s,
                        "devices": st.devices,
                        "dispatches": st.dispatches,
                        "occupancy": occupancy,
                        "eps": float(item.eps[i]),
                    }
                    if item.has_mass:
                        out["cost"] = sol.cost
                        out["plan"] = sol.plan()
                    else:
                        y_b, y_a = sol.duals()
                        out["cost"] = sol.cost / m
                        out["matching"] = sol.matching()
                        out["dual_lower_bound"] = float(
                            (y_b.sum() + y_a.sum()) / m
                        )
                    _fulfil(req.future, out)
                self._done(item.reqs)
            except Exception as e:
                for req in item.reqs:
                    _fail(req.future, e)
                self._done(item.reqs)

    def _done(self, reqs):
        with self._lock:
            for r in reqs:
                # only decrement for futures still tracked: a worker
                # finishing an in-flight item AFTER _abort_pending already
                # accounted for it must not drive the counter negative
                # (that would let a later flush() return early)
                if r.future in self._pending:
                    self._pending.discard(r.future)
                    self._outstanding -= 1
            self._lock.notify_all()
