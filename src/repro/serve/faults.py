"""Deterministic fault injection for the serving layers (chaos harness).

The fault-tolerance claims in serve/ — no Future stranded, no healthy
request lost to a neighbor's poison, every degraded answer re-validated
by its certificate — are only claims until something actually fails.
This module injects the failures, deterministically, from a seeded
:class:`FaultPlan`:

  * **NaN poison** — ``on_submit`` corrupts chosen submit ordinals'
    supply points with NaN, exercising the admission gate (or, with
    validation off and ``REPRO_DEBUG_CHECKS=1``, the checkify-triggered
    bisection path);
  * **dispatch exceptions** — ``on_dispatch`` raises
    :class:`~repro.serve.ft.TransientDispatchError` for the first N
    dispatch attempts, exercising the retry/backoff degradation ladder;
  * **poisoned dispatch** — raising :class:`PoisonedDispatchError` when
    a chosen request is present in the dispatched bucket, exercising
    bisection without needing the checkify mode;
  * **artificial latency** — a sleep before every dispatch attempt,
    exercising deadline budgets;
  * **worker-thread death** — :class:`WorkerDeath` derives from
    ``SystemExit``, so it escapes the dispatch worker's ``except
    Exception`` recovery exactly like a real thread crash and kills the
    thread silently; ``flush()``/``close()`` must then fail the stranded
    Futures.

The injector is its own lock domain (it is called from scheduler worker
threads and the submitting thread) and counts submits/dispatch attempts
itself, so a plan replays bit-identically for a fixed request sequence.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .ft import TransientDispatchError

__all__ = [
    "FaultPlan",
    "FaultInjector",
    "PoisonedDispatchError",
    "WorkerDeath",
]


class PoisonedDispatchError(RuntimeError):
    """Injected data-dependent dispatch failure: deterministic for the
    same lanes, like a real checkify NaN trip — ``is_poison`` routes it
    to bisection, not to the retry ladder."""
    poisoned_instance = True


class WorkerDeath(SystemExit):
    """Injected worker-thread death. Derives from ``SystemExit`` (not
    ``Exception``) so no recovery path can catch it — the worker thread
    dies mid-item, exactly the failure mode flush()/close() must mop up
    after."""


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, declarative chaos schedule (all ordinals are 0-based).

    ``poison_submits`` NaN-corrupts those submit ordinals' inputs;
    ``poison_rate`` additionally poisons each submit with this seeded
    probability. ``poison_dispatch_of`` raises
    :class:`PoisonedDispatchError` whenever one of those submit ordinals
    is present in a dispatched bucket (dispatch-time poison: survives
    admission, triggers bisection). ``transient_dispatches`` fails the
    first N dispatch attempts with a retryable error;
    ``dispatch_latency_s`` sleeps before every attempt;
    ``kill_worker_at_dispatch`` raises :class:`WorkerDeath` on that
    attempt ordinal."""
    seed: int = 0
    poison_submits: Tuple[int, ...] = ()
    poison_rate: float = 0.0
    poison_dispatch_of: Tuple[int, ...] = ()
    transient_dispatches: int = 0
    dispatch_latency_s: float = 0.0
    kill_worker_at_dispatch: Optional[int] = None


@dataclass
class FaultInjector:
    """Runtime companion of a :class:`FaultPlan`; hand one to
    ``AsyncOTScheduler(faults=...)`` / ``OTService(faults=...)``.

    ``log`` records every injected fault as ``(kind, ordinal)`` so chaos
    tests can assert the plan actually fired."""
    plan: FaultPlan = field(default_factory=FaultPlan)
    log: List[Tuple[str, int]] = field(default_factory=list)

    def __post_init__(self):
        self._lock = threading.Lock()
        self._rng = np.random.default_rng(self.plan.seed)
        self._submits = 0
        self._dispatches = 0

    # -- submit-side ----------------------------------------------------

    def on_submit(self, x: np.ndarray) -> Tuple[np.ndarray, int]:
        """Account one submit; returns ``(possibly-poisoned x, submit
        ordinal)``. Poison is a NaN written into the first supply point —
        it propagates into the batched cost matrix, which is what the
        admission gate (and checkify) actually inspect."""
        with self._lock:
            seq = self._submits
            self._submits += 1
            hit = seq in self.plan.poison_submits or (
                self.plan.poison_rate > 0.0
                and float(self._rng.random()) < self.plan.poison_rate)
            if hit:
                self.log.append(("poison", seq))
        if not hit:
            return x, seq
        x = np.array(x, dtype=np.asarray(x).dtype, copy=True)
        x.reshape(-1)[0] = np.nan
        return x, seq

    # -- dispatch-side --------------------------------------------------

    def on_dispatch(self, submit_seqs: Tuple[int, ...] = ()) -> None:
        """Called at the top of every dispatch attempt with the submit
        ordinals in the bucket; raises per the plan (latency is applied
        first so even failing attempts take wall-clock time)."""
        with self._lock:
            att = self._dispatches
            self._dispatches += 1
            kill = (self.plan.kill_worker_at_dispatch is not None
                    and att == self.plan.kill_worker_at_dispatch)
            transient = att < self.plan.transient_dispatches
            poisoned = sorted(
                set(submit_seqs) & set(self.plan.poison_dispatch_of))
            if kill:
                self.log.append(("kill", att))
            elif transient:
                self.log.append(("transient", att))
            elif poisoned:
                self.log.append(("poison-dispatch", att))
        if self.plan.dispatch_latency_s > 0.0:
            time.sleep(self.plan.dispatch_latency_s)
        if kill:
            raise WorkerDeath(f"fault injection: worker death at dispatch "
                              f"attempt {att}")
        if transient:
            raise TransientDispatchError(
                f"fault injection: transient failure at dispatch attempt "
                f"{att}")
        if poisoned:
            raise PoisonedDispatchError(
                f"fault injection: poisoned request(s) {poisoned} in "
                f"dispatched bucket (attempt {att})")
