"""Fault-tolerance helpers shared by the serving layers.

Three concerns live here so ``OTService`` and ``AsyncOTScheduler`` agree
on them exactly:

  * request validation (:func:`require_mass_pair` — the one home of the
    "provide both nu and mu" rule, naming the failing request/tenant);
  * failure classification (:func:`is_transient` vs :func:`is_poison`):
    a transient infrastructure failure (device OOM, mesh collective
    error) is worth retrying on a safer rung, while poison (a checkify
    ``JaxRuntimeError`` from NaN inputs, a corrupted-state invariant) is
    a property of the DATA — retrying reproduces it, so the right move
    is bisection and quarantine;
  * the degradation ladder (:func:`degradation_ladder` +
    :func:`run_with_recovery`): transient failures retry with
    exponential backoff down ``mesh -> compact single-device -> host
    CPU``. The last rung pins the compacting driver to the host CPU
    device — the safe-harbor equivalent of "lockstep on CPU" that still
    honors the per-request eps arrays and deadlines serving buckets
    carry (the lockstep driver can express neither).
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Tuple

import jax

from ..core.validate import RequestRejected  # noqa: F401  (re-export: the
#   serving layers raise it for both admission and dispatch-time poison)

__all__ = [
    "RequestRejected",
    "TransientDispatchError",
    "require_mass_pair",
    "is_transient",
    "is_poison",
    "degradation_ladder",
    "run_with_recovery",
]


class TransientDispatchError(RuntimeError):
    """A dispatch failure worth retrying: the inputs are fine, the
    attempt was not (device OOM, collective timeout, injected chaos)."""


def require_mass_pair(nu, mu, *, who: str = "request") -> bool:
    """The one home of the nu/mu pairing rule: both present (general OT)
    or both absent (assignment distance). Returns ``has_mass``; raises a
    ``ValueError`` that names the offending request/tenant."""
    if (nu is None) != (mu is None):
        supplied = "nu" if nu is not None else "mu"
        raise ValueError(
            f"provide both nu and mu (general OT) or neither (assignment "
            f"distance): {who} supplied only {supplied}")
    return nu is not None


def is_transient(exc: BaseException) -> bool:
    """Worth retrying? Injected :class:`TransientDispatchError`, plus
    device-runtime failures (``XlaRuntimeError``: OOM, collective errors,
    backend faults) — those are attempt properties, not data properties,
    and a smaller/safer rung may succeed."""
    if isinstance(exc, TransientDispatchError):
        return True
    # jaxlib's XlaRuntimeError moves between modules across jax versions;
    # match by name so the ladder doesn't couple to a private import path
    return type(exc).__name__ == "XlaRuntimeError"


def is_poison(exc: BaseException) -> bool:
    """A data-dependent failure: retrying the same lanes reproduces it,
    so the caller should bisect and quarantine instead. Matches the
    checkify sanitizer's ``JaxRuntimeError`` (REPRO_DEBUG_CHECKS=1 NaN /
    invariant trips), plain ``FloatingPointError``, and anything tagged
    ``poisoned_instance`` (the fault-injection harness)."""
    if isinstance(exc, FloatingPointError):
        return True
    if getattr(exc, "poisoned_instance", False):
        return True
    try:
        from jax.experimental.checkify import JaxRuntimeError
    except ImportError:                       # pragma: no cover
        return False
    return isinstance(exc, JaxRuntimeError)


def degradation_ladder(policy) -> List[Tuple[str, Any, Any]]:
    """``[(level_name, policy, pinned_device), ...]`` from the configured
    policy down to the host-CPU safe harbor.

    Level 0 is the caller's policy verbatim. Each later rung strips one
    failure surface: ``compact`` drops the mesh (no collectives, one
    device), ``cpu`` additionally pins dispatch to the host CPU device
    (survives an accelerator wedged by OOM). Rungs equal to the
    configured policy are deduplicated, so a compact-policy scheduler
    gets a 2-rung ladder.
    """
    from ..core.api import DispatchPolicy

    mode = policy.resolved_mode()
    ladder: List[Tuple[str, Any, Any]] = [(mode, policy, None)]
    compact = DispatchPolicy(
        mode="compact", chunk=policy.chunk, buckets=policy.buckets,
        guaranteed=policy.guaranteed)
    if mode != "compact":
        ladder.append(("compact", compact, None))
    cpus = jax.devices("cpu")
    if cpus:
        cpu0 = cpus[0]
        if jax.default_backend() != "cpu" or mode == "mesh":
            ladder.append(("cpu", compact, cpu0))
    return ladder


def run_with_recovery(
    attempt: Callable[[str, Any, Any], Any],
    ladder: List[Tuple[str, Any, Any]],
    *,
    retries_per_level: int = 2,
    backoff_s: float = 0.05,
    sleep: Callable[[float], None] = time.sleep,
    transient: Callable[[BaseException], bool] = is_transient,
) -> Tuple[Any, int, int]:
    """Run ``attempt(level_name, policy, device)`` down the ladder.

    Transient failures retry ``retries_per_level`` times per rung with
    exponential backoff (``backoff_s * 2**attempt_on_level``), then fall
    to the next rung. Non-transient failures (poison, programming errors)
    propagate immediately — retrying data-dependent failures only burns
    budget reproducing them. Returns ``(result, level_index,
    total_attempts)``; exhausting the ladder re-raises the last error.
    """
    last: Optional[BaseException] = None
    total = 0
    for level, (name, pol, dev) in enumerate(ladder):
        for a in range(max(1, retries_per_level)):
            total += 1
            try:
                return attempt(name, pol, dev), level, total
            except Exception as e:
                if not transient(e):
                    raise
                last = e
                if backoff_s > 0:
                    sleep(backoff_s * (2 ** a))
    assert last is not None
    raise last
