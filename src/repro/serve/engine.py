"""Batched serving engine: prefill a batch of (padded) prompts, then greedy/
temperature decode with per-sequence stopping. Also exposes the paper's OT
solver as a batched endpoint (cost matrices via the Pallas kernel path on
TPU), mirroring the paper's experiment harness as a service."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclass
class Request:
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclass
class Completion:
    tokens: np.ndarray
    prefill_len: int
    decode_steps: int
    latency_s: float


class Engine:
    """Synchronous batched engine: submit() queues requests; run_batch()
    pads them to a common prompt length, prefills once, and decodes the
    whole batch in lockstep with per-sequence early-stop masking."""

    def __init__(self, cfg, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos)
        )
        self._prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b))

    def submit(self, req: Request):
        self.queue.append(req)

    def run_batch(self) -> List[Completion]:
        if not self.queue:
            return []
        reqs, self.queue = self.queue, []
        t0 = time.perf_counter()
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        caches, logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        caches = M.pad_caches(self.cfg, caches, self.max_len)
        max_new = max(r.max_new_tokens for r in reqs)
        out = np.zeros((b, max_new), np.int32)
        done = np.zeros((b,), bool)
        cur = jnp.argmax(logits[:, : self.cfg.vocab_size], -1)[:, None]
        cur = cur.astype(jnp.int32)
        steps = 0
        for t in range(max_new):
            out[:, t] = np.asarray(cur[:, 0])
            for i, r in enumerate(reqs):
                if r.eos_id is not None and out[i, t] == r.eos_id:
                    done[i] = True
                if t + 1 >= r.max_new_tokens:
                    done[i] = True
            steps += 1
            if done.all() or plen + t + 1 >= self.max_len:
                break
            logits, caches = self._decode(
                self.params, caches, cur, jnp.int32(plen + t)
            )
            cur = jnp.argmax(
                logits[:, : self.cfg.vocab_size], -1
            )[:, None].astype(jnp.int32)
        dt = time.perf_counter() - t0
        return [
            Completion(tokens=out[i, : min(reqs[i].max_new_tokens, steps)],
                       prefill_len=plen, decode_steps=steps, latency_s=dt)
            for i in range(b)
        ]


class OTService:
    """Batched OT-distance endpoint (the paper's solver as a service)."""

    def __init__(self, eps: float = 0.05, metric: str = "euclidean",
                 use_pallas: bool = True):
        from repro.core.pushrelabel import solve_assignment
        from repro.core.costs import build_cost_matrix

        self.eps = eps
        self.metric = metric
        self.kernel = "pallas" if use_pallas else "jnp"
        self._solve = solve_assignment
        self._cost = build_cost_matrix

    def distance(self, x: np.ndarray, y: np.ndarray) -> Dict[str, Any]:
        c = self._cost(jnp.asarray(x), jnp.asarray(y), self.metric,
                       kernel=self.kernel)
        r = self._solve(c, self.eps)
        n = x.shape[0]
        return {
            "cost": float(r.cost) / n,
            "matching": np.asarray(r.matching),
            "phases": int(r.phases),
            "dual_lower_bound": float(
                (jnp.sum(r.y_b) + jnp.sum(r.y_a)) / n
            ),
        }
