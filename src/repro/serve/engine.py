"""Batched serving engine: prefill a batch of (padded) prompts, then greedy/
temperature decode with per-sequence stopping. Also exposes the paper's OT
solver as a batched endpoint (cost matrices via the Pallas kernel path on
TPU), mirroring the paper's experiment harness as a service."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.obs import MetricsRegistry, Tracer, new_id
from repro.obs import now as _now


@dataclass
class Request:
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclass
class Completion:
    tokens: np.ndarray
    prefill_len: int
    decode_steps: int
    latency_s: float


class Engine:
    """Synchronous batched engine: submit() queues requests; run_batch()
    pads them to a common prompt length, prefills once, and decodes the
    whole batch in lockstep with per-sequence early-stop masking."""

    def __init__(self, cfg, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos)
        )
        self._prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b))

    def submit(self, req: Request):
        self.queue.append(req)

    def run_batch(self) -> List[Completion]:
        if not self.queue:
            return []
        reqs, self.queue = self.queue, []
        t0 = _now()
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        caches, logits = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        caches = M.pad_caches(self.cfg, caches, self.max_len)
        max_new = max(r.max_new_tokens for r in reqs)
        out = np.zeros((b, max(max_new, 1)), np.int32)
        # max_new_tokens=0 requests are complete before the first step
        done = np.asarray([r.max_new_tokens <= 0 for r in reqs])
        # Per-sequence accounting: the batch decodes in lockstep, but each
        # request's tokens end at its own EOS / max_new_tokens, its
        # decode_steps is the number of steps it was live, and its latency
        # is the wall time until *its* completion (not the whole batch's).
        steps_per_seq = np.zeros((b,), np.int32)
        finish_time = np.full((b,), np.nan)
        cur = jnp.argmax(logits[:, : self.cfg.vocab_size], -1)[:, None]
        cur = cur.astype(jnp.int32)
        for t in range(max_new):
            out[:, t] = np.asarray(cur[:, 0])
            now = _now()
            for i, r in enumerate(reqs):
                if done[i]:
                    continue
                steps_per_seq[i] = t + 1
                hit_eos = r.eos_id is not None and out[i, t] == r.eos_id
                if hit_eos or t + 1 >= r.max_new_tokens:
                    done[i] = True
                    finish_time[i] = now
            if done.all() or plen + t + 1 >= self.max_len:
                break
            logits, caches = self._decode(
                self.params, caches, cur, jnp.int32(plen + t)
            )
            cur = jnp.argmax(
                logits[:, : self.cfg.vocab_size], -1
            )[:, None].astype(jnp.int32)
        t_end = _now()
        finish_time = np.where(np.isnan(finish_time), t_end, finish_time)
        return [
            Completion(tokens=out[i, : steps_per_seq[i]],
                       prefill_len=plen,
                       decode_steps=int(steps_per_seq[i]),
                       latency_s=float(finish_time[i] - t0))
            for i in range(b)
        ]


@dataclass
class OTRequest:
    x: np.ndarray                      # (m, d) supply points
    y: np.ndarray                      # (n, d) demand points
    nu: Optional[np.ndarray] = None    # (m,) masses -> general-OT mode
    mu: Optional[np.ndarray] = None    # (n,) masses


class OTService:
    """Batched OT-distance endpoint (the paper's solver as a service).

    Mirrors ``Engine``: ``submit()`` queues distance requests; ``run_batch()``
    groups them into shape buckets, pads each bucket to a fixed shape, and
    dispatches every bucket through the unified ``core/api.solve`` front
    door (one DispatchPolicy per service: lockstep / compacting / mesh-
    distributed, chosen by the constructor arguments below). With
    ``compact=True`` (default) a bucket is solved by the convergence-
    compacting driver (core/compaction.py): converged requests retire
    between k-phase dispatches instead of riding lockstep until the bucket's
    slowest request finishes (a win on skewed traffic; pass compact=False
    for tiny/uniform workloads where the per-chunk converged-mask sync
    outweighs it). Point-set requests (no masses) run the
    assignment solver; requests with (nu, mu) run the general OT solver.
    ``distance()`` stays as the one-shot convenience wrapper.

    ``want=`` (a tuple of artifact names, e.g. ``("cost", "plan_sparse")``)
    switches ``run_batch`` onto the typed Solution surface
    (core/solution.py): it returns per-request
    :class:`~repro.core.solution.Solution` views instead of dicts, and
    only the declared artifacts ever cross device->host — cost-only
    services fetch O(B) scalars per bucket, never the dense plans. With
    ``want=None`` (default) run_batch is a thin adapter emitting the
    historical per-request dicts, bit-identical to the pre-Solution
    surface (including the legacy ``dispatches``/``devices`` keys, kept
    for one release — prefer ``Solution.stats``).

    Observability: the service owns a :class:`repro.obs.MetricsRegistry`
    (attach sinks via ``sinks=``). Every ``run_batch`` bucket gets its
    own trace (``svc-N``) with bucket/admission/solve/artifact-fetch
    spans, per-rejected-ticket events, and the chunked drivers' per-chunk
    events parented under the solve span. ``stats_dict()`` is a view
    over that registry — there is no hand-maintained tally, and results
    are bit-identical with or without a sink attached.
    """

    def __init__(self, eps: float = 0.05, metric: str = "euclidean",
                 use_pallas: bool = True, buckets=None,
                 compact: bool = True, chunk: Optional[int] = None,
                 mesh=None, want: Optional[tuple] = None,
                 validate: bool = True,
                 admission_tol: Optional[float] = None,
                 sinks=(), solver: str = "pushrelabel"):
        from repro.core import batched as B
        from repro.core import compaction as C
        from repro.core import validate as V
        from repro.core.api import DispatchPolicy
        from repro.core.costs import COSTS, build_cost_matrix

        self.eps = eps
        self.metric = metric
        # per-ticket admission gate: poisoned tickets get a
        # RequestRejected INSTANCE in the result list, healthy co-bucketed
        # tickets still solve (lane-independence of the batched drivers)
        self.validate = bool(validate)
        self.admission_tol = (V.DEFAULT_TOL if admission_tol is None
                              else float(admission_tol))
        # Pallas cost kernels only where they compile (TPU); everywhere else
        # they would run in interpret mode, i.e. a pure emulation tax.
        self.kernel = ("pallas" if use_pallas
                       and jax.default_backend() == "tpu" else "jnp")
        self.buckets = tuple(buckets) if buckets else B.DEFAULT_BUCKETS
        self.compact = compact
        self.chunk = C.DEFAULT_CHUNK if chunk is None else int(chunk)
        # mesh != None routes every bucket through the mesh-distributed
        # compacting driver (core/distributed.py): batch axis sharded
        # across devices, same per-request results. Every bucket solve
        # goes through the unified core/api.solve front door under this
        # one policy (from_legacy owns the compact/mesh keyword mapping
        # and its mesh-requires-compact rule).
        # ``solver`` routes OT-mode buckets through the solver portfolio
        # (core/api DispatchPolicy.solver: pushrelabel / sinkhorn /
        # hybrid / measured-"auto"); assignment-mode requests ignore it.
        self._policy = DispatchPolicy.from_legacy(
            compact, mesh, chunk=self.chunk, buckets=self.buckets,
            solver=solver)
        self.want = None if want is None else tuple(want)
        self.mesh = mesh
        self.queue: List[OTRequest] = []
        self._B = B
        self._C = C
        self._cost = build_cost_matrix
        self._cost_batched = jax.jit(jax.vmap(COSTS[metric]))
        # stats_dict() is a view over this registry; attach sinks to
        # stream the same observations out as structured events
        self.metrics = MetricsRegistry(sinks=sinks)
        self._tracer = Tracer(self.metrics)
        reg = self.metrics
        self._c_requests = reg.counter("service.requests")
        self._c_batches = reg.counter("service.batches")
        self._c_rejected = reg.counter("service.rejected")
        self._c_dispatches = reg.counter("service.dispatches")
        self._h_solve = reg.histogram("service.solve_s",
                                      MetricsRegistry.LATENCY_BOUNDS)

    def stats_dict(self) -> Dict[str, Any]:
        """Service counters as a plain dict — a view over the metrics
        registry (the same numbers any attached sink streamed out)."""
        snap = self.metrics.snapshot()
        solve_h = snap.get("service.solve_s", {"count": 0, "sum": 0.0})
        return {
            "requests": snap.get("service.requests", 0),
            "batches": snap.get("service.batches", 0),
            "rejected": snap.get("service.rejected", 0),
            "dispatches": snap.get("service.dispatches", 0),
            "total_solve_s": solve_h["sum"],
        }

    def submit(self, x: np.ndarray, y: np.ndarray,
               nu: Optional[np.ndarray] = None,
               mu: Optional[np.ndarray] = None) -> int:
        """Queue one distance request; returns its ticket (position in the
        result list of the next run_batch)."""
        from .ft import require_mass_pair

        require_mass_pair(nu, mu, who=f"ticket #{len(self.queue)}")
        self.queue.append(OTRequest(x=np.asarray(x), y=np.asarray(y),
                                    nu=nu, mu=mu))
        return len(self.queue) - 1

    def _batched_cost(self, xs, ys):
        if self.kernel == "pallas":
            from repro.kernels import ops

            # one kernel launch for the whole bucket: grid (B, m/BM, n/BN),
            # each batch slice bit-identical to the per-instance kernel
            return ops.cost_matrix_batched(xs, ys, metric=self.metric)
        return self._cost_batched(xs, ys)

    def run_batch(self) -> List[Any]:
        """Solve all queued requests via bucketed batched dispatch; returns
        results in submission order: the historical per-request dicts
        (``want=None``, bit-identical adapter), or per-request
        ``Solution`` views when the service declared ``want=``.

        With ``validate=True`` (default) each bucket passes the admission
        gate first: a poisoned ticket's slot holds its
        :class:`~repro.core.validate.RequestRejected` instance (not a
        result dict) while healthy co-bucketed tickets solve normally."""
        if not self.queue:
            return []
        from repro.core.api import ASSIGNMENT, OT, solve

        reqs, self.queue = self.queue, []
        results: List[Optional[Any]] = [None] * len(reqs)
        # Split by point dim + solver mode, then reuse the core bucketing
        # for the (m, n) shape grouping -- one compiled program per
        # (bucket, d, mode), shared by later batches of the same key.
        modes: Dict[tuple, List[int]] = {}
        for i, r in enumerate(reqs):
            modes.setdefault((r.x.shape[1], r.nu is not None), []).append(i)
        for (d, has_mass), sub in sorted(modes.items()):
            shapes = [(reqs[i].x.shape[0], reqs[i].y.shape[0]) for i in sub]
            for grp in self._B.bucket_instances(shapes, self.buckets):
                idx = [sub[j] for j in grp.indices]
                (mb, nb), sizes = grp.key, grp.sizes
                tid = new_id("svc")
                bsp = self._tracer.start("bucket", trace_id=tid,
                                         bucket=[int(mb), int(nb)],
                                         batch=len(idx),
                                         tickets=[int(i) for i in idx])
                gt0 = bsp.t_start
                xs = self._B.pad_stack([reqs[i].x for i in idx], (mb, d))
                ys = self._B.pad_stack([reqs[i].y for i in idx], (nb, d))
                c = self._batched_cost(xs, ys)
                nu = mu = None
                if has_mass:
                    nu = self._B.pad_stack([reqs[i].nu for i in idx], (mb,))
                    mu = self._B.pad_stack([reqs[i].mu for i in idx], (nb,))
                if self.validate:
                    from repro.core.validate import (RequestRejected,
                                                     admission_codes)

                    ins = ({"c": c, "nu": nu, "mu": mu} if has_mass
                           else {"c": c})
                    with self._tracer.span(
                            "admission", trace_id=tid,
                            parent=bsp.span_id) as asp:
                        codes = admission_codes(ins, sizes=sizes,
                                                tol=self.admission_tol)
                        bad = np.flatnonzero(codes != 0)
                        asp.attrs["rejected"] = int(bad.size)
                    if bad.size:
                        # quarantined tickets get their rejection IN the
                        # result list (run_batch has no Future to fail);
                        # the healthy rest of the bucket still solves
                        self._c_rejected.add(int(bad.size))
                        for j in bad:
                            self._tracer.event(
                                "rejected", trace_id=tid,
                                parent_id=bsp.span_id,
                                ticket=int(idx[j]), code=int(codes[j]))
                            results[idx[j]] = RequestRejected(
                                f"ticket #{idx[j]}", int(codes[j]))
                        keep = np.flatnonzero(codes == 0)
                        if keep.size == 0:
                            bsp.end(outcome="all-rejected")
                            continue
                        c = c[keep]
                        if has_mass:
                            nu, mu = nu[keep], mu[keep]
                        sizes = sizes[keep]
                        idx = [idx[j] for j in keep]
                if has_mass:
                    spec, inputs = OT, {"c": c, "nu": nu, "mu": mu}
                    legacy_want = ("cost", "plan")
                else:
                    spec, inputs = ASSIGNMENT, {"c": c}
                    legacy_want = ("cost", "matching", "duals")
                want = legacy_want if self.want is None else self.want
                with self._tracer.span("solve", trace_id=tid,
                                       parent=bsp.span_id,
                                       batch=len(idx)) as ssp:
                    batch = solve(spec, inputs, self.eps, self._policy,
                                  sizes=sizes, want=want,
                                  obs=self._tracer.bind(
                                      trace_id=tid, parent=ssp.span_id))
                with self._tracer.span("artifact-fetch", trace_id=tid,
                                       parent=bsp.span_id):
                    # the O(B)-scalar (ungated) phase fetch blocks until
                    # the bucket is solved regardless of the declared
                    # want; big artifacts stay on device unless requested
                    batch.phases()
                    if self.want is None:
                        # legacy latency_s includes the legacy artifact
                        # device->host fetches, as the pre-Solution
                        # surface measured it
                        batch.cost()
                        if has_mass:
                            batch.plan()
                        else:
                            batch.matching()
                            batch.duals()
                gdt = _now() - gt0
                st = batch.driver_stats
                self._c_batches.add(1)
                self._c_requests.add(len(idx))
                self._h_solve.observe(gdt)
                if st is not None:
                    self._c_dispatches.add(int(st.dispatches))
                bsp.end(kept=len(idx), solve_s=gdt)
                for k, i in enumerate(idx):
                    sol = batch[k]
                    if self.want is not None:
                        results[i] = sol
                        continue
                    m, n = sizes[k]
                    if has_mass:
                        out: Dict[str, Any] = {
                            "cost": sol.cost,
                            "plan": sol.plan(),
                            "phases": sol.phases,
                            "batch_size": len(idx),
                            "bucket": (mb, nb),
                            "latency_s": gdt,
                        }
                    else:
                        y_b, y_a = sol.duals()
                        out = {
                            "cost": sol.cost / m,
                            "matching": sol.matching(),
                            "phases": sol.phases,
                            "dual_lower_bound": float(
                                (y_b.sum() + y_a.sum()) / m
                            ),
                            "batch_size": len(idx),
                            "bucket": (mb, nb),
                            "latency_s": gdt,
                        }
                    # legacy keys, kept for one release: uniform
                    # accounting now lives on Solution.stats
                    if st is not None:
                        out["dispatches"] = st.dispatches
                        if hasattr(st, "devices"):
                            out["devices"] = st.devices
                    results[i] = out
        assert all(r is not None for r in results)
        return results  # submission order

    def distance(self, x: np.ndarray, y: np.ndarray,
                 nu: Optional[np.ndarray] = None,
                 mu: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """One-shot convenience: solve just this request. Queued requests
        and their tickets are left untouched for the next run_batch()."""
        held, self.queue = self.queue, []
        try:
            self.submit(x, y, nu=nu, mu=mu)
            out = self.run_batch()[0]
            if isinstance(out, BaseException):
                raise out        # one-shot callers want the exception
            return out
        finally:
            self.queue = held
