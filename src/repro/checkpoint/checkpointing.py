"""Sharded, fault-tolerant checkpointing (no orbax).

Layout: <dir>/step_<n>/  arrays.npz (flattened pytree leaves)
                         manifest.json (treedef, shapes, dtypes, crc32, step)
Writes go to a temp dir + atomic rename, so a killed writer never corrupts
the latest checkpoint; restore picks the newest directory whose manifest
passes CRC. Save can run on a background thread (async=True); `retain`
bounds disk usage.

Elastic restore: arrays are saved as full logical tensors (device_get on the
addressable global array); restoring onto a different mesh just re-shards -
the trainer passes target shardings at restore time."""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _encode(arr: np.ndarray):
    """npz cannot represent ml_dtypes (bfloat16 etc.); store a same-width
    integer view and record the logical dtype in the manifest."""
    name = arr.dtype.name
    if arr.dtype.kind == "V" or name not in np.sctypeDict:
        width = arr.dtype.itemsize
        return arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[width]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str):
    if arr.dtype.name != dtype_name:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def save(directory: str, step: int, tree: Any, *, async_: bool = False,
         retain: int = 3):
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def write():
        os.makedirs(directory, exist_ok=True)
        tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_")
        try:
            encoded = [_encode(l) for l in host_leaves]
            arrays = {f"a{i}": a for i, (a, _) in enumerate(encoded)}
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            crc = 0
            for a, _ in encoded:
                crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "num_leaves": len(host_leaves),
                "shapes": [list(l.shape) for l in host_leaves],
                "dtypes": [name for _, name in encoded],
                "crc32": crc,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(directory, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        _gc(directory, retain)

    if async_:
        t = threading.Thread(target=write, daemon=False)
        t.start()
        return t
    write()
    return None


def _gc(directory: str, retain: int):
    steps = sorted(
        d for d in os.listdir(directory) if d.startswith("step_")
    )
    for d in steps[:-retain]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in sorted(os.listdir(directory), reverse=True):
        if not d.startswith("step_"):
            continue
        path = os.path.join(directory, d)
        if _verify(path):
            best = int(d.split("_")[1])
            break
    return best


def _verify(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            crc = 0
            for i in range(manifest["num_leaves"]):
                crc = zlib.crc32(
                    np.ascontiguousarray(z[f"a{i}"]).tobytes(), crc
                )
        return crc == manifest["crc32"]
    except Exception:
        return False


def restore(directory: str, step: int, like: Any, *, shardings: Any = None):
    """Restore into the structure of `like`. If `shardings` (a matching
    pytree of NamedSharding) is given, leaves are placed sharded - this is
    the elastic-rescale path: any mesh works as long as dims divide."""
    path = os.path.join(directory, f"step_{step:010d}")
    if not _verify(path):
        raise IOError(f"checkpoint {path} fails CRC verification")
    leaves, treedef = _flatten(like)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        host = [
            _decode(z[f"a{i}"], manifest["dtypes"][i])
            for i in range(len(leaves))
        ]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        out = [
            jax.device_put(h, s) if s is not None else jax.device_put(h)
            for h, s in zip(host, sh_leaves)
        ]
    else:
        out = [jax.device_put(h) for h in host]
    return treedef.unflatten(out)
