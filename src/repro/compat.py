"""jax API-drift shims, shared by every layer (core, models, launch).

Covers the surface this repo needs across the jax versions it runs on:

  * ``shard_map`` left ``jax.experimental`` (and gained a while_loop
    replication rule) on newer jax; older releases need the experimental
    import with ``check_rep=False`` for while_loop-carrying bodies.
  * ``pvary`` only exists where the varying-axes checker does; older jax
    accepts the pmax'd outputs without it.
  * ``Compiled.cost_analysis()`` returns a dict on newer jax, a
    one-element list of dicts on older releases.
"""
from __future__ import annotations

import jax

_raw_shard_map = getattr(jax, "shard_map", None)
if _raw_shard_map is None:
    from jax.experimental.shard_map import shard_map as _raw_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _raw_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)
else:
    def shard_map(f, *, mesh, in_specs, out_specs):
        return _raw_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs)


pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
