"""Mamba-2 (SSD, state-space duality) block - chunked quadratic-intra /
recurrent-inter algorithm (arXiv:2405.21060), plus O(1)-state single-token
decode.

TPU-sharding adaptation (documented in DESIGN.md): the fused ``in_proj`` of
the reference implementation is split into separate z / x / B / C / dt
projections so each output can carry its own tensor-parallel sharding
(d_inner and heads shard over 'tp'; the small B/C/dt streams stay
replicated) - the fused projection would place split points off shard
boundaries and force per-layer reshards. Depthwise convs split exactly.

The chunk loop is a lax.scan so prefill memory stays O(chunk^2 + state) per
layer regardless of sequence length."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _init, rmsnorm


def mamba_dims(cfg):
    d_inner = 2 * cfg.d_model
    headdim = cfg.ssm_headdim
    nheads = d_inner // headdim
    d_state = cfg.ssm_state
    return d_inner, headdim, nheads, d_state


def mamba_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, headdim, nheads, d_state = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_z": _init(ks[0], (d, d_inner), dtype=dtype),
        "in_x": _init(ks[1], (d, d_inner), dtype=dtype),
        "in_b": _init(ks[2], (d, d_state), dtype=dtype),
        "in_c": _init(ks[3], (d, d_state), dtype=dtype),
        "in_dt": _init(ks[4], (d, nheads), dtype=dtype),
        "conv_x": _init(ks[5], (4, d_inner), scale=0.5, dtype=dtype),
        "conv_b": _init(ks[5], (4, d_state), scale=0.5, dtype=dtype),
        "conv_c": _init(ks[5], (4, d_state), scale=0.5, dtype=dtype),
        "conv_bias_x": jnp.zeros((d_inner,), dtype),
        "conv_bias_b": jnp.zeros((d_state,), dtype),
        "conv_bias_c": jnp.zeros((d_state,), dtype),
        "a_log": jnp.zeros((nheads,), dtype),
        "d_skip": jnp.ones((nheads,), dtype),
        "dt_bias": jnp.zeros((nheads,), dtype),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": _init(ks[2], (d_inner, d), dtype=dtype),
    }


def _causal_conv(x, w, bias):
    """Depthwise causal conv, kernel 4, over (B, L, C)."""
    pad = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))
    out = (
        pad[:, 0:-3] * w[0] + pad[:, 1:-2] * w[1]
        + pad[:, 2:-1] * w[2] + pad[:, 3:] * w[3]
    )
    return jax.nn.silu(out + bias)


def ssd_scan(x, dt, a, b_mat, c_mat, chunk: int = 256, init_state=None):
    """Chunked SSD. x: (B,L,H,P); dt: (B,L,H); a: (H,) (negative);
    b_mat/c_mat: (B,L,N). Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l, h, p_ = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(bsz, nc, chunk, h, p_).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    bc = b_mat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c_mat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p_, n), jnp.float32)

    def body(state, inp):
        xk, dtk, bk, ck = inp                    # (B,Q,H,P),(B,Q,H),(B,Q,N)
        da = dtk * a                             # (B,Q,H)
        cums = jnp.cumsum(da, axis=1)            # inclusive cumsum over chunk
        seg = cums[:, :, None, :] - cums[:, None, :, :]   # (B,Qi,Qj,H)
        tri = jnp.tril(jnp.ones((xk.shape[1], xk.shape[1]), bool))
        # mask BEFORE exp: upper-triangle seg is positive and can overflow,
        # which would poison the backward pass (inf * 0 = nan).
        seg = jnp.where(tri[None, :, :, None], seg, -1e30)
        decay = jnp.exp(seg)
        cb = jnp.einsum("bin,bjn->bij", ck, bk)  # (B,Qi,Qj)
        xdt = xk * dtk[..., None]                # (B,Q,H,P)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb, decay, xdt)
        # inter-chunk: contribution of incoming state
        state_decay = jnp.exp(cums)              # (B,Q,H)
        y_inter = jnp.einsum(
            "bin,bhpn,bih->bihp", ck, state, state_decay
        )
        # update state: S' = S*exp(sum da) + sum_i exp(cum_end - cum_i) xdt_i b_i
        total = cums[:, -1]                      # (B,H)
        rem = jnp.exp(total[:, None, :] - cums)  # (B,Q,H)
        s_local = jnp.einsum("bqhp,bqn,bqh->bhpn", xdt, bk, rem)
        state = state * jnp.exp(total)[:, :, None, None] + s_local
        return state, y_intra + y_inter

    state, ys = jax.lax.scan(body, init_state, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p_)
    return y[:, :l], state


def _project(p, u):
    z = u @ p["in_z"]
    x = u @ p["in_x"]
    b_raw = u @ p["in_b"]
    c_raw = u @ p["in_c"]
    dt = u @ p["in_dt"]
    return z, x, b_raw, c_raw, dt


def mamba_forward(p, cfg, u, cache=None, pos=None):
    """Full-sequence forward. Returns (out, cache); cache = (conv_x_state
    (B,3,d_inner), conv_b_state, conv_c_state, ssm_state (B,H,P,N))."""
    d_inner, headdim, nheads, d_state = mamba_dims(cfg)
    bsz, l, _ = u.shape
    z, x_raw, b_raw, c_raw, dt = _project(p, u)

    def tail(t):
        return t[:, -3:, :] if l >= 3 else jnp.pad(
            t, ((0, 0), (3 - l, 0), (0, 0))
        )

    conv_state = (tail(x_raw), tail(b_raw), tail(c_raw))
    x = _causal_conv(x_raw, p["conv_x"], p["conv_bias_x"])
    b_mat = _causal_conv(b_raw, p["conv_b"], p["conv_bias_b"])
    c_mat = _causal_conv(c_raw, p["conv_c"], p["conv_bias_c"])
    x = x.reshape(bsz, l, nheads, headdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ssd_scan(
        x.astype(jnp.float32), dt, a,
        b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
    )
    y = y + x.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_inner).astype(u.dtype)
    y = rmsnorm(p["norm_w"], y * jax.nn.silu(z))
    return y @ p["out_proj"], conv_state + (state,)


def mamba_decode(p, cfg, u, cache):
    """Single-token decode. u: (B, 1, d)."""
    d_inner, headdim, nheads, d_state = mamba_dims(cfg)
    bsz = u.shape[0]
    cx, cb, cc, ssm_state = cache
    z, x_raw, b_raw, c_raw, dt = _project(p, u)

    def step_conv(state, new, w, bias):
        new = new[:, 0]
        out = (state[:, 0] * w[0] + state[:, 1] * w[1]
               + state[:, 2] * w[2] + new * w[3])
        out = jax.nn.silu(out + bias)
        state = jnp.concatenate([state[:, 1:], new[:, None, :]], axis=1)
        return out, state

    x, cx = step_conv(cx, x_raw, p["conv_x"], p["conv_bias_x"])
    b_mat, cb = step_conv(cb, b_raw, p["conv_b"], p["conv_bias_b"])
    c_mat, cc = step_conv(cc, c_raw, p["conv_c"], p["conv_bias_c"])

    x = x.reshape(bsz, nheads, headdim).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)
    xdt = x * dt[..., None]
    ssm_state = (
        ssm_state * da[:, :, None, None]
        + jnp.einsum("bhp,bn->bhpn", xdt, b_mat.astype(jnp.float32))
    )
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, c_mat.astype(jnp.float32))
    y = y + x * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(u.dtype)
    y = rmsnorm(p["norm_w"], y * jax.nn.silu(z))
    return y @ p["out_proj"], (cx, cb, cc, ssm_state)


def mamba_cache_init(cfg, batch, dtype=jnp.float32):
    d_inner, headdim, nheads, d_state = mamba_dims(cfg)
    return (
        jnp.zeros((batch, 3, d_inner), dtype),
        jnp.zeros((batch, 3, d_state), dtype),
        jnp.zeros((batch, 3, d_state), dtype),
        jnp.zeros((batch, nheads, headdim, d_state), jnp.float32),
    )
