"""Top-level model assembly: embeddings -> stages -> head, with train loss,
prefill and single-token decode entry points, plus abstract input specs for
the multi-pod dry-run (ShapeDtypeStruct only, no allocation)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import sharding
from .layers import embed_init, embed_lookup, rmsnorm, rmsnorm_init, _init, \
    cross_entropy_chunked
from .transformer import (
    build_stages, encoder_stages, stage_init, stages_forward, stages_prefill,
    stages_decode,
)

COMPUTE_DTYPE = jnp.bfloat16


def _pdtype(cfg):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


def _cast(params):
    """Mixed precision: fp32 master weights compute in bf16 (grads land on
    the fp32 masters through the cast)."""
    return jax.tree.map(
        lambda w: w.astype(COMPUTE_DTYPE)
        if w.dtype == jnp.float32 else w,
        params,
    )


class Model(NamedTuple):
    cfg: Any
    stages: Any
    init_params: Any
    loss_fn: Any
    forward_hidden: Any
    prefill: Any
    decode_step: Any
    input_specs: Any


def init_params(cfg, key):
    dtype = _pdtype(cfg)
    ks = jax.random.split(key, 8)
    stages = build_stages(cfg)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": _init(ks[1], (cfg.d_model, cfg.vocab_padded), dtype=dtype),
        "stages": [
            stage_init(k, cfg, spec, n, dtype)
            for k, (spec, n) in zip(jax.random.split(ks[2], len(stages)),
                                    stages)
        ],
    }
    if cfg.family == "audio":
        enc = encoder_stages(cfg)
        params["encoder"] = {
            "stages": [
                stage_init(k, cfg, spec, n, dtype)
                for k, (spec, n) in zip(
                    jax.random.split(ks[3], len(enc)), enc)
            ],
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
    return params


def _embed_inputs(params, cfg, batch):
    """Returns (x (B,S,d) bf16, positions (B,S), loss_mask (B,S), memory)."""
    memory = None
    if cfg.input_mode == "frames":
        frames = batch["frames"].astype(COMPUTE_DTYPE)
        enc_pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1]), frames.shape[:2]
        )
        memory = stages_forward(
            params["encoder"]["stages"], cfg, encoder_stages(cfg),
            frames, enc_pos, causal=False,
        )
        memory = rmsnorm(params["encoder"]["final_norm"], memory)
    tokens = batch["tokens"]
    x = embed_lookup(params["embed"], tokens).astype(COMPUTE_DTYPE)
    mask = jnp.ones(tokens.shape, jnp.float32)
    if cfg.input_mode == "tokens+patches":
        patches = batch["patches"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([patches, x], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(patches.shape[:2], jnp.float32), mask], axis=1
        )
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x = sharding.constrain(x, "dp", "tp" if cfg.seq_shard else None, None)
    return x, positions, mask, memory


def loss_fn(params, cfg, batch):
    """Next-token CE. batch['tokens']: (B, S+1) int32 (inputs||label tail)."""
    params = _cast(params)
    tokens = batch["tokens"]
    inp = {**batch, "tokens": tokens[:, :-1]}
    x, positions, mask, memory = _embed_inputs(params, cfg, inp)
    stages = build_stages(cfg)
    x = stages_forward(params["stages"], cfg, stages, x, positions,
                       memory=memory)
    x = rmsnorm(params["final_norm"], x)
    # align labels with the (possibly patch-prefixed) sequence
    n_prefix = x.shape[1] - (tokens.shape[1] - 1)
    labels = tokens[:, 1:]
    if n_prefix:
        labels = jnp.concatenate(
            [jnp.zeros((x.shape[0], n_prefix), labels.dtype), labels], axis=1
        )
    head = params["lm_head"]

    def logits_fn(xc):
        return sharding.constrain(
            xc.astype(COMPUTE_DTYPE) @ head, "dp", None, "tp"
        )

    return cross_entropy_chunked(logits_fn, x, labels, mask)


def prefill(params, cfg, batch):
    """Returns (caches, last_logits)."""
    params = _cast(params)
    x, positions, _, memory = _embed_inputs(params, cfg, batch)
    stages = build_stages(cfg)
    x, caches = stages_prefill(params["stages"], cfg, stages, x, positions,
                               memory=memory)
    x = rmsnorm(params["final_norm"], x[:, -1:])
    logits = (x.astype(COMPUTE_DTYPE) @ params["lm_head"])[:, 0]
    return caches, logits


def decode_step(params, cfg, caches, token, pos):
    """token: (B, 1) int32; pos: () int32. Returns (logits (B, V), caches)."""
    params = _cast(params)
    x = embed_lookup(params["embed"], token).astype(COMPUTE_DTYPE)
    stages = build_stages(cfg)
    x, caches = stages_decode(params["stages"], cfg, stages, x, caches, pos)
    x = rmsnorm(params["final_norm"], x)
    logits = (x.astype(COMPUTE_DTYPE) @ params["lm_head"])[:, 0]
    return logits, caches


def pad_caches(cfg, caches, max_len: int):
    """Grow self-attention KV caches to max_len slots (serving headroom).
    Mamba/cross caches are length-independent and pass through."""

    def grow(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("self_k", "self_v"):
            pad = max_len - leaf.shape[2]   # (period, B, S, KvH, Dh)
            if pad > 0:
                leaf = jnp.pad(leaf, ((0, 0), (0, 0), (0, pad), (0, 0),
                                      (0, 0)))
        return leaf

    return jax.tree_util.tree_map_with_path(grow, caches)


def decode_cache_specs(cfg, batch_size: int, seq_len: int):
    """Abstract cache pytree for the dry-run decode path (no allocation):
    eval_shape over prefill with abstract inputs of the cache length."""
    specs = input_specs(cfg, seq_len, batch_size, kind="prefill")

    def f(params, b):
        return prefill(params, cfg, b)

    params_s = abstract_params(cfg)
    caches, _ = jax.eval_shape(f, params_s, specs)
    return caches


def abstract_params(cfg):
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.key(0))


def input_specs(cfg, seq_len: int, batch: int, kind: str = "train"):
    """ShapeDtypeStruct stand-ins for every model input."""
    sd = jax.ShapeDtypeStruct
    i32, bf16 = jnp.int32, jnp.bfloat16
    if kind == "train":
        b = {"tokens": sd((batch, seq_len + 1), i32)}
    elif kind == "prefill":
        b = {"tokens": sd((batch, seq_len), i32)}
    elif kind == "decode":
        return {"token": sd((batch, 1), i32),
                "pos": sd((), i32)}
    else:
        raise ValueError(kind)
    if cfg.input_mode == "frames":
        # encoder frames: precomputed frame embeddings (frontend stub)
        n = seq_len if kind == "train" else seq_len
        b["frames"] = sd((batch, n, cfg.d_model), bf16)
    if cfg.input_mode == "tokens+patches":
        b["patches"] = sd((batch, cfg.num_patch_tokens, cfg.d_model), bf16)
        # patches occupy part of the sequence budget
        toks = max(seq_len - cfg.num_patch_tokens, 8)
        key = "tokens"
        b[key] = sd((batch, toks + 1 if kind == "train" else toks), i32)
    return b
