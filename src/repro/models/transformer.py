"""Layer-stack machinery for all assigned families.

A model is a list of *stages*; a stage is (period_spec, n_periods) where
period_spec is a tuple of (layer_type, ffn_kind) entries. Uniform stacks have
a 1-layer period scanned n times (compile once per layer type); Jamba's 1:7
hybrid is an 8-layer period scanned 9 times. Params for a stage are stacked
pytrees with a leading period axis; train/prefill/decode all run as
lax.scan over that axis (remat per period for training).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from . import sharding
from .attention import (
    attn_init, attn_forward, attn_prefill, attn_decode, cross_attn_forward,
    flash_attention,
)
from .layers import glu_mlp, glu_mlp_init, rmsnorm, rmsnorm_init
from .mamba import mamba_init, mamba_forward, mamba_decode
from .moe import moe_init, moe_forward

Spec = Tuple[Tuple[str, Optional[str]], ...]


def build_stages(cfg) -> List[Tuple[Spec, int]]:
    if cfg.family in ("dense", "vlm"):
        return [((("attn", "mlp"),), cfg.num_layers)]
    if cfg.family == "moe":
        stages = []
        fd = cfg.first_dense_layers
        if fd:
            stages.append(((("attn", "mlp"),), fd))
        stages.append(((("attn", "moe"),), cfg.num_layers - fd))
        return stages
    if cfg.family == "ssm":
        return [((("mamba", None),), cfg.num_layers)]
    if cfg.family == "hybrid":
        period = [("attn", "mlp")]
        for i in range(1, cfg.attn_period):
            period.append(("mamba", "moe" if i % 2 == 1 else "mlp"))
        assert cfg.num_layers % cfg.attn_period == 0
        return [(tuple(period), cfg.num_layers // cfg.attn_period)]
    if cfg.family == "audio":
        # decoder stack (encoder built separately)
        return [((("attn_cross", "mlp"),), cfg.num_layers)]
    raise ValueError(cfg.family)


def encoder_stages(cfg) -> List[Tuple[Spec, int]]:
    return [((("attn", "mlp"),), cfg.encoder_layers)]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def layer_init(key, cfg, ltype, ffn, dtype):
    p: Dict[str, Any] = {}
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if ltype in ("attn", "attn_cross"):
        p["ln1"] = rmsnorm_init(d, dtype)
        p["attn"] = attn_init(ks[0], cfg, dtype)
        if ltype == "attn_cross":
            p["ln_x"] = rmsnorm_init(d, dtype)
            p["xattn"] = attn_init(ks[1], cfg.with_(qk_norm=False), dtype)
    elif ltype == "mamba":
        p["ln1"] = rmsnorm_init(d, dtype)
        p["mamba"] = mamba_init(ks[0], cfg, dtype)
    if ffn == "mlp":
        p["ln2"] = rmsnorm_init(d, dtype)
        p["mlp"] = glu_mlp_init(ks[2], d, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["ln2"] = rmsnorm_init(d, dtype)
        p["moe"] = moe_init(ks[3], cfg, dtype)
    return p


def stage_init(key, cfg, spec: Spec, n: int, dtype):
    def one(k):
        ks = jax.random.split(k, len(spec))
        return {
            f"l{i}": layer_init(ks[i], cfg, lt, ffn, dtype)
            for i, (lt, ffn) in enumerate(spec)
        }

    return jax.vmap(one)(jax.random.split(key, n))


# --------------------------------------------------------------------------
# MoE dispatch wrapper (shard_map when a mesh is configured)
# --------------------------------------------------------------------------

_ROUTED = ("router", "w_gate", "w_up", "w_down")


def apply_moe(p, cfg, x):
    mesh = sharding.get_mesh()
    routed = {k: p[k] for k in _ROUTED}
    tp = sharding._STATE["tp"]
    if (
        mesh is None
        or tp not in mesh.axis_names
        or cfg.num_experts % mesh.shape[tp] != 0
    ):
        out = moe_forward(routed, cfg.with_(num_shared_experts=0), x)
    else:
        dp_size = 1
        for a in sharding._STATE["dp"]:
            if a in mesh.axis_names:
                dp_size *= mesh.shape[a]
        # decode batches (e.g. B=1 long-context) may not divide dp:
        # replicate tokens across dp in that case (experts still sharded).
        dp = (sharding.pspec("dp", None, None)
              if x.shape[0] % dp_size == 0
              else sharding.pspec(None, None, None))
        especs = {
            "router": P(None, None),
            "w_gate": P(tp, None, None),
            "w_up": P(tp, None, None),
            "w_down": P(tp, None, None),
        }
        out = sharding.shard_map(
            lambda xx, pp: moe_forward(
                pp, cfg.with_(num_shared_experts=0), xx, axis_name=tp
            ),
            mesh=mesh,
            in_specs=(dp, especs),
            out_specs=dp,
        )(x, routed)
    if cfg.num_shared_experts:
        out = out + glu_mlp(p["shared"], x)
    return out


# --------------------------------------------------------------------------
# forward (no cache)
# --------------------------------------------------------------------------

def apply_layer(lp, cfg, lt, ffn, x, positions, memory=None, causal=True):
    if cfg.parallel_block and lt == "attn" and ffn == "mlp":
        # parallel residual: partial attn-out and partial mlp-out are summed
        # BEFORE replication, so the partitioner emits a single all-reduce.
        h = attn_forward(lp["attn"], cfg, rmsnorm(lp["ln1"], x), positions,
                         causal=causal)
        h = h + glu_mlp(lp["mlp"], rmsnorm(lp["ln2"], x))
        return sharding.constrain(
            x + h, "dp", "tp" if cfg.seq_shard else None, None
        )
    if lt in ("attn", "attn_cross"):
        x = x + attn_forward(lp["attn"], cfg, rmsnorm(lp["ln1"], x),
                             positions, causal=causal)
        if lt == "attn_cross":
            x = x + cross_attn_forward(
                lp["xattn"], cfg, rmsnorm(lp["ln_x"], x), memory
            )
    elif lt == "mamba":
        x = x + mamba_forward(lp["mamba"], cfg, rmsnorm(lp["ln1"], x))[0]
    if ffn == "mlp":
        x = x + glu_mlp(lp["mlp"], rmsnorm(lp["ln2"], x))
    elif ffn == "moe":
        x = x + apply_moe(lp["moe"], cfg, rmsnorm(lp["ln2"], x))
    return sharding.constrain(
        x, "dp", "tp" if cfg.seq_shard else None, None
    )


def stages_forward(stage_params, cfg, stages, x, positions, memory=None,
                   causal=True, remat=True):
    for (spec, _n), sp in zip(stages, stage_params):
        def body(x_, lp, spec=spec):
            for i, (lt, ffn) in enumerate(spec):
                x_ = apply_layer(lp[f"l{i}"], cfg, lt, ffn, x_, positions,
                                 memory=memory, causal=causal)
            return x_

        if remat and cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(lambda c, lp: (body(c, lp), None), x, sp,
                            unroll=cfg.scan_unroll)
    return x


# --------------------------------------------------------------------------
# prefill / decode (KV + state caches)
# --------------------------------------------------------------------------

def layer_prefill(lp, cfg, lt, ffn, x, positions, memory=None):
    cache = {}
    if cfg.parallel_block and lt == "attn" and ffn == "mlp":
        h, (k, v) = attn_prefill(lp["attn"], cfg, rmsnorm(lp["ln1"], x),
                                 positions)
        cache["self_k"], cache["self_v"] = k, v
        h = h + glu_mlp(lp["mlp"], rmsnorm(lp["ln2"], x))
        return sharding.constrain(
            x + h, "dp", "tp" if cfg.seq_shard else None, None
        ), cache
    if lt in ("attn", "attn_cross"):
        h, (k, v) = attn_prefill(lp["attn"], cfg, rmsnorm(lp["ln1"], x),
                                 positions)
        x = x + h
        cache["self_k"], cache["self_v"] = k, v
        if lt == "attn_cross":
            b = memory.shape[0]
            kvh, dh = cfg.num_kv_heads, cfg.head_dim
            ck = (memory @ lp["xattn"]["wk"]).reshape(b, -1, kvh, dh)
            cv = (memory @ lp["xattn"]["wv"]).reshape(b, -1, kvh, dh)
            cache["cross_k"], cache["cross_v"] = ck, cv
            xq = rmsnorm(lp["ln_x"], x)
            x = x + cross_attn_forward(lp["xattn"], cfg, xq, memory)
    elif lt == "mamba":
        h, mcache = mamba_forward(lp["mamba"], cfg, rmsnorm(lp["ln1"], x))
        x = x + h
        cache["mamba"] = mcache
    if ffn == "mlp":
        x = x + glu_mlp(lp["mlp"], rmsnorm(lp["ln2"], x))
    elif ffn == "moe":
        x = x + apply_moe(lp["moe"], cfg, rmsnorm(lp["ln2"], x))
    return sharding.constrain(
        x, "dp", "tp" if cfg.seq_shard else None, None
    ), cache


def _cross_decode(p, cfg, x, ck, cv):
    b = x.shape[0]
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, dh)
    out = flash_attention(q, ck, cv, causal=False)
    return out.reshape(b, 1, h * dh) @ p["wo"]


def layer_decode(lp, cfg, lt, ffn, x, cache, pos):
    new_cache = {}
    if lt in ("attn", "attn_cross"):
        h, (k, v) = attn_decode(
            lp["attn"], cfg, rmsnorm(lp["ln1"], x),
            (cache["self_k"], cache["self_v"]), pos,
        )
        x = x + h
        new_cache["self_k"], new_cache["self_v"] = k, v
        if lt == "attn_cross":
            xq = rmsnorm(lp["ln_x"], x)
            x = x + _cross_decode(lp["xattn"], cfg, xq,
                                  cache["cross_k"], cache["cross_v"])
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
    elif lt == "mamba":
        h, mcache = mamba_decode(lp["mamba"], cfg, rmsnorm(lp["ln1"], x),
                                 cache["mamba"])
        x = x + h
        new_cache["mamba"] = mcache
    if ffn == "mlp":
        x = x + glu_mlp(lp["mlp"], rmsnorm(lp["ln2"], x))
    elif ffn == "moe":
        x = x + apply_moe(lp["moe"], cfg, rmsnorm(lp["ln2"], x))
    return x, new_cache


def _period_prefill(lp, cfg, spec, x, positions, memory):
    caches = {}
    for i, (lt, ffn) in enumerate(spec):
        x, c = layer_prefill(lp[f"l{i}"], cfg, lt, ffn, x, positions, memory)
        caches[f"l{i}"] = c
    return x, caches


def _period_decode(lp, cfg, spec, x, cache, pos):
    new = {}
    for i, (lt, ffn) in enumerate(spec):
        x, c = layer_decode(lp[f"l{i}"], cfg, lt, ffn, x, cache[f"l{i}"], pos)
        new[f"l{i}"] = c
    return x, new


def stages_prefill(stage_params, cfg, stages, x, positions, memory=None):
    caches = []
    for (spec, _n), sp in zip(stages, stage_params):
        def body(x_, lp, spec=spec):
            return _period_prefill(lp, cfg, spec, x_, positions, memory)

        x, cache = jax.lax.scan(body, x, sp, unroll=cfg.scan_unroll)
        caches.append(cache)
    return x, caches


def stages_decode(stage_params, cfg, stages, x, caches, pos):
    new_caches = []
    for (spec, _n), sp, cache in zip(stages, stage_params, caches):
        def body(x_, inp, spec=spec):
            lp, cl = inp
            return _period_decode(lp, cfg, spec, x_, cl, pos)

        x, new = jax.lax.scan(body, x, (sp, cache),
                              unroll=cfg.scan_unroll)
        new_caches.append(new)
    return x, new_caches
