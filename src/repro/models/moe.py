"""Mixture-of-Experts layer with three interchangeable routers:

  - ``topk``        : standard softmax-top-k gating (baseline).
  - ``sinkhorn``    : Sinkhorn-normalized balanced gating (baseline; the
                      numerical method the paper competes with).
  - ``pushrelabel`` : THE PAPER. Token->expert assignment is an unbalanced
                      optimal-transport instance (tokens supply k units each,
                      experts demand capacity); we run a fixed budget of
                      integer push-relabel phases (transport._phase) inside
                      the training step. BASE-layers (arXiv:2103.16716)
                      formulated routing as exactly this assignment problem,
                      solved there with the Hungarian method / auction; the
                      push-relabel solver gives the O(log n / eps^2)-depth
                      parallel version.

Expert parallelism: experts are sharded over the 'model' mesh axis;
activations are replicated across it, so each shard dispatches to its local
experts only (no all_to_all) and partial outputs are combined with one psum -
the same collective volume as a Megatron TP MLP. Dispatch is sort-based
(argsort by expert id -> rank-within-expert -> capacity-bounded scatter), no
(T, E, C) one-hot tensors.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _init, glu_mlp_init, glu_mlp
from repro.core.transport import OTState, _phase


def moe_init(key, cfg, dtype=jnp.float32):
    d, e, ffe = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e), scale=0.02, dtype=jnp.float32),
        "w_gate": _init(ks[1], (e, d, ffe), dtype=dtype),
        "w_up": _init(ks[2], (e, d, ffe), dtype=dtype),
        "w_down": _init(ks[3], (e, ffe, d), dtype=dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = glu_mlp_init(
            ks[4], d, cfg.num_shared_experts * ffe, dtype=dtype
        )
    return p


# --------------------------------------------------------------------------
# Routers: all return (sel (T, k) int32, gates (T, k) float32).
# --------------------------------------------------------------------------

def route_topk(logits, k):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, sel = jax.lax.top_k(probs, k)
    return sel.astype(jnp.int32), gates / jnp.maximum(
        gates.sum(-1, keepdims=True), 1e-9
    )


def route_sinkhorn(logits, k, iters: int = 8):
    """Balanced gating via Sinkhorn normalization of the prob matrix
    (S-BASE style). Selection through the balanced matrix, gate values from
    the raw softmax (straight-through)."""
    t, e = logits.shape
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    f = jnp.zeros((t,))
    g = jnp.zeros((e,))
    log_cap = math.log(1.0 / e)

    def body(_, fg):
        f, g = fg
        g = log_cap - jax.nn.logsumexp(logp + f[:, None], axis=0)
        f = -math.log(t) * 0 - jax.nn.logsumexp(logp + g[None, :], axis=1)
        return f, g

    f, g = jax.lax.fori_loop(0, iters, body, (f, g))
    balanced = logp + f[:, None] + g[None, :]
    _, sel = jax.lax.top_k(jax.lax.stop_gradient(balanced), k)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates = jnp.take_along_axis(probs, sel, axis=1)
    return sel.astype(jnp.int32), gates / jnp.maximum(
        gates.sum(-1, keepdims=True), 1e-9
    )


def pushrelabel_assign(
    affinity: jnp.ndarray,
    k: int,
    capacity: int,
    *,
    levels: int = 16,
    phases: int = 12,
    max_rounds: int = 8,
) -> jnp.ndarray:
    """Balanced token->expert flows via a fixed budget of push-relabel
    phases on the integer OT instance (supplies = k per token, demands =
    capacity per expert, cost = quantized -affinity). Returns (T, E) int32
    flow. Runs entirely inside jit (fori_loop over _phase)."""
    t, e = affinity.shape
    aff = affinity.astype(jnp.float32)
    lo = jnp.min(aff)
    hi = jnp.max(aff)
    cost = (hi - aff) / jnp.maximum(hi - lo, 1e-9)         # in [0, 1]
    c_int = jnp.clip(
        jnp.floor(cost * levels).astype(jnp.int32), 0, levels
    )
    eps = 1.0 / levels
    # zeros derived from the (possibly shard_map-varying) cost matrix so the
    # fori/while carries keep consistent varying-axes under shard_map
    zero_t = c_int[:, 0] * 0
    zero_e = c_int[0, :] * 0
    zero_s = jnp.sum(c_int[:1, :1]) * 0
    init = OTState(
        y_b=zero_t + 1,
        ya_hi=zero_e,
        free_b=zero_t + k,
        free_a=zero_e + capacity,
        f_hi=c_int * 0,
        f_lo=c_int * 0,
        phases=zero_s,
        rounds=zero_s,
    )
    state = jax.lax.fori_loop(
        0, phases, lambda _, s: _phase(c_int, s, max_rounds), init
    )
    return state.f_hi + state.f_lo


def route_pushrelabel(logits, k, *, phases: int = 24):
    t, e = logits.shape
    capacity = -(-t * k // e)  # ceil: perfectly balanced demand
    flow = pushrelabel_assign(
        jax.lax.stop_gradient(logits), k, capacity, phases=phases
    )
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    # Expand the flow MULTISET into k slots (flow[t,e] units can exceed 1;
    # a distinct-expert top_k would spill extra slots onto hot experts).
    # Unmatched units fall back to the best expert with residual capacity.
    residual = jnp.maximum(capacity - jnp.sum(flow, axis=0), 0)
    base = probs + (residual[None, :] > 0).astype(jnp.float32) * 2.0
    score = flow.astype(jnp.float32) * 10.0 + base
    sels = []
    for _ in range(k):
        pick = jnp.argmax(score, axis=1)
        sels.append(pick.astype(jnp.int32))
        # consume one flow unit (or burn the fallback bonus) at the pick
        score = score.at[jnp.arange(t), pick].add(-10.0)
    sel = jnp.stack(sels, axis=1)
    gates = jnp.take_along_axis(probs, sel, axis=1)
    return sel.astype(jnp.int32), gates / jnp.maximum(
        gates.sum(-1, keepdims=True), 1e-9
    )


ROUTERS = {
    "topk": lambda logits, k: route_topk(logits, k),
    "sinkhorn": lambda logits, k: route_sinkhorn(logits, k),
    "pushrelabel": lambda logits, k: route_pushrelabel(logits, k),
}


# --------------------------------------------------------------------------
# Sort-based capacity dispatch (local experts [e0, e0 + e_loc)).
# --------------------------------------------------------------------------

def _dispatch_local(tokens, sel, gates, e0, e_loc, cap):
    """tokens (T,d); sel/gates (T,k). Returns (buffer (e_loc*cap, d),
    buf_gate (e_loc*cap,), src_token (e_loc*cap,) int32 with -1 holes)."""
    t, d = tokens.shape
    k = sel.shape[1]
    flat_e = (sel - e0).reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gates.reshape(-1)
    local = (flat_e >= 0) & (flat_e < e_loc)
    key = jnp.where(local, flat_e, e_loc)
    order = jnp.argsort(key, stable=True)
    e_sorted = key[order]
    # rank within expert segment
    idx = jnp.arange(t * k, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.array([True]), e_sorted[1:] != e_sorted[:-1]]
    )
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0)
    )
    rank = idx - seg_start
    ok = (e_sorted < e_loc) & (rank < cap)
    slot = jnp.where(ok, e_sorted * cap + rank, e_loc * cap)
    buffer = jnp.zeros((e_loc * cap, d), tokens.dtype).at[slot].set(
        tokens[flat_tok[order]], mode="drop"
    )
    buf_gate = jnp.zeros((e_loc * cap,), jnp.float32).at[slot].set(
        flat_gate[order], mode="drop"
    )
    src = jnp.full((e_loc * cap,), -1, jnp.int32).at[slot].set(
        flat_tok[order], mode="drop"
    )
    return buffer, buf_gate, src


def moe_local_forward(p_experts, cfg, tokens, sel, gates, e0, e_loc):
    """Per-shard expert compute: dispatch -> GLU experts -> weighted return.
    tokens: (T, d). Returns partial (T, d) covering local experts only."""
    t, d = tokens.shape
    cap = int(t * cfg.top_k / cfg.num_experts * cfg.capacity_factor) + 1
    buffer, buf_gate, src = _dispatch_local(tokens, sel, gates, e0, e_loc, cap)
    xb = buffer.reshape(e_loc, cap, d)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xb, p_experts["w_gate"])
    ) * jnp.einsum("ecd,edf->ecf", xb, p_experts["w_up"])
    yb = jnp.einsum("ecf,efd->ecd", h, p_experts["w_down"])
    y_flat = yb.reshape(e_loc * cap, d) * buf_gate[:, None].astype(yb.dtype)
    out = jnp.zeros((t, d), yb.dtype).at[
        jnp.where(src >= 0, src, t)
    ].add(y_flat, mode="drop")
    return out


def moe_forward(p, cfg, x, *, axis_name=None):
    """x: (B, S, d). Inside shard_map (axis_name set) the expert weights
    arrive pre-sharded along the expert dim (block (E_loc, ...)); the local
    expert range is derived from the block shape and axis index, and partial
    outputs are psum-combined across the axis."""
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    logits = tokens.astype(jnp.float32) @ p["router"]
    sel, gates = ROUTERS[cfg.router](logits, cfg.top_k)
    e_loc = p["w_gate"].shape[0]
    if axis_name is not None:
        e0 = jax.lax.axis_index(axis_name) * e_loc
    else:
        e0 = 0
    experts = {k_: p[k_] for k_ in ("w_gate", "w_up", "w_down")}
    out = moe_local_forward(experts, cfg, tokens, sel, gates, e0, e_loc)
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)
    out = out.reshape(b, s, d).astype(x.dtype)
    if cfg.num_shared_experts:
        out = out + glu_mlp(p["shared"], x)
    return out


def load_balance_stats(logits, sel, num_experts):
    """Aux metrics: expert load entropy + max/mean load ratio."""
    t = sel.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[sel.reshape(-1)].add(1.0)
    load = counts / jnp.maximum(counts.sum(), 1.0)
    entropy = -jnp.sum(load * jnp.log(load + 1e-9))
    imbalance = jnp.max(counts) / jnp.maximum(counts.mean(), 1e-9)
    return {"load_entropy": entropy, "load_imbalance": imbalance}
