"""Shared neural building blocks (pure-JAX, no flax): norms, RoPE, GLU MLP,
embeddings, chunked cross-entropy. Parameters are plain pytrees of arrays;
every apply function is functional."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def rmsnorm(w, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def glu_mlp_init(key, d, ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _init(k1, (d, ff), dtype=dtype),
        "w_up": _init(k2, (d, ff), dtype=dtype),
        "w_down": _init(k3, (ff, d), dtype=dtype),
    }


def glu_mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def embed_init(key, vocab, d, dtype=jnp.float32):
    return _init(key, (vocab, d), scale=0.02, dtype=dtype)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def cross_entropy_chunked(logits_fn, x, labels, mask, chunk: int = 512):
    """Streaming CE over sequence chunks so the (B, S, V) logits tensor is
    never materialized in full. ``logits_fn(x_chunk) -> (B, c, V)``."""
    b, s, _ = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // chunk

    def body(carry, idx):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        logits = logits_fn(xs).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms
        return (tot + jnp.sum(nll), cnt + jnp.sum(ms)), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), jnp.arange(nc))
    return tot / jnp.maximum(cnt, 1.0)
