"""Logical-axis sharding helpers.

Logical axes: 'dp' (batch / FSDP shard axis -> physical ('pod', 'data')),
'tp' (tensor/expert parallel -> physical 'model'). Models only speak logical
axes; this module resolves them against the active mesh configuration, and
every helper degrades to a no-op when no mesh is configured (single-device
smoke tests)."""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map  # noqa: F401  (models call
# sharding.shard_map; the version-drift handling lives in repro.compat)

_STATE = {"mesh": None, "dp": ("pod", "data"), "tp": "model"}


def set_mesh(mesh: Optional[Mesh], dp=None, tp=None) -> None:
    _STATE["mesh"] = mesh
    if mesh is not None:
        names = mesh.axis_names
        if dp is None:
            dp = tuple(n for n in names if n != "model")
        if tp is None:
            tp = "model" if "model" in names else None
        _STATE["dp"] = tuple(dp) if isinstance(dp, (list, tuple)) else (dp,)
        _STATE["tp"] = tp


def get_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


def _resolve(axis):
    if axis is None:
        return None
    if axis == "dp":
        dp = _STATE["dp"]
        return dp if len(dp) > 1 else dp[0]
    if axis == "tp":
        return _STATE["tp"]
    return axis


def pspec(*axes) -> P:
    return P(*[_resolve(a) for a in axes])


def constrain(x, *axes):
    """with_sharding_constraint on logical axes; no-op without a mesh."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, pspec(*axes))
    )


def named(*axes) -> Optional[NamedSharding]:
    mesh = _STATE["mesh"]
    if mesh is None:
        return None
    return NamedSharding(mesh, pspec(*axes))


# --------------------------------------------------------------------------
# Parameter sharding rules (FSDP over 'dp' + tensor/expert parallel on 'tp')
# --------------------------------------------------------------------------

_RULES = {
    # (parent, name) or name -> logical axes for the *unstacked* leaf
    "embed": ("tp", "dp"),
    "lm_head": ("dp", "tp"),
    "final_norm": (None,),
    "wq": ("dp", "tp"), "wk": ("dp", "tp"), "wv": ("dp", "tp"),
    "wo": ("tp", "dp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "q_norm": (None,), "k_norm": (None,),
    "ln1": (None,), "ln2": (None,), "ln_x": (None,),
    "w_gate": ("dp", "tp"), "w_up": ("dp", "tp"), "w_down": ("tp", "dp"),
    ("moe", "router"): ("dp", None),
    ("moe", "w_gate"): ("tp", "dp", None),
    ("moe", "w_up"): ("tp", "dp", None),
    ("moe", "w_down"): ("tp", None, "dp"),
    "in_z": ("dp", "tp"), "in_x": ("dp", "tp"), "in_dt": ("dp", "tp"),
    "in_b": ("dp", None), "in_c": ("dp", None),
    "conv_x": (None, "tp"), "conv_b": (None, None), "conv_c": (None, None),
    "conv_bias_x": ("tp",), "conv_bias_b": (None,), "conv_bias_c": (None,),
    "a_log": ("tp",), "d_skip": ("tp",), "dt_bias": ("tp",),
    "norm_w": ("tp",), "out_proj": ("tp", "dp"),
}


def _leaf_rule(path, leaf):
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1] if keys else ""
    parent = keys[-2] if len(keys) >= 2 else ""
    rule = _RULES.get((parent, name), _RULES.get(name))
    if rule is None:
        rule = (None,) * leaf.ndim
    # stacked stage leaves carry a leading period axis
    pad = leaf.ndim - len(rule)
    rule = (None,) * pad + tuple(rule)
    return pspec(*rule)


def param_pspecs(params):
    """PartitionSpec tree matching a (possibly abstract) param tree."""
    return jax.tree_util.tree_map_with_path(_leaf_rule, params)


def param_shardings(params):
    mesh = _STATE["mesh"]
    assert mesh is not None
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _leaf_rule(p, l)), params
    )
