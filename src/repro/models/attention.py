"""GQA attention: flash-style chunked softmax for train/prefill, plain
KV-cache attention for decode (decode shards the cache on the sequence axis
across 'model' - FlashDecoding-style - via sharding constraints; the SPMD
partitioner turns the softmax reductions into the partial-stat collectives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _init, apply_rope, rmsnorm

NEG_INF = -1e30


def attn_init(key, cfg, dtype=jnp.float32):
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": _init(ks[0], (d, h * dh), dtype=dtype),
        "wk": _init(ks[1], (d, kvh * dh), dtype=dtype),
        "wv": _init(ks[2], (d, kvh * dh), dtype=dtype),
        "wo": _init(ks[3], (h * dh, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kvh * dh,), dtype)
        p["bv"] = jnp.zeros((kvh * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _project_qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kvh, dh)
    v = v.reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention(q, k, v, *, causal: bool, q_block: int = 512,
                    kv_block: int = 1024):
    """Online-softmax attention. q: (B, Sq, H, Dh); k/v: (B, Sk, KvH, Dh).

    The (q-block, kv-block) iteration space is flattened to a static list of
    *causally intersecting* pairs and processed by one lax.scan - FLOPs are
    ~half of the rectangular masked version for causal self-attention, and
    the (Sq, Sk) score matrix is never materialized. GQA via head-group
    reshape. Peak intermediate: (B, KvH, g, q_block, kv_block).
    """
    in_dtype = q.dtype
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = dh ** -0.5
    q = (q * scale).astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    pq = (-sq) % q_block
    pk = (-sk) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = q.shape[1] // q_block
    nk = k.shape[1] // kv_block
    qr = q.reshape(b, nq, q_block, kvh, g, dh).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(b, nk, kv_block, kvh, dh)
    vr = v.reshape(b, nk, kv_block, kvh, dh)
    # qr: (nq, B, KvH, g, qb, Dh)

    if causal:
        pairs = [(qi, ki) for qi in range(nq) for ki in range(nk)
                 if ki * kv_block < (qi + 1) * q_block]
    else:
        pairs = [(qi, ki) for qi in range(nq) for ki in range(nk)]
    qi_arr = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.array([p[1] for p in pairs], jnp.int32)

    m0 = jnp.full((nq, b, kvh, g, q_block), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, kvh, g, q_block), jnp.float32)
    a0 = jnp.zeros((nq, b, kvh, g, q_block, dh), jnp.float32)

    def body(carry, t):
        m, l, acc = carry
        qi, ki = qi_arr[t], ki_arr[t]
        qb_ = jax.lax.dynamic_index_in_dim(qr, qi, 0, keepdims=False)
        kb_ = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
        vb_ = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
        s_ = jnp.einsum("bhgqd,bkhd->bhgqk", qb_, kb_)
        k_pos = ki * kv_block + jnp.arange(kv_block)
        valid = k_pos[None, :] < sk
        if causal:
            q_pos = qi * q_block + jnp.arange(q_block)
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        s_ = jnp.where(valid[None, None, None], s_, NEG_INF)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_old, jnp.max(s_, axis=-1))
        p_ = jnp.exp(s_ - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l_new = l_old * corr + jnp.sum(p_, axis=-1)
        a_new = a_old * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p_, vb_
        )
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(len(pairs)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (nq, B, KvH, g, qb, Dh) -> (B, S, H, Dh)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_block, h, dh)
    return out[:, :sq].astype(in_dtype)


def attn_forward(p, cfg, x, positions, *, causal=True):
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = flash_attention(q, k, v, causal=causal)
    return out.reshape(b, s, -1) @ p["wo"]


def cross_attn_forward(p, cfg, x, memory):
    """Decoder cross-attention onto encoder memory (no RoPE, not causal)."""
    b, s, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (memory @ p["wk"]).reshape(b, memory.shape[1], kvh, dh)
    v = (memory @ p["wv"]).reshape(b, memory.shape[1], kvh, dh)
    out = flash_attention(q, k, v, causal=False)
    return out.reshape(b, s, -1) @ p["wo"]


def attn_prefill(p, cfg, x, positions):
    """Returns (out, (k_cache, v_cache)) for serving."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = flash_attention(q, k, v, causal=True)
    return out.reshape(b, s, -1) @ p["wo"], (k, v)


def attn_decode(p, cfg, x, cache, pos):
    """One-token decode. cache: (k, v) each (B, S_max, KvH, Dh); pos ()."""
    b, s, _ = x.shape  # s == 1
    k_cache, v_cache = cache
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    s_max = k_cache.shape[1]
    if cfg.fast_decode_math:
        # read the cache ONCE in its storage dtype; fp32 accumulation via
        # preferred_element_type - no materialized fp32 cache copies.
        qg = (q * dh ** -0.5).reshape(b, 1, kvh, g, dh).astype(k_cache.dtype)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                            preferred_element_type=jnp.float32)
        valid = jnp.arange(s_max)[None, None, None, None, :] <= pos
        scores = jnp.where(valid, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(k_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
    else:
        qg = (q * dh ** -0.5).reshape(b, 1, kvh, g, dh).astype(jnp.float32)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                            k_cache.astype(jnp.float32))
        valid = jnp.arange(s_max)[None, None, None, None, :] <= pos
        scores = jnp.where(valid, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w,
                         v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, h * dh).astype(x.dtype)
    return out @ p["wo"], (k_cache, v_cache)
