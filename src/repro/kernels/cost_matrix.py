"""Pallas TPU kernels for pairwise cost matrices (the paper's experiment
inputs: Euclidean on 2-D points, L1 on normalized images).

sqeuclidean/euclidean use the MXU through the Gram identity
``|x|^2 + |y|^2 - 2 x.y^T`` - the kernel is one (BM, D) x (D, BN) matmul per
tile plus a VPU epilogue. L1 has no matmul form; the kernel streams the
feature axis in chunks of K to bound the (BM, BN, K) broadcast in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqeuclid_kernel(x_ref, y_ref, o_ref, *, euclid: bool):
    x = x_ref[...]
    y = y_ref[...]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    y2 = jnp.sum(y * y, axis=1, keepdims=True)
    g = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d = jnp.maximum(x2 + y2.T - 2.0 * g, 0.0)
    o_ref[...] = jnp.sqrt(d + 1e-30) if euclid else d


def _l1_kernel(x_ref, y_ref, o_ref, *, k: int, d: int):
    bm = x_ref.shape[0]
    bn = y_ref.shape[0]
    steps = d // k

    def body(s, acc):
        xc = x_ref[:, pl.dslice(s * k, k)]
        yc = y_ref[:, pl.dslice(s * k, k)]
        return acc + jnp.sum(
            jnp.abs(xc[:, None, :] - yc[None, :, :]), axis=-1
        )

    o_ref[...] = jax.lax.fori_loop(
        0, steps, body, jnp.zeros((bm, bn), jnp.float32)
    )


def cost_matrix(
    x: jnp.ndarray,
    y: jnp.ndarray,
    metric: str = "sqeuclidean",
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 32,
    interpret: bool = True,
):
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2
    pm, pn = (-m) % block_m, (-n) % block_n
    pk = (-d) % block_k if metric == "l1" else 0
    x_p = jnp.pad(x.astype(jnp.float32), ((0, pm), (0, pk)))
    y_p = jnp.pad(y.astype(jnp.float32), ((0, pn), (0, pk)))
    mp, np_, dp = m + pm, n + pn, d + pk
    grid = (mp // block_m, np_ // block_n)

    if metric in ("sqeuclidean", "euclidean"):
        kern = functools.partial(_sqeuclid_kernel, euclid=metric == "euclidean")
    elif metric == "l1":
        kern = functools.partial(_l1_kernel, k=block_k, d=dp)
    else:
        raise ValueError(metric)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(x_p, y_p)
    return out[:m, :n]
