"""Pallas TPU kernels for pairwise cost matrices (the paper's experiment
inputs: Euclidean on 2-D points, L1 on normalized images).

sqeuclidean/euclidean use the MXU through the Gram identity
``|x|^2 + |y|^2 - 2 x.y^T`` - the kernel is one (BM, D) x (D, BN) matmul per
tile plus a VPU epilogue. L1 has no matmul form; the kernel streams the
feature axis in chunks of K to bound the (BM, BN, K) broadcast in VMEM.

``cost_matrix_batched`` adds a leading batch axis to the grid — grid
(B, m/BM, n/BN), one instance per leading index, mirroring
``slack_propose_batched``'s layout — so a whole shape bucket of point
clouds becomes ONE kernel launch. Both variants share the same tile bodies,
so each batch slice is bit-identical to the unbatched kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .slack_propose import _resolve_interpret


def _sqeuclid_tile(x, y, euclid: bool):
    """Shared (BM, D) x (BN, D) -> (BM, BN) tile body."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    y2 = jnp.sum(y * y, axis=1, keepdims=True)
    g = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d = jnp.maximum(x2 + y2.T - 2.0 * g, 0.0)
    return jnp.sqrt(d + 1e-30) if euclid else d


def _sqeuclid_kernel(x_ref, y_ref, o_ref, *, euclid: bool):
    o_ref[...] = _sqeuclid_tile(x_ref[...], y_ref[...], euclid)


def _sqeuclid_kernel_batched(x_ref, y_ref, o_ref, *, euclid: bool):
    o_ref[0] = _sqeuclid_tile(x_ref[0], y_ref[0], euclid)


def _l1_tile(x_ref, y_ref, k: int, d: int, bm: int, bn: int, batched: bool):
    """Shared L1 tile body: stream the feature axis in chunks of k."""
    steps = d // k

    def load(ref, s):
        if batched:
            return ref[0, :, pl.dslice(s * k, k)]
        return ref[:, pl.dslice(s * k, k)]

    def body(s, acc):
        xc = load(x_ref, s)
        yc = load(y_ref, s)
        return acc + jnp.sum(
            jnp.abs(xc[:, None, :] - yc[None, :, :]), axis=-1
        )

    return jax.lax.fori_loop(
        0, steps, body, jnp.zeros((bm, bn), jnp.float32)
    )


def _l1_kernel(x_ref, y_ref, o_ref, *, k: int, d: int):
    bm, bn = x_ref.shape[0], y_ref.shape[0]
    o_ref[...] = _l1_tile(x_ref, y_ref, k, d, bm, bn, batched=False)


def _l1_kernel_batched(x_ref, y_ref, o_ref, *, k: int, d: int):
    bm, bn = x_ref.shape[1], y_ref.shape[1]
    o_ref[0] = _l1_tile(x_ref, y_ref, k, d, bm, bn, batched=True)


def cost_matrix(
    x: jnp.ndarray,
    y: jnp.ndarray,
    metric: str = "sqeuclidean",
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 32,
    interpret: bool | None = None,
):
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2
    pm, pn = (-m) % block_m, (-n) % block_n
    pk = (-d) % block_k if metric == "l1" else 0
    x_p = jnp.pad(x.astype(jnp.float32), ((0, pm), (0, pk)))
    y_p = jnp.pad(y.astype(jnp.float32), ((0, pn), (0, pk)))
    mp, np_, dp = m + pm, n + pn, d + pk
    grid = (mp // block_m, np_ // block_n)

    if metric in ("sqeuclidean", "euclidean"):
        kern = functools.partial(_sqeuclid_kernel, euclid=metric == "euclidean")
    elif metric == "l1":
        kern = functools.partial(_l1_kernel, k=block_k, d=dp)
    else:
        raise ValueError(metric)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(x_p, y_p)
    return out[:m, :n]


def cost_matrix_batched(
    x: jnp.ndarray,
    y: jnp.ndarray,
    metric: str = "sqeuclidean",
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 32,
    interpret: bool | None = None,
):
    """Batched pairwise costs: (B, m, d) x (B, n, d) -> (B, m, n).

    One kernel launch for the whole batch, grid (B, m/BM, n/BN); each batch
    slice is bit-identical to ``cost_matrix`` on that instance (identical
    tile bodies, identical padded-tile handling)."""
    b, m, d = x.shape
    b2, n, d2 = y.shape
    assert b == b2 and d == d2
    pm, pn = (-m) % block_m, (-n) % block_n
    pk = (-d) % block_k if metric == "l1" else 0
    x_p = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pm), (0, pk)))
    y_p = jnp.pad(y.astype(jnp.float32), ((0, 0), (0, pn), (0, pk)))
    mp, np_, dp = m + pm, n + pn, d + pk
    grid = (b, mp // block_m, np_ // block_n)

    if metric in ("sqeuclidean", "euclidean"):
        kern = functools.partial(_sqeuclid_kernel_batched,
                                 euclid=metric == "euclidean")
    elif metric == "l1":
        kern = functools.partial(_l1_kernel_batched, k=block_k, d=dp)
    else:
        raise ValueError(metric)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, dp), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_n, dp), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda g, i, j: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, mp, np_), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(x_p, y_p)
    return out[:, :m, :n]
