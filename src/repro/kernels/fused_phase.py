"""Pallas kernel: a full k-phase push-relabel dispatch with state in VMEM.

The stepped cores (``core/pushrelabel.run_assignment_phases`` /
``core/transport.run_ot_phases``) round-trip the solver state through
XLA/HBM between the slack/propose kernel and the push/relabel updates on
every propose round. This kernel fuses the whole chunk — slack +
propose/accept + push + relabel, for up to ``k`` phases — into ONE
``pallas_call``: the state (duals, matching/flows, free mask) is read into
VMEM registers once, the nested phase/round ``lax.while_loop``s run inside
the kernel body, and the state is written back exactly once per dispatch.

Bit parity with the stepped cores is a hard contract (the compacting and
mesh drivers interleave fused and stepped programs freely), which pins
three things:

  * the hash is the identical ``_mix`` chain over the identical
    ``row * H1 + col * H2 + salt_round * H3`` preimage, with
    ``salt_round = phases * 7919 + round`` (constants shared with
    ``core/matching`` / ``kernels/slack_propose``);
  * scatter/gather steps of the stepped cores are re-expressed as dense
    one-hot reductions with *identical* tie-breaking: ``argmin`` becomes
    min-key + first-min-index, per-column winner selection becomes a
    masked row-iota min, and the OT FIFO grant prefix becomes a one-hot
    masked min of the exclusive row cumsum;
  * round/phase caps come from the LOGICAL (pre-tile-padding) shape, so
    the loop trip counts equal the stepped cores' exactly.

Tile padding: inputs are padded up to (block_m, block_n) multiples before
the call (whole-array blocks — the k-phase loop needs every tile resident,
so block sizes here choose the *pad granularity*, aligning the arrays to
the backend's native tile). Padded rows carry zero supply/free mass and
padded columns are never admissible (``avail = 0`` / zero capacity +
``PAD_COST``), the same born-inert convention the bucketed batch drivers
use, so the padded trajectory equals the unpadded one bit for bit.

The kernel is shape-generic per instance; the batch grid comes from the
drivers ``vmap``-ing the jitted wrappers in ``kernels/ops.py`` (exactly
how ``slack_propose_batched`` acquires its leading grid axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .slack_propose import _H1, _H2, _H3, _UMAX, _mix, _resolve_interpret

# Sentinel cost for tile-padded edges; must match core.pushrelabel.PAD_COST
# (duals can never sum to it, so padded edges are never admissible).
_PAD_COST = 1 << 26

_I32_MAX = jnp.iinfo(jnp.int32).max


def _iotas(mp: int, np_: int):
    row_i = jax.lax.broadcasted_iota(jnp.int32, (mp, 1), 0)
    col_i = jax.lax.broadcasted_iota(jnp.int32, (1, np_), 1)
    return row_i, col_i


def _keys(row_u, col_u, salt_round):
    """uint32 proposal keys, identical to ``matching.proposal_keys``."""
    return _mix(row_u + col_u + salt_round.astype(jnp.uint32)
                * jnp.uint32(_H3))


def _first_min_col(keys, col_i, col_real, np_: int):
    """First column index attaining the row-min key, restricted to logical
    columns — ``jnp.argmin(keys, axis=1)`` re-expressed without gather
    (padded columns hold UMAX so they never beat a logical min, and the
    ``col_real`` mask keeps them out of the index min even on all-UMAX
    rows, where argmin's first-min falls on column 0)."""
    rowmin = jnp.min(keys, axis=1, keepdims=True)
    return jnp.min(
        jnp.where((keys == rowmin) & col_real, col_i, jnp.int32(np_)),
        axis=1, keepdims=True,
    )


# --------------------------------------------------------------------------
# Assignment (Algorithm 1): k phases of matching + push + relabel
# --------------------------------------------------------------------------


def _assignment_kernel(c_ref, mba_ref, mab_ref, yb_ref, ya_ref, scal_ref,
                       mba_out, mab_out, yb_out, ya_out, scal_out,
                       *, m: int, n: int, k: int):
    mp, np_ = c_ref.shape
    c = c_ref[...]
    scal = scal_ref[...]
    phases0, rounds0, sum0 = scal[0, 0], scal[0, 1], scal[0, 2]
    threshold, phase_cap, m_valid = scal[0, 3], scal[0, 4], scal[0, 5]

    row_i, col_i = _iotas(mp, np_)
    row_ok = row_i < m_valid            # m_valid <= m: tile pad rows excluded
    col_real = col_i < n
    row_u = row_i.astype(jnp.uint32) * jnp.uint32(_H1)
    col_u = col_i.astype(jnp.uint32) * jnp.uint32(_H2)
    mm_cap = jnp.int32(min(m, n) + 1)   # logical-shape round cap
    start = phases0

    def phase_cond(s):
        mba, _, _, _, phases, _, _ = s
        free = jnp.sum(((mba < 0) & row_ok).astype(jnp.int32))
        return ((free > threshold) & (phases < phase_cap)
                & (phases - start < jnp.int32(k)))

    def phase_body(s):
        mba, mab, yb, ya, phases, rounds, sum_ni = s
        in_bp = (mba < 0) & row_ok                        # B' (mp, 1)

        # (I) greedy maximal matching M' (matching.greedy_maximal_matching)
        def mm_cond(t):
            _, _, _, r, done = t
            return (~done) & (r < mm_cap)

        def mm_body(t):
            mpb, avail, active, r, _ = t
            keys = _keys(row_u, col_u, phases * jnp.int32(7919) + r)
            adm = (yb + ya == c + 1) & avail
            keys = jnp.where(adm, keys, jnp.uint32(_UMAX))
            best = _first_min_col(keys, col_i, col_real, np_)
            has_prop = jnp.any(adm, axis=1, keepdims=True) & active
            prop = has_prop & (best == col_i)             # one-hot proposals
            # accept: per column, lowest-index proposing row wins
            winners = jnp.min(jnp.where(prop, row_i, jnp.int32(mp)),
                              axis=0, keepdims=True)
            won_edge = prop & (winners == row_i)
            won = jnp.any(won_edge, axis=1, keepdims=True)
            taken = jnp.any(won_edge, axis=0, keepdims=True)
            return (jnp.where(won, best, mpb), avail & ~taken,
                    active & ~won, r + 1, ~jnp.any(has_prop))

        mpb, _, _, mm_rounds, _ = jax.lax.while_loop(
            mm_cond, mm_body,
            (jnp.full((mp, 1), -1, jnp.int32), col_real, in_bp,
             jnp.int32(0), jnp.bool_(False)),
        )

        # (II) push: add M' to M, displacing old partners of M' columns
        won = mpb >= 0
        newmat = won & (mpb == col_i)                     # one-hot M'
        col_new = jnp.any(newmat, axis=0, keepdims=True)
        displaced = (mba >= 0) & jnp.any((mba == col_i) & col_new,
                                         axis=1, keepdims=True)
        mba = jnp.where(won, mpb,
                        jnp.where(displaced, jnp.int32(-1), mba))
        new_row = jnp.min(jnp.where(newmat, row_i, jnp.int32(mp)),
                          axis=0, keepdims=True)
        mab = jnp.where(col_new, new_row, mab)
        # (III) relabel
        ya = ya - col_new.astype(jnp.int32)
        yb = yb + (in_bp & ~won).astype(jnp.int32)
        return (mba, mab, yb, ya, phases + 1, rounds + mm_rounds,
                sum_ni + jnp.sum(in_bp.astype(jnp.int32)))

    mba, mab, yb, ya, phases, rounds, sum_ni = jax.lax.while_loop(
        phase_cond, phase_body,
        (mba_ref[...], mab_ref[...], yb_ref[...], ya_ref[...],
         phases0, rounds0, sum0),
    )
    mba_out[...] = mba
    mab_out[...] = mab
    yb_out[...] = yb
    ya_out[...] = ya
    scal_out[...] = jnp.stack(
        [phases, rounds, sum_ni, threshold, phase_cap, m_valid,
         jnp.int32(0), jnp.int32(0)]
    ).reshape(1, 8)


def _pad2(x, mp, np_, value):
    m, n = x.shape
    if (m, n) == (mp, np_):
        return x
    return jnp.pad(x, ((0, mp - m), (0, np_ - n)), constant_values=value)


def fused_assignment_phases(
    c_int, match_ba, match_ab, y_b, y_a, phases, rounds, sum_ni,
    threshold, phase_cap, m_valid, *, k: int,
    block_m: int = 8, block_n: int = 128, interpret: bool | None = None,
):
    """At most ``k`` assignment phases in one fused kernel launch.

    Array arguments are the ``PushRelabelState`` fields plus the traced
    termination operands; returns the updated fields in the same order
    (the jitted wrapper in ``kernels/ops.py`` re-wraps the NamedTuple).
    Bit-identical to chaining ``assignment_phase`` for every ``k``.
    """
    m, n = c_int.shape
    mp = m + (-m) % block_m
    np_ = n + (-n) % block_n
    c_p = _pad2(c_int, mp, np_, _PAD_COST)
    mba_p = jnp.pad(match_ba, (0, mp - m),
                    constant_values=-1).reshape(mp, 1)
    yb_p = jnp.pad(y_b, (0, mp - m)).reshape(mp, 1)
    mab_p = jnp.pad(match_ab, (0, np_ - n),
                    constant_values=-1).reshape(1, np_)
    ya_p = jnp.pad(y_a, (0, np_ - n)).reshape(1, np_)
    scal = jnp.stack([
        jnp.asarray(phases, jnp.int32), jnp.asarray(rounds, jnp.int32),
        jnp.asarray(sum_ni, jnp.int32), jnp.asarray(threshold, jnp.int32),
        jnp.asarray(phase_cap, jnp.int32), jnp.asarray(m_valid, jnp.int32),
        jnp.int32(0), jnp.int32(0),
    ]).reshape(1, 8)
    i32 = jnp.int32
    mba, mab, yb, ya, scal = pl.pallas_call(
        functools.partial(_assignment_kernel, m=m, n=n, k=k),
        out_shape=[
            jax.ShapeDtypeStruct((mp, 1), i32),
            jax.ShapeDtypeStruct((1, np_), i32),
            jax.ShapeDtypeStruct((mp, 1), i32),
            jax.ShapeDtypeStruct((1, np_), i32),
            jax.ShapeDtypeStruct((1, 8), i32),
        ],
        interpret=_resolve_interpret(interpret),
    )(c_p, mba_p, mab_p, yb_p, ya_p, scal)
    return (mba[:m, 0], mab[0, :n], yb[:m, 0], ya[0, :n],
            scal[0, 0], scal[0, 1], scal[0, 2])


# --------------------------------------------------------------------------
# General OT (Algorithm 2): k phases of capacity grants + push + relabel
# --------------------------------------------------------------------------


def _ot_kernel(c_ref, yb_ref, yahi_ref, fb_ref, fa_ref, fhi_ref, flo_ref,
               scal_ref, yb_out, yahi_out, fb_out, fa_out, fhi_out,
               flo_out, scal_out, *, n: int, k: int, max_rounds: int):
    nbp, nap = c_ref.shape
    c = c_ref[...]
    scal = scal_ref[...]
    phases0, rounds0 = scal[0, 0], scal[0, 1]
    threshold, phase_cap = scal[0, 2], scal[0, 3]

    row_i, col_i = _iotas(nbp, nap)
    col_real = col_i < n
    row_u = row_i.astype(jnp.uint32) * jnp.uint32(_H1)
    col_u = col_i.astype(jnp.uint32) * jnp.uint32(_H2)
    big = jnp.int32(_I32_MAX)
    start = phases0

    def phase_cond(s):
        _, _, fb, _, _, _, phases, _ = s
        free = jnp.sum(fb)
        return ((free > threshold) & (phases < phase_cap)
                & (phases - start < jnp.int32(k)))

    def phase_body(s):
        yb, yahi, fb, fa, fhi, flo, phases, rounds = s
        # hi-cluster capacity available to M' (transport._phase)
        cap0 = jnp.where(yahi == 0, fa, 0) + jnp.sum(fhi, axis=0,
                                                     keepdims=True)

        def g_cond(t):
            _, _, _, r, done = t
            return (~done) & (r < jnp.int32(max_rounds))

        def g_body(t):
            rem, cap, granted, r, _ = t
            keys = _keys(row_u, col_u, phases * jnp.int32(7919) + r)
            adm = (yb + yahi == c + 1) & (cap > 0)
            keys = jnp.where(adm, keys, jnp.uint32(_UMAX))
            best = _first_min_col(keys, col_i, col_real, nap)
            can = jnp.any(adm, axis=1, keepdims=True) & (rem > 0)
            prop = can & (best == col_i)                # one-hot proposals
            # FIFO grants by row order: segmented exclusive prefix of the
            # proposal amounts (transport._grant_round), one-hot reduced
            amt = jnp.where(can, rem, 0)
            excl = jnp.cumsum(amt, axis=0) - amt        # (nbp, 1)
            base = jnp.min(
                jnp.where(prop, jnp.broadcast_to(excl, (nbp, nap)), big),
                axis=0, keepdims=True)                  # per-col min excl
            base_t = jnp.min(jnp.where(prop, base, big), axis=1,
                             keepdims=True)             # base[tgt] per row
            cap_t = jnp.min(jnp.where(prop, cap, big), axis=1,
                            keepdims=True)              # cap_a[tgt] per row
            prefix = excl - jnp.where(can, base_t, 0)
            grant = jnp.where(can, jnp.clip(cap_t - prefix, 0, amt), 0)
            g_edge = jnp.where(prop, grant, 0)
            return (rem - grant,
                    cap - jnp.sum(g_edge, axis=0, keepdims=True),
                    granted + g_edge, r + 1, ~jnp.any(can))

        rem, _, granted, g_rounds, _ = jax.lax.while_loop(
            g_cond, g_body,
            (fb, cap0, jnp.zeros((nbp, nap), jnp.int32),
             jnp.int32(0), jnp.bool_(False)),
        )

        # push: displaced hi flow stripped bottom rows first
        g_a = jnp.sum(granted, axis=0, keepdims=True)
        use_free = jnp.minimum(g_a, jnp.where(yahi == 0, fa, 0))
        disp = g_a - use_free
        # suffix-exclusive column sums == reversed-cumsum form, exactly
        suffix_excl = (jnp.sum(fhi, axis=0, keepdims=True)
                       - jnp.cumsum(fhi, axis=0))
        take = jnp.clip(disp - suffix_excl, 0, fhi)
        fhi2 = fhi - take
        freed = jnp.sum(take, axis=1, keepdims=True)

        # relabel: granted copies drop one level; empty hi clusters collapse
        fa2 = fa - use_free
        hi_left = (jnp.where(yahi == 0, fa2, 0)
                   + jnp.sum(fhi2, axis=0, keepdims=True))
        collapse = (hi_left == 0) & (g_a > 0)
        yahi2 = jnp.where(collapse, yahi - 1, yahi)
        fhi3 = jnp.where(collapse, flo + granted, fhi2)
        flo3 = jnp.where(collapse, 0, flo + granted)
        yb2 = yb + ((fb > 0) & (rem > 0)).astype(jnp.int32)
        return (yb2, yahi2, rem + freed, fa2, fhi3, flo3,
                phases + 1, rounds + g_rounds)

    yb, yahi, fb, fa, fhi, flo, phases, rounds = jax.lax.while_loop(
        phase_cond, phase_body,
        (yb_ref[...], yahi_ref[...], fb_ref[...], fa_ref[...],
         fhi_ref[...], flo_ref[...], phases0, rounds0),
    )
    yb_out[...] = yb
    yahi_out[...] = yahi
    fb_out[...] = fb
    fa_out[...] = fa
    fhi_out[...] = fhi
    flo_out[...] = flo
    scal_out[...] = jnp.stack(
        [phases, rounds, threshold, phase_cap,
         jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0)]
    ).reshape(1, 8)


def fused_ot_phases(
    c_int, y_b, ya_hi, free_b, free_a, f_hi, f_lo, phases, rounds,
    threshold, phase_cap, *, k: int, max_rounds: int,
    block_m: int = 8, block_n: int = 128, interpret: bool | None = None,
):
    """At most ``k`` OT phases in one fused kernel launch; array arguments
    are the ``OTState`` fields. Bit-identical to ``transport._phase``
    chained under the ``run_ot_phases`` guard for every ``k``."""
    nb, na = c_int.shape
    nbp = nb + (-nb) % block_m
    nap = na + (-na) % block_n
    c_p = _pad2(c_int, nbp, nap, _PAD_COST)
    yb_p = jnp.pad(y_b, (0, nbp - nb)).reshape(nbp, 1)
    fb_p = jnp.pad(free_b, (0, nbp - nb)).reshape(nbp, 1)
    yahi_p = jnp.pad(ya_hi, (0, nap - na)).reshape(1, nap)
    fa_p = jnp.pad(free_a, (0, nap - na)).reshape(1, nap)
    fhi_p = _pad2(f_hi, nbp, nap, 0)
    flo_p = _pad2(f_lo, nbp, nap, 0)
    scal = jnp.stack([
        jnp.asarray(phases, jnp.int32), jnp.asarray(rounds, jnp.int32),
        jnp.asarray(threshold, jnp.int32), jnp.asarray(phase_cap, jnp.int32),
        jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
    ]).reshape(1, 8)
    i32 = jnp.int32
    yb, yahi, fb, fa, fhi, flo, scal = pl.pallas_call(
        functools.partial(_ot_kernel, n=na, k=k, max_rounds=max_rounds),
        out_shape=[
            jax.ShapeDtypeStruct((nbp, 1), i32),
            jax.ShapeDtypeStruct((1, nap), i32),
            jax.ShapeDtypeStruct((nbp, 1), i32),
            jax.ShapeDtypeStruct((1, nap), i32),
            jax.ShapeDtypeStruct((nbp, nap), i32),
            jax.ShapeDtypeStruct((nbp, nap), i32),
            jax.ShapeDtypeStruct((1, 8), i32),
        ],
        interpret=_resolve_interpret(interpret),
    )(c_p, yb_p, yahi_p, fb_p, fa_p, fhi_p, flo_p, scal)
    return (yb[:nb, 0], yahi[0, :na], fb[:nb, 0], fa[0, :na],
            fhi[:nb, :na], flo[:nb, :na], scal[0, 0], scal[0, 1])
