"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python-on-XLA semantics, which validates the exact tiling logic
that will run on TPU. On a TPU backend `interpret=False` compiles to Mosaic.

Block sizes are resolved per backend from ``_BLOCK_TABLE`` when a wrapper
is called without explicit overrides: TPU wants MXU/VPU-native 128-wide
tiles, GPU favors shorter row tiles (more blocks in flight per SM, still
128-wide for coalescing), CPU interpret mode keeps the TPU shapes so the
emulated tiling matches what ships. Explicit ``block_m=``/``block_n=``
always win (the block-size invariance tests sweep them).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import slack_propose as _sp
from . import cost_matrix as _cm
from . import sinkhorn_step as _ss
from . import fused_phase as _fp
from ..core.pushrelabel import PushRelabelState
from ..core.transport import OTState


def _interpret() -> bool:
    # single source of truth for the backend->interpret policy
    return _sp._resolve_interpret(None)


# Per-backend (block_m, block_n[, block_k]) defaults per kernel family.
# ``fused_phase`` blocks are a pad granularity (whole-array kernel), so the
# row tile is the narrow VMEM sublane count, not a grid tile.
_BLOCK_TABLE = {
    "tpu": {
        "slack_propose": (128, 128),
        "cost_matrix": (128, 128, 32),
        "sinkhorn_row_update": (128, 128),
        "fused_phase": (8, 128),
    },
    "gpu": {
        "slack_propose": (64, 128),
        "cost_matrix": (64, 128, 32),
        "sinkhorn_row_update": (64, 128),
        "fused_phase": (16, 128),
    },
    # interpret-mode backends (cpu et al.) mirror the TPU tiling so the
    # emulated kernels exercise the shipped block shapes
    "cpu": {
        "slack_propose": (128, 128),
        "cost_matrix": (128, 128, 32),
        "sinkhorn_row_update": (128, 128),
        "fused_phase": (8, 128),
    },
}


def kernel_blocks(kernel: str, backend: str | None = None) -> tuple:
    """Backend-tuned block sizes for ``kernel`` (see ``_BLOCK_TABLE``)."""
    backend = backend or jax.default_backend()
    table = _BLOCK_TABLE.get(backend, _BLOCK_TABLE["cpu"])
    return table[kernel]


def _blocks2(kernel: str, block_m, block_n) -> tuple:
    bm, bn = kernel_blocks(kernel)[:2]
    return (bm if block_m is None else block_m,
            bn if block_n is None else block_n)


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def slack_propose(c_int, y_b, y_a, avail_a, salt, *, block_m=None,
                  block_n=None):
    # interpret=None: resolved per-backend inside the kernel module
    # (compiled Mosaic on TPU, interpret elsewhere).
    block_m, block_n = _blocks2("slack_propose", block_m, block_n)
    return _sp.slack_propose(
        c_int, y_b, y_a, avail_a, salt,
        block_m=block_m, block_n=block_n, interpret=None,
    )


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def slack_propose_batched(c_int, y_b, y_a, avail_a, salt, *,
                          block_m=None, block_n=None):
    block_m, block_n = _blocks2("slack_propose", block_m, block_n)
    return _sp.slack_propose_batched(
        c_int, y_b, y_a, avail_a, salt,
        block_m=block_m, block_n=block_n, interpret=None,
    )


@partial(jax.jit, static_argnames=("metric", "block_m", "block_n", "block_k"))
def cost_matrix(x, y, metric="sqeuclidean", *, block_m=None, block_n=None,
                block_k=None):
    bm, bn, bk = kernel_blocks("cost_matrix")
    return _cm.cost_matrix(
        x, y, metric,
        block_m=block_m or bm, block_n=block_n or bn, block_k=block_k or bk,
        interpret=None,
    )


@partial(jax.jit, static_argnames=("metric", "block_m", "block_n", "block_k"))
def cost_matrix_batched(x, y, metric="sqeuclidean", *, block_m=None,
                        block_n=None, block_k=None):
    """(B, m, d) x (B, n, d) -> (B, m, n) in one kernel launch; grid
    (B, m/BM, n/BN), mirroring slack_propose_batched's layout."""
    bm, bn, bk = kernel_blocks("cost_matrix")
    return _cm.cost_matrix_batched(
        x, y, metric,
        block_m=block_m or bm, block_n=block_n or bn, block_k=block_k or bk,
        interpret=None,
    )


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def sinkhorn_row_update(c, g, log_nu, reg, *, block_m=None, block_n=None):
    # reg is a TRACED operand (the kernel reads it from a (1, 1) input):
    # one compiled program serves every accuracy, and the SINKHORN spec's
    # vmapped chunk dispatch can carry per-lane reg through it
    block_m, block_n = _blocks2("sinkhorn_row_update", block_m, block_n)
    return _ss.sinkhorn_row_update(
        c, g, log_nu, reg,
        block_m=block_m, block_n=block_n, interpret=None,
    )


# --------------------------------------------------------------------------
# Fused k-phase dispatches: drop-in replacements for the stepped cores'
# run_*_phases (same signature, same donation contract, bit-identical
# state trajectory), with the whole chunk in one pallas_call.
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("k", "block_m", "block_n"),
         donate_argnums=(1,))
def fused_run_assignment_phases(c_int, state: PushRelabelState, threshold,
                                phase_cap, k: int, m_valid=None, *,
                                block_m=None, block_n=None
                                ) -> PushRelabelState:
    """Fused counterpart of ``core.pushrelabel.run_assignment_phases``:
    at most ``k`` phases in ONE kernel launch, state resident in VMEM
    across the whole chunk. ``state`` is DONATED, exactly like the
    stepped core — callers must rebind."""
    m, n = c_int.shape
    block_m, block_n = _blocks2("fused_phase", block_m, block_n)
    mv = jnp.int32(m) if m_valid is None else jnp.asarray(m_valid, jnp.int32)
    (mba, mab, y_b, y_a, phases, rounds, sum_ni) = _fp.fused_assignment_phases(
        c_int, state.match_ba, state.match_ab, state.y_b, state.y_a,
        state.phases, state.rounds, state.sum_ni,
        threshold, phase_cap, mv,
        k=k, block_m=block_m, block_n=block_n, interpret=None,
    )
    return PushRelabelState(match_ba=mba, match_ab=mab, y_b=y_b, y_a=y_a,
                            phases=phases, rounds=rounds, sum_ni=sum_ni)


@partial(jax.jit, static_argnames=("k", "max_rounds", "block_m", "block_n"),
         donate_argnums=(1,))
def fused_run_ot_phases(c_int, state: OTState, threshold, phase_cap,
                        k: int, max_rounds: int, *, block_m=None,
                        block_n=None) -> OTState:
    """Fused counterpart of ``core.transport.run_ot_phases`` (state —
    dominated by the two (nb, na) flow matrices — stays in VMEM across
    the k-phase chunk; DONATED like the stepped core)."""
    block_m, block_n = _blocks2("fused_phase", block_m, block_n)
    (y_b, ya_hi, free_b, free_a, f_hi, f_lo, phases, rounds) = \
        _fp.fused_ot_phases(
            c_int, state.y_b, state.ya_hi, state.free_b, state.free_a,
            state.f_hi, state.f_lo, state.phases, state.rounds,
            threshold, phase_cap,
            k=k, max_rounds=max_rounds, block_m=block_m, block_n=block_n,
            interpret=None,
        )
    return OTState(y_b=y_b, ya_hi=ya_hi, free_b=free_b, free_a=free_a,
                   f_hi=f_hi, f_lo=f_lo, phases=phases, rounds=rounds)


def make_pallas_propose_fn(block_m: int | None = None,
                           block_n: int | None = None):
    """Adapter matching matching.greedy_maximal_matching's propose_fn
    signature, so the phase loop can run on the fused kernel."""
    block_m, block_n = _blocks2("slack_propose", block_m, block_n)

    def propose(c_int, y_b, y_a, active_b, avail_a, salt_round):
        col, key = _sp.slack_propose(
            c_int, y_b, y_a, avail_a, salt_round,
            block_m=block_m, block_n=block_n, interpret=None,
        )
        found = key != jnp.uint32(0xFFFFFFFF)
        return jnp.where(active_b & found, col, jnp.int32(-1))

    return propose


# --------------------------------------------------------------------------
# repro.analysis registration: the jitted Pallas wrappers. make_jaxpr only
# TRACES them (pallas_call becomes an eqn; the kernel body never executes),
# so registration-time tracing is cheap and backend-independent.
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_slack_propose():
    m = n = 128
    return _audit.trace_entry(
        name="kernels.ops.slack_propose",
        fn=lambda c_int, y_b, y_a, avail_a, salt: slack_propose(
            c_int, y_b, y_a, avail_a, salt),
        args={
            "c_int": jnp.zeros((m, n), jnp.int32),
            "y_b": jnp.zeros((m,), jnp.int32),
            "y_a": jnp.zeros((n,), jnp.int32),
            "avail_a": jnp.ones((n,), bool),
            "salt": jnp.uint32(0),
        },
        must_trace={"salt"},
        tags={"pallas", "assignment"},
        source=__name__,
    )


def _trace_cost_matrix(batched: bool):
    m, n, d = 128, 128, 32
    if batched:
        x = jnp.zeros((2, m, d), jnp.float32)
        y = jnp.zeros((2, n, d), jnp.float32)
        fn = lambda x, y: cost_matrix_batched(x, y)  # noqa: E731
        name = "kernels.ops.cost_matrix_batched"
    else:
        x = jnp.zeros((m, d), jnp.float32)
        y = jnp.zeros((n, d), jnp.float32)
        fn = lambda x, y: cost_matrix(x, y)  # noqa: E731
        name = "kernels.ops.cost_matrix"
    return _audit.trace_entry(
        name=name, fn=fn, args={"x": x, "y": y},
        tags={"pallas"}, source=__name__,
    )


def _trace_sinkhorn_row_update():
    m, n = 128, 128
    return _audit.trace_entry(
        name="kernels.ops.sinkhorn_row_update",
        fn=lambda c, g, log_nu, reg: sinkhorn_row_update(c, g, log_nu, reg),
        args={
            "c": jnp.zeros((m, n), jnp.float32),
            "g": jnp.zeros((n,), jnp.float32),
            "log_nu": jnp.zeros((m,), jnp.float32),
            "reg": jnp.float32(0.05),
        },
        must_trace={"reg"},
        tags={"pallas", "sinkhorn"},
        source=__name__,
    )


def _trace_fused_assignment():
    from ..core.pushrelabel import init_assignment_state

    m = n = 8
    return _audit.trace_entry(
        name="kernels.ops.fused_run_assignment_phases",
        fn=lambda c_int, state, threshold, phase_cap, m_valid:
            fused_run_assignment_phases(c_int, state, threshold, phase_cap,
                                        4, m_valid=m_valid),
        args={
            "c_int": jnp.zeros((m, n), jnp.int32),
            "state": init_assignment_state(m, n),
            "threshold": jnp.int32(0),
            "phase_cap": jnp.int32(8),
            "m_valid": jnp.int32(m),
        },
        donated={"state"},
        must_trace={"threshold", "phase_cap", "m_valid"},
        tags={"pallas", "stepped-core", "assignment", "fused"},
        source=__name__,
    )


def _trace_fused_ot():
    from ..core.transport import init_ot_state

    m = n = 8
    return _audit.trace_entry(
        name="kernels.ops.fused_run_ot_phases",
        fn=lambda c_int, state, threshold, phase_cap:
            fused_run_ot_phases(c_int, state, threshold, phase_cap, 4,
                                max_rounds=int(m + n + 2)),
        args={
            "c_int": jnp.zeros((m, n), jnp.int32),
            "state": init_ot_state(jnp.ones((m,), jnp.int32),
                                   jnp.ones((n,), jnp.int32)),
            "threshold": jnp.int32(0),
            "phase_cap": jnp.int32(8),
        },
        donated={"state"},
        must_trace={"threshold", "phase_cap"},
        tags={"pallas", "stepped-core", "ot", "fused"},
        source=__name__,
    )


_audit.register("kernels.ops.slack_propose", _trace_slack_propose,
                source=__name__)
_audit.register("kernels.ops.cost_matrix",
                lambda: _trace_cost_matrix(False), source=__name__)
_audit.register("kernels.ops.cost_matrix_batched",
                lambda: _trace_cost_matrix(True), source=__name__)
_audit.register("kernels.ops.sinkhorn_row_update", _trace_sinkhorn_row_update,
                source=__name__)
_audit.register("kernels.ops.fused_run_assignment_phases",
                _trace_fused_assignment, source=__name__)
_audit.register("kernels.ops.fused_run_ot_phases", _trace_fused_ot,
                source=__name__)
