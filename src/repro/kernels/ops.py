"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python-on-XLA semantics, which validates the exact tiling logic
that will run on TPU. On a TPU backend `interpret=False` compiles to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import slack_propose as _sp
from . import cost_matrix as _cm
from . import sinkhorn_step as _ss


def _interpret() -> bool:
    # single source of truth for the backend->interpret policy
    return _sp._resolve_interpret(None)


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def slack_propose(c_int, y_b, y_a, avail_a, salt, *, block_m=128, block_n=128):
    # interpret=None: resolved per-backend inside the kernel module
    # (compiled Mosaic on TPU, interpret elsewhere).
    return _sp.slack_propose(
        c_int, y_b, y_a, avail_a, salt,
        block_m=block_m, block_n=block_n, interpret=None,
    )


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def slack_propose_batched(c_int, y_b, y_a, avail_a, salt, *,
                          block_m=128, block_n=128):
    return _sp.slack_propose_batched(
        c_int, y_b, y_a, avail_a, salt,
        block_m=block_m, block_n=block_n, interpret=None,
    )


@partial(jax.jit, static_argnames=("metric", "block_m", "block_n", "block_k"))
def cost_matrix(x, y, metric="sqeuclidean", *, block_m=128, block_n=128,
                block_k=32):
    return _cm.cost_matrix(
        x, y, metric,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("metric", "block_m", "block_n", "block_k"))
def cost_matrix_batched(x, y, metric="sqeuclidean", *, block_m=128,
                        block_n=128, block_k=32):
    """(B, m, d) x (B, n, d) -> (B, m, n) in one kernel launch; grid
    (B, m/BM, n/BN), mirroring slack_propose_batched's layout."""
    return _cm.cost_matrix_batched(
        x, y, metric,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("reg", "block_m", "block_n"))
def sinkhorn_row_update(c, g, log_nu, reg, *, block_m=128, block_n=128):
    return _ss.sinkhorn_row_update(
        c, g, log_nu, reg,
        block_m=block_m, block_n=block_n, interpret=_interpret(),
    )


def make_pallas_propose_fn(block_m: int = 128, block_n: int = 128):
    """Adapter matching matching.greedy_maximal_matching's propose_fn
    signature, so the phase loop can run on the fused kernel."""

    def propose(c_int, y_b, y_a, active_b, avail_a, salt_round):
        col, key = _sp.slack_propose(
            c_int, y_b, y_a, avail_a, salt_round,
            block_m=block_m, block_n=block_n, interpret=None,
        )
        found = key != jnp.uint32(0xFFFFFFFF)
        return jnp.where(active_b & found, col, jnp.int32(-1))

    return propose


# --------------------------------------------------------------------------
# repro.analysis registration: the jitted Pallas wrappers. make_jaxpr only
# TRACES them (pallas_call becomes an eqn; the kernel body never executes),
# so registration-time tracing is cheap and backend-independent.
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_slack_propose():
    m = n = 128
    return _audit.trace_entry(
        name="kernels.ops.slack_propose",
        fn=lambda c_int, y_b, y_a, avail_a, salt: slack_propose(
            c_int, y_b, y_a, avail_a, salt),
        args={
            "c_int": jnp.zeros((m, n), jnp.int32),
            "y_b": jnp.zeros((m,), jnp.int32),
            "y_a": jnp.zeros((n,), jnp.int32),
            "avail_a": jnp.ones((n,), bool),
            "salt": jnp.uint32(0),
        },
        must_trace={"salt"},
        tags={"pallas", "assignment"},
        source=__name__,
    )


def _trace_cost_matrix(batched: bool):
    m, n, d = 128, 128, 32
    if batched:
        x = jnp.zeros((2, m, d), jnp.float32)
        y = jnp.zeros((2, n, d), jnp.float32)
        fn = lambda x, y: cost_matrix_batched(x, y)  # noqa: E731
        name = "kernels.ops.cost_matrix_batched"
    else:
        x = jnp.zeros((m, d), jnp.float32)
        y = jnp.zeros((n, d), jnp.float32)
        fn = lambda x, y: cost_matrix(x, y)  # noqa: E731
        name = "kernels.ops.cost_matrix"
    return _audit.trace_entry(
        name=name, fn=fn, args={"x": x, "y": y},
        tags={"pallas"}, source=__name__,
    )


def _trace_sinkhorn_row_update():
    m, n = 128, 128
    return _audit.trace_entry(
        name="kernels.ops.sinkhorn_row_update",
        fn=lambda c, g, log_nu: sinkhorn_row_update(c, g, log_nu, 0.05),
        args={
            "c": jnp.zeros((m, n), jnp.float32),
            "g": jnp.zeros((n,), jnp.float32),
            "log_nu": jnp.zeros((m,), jnp.float32),
        },
        tags={"pallas", "sinkhorn"},
        source=__name__,
    )


_audit.register("kernels.ops.slack_propose", _trace_slack_propose,
                source=__name__)
_audit.register("kernels.ops.cost_matrix",
                lambda: _trace_cost_matrix(False), source=__name__)
_audit.register("kernels.ops.cost_matrix_batched",
                lambda: _trace_cost_matrix(True), source=__name__)
_audit.register("kernels.ops.sinkhorn_row_update", _trace_sinkhorn_row_update,
                source=__name__)
