"""Pallas TPU kernel: fused slack + admissibility + hash-random proposal.

This is the n^2 hot loop of every push-relabel phase. The reference path
materializes three (m, n) intermediates in HBM (slack, admissible mask,
proposal keys); this kernel streams cost tiles HBM->VMEM once and emits only
two (m,) vectors (winning column + winning hash key), i.e. it is a pure
min-reduction over the column axis with everything fused into the tile.

Tiling: grid (m/BM, n/BN); the column axis is the reduction axis, so the
output BlockSpec is constant in j and the accumulator pattern (@pl.when on
j == 0 / strict-less merge) gives exactly jnp.argmin's first-min semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_H1 = 2654435761
_H2 = 2246822519
_H3 = 3266489917
_UMAX = 0xFFFFFFFF


def _mix(h):
    h2 = jnp.uint32(_H2)
    h3 = jnp.uint32(_H3)
    h = h ^ (h >> jnp.uint32(15))
    h = h * h2
    h = h ^ (h >> jnp.uint32(13))
    h = h * h3
    return h ^ (h >> jnp.uint32(16))


def _tile_propose(c, yb, ya, avail, salt, i, j, bm: int, bn: int):
    """Shared tile body: fused slack + admissibility + hash-key argmin on
    one (bm, bn) tile at grid position (i, j). Returns the tile's winning
    (key, global col) per row, each (bm, 1). Both the unbatched and the
    batched kernel reduce these with the identical first-min accumulator,
    so the two stay bit-identical by construction."""
    rows_g = (i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
              ).astype(jnp.uint32)
    cols_l = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    cols_g = (j * bn + cols_l).astype(jnp.uint32)

    keys = _mix(rows_g * jnp.uint32(_H1) + cols_g * jnp.uint32(_H2)
                + salt * jnp.uint32(_H3))
    adm = (yb + ya == c + 1) & (avail != 0)
    keys = jnp.where(adm, keys, jnp.uint32(_UMAX))

    tile_key = jnp.min(keys, axis=1, keepdims=True)          # (bm, 1)
    tile_col = (j * bn + jnp.argmin(keys, axis=1)[:, None]).astype(jnp.int32)
    return tile_key, tile_col


def _kernel(salt_ref, c_ref, yb_ref, ya_ref, avail_ref, col_out, key_out,
            *, bm: int, bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    tile_key, tile_col = _tile_propose(
        c_ref[...], yb_ref[...], ya_ref[...], avail_ref[...],
        salt_ref[0, 0].astype(jnp.uint32), i, j, bm, bn,
    )

    @pl.when(j == 0)
    def _init():
        key_out[...] = jnp.full_like(key_out[...], jnp.uint32(_UMAX))
        col_out[...] = jnp.full_like(col_out[...], -1)

    better = tile_key < key_out[...]
    key_out[...] = jnp.where(better, tile_key, key_out[...])
    col_out[...] = jnp.where(better, tile_col, col_out[...])


def _resolve_interpret(interpret: bool | None) -> bool:
    """None -> compiled on TPU (Mosaic), interpret elsewhere. The old default
    of ``interpret=True`` silently paid the emulation tax on every backend."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def slack_propose(
    c_int: jnp.ndarray,
    y_b: jnp.ndarray,
    y_a: jnp.ndarray,
    avail_a: jnp.ndarray,
    salt,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
):
    """Returns (best_col (m,) int32 with -1 sentinel, best_key (m,) uint32)."""
    interpret = _resolve_interpret(interpret)
    m, n = c_int.shape
    pm = (-m) % block_m
    pn = (-n) % block_n
    c_p = jnp.pad(c_int, ((0, pm), (0, pn)))
    yb_p = jnp.pad(y_b.astype(jnp.int32), (0, pm))[:, None]
    # padded columns: force non-admissible via avail = 0
    ya_p = jnp.pad(y_a.astype(jnp.int32), (0, pn))[None, :]
    av_p = jnp.pad(avail_a.astype(jnp.int32), (0, pn))[None, :]
    salt_arr = jnp.asarray(salt, jnp.int32).reshape(1, 1)
    mp, np_ = m + pm, n + pn

    grid = (mp // block_m, np_ // block_n)
    col, key = pl.pallas_call(
        functools.partial(_kernel, bm=block_m, bn=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, 1), jnp.int32),
            jax.ShapeDtypeStruct((mp, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(salt_arr, c_p, yb_p, ya_p, av_p)
    return col[:m, 0], key[:m, 0]


def _kernel_batched(salt_ref, c_ref, yb_ref, ya_ref, avail_ref,
                    col_out, key_out, *, bm: int, bn: int):
    """Batched variant: grid (B, m/BM, n/BN); one instance per leading index.
    Hash keys use the within-instance (row, col) and the instance's own salt,
    so each batch slice reproduces the unbatched kernel bit for bit."""
    i = pl.program_id(1)
    j = pl.program_id(2)

    tile_key, tile_col = _tile_propose(
        c_ref[0], yb_ref[0], ya_ref[0], avail_ref[0],
        salt_ref[0, 0, 0].astype(jnp.uint32), i, j, bm, bn,
    )

    @pl.when(j == 0)
    def _init():
        key_out[...] = jnp.full_like(key_out[...], jnp.uint32(_UMAX))
        col_out[...] = jnp.full_like(col_out[...], -1)

    better = tile_key[None] < key_out[...]
    key_out[...] = jnp.where(better, tile_key[None], key_out[...])
    col_out[...] = jnp.where(better, tile_col[None], col_out[...])


def slack_propose_batched(
    c_int: jnp.ndarray,
    y_b: jnp.ndarray,
    y_a: jnp.ndarray,
    avail_a: jnp.ndarray,
    salt: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
):
    """Batched fused propose: (B, m, n) costs, per-instance duals and salts.

    Returns (best_col (B, m) int32 with -1 sentinel, best_key (B, m) uint32),
    each batch slice identical to ``slack_propose`` on that instance.
    """
    interpret = _resolve_interpret(interpret)
    b, m, n = c_int.shape
    pm = (-m) % block_m
    pn = (-n) % block_n
    c_p = jnp.pad(c_int, ((0, 0), (0, pm), (0, pn)))
    yb_p = jnp.pad(y_b.astype(jnp.int32), ((0, 0), (0, pm)))[:, :, None]
    ya_p = jnp.pad(y_a.astype(jnp.int32), ((0, 0), (0, pn)))[:, None, :]
    # padded columns: force non-admissible via avail = 0
    av_p = jnp.pad(avail_a.astype(jnp.int32), ((0, 0), (0, pn)))[:, None, :]
    salt_arr = jnp.asarray(salt, jnp.int32).reshape(b, 1, 1)
    mp, np_ = m + pm, n + pn

    grid = (b, mp // block_m, np_ // block_n)
    col, key = pl.pallas_call(
        functools.partial(_kernel_batched, bm=block_m, bn=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda g, i, j: (g, 0, 0)),
            pl.BlockSpec((1, block_m, block_n), lambda g, i, j: (g, i, j)),
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, 1, block_n), lambda g, i, j: (g, 0, j)),
            pl.BlockSpec((1, 1, block_n), lambda g, i, j: (g, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, mp, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, mp, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(salt_arr, c_p, yb_p, ya_p, av_p)
    return col[:, :m, 0], key[:, :m, 0]
