"""Pallas TPU kernel: fused log-domain Sinkhorn row update with online
logsumexp (flash-attention-style running max/sum over column tiles).

Computes  f_i = reg * (log_nu_i - LSE_j((g_j - c_ij)/reg))  reading each cost
tile exactly once and never materializing the (m, n) scaled matrix. Column
tiles are the reduction axis: two (BM, 1) accumulators (running max, running
scaled sum) ride along the j axis; the final tile writes f.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .slack_propose import _resolve_interpret


def _kernel(c_ref, g_ref, lognu_ref, reg_ref, f_ref, m_acc, s_acc, *,
            nj: int):
    j = pl.program_id(1)
    # reg arrives as a (1, 1) operand rather than a baked Python float, so
    # one compiled program serves every accuracy (and per-lane reg under
    # vmap) — the recompile-hazard contract pinned by repro.analysis
    inv_reg = 1.0 / reg_ref[0, 0]
    z = (g_ref[...] - c_ref[...]) * inv_reg      # (bm, bn)
    zmax = jnp.max(z, axis=1, keepdims=True)     # (bm, 1)

    @pl.when(j == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, -jnp.inf)
        s_acc[...] = jnp.zeros_like(s_acc)

    m_old = m_acc[...]
    m_new = jnp.maximum(m_old, zmax)
    # guard exp(-inf - -inf)
    corr = jnp.where(jnp.isfinite(m_old), jnp.exp(m_old - m_new), 0.0)
    s_new = s_acc[...] * corr + jnp.sum(jnp.exp(z - m_new), axis=1,
                                        keepdims=True)
    m_acc[...] = m_new
    s_acc[...] = s_new

    @pl.when(j == nj - 1)
    def _final():
        lse = m_new + jnp.log(jnp.maximum(s_new, 1e-38))
        f_ref[...] = reg_ref[0, 0] * (lognu_ref[...] - lse)


def sinkhorn_row_update(
    c: jnp.ndarray,
    g: jnp.ndarray,
    log_nu: jnp.ndarray,
    reg,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
):
    m, n = c.shape
    pm, pn = (-m) % block_m, (-n) % block_n
    # pad columns with +inf cost => z = -inf => contributes exp(-inf) = 0
    c_p = jnp.pad(c.astype(jnp.float32), ((0, pm), (0, pn)),
                  constant_values=jnp.inf)
    g_p = jnp.pad(g.astype(jnp.float32), (0, pn))[None, :]
    lognu_p = jnp.pad(log_nu.astype(jnp.float32), (0, pm))[:, None]
    # reg as a (1, 1) operand (float or traced scalar both accepted): every
    # grid cell maps to the same block, so the kernel reads one value
    reg_a = jnp.asarray(reg, jnp.float32).reshape(1, 1)
    mp, np_ = m + pm, n + pn
    grid = (mp // block_m, np_ // block_n)

    f, _, _ = pl.pallas_call(
        functools.partial(_kernel, nj=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(c_p, g_p, lognu_p, reg_a)
    return f[:m, 0]
