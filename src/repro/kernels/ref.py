"""Pure-jnp oracles for every Pallas kernel. The kernels must match these
bit-for-bit (integer kernels) or to float tolerance (cost/sinkhorn)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.matching import proposal_keys


def slack_propose_ref(c_int, y_b, y_a, avail_a, salt):
    """Per-row hash-random admissible column among available columns.

    Returns (best_col, best_key): best_col == -1 where no admissible edge
    exists; key is the winning hash (uint32 max when none).
    """
    m, n = c_int.shape
    adm = (y_b[:, None] + y_a[None, :] == c_int + 1) & avail_a[None, :]
    keys = proposal_keys(m, n, salt)
    keys = jnp.where(adm, keys, jnp.uint32(0xFFFFFFFF))
    best_key = jnp.min(keys, axis=1)
    best = jnp.argmin(keys, axis=1).astype(jnp.int32)
    found = best_key != jnp.uint32(0xFFFFFFFF)
    return jnp.where(found, best, jnp.int32(-1)), best_key


def cost_matrix_ref(x, y, metric: str = "sqeuclidean"):
    if metric in ("sqeuclidean", "euclidean"):
        x2 = jnp.sum(x * x, axis=-1, keepdims=True)
        y2 = jnp.sum(y * y, axis=-1, keepdims=True)
        d = jnp.maximum(x2 + y2.T - 2.0 * (x @ y.T), 0.0)
        return jnp.sqrt(d + 1e-30) if metric == "euclidean" else d
    if metric == "l1":
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    raise ValueError(metric)


def sinkhorn_row_ref(c, g, log_nu, reg: float):
    """f_i = reg * (log_nu_i - logsumexp_j((g_j - c_ij)/reg))."""
    return reg * (
        log_nu - jax.nn.logsumexp((g[None, :] - c) / reg, axis=1)
    )
