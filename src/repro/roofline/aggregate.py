"""Render the EXPERIMENTS.md dry-run + roofline tables from
results/dryrun/*.json.   PYTHONPATH=src python -m repro.roofline.aggregate"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(out_dir="results/dryrun", fallback_dir="results/dryrun_scan"):
    cells = {}
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        key = (r["arch"], r["shape"], "mp" if r.get("multi_pod") else "sp")
        extra = os.path.basename(p).replace(".json", "").split("__")[3:]
        if extra:
            key = key + tuple(extra)
        cells[key] = r
    # scan-mode fallbacks for cells whose unrolled compile was impractical
    # on the 1-core dev host (flagged; flops are per-layer undercounts)
    if fallback_dir and os.path.isdir(fallback_dir):
        for p in sorted(glob.glob(os.path.join(fallback_dir, "*.json"))):
            r = json.load(open(p))
            key = (r["arch"], r["shape"],
                   "mp" if r.get("multi_pod") else "sp")
            if key not in cells:
                r["scan_fallback"] = True
                cells[key] = r
    return cells


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(cells) -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | GiB/dev | coll ops (AR/AG/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(cells):
        if len(key) > 3:
            continue
        r = cells[key]
        arch, shape, mesh = key
        if r.get("skipped"):
            lines.append(
                f"| {arch} | {shape} | {mesh} | SKIP ({r['skipped'].split(':')[0]}) | - | - | - |")
            continue
        if not r.get("ok"):
            lines.append(
                f"| {arch} | {shape} | {mesh} | **FAIL** {r.get('error','')[:60]} | {r.get('compile_s')} | - | - |")
            continue
        c = r["roofline"]["collective"]["counts"]
        coll = (f"{c['all-reduce']}/{c['all-gather']}/{c['reduce-scatter']}"
                f"/{c['all-to-all']}/{c['collective-permute']}")
        lines.append(
            f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
            f"{r['memory']['peak_per_device_gb']} | {coll} |")
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| arch | shape | t_comp (ms) | t_mem raw/adj (ms) | t_coll (ms) "
        "| dominant | roofline frac | MODEL/HLO flops "
        "| what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(cells):
        if len(key) > 3 or key[2] != "sp":
            continue
        r = cells[key]
        arch, shape, _ = key
        if r.get("skipped") or not r.get("ok"):
            continue
        t = r["roofline"]
        note = _note(r)
        if r.get("scan_fallback"):
            note = "scan-mode cell (flops undercounted per layer); " + note
        adj = t.get("t_memory_adjusted_s", t["t_memory_s"])
        mark = " (scan)" if r.get("scan_fallback") else ""
        shape = shape + mark
        lines.append(
            f"| {arch} | {shape} | {t['t_compute_s']*1e3:.1f} | "
            f"{t['t_memory_s']*1e3:.1f}/{adj*1e3:.1f} | "
            f"{t['t_collective_s']*1e3:.1f} | "
            f"{t['dominant']} | {t['roofline_fraction']:.3f} | "
            f"{r['hlo_flops_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def _note(r) -> str:
    t = r["roofline"]
    by = r["roofline"]["collective"]["by_op"]
    if t["dominant"] == "memory":
        return ("shrink activation residency: sequence-shard the residual "
                "stream / fp8 or bf16 intermediates / larger fusion regions")
    if t["dominant"] == "collective":
        top = max(by, key=by.get)
        return (f"dominant {top}: overlap with compute, reduce payload "
                f"dtype, or re-shard to cut the gather volume")
    return "MXU-bound: raise per-chip utilization (layout/fusion), or scale out"


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    cells = load(out_dir)
    n_ok = sum(1 for r in cells.values() if r.get("ok"))
    n_fail = sum(1 for r in cells.values() if not r.get("ok"))
    n_skip = sum(1 for r in cells.values() if r.get("skipped"))
    print(f"## Dry-run summary: {len(cells)} cells, {n_ok} ok "
          f"({n_skip} skipped-by-design), {n_fail} failed\n")
    print("### Dry-run table\n")
    print(dryrun_table(cells))
    print("\n### Roofline table (single-pod, 256 chips, unrolled HLO)\n")
    print(roofline_table(cells))


if __name__ == "__main__":
    main()
