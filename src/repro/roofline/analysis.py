"""Three-term roofline analysis from compiled dry-run artifacts.

compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
memory     = HLO_bytes_per_device / HBM_bandwidth
collective = moved_bytes_per_device / ICI_link_bandwidth

FLOPs/bytes come from compiled.cost_analysis() (the module is post-SPMD, so
numbers are per device). Collective bytes are parsed from compiled.as_text():
for each all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction we take the RESULT shape (operands are not
always annotated inline) and convert to wire bytes with the standard ring
cost model using the replica-group size N:

  all-reduce       2 (N-1)/N * result      (result == operand)
  all-gather       (N-1)/N * result        (result == gathered buffer)
  reduce-scatter   (N-1)   * result        (operand == N * result)
  all-to-all       (N-1)/N * result
  collective-permute        result

Caveat (documented): collectives inside while-loop bodies are counted once,
not per trip - solver/router loops therefore undercount; train/prefill paths
are scan-free at the collective level (scan bodies ARE counted per HLO
semantics? no - scan lowers to while; we report `while_ops` alongside so
affected cells are flagged).
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(b * n)


def _line_result_bytes(line: str) -> float:
    # result may be a tuple "( ... )" (e.g. all-to-all / -start variants)
    m = _COLL_RE.search(line)
    if not m:
        return 0.0
    if m.group(1) is not None:  # tuple result
        total = 0.0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            total += _shape_bytes(dt, dims)
        # '-start' tuples repeat (operand, result); halve to avoid double count
        return total / 2.0
    return _shape_bytes(m.group(2), m.group(3))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, default_group: int = 16) -> Dict:
    out = {
        "all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    counts = {k: 0 for k in out}
    moved = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(4)
        rb = _line_result_bytes(line)
        n = max(_group_size(line, default_group), 2)
        if op == "all-reduce":
            mv = 2.0 * (n - 1) / n * rb
        elif op == "all-gather":
            mv = (n - 1) / n * rb
        elif op == "reduce-scatter":
            mv = (n - 1) * rb
        elif op == "all-to-all":
            mv = (n - 1) / n * rb
        else:
            mv = rb
        out[op] += mv
        counts[op] += 1
        moved += mv
    return {"moved_bytes": moved, "by_op": out, "counts": counts,
            "while_ops": hlo_text.count(" while(")}


def dus_alias_bytes(hlo_text: str) -> float:
    """Bytes attributed to dynamic-update-slice full-buffer read+write.

    XLA's cost analysis charges a DUS with reading and writing the ENTIRE
    buffer; with input/output aliasing (donated KV caches) the real HBM
    traffic is just the updated slice. Summing 2x the result bytes of every
    dus instruction (incl. dus-rooted/named fusions) gives the over-charge
    to subtract for the alias-adjusted memory term."""
    total = 0.0
    for line in hlo_text.splitlines():
        if "dynamic-update-slice" not in line:
            continue
        lhs, eq, rhs = line.partition("=")
        if not eq:
            continue
        rhs = rhs.lstrip()
        m = re.match(r"(\w+)\[([\d,]*)\]", rhs)
        if not m:
            continue
        opcode = rhs.split("(")[0].split()[-1] if "(" in rhs else ""
        is_dus_def = (
            "dynamic-update-slice" in lhs
            or opcode.endswith("dynamic-update-slice")
        )
        if is_dus_def:
            total += 2.0 * _shape_bytes(m.group(1), m.group(2))
    return total


def roofline_terms(cost: Dict, hlo_text: str) -> Dict:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    dus = dus_alias_bytes(hlo_text)
    bytes_adj = max(bytes_ - dus, 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_memory_adj = bytes_adj / HBM_BW
    t_coll = coll["moved_bytes"] / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory_adj),
        ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "dus_alias_bytes": dus,
        "bytes_per_device_alias_adjusted": bytes_adj,
        "collective": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_adjusted_s": t_memory_adj,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_time_s": max(t_compute, t_memory_adj, t_coll),
        "roofline_fraction": t_compute / max(t_compute, t_memory_adj,
                                             t_coll, 1e-30),
    }


def model_flops(cfg, shape, n_chips: int) -> Dict:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train;
    2 N_active per token for decode/prefill forward-only."""
    from repro.models import model as M
    import jax
    import numpy as np

    tree = M.abstract_params(cfg)
    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = [p.key for p in path if hasattr(p, "key")]
        n = int(np.prod(leaf.shape))
        total += n
        if any(k in ("w_gate", "w_up", "w_down") for k in keys) and \
                any(k == "moe" for k in keys):
            active += int(n * cfg.top_k / max(cfg.num_experts, 1))
        elif "embed" in keys:
            pass  # embedding lookup is a gather, not a matmul
        else:
            active += n
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return {
        "n_params_total": total,
        "n_params_active": active,
        "tokens": tokens,
        "model_flops_total": mult * active * tokens,
        "model_flops_per_device": mult * active * tokens / n_chips,
    }
