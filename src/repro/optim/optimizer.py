"""Optimizers (no optax): AdamW and Adafactor (factored second moment, for
the >100B configs where full Adam state does not fit), cosine LR schedule
with warmup, global-norm clipping, and an int8 error-feedback gradient
compressor for bandwidth-limited cross-pod reductions."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any          # first moment (AdamW) or None-like zeros (Adafactor)
    v: Any          # second moment / factored tuple
    comp_err: Any   # error-feedback residual (only when compression on)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * (step + 1) / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.int32(0), m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    comp_err=None)


def adamw_update(params, grads, state: OptState, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, wd=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        new_p = p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    p_leaves, treedef = jax.tree.flatten(params)
    outs = [
        upd(p, g, m, v)
        for p, g, m, v in zip(
            p_leaves,
            treedef.flatten_up_to(grads),
            treedef.flatten_up_to(state.m),
            treedef.flatten_up_to(state.v),
        )
    ]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, OptState(step=step, m=new_m, v=new_v,
                           comp_err=state.comp_err)


# --------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) - factored v, no m by default
# --------------------------------------------------------------------------

def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def one(p):
        if _factored(p.shape):
            return (
                jnp.zeros(p.shape[:-1], jnp.float32),      # row stats
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            )
        return (jnp.zeros(p.shape, jnp.float32),)

    return OptState(
        step=jnp.int32(0),
        m=None,
        v=jax.tree.map(one, params),
        comp_err=None,
    )


def adafactor_update(params, grads, state: OptState, lr, *, d2=0.999,
                     eps=1e-30, clip_thresh=1.0, wd=0.0):
    step = state.step + 1

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            vr, vc = v
            vr = d2 * vr + (1 - d2) * jnp.mean(g2, axis=-1)
            vc = d2 * vc + (1 - d2) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            u = g * jax.lax.rsqrt(r[..., None] * vc[..., None, :] + eps)
            new_v = (vr, vc)
        else:
            (v0,) = v
            v0 = d2 * v0 + (1 - d2) * g2
            u = g * jax.lax.rsqrt(v0 + eps)
            new_v = (v0,)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / clip_thresh)
        new_p = p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), new_v

    p_leaves, treedef = jax.tree.flatten(params)
    outs = [
        upd(p, g, v)
        for p, g, v in zip(
            p_leaves,
            treedef.flatten_up_to(grads),
            treedef.flatten_up_to(state.v),
        )
    ]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_v = treedef.unflatten([o[1] for o in outs])
    return new_p, OptState(step=step, m=None, v=new_v,
                           comp_err=state.comp_err)


# --------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod bandwidth trick)
# --------------------------------------------------------------------------

def compress_int8(g, err):
    """Quantize g+err to int8 with per-tensor scale; return (q, scale, new_err).
    Error feedback keeps the quantization bias out of the optimizer path."""
    g = g.astype(jnp.float32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
}


def make_optimizer(name: str, lr_fn):
    init, update = OPTIMIZERS[name]

    def step(params, grads, state):
        lr = lr_fn(state.step)
        return update(params, grads, state, lr)

    return init, step
