"""mamba2-2.7b [ssm] - SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=0,
    ssm_state=128, ssm_headdim=64, subquadratic=True,
)
