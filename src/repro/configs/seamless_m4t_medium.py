"""seamless-m4t-medium [audio] - enc-dec; modality frontend is a stub
(input_specs provides precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    encoder_layers=12, input_mode="frames",
)
