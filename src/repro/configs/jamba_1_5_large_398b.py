"""jamba-1.5-large-398b [hybrid] - Mamba+attn 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    num_experts=16, top_k=2, d_ff_expert=24576,
    ssm_state=128, ssm_headdim=128, attn_period=8, subquadratic=True,
    param_dtype="bfloat16", optimizer="adafactor",
)
