"""kimi-k2-1t-a32b [moe] - trillion-param MoE, 384 experts top-8.
[arXiv:2501.kimi2; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=18432, vocab_size=163840, head_dim=128,
    num_experts=384, top_k=8, num_shared_experts=1, d_ff_expert=2048,
    first_dense_layers=1, rope_theta=5e4,
    param_dtype="bfloat16", optimizer="adafactor",
)
