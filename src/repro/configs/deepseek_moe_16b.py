"""deepseek-moe-16b [moe] - 2 shared + 64 routed top-6, fine-grained.
[arXiv:2401.06066; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, head_dim=128,
    num_experts=64, top_k=6, num_shared_experts=2, d_ff_expert=1408,
    first_dense_layers=1,
)
