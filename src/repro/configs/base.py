"""Architecture + shape configuration schema."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    router: str = "topk"        # topk | sinkhorn | pushrelabel
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    attn_period: int = 0        # hybrid: 1 attention layer per this many
    # --- encoder-decoder ---
    encoder_layers: int = 0
    # --- modality frontend (stub: precomputed embeddings) ---
    input_mode: str = "tokens"  # tokens | frames | tokens+patches
    num_patch_tokens: int = 0
    # --- numerics / memory ---
    param_dtype: str = "float32"
    optimizer: str = "adamw"    # adamw | adafactor
    remat: bool = True
    # dry-run only: unroll the layer scan so XLA cost analysis counts every
    # layer (a scanned body is costed once); execution configs keep scan.
    scan_unroll: bool = False
    # hillclimb: shard the residual stream's sequence dim over 'tp' between
    # layers (Megatron-style sequence parallelism)
    seq_shard: bool = False
    # hillclimb: decode attention reads the KV cache in bf16 with fp32
    # accumulation (preferred_element_type) instead of materializing fp32
    # copies of the full cache each step
    fast_decode_math: bool = False
    # hillclimb: PaLM-style parallel attention+FFN residual block - the two
    # per-layer tensor-parallel all-reduces merge into one (halves TP
    # collective payload; an architecture variant, off by default)
    parallel_block: bool = False
    # sub-quadratic decode possible (SSM/hybrid) -> long_500k runnable
    subquadratic: bool = False

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // 256) * 256

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeConfig("long_500k", 128, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Per the assignment: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention architecture"
    return True, ""
