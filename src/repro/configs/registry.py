"""Architecture registry: --arch <id> resolves here."""
from . import (
    qwen3_4b, codeqwen1_5_7b, llama3_2_3b, command_r_plus_104b,
    kimi_k2_1t_a32b, deepseek_moe_16b, seamless_m4t_medium,
    mamba2_2_7b, jamba_1_5_large_398b, llava_next_mistral_7b,
)
from .base import (  # noqa: F401  (re-exported registry surface)
    ArchConfig, ShapeConfig, SHAPES, SMOKE_SHAPES, shape_applicable,
)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    qwen3_4b, codeqwen1_5_7b, llama3_2_3b, command_r_plus_104b,
    kimi_k2_1t_a32b, deepseek_moe_16b, seamless_m4t_medium,
    mamba2_2_7b, jamba_1_5_large_398b, llava_next_mistral_7b,
)}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, min(4, cfg.num_layers)),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32 if cfg.head_dim else 0,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        param_dtype="float32",
        optimizer="adamw",
    )
    if cfg.family == "hybrid":
        kw["num_layers"] = cfg.attn_period  # one full period
    if cfg.num_experts:
        kw.update(num_experts=8, top_k=min(cfg.top_k, 2), d_ff_expert=64,
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=16)
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    if cfg.num_patch_tokens:
        kw["num_patch_tokens"] = 8
    return cfg.with_(**kw)
