"""llava-next-mistral-7b [vlm] - anyres tiling; patch frontend is a stub
(input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128, rope_theta=1e6,
    input_mode="tokens+patches", num_patch_tokens=576,
)
