"""Literal Section-4 reduction: materialize unit copies and run the
unbalanced assignment solver. Exponentially sized in 1/eps - used ONLY as a
test oracle (small theta) for the clustered production solver in transport.py.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .pushrelabel import solve_assignment_int, complete_matching, round_costs


def solve_ot_via_copies(c, nu, mu, eps: float, theta: float):
    """Returns (plan, cost, int-state) by expanding each node into copies."""
    c = np.asarray(c, np.float32)
    nu = np.asarray(nu, np.float64)
    mu = np.asarray(mu, np.float64)
    scale = max(float(c.max()), 1e-30)
    s_int = np.floor(nu * theta).astype(np.int64)
    d_int = np.ceil(mu * theta).astype(np.int64)
    rows = np.repeat(np.arange(c.shape[0]), s_int)
    cols = np.repeat(np.arange(c.shape[1]), d_int)
    big_c = c[np.ix_(rows, cols)] / scale
    c_int = round_costs(jnp.asarray(big_c), eps)
    state = solve_assignment_int(c_int, eps)
    matching = np.asarray(
        complete_matching(state.match_ba, state.match_ab)
    )
    plan = np.zeros(c.shape, np.float64)
    valid = matching >= 0
    np.add.at(plan, (rows[valid], cols[matching[valid]]), 1.0 / theta)
    cost = float((plan * c).sum())
    return plan, cost, state, rows, cols
