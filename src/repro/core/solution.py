"""Typed ``Solution`` result surface: lazy artifact fetch, compact sparse
plans, and a-posteriori certificates — the paper's deliverables as an API.

The paper's headline advantages over Sinkhorn are that the push-relabel
solver "readily provides a compact transport plan as well as a solution to
an approximate version of the dual formulation". This module is where both
become first-class results instead of fields buried in a dense NamedTuple:

  ``cost``         the primal objective <plan, C> (Theorem 1.2 / 1.3:
                   cost <= OPT + eps * m once ``guaranteed=True`` runs the
                   solver at eps/3 — rounding + completion + eps-feasibility
                   each contribute <= eps/3 * m after rescaling).
  ``duals``        the approximate DUAL solution (y_b, y_a): scaled copies
                   of the integer duals the push-relabel loop maintains.
                   They are eps-feasible — y(b) + y(a) <= c(b, a) + eps *
                   max(c) on every edge (paper invariant I2) — which makes
                   sum-form dual objectives a certified LOWER bound on OPT
                   up to eps * m * max(c) (see ``additive_gap``).
  ``plan`` /       the primal transport plan. The push-relabel plan is
  ``plan_sparse``  COMPACT (Lahn-Mulchandani-Raghvendra frame sparse
                   support as the deliverable of combinatorial OT): its
                   support is O(m + n) in practice versus the dense m*n of
                   Sinkhorn, so ``plan_sparse()`` ships COO triplets and
                   ``SparsePlan.to_dense()`` reproduces the dense plan
                   bit for bit.
  ``matching``     Algorithm 1's primal: the (partial-then-completed)
                   row -> column matching.
  ``state``        the raw integer pre-completion solver state, for the
                   machine-checkable certificates in core/feasibility.py.
  ``stats``        uniform dispatch accounting (:class:`SolveStats`) with
                   explicit defaults across lockstep/compact/mesh paths.

Artifacts are fetched from device to host LAZILY and at most once: a
``SolutionBatch`` holds the device-resident batched result, and each
accessor materializes only its own arrays (tracked by ``fetched_bytes``).
Callers declare artifacts up front via ``solve(..., want=("cost",))``;
an un-declared accessor raises :class:`ArtifactNotRequested` so a serving
path can never silently pay O(B * m * n) device->host bandwidth for a
plan nobody asked for — cost-only traffic moves O(B) scalars.

Certificates are computed ON DEVICE (O(B) scalars fetched): the dual
objective, the eps-feasibility margin, and ``additive_gap() = cost -
dual_objective``, an a-posteriori upper bound on ``cost - OPT`` up to the
eps * m * max(c) dual slack (paper Lemma 3.2 bounds every term; see
``additive_gap_bound``).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .problem import pow2_at_least

__all__ = [
    "ArtifactNotRequested",
    "SolveStats",
    "SparsePlan",
    "SparsePlanBatch",
    "Solution",
    "SolutionBatch",
]


class ArtifactNotRequested(ValueError):
    """Accessing an artifact that was not declared in ``want=``."""


# --------------------------------------------------------------------------
# Uniform dispatch stats (satellite: "devices"/"dispatches" with defaults)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class SolveStats:
    """Per-dispatch accounting, uniform across EVERY dispatch path.

    The legacy surfaces leaked the driver through the result: ``devices``
    existed only on mesh results, ``dispatches`` was absent on lockstep.
    Here every field exists with an explicit default (lockstep is one
    dispatch on one device), so callers never probe with ``hasattr``.
    """
    mode: str                      # "lockstep" | "compact" | "mesh"
    batch: int                     # real instances in the dispatch
    bucket: Optional[Tuple[int, int]] = None   # padded dispatch shape
    dispatches: int = 1
    devices: int = 1
    placement: str = "batch"
    chunk: Optional[int] = None
    occupancy: Tuple[Tuple[int, int], ...] = ()
    collapsed_at: Optional[int] = None
    # fault-tolerance accounting (serving layers fill these in)
    deadline_hit: bool = False     # chunk loop cut by a wall-clock budget
    attempts: int = 1              # dispatch attempts incl. ladder retries
    ladder_level: int = 0          # 0 = configured policy; higher = degraded
    quarantined: int = 0           # requests quarantined from this bucket
    # solver-portfolio accounting (core/api records these when
    # DispatchPolicy.solver routes away from the default)
    solver: str = "pushrelabel"    # solver that produced this result
    predicted_s: Optional[float] = None  # cost-model per-batch prediction
    actual_s: Optional[float] = None     # measured dispatch wall time

    @classmethod
    def from_driver(cls, st: Any, *, mode: str, batch: int,
                    bucket: Optional[Tuple[int, int]] = None,
                    solver: str = "pushrelabel",
                    predicted_s: Optional[float] = None) -> "SolveStats":
        """Fold a driver stats object (CompactionStats, DistributedStats,
        or None for the lockstep path) into the uniform surface."""
        if st is None:
            return cls(mode=mode, batch=batch, bucket=bucket, solver=solver,
                       predicted_s=predicted_s)
        return cls(
            mode=mode, batch=batch, bucket=bucket,
            dispatches=int(st.dispatches) or 1,
            devices=int(getattr(st, "devices", 1)),
            placement=str(getattr(st, "placement", "batch")),
            chunk=int(st.chunk) if st.chunk else None,
            occupancy=tuple(tuple(o) for o in st.occupancy),
            collapsed_at=getattr(st, "collapsed_at", None),
            deadline_hit=bool(getattr(st, "deadline_hit", False)),
            solver=solver, predicted_s=predicted_s,
            actual_s=getattr(st, "solve_s", None),
        )

    def as_dict(self) -> Dict[str, Any]:
        return {
            "mode": self.mode, "batch": self.batch, "bucket": self.bucket,
            "dispatches": self.dispatches, "devices": self.devices,
            "placement": self.placement, "chunk": self.chunk,
            "occupancy": [list(o) for o in self.occupancy],
            "collapsed_at": self.collapsed_at,
            "deadline_hit": self.deadline_hit, "attempts": self.attempts,
            "ladder_level": self.ladder_level,
            "quarantined": self.quarantined,
            "solver": self.solver, "predicted_s": self.predicted_s,
            "actual_s": self.actual_s,
        }


# --------------------------------------------------------------------------
# Device-side helpers (tiny jitted reductions: O(B) scalars cross to host)
# --------------------------------------------------------------------------

@jax.jit
def _count_nnz(plan):
    return jnp.sum(plan != 0, axis=(1, 2)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k",))
def _coo_extract(plan, k: int):
    """Per-instance COO extraction at static capacity ``k``: flat indices
    (fill = m*n past each instance's nnz) and the gathered values."""
    b, m, n = plan.shape
    flat = plan.reshape(b, m * n)

    def one(f):
        idx = jnp.nonzero(f, size=k, fill_value=m * n)[0].astype(jnp.int32)
        vals = jnp.where(idx < m * n, f[jnp.clip(idx, 0, m * n - 1)],
                         jnp.float32(0.0))
        return idx, vals

    return jax.vmap(one)(flat)


@jax.jit
def _masked_max(c, m_valid, n_valid):
    """(B,) max cost over each instance's valid block — the solver's
    rescaling factor (``scale`` in the prologues)."""
    _, m, n = c.shape
    rok = jnp.arange(m)[None, :] < m_valid[:, None]
    cok = jnp.arange(n)[None, :] < n_valid[:, None]
    mask = rok[:, :, None] & cok[:, None, :]
    # strong-typed zero: a weak `0.0` here would silently re-promote if a
    # caller ever fed f16/bf16 costs (dtype-drift audit, rule weak-literal)
    return jnp.max(jnp.where(mask, c, jnp.float32(0.0)), axis=(1, 2))


@jax.jit
def _dual_obj_assignment(y_b, y_a, m_valid, n_valid):
    _, m = y_b.shape
    _, n = y_a.shape
    rok = jnp.arange(m)[None, :] < m_valid[:, None]
    cok = jnp.arange(n)[None, :] < n_valid[:, None]
    z = jnp.float32(0.0)
    return (jnp.sum(jnp.where(rok, y_b, z), axis=1)
            + jnp.sum(jnp.where(cok, y_a, z), axis=1))


@jax.jit
def _dual_obj_ot(y_b, y_a, nu, mu, m_valid, n_valid):
    _, m = y_b.shape
    _, n = y_a.shape
    rok = jnp.arange(m)[None, :] < m_valid[:, None]
    cok = jnp.arange(n)[None, :] < n_valid[:, None]
    z = jnp.float32(0.0)
    return (jnp.sum(jnp.where(rok, nu * y_b, z), axis=1)
            + jnp.sum(jnp.where(cok, mu * y_a, z), axis=1))


@jax.jit
def _feasibility_margin(c, y_b, y_a, m_valid, n_valid, col_live):
    """(B,) max over each instance's live edges of y_b[i] + y_a[j] - c[i,j]
    (eps-feasibility holds when this is <= eps * scale up to f32 slop)."""
    _, m, n = c.shape
    rok = jnp.arange(m)[None, :] < m_valid[:, None]
    cok = (jnp.arange(n)[None, :] < n_valid[:, None]) & col_live
    s = y_b[:, :, None] + y_a[:, None, :] - c
    mask = rok[:, :, None] & cok[:, None, :]
    neg = jnp.float32(-np.inf)
    return jnp.max(jnp.where(mask, s, neg), axis=(1, 2))


@jax.jit
def _masked_sum(v, valid):
    _, m = v.shape
    ok = jnp.arange(m)[None, :] < valid[:, None]
    return jnp.sum(jnp.where(ok, v, jnp.float32(0.0)), axis=1)


# --------------------------------------------------------------------------
# Compact sparse transport plans
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class SparsePlan:
    """One instance's transport plan as COO triplets.

    The push-relabel plan is compact: its support is bounded by the flow
    support of Algorithm 2 plus the two northwest-corner repairs (each
    <= m + n - 1 entries), observed <= ~3 * max(m, n) in practice versus
    the dense m * n a Sinkhorn plan ships. ``to_dense()`` scatters the
    verbatim f32 values back, reproducing the dense plan bit for bit.
    """
    rows: np.ndarray    # (nnz,) int32
    cols: np.ndarray    # (nnz,) int32
    vals: np.ndarray    # (nnz,) float32
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.vals.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.rows.nbytes + self.cols.nbytes + self.vals.nbytes)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.float32)
        out[self.rows, self.cols] = self.vals
        return out


@dataclass(frozen=True)
class SparsePlanBatch:
    """Batched COO plans at a shared capacity (one extraction program per
    (bucket shape, pow2 capacity)); ``idx`` is flat row-major with fill
    ``m * n`` past each instance's ``nnz``."""
    idx: np.ndarray     # (B, K) int32 flat indices, fill = m * n
    vals: np.ndarray    # (B, K) float32
    nnz: np.ndarray     # (B,) int32
    shape: Tuple[int, int]          # padded bucket shape (m, n)

    @property
    def nbytes(self) -> int:
        return int(self.idx.nbytes + self.vals.nbytes + self.nnz.nbytes)

    def instance(self, j: int, shape: Optional[Tuple[int, int]] = None
                 ) -> SparsePlan:
        m, n = self.shape
        k = int(self.nnz[j])
        idx = self.idx[j, :k].astype(np.int64)
        return SparsePlan(rows=(idx // n).astype(np.int32),
                          cols=(idx % n).astype(np.int32),
                          vals=self.vals[j, :k],
                          shape=tuple(shape) if shape else (m, n))


# --------------------------------------------------------------------------
# The Solution surface
# --------------------------------------------------------------------------

class SolutionBatch:
    """Typed, lazily-fetched view over one dispatched batch result.

    Construction does NOT move the result to host: the batched device
    arrays stay put, and each artifact accessor fetches exactly its own
    arrays, once (``fetched_bytes`` audits the device->host traffic).
    ``want`` (from ``solve(..., want=...)``) gates the accessors; ``None``
    allows everything lazily.

    Index with ``batch[i]`` (or iterate) for per-instance
    :class:`Solution` views sharing this batch's fetch cache.
    """

    def __init__(self, spec: Any, result: Any, *, stats: SolveStats,
                 driver_stats: Any = None, inputs: Dict[str, Any],
                 sizes: Optional[np.ndarray], eps: np.ndarray,
                 eps_internal: np.ndarray, guaranteed: bool = False,
                 want: Optional[Tuple[str, ...]] = None,
                 state: Any = None,
                 degraded: Optional[np.ndarray] = None) -> None:
        self.spec = spec
        self.stats = stats
        self.guaranteed = guaranteed
        self._r = result
        self._driver_stats = driver_stats
        self._inputs = inputs
        self._state = state
        b, m, n = spec.batch_shape(inputs) if inputs else (0, 0, 0)
        self.batch = int(stats.batch)
        self.padded_shape = (int(m), int(n))
        if sizes is None:
            sizes = np.stack(
                [np.full((self.batch,), m, np.int32),
                 np.full((self.batch,), n, np.int32)], axis=1)
        self.sizes = np.asarray(sizes, np.int32)
        self._degraded = (None if degraded is None
                          else np.asarray(degraded, bool)[:self.batch])
        self.eps = np.asarray(eps, np.float64)
        self.eps_internal = np.asarray(eps_internal, np.float64)
        self.want = None if want is None else tuple(want)
        if self.want is not None:
            unknown = [w for w in self.want if w not in spec.artifacts]
            if unknown:
                raise ValueError(
                    f"unknown artifact(s) {unknown} for spec "
                    f"{spec.name!r}; available: {spec.artifacts}")
        self._host: Dict[str, Dict[str, np.ndarray]] = {}
        self._sparse: Optional[SparsePlanBatch] = None
        self._plan_dense: Optional[np.ndarray] = None
        self._derived: Dict[str, np.ndarray] = {}
        self._prune_unwanted()

    def _prune_unwanted(self) -> None:
        """With a declared ``want``, drop the device references to big
        buffers the gating forbids reading — the dense plan, the integer
        state's flow matrices, and (when the ``duals`` certificate group
        is not declared) the cost-matrix inputs — so a long-lived
        Solution, e.g. one resolved onto a serving Future, never pins
        O(B * M * N) device memory it can never fetch."""
        if self.want is None:
            return
        r = self._r
        kw = {}
        if ("plan" not in self.want and "plan_sparse" not in self.want
                and getattr(r, "plan", None) is not None):
            kw["plan"] = None
        if "state" not in self.want:
            self._state = None
            if getattr(r, "state", None) is not None:
                kw["state"] = None
        if kw and hasattr(r, "_replace"):
            self._r = r._replace(**kw)
        if "duals" not in self.want:
            # the certificate accessors (scale/mass/dual_objective/
            # additive_gap/dual_feasible) are gated behind "duals"; with
            # the group undeclared the inputs are unreachable
            self._inputs = None

    # -- fetch machinery ----------------------------------------------

    def _check(self, name: str) -> None:
        if self.want is not None and name not in self.want:
            raise ArtifactNotRequested(
                f"artifact {name!r} was not requested: this solve declared "
                f"want={self.want}; add {name!r} to fetch it")

    def _fetch(self, name: str) -> Dict[str, np.ndarray]:
        """Host arrays for one artifact, fetched at most once."""
        cached = self._host.get(name)
        if cached is None:
            dev = self.spec.artifact_device(name, self._r, self._state)
            cached = {k: np.asarray(v) for k, v in dev.items()}
            self._host[name] = cached
        return cached

    @property
    def driver_stats(self) -> Any:
        """The raw driver stats object behind :attr:`stats`
        (CompactionStats / DistributedStats; None for plain lockstep) —
        for the legacy adapters' conditional ``dispatches``/``devices``
        keys and occupancy-curve consumers."""
        return self._driver_stats

    @property
    def fetched_bytes(self) -> int:
        """Total device->host bytes materialized by this batch so far —
        the audit behind the "cost-only traffic never ships plans" claim."""
        total = 0
        for group in self._host.values():
            total += sum(int(a.nbytes) for a in group.values())
        if self._sparse is not None:
            total += self._sparse.nbytes
        total += sum(int(a.nbytes) for a in self._derived.values())
        return total

    # -- batch-level artifact accessors -------------------------------

    def cost(self) -> np.ndarray:
        """(B,) primal objective values (O(B) scalars fetched)."""
        self._check("cost")
        return self._fetch("cost")["cost"][:self.batch]

    def degraded(self) -> np.ndarray:
        """(B,) bool: lanes whose chunk loop was cut by a wall-clock
        deadline BEFORE their termination predicate fired. A degraded
        lane's answer is still primal-feasible with eps-feasible duals
        (the paper maintains invariant I2 at every phase, not just the
        last), so its certificate accessors remain valid — only its
        ``additive_gap()`` is larger than a converged run's. Always
        available (no ``want`` gating): it is O(B) bools computed at
        dispatch time."""
        if self._degraded is None:
            return np.zeros((self.batch,), bool)
        return self._degraded

    def phases(self) -> np.ndarray:
        return self._fetch("scalars")["phases"][:self.batch]

    def rounds(self) -> np.ndarray:
        return self._fetch("scalars")["rounds"][:self.batch]

    def theta(self) -> np.ndarray:
        sc = self._fetch("scalars")
        if "theta" not in sc:
            raise AttributeError(f"spec {self.spec.name!r} has no theta")
        return sc["theta"][:self.batch]

    def duals(self) -> Tuple[np.ndarray, np.ndarray]:
        """((B, M), (B, N)) scaled approximate duals (padded shapes)."""
        self._check("duals")
        d = self._fetch("duals")
        return d["y_b"][:self.batch], d["y_a"][:self.batch]

    def matching(self) -> np.ndarray:
        self._check("matching")
        return self._fetch("matching")["matching"][:self.batch]

    def plan(self) -> np.ndarray:
        """(B, M, N) DENSE plans — the O(B * m * n) fetch ``want=`` gating
        exists to avoid; prefer :meth:`plan_sparse` for serving. Cached:
        derived host work (the assignment one-hot scatter) runs once."""
        self._check("plan")
        if self._plan_dense is None:
            self._plan_dense = self.spec.artifact_plan_dense(
                self._fetch("plan"), self.batch, self.padded_shape)
        return self._plan_dense

    def plan_sparse(self) -> SparsePlanBatch:
        """Batched COO plans: O(B * nnz) bytes instead of O(B * m * n).

        The capacity is the max per-instance support rounded up to a power
        of two, so repeat traffic reuses one extraction program per
        (bucket shape, capacity)."""
        self._check("plan_sparse")
        if self._sparse is None:
            self._sparse = self.spec.artifact_plan_sparse(
                self._r, self._fetch, self.batch, self.padded_shape)
        return self._sparse

    def state(self) -> Any:
        """The raw integer pre-completion solver state (batched pytree, at
        the padded bucket shape) for core/feasibility.py certificates."""
        self._check("state")
        st = self.spec.artifact_state(self._r, self._state)
        if st is None:
            raise ArtifactNotRequested(
                "pre-completion state was not retained by this dispatch; "
                "request it up front with want=('state', ...)")
        return st

    # -- certificates (device-side reductions, O(B) scalars fetched) ---

    def scale(self) -> np.ndarray:
        """(B,) per-instance max cost over the valid block — the paper's
        rescaling factor; additive bounds are stated against it. Part of
        the certificate group: requires ``"duals"`` in ``want``."""
        self._check("duals")
        key = "scale"
        if key not in self._derived:
            self._derived[key] = np.asarray(_masked_max(
                self._inputs["c"], jnp.asarray(self.sizes[:, 0]),
                jnp.asarray(self.sizes[:, 1])))[:self.batch]
        return self._derived[key]

    def dual_objective(self) -> np.ndarray:
        """(B,) dual objective of the approximate duals: sum(y) for the
        assignment LP, <nu, y_b> + <mu, y_a> for OT. eps-feasibility makes
        it >= OPT - eps * m * scale (a certified lower bound on OPT)."""
        self._check("duals")
        key = "dual_objective"
        if key not in self._derived:
            mv = jnp.asarray(self.sizes[:, 0])
            nv = jnp.asarray(self.sizes[:, 1])
            if "nu" in self._inputs:
                obj = _dual_obj_ot(self._r.y_b, self._r.y_a,
                                   self._inputs["nu"], self._inputs["mu"],
                                   mv, nv)
            else:
                obj = _dual_obj_assignment(self._r.y_b, self._r.y_a, mv, nv)
            self._derived[key] = np.asarray(obj)[:self.batch]
        return self._derived[key]

    def mass(self) -> np.ndarray:
        """(B,) total supply mass: the paper's ``m`` (rows for assignment,
        sum(nu) for OT) that the additive bound multiplies. Part of the
        certificate group: requires ``"duals"`` in ``want``."""
        self._check("duals")
        key = "mass"
        if key not in self._derived:
            if "nu" in self._inputs:
                self._derived[key] = np.asarray(_masked_sum(
                    self._inputs["nu"],
                    jnp.asarray(self.sizes[:, 0])))[:self.batch]
            else:
                self._derived[key] = self.sizes[:self.batch, 0].astype(
                    np.float64)
        return self._derived[key]

    def additive_gap(self) -> np.ndarray:
        """(B,) a-posteriori primal-dual gap ``cost - dual_objective``.

        With eps-feasible duals, ``OPT >= dual_objective - eps * m *
        scale`` — so ``additive_gap`` certifies ``cost - OPT <=
        additive_gap + eps * m * scale`` from the RESULT alone, no exact
        solver needed. Under ``guaranteed=True`` (internal eps/3) the gap
        itself satisfies the paper's ``<= eps * m * scale`` headline
        bound (Theorem 1.2/1.3 plus Lemma 3.2's dual bound on the <=
        eps*m/3 uncompleted rows)."""
        return self.cost().astype(np.float64) - self.dual_objective()

    def additive_gap_bound(self) -> np.ndarray:
        """(B,) the paper's bound ``eps * m * scale`` the gap is validated
        against under ``guaranteed=True`` (caller-facing eps, mass ``m``,
        costs rescaled by ``scale = max(c)``)."""
        return self.eps[:self.batch] * self.mass() * self.scale()

    def dual_feasible(self, tol: float = 1e-5) -> np.ndarray:
        """(B,) bool: eps-feasibility of the scaled duals, checked on
        device over every live edge — y(b) + y(a) <= c + eps * scale
        (paper invariant I2, the relaxed dual constraint), with ``tol``
        absorbing the f32 scaling of the integer duals."""
        self._check("duals")
        mv = jnp.asarray(self.sizes[:, 0])
        nv = jnp.asarray(self.sizes[:, 1])
        b, _, n = self._inputs["c"].shape
        if "mu" in self._inputs:
            # only live columns (mu > 0 -> d_int >= 1) carry copies and
            # hence dual constraints (see core/feasibility.py)
            live = self._inputs["mu"] > 0
        else:
            live = jnp.ones((b, n), bool)
        margin = np.asarray(_feasibility_margin(
            self._inputs["c"], self._r.y_b, self._r.y_a, mv, nv, live)
        )[:self.batch]
        slack = (self.eps_internal[:self.batch] * self.scale()
                 + tol * np.maximum(self.scale(), 1.0))
        return margin <= slack

    # -- per-instance views --------------------------------------------

    def __len__(self) -> int:
        return self.batch

    def __getitem__(self, j: int) -> "Solution":
        if not (0 <= j < self.batch):
            raise IndexError(j)
        return Solution(self, j)

    def __iter__(self) -> Iterator["Solution"]:
        return (self[j] for j in range(self.batch))


class Solution:
    """One instance's typed result: a view into a :class:`SolutionBatch`
    (shared device arrays, shared fetch cache), trimmed to the instance's
    true (m, n) inside the padded bucket."""

    def __init__(self, batch: SolutionBatch, j: int) -> None:
        self._b = batch
        self._j = j
        self.shape: Tuple[int, int] = (int(batch.sizes[j, 0]),
                                       int(batch.sizes[j, 1]))

    # -- cheap scalar diagnostics --------------------------------------

    @property
    def spec_name(self) -> str:
        return self._b.spec.name

    @property
    def eps(self) -> float:
        return float(self._b.eps[self._j])

    @property
    def stats(self) -> SolveStats:
        return self._b.stats

    @property
    def degraded(self) -> bool:
        """True when this lane was cut by a deadline budget; re-validate
        with ``dual_feasible()`` / ``additive_gap()`` (still sound)."""
        return bool(self._b.degraded()[self._j])

    @property
    def cost(self) -> float:
        return float(self._b.cost()[self._j])

    @property
    def phases(self) -> int:
        return int(self._b.phases()[self._j])

    @property
    def rounds(self) -> int:
        return int(self._b.rounds()[self._j])

    @property
    def theta(self) -> float:
        return float(self._b.theta()[self._j])

    # -- artifacts ------------------------------------------------------

    def duals(self) -> Tuple[np.ndarray, np.ndarray]:
        """(y_b (m,), y_a (n,)) scaled approximate duals."""
        mi, ni = self.shape
        y_b, y_a = self._b.duals()
        return y_b[self._j, :mi], y_a[self._j, :ni]

    def matching(self) -> np.ndarray:
        mi, _ = self.shape
        return self._b.matching()[self._j, :mi]

    def plan(self) -> np.ndarray:
        mi, ni = self.shape
        return self._b.plan()[self._j, :mi, :ni]

    def plan_sparse(self) -> SparsePlan:
        return self._b.plan_sparse().instance(self._j, self.shape)

    def state(self) -> Any:
        """This instance's integer pre-completion state (leaves at the
        PADDED bucket shape, as the feasibility certificates expect)."""
        return jax.tree_util.tree_map(lambda a: a[self._j], self._b.state())

    # -- certificates ---------------------------------------------------

    def dual_objective(self) -> float:
        return float(self._b.dual_objective()[self._j])

    def additive_gap(self) -> float:
        return float(self._b.additive_gap()[self._j])

    def additive_gap_bound(self) -> float:
        return float(self._b.additive_gap_bound()[self._j])

    def dual_feasible(self, tol: float = 1e-5) -> bool:
        return bool(self._b.dual_feasible(tol)[self._j])

    # -- legacy adapter -------------------------------------------------

    def legacy_dict(self) -> Dict[str, Any]:
        """The exact per-instance dict the pre-Solution ragged front ends
        returned (bit-identical values; conditional ``dispatches`` /
        ``devices`` keys preserved for one release)."""
        out = self._b.spec.legacy_instance_dict(self)
        out["batch_size"] = self._b.batch
        if self._b.stats.bucket is not None:
            out["bucket"] = self._b.stats.bucket
        st = self._b._driver_stats
        if st is not None:
            out["dispatches"] = st.dispatches
            if hasattr(st, "devices"):
                out["devices"] = st.devices
        if self.degraded:
            # new-surface-only key: absent on every non-degraded result,
            # so pre-deadline consumers see bit-identical dicts
            out["degraded"] = True
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"Solution({self.spec_name}, shape={self.shape}, "
                f"eps={self.eps}, mode={self.stats.mode!r})")


def sparse_from_dense_device(plan, batch: int) -> SparsePlanBatch:
    """COO-extract a (B, M, N) device plan: count support on device, pick
    the pow2 capacity, run the fixed-capacity extraction, and fetch only
    the compact triplets. Shared by both specs' ``plan_sparse`` producers."""
    _, m, n = plan.shape
    nnz = np.asarray(_count_nnz(plan))[:batch]
    k = min(pow2_at_least(int(nnz.max(initial=1))), m * n)
    idx, vals = _coo_extract(plan, k)
    return SparsePlanBatch(idx=np.asarray(idx)[:batch],
                           vals=np.asarray(vals)[:batch],
                           nnz=nnz, shape=(int(m), int(n)))


# --------------------------------------------------------------------------
# repro.analysis registration: the certificate reductions. These carry the
# "certificate" tag, which turns on the strict dtype rules (no weak-typed
# float literals, flag f32 sum accumulation) — a silently re-promoted
# certificate is the PR-2 termination-threshold bug class applied to the
# paper's additive-gap bound instead of the solver loop.
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_certificates():
    b, m, n = 2, 4, 4
    c = jnp.zeros((b, m, n), jnp.float32)
    y_b = jnp.zeros((b, m), jnp.float32)
    y_a = jnp.zeros((b, n), jnp.float32)
    nu = jnp.full((b, m), 0.25, jnp.float32)
    mu = jnp.full((b, n), 0.25, jnp.float32)
    mv = jnp.full((b,), m, jnp.int32)
    nv = jnp.full((b,), n, jnp.int32)
    live = jnp.ones((b, n), bool)
    plan = jnp.zeros((b, m, n), jnp.float32)
    mk = lambda name, fn, args: _audit.EntrySpec(  # noqa: E731
        name=name,
        build=lambda: _audit.trace_entry(
            name=name, fn=fn, args=args, tags={"certificate"},
            source=__name__),
        source=__name__,
    )
    return [
        mk("core.solution._masked_max", _masked_max,
           {"c": c, "m_valid": mv, "n_valid": nv}),
        mk("core.solution._dual_obj_assignment", _dual_obj_assignment,
           {"y_b": y_b, "y_a": y_a, "m_valid": mv, "n_valid": nv}),
        mk("core.solution._dual_obj_ot", _dual_obj_ot,
           {"y_b": y_b, "y_a": y_a, "nu": nu, "mu": mu,
            "m_valid": mv, "n_valid": nv}),
        mk("core.solution._feasibility_margin", _feasibility_margin,
           {"c": c, "y_b": y_b, "y_a": y_a, "m_valid": mv, "n_valid": nv,
            "col_live": live}),
        mk("core.solution._masked_sum", _masked_sum,
           {"v": y_b, "valid": mv}),
        mk("core.solution._count_nnz", _count_nnz, {"plan": plan}),
    ]


for _es in _trace_certificates():
    _audit.register(_es.name, _es.build, source=_es.source)
del _es
