"""Pre-admission input validation: vectorized poison detection + quarantine.

One NaN-poisoned cost matrix used to take down an entire collated bucket
of unrelated requests: the checkify sanitizer (PR 6) detects the poison
mid-dispatch, but detection without isolation fails every Future in the
batch. This module is the cheap front gate — a single jitted reduction
over the batched inputs that classifies each lane BEFORE dispatch:

  ``NONFINITE_COST``   a NaN/inf cost inside the instance's valid block;
  ``NEGATIVE_MASS``    a negative or non-finite supply/demand weight;
  ``MASS_IMBALANCE``   ``|sum(nu) - sum(mu)|`` beyond a relative
                       tolerance (the OT rounding step assumes balanced
                       marginals; an imbalanced pair silently shifts the
                       primal objective).

Codes are a bitmask so one lane can carry several reasons. The serving
layers (``serve/scheduler.py``, ``serve/engine.py``) call
:func:`admission_codes` per collated bucket and quarantine offending
lanes with a per-request :class:`RequestRejected` while the rest of the
bucket proceeds untouched — the batched solve is lane-independent, so
dropping a poisoned lane never perturbs a healthy neighbor's result.

The reductions are ordinary audited entry points (they self-register
with ``repro.analysis``): the tolerance is traced data (``must_trace``),
never a baked constant, and every output is a strongly-typed int32 —
the weak-float drift rules apply to this module like any other.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .problem import _sizes_arrays

__all__ = [
    "OK",
    "NONFINITE_COST",
    "NEGATIVE_MASS",
    "MASS_IMBALANCE",
    "DEFAULT_TOL",
    "RequestRejected",
    "describe",
    "admission_codes",
    "check_admission",
]

OK = 0
NONFINITE_COST = 1
NEGATIVE_MASS = 2
MASS_IMBALANCE = 4

#: Relative mass-imbalance tolerance: |sum(nu) - sum(mu)| may be at most
#: this fraction of max(total mass, 1).
DEFAULT_TOL = 1e-3

_REASONS = (
    (NONFINITE_COST, "non-finite cost"),
    (NEGATIVE_MASS, "negative or non-finite mass"),
    (MASS_IMBALANCE, "mass imbalance beyond tolerance"),
)


def describe(code: int) -> str:
    """Human-readable reason string for a bitmask admission code."""
    parts = [text for bit, text in _REASONS if code & bit]
    return " + ".join(parts) if parts else "ok"


class RequestRejected(RuntimeError):
    """A request refused admission (or quarantined mid-dispatch).

    Carries the machine-readable ``code`` bitmask alongside ``who`` (the
    tenant/request name the serving layer supplies) so a client can
    distinguish its own poisoned input from a neighbor's transient
    infrastructure failure.
    """

    def __init__(self, who: str, code: int, reason: Optional[str] = None):
        self.who = str(who)
        self.code = int(code)
        self.reason = reason if reason is not None else describe(int(code))
        super().__init__(
            f"{self.who} rejected at admission: {self.reason} "
            f"(code {self.code})")


# --------------------------------------------------------------------------
# Jitted per-lane classification (O(B) int32 codes cross to host)
# --------------------------------------------------------------------------

@jax.jit
def _admission_assignment(c, m_valid, n_valid):
    """(B,) int32 codes for assignment instances: cost finiteness over
    each instance's valid block (padding lanes/edges are exempt)."""
    _, m, n = c.shape
    rok = jnp.arange(m)[None, :] < m_valid[:, None]
    cok = jnp.arange(n)[None, :] < n_valid[:, None]
    mask = rok[:, :, None] & cok[:, None, :]
    bad_c = jnp.any(~jnp.isfinite(c) & mask, axis=(1, 2))
    return jnp.where(bad_c, jnp.int32(NONFINITE_COST), jnp.int32(OK))


@jax.jit
def _admission_ot(c, nu, mu, m_valid, n_valid, tol):
    """(B,) int32 bitmask codes for OT instances.

    ``tol`` is traced data (one program serves every tolerance); the
    imbalance test is relative to ``max(total mass, 1)`` so tiny and
    huge marginals are held to the same proportional standard.
    """
    _, m, n = c.shape
    rok = jnp.arange(m)[None, :] < m_valid[:, None]
    cok = jnp.arange(n)[None, :] < n_valid[:, None]
    mask = rok[:, :, None] & cok[:, None, :]
    bad_c = jnp.any(~jnp.isfinite(c) & mask, axis=(1, 2))
    bad_nu = jnp.any((~jnp.isfinite(nu) | (nu < 0)) & rok, axis=1)
    bad_mu = jnp.any((~jnp.isfinite(mu) | (mu < 0)) & cok, axis=1)
    z = jnp.float32(0.0)
    s_nu = jnp.sum(jnp.where(rok, nu, z), axis=1)
    s_mu = jnp.sum(jnp.where(cok, mu, z), axis=1)
    scale = jnp.maximum(jnp.maximum(s_nu, s_mu), jnp.float32(1.0))
    imbalanced = jnp.abs(s_nu - s_mu) > tol * scale
    zero = jnp.int32(OK)
    return (jnp.where(bad_c, jnp.int32(NONFINITE_COST), zero)
            | jnp.where(bad_nu | bad_mu, jnp.int32(NEGATIVE_MASS), zero)
            | jnp.where(imbalanced, jnp.int32(MASS_IMBALANCE), zero))


# --------------------------------------------------------------------------
# Host wrappers
# --------------------------------------------------------------------------

def admission_codes(inputs: Dict[str, Any], *,
                    sizes: Optional[np.ndarray] = None,
                    tol: float = DEFAULT_TOL) -> np.ndarray:
    """(B,) int32 admission codes for a canonical batched input dict.

    ``inputs`` holds ``c`` (B, M, N) and, for OT, ``nu``/``mu``;
    ``sizes`` is the usual (B, 2) true-shape array (``None`` = every lane
    fills the padded block). 0 means admitted; nonzero is a bitmask of
    rejection reasons (see :func:`describe`).
    """
    c = inputs["c"]
    b, m, n = (int(s) for s in np.shape(c))
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    mv = jnp.asarray(m_valid, jnp.int32)
    nv = jnp.asarray(n_valid, jnp.int32)
    if inputs.get("nu") is not None:
        codes = _admission_ot(
            jnp.asarray(c), jnp.asarray(inputs["nu"]),
            jnp.asarray(inputs["mu"]), mv, nv, jnp.float32(tol))
    else:
        codes = _admission_assignment(jnp.asarray(c), mv, nv)
    return np.asarray(codes)


def check_admission(inputs: Dict[str, Any], *,
                    sizes: Optional[np.ndarray] = None,
                    tol: float = DEFAULT_TOL,
                    who: str = "instance") -> np.ndarray:
    """Run :func:`admission_codes` and raise :class:`RequestRejected`
    naming every offending lane; returns the (all-zero) codes when clean."""
    codes = admission_codes(inputs, sizes=sizes, tol=tol)
    bad = np.flatnonzero(codes)
    if bad.size:
        shown = ", ".join(
            f"{who} {int(j)}: {describe(int(codes[j]))}" for j in bad[:8])
        more = "" if bad.size <= 8 else f" (+{int(bad.size) - 8} more)"
        raise RequestRejected(
            f"{int(bad.size)}/{int(codes.size)} lane(s)",
            int(codes[bad[0]]), reason=shown + more)
    return codes


# --------------------------------------------------------------------------
# repro.analysis registration: the admission reductions are dispatch-path
# entry points (one runs per collated bucket), so they carry the same
# contracts as the solver chunks — the tolerance must be traced data, not
# a baked constant (the recompile-churn bug class), and the int32 codes
# must not pick up weak-float drift.
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_admission():
    b, m, n = 2, 4, 4
    c = jnp.zeros((b, m, n), jnp.float32)
    nu = jnp.full((b, m), 0.25, jnp.float32)
    mu = jnp.full((b, n), 0.25, jnp.float32)
    mv = jnp.full((b,), m, jnp.int32)
    nv = jnp.full((b,), n, jnp.int32)
    mk = lambda name, fn, args, must: _audit.EntrySpec(  # noqa: E731
        name=name,
        build=lambda: _audit.trace_entry(
            name=name, fn=fn, args=args, must_trace=must,
            tags={"admission"}, source=__name__),
        source=__name__,
    )
    return [
        mk("core.validate.admission[assignment]", _admission_assignment,
           {"c": c, "m_valid": mv, "n_valid": nv}, ()),
        mk("core.validate.admission[ot]", _admission_ot,
           {"c": c, "nu": nu, "mu": mu, "m_valid": mv, "n_valid": nv,
            "tol": jnp.float32(DEFAULT_TOL)}, ("tol",)),
    ]


for _es in _trace_admission():
    _audit.register(_es.name, _es.build, source=_es.source)
del _es
