"""Machine-checkable certificates for the paper's invariants (all integer).

These run on the *int* state (units of eps) so every check is exact:
  (I1)  y_b >= 0, y_a <= 0, free rows-of-A... in our orientation: free demand
        columns have y_a == 0; y_b >= 0 elementwise; y_a <= 0 elementwise.
  (I2)  eps-feasibility: non-matching y_b[i] + y_a[j] <= c[i,j] + 1 for all
        (i, j); matching edges y_b[i] + y_a[j] == c[i,j].
  Lemma 3.2: |y| <= 1/eps + 2 units (i.e. 1 + 2*eps).
"""
from __future__ import annotations

import numpy as np


def check_invariants(c_int, y_b, y_a, match_ba, eps: float) -> dict:
    c_int = np.asarray(c_int)
    y_b = np.asarray(y_b)
    y_a = np.asarray(y_a)
    match_ba = np.asarray(match_ba)
    m, n = c_int.shape
    out = {}
    out["I1_yb_nonneg"] = bool((y_b >= 0).all())
    out["I1_ya_nonpos"] = bool((y_a <= 0).all())
    matched_cols = match_ba[match_ba >= 0]
    free_col_mask = np.ones(n, bool)
    free_col_mask[matched_cols] = False
    out["I1_free_a_zero"] = bool((y_a[free_col_mask] == 0).all())
    s = y_b[:, None] + y_a[None, :]
    feas = s <= c_int + 1
    rows = np.arange(m)[match_ba >= 0]
    cols = match_ba[match_ba >= 0]
    tight = s[rows, cols] == c_int[rows, cols]
    out["I2_matching_tight"] = bool(tight.all())
    nonmatch = feas.copy()
    out["I2_feasible"] = bool(nonmatch.all())
    bound = int(np.ceil(1.0 / eps)) + 2
    out["L32_dual_bound"] = bool(
        (np.abs(y_b) <= bound).all() and (np.abs(y_a) <= bound).all()
    )
    out["valid_matching"] = len(cols) == len(np.unique(cols))
    return out


def check_ot_invariants(c_int, state, s_int, d_int, eps: float) -> dict:
    """Integer certificates for the clustered OT solver (transport.py).

    Expands the 2-cluster representation back to per-copy duals and checks
    the paper's invariants + Lemma 4.1 on the *final* state.
    """
    c = np.asarray(c_int)
    y_b = np.asarray(state.y_b)
    ya_hi = np.asarray(state.ya_hi)
    free_b = np.asarray(state.free_b)
    free_a = np.asarray(state.free_a)
    f_hi = np.asarray(state.f_hi)
    f_lo = np.asarray(state.f_lo)
    s_int = np.asarray(s_int)
    d_int = np.asarray(d_int)
    live = d_int > 0  # columns with no demand have no copies -> no constraints
    out = {}
    out["conserve_supply"] = bool(
        ((f_hi + f_lo).sum(1) + free_b == s_int).all()
    )
    out["conserve_demand"] = bool(
        ((f_hi + f_lo).sum(0) + free_a == d_int).all()
    )
    out["I1_ya_nonpos"] = bool((ya_hi[live] <= 0).all())
    out["I1_free_a_at_zero"] = bool((ya_hi[live & (free_a > 0)] == 0).all())
    out["I1_yb_positive"] = bool((y_b >= 1).all())  # init eps, only rises
    # Feasibility (2) for the max-dual copies (free b at y_b, a at ya_hi).
    s = y_b[:, None] + ya_hi[None, :]
    out["I2_feasible"] = bool((s[:, live] <= c[:, live] + 1).all())
    # Lemma 4.1: matched b-copy duals (tightness-derived) live in
    # {y_b, y_b - 1}; raises keep free copies at the max.
    bh = c - ya_hi[None, :]          # b-copy dual where flow sits at hi
    bl = c - ya_hi[None, :] + 1      # ... at lo
    okh = (f_hi == 0) | ((bh <= y_b[:, None]) & (bh >= y_b[:, None] - 1))
    okl = (f_lo == 0) | ((bl <= y_b[:, None]) & (bl >= y_b[:, None] - 1))
    out["L41_two_clusters_hi"] = bool(okh.all())
    out["L41_two_clusters_lo"] = bool(okl.all())
    bound = int(np.ceil(1.0 / eps)) + 2
    out["L32_dual_bound"] = bool(
        (np.abs(y_b) <= bound).all() and (np.abs(ya_hi[live]) <= bound).all()
    )
    return out


def is_maximal(adm: np.ndarray, mprime_b: np.ndarray, active_rows: np.ndarray) -> bool:
    """No admissible edge joins an unmatched active row to an unmatched col."""
    adm = np.asarray(adm)
    n = adm.shape[1]
    col_used = np.zeros(n, bool)
    used = mprime_b[mprime_b >= 0]
    col_used[used] = False if used.size == 0 else True
    row_free = active_rows & (np.asarray(mprime_b) < 0)
    sub = adm[row_free][:, ~col_used]
    return not bool(sub.any())
