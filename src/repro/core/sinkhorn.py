"""Log-domain Sinkhorn baseline (Cuturi 2013 / Altschuler et al. 2017).

The paper benchmarks against POT's Sinkhorn. We implement the numerically
stabilized log-domain variant; regularization follows the standard additive-
approximation recipe: to target an additive error of ~eps on costs scaled to
[0, 1], use reg = eps / (4 log n) and iterate until the marginal violation is
below eps' (Altschuler et al.). A plain (non-log) variant is included because
that is what POT runs by default - it exhibits exactly the small-eps
underflow the paper points out.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SinkhornResult(NamedTuple):
    plan: jnp.ndarray
    cost: jnp.ndarray
    f: jnp.ndarray          # row potentials (log-domain)
    g: jnp.ndarray          # col potentials
    iters: jnp.ndarray
    marginal_err: jnp.ndarray


@partial(jax.jit, static_argnames=("reg", "max_iters", "use_log"))
def sinkhorn(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    reg: float,
    max_iters: int = 10_000,
    tol: float = 1e-9,
    use_log: bool = True,
) -> SinkhornResult:
    """Entropy-regularized OT. rows = nu (supply), cols = mu (demand).

    ``tol`` is a TRACED operand, not a compile-time constant: derive it
    on host (``sinkhorn_marginal_tolerance`` does the float64 arithmetic)
    and distinct tolerances share one compiled program."""
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    tol = jnp.asarray(tol, jnp.float32)
    log_nu = jnp.log(jnp.maximum(nu, 1e-38))
    log_mu = jnp.log(jnp.maximum(mu, 1e-38))

    if use_log:
        def body(carry):
            f, g, it, err = carry
            # row update: f_i = reg*(log nu_i - lse_j((g_j - c_ij)/reg))
            f = reg * (log_nu - jax.nn.logsumexp((g[None, :] - c) / reg, axis=1))
            g = reg * (log_mu - jax.nn.logsumexp((f[:, None] - c) / reg, axis=0))
            logp = (f[:, None] + g[None, :] - c) / reg
            row = jnp.sum(jnp.exp(logp), axis=1)
            err = jnp.sum(jnp.abs(row - nu))
            return f, g, it + 1, err

        def cond(carry):
            _, _, it, err = carry
            return (err > tol) & (it < max_iters)

        f0 = jnp.zeros(c.shape[0], jnp.float32)
        g0 = jnp.zeros(c.shape[1], jnp.float32)
        f, g, it, err = jax.lax.while_loop(
            cond, body, (f0, g0, jnp.int32(0), jnp.float32(jnp.inf))
        )
        plan = jnp.exp((f[:, None] + g[None, :] - c) / reg)
    else:
        # POT-style kernel-matrix iteration (fast but underflows at small reg).
        k = jnp.exp(-c / reg)

        def body(carry):
            u, v, it, err = carry
            u = nu / jnp.maximum(k @ v, 1e-38)
            v = mu / jnp.maximum(k.T @ u, 1e-38)
            row = u * (k @ v)
            err = jnp.sum(jnp.abs(row - nu))
            return u, v, it + 1, err

        def cond(carry):
            _, _, it, err = carry
            return (err > tol) & (it < max_iters)

        u0 = jnp.ones(c.shape[0], jnp.float32)
        v0 = jnp.ones(c.shape[1], jnp.float32)
        u, v, it, err = jax.lax.while_loop(
            cond, body, (u0, v0, jnp.int32(0), jnp.float32(jnp.inf))
        )
        plan = u[:, None] * k * v[None, :]
        f = reg * jnp.log(jnp.maximum(u, 1e-38))
        g = reg * jnp.log(jnp.maximum(v, 1e-38))

    cost = jnp.sum(plan * c)
    return SinkhornResult(plan=plan, cost=cost, f=f, g=g, iters=it, marginal_err=err)


def reg_for_additive_eps(eps: float, n: int) -> float:
    """Altschuler-et-al. style regularization for additive error ~eps*max(c)."""
    return max(eps / (4.0 * math.log(max(n, 2))), 1e-6)


def sinkhorn_marginal_tolerance(eps, mass: float = 1.0) -> float:
    """Host-float64 L1 marginal-violation threshold for an additive-eps
    target: eps/8 * total mass (the AWR stopping rule). Computed entirely
    in float64 on host — the same device-f32 threshold bug class PR 2
    fixed for OT termination — and handed to ``sinkhorn`` as its traced
    ``tol`` operand."""
    return float(np.float64(eps) / 8.0 * np.float64(mass))


# --------------------------------------------------------------------------
# repro.analysis registration: the recompile-hazard contract of the
# tolerance fix — ``tol`` must arrive as a traced operand (a baked
# Python-float threshold both recompiles per accuracy and gets rounded
# through the device-f32 comparison the host-f64 derivation avoids).
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_sinkhorn():
    n = 8

    def run(c, nu, mu, tol):
        r = sinkhorn(c, nu, mu, reg=0.05, max_iters=16, tol=tol)
        return {"plan": r.plan, "cost": r.cost, "f": r.f, "g": r.g,
                "iters": r.iters, "marginal_err": r.marginal_err}

    return _audit.trace_entry(
        name="core.sinkhorn.sinkhorn",
        fn=run,
        args={
            "c": jnp.zeros((n, n), jnp.float32),
            "nu": jnp.full((n,), 1.0 / n, jnp.float32),
            "mu": jnp.full((n,), 1.0 / n, jnp.float32),
            "tol": jnp.float32(1e-6),
        },
        must_trace={"tol"},
        tags={"sinkhorn", "baseline"},
        source=__name__,
    )


_audit.register("core.sinkhorn.sinkhorn", _trace_sinkhorn, source=__name__)
