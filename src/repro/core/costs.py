"""Cost-matrix builders used by the paper's experiments.

Synthetic inputs: Euclidean distance between 2-D points sampled from the unit
square (Fig. 1). MNIST inputs: L1 distance between L1-normalized images
(Fig. 2). ``kernel='pallas'`` routes through the Pallas TPU kernel (validated
in interpret mode on CPU); default is the pure-jnp path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sqeuclidean(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(m,d),(n,d) -> (m,n) squared distances via the MXU-friendly identity."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True)
    d = x2 + y2.T - 2.0 * (x @ y.T)
    return jnp.maximum(d, 0.0)


def euclidean(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(sqeuclidean(x, y) + 1e-30)


def l1(x: jnp.ndarray, y: jnp.ndarray, block: int = 2048) -> jnp.ndarray:
    """(m,d),(n,d) -> (m,n) L1 distances, scanned over row blocks to bound the
    (block, n, d) broadcast intermediate."""
    m = x.shape[0]
    block = min(block, m)
    pad = (-m) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xb = xp.reshape(-1, block, x.shape[1])

    def one(xi):
        return jnp.sum(jnp.abs(xi[:, None, :] - y[None, :, :]), axis=-1)

    out = jax.lax.map(one, xb).reshape(-1, y.shape[0])
    return out[:m]


COSTS = {"sqeuclidean": sqeuclidean, "euclidean": euclidean, "l1": l1}


def build_cost_matrix(x, y, metric: str = "euclidean", kernel: str = "jnp"):
    if kernel == "pallas":
        from repro.kernels import ops

        return ops.cost_matrix(x, y, metric=metric)
    return COSTS[metric](jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32))
