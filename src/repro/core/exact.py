"""Exact oracles used only in tests/benchmarks (never in the hot path).

- assignment: scipy's Jonker-Volgenant ``linear_sum_assignment``.
- optimal transport: scipy ``linprog`` (HiGHS) on the flow LP for small n.
"""
from __future__ import annotations

import numpy as np


def exact_assignment_cost(c) -> float:
    from scipy.optimize import linear_sum_assignment

    c = np.asarray(c)
    r, col = linear_sum_assignment(c)
    return float(c[r, col].sum())


def exact_ot_cost(c, mu, nu) -> float:
    """min <C, P> s.t. P 1 = mu, P^T 1 = nu, P >= 0 (balanced OT)."""
    from scipy.optimize import linprog

    c = np.asarray(c, np.float64)
    mu = np.asarray(mu, np.float64)
    nu = np.asarray(nu, np.float64)
    m, n = c.shape
    a_eq = np.zeros((m + n, m * n))
    for i in range(m):
        a_eq[i, i * n : (i + 1) * n] = 1.0
    for j in range(n):
        a_eq[m + j, j::n] = 1.0
    res = linprog(
        c.ravel(), A_eq=a_eq[:-1], b_eq=np.concatenate([mu, nu])[:-1],
        bounds=(0, None), method="highs",
    )
    assert res.success, res.message
    return float(res.fun)
