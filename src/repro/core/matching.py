"""Parallel greedy maximal matching via randomized propose/accept rounds.

This is step (I) of each push-relabel phase (the only non-O(1) parallel step).
Every free supply vertex ``b`` proposes to one *admissible* demand vertex ``a``
chosen by a per-(b, a, round) hash key (Israeli-Itai style randomization,
expected O(log n) rounds); every ``a`` accepts the lowest-index proposer.
Accepted pairs leave the pool; repeat until no proposals exist, at which point
the produced matching M' is maximal on the admissible subgraph.

Everything is integer-exact: admissibility is ``y_b + y_a == C + 1`` (tight
relaxed feasibility, in units of eps). All arrays live on device; the loop is
a ``lax.while_loop`` so the whole phase stays inside one XLA program.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Knuth/xxhash-style odd multipliers for the integer mix.
_H1 = jnp.uint32(2654435761)
_H2 = jnp.uint32(2246822519)
_H3 = jnp.uint32(3266489917)


def _mix(h: jnp.ndarray) -> jnp.ndarray:
    h = h ^ (h >> jnp.uint32(15))
    h = h * _H2
    h = h ^ (h >> jnp.uint32(13))
    h = h * _H3
    return h ^ (h >> jnp.uint32(16))


def proposal_keys(m: int, n: int, salt: jnp.ndarray) -> jnp.ndarray:
    """Deterministic pseudo-random uint32 key per (row, col) for one round."""
    rows = jnp.arange(m, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(n, dtype=jnp.uint32)[None, :]
    return _mix(rows * _H1 + cols * _H2 + salt.astype(jnp.uint32) * _H3)


class MaximalMatchingState(NamedTuple):
    mprime_b: jnp.ndarray   # (m,) int32: M' partner col per row, -1 if none
    mprime_a: jnp.ndarray   # (n,) int32: M' partner row per col, -1 if none
    avail_a: jnp.ndarray    # (n,) bool: col not yet matched in M'
    active_b: jnp.ndarray   # (m,) bool: row in B' not yet matched in M'
    rounds: jnp.ndarray     # () int32
    done: jnp.ndarray       # () bool


def greedy_maximal_matching(
    c_int: jnp.ndarray,
    y_b: jnp.ndarray,
    y_a: jnp.ndarray,
    in_bprime: jnp.ndarray,
    salt: jnp.ndarray,
    *,
    propose_fn=None,
) -> MaximalMatchingState:
    """Maximal matching M' on the admissible subgraph touching B'.

    Args:
      c_int: (m, n) int32 costs in units of eps.
      y_b: (m,) int32 supply duals (units of eps).
      y_a: (n,) int32 demand duals (units of eps).
      in_bprime: (m,) bool, rows that are free in M (the set B').
      salt: scalar int32 folded into the per-round hash (phase index).
      propose_fn: optional override computing per-row proposals; signature
        (c_int, y_b, y_a, active_b, avail_a, salt_round) -> (m,) int32 col or
        -1. Used to swap in the Pallas kernel.
    """
    m, n = c_int.shape
    if propose_fn is None:
        propose_fn = _propose_dense

    init = MaximalMatchingState(
        mprime_b=jnp.full((m,), -1, jnp.int32),
        mprime_a=jnp.full((n,), -1, jnp.int32),
        avail_a=jnp.ones((n,), bool),
        active_b=in_bprime,
        rounds=jnp.int32(0),
        done=jnp.bool_(False),
    )

    def cond(s: MaximalMatchingState):
        return (~s.done) & (s.rounds < jnp.int32(min(m, n) + 1))

    def body(s: MaximalMatchingState) -> MaximalMatchingState:
        salt_round = salt * jnp.int32(7919) + s.rounds
        prop = propose_fn(c_int, y_b, y_a, s.active_b, s.avail_a, salt_round)
        has_prop = prop >= 0
        # Accept: per column, lowest-index proposing row wins.
        rows = jnp.arange(m, dtype=jnp.int32)
        sentinel = jnp.int32(m)
        tgt = jnp.where(has_prop, prop, 0)
        winners = jnp.full((n,), sentinel, jnp.int32).at[tgt].min(
            jnp.where(has_prop, rows, sentinel), mode="drop"
        )
        won = has_prop & (winners[tgt] == rows)
        new_col = jnp.where(won, prop, s.mprime_b)
        # Column-side bookkeeping for the pairs just matched. The drop
        # sentinel must be out of range for the COLUMN axis (n, not m).
        col_sentinel = jnp.int32(n)
        mprime_a = s.mprime_a.at[jnp.where(won, prop, col_sentinel)].set(
            rows, mode="drop"
        )
        avail_a = s.avail_a.at[jnp.where(won, prop, col_sentinel)].set(
            False, mode="drop"
        )
        return MaximalMatchingState(
            mprime_b=new_col,
            mprime_a=mprime_a,
            avail_a=avail_a,
            active_b=s.active_b & ~won,
            rounds=s.rounds + 1,
            done=~jnp.any(has_prop),
        )

    return jax.lax.while_loop(cond, body, init)


def _propose_dense(c_int, y_b, y_a, active_b, avail_a, salt_round):
    """Reference proposal step: dense masked hash-argmin over columns."""
    m, n = c_int.shape
    adm = (y_b[:, None] + y_a[None, :] == c_int + 1) & avail_a[None, :]
    keys = proposal_keys(m, n, salt_round)
    keys = jnp.where(adm, keys, jnp.uint32(0xFFFFFFFF))
    best = jnp.argmin(keys, axis=1).astype(jnp.int32)
    any_adm = jnp.any(adm, axis=1) & active_b
    return jnp.where(any_adm, best, jnp.int32(-1))
