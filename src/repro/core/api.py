"""The unified solve() front door: one entry point, every dispatch path.

``solve(spec, instances, eps, policy)`` routes any batch of assignment/OT
work — a ragged list of instances or one pre-batched bucket — through a
single code path to whichever driver the :class:`DispatchPolicy` selects:

  * ``lockstep``   the PR-1 fixed-shape vmapped while_loop (one dispatch,
                   every lane runs until the slowest converges);
  * ``compact``    the convergence-compacting chunked-phase driver
                   (core/compaction.py) — per-instance eps supported;
  * ``mesh``       the mesh-distributed compacting driver
                   (core/distributed.py), with ``placement`` choosing
                   batch-axis sharding vs per-instance row/col matrix
                   sharding ("auto" applies ``choose_placement``).

Results are IDENTICAL across policies for the batch-sharded family
(lockstep == compact == mesh/batch, bit for bit); mesh/matrix matches to
reassociation ulps in the float epilogue (the documented shape caveat in
core/distributed.py).

Result surface — callers declare artifacts up front:

    sols = solve(OT, instances, eps, want=("cost", "duals"))
    sols[0].cost, sols[0].additive_gap()

``want=`` (a tuple of artifact names, also settable on the policy) makes
``solve`` return the typed Solution surface (core/solution.py): a
:class:`~repro.core.solution.SolutionBatch` for the pre-batched dict
form, a list of per-instance :class:`~repro.core.solution.Solution`
views for the ragged form. Artifacts are fetched device->host lazily and
at most once, so cost-only traffic moves O(B) scalars instead of the
O(B * m * n) dense plans; un-requested artifacts raise instead of
silently paying the bandwidth. With ``want=None`` (default) the legacy
surfaces are returned unchanged — ``(result, stats)`` for the dict form,
per-instance dicts for the ragged form — produced by a thin adapter over
the same Solution machinery, bit-identical to the historical values.

The serving layers (``OTService``, ``AsyncOTScheduler``) and the ragged
``solve_*_ragged`` wrappers all call this front door, so a new dispatch
strategy lands in exactly one place.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..obs.metrics import now as _now
from .compaction import DEFAULT_CHUNK, CompactionStats, solve_compacting
from .distributed import solve_mesh
from .problem import (  # noqa: F401  (re-exported: the front door and
    #   the specs it dispatches are one import site)
    ASSIGNMENT,
    FUSED_ASSIGNMENT,
    FUSED_OT,
    OT,
    fused_variant,
)
from .solution import Solution, SolutionBatch, SolveStats

_MODES = ("auto", "lockstep", "compact", "mesh")
_SOLVERS = ("pushrelabel", "sinkhorn", "hybrid", "auto")


@dataclass(frozen=True)
class DispatchPolicy:
    """How a batch should be dispatched.

    Args:
      mode: "auto" (mesh when ``mesh`` is set, else compact), "lockstep",
        "compact", or "mesh".
      mesh: 1-D batch mesh (``launch.mesh.make_batch_mesh``); required
        meaningfully only for mode="mesh" (None resolves the default
        host mesh there).
      placement: mesh-mode placement — "auto" | "batch" | "matrix".
      chunk: k, phases per dispatch of the compacting drivers.
      buckets: shape-bucket boundaries for ragged input (None -> the
        core/batched.py defaults; oversized shapes mint ceil-pow2
        buckets).
      guaranteed: run at eps/3 for the paper's <= OPT + eps*m bound.
      want: artifacts to expose on the typed Solution surface (e.g.
        ``("cost", "duals", "plan_sparse")``); None keeps the legacy
        return surface. ``solve(..., want=...)`` overrides this.
      validate: run the vectorized admission check (core/validate.py) on
        every dispatched bucket and raise
        :class:`~repro.core.validate.RequestRejected` naming the
        offending lanes before any solver program runs. The serving
        layers do their own per-request quarantine instead (reject one
        Future, keep the bucket); this flag is the all-or-nothing direct
        API equivalent.
      fused: run the k-phase loop through the fused Pallas phase kernel
        (``kernels/fused_phase``): slack + propose/accept + push +
        relabel in ONE kernel with the solver state resident in VMEM
        across all k phases, instead of the stepped
        ``slack_propose``-plus-XLA-update loop. Bit-identical results
        (asserted in tests/test_fused_phase.py); block sizes come from
        the backend table in ``kernels/ops.py``. Under mesh/matrix
        placement the per-instance row/col-sharded solve falls back to
        the stepped kernels (the fused kernel is a whole-instance
        program; sharding a single instance across devices is exactly
        the regime it cannot cover).
      solver: which ALGORITHM solves OT-family batches —
        "pushrelabel" (default: the paper's solver, guaranteed at every
        eps), "sinkhorn" (the log-domain AWR-scheduled spec in
        repro.portfolio — same additive-eps certificate, cheaper at
        loose eps), "hybrid" (coarse Sinkhorn duals warm-start the
        push-relabel finish; keeps the push-relabel guarantee), or
        "auto" (route per batch via the measured cost model,
        ``repro.portfolio.costmodel`` — deterministic for a loaded
        table, so an auto dispatch is bit-identical to naming its
        choice). Assignment batches ignore this knob (push-relabel is
        the only assignment solver). The chosen solver and the
        predicted-vs-actual wall cost land in ``SolveStats``.
    """
    mode: str = "auto"
    mesh: Any = None
    placement: str = "auto"
    chunk: Optional[int] = None
    buckets: Optional[Tuple[int, ...]] = None
    guaranteed: bool = False
    want: Optional[Tuple[str, ...]] = None
    validate: bool = False
    fused: bool = False
    solver: str = "pushrelabel"

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown dispatch mode {self.mode!r}; "
                             f"expected one of {_MODES}")
        if self.solver not in _SOLVERS:
            raise ValueError(f"unknown solver {self.solver!r}; "
                             f"expected one of {_SOLVERS}")
        if self.mode == "lockstep" and self.mesh is not None:
            raise ValueError("mode='lockstep' cannot dispatch over a mesh "
                             "— use mode='compact' or mode='mesh' (the "
                             "distributed driver is the compacting driver)")

    def resolved_mode(self) -> str:
        if self.mode != "auto":
            return self.mode
        return "mesh" if self.mesh is not None else "compact"

    @classmethod
    def from_legacy(cls, compact: bool, mesh=None, *, chunk=None,
                    buckets=None, guaranteed: bool = False,
                    placement: str = "auto",
                    want: Optional[Tuple[str, ...]] = None,
                    solver: str = "pushrelabel") -> "DispatchPolicy":
        """Map the legacy ``compact=``/``mesh=`` keyword surface
        (``solve_*_ragged``, ``OTService``) onto a policy — the ONE place
        that mapping and its mesh-requires-compact rule live."""
        if mesh is not None and not compact:
            raise ValueError("mesh dispatch requires compact=True (the "
                             "distributed driver is the compacting "
                             "driver)")
        mode = ("mesh" if mesh is not None
                else ("compact" if compact else "lockstep"))
        return cls(mode=mode, mesh=mesh, placement=placement, chunk=chunk,
                   buckets=None if buckets is None else tuple(buckets),
                   guaranteed=guaranteed,
                   want=None if want is None else tuple(want),
                   solver=solver)


def _resolve_solver(spec, policy: DispatchPolicy, inputs, eps):
    """(solver name, dispatch spec, predicted per-instance seconds) for
    ONE pre-batched bucket. Deterministic and side-effect free: calling
    it twice (the solve() wrapper does, to pick the Solution wrap spec)
    yields the same routing the dispatch took, so an "auto" result is
    bit-identical to naming the chosen solver. Only the OT family
    reroutes — assignment (and already-rerouted specs like the hybrid
    finish) pass through as push-relabel."""
    base = getattr(spec, "stepped", spec)
    if policy.solver == "pushrelabel" or base is not OT:
        return "pushrelabel", spec, None
    from .. import portfolio

    solver = policy.solver
    c = np.asarray(inputs["c"]) if isinstance(inputs, dict) else None
    n_eff = int(max(c.shape[1], c.shape[2])) if c is not None else 0
    eps_min = float(np.min(np.asarray(eps, np.float64)))
    if solver == "auto":
        solver, predicted = portfolio.choose(n_eff, eps_min)
    else:
        model = portfolio.get_model()
        predicted = (None if model is None
                     else model.predict(solver, n_eff, eps_min))
    if solver == "sinkhorn":
        # stepped spec here; policy.fused upgrades it to the Pallas row
        # kernel downstream via fused_variant (the fused_spec hook)
        return "sinkhorn", portfolio.SINKHORN, predicted
    if solver == "hybrid":
        return "hybrid", spec, predicted
    return "pushrelabel", spec, predicted


def dispatch(
    spec,
    inputs: Dict[str, Any],
    eps,
    *,
    sizes=None,
    policy: Optional[DispatchPolicy] = None,
    keep_state: bool = False,
    deadline: Optional[float] = None,
    obs=None,
    **prep_kw,
):
    """Solve ONE pre-batched bucket (dict of (B, ...) operands) under
    ``policy``. Returns ``(result, stats)`` — ``stats`` is None for the
    plain lockstep path (it has no chunk/occupancy accounting),
    CompactionStats for compact (and for lockstep with
    ``keep_state=True``, which stashes the pre-completion state on a
    minimal stats object), DistributedStats for mesh. ``deadline`` is an
    absolute monotonic-clock (``repro.obs.now``) wall-clock budget for
    the chunked drivers (best-so-far cut; lockstep has no chunk loop to
    cut, so the combination raises). ``obs`` threads a per-chunk event
    emitter (``repro.obs.Tracer``) into the chunked drivers; lockstep
    ignores it (one unbounded program, nothing per-chunk to report).

    ``policy.solver`` routes the bucket through the solver portfolio
    (push-relabel / Sinkhorn / hybrid / measured-auto); the chosen
    solver, the cost model's prediction, and the measured dispatch wall
    time are annotated onto the returned stats (``solver`` /
    ``predicted_s`` / ``solve_s``) and emitted as a ``"solver-choice"``
    obs event."""
    policy = policy or DispatchPolicy()
    solver, spec, predicted = _resolve_solver(spec, policy, inputs, eps)
    t0 = _now()
    if solver == "hybrid":
        from ..portfolio.hybrid import dispatch_hybrid

        r, stats = dispatch_hybrid(
            inputs, eps, sizes=sizes, policy=policy,
            keep_state=keep_state, deadline=deadline, obs=obs, **prep_kw)
    else:
        r, stats = _dispatch_one(
            spec, inputs, eps, sizes=sizes, policy=policy,
            keep_state=keep_state, deadline=deadline, obs=obs, **prep_kw)
    solve_s = _now() - t0
    if stats is not None:
        # driver stats are plain mutable dataclasses; a stats object
        # that refuses the annotation just goes without it
        for kk, v in (("solver", solver), ("predicted_s", predicted),
                      ("solve_s", solve_s)):
            try:
                setattr(stats, kk, v)
            except (AttributeError, TypeError):
                pass
    if obs is not None:
        obs.event("solver-choice", solver=solver, predicted_s=predicted,
                  solve_s=solve_s)
    return r, stats


def _dispatch_one(
    spec,
    inputs: Dict[str, Any],
    eps,
    *,
    sizes=None,
    policy: Optional[DispatchPolicy] = None,
    keep_state: bool = False,
    deadline: Optional[float] = None,
    obs=None,
    **prep_kw,
):
    """The single-solver dispatch body: mode routing only (the solver
    was already resolved by :func:`dispatch`)."""
    policy = policy or DispatchPolicy()
    mode = policy.resolved_mode()
    if policy.fused:
        spec = fused_variant(spec)
    if policy.validate:
        from .validate import check_admission
        check_admission(spec.canonicalize(inputs), sizes=sizes)
    if mode == "lockstep":
        if deadline is not None:
            raise ValueError(
                "deadline requires a chunked driver (mode='compact' or "
                "'mesh'); the lockstep path dispatches one unbounded "
                "program that cannot be cut mid-flight")
        eps_u = np.unique(np.asarray(eps, np.float64))
        if eps_u.size > 1:
            raise ValueError("per-instance eps requires compact=True")
        r, state = spec.solve_lockstep(
            inputs, float(eps_u[0]), sizes=sizes,
            guaranteed=policy.guaranteed, keep_state=keep_state, **prep_kw)
        if keep_state:
            b = int(np.shape(inputs["c"])[0])
            st = CompactionStats(batch=b, dispatched_batch=b, chunk=0,
                                 dispatches=1, final_state=state)
            return r, st
        return r, None
    k = DEFAULT_CHUNK if policy.chunk is None else int(policy.chunk)
    if mode == "compact":
        return solve_compacting(
            spec, inputs, eps, sizes=sizes, k=k,
            guaranteed=policy.guaranteed, keep_state=keep_state,
            deadline=deadline, obs=obs, **prep_kw)
    if mode == "mesh":
        return solve_mesh(
            spec, inputs, eps, policy.mesh, sizes=sizes, k=k,
            guaranteed=policy.guaranteed, placement=policy.placement,
            keep_state=keep_state, deadline=deadline, obs=obs, **prep_kw)
    raise ValueError(f"unknown dispatch mode {mode!r}")


def _wrap_solution(
    spec, inputs: Dict[str, Any], eps, policy: DispatchPolicy,
    r, stats, *, sizes, want: Optional[Tuple[str, ...]],
    bucket: Optional[Tuple[int, int]] = None,
    solver: str = "pushrelabel", predicted: Optional[float] = None,
) -> SolutionBatch:
    """Wrap one dispatched bucket result in a SolutionBatch (the typed
    surface); device arrays stay put until an artifact is fetched."""
    inputs_c = spec.canonicalize(inputs)
    b = int(spec.batch_shape(inputs_c)[0])
    eps_user = np.broadcast_to(np.asarray(eps, np.float64), (b,)).copy()
    eps_internal = eps_user / 3.0 if policy.guaranteed else eps_user
    sstats = SolveStats.from_driver(stats, mode=policy.resolved_mode(),
                                    batch=b, bucket=bucket, solver=solver,
                                    predicted_s=predicted)
    state = getattr(stats, "final_state", None) if stats is not None else None
    un = getattr(stats, "unconverged", None) if stats is not None else None
    degraded = None if un is None else np.asarray(un, bool)[:b]
    return SolutionBatch(
        spec, r, stats=sstats, driver_stats=stats, inputs=inputs_c,
        sizes=sizes, eps=eps_user, eps_internal=eps_internal,
        guaranteed=policy.guaranteed, want=want, state=state,
        degraded=degraded)


def solve(
    spec,
    instances: Union[Sequence, Dict[str, Any]],
    eps,
    policy: Optional[DispatchPolicy] = None,
    *,
    sizes=None,
    keep_state: bool = False,
    want: Optional[Sequence[str]] = None,
    deadline: Optional[float] = None,
    obs=None,
    **prep_kw,
) -> Union[SolutionBatch, List[Solution], Tuple[Any, Any], List[dict]]:
    """The front door. Two input forms:

    * ``instances`` is a DICT of pre-batched (B, ...) operands (``{"c":
      ...}`` for ``ASSIGNMENT``, ``{"c": ..., "nu": ..., "mu": ...}`` for
      ``OT``; ``sizes`` gives true shapes inside the padding): one bucket
      is dispatched — this is what the serving layers call per bucket.
      Returns a :class:`SolutionBatch` when ``want`` is declared, the
      legacy ``(result, stats)`` tuple otherwise.

    * ``instances`` is a ragged LIST (cost matrices for ``ASSIGNMENT``,
      ``(c, nu, mu)`` triples for ``OT``): instances are grouped into
      shape buckets (``policy.buckets``), padded, dispatched per bucket.
      Returns per-instance :class:`Solution` views (input order) when
      ``want`` is declared, the legacy per-instance dicts otherwise.
      ``eps`` may be per-instance; under lockstep mode each bucket is
      sub-grouped by eps value (lockstep bakes eps into the compiled
      program), so mixed-accuracy sets work under EVERY policy.

    ``want`` declares the artifacts the caller will fetch (see
    ``spec.artifacts``; e.g. ``("cost", "duals", "plan_sparse")``). The
    pre-completion integer ``state`` is just another artifact: asking for
    it (or passing ``keep_state=True``) retains it on every dispatch
    path, including lockstep and the ragged form.

    ``deadline`` (absolute ``time.monotonic()``) threads a wall-clock
    budget into the chunked drivers: dispatching stops when the next
    k-phase chunk would overrun it, and lanes cut before their
    termination predicate fired come back flagged
    ``Solution.degraded=True`` — still primal-feasible with eps-feasible
    duals, so ``dual_feasible()``/``additive_gap()`` re-validate the
    partial answer per request.

    ``obs`` threads an optional event emitter (``repro.obs.Tracer``) into
    the chunked drivers for per-chunk phase/occupancy/compile-cache
    events; results are bit-identical with or without it.
    """
    policy = policy or DispatchPolicy()
    if want is None:
        want = policy.want
    if want is not None:
        want = tuple(want)
        unknown = [w for w in want if w not in spec.artifacts]
        if unknown:
            raise ValueError(f"unknown artifact(s) {unknown} for spec "
                             f"{spec.name!r}; available: {spec.artifacts}")
        if keep_state and "state" not in want:
            # an explicit keep_state IS a request for the state artifact:
            # promote it into the declaration rather than retaining a
            # state the gating would then refuse to hand over
            want = want + ("state",)
        keep_state = keep_state or "state" in want
    if isinstance(instances, dict):
        if want is None:
            return dispatch(spec, instances, eps, sizes=sizes,
                            policy=policy, keep_state=keep_state,
                            deadline=deadline, obs=obs, **prep_kw)
        r, stats = dispatch(spec, instances, eps, sizes=sizes,
                            policy=policy, keep_state=keep_state,
                            deadline=deadline, obs=obs, **prep_kw)
        # re-resolve (deterministic) to wrap with the spec that actually
        # produced r: SINKHORN's result shape for sinkhorn routing, the
        # OT base for hybrid (its finish IS a push-relabel solve)
        solver, wspec, predicted = _resolve_solver(spec, policy,
                                                   instances, eps)
        return _wrap_solution(wspec, instances, eps, policy, r, stats,
                              sizes=sizes, want=want, solver=solver,
                              predicted=predicted)
    sols = _solve_ragged(spec, list(instances), eps, policy,
                         keep_state=keep_state, want=want,
                         deadline=deadline, obs=obs, **prep_kw)
    if want is not None:
        return sols
    # legacy adapter: the historical per-instance dicts, produced from the
    # same Solution views (bit-identical values; ``state`` rides along
    # when requested instead of raising as the pre-Solution surface did)
    out = []
    for s in sols:
        d = s.legacy_dict()
        if keep_state:
            d["state"] = s.state()
        out.append(d)
    return out


def _solve_ragged(spec, instances: list, eps, policy: DispatchPolicy,
                  *, keep_state: bool = False,
                  want: Optional[Tuple[str, ...]] = None,
                  deadline: Optional[float] = None,
                  obs=None,
                  **prep_kw) -> List[Solution]:
    from .batched import DEFAULT_BUCKETS, bucket_instances

    shapes = [spec.instance_shape(x) for x in instances]
    eps_arr = np.broadcast_to(np.asarray(eps, np.float64),
                              (len(instances),))
    buckets = (DEFAULT_BUCKETS if policy.buckets is None
               else tuple(policy.buckets))
    lockstep = policy.resolved_mode() == "lockstep"
    results: List[Optional[Solution]] = [None] * len(instances)
    for grp in bucket_instances(shapes, buckets):
        if lockstep:
            # lockstep compiles eps into the program: sub-group the
            # bucket by eps value so mixed-accuracy sets still dispatch
            by_eps: Dict[float, List[int]] = {}
            for i in grp.indices:
                by_eps.setdefault(float(eps_arr[i]), []).append(i)
            subgroups = [by_eps[e] for e in sorted(by_eps)]
        else:
            subgroups = [grp.indices]
        for idx in subgroups:
            inputs = spec.pad_group([instances[i] for i in idx], grp.key)
            sz = np.asarray([shapes[i] for i in idx], np.int32)
            r, stats = dispatch(spec, inputs, eps_arr[idx], sizes=sz,
                                policy=policy, keep_state=keep_state,
                                deadline=deadline, obs=obs, **prep_kw)
            # per-bucket re-resolution (auto may route buckets to
            # different solvers); deterministic, so it matches dispatch
            solver, wspec, predicted = _resolve_solver(
                spec, policy, inputs, eps_arr[idx])
            batch = _wrap_solution(wspec, inputs, eps_arr[idx], policy, r,
                                   stats, sizes=sz, want=want,
                                   bucket=grp.key, solver=solver,
                                   predicted=predicted)
            # per-instance views share the batch's device arrays and its
            # fetch cache: one device->host fetch per artifact per
            # bucket, never per instance
            for j, i in enumerate(idx):
                results[i] = batch[j]
    return results
