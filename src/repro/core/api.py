"""The unified solve() front door: one entry point, every dispatch path.

``solve(spec, instances, eps, policy)`` routes any batch of assignment/OT
work — a ragged list of instances or one pre-batched bucket — through a
single code path to whichever driver the :class:`DispatchPolicy` selects:

  * ``lockstep``   the PR-1 fixed-shape vmapped while_loop (one dispatch,
                   every lane runs until the slowest converges);
  * ``compact``    the convergence-compacting chunked-phase driver
                   (core/compaction.py) — per-instance eps supported;
  * ``mesh``       the mesh-distributed compacting driver
                   (core/distributed.py), with ``placement`` choosing
                   batch-axis sharding vs per-instance row/col matrix
                   sharding ("auto" applies ``choose_placement``).

Results are IDENTICAL across policies for the batch-sharded family
(lockstep == compact == mesh/batch, bit for bit); mesh/matrix matches to
reassociation ulps in the float epilogue (the documented shape caveat in
core/distributed.py). The serving layers (``OTService``,
``AsyncOTScheduler``) and the ragged ``solve_*_ragged`` wrappers all call
this front door, so a new dispatch strategy lands in exactly one place.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .compaction import DEFAULT_CHUNK, solve_compacting
from .distributed import solve_mesh
from .problem import ASSIGNMENT, OT  # noqa: F401  (re-exported: the
#   front door and the specs it dispatches are one import site)

_MODES = ("auto", "lockstep", "compact", "mesh")


@dataclass(frozen=True)
class DispatchPolicy:
    """How a batch should be dispatched.

    Args:
      mode: "auto" (mesh when ``mesh`` is set, else compact), "lockstep",
        "compact", or "mesh".
      mesh: 1-D batch mesh (``launch.mesh.make_batch_mesh``); required
        meaningfully only for mode="mesh" (None resolves the default
        host mesh there).
      placement: mesh-mode placement — "auto" | "batch" | "matrix".
      chunk: k, phases per dispatch of the compacting drivers.
      buckets: shape-bucket boundaries for ragged input (None -> the
        core/batched.py defaults; oversized shapes mint ceil-pow2
        buckets).
      guaranteed: run at eps/3 for the paper's <= OPT + eps*m bound.
    """
    mode: str = "auto"
    mesh: Any = None
    placement: str = "auto"
    chunk: Optional[int] = None
    buckets: Optional[Tuple[int, ...]] = None
    guaranteed: bool = False

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown dispatch mode {self.mode!r}; "
                             f"expected one of {_MODES}")
        if self.mode == "lockstep" and self.mesh is not None:
            raise ValueError("mode='lockstep' cannot dispatch over a mesh "
                             "— use mode='compact' or mode='mesh' (the "
                             "distributed driver is the compacting driver)")

    def resolved_mode(self) -> str:
        if self.mode != "auto":
            return self.mode
        return "mesh" if self.mesh is not None else "compact"

    @classmethod
    def from_legacy(cls, compact: bool, mesh=None, *, chunk=None,
                    buckets=None, guaranteed: bool = False,
                    placement: str = "auto") -> "DispatchPolicy":
        """Map the legacy ``compact=``/``mesh=`` keyword surface
        (``solve_*_ragged``, ``OTService``) onto a policy — the ONE place
        that mapping and its mesh-requires-compact rule live."""
        if mesh is not None and not compact:
            raise ValueError("mesh dispatch requires compact=True (the "
                             "distributed driver is the compacting "
                             "driver)")
        mode = ("mesh" if mesh is not None
                else ("compact" if compact else "lockstep"))
        return cls(mode=mode, mesh=mesh, placement=placement, chunk=chunk,
                   buckets=None if buckets is None else tuple(buckets),
                   guaranteed=guaranteed)


def dispatch(
    spec,
    inputs: Dict[str, Any],
    eps,
    *,
    sizes=None,
    policy: Optional[DispatchPolicy] = None,
    keep_state: bool = False,
    **prep_kw,
):
    """Solve ONE pre-batched bucket (dict of (B, ...) operands) under
    ``policy``. Returns ``(result, stats)`` — ``stats`` is None for the
    lockstep path (it has no chunk/occupancy accounting),
    CompactionStats for compact, DistributedStats for mesh."""
    policy = policy or DispatchPolicy()
    mode = policy.resolved_mode()
    if mode == "lockstep":
        if keep_state:
            # the lockstep path has no stats object to carry the
            # pre-completion state; fail loudly like the other paths
            raise ValueError("keep_state=True requires mode='compact' or "
                             "mesh batch placement")
        eps_u = np.unique(np.asarray(eps, np.float64))
        if eps_u.size > 1:
            raise ValueError("per-instance eps requires compact=True")
        return spec.solve_lockstep(
            inputs, float(eps_u[0]), sizes=sizes,
            guaranteed=policy.guaranteed, **prep_kw), None
    k = DEFAULT_CHUNK if policy.chunk is None else int(policy.chunk)
    if mode == "compact":
        return solve_compacting(
            spec, inputs, eps, sizes=sizes, k=k,
            guaranteed=policy.guaranteed, keep_state=keep_state, **prep_kw)
    if mode == "mesh":
        return solve_mesh(
            spec, inputs, eps, policy.mesh, sizes=sizes, k=k,
            guaranteed=policy.guaranteed, placement=policy.placement,
            keep_state=keep_state, **prep_kw)
    raise ValueError(f"unknown dispatch mode {mode!r}")


def solve(
    spec,
    instances: Union[Sequence, Dict[str, Any]],
    eps,
    policy: Optional[DispatchPolicy] = None,
    *,
    sizes=None,
    keep_state: bool = False,
    **prep_kw,
):
    """The front door. Two input forms:

    * ``instances`` is a DICT of pre-batched (B, ...) operands (``{"c":
      ...}`` for ``ASSIGNMENT``, ``{"c": ..., "nu": ..., "mu": ...}`` for
      ``OT``; ``sizes`` gives true shapes inside the padding): one bucket
      is dispatched and ``(result, stats)`` returned — this is what the
      serving layers call per bucket.

    * ``instances`` is a ragged LIST (cost matrices for ``ASSIGNMENT``,
      ``(c, nu, mu)`` triples for ``OT``): instances are grouped into
      shape buckets (``policy.buckets``), padded, dispatched per bucket,
      and a list of per-instance result dicts is returned in input order.
      ``eps`` may be per-instance; under lockstep mode each bucket is
      sub-grouped by eps value (lockstep bakes eps into the compiled
      program), so mixed-accuracy sets work under EVERY policy.
    """
    policy = policy or DispatchPolicy()
    if isinstance(instances, dict):
        return dispatch(spec, instances, eps, sizes=sizes, policy=policy,
                        keep_state=keep_state, **prep_kw)
    if keep_state:
        # the ragged path returns per-instance dicts, not (result, stats)
        # — there is nowhere to surface the pre-completion state; fail
        # loudly instead of silently dropping the flag
        raise ValueError("keep_state=True requires the pre-batched dict "
                         "input form (it is returned on the stats)")
    return _solve_ragged(spec, list(instances), eps, policy, **prep_kw)


def _solve_ragged(spec, instances: list, eps,
                  policy: DispatchPolicy, **prep_kw) -> List[dict]:
    from .batched import DEFAULT_BUCKETS, bucket_instances

    shapes = [spec.instance_shape(x) for x in instances]
    eps_arr = np.broadcast_to(np.asarray(eps, np.float64),
                              (len(instances),))
    buckets = (DEFAULT_BUCKETS if policy.buckets is None
               else tuple(policy.buckets))
    lockstep = policy.resolved_mode() == "lockstep"
    results: List[Optional[dict]] = [None] * len(instances)
    for grp in bucket_instances(shapes, buckets):
        if lockstep:
            # lockstep compiles eps into the program: sub-group the
            # bucket by eps value so mixed-accuracy sets still dispatch
            by_eps: Dict[float, List[int]] = {}
            for i in grp.indices:
                by_eps.setdefault(float(eps_arr[i]), []).append(i)
            subgroups = [by_eps[e] for e in sorted(by_eps)]
        else:
            subgroups = [grp.indices]
        for idx in subgroups:
            inputs = spec.pad_group([instances[i] for i in idx], grp.key)
            sz = np.asarray([shapes[i] for i in idx], np.int32)
            r, stats = dispatch(spec, inputs, eps_arr[idx], sizes=sz,
                                policy=policy, **prep_kw)
            # one device->host fetch per result array, not per instance
            host = spec.fetch(r)
            for j, i in enumerate(idx):
                out = spec.unpack(host, j, shapes[i])
                out["batch_size"] = len(idx)
                out["bucket"] = grp.key
                if stats is not None:
                    out["dispatches"] = stats.dispatches
                    if hasattr(stats, "devices"):
                        out["devices"] = stats.devices
                results[i] = out
    return results
