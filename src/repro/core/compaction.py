"""Convergence-compacting chunked-phase batch driver.

The lockstep batched solvers (core/batched.py) vmap one unbounded
``lax.while_loop`` over the batch, so every instance in a bucket burns
phase-iterations until the *slowest* instance converges — ROADMAP measured
~3x max-phase skew at eps=0.1, i.e. most batched FLOPs were select-masked
no-ops. This driver recovers the paper's per-instance O(log n / eps^2)
parallel bound for a fleet of instances by retiring converged work early:

  1. dispatch ``k`` phases to the whole bucket via the resumable stepped
     cores (``run_assignment_phases`` / ``run_ot_phases``);
  2. fetch the (B,) converged mask (one scalar-per-instance device->host
     sync per chunk — the phase loops themselves never sync);
  3. once occupancy has halved, scatter the bucket's states into a full-B
     result buffer and gather the survivors into the next power-of-two
     batch bucket (converged instances pad the gather; their termination
     predicate is already false, so they add zero loop iterations);
  4. when everyone has terminated, run the completion/cost epilogue ONCE,
     in bulk, over the full-B buffer of retired states.

Every dispatched program is keyed by (bucket shape, k, batch bucket), so
the power-of-two descent B -> B/2 -> ... compiles each size once and
reuses it for all future traffic. Per-instance state trajectories are
bit-identical to the lockstep path (and hence to unbatched solves): the
chunked loops share the exact phase body, vmap lanes never interact, and
the deterministic proposal hash keys depend only on the within-instance
(row, col, phase) — never on batch position. Retiring a neighbor cannot
perturb a survivor.

Unlike the lockstep path, ``eps`` may be a per-instance (B,) array here:
the rounding prologue takes eps as a traced scalar and the termination
threshold/phase cap are per-instance anyway, so one compacted dispatch can
serve a mixed-accuracy batch (the skew such mixtures create is exactly
what compaction absorbs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .batched import (
    BatchedAssignmentResult,
    _mask_ot_inputs,
    _sizes_arrays,
    _theta_array,
)
from .pushrelabel import (
    _max_phases,
    assignment_converged,
    assignment_epilogue,
    assignment_prologue,
    init_assignment_state,
    run_assignment_phases,
)
from .transport import (
    init_ot_state,
    ot_converged,
    ot_epilogue,
    ot_phase_cap,
    ot_prologue,
    run_ot_phases,
)

DEFAULT_CHUNK = 8


@dataclass
class CompactionStats:
    """Occupancy/waste accounting for one compacted solve."""
    batch: int                 # real instances
    dispatched_batch: int      # power-of-two padded batch the driver ran
    chunk: int                 # k, phases per dispatch
    dispatches: int = 0
    # (batch bucket, live instances) after each k-phase dispatch
    occupancy: List[Tuple[int, int]] = field(default_factory=list)
    slot_phases: int = 0       # phase-slots actually executed (all lanes)
    phases_needed: int = 0     # sum of per-instance converged phase counts
    lockstep_slot_phases: int = 0  # batch * max(phases): what lockstep burns
    # final integer ASSIGNMENT state (trimmed to the real batch), stashed
    # only when the solver is called with ``keep_state=True`` so the
    # feasibility certificates (core/feasibility.py) can run on the exact
    # pre-completion state (BatchedAssignmentResult carries no state; the
    # OT result's ``state`` field already does). Not serialized.
    final_state: Optional[Any] = None

    def as_dict(self) -> dict:
        return {
            "batch": self.batch,
            "dispatched_batch": self.dispatched_batch,
            "chunk": self.chunk,
            "dispatches": self.dispatches,
            "occupancy": [list(o) for o in self.occupancy],
            "slot_phases": self.slot_phases,
            "phases_needed": self.phases_needed,
            "lockstep_slot_phases": self.lockstep_slot_phases,
        }


def pow2_at_least(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    p = 1
    while p < x:
        p *= 2
    return p


@jax.jit
def _gather(tree, idx):
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


@jax.jit
def _scatter(buf, tree, idx):
    return jax.tree_util.tree_map(lambda b, a: b.at[idx].set(a), buf, tree)


def _drive(data, state, run_fn, conv_fn, max_chunks: int,
           stats: CompactionStats):
    """Generic compacting loop over a per-instance ``data`` pytree (solver
    inputs: integer costs, thresholds, caps) and a solver-state pytree.

    ``run_fn(data, state) -> state`` advances every lane by at most
    ``stats.chunk`` phases (the chunk size is baked into ``run_fn``) and
    DONATES the state buffers (re-dispatch never holds two copies of the
    solver state in device memory); ``conv_fn(data, state) -> (B,) bool``
    is the per-lane termination predicate. Returns the full-size state
    pytree with every lane terminated, in original batch order."""
    idx = np.arange(stats.dispatched_batch)
    # The result buffer is born at the FIRST flush (where ``idx`` is still
    # the identity, so the flush is just the current state) rather than
    # aliasing the initial state: run_fn donates its state argument, and a
    # buffer that aliased the donated initial state would be dead here.
    buf = None
    cur_d, cur_s = data, state
    ph_prev = np.zeros((stats.dispatched_batch,), np.int64)
    for _ in range(max_chunks):
        cur_s = run_fn(cur_d, cur_s)
        stats.dispatches += 1
        conv = np.asarray(conv_fn(cur_d, cur_s))
        ph = np.asarray(cur_s.phases, np.int64)
        bb = int(conv.shape[0])
        # the vmapped while_loop runs every lane for the max phase delta
        stats.slot_phases += bb * int((ph - ph_prev).max(initial=0))
        ph_prev = ph
        live = int((~conv).sum())
        stats.occupancy.append((bb, live))
        if live == 0:
            buf = cur_s if buf is None else _scatter(buf, cur_s,
                                                     jnp.asarray(idx))
            break
        nb = pow2_at_least(live)
        if nb <= bb // 2:
            # retire: flush ALL current lanes to the result buffer (the
            # survivor writes are dead — overwritten by a later flush —
            # but a full-lane scatter keeps the index vector at the fixed
            # bucket length, so the program set stays one-per-(shape, B);
            # scattering only the converged lanes would retrace per
            # data-dependent lane count), then gather survivors (padded
            # with one converged lane, which is inert — its predicate is
            # already false) into the next bucket.
            buf = cur_s if buf is None else _scatter(buf, cur_s,
                                                     jnp.asarray(idx))
            surv = np.flatnonzero(~conv)
            fill = np.flatnonzero(conv)[:1]
            sel = np.concatenate([surv, np.repeat(fill, nb - live)])
            sel_j = jnp.asarray(sel)
            cur_d = _gather(cur_d, sel_j)
            cur_s = _gather(cur_s, sel_j)
            idx = idx[sel]
            ph_prev = ph[sel]
    else:
        # phase caps bound every lane, so the loop always breaks; flush
        # defensively if a cap change ever violates that.
        buf = cur_s if buf is None else _scatter(buf, cur_s,
                                                 jnp.asarray(idx))
    return buf


def _eps_array(eps, b: int, guaranteed: bool) -> np.ndarray:
    arr = np.broadcast_to(np.asarray(eps, np.float64), (b,)).copy()
    if guaranteed:
        arr = arr / 3.0
    if (arr <= 0).any():
        raise ValueError("eps must be positive")
    return arr


class PreparedAssignment(NamedTuple):
    """Host-side prep shared by the single-device compacting driver and the
    mesh-distributed driver (core/distributed.py): padded inputs, per-lane
    host-float64 thresholds/caps, and the dispatched (power-of-two) batch."""
    c: jnp.ndarray            # (bp, M, N) padded costs
    eps_arr: np.ndarray       # (bp,) float64 per-lane eps
    m_valid: np.ndarray       # (bp,) int32
    n_valid: np.ndarray       # (bp,) int32
    threshold: np.ndarray     # (bp,) int32
    phase_cap: np.ndarray     # (bp,) int32
    bp: int                   # dispatched batch (power of two >= min_batch)


def prepare_assignment_batch(c, eps, sizes, guaranteed: bool,
                             min_batch: int = 1) -> PreparedAssignment:
    """Masking/threshold/padding half of the compacting assignment solve.

    Pads the batch to ``max(pow2_at_least(B), min_batch)`` with
    born-converged empty instances (zero valid rows -> free supply 0 <=
    threshold 0): the distributed driver passes ``min_batch = device
    count`` so the batch axis starts divisible by the mesh. Thresholds are
    host float64, identical to the unbatched ``int(eps * m)``."""
    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    eps_arr = _eps_array(eps, b, guaranteed)
    threshold = np.asarray(
        [int(e * int(mi)) for e, mi in zip(eps_arr, m_valid)], np.int32
    )
    phase_cap = np.asarray([_max_phases(float(e), m) for e in eps_arr],
                           np.int32)
    bp = max(pow2_at_least(b), pow2_at_least(min_batch))
    if bp > b:
        pad = bp - b
        c = jnp.concatenate([c, jnp.zeros((pad, m, n), jnp.float32)])
        m_valid = np.concatenate([m_valid, np.zeros((pad,), np.int32)])
        n_valid = np.concatenate([n_valid, np.zeros((pad,), np.int32)])
        threshold = np.concatenate([threshold, np.zeros((pad,), np.int32)])
        phase_cap = np.concatenate([phase_cap, np.zeros((pad,), np.int32)])
        eps_arr = np.concatenate([eps_arr, np.full((pad,), eps_arr[0])])
    return PreparedAssignment(c, eps_arr, m_valid, n_valid, threshold,
                              phase_cap, bp)


class PreparedOT(NamedTuple):
    """OT counterpart of :class:`PreparedAssignment`."""
    c: jnp.ndarray            # (bp, M, N) masked+padded costs
    nu: jnp.ndarray           # (bp, M)
    mu: jnp.ndarray           # (bp, N)
    eps_arr: np.ndarray       # (bp,) float64
    th: np.ndarray            # (bp,) float32 per-lane theta
    threshold: np.ndarray     # (bp,) int32 host-float64 termination
    phase_cap: np.ndarray     # (bp,) int32
    bp: int


def prepare_ot_batch(c, nu, mu, eps, sizes, theta, guaranteed: bool,
                     min_batch: int = 1) -> PreparedOT:
    """Masking/threshold/padding half of the compacting OT solve; shares the
    padding-mask + host-float64 threshold code with the lockstep path
    (``_mask_ot_inputs``) so the code paths can never diverge. Batch padding
    is born-converged (zero mass -> free supply 0 <= threshold 0)."""
    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    eps_arr = _eps_array(eps, b, guaranteed)
    th = _theta_array(m_valid, n_valid, eps_arr, theta)
    phase_cap = np.asarray([ot_phase_cap(float(e)) for e in eps_arr],
                           np.int32)
    c, nu, mu, threshold = _mask_ot_inputs(c, nu, mu, m_valid, n_valid,
                                           th, eps_arr)
    bp = max(pow2_at_least(b), pow2_at_least(min_batch))
    if bp > b:
        pad = bp - b
        c = jnp.concatenate([c, jnp.zeros((pad, m, n), jnp.float32)])
        nu = jnp.concatenate([nu, jnp.zeros((pad, m), jnp.float32)])
        mu = jnp.concatenate([mu, jnp.zeros((pad, n), jnp.float32)])
        th = np.concatenate([th, np.ones((pad,), np.float32)])
        threshold = np.concatenate([threshold, np.zeros((pad,), np.int32)])
        phase_cap = np.concatenate([phase_cap, np.zeros((pad,), np.int32)])
        eps_arr = np.concatenate([eps_arr, np.full((pad,), eps_arr[0])])
    return PreparedOT(c, nu, mu, eps_arr, th, threshold, phase_cap, bp)


# --------------------------------------------------------------------------
# Assignment
# --------------------------------------------------------------------------

@jax.jit
def _assign_prologue_b(c, eps, m_valid, n_valid):
    return jax.vmap(assignment_prologue)(c, eps, m_valid, n_valid)


@partial(jax.jit, static_argnames=("k",), donate_argnums=(1,))
def _assign_chunk(data, state, k: int):
    return jax.vmap(
        lambda d, s: run_assignment_phases(
            d["c_int"], s, d["threshold"], d["phase_cap"], k,
            m_valid=d["m_valid"],
        )
    )(data, state)


@jax.jit
def _assign_conv(data, state):
    return jax.vmap(
        lambda d, s: assignment_converged(
            s, d["threshold"], d["phase_cap"], m_valid=d["m_valid"]
        )
    )(data, state)


@jax.jit
def _assign_epilogue_b(cm, scale, state, eps, row_ok, col_ok):
    return jax.vmap(assignment_epilogue)(cm, scale, state, eps,
                                         row_ok, col_ok)


def solve_assignment_batched_compacting(
    c: jnp.ndarray,
    eps,
    *,
    sizes=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    keep_state: bool = False,
):
    """Compacting counterpart of ``solve_assignment_batched``.

    Args:
      c: (B, M, N) padded costs, as in the lockstep path.
      eps: scalar, or (B,) per-instance array (mixed-accuracy batch — the
        lockstep path cannot express this).
      k: phases per dispatch; any value yields identical results.
      keep_state: stash the final pre-completion integer state on the
        returned stats (``final_state``) for feasibility certificates;
        off by default so serving paths don't retain an extra state copy.

    Returns ``(BatchedAssignmentResult, CompactionStats)``; every result
    leaf is bit-identical per instance to the lockstep path (and to the
    unbatched solver) for a shared scalar eps.
    """
    c = jnp.asarray(c, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    if b == 0:
        z = lambda *s: jnp.zeros(s, jnp.float32)
        out = BatchedAssignmentResult(
            matching=jnp.zeros((0, m), jnp.int32), cost=z(0),
            y_b=z(0, m), y_a=z(0, n),
            phases=jnp.zeros((0,), jnp.int32),
            rounds=jnp.zeros((0,), jnp.int32),
            matched_before_completion=jnp.zeros((0,), jnp.int32),
        )
        return out, CompactionStats(batch=0, dispatched_batch=0, chunk=k)
    # Pad the batch to a power of two with born-converged empty instances,
    # so the descent B -> B/2 -> ... visits only power-of-two shapes.
    p = prepare_assignment_batch(c, eps, sizes, guaranteed)
    c, eps_arr, bp = p.c, p.eps_arr, p.bp
    threshold, phase_cap = p.threshold, p.phase_cap

    eps_j = jnp.asarray(eps_arr, jnp.float32)
    mv_j = jnp.asarray(p.m_valid)
    nv_j = jnp.asarray(p.n_valid)
    cm, c_int, scale, row_ok, col_ok = _assign_prologue_b(c, eps_j, mv_j,
                                                          nv_j)
    data = {
        "c_int": c_int,
        "threshold": jnp.asarray(threshold),
        "phase_cap": jnp.asarray(phase_cap),
        "m_valid": mv_j,
    }
    state0 = jax.vmap(lambda _: init_assignment_state(m, n))(
        jnp.zeros((bp,))
    )
    stats = CompactionStats(batch=b, dispatched_batch=bp, chunk=k)
    max_chunks = -(-int(phase_cap.max(initial=1)) // max(k, 1)) + 2
    final = _drive(data, state0, partial(_assign_chunk, k=k), _assign_conv,
                   max_chunks, stats)
    r = _assign_epilogue_b(cm, scale, final, eps_j, row_ok, col_ok)

    phases = np.asarray(final.phases[:b], np.int64)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    if keep_state:
        stats.final_state = jax.tree_util.tree_map(lambda a: a[:b], final)
    out = BatchedAssignmentResult(
        matching=r.matching[:b],
        cost=r.cost[:b],
        y_b=r.y_b[:b],
        y_a=r.y_a[:b],
        phases=r.phases[:b],
        rounds=r.rounds[:b],
        matched_before_completion=r.matched_before_completion[:b],
    )
    return out, stats


# --------------------------------------------------------------------------
# General OT
# --------------------------------------------------------------------------

@jax.jit
def _ot_prologue_b(c, nu, mu, theta, eps):
    return jax.vmap(ot_prologue)(c, nu, mu, theta, eps)


@partial(jax.jit, static_argnames=("k", "max_rounds"), donate_argnums=(1,))
def _ot_chunk(data, state, k: int, max_rounds: int):
    return jax.vmap(
        lambda d, s: run_ot_phases(d["c_int"], s, d["threshold"],
                                   d["phase_cap"], k, max_rounds)
    )(data, state)


@jax.jit
def _ot_conv(data, state):
    return jax.vmap(
        lambda d, s: ot_converged(s, d["threshold"], d["phase_cap"])
    )(data, state)


@jax.jit
def _ot_epilogue_b(c, nu, mu, theta, eps, scale, s_int, d_int, state):
    return jax.vmap(ot_epilogue)(c, nu, mu, theta, eps, scale, s_int,
                                 d_int, state)


def solve_ot_batched_compacting(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps,
    *,
    sizes=None,
    theta=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
):
    """Compacting counterpart of ``solve_ot_batched``.

    Same contract as the lockstep path ((B, M, N) costs, (B, M)/(B, N)
    masses, padding zeroed from ``sizes``), plus per-instance ``eps``
    support. Returns ``(OTResult with leading batch axes, CompactionStats)``.
    """
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    if b == 0:
        from .transport import OTResult, OTState

        zf = lambda *s: jnp.zeros(s, jnp.float32)
        zi = lambda *s: jnp.zeros(s, jnp.int32)
        out = OTResult(
            plan=zf(0, m, n), cost=zf(0), y_b=zf(0, m), y_a=zf(0, n),
            phases=zi(0), rounds=zi(0),
            state=OTState(y_b=zi(0, m), ya_hi=zi(0, n), free_b=zi(0, m),
                          free_a=zi(0, n), f_hi=zi(0, m, n),
                          f_lo=zi(0, m, n), phases=zi(0), rounds=zi(0)),
            theta=zf(0), s_int=zi(0, m), d_int=zi(0, n),
        )
        return out, CompactionStats(batch=0, dispatched_batch=0, chunk=k)
    # Padding masks + host-float64 thresholds shared with the lockstep
    # path (so the two can never diverge), power-of-two batch padding with
    # born-converged empty instances.
    p = prepare_ot_batch(c, nu, mu, eps, sizes, theta, guaranteed)
    c, nu, mu, eps_arr, bp = p.c, p.nu, p.mu, p.eps_arr, p.bp
    th, threshold, phase_cap = p.th, p.threshold, p.phase_cap

    eps_j = jnp.asarray(eps_arr, jnp.float32)
    th_j = jnp.asarray(th)
    c_int, s_int, d_int, scale = _ot_prologue_b(c, nu, mu, th_j, eps_j)
    data = {
        "c_int": c_int,
        "threshold": jnp.asarray(threshold),
        "phase_cap": jnp.asarray(phase_cap),
    }
    state0 = jax.vmap(init_ot_state)(s_int, d_int)
    stats = CompactionStats(batch=b, dispatched_batch=bp, chunk=k)
    max_rounds = int(m + n + 2)
    max_chunks = -(-int(phase_cap.max(initial=1)) // max(k, 1)) + 2
    final = _drive(data, state0,
                   partial(_ot_chunk, k=k, max_rounds=max_rounds),
                   _ot_conv, max_chunks, stats)
    r = _ot_epilogue_b(c, nu, mu, th_j, eps_j, scale, s_int, d_int, final)

    phases = np.asarray(final.phases[:b], np.int64)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    out = jax.tree_util.tree_map(lambda a: a[:b], r)
    return out, stats
