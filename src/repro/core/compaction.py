"""Convergence-compacting chunked-phase batch driver, generic over a
:class:`~repro.core.problem.ProblemSpec`.

The lockstep batched solvers (core/batched.py) vmap one unbounded
``lax.while_loop`` over the batch, so every instance in a bucket burns
phase-iterations until the *slowest* instance converges — ROADMAP measured
~3x max-phase skew at eps=0.1, i.e. most batched FLOPs were select-masked
no-ops. This driver recovers the paper's per-instance O(log n / eps^2)
parallel bound for a fleet of instances by retiring converged work early:

  1. dispatch ``k`` phases to the whole bucket via the resumable stepped
     cores (``spec.run_phases``);
  2. fetch the (B,) converged mask (one scalar-per-instance device->host
     sync per chunk — the phase loops themselves never sync);
  3. once occupancy has halved, scatter the bucket's states into a full-B
     result buffer and gather the survivors into the next power-of-two
     batch bucket (converged instances pad the gather; their termination
     predicate is already false, so they add zero loop iterations);
  4. when everyone has terminated, run the completion/cost epilogue ONCE,
     in bulk, over the full-B buffer of retired states.

The driver is written once: ``solve_compacting(spec, ...)`` takes any
ProblemSpec (``ASSIGNMENT`` or ``OT`` from core/problem.py) and never
mentions either problem by name. The public per-problem entry points
(``solve_assignment_batched_compacting`` / ``solve_ot_batched_compacting``)
are thin spec-binding wrappers with their original signatures.

Every dispatched program is keyed by (bucket shape, k, batch bucket), so
the power-of-two descent B -> B/2 -> ... compiles each size once and
reuses it for all future traffic. Per-instance state trajectories are
bit-identical to the lockstep path (and hence to unbatched solves): the
chunked loops share the exact phase body, vmap lanes never interact, and
the deterministic proposal hash keys depend only on the within-instance
(row, col, phase) — never on batch position. Retiring a neighbor cannot
perturb a survivor.

Unlike the lockstep path, ``eps`` may be a per-instance (B,) array here:
the rounding prologue takes eps as a traced scalar and the termination
threshold/phase cap are per-instance anyway, so one compacted dispatch can
serve a mixed-accuracy batch (the skew such mixtures create is exactly
what compaction absorbs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# the serving stack's one monotonic clock (repro.obs.metrics.now): chunk
# timing and deadline checks here share a time base with the scheduler's
# spans, submit timestamps, and per-request deadlines
from ..obs.metrics import now as _now
from .problem import ASSIGNMENT, OT, pow2_at_least

DEFAULT_CHUNK = 8


@dataclass
class CompactionStats:
    """Occupancy/waste accounting for one compacted solve."""
    batch: int                 # real instances
    dispatched_batch: int      # power-of-two padded batch the driver ran
    chunk: int                 # k, phases per dispatch
    dispatches: int = 0
    # (batch bucket, live instances) after each k-phase dispatch
    occupancy: List[Tuple[int, int]] = field(default_factory=list)
    slot_phases: int = 0       # phase-slots actually executed (all lanes)
    phases_needed: int = 0     # sum of per-instance converged phase counts
    lockstep_slot_phases: int = 0  # batch * max(phases): what lockstep burns
    # final integer solver state (trimmed to the real batch), stashed only
    # when the solver is called with ``keep_state=True`` so the feasibility
    # certificates (core/feasibility.py) can run on the exact
    # pre-completion state (BatchedAssignmentResult carries no state; the
    # OT result's ``state`` field already does). Not serialized.
    final_state: Optional[Any] = None
    # wall-clock deadline support: ``deadline_hit`` records that the chunk
    # loop stopped dispatching because its next chunk would overrun the
    # caller's budget; ``unconverged`` is the (dispatched_batch,) bool mask
    # of lanes (original batch order) whose termination predicate had not
    # yet fired at the cut — their answers are best-so-far (still
    # primal-feasible with eps-feasible duals; see Solution.degraded).
    deadline_hit: bool = False
    unconverged: Optional[Any] = None

    def as_dict(self) -> dict:
        return {
            "batch": self.batch,
            "dispatched_batch": self.dispatched_batch,
            "chunk": self.chunk,
            "dispatches": self.dispatches,
            "occupancy": [list(o) for o in self.occupancy],
            "slot_phases": self.slot_phases,
            "phases_needed": self.phases_needed,
            "lockstep_slot_phases": self.lockstep_slot_phases,
            "deadline_hit": self.deadline_hit,
        }


@jax.jit
def _gather(tree, idx):
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


@jax.jit
def _scatter(buf, tree, idx):
    return jax.tree_util.tree_map(lambda b, a: b.at[idx].set(a), buf, tree)


def _drive(data, state, run_fn, conv_fn, max_chunks: int,
           stats: CompactionStats, deadline: Optional[float] = None,
           obs=None):
    """Generic compacting loop over a per-instance ``data`` pytree (solver
    inputs: integer costs, thresholds, caps) and a solver-state pytree.

    ``run_fn(data, state) -> state`` advances every lane by at most
    ``stats.chunk`` phases (the chunk size is baked into ``run_fn``) and
    DONATES the state buffers (re-dispatch never holds two copies of the
    solver state in device memory); ``conv_fn(data, state) ->
    ((B,) bool, (B,) int32)`` is the per-lane termination predicate
    bundled with the per-lane phase counters. Returns the full-size state
    pytree with every lane terminated, in original batch order.

    The ``conv, ph = jax.device_get(...)`` fetch is the ONLY device->host
    sync in the loop (one per chunk) — the phase counters ride the same
    dispatch as the mask precisely so they don't cost a second blocking
    fetch. ``repro.analysis``'s hot-loop sync audit pins this contract.

    ``deadline`` is an absolute ``time.monotonic()`` instant: after each
    chunk the driver compares the host clock (free — the conv fetch
    already synced) plus the measured duration of the chunk that just ran
    against it, and stops dispatching when the NEXT chunk would overrun,
    flushing best-so-far state and recording the still-unconverged lanes
    on ``stats``. At least one chunk always runs (progress guarantee).

    ``obs`` is an optional event emitter (duck-typed
    ``repro.obs.Tracer``): one ``"chunk"`` event per dispatch carrying
    the batch bucket, live-lane count, wall time, max phase delta, and
    the chunk program's jit-cache delta (nonzero exactly when this
    dispatch compiled), plus a ``"deadline-cut"`` event when the budget
    stops the loop. Everything emitted is a host scalar the loop already
    had — observability adds no device->host syncs (the sync audit holds
    this loop to the single conv fetch either way)."""
    idx = np.arange(stats.dispatched_batch)
    cache_fn = getattr(run_fn, "_cache_size", None) if obs is not None \
        else None
    cache_prev = cache_fn() if cache_fn is not None else 0
    # The result buffer is born at the FIRST flush (where ``idx`` is still
    # the identity, so the flush is just the current state) rather than
    # aliasing the initial state: run_fn donates its state argument, and a
    # buffer that aliased the donated initial state would be dead here.
    buf = None
    cur_d, cur_s = data, state
    ph_prev = np.zeros((stats.dispatched_batch,), np.int64)
    for _ in range(max_chunks):
        t_chunk = _now()
        cur_s = run_fn(cur_d, cur_s)
        stats.dispatches += 1
        conv, ph = jax.device_get(conv_fn(cur_d, cur_s))
        t_chunk = _now() - t_chunk
        ph = ph.astype(np.int64)
        bb = int(conv.shape[0])
        # the vmapped while_loop runs every lane for the max phase delta
        dph = int((ph - ph_prev).max(initial=0))
        stats.slot_phases += bb * dph
        ph_prev = ph
        live = int((~conv).sum())
        stats.occupancy.append((bb, live))
        if obs is not None:
            cache_now = cache_fn() if cache_fn is not None else 0
            obs.event("chunk", bucket=bb, live=live, chunk_s=t_chunk,
                      phases=dph, compiled=cache_now - cache_prev)
            cache_prev = cache_now
        if live == 0:
            buf = cur_s if buf is None else _scatter(buf, cur_s,
                                                     jnp.asarray(idx))
            break
        if deadline is not None and _now() + t_chunk >= deadline:
            # the earliest deadline is at risk: another chunk (estimated
            # by the one that just ran) would overrun it. Flush best-so-
            # far state and mark the lanes that had not yet terminated —
            # the epilogue is well-defined on any phase boundary (the
            # phase-cap termination path already runs it on unconverged
            # states), so callers get a primal-feasible answer whose
            # certificate reports the true (larger) gap.
            stats.deadline_hit = True
            un = np.zeros((stats.dispatched_batch,), bool)
            un[idx[~conv]] = True
            stats.unconverged = un
            if obs is not None:
                obs.event("deadline-cut", bucket=bb, live=live)
            buf = cur_s if buf is None else _scatter(buf, cur_s,
                                                     jnp.asarray(idx))
            break
        nb = pow2_at_least(live)
        if nb <= bb // 2:
            # retire: flush ALL current lanes to the result buffer (the
            # survivor writes are dead — overwritten by a later flush —
            # but a full-lane scatter keeps the index vector at the fixed
            # bucket length, so the program set stays one-per-(shape, B);
            # scattering only the converged lanes would retrace per
            # data-dependent lane count), then gather survivors (padded
            # with one converged lane, which is inert — its predicate is
            # already false) into the next bucket.
            buf = cur_s if buf is None else _scatter(buf, cur_s,
                                                     jnp.asarray(idx))
            surv = np.flatnonzero(~conv)
            fill = np.flatnonzero(conv)[:1]
            sel = np.concatenate([surv, np.repeat(fill, nb - live)])
            sel_j = jnp.asarray(sel)
            cur_d = _gather(cur_d, sel_j)
            cur_s = _gather(cur_s, sel_j)
            idx = idx[sel]
            ph_prev = ph[sel]
    else:
        # phase caps bound every lane, so the loop always breaks; flush
        # defensively if a cap change ever violates that.
        buf = cur_s if buf is None else _scatter(buf, cur_s,
                                                 jnp.asarray(idx))
    return buf


# --------------------------------------------------------------------------
# One jitted function family per (spec, k) — shared with the collapsed
# single-device tail of the distributed driver.
# --------------------------------------------------------------------------

@lru_cache(maxsize=None)
def spec_fns(spec, k: int):
    """(prologue, init, chunk, conv, epilogue): the spec's per-instance
    stepped-core functions vmapped over the batch and jitted. The chunk
    dispatch donates the state buffers (one copy of solver state on
    device, not two). ``conv`` returns ``(mask, phases)`` in one program
    so the driver's per-chunk device->host sync fetches both in a single
    blocking transfer (the hot-loop sync audit in repro.analysis holds
    the loop to exactly that one fetch)."""
    prologue = jax.jit(lambda ops: jax.vmap(spec.prologue)(ops))
    init = jax.jit(lambda data, ctx: jax.vmap(spec.init_state)(data, ctx))
    chunk = jax.jit(
        lambda data, state: jax.vmap(
            lambda d, s: spec.run_phases(d, s, k))(data, state),
        donate_argnums=(1,),
    )
    conv = jax.jit(
        lambda data, state: (jax.vmap(spec.converged)(data, state),
                             state.phases))
    epilogue = jax.jit(
        lambda ctx, state: jax.vmap(spec.epilogue)(ctx, state))
    return prologue, init, chunk, conv, epilogue


def max_chunk_dispatches(phase_cap: np.ndarray, k: int) -> int:
    """Upper bound on k-phase dispatches (phase caps bound every lane)."""
    return -(-int(phase_cap.max(initial=1)) // max(k, 1)) + 2


def solve_compacting(
    spec,
    inputs,
    eps,
    *,
    sizes=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    keep_state: bool = False,
    deadline: Optional[float] = None,
    obs=None,
    **prep_kw,
):
    """The generic compacting driver: solve a (B, M, N) batch of ``spec``
    instances with convergence compaction.

    Args:
      spec: a ProblemSpec (``ASSIGNMENT`` or ``OT`` from core/problem.py).
      inputs: dict of batched operands (``{"c": ...}`` for assignment,
        ``{"c": ..., "nu": ..., "mu": ...}`` for OT).
      eps: scalar, or (B,) per-instance array (mixed-accuracy batch — the
        lockstep path cannot express this).
      k: phases per dispatch; any value yields identical results.
      keep_state: stash the final pre-completion integer state on the
        returned stats (``final_state``) for feasibility certificates;
        off by default so serving paths don't retain an extra state copy.
      deadline: absolute monotonic-clock (``repro.obs.now``) budget; the
        chunk loop stops dispatching when the next chunk would overrun it
        and returns best-so-far answers (``stats.deadline_hit`` /
        ``unconverged``).
      obs: optional event emitter (``repro.obs.Tracer``): per-chunk
        ``"chunk"`` events (bucket, live, wall time, phase delta,
        jit-cache delta) and ``"deadline-cut"`` — see :func:`_drive`.
      prep_kw: spec-specific prep options (OT: ``theta``).

    Returns ``(result, CompactionStats)``; every result leaf is
    bit-identical per instance to the lockstep path (and to the unbatched
    solver) for a shared scalar eps.
    """
    inputs = spec.canonicalize(inputs)
    b, m, n = spec.batch_shape(inputs)
    if b == 0:
        return (spec.empty_result(m, n),
                CompactionStats(batch=0, dispatched_batch=0, chunk=k))
    # Pad the batch to a power of two with born-converged empty instances,
    # so the descent B -> B/2 -> ... visits only power-of-two shapes.
    p = spec.prepare(inputs, eps, sizes=sizes, guaranteed=guaranteed,
                     **prep_kw)
    if _audit_debug_checks():
        # Sanitizer mode: checkify-instrumented (nan/index/div + solver
        # invariants) variants of the dispatched programs. Slower (no
        # donation, per-chunk error sync) — never on by default.
        from ..analysis.checkified import checkified_spec_fns
        prologue, init, chunk, conv, epilogue = checkified_spec_fns(spec, k)
    else:
        prologue, init, chunk, conv, epilogue = spec_fns(spec, k)
    ops = {kk: jnp.asarray(v) for kk, v in p.ops.items()}
    data, ctx = prologue(ops)
    # epilogue operands the prologue does not transform are taken straight
    # from ops (outside the jit), not round-tripped through it — a
    # pass-through output would materialize a second device copy of the
    # (bp, M, N) operands
    ctx = {**ctx, **{kk: ops[kk] for kk in spec.ctx_ops}}
    state0 = init(data, ctx)
    stats = CompactionStats(batch=b, dispatched_batch=p.bp, chunk=k)
    final = _drive(data, state0, chunk, conv,
                   max_chunk_dispatches(p.phase_cap, k), stats,
                   deadline=deadline, obs=obs)
    r = epilogue(ctx, final)

    phases = np.asarray(final.phases[:b], np.int64)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    if keep_state:
        stats.final_state = jax.tree_util.tree_map(lambda a: a[:b], final)
    return spec.trim(r, b), stats


# --------------------------------------------------------------------------
# Spec-binding wrappers (original public entry points, unchanged contracts)
# --------------------------------------------------------------------------

def solve_assignment_batched_compacting(
    c: jnp.ndarray,
    eps,
    *,
    sizes=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    keep_state: bool = False,
):
    """Compacting counterpart of ``solve_assignment_batched``; binds
    ``ASSIGNMENT`` to :func:`solve_compacting` (see there for the
    contract). Returns ``(BatchedAssignmentResult, CompactionStats)``."""
    return solve_compacting(ASSIGNMENT, {"c": c}, eps, sizes=sizes, k=k,
                            guaranteed=guaranteed, keep_state=keep_state)


def solve_ot_batched_compacting(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps,
    *,
    sizes=None,
    theta=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    keep_state: bool = False,
):
    """Compacting counterpart of ``solve_ot_batched``; binds ``OT`` to
    :func:`solve_compacting`. Same contract as the lockstep path
    ((B, M, N) costs, (B, M)/(B, N) masses, padding zeroed from
    ``sizes``), plus per-instance ``eps`` support. Returns
    ``(OTResult with leading batch axes, CompactionStats)``."""
    return solve_compacting(OT, {"c": c, "nu": nu, "mu": mu}, eps,
                            sizes=sizes, k=k, guaranteed=guaranteed,
                            keep_state=keep_state, theta=theta)


# --------------------------------------------------------------------------
# repro.analysis registration: the vmapped chunk/conv dispatches are the
# programs the compacting loop actually re-issues per bucket, so they are
# what the donation-safety and dtype-drift rules must see.
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402
from ..analysis import debug_checks_enabled as _audit_debug_checks  # noqa: E402


def _tiny_batch(spec_name: str):
    """A deterministic (2, 4, 4) prepared batch for tracing dispatches."""
    spec = ASSIGNMENT if spec_name == "assignment" else OT
    b, mn = 2, 4
    c = np.linspace(0.0, 1.0, b * mn * mn, dtype=np.float32)
    inputs = {"c": c.reshape(b, mn, mn)}
    if spec_name == "ot":
        inputs["nu"] = np.full((b, mn), 1.0 / mn, np.float32)
        inputs["mu"] = np.full((b, mn), 1.0 / mn, np.float32)
    p = spec.prepare(spec.canonicalize(inputs), 0.25)
    prologue, init, chunk, conv, _ = spec_fns(spec, 2)
    ops = {kk: jnp.asarray(v) for kk, v in p.ops.items()}
    data, ctx = prologue(ops)
    state = init(data, ctx)
    return chunk, conv, data, state


def _trace_chunk(spec_name: str):
    chunk, _, data, state = _tiny_batch(spec_name)
    return _audit.trace_entry(
        name=f"core.compaction.chunk[{spec_name}]",
        fn=chunk,
        args={"data": data, "state": state},
        donated={"state"},
        tags={"chunk-dispatch", spec_name},
        source=__name__,
    )


def _trace_conv(spec_name: str):
    _, conv, data, state = _tiny_batch(spec_name)
    return _audit.trace_entry(
        name=f"core.compaction.conv[{spec_name}]",
        fn=conv,
        args={"data": data, "state": state},
        tags={"conv-dispatch", spec_name},
        source=__name__,
    )


_audit.register("core.compaction.chunk[assignment]",
                lambda: _trace_chunk("assignment"), source=__name__)
_audit.register("core.compaction.chunk[ot]",
                lambda: _trace_chunk("ot"), source=__name__)
_audit.register("core.compaction.conv[assignment]",
                lambda: _trace_conv("assignment"), source=__name__)
_audit.register("core.compaction.conv[ot]",
                lambda: _trace_conv("ot"), source=__name__)
