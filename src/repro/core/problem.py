"""ProblemSpec: the stepped-core contract shared by assignment and OT.

The paper presents two push-relabel solvers — Algorithm 1 (assignment,
O(n^2/eps)) and Algorithm 2 (general OT, O(n^2/eps^2)) — that share one
skeleton: scale/round the instance to integers, run phases until the free
supply drops below a termination threshold, then complete/price the
result. Every batch driver in this repo (lockstep vmap, convergence
compaction, mesh-distributed dispatch) iterates that same skeleton; this
module captures it once as a protocol so each driver is written ONCE and
bound to a problem by a spec object, instead of maintaining parallel
``_assign_*`` / ``_ot_*`` function families per driver.

Protocol methods, mapped to the paper's algorithm steps:

  ``prepare``       host-side batch prep: padding masks, per-instance
                    eps/theta, the host-float64 termination thresholds
                    (``int(eps * m)`` for Algorithm 1; ``int(eps *
                    sum(s_int))`` for Algorithm 2) and phase-cap safety
                    bounds (Lemma 3.3 / Lemma 4.2 analogues), plus
                    power-of-two batch padding with born-converged empty
                    instances.
  ``prologue``      Algorithm 1/2 step 0 — scaling and rounding: float
                    costs (and masses, for OT) to the integer instance
                    the phases operate on. Returns ``(data, ctx)``:
                    ``data`` feeds the phase loop, ``ctx`` is kept intact
                    for the epilogue.
  ``init_state``    the paper's initialization: all supply free,
                    y(b) = eps (one unit), y(a) = 0, zero flow.
  ``run_phases``    at most k phases of the main loop (each phase: one
                    deterministic propose/push-relabel sweep over the
                    admissible graph). Resumable: chaining calls is
                    bit-identical to the one-shot solve for any k.
  ``converged``     the loop guard — free supply <= threshold, or the
                    phase cap (safety bound) hit.
  ``epilogue``      completion + pricing: arbitrarily match the <= eps*m
                    leftover free supply (Algorithm 1) / emit the
                    rounded transport plan (Algorithm 2), price against
                    the float costs, scale duals back.

``prologue`` through ``epilogue`` are pure per-instance jax functions
over pytrees; drivers vmap/jit/shard_map them (see ``core/compaction``
and ``core/distributed``), so one spec serves every dispatch strategy.
The remaining methods are host-side glue: ragged-instance handling for
the ``core/api.solve`` front door, the lockstep fixed-shape path, and
the per-instance row/col matrix-sharded path of ``core/sharded``.

Two singleton specs are exported: ``ASSIGNMENT`` and ``OT``. They are
stateless; identity-hashing makes them usable as jit-cache keys.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .pushrelabel import (
    _max_phases,
    assignment_converged,
    assignment_epilogue,
    assignment_prologue,
    init_assignment_state,
    run_assignment_phases,
)
from .transport import (
    OTResult,
    OTState,
    init_ot_state,
    ot_converged,
    ot_epilogue,
    ot_phase_cap,
    ot_prologue,
    run_ot_phases,
)


def pow2_at_least(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    p = 1
    while p < x:
        p *= 2
    return p


def eps_array(eps, b: int, guaranteed: bool) -> np.ndarray:
    """(b,) host-float64 per-instance eps (the /3 of the guaranteed bound
    applied); shared by every driver so the scaling can never diverge."""
    arr = np.broadcast_to(np.asarray(eps, np.float64), (b,)).copy()
    if guaranteed:
        arr = arr / 3.0
    if (arr <= 0).any():
        raise ValueError("eps must be positive")
    return arr


class PreparedBatch(NamedTuple):
    """Host-side output of ``ProblemSpec.prepare``: device operands plus
    the host copies of the per-lane thresholds/caps the drivers schedule
    with. ``ops`` arrays all have the (bp,) dispatched batch leading."""
    ops: Dict[str, Any]        # operands for the (vmapped) prologue
    threshold: np.ndarray      # (bp,) int32 host-float64-derived
    phase_cap: np.ndarray      # (bp,) int32 safety bound per lane
    eps_arr: np.ndarray        # (bp,) float64 per-lane eps
    bp: int                    # dispatched batch (power of two)


class ProblemSpec(Protocol):
    """Stepped-core contract; see the module docstring for the mapping to
    the paper's Algorithm 1/2. Implementations must be stateless."""
    name: str

    # -- host-side batch prep ------------------------------------------
    def canonicalize(self, inputs: Dict[str, Any]) -> Dict[str, Any]: ...
    def batch_shape(self, inputs: Dict[str, Any]) -> Tuple[int, int, int]: ...
    def prepare(self, inputs, eps, *, sizes=None, guaranteed: bool = False,
                min_batch: int = 1, **kw) -> PreparedBatch: ...

    # names of ``ops`` entries the epilogue consumes VERBATIM: the drivers
    # merge them into ``ctx`` outside the jit boundary instead of routing
    # them through the prologue as pass-through outputs (which would
    # materialize a second device copy of the big operands)
    ctx_ops: Tuple[str, ...]

    # -- per-instance jax functions (drivers vmap/jit/shard_map these) --
    def prologue(self, ops: Dict[str, Any]): ...
    def init_state(self, data: Dict[str, Any], ctx: Dict[str, Any]): ...
    def run_phases(self, data: Dict[str, Any], state, k: int): ...
    def converged(self, data: Dict[str, Any], state): ...
    def epilogue(self, ctx: Dict[str, Any], state): ...

    # -- result shaping ------------------------------------------------
    def empty_result(self, m: int, n: int): ...
    def trim(self, r, b: int): ...

    # -- ragged front door / lockstep / matrix placement ---------------
    def instance_shape(self, inst) -> Tuple[int, int]: ...
    def pad_group(self, insts, key) -> Dict[str, Any]: ...
    def solve_lockstep(self, inputs, eps: float, *, sizes=None,
                       guaranteed: bool = False,
                       keep_state: bool = False, **kw): ...
    def matrix_instance(self, host, i, mi, ni, mp, np_, eps_i, mesh2,
                        row_axis, col_axis, **kw): ...
    def matrix_stack(self, rows, m_valid, n_valid, m: int, n: int): ...

    # -- per-artifact producers (the Solution surface) ------------------
    # The host-side epilogue is split per artifact so un-requested
    # artifacts (above all the dense (B, M, N) plan and the raw integer
    # state) are never materialized on host: ``artifact_device`` hands the
    # DEVICE arrays for one artifact to core/solution.py, which fetches
    # them lazily and at most once.
    artifacts: Tuple[str, ...]
    # whether the spec's RESULT already carries the pre-completion state
    # (OT does; assignment needs the dispatch to retain it explicitly)
    state_on_result: bool

    def artifact_device(self, name: str, r, state) -> Dict[str, Any]: ...
    def artifact_plan_dense(self, host: Dict[str, np.ndarray], batch: int,
                            shape: Tuple[int, int]) -> np.ndarray: ...
    def artifact_plan_sparse(self, r, fetch, batch: int,
                             shape: Tuple[int, int]): ...
    def artifact_state(self, r, state): ...
    def legacy_instance_dict(self, sol) -> Dict[str, Any]: ...


def _sizes_arrays(sizes, b, m, n):
    """Host-side (B,) m_valid / n_valid arrays (full shape when sizes=None)."""
    if sizes is None:
        return (np.full((b,), m, np.int32), np.full((b,), n, np.int32))
    sizes = np.asarray(sizes, np.int32)
    if sizes.shape != (b, 2):
        raise ValueError(f"sizes must be ({b}, 2), got {sizes.shape}")
    if (sizes[:, 0] > m).any() or (sizes[:, 1] > n).any():
        raise ValueError("instance size exceeds padded bucket shape")
    return sizes[:, 0].copy(), sizes[:, 1].copy()


def _theta_array(sizes_m, sizes_n, eps, theta) -> np.ndarray:
    """Per-instance theta = 4*max(m, n)/eps, computed on host in float64 and
    cast to f32 so it is bit-identical to the unbatched solve_ot default.
    ``eps`` may be a scalar or a (B,) array (compacting driver)."""
    if theta is not None:
        return np.broadcast_to(
            np.asarray(theta, np.float32), sizes_m.shape
        ).copy()
    eps = np.asarray(eps, np.float64)
    return (4.0 * np.maximum(sizes_m, sizes_n) / eps).astype(np.float32)


def _mask_ot_inputs(c, nu, mu, m_valid, n_valid, theta, eps):
    """Zero mass/cost outside each instance's block and compute the
    per-instance termination thresholds in host float64 from the masked
    masses — identical to the unbatched solve_ot (the on-device f32
    product rounds the wrong way for some (eps, total_mass) pairs).
    Shared by the lockstep and compacting paths so the two can never
    diverge on threshold/masking semantics. ``eps`` scalar or (B,)."""
    b, m, n = c.shape
    row_ok = np.arange(m)[None, :] < m_valid[:, None]
    col_ok = np.arange(n)[None, :] < n_valid[:, None]
    eps_b = np.broadcast_to(np.asarray(eps, np.float64), (b,))
    nu_h = np.where(row_ok, np.asarray(nu, np.float32), np.float32(0.0))
    # vectorized ot_termination_threshold: f32 floor(nu * theta) per entry
    # (the device rounding), f64 row sums, f64 eps product, truncation
    s_rows = np.floor(nu_h * np.asarray(theta, np.float32)[:, None])
    thr = (eps_b * s_rows.sum(axis=1, dtype=np.float64)).astype(np.int64) \
        .astype(np.int32)
    mask = jnp.asarray(row_ok[:, :, None] & col_ok[:, None, :])
    c = jnp.where(mask, c, 0.0)
    nu = jnp.where(jnp.asarray(row_ok), nu, 0.0)
    mu = jnp.where(jnp.asarray(col_ok), mu, 0.0)
    return c, nu, mu, thr


def _pad_lanes(bp: int, b: int, arrays: Dict[str, Any],
               fills: Dict[str, Any] | None = None) -> Dict[str, Any]:
    """Pad every (b, ...) array in ``arrays`` up to ``bp`` lanes with
    zeros (born-converged empty instances: zero valid rows / zero mass ->
    free supply 0 <= threshold 0). ``fills`` overrides the pad value per
    key — eps/theta lanes must stay nonzero so the prologue's divisions
    remain finite (the lanes are born converged regardless)."""
    if bp == b:
        return arrays
    out = {}
    for k, a in arrays.items():
        fill = (fills or {}).get(k, 0)
        if isinstance(a, np.ndarray):
            pad = np.full((bp - b,) + a.shape[1:], fill, a.dtype)
            out[k] = np.concatenate([a, pad])
        else:
            pad = jnp.full((bp - b,) + a.shape[1:], fill, a.dtype)
            out[k] = jnp.concatenate([a, pad])
    return out


# --------------------------------------------------------------------------
# Assignment (paper Algorithm 1)
# --------------------------------------------------------------------------

class AssignmentSpec:
    """ProblemSpec instance for the assignment solver (Algorithm 1),
    built from the stepped core in ``core/pushrelabel``."""

    name = "assignment"

    # -- host-side batch prep ------------------------------------------

    def canonicalize(self, inputs):
        c = jnp.asarray(inputs["c"], jnp.float32)
        if c.ndim != 3:
            raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
        return {"c": c}

    def batch_shape(self, inputs):
        return inputs["c"].shape

    def prepare(self, inputs, eps, *, sizes=None, guaranteed: bool = False,
                min_batch: int = 1) -> PreparedBatch:
        """Masking/threshold/padding half of a batched assignment solve.

        Pads the batch to ``max(pow2_at_least(B), min_batch)`` with
        born-converged empty instances (the distributed driver passes
        ``min_batch = device count`` so the batch axis starts divisible
        by the mesh). Thresholds are host float64, identical to the
        unbatched ``int(eps * m)``."""
        c = inputs["c"]
        b, m, n = c.shape
        m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
        eps_arr = eps_array(eps, b, guaranteed)
        threshold = np.asarray(
            [int(e * int(mi)) for e, mi in zip(eps_arr, m_valid)], np.int32
        )
        phase_cap = np.asarray([_max_phases(float(e), m) for e in eps_arr],
                               np.int32)
        bp = max(pow2_at_least(b), pow2_at_least(min_batch))
        ops = _pad_lanes(bp, b, {
            "c": c,
            "eps": eps_arr.astype(np.float32),
            "m_valid": m_valid,
            "n_valid": n_valid,
            "threshold": threshold,
            "phase_cap": phase_cap,
        }, fills={"eps": np.float32(eps_arr[0])})
        if bp > b:
            eps_arr = np.concatenate(
                [eps_arr, np.full((bp - b,), eps_arr[0])])
        return PreparedBatch(ops=ops, threshold=np.asarray(ops["threshold"]),
                             phase_cap=np.asarray(ops["phase_cap"]),
                             eps_arr=eps_arr, bp=bp)

    # -- per-instance jax functions ------------------------------------

    ctx_ops = ("eps",)

    def prologue(self, ops):
        cm, c_int, scale, row_ok, col_ok = assignment_prologue(
            ops["c"], ops["eps"], ops["m_valid"], ops["n_valid"])
        data = {"c_int": c_int, "threshold": ops["threshold"],
                "phase_cap": ops["phase_cap"], "m_valid": ops["m_valid"]}
        ctx = {"cm": cm, "scale": scale, "row_ok": row_ok, "col_ok": col_ok}
        return data, ctx

    def init_state(self, data, ctx):
        m, n = data["c_int"].shape
        return init_assignment_state(m, n)

    def run_phases(self, data, state, k: int):
        return run_assignment_phases(
            data["c_int"], state, data["threshold"], data["phase_cap"], k,
            m_valid=data["m_valid"])

    def converged(self, data, state):
        return assignment_converged(state, data["threshold"],
                                    data["phase_cap"],
                                    m_valid=data["m_valid"])

    def epilogue(self, ctx, state):
        return assignment_epilogue(ctx["cm"], ctx["scale"], state,
                                   ctx["eps"], ctx["row_ok"], ctx["col_ok"])

    # -- result shaping ------------------------------------------------

    def empty_result(self, m: int, n: int):
        from .batched import BatchedAssignmentResult

        z = lambda *s: jnp.zeros(s, jnp.float32)
        return BatchedAssignmentResult(
            matching=jnp.zeros((0, m), jnp.int32), cost=z(0),
            y_b=z(0, m), y_a=z(0, n),
            phases=jnp.zeros((0,), jnp.int32),
            rounds=jnp.zeros((0,), jnp.int32),
            matched_before_completion=jnp.zeros((0,), jnp.int32),
        )

    def trim(self, r, b: int):
        from .batched import BatchedAssignmentResult

        return BatchedAssignmentResult(
            matching=r.matching[:b],
            cost=r.cost[:b],
            y_b=r.y_b[:b],
            y_a=r.y_a[:b],
            phases=r.phases[:b],
            rounds=r.rounds[:b],
            matched_before_completion=r.matched_before_completion[:b],
        )

    # -- ragged front door / lockstep ----------------------------------

    def instance_shape(self, inst):
        return tuple(np.asarray(inst).shape)

    def pad_group(self, insts, key):
        from .batched import pad_stack

        return {"c": pad_stack(list(insts), key)}

    def solve_lockstep(self, inputs, eps: float, *, sizes=None,
                       guaranteed: bool = False, keep_state: bool = False):
        from .batched import solve_assignment_batched

        if keep_state:
            return solve_assignment_batched(
                inputs["c"], eps, sizes=sizes, guaranteed=guaranteed,
                keep_state=True)
        return solve_assignment_batched(inputs["c"], eps, sizes=sizes,
                                        guaranteed=guaranteed), None

    # -- per-artifact producers ----------------------------------------
    # Algorithm 1's deliverables, one producer each: the primal matching
    # (and its unit transport-plan view), the scaled approximate duals,
    # the objective, and the raw integer pre-completion state.

    artifacts = ("cost", "duals", "matching", "plan", "plan_sparse",
                 "state", "stats")
    state_on_result = False

    def artifact_device(self, name, r, state):
        if name == "cost":
            return {"cost": r.cost}
        if name == "scalars":
            return {"phases": r.phases, "rounds": r.rounds}
        if name == "duals":
            return {"y_b": r.y_b, "y_a": r.y_a}
        if name in ("matching", "plan"):
            # the dense plan is DERIVED from the compact matching on host;
            # only the (B, M) matching ever crosses device->host
            return {"matching": r.matching}
        raise KeyError(name)

    def artifact_plan_dense(self, host, batch, shape):
        m, n = shape
        matching = host["matching"][:batch]
        out = np.zeros((batch, m, n), np.float32)
        b_idx, r_idx = np.nonzero(matching >= 0)
        out[b_idx, r_idx, matching[b_idx, r_idx]] = 1.0
        return out

    def artifact_plan_sparse(self, r, fetch, batch, shape):
        from .solution import SparsePlanBatch

        m, n = shape
        matching = fetch("matching")["matching"][:batch].astype(np.int64)
        valid = matching >= 0
        nnz = valid.sum(axis=1).astype(np.int32)
        k = min(pow2_at_least(int(nnz.max(initial=1))), max(m * n, 1))
        idx = np.full((batch, k), m * n, np.int32)
        vals = np.zeros((batch, k), np.float32)
        for j in range(batch):
            rows = np.flatnonzero(valid[j])
            idx[j, :rows.size] = rows * n + matching[j, rows]
            vals[j, :rows.size] = 1.0
        return SparsePlanBatch(idx=idx, vals=vals, nnz=nnz,
                               shape=(int(m), int(n)))

    def artifact_state(self, r, state):
        # BatchedAssignmentResult carries no state: it exists only when
        # the dispatch retained it (keep_state / want=("state",))
        return state

    def legacy_instance_dict(self, sol):
        y_b, y_a = sol.duals()
        return {
            "matching": sol.matching(),
            "cost": sol.cost,
            "phases": sol.phases,
            "rounds": sol.rounds,
            "y_b": y_b,
            "y_a": y_a,
        }

    # -- matrix placement (row/col sharding per large instance) --------

    def matrix_instance(self, host, i, mi, ni, mp, np_, eps_i, mesh2,
                        row_axis, col_axis):
        from .sharded import solve_assignment_sharded

        # pad up to mesh-divisible dims (sharded dims must divide the
        # mesh); the PAD_COST/masked-completion machinery makes the
        # padded solve equal the unpadded one
        ci = np.zeros((mp, np_), np.float32)
        ci[:mi, :ni] = host["c"][i, :mi, :ni]
        return solve_assignment_sharded(
            ci, eps_i, mesh2, row_axis=row_axis, col_axis=col_axis,
            m_valid=mi, n_valid=ni,
        )

    def matrix_stack(self, rows, m_valid, n_valid, m: int, n: int):
        from .batched import BatchedAssignmentResult

        b = len(rows)
        matching = np.full((b, m), -1, np.int32)
        cost = np.zeros((b,), np.float32)
        y_b = np.zeros((b, m), np.float32)
        y_a = np.zeros((b, n), np.float32)
        phases = np.zeros((b,), np.int32)
        rounds = np.zeros((b,), np.int32)
        mbc = np.zeros((b,), np.int32)
        for i, r in enumerate(rows):
            mi, ni = int(m_valid[i]), int(n_valid[i])
            matching[i, :mi] = np.asarray(r.matching)[:mi]
            cost[i] = float(r.cost)
            y_b[i, :mi] = np.asarray(r.y_b)[:mi]
            y_a[i, :ni] = np.asarray(r.y_a)[:ni]
            phases[i] = int(r.phases)
            rounds[i] = int(r.rounds)
            mbc[i] = int(r.matched_before_completion)
        return BatchedAssignmentResult(
            matching=jnp.asarray(matching), cost=jnp.asarray(cost),
            y_b=jnp.asarray(y_b), y_a=jnp.asarray(y_a),
            phases=jnp.asarray(phases), rounds=jnp.asarray(rounds),
            matched_before_completion=jnp.asarray(mbc),
        )


# --------------------------------------------------------------------------
# General OT (paper Algorithm 2)
# --------------------------------------------------------------------------

class OTSpec:
    """ProblemSpec instance for the general OT solver (Algorithm 2),
    built from the stepped core in ``core/transport``."""

    name = "ot"

    # -- host-side batch prep ------------------------------------------

    def canonicalize(self, inputs):
        c = jnp.asarray(inputs["c"], jnp.float32)
        if c.ndim != 3:
            raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
        return {"c": c,
                "nu": jnp.asarray(inputs["nu"], jnp.float32),
                "mu": jnp.asarray(inputs["mu"], jnp.float32)}

    def batch_shape(self, inputs):
        return inputs["c"].shape

    def prepare(self, inputs, eps, *, sizes=None, guaranteed: bool = False,
                min_batch: int = 1, theta=None) -> PreparedBatch:
        """OT counterpart of ``AssignmentSpec.prepare``: shares the
        padding-mask + host-float64 threshold code with the lockstep path
        (``_mask_ot_inputs``) so the code paths can never diverge. Batch
        padding is born-converged (zero mass -> free supply 0 <=
        threshold 0)."""
        c, nu, mu = inputs["c"], inputs["nu"], inputs["mu"]
        b, m, n = c.shape
        m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
        eps_arr = eps_array(eps, b, guaranteed)
        th = _theta_array(m_valid, n_valid, eps_arr, theta)
        phase_cap = np.asarray([ot_phase_cap(float(e)) for e in eps_arr],
                               np.int32)
        c, nu, mu, threshold = _mask_ot_inputs(c, nu, mu, m_valid, n_valid,
                                               th, eps_arr)
        bp = max(pow2_at_least(b), pow2_at_least(min_batch))
        ops = _pad_lanes(bp, b, {
            "c": c, "nu": nu, "mu": mu,
            "eps": eps_arr.astype(np.float32),
            "theta": th,
            "threshold": threshold,
            "phase_cap": phase_cap,
        }, fills={"eps": np.float32(eps_arr[0]), "theta": np.float32(1.0)})
        if bp > b:
            eps_arr = np.concatenate(
                [eps_arr, np.full((bp - b,), eps_arr[0])])
        return PreparedBatch(ops=ops, threshold=np.asarray(ops["threshold"]),
                             phase_cap=np.asarray(ops["phase_cap"]),
                             eps_arr=eps_arr, bp=bp)

    # -- per-instance jax functions ------------------------------------

    ctx_ops = ("c", "nu", "mu", "theta", "eps")

    def prologue(self, ops):
        c_int, s_int, d_int, scale = ot_prologue(
            ops["c"], ops["nu"], ops["mu"], ops["theta"], ops["eps"])
        data = {"c_int": c_int, "threshold": ops["threshold"],
                "phase_cap": ops["phase_cap"]}
        ctx = {"scale": scale, "s_int": s_int, "d_int": d_int}
        return data, ctx

    def init_state(self, data, ctx):
        return init_ot_state(ctx["s_int"], ctx["d_int"])

    def run_phases(self, data, state, k: int):
        m, n = data["c_int"].shape
        return run_ot_phases(data["c_int"], state, data["threshold"],
                             data["phase_cap"], k, int(m + n + 2))

    def converged(self, data, state):
        return ot_converged(state, data["threshold"], data["phase_cap"])

    def epilogue(self, ctx, state):
        return ot_epilogue(ctx["c"], ctx["nu"], ctx["mu"], ctx["theta"],
                           ctx["eps"], ctx["scale"], ctx["s_int"],
                           ctx["d_int"], state)

    # -- result shaping ------------------------------------------------

    def empty_result(self, m: int, n: int):
        zf = lambda *s: jnp.zeros(s, jnp.float32)
        zi = lambda *s: jnp.zeros(s, jnp.int32)
        return OTResult(
            plan=zf(0, m, n), cost=zf(0), y_b=zf(0, m), y_a=zf(0, n),
            phases=zi(0), rounds=zi(0),
            state=OTState(y_b=zi(0, m), ya_hi=zi(0, n), free_b=zi(0, m),
                          free_a=zi(0, n), f_hi=zi(0, m, n),
                          f_lo=zi(0, m, n), phases=zi(0), rounds=zi(0)),
            theta=zf(0), s_int=zi(0, m), d_int=zi(0, n),
        )

    def trim(self, r, b: int):
        return jax.tree_util.tree_map(lambda a: a[:b], r)

    # -- ragged front door / lockstep ----------------------------------

    def instance_shape(self, inst):
        return tuple(np.asarray(inst[0]).shape)

    def pad_group(self, insts, key):
        from .batched import pad_stack

        mb, nb = key
        return {"c": pad_stack([c for c, _, _ in insts], (mb, nb)),
                "nu": pad_stack([nu for _, nu, _ in insts], (mb,)),
                "mu": pad_stack([mu for _, _, mu in insts], (nb,))}

    def solve_lockstep(self, inputs, eps: float, *, sizes=None,
                       guaranteed: bool = False, keep_state: bool = False,
                       theta=None):
        from .batched import solve_ot_batched

        r = solve_ot_batched(inputs["c"], inputs["nu"], inputs["mu"],
                             eps, sizes=sizes, theta=theta,
                             guaranteed=guaranteed)
        # the OT result already carries its pre-completion state
        return (r, r.state) if keep_state else (r, None)

    # -- per-artifact producers ----------------------------------------
    # Algorithm 2's deliverables, one producer each: the primal plan
    # (dense on demand, compact COO by default), the scaled approximate
    # duals of the clustered copies, the objective, and the raw integer
    # state for the Lemma 4.1 certificates.

    artifacts = ("cost", "duals", "plan", "plan_sparse", "state", "stats")
    state_on_result = True

    def artifact_device(self, name, r, state):
        if name == "cost":
            return {"cost": r.cost}
        if name == "scalars":
            return {"phases": r.phases, "rounds": r.rounds,
                    "theta": r.theta}
        if name == "duals":
            return {"y_b": r.y_b, "y_a": r.y_a}
        if name == "plan":
            return {"plan": r.plan}
        raise KeyError(name)

    def artifact_plan_dense(self, host, batch, shape):
        return host["plan"][:batch]

    def artifact_plan_sparse(self, r, fetch, batch, shape):
        from .solution import sparse_from_dense_device

        # compacted ON DEVICE: only the COO triplets cross to host
        return sparse_from_dense_device(r.plan, batch)

    def artifact_state(self, r, state):
        return state if state is not None else r.state

    def legacy_instance_dict(self, sol):
        return {
            "plan": sol.plan(),
            "cost": sol.cost,
            "phases": sol.phases,
            "rounds": sol.rounds,
            "theta": sol.theta,
        }

    # -- matrix placement ----------------------------------------------

    def matrix_instance(self, host, i, mi, ni, mp, np_, eps_i, mesh2,
                        row_axis, col_axis, theta=None):
        from .sharded import solve_ot_sharded

        # pad to mesh-divisible dims with zero mass/cost (inert lanes:
        # zero supply never proposes, zero demand grants nothing); theta
        # comes from the TRUE size so the trajectory equals the unpadded
        # solve's (host float64 -> f32, as _theta_array)
        ci = np.zeros((mp, np_), np.float32)
        ci[:mi, :ni] = host["c"][i, :mi, :ni]
        nui = np.zeros((mp,), np.float32)
        nui[:mi] = host["nu"][i, :mi]
        mui = np.zeros((np_,), np.float32)
        mui[:ni] = host["mu"][i, :ni]
        if theta is None:
            th_i = float(np.float32(4.0 * max(mi, ni) / np.float64(eps_i)))
        else:
            b = host["c"].shape[0]
            th_i = float(np.broadcast_to(
                np.asarray(theta, np.float32), (b,))[i])
        return solve_ot_sharded(
            ci, nui, mui, eps_i, mesh2, row_axis=row_axis,
            col_axis=col_axis, theta=th_i,
        )

    def matrix_stack(self, rows, m_valid, n_valid, m: int, n: int):
        b = len(rows)
        plan = np.zeros((b, m, n), np.float32)
        cost = np.zeros((b,), np.float32)
        y_b = np.zeros((b, m), np.float32)
        y_a = np.zeros((b, n), np.float32)
        phases = np.zeros((b,), np.int32)
        rounds = np.zeros((b,), np.int32)
        thetas = np.zeros((b,), np.float32)
        s_int = np.zeros((b, m), np.int32)
        d_int = np.zeros((b, n), np.int32)
        st = {
            "y_b": np.zeros((b, m), np.int32),
            "ya_hi": np.zeros((b, n), np.int32),
            "free_b": np.zeros((b, m), np.int32),
            "free_a": np.zeros((b, n), np.int32),
            "f_hi": np.zeros((b, m, n), np.int32),
            "f_lo": np.zeros((b, m, n), np.int32),
            "phases": np.zeros((b,), np.int32),
            "rounds": np.zeros((b,), np.int32),
        }
        for i, r in enumerate(rows):
            mi, ni = int(m_valid[i]), int(n_valid[i])
            plan[i, :mi, :ni] = np.asarray(r.plan)[:mi, :ni]
            cost[i] = float(r.cost)
            y_b[i, :mi] = np.asarray(r.y_b)[:mi]
            y_a[i, :ni] = np.asarray(r.y_a)[:ni]
            phases[i] = int(r.phases)
            rounds[i] = int(r.rounds)
            thetas[i] = float(r.theta)
            s_int[i, :mi] = np.asarray(r.s_int)[:mi]
            d_int[i, :ni] = np.asarray(r.d_int)[:ni]
            st["y_b"][i, :mi] = np.asarray(r.state.y_b)[:mi]
            st["ya_hi"][i, :ni] = np.asarray(r.state.ya_hi)[:ni]
            st["free_b"][i, :mi] = np.asarray(r.state.free_b)[:mi]
            st["free_a"][i, :ni] = np.asarray(r.state.free_a)[:ni]
            st["f_hi"][i, :mi, :ni] = np.asarray(r.state.f_hi)[:mi, :ni]
            st["f_lo"][i, :mi, :ni] = np.asarray(r.state.f_lo)[:mi, :ni]
            st["phases"][i] = int(r.state.phases)
            st["rounds"][i] = int(r.state.rounds)
        state = OTState(**{k: jnp.asarray(v) for k, v in st.items()})
        return OTResult(
            plan=jnp.asarray(plan), cost=jnp.asarray(cost),
            y_b=jnp.asarray(y_b), y_a=jnp.asarray(y_a),
            phases=jnp.asarray(phases), rounds=jnp.asarray(rounds),
            state=state, theta=jnp.asarray(thetas),
            s_int=jnp.asarray(s_int), d_int=jnp.asarray(d_int),
        )


# --------------------------------------------------------------------------
# Fused-kernel spec variants
# --------------------------------------------------------------------------
#
# Same protocol, same prologue/epilogue/trim/artifact surface — only
# ``run_phases`` differs: it dispatches the single fused Pallas kernel
# (``kernels/fused_phase``) that keeps the full solver state in VMEM
# across all k phases instead of bouncing it through HBM between
# ``slack_propose`` and the XLA state updates. The fused kernels are
# bit-identical to the stepped cores (asserted in
# tests/test_fused_phase.py), so every driver-level invariant — chained
# resumability, lockstep == compact, padded-lane inertness — carries
# over unchanged. Because ``core/compaction.spec_fns`` caches programs
# per spec IDENTITY, the fused singletons get their own jit program
# family automatically; ``name`` stays "assignment"/"ot" so result
# shaping, bucketing, and the serving layers treat them as the same
# problem. ``stepped`` points back at the base singleton — the checkify
# sanitizer (analysis/checkified.py) re-routes through it because it
# cannot instrument the inside of a Pallas kernel.


class FusedAssignmentSpec(AssignmentSpec):
    """AssignmentSpec whose k-phase loop is the fused Pallas kernel."""

    fused = True

    def run_phases(self, data, state, k: int):
        from ..kernels import ops as _kops

        return _kops.fused_run_assignment_phases(
            data["c_int"], state, data["threshold"], data["phase_cap"], k,
            m_valid=data["m_valid"])

    def _lockstep_k(self, eps_arr, m: int) -> int:
        return max(_max_phases(float(e), m) for e in eps_arr) + 1

    def solve_lockstep(self, inputs, eps: float, *, sizes=None,
                       guaranteed: bool = False, keep_state: bool = False):
        return _fused_lockstep(self, inputs, eps, sizes=sizes,
                               guaranteed=guaranteed, keep_state=keep_state)


class FusedOTSpec(OTSpec):
    """OTSpec whose k-phase loop is the fused Pallas kernel."""

    fused = True

    def run_phases(self, data, state, k: int):
        from ..kernels import ops as _kops

        m, n = data["c_int"].shape
        return _kops.fused_run_ot_phases(
            data["c_int"], state, data["threshold"], data["phase_cap"], k,
            int(m + n + 2))

    def _lockstep_k(self, eps_arr, m: int) -> int:
        return max(ot_phase_cap(float(e)) for e in eps_arr) + 1

    def solve_lockstep(self, inputs, eps: float, *, sizes=None,
                       guaranteed: bool = False, keep_state: bool = False,
                       theta=None):
        return _fused_lockstep(self, inputs, eps, sizes=sizes,
                               guaranteed=guaranteed, keep_state=keep_state,
                               theta=theta)


def _fused_lockstep(spec, inputs, eps, *, sizes, guaranteed, keep_state,
                    **prep_kw):
    """Lockstep for the fused specs: one compacting dispatch with k set
    above every phase cap, so the whole batch runs to termination in a
    single kernel launch — genuine lockstep semantics (no compaction ever
    fires) through the fused ``run_phases``. The base specs' lockstep
    delegates to ``core/batched``, which is hard-wired to the stepped
    while-loop cores; routing through the spec-generic compacting driver
    keeps the fused path out of that module entirely."""
    from .compaction import solve_compacting

    b, m, _ = (int(s) for s in np.shape(inputs["c"]))
    k_all = spec._lockstep_k(eps_array(eps, b, guaranteed), m)
    r, stats = solve_compacting(
        spec, inputs, eps, sizes=sizes, k=k_all, guaranteed=guaranteed,
        keep_state=keep_state, **prep_kw)
    return r, (stats.final_state if keep_state else None)


ASSIGNMENT = AssignmentSpec()
OT = OTSpec()
FUSED_ASSIGNMENT = FusedAssignmentSpec()
FUSED_OT = FusedOTSpec()
FusedAssignmentSpec.stepped = ASSIGNMENT
FusedOTSpec.stepped = OT
AssignmentSpec.fused = False
OTSpec.fused = False


def fused_variant(spec):
    """Map a base spec to its fused-kernel variant (identity on the fused
    singletons themselves). Specs outside this module register theirs by
    setting a ``fused_spec`` attribute (e.g. the portfolio's SINKHORN ->
    SINKHORN_KERNEL) so core never has to import them. Raises for unknown
    specs rather than guessing."""
    if getattr(spec, "fused", False):
        return spec
    if spec is ASSIGNMENT:
        return FUSED_ASSIGNMENT
    if spec is OT:
        return FUSED_OT
    alt = getattr(spec, "fused_spec", None)
    if alt is not None:
        return alt
    raise ValueError(f"no fused variant registered for spec {spec!r}")


# --------------------------------------------------------------------------
# Static-audit registration (repro.analysis): the prologue -> init_state
# chains are where the PR-3 donated-buffer aliasing bug lived — the state
# handed to the donating chunk dispatch must not share buffers with
# anything the epilogue (or the driver) still reads. The "state-init-chain"
# tag makes the donation-safety rule run its jaxpr alias analysis here.
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_assignment_state_chain():
    m = n = 8

    def chain(c, eps, m_valid, n_valid):
        data, ctx = ASSIGNMENT.prologue({
            "c": c, "eps": eps, "m_valid": m_valid, "n_valid": n_valid,
            "threshold": jnp.int32(0), "phase_cap": jnp.int32(8)})
        state = ASSIGNMENT.init_state(data, ctx)
        return {"state": state,
                "retained": {"c_int": data["c_int"], "cm": ctx["cm"],
                             "scale": ctx["scale"]}}

    return _audit.trace_entry(
        name="core.problem.assignment_state_chain",
        fn=chain,
        args={
            "c": jnp.zeros((m, n), jnp.float32),
            "eps": jnp.float32(0.1),
            "m_valid": jnp.int32(m),
            "n_valid": jnp.int32(n),
        },
        retained={"c"},
        must_trace={"eps", "m_valid", "n_valid"},
        tags={"state-init-chain", "assignment"},
        source=__name__,
    )


def _trace_ot_state_chain():
    m = n = 8

    def chain(c, nu, mu, theta, eps):
        data, ctx = OT.prologue({
            "c": c, "nu": nu, "mu": mu, "theta": theta, "eps": eps,
            "threshold": jnp.int32(0), "phase_cap": jnp.int32(8)})
        state = OT.init_state(data, ctx)
        return {"state": state,
                "retained": {"c_int": data["c_int"],
                             "s_int": ctx["s_int"], "d_int": ctx["d_int"],
                             "scale": ctx["scale"]}}

    return _audit.trace_entry(
        name="core.problem.ot_state_chain",
        fn=chain,
        args={
            "c": jnp.zeros((m, n), jnp.float32),
            "nu": jnp.full((m,), 1.0 / m, jnp.float32),
            "mu": jnp.full((n,), 1.0 / n, jnp.float32),
            "theta": jnp.float32(4.0 * m / 0.1),
            "eps": jnp.float32(0.1),
        },
        retained={"c", "nu", "mu"},
        must_trace={"eps", "theta"},
        tags={"state-init-chain", "ot"},
        source=__name__,
    )


_audit.register("core.problem.assignment_state_chain",
                _trace_assignment_state_chain, source=__name__)
_audit.register("core.problem.ot_state_chain", _trace_ot_state_chain,
                source=__name__)
