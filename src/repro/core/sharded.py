"""Distributed push-relabel: the cost matrix is sharded (rows=supply on
'data', cols=demand on 'model') and the *same* integer phase loop from
pushrelabel.py runs under pjit - the SPMD partitioner turns the row-argmin
propose into per-shard argmins + cross-shard min-reductions and the
scatter-min accept into per-shard scatters + all-reduce(min), i.e. exactly
the parallel schedule described in DESIGN.md 2.

Because proposals/acceptance use deterministic hash keys with min-reductions,
the distributed solve is BIT-IDENTICAL to the single-device solve (tested on
a forced multi-device CPU in tests/test_sharded_ot.py)."""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .pushrelabel import (
    AssignmentResult, complete_matching, round_costs, solve_assignment_int,
)

from ..compat import pvary as _pvary, shard_map as _shard_map


def solve_assignment_sharded(
    c: jnp.ndarray,
    eps: float,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    guaranteed: bool = False,
    m_valid: int | None = None,
    n_valid: int | None = None,
) -> AssignmentResult:
    """Assignment solve with the cost matrix sharded across `mesh`.

    The input matrix is placed sharded; all phase state (duals, matchings)
    stays 1-D sharded along its natural axis. Output matches the
    single-device `solve_assignment` bit for bit.

    ``m_valid``/``n_valid`` mark the input as padded: only the leading
    (m_valid, n_valid) block is the real instance (padded edges get the
    batched solver's PAD_COST / masked-completion treatment, so the result
    equals the unpadded solve). The distributed matrix placement
    (core/distributed.py) uses this to pad instances up to mesh-divisible
    shapes — this jax requires sharded dims divisible by the mesh."""
    from .pushrelabel import assignment_epilogue, assignment_prologue

    if guaranteed:
        eps = eps / 3.0
    c = jnp.asarray(c, jnp.float32)
    m = c.shape[0]
    if m_valid is None:
        mv = nv = None
        threshold = None
        cm, c_int, scale, row_ok, col_ok = assignment_prologue(c, eps)
    else:
        mv = jnp.int32(int(m_valid))
        nv = jnp.int32(int(n_valid))
        threshold = jnp.int32(int(eps * int(m_valid)))
        cm, c_int, scale, row_ok, col_ok = assignment_prologue(
            c, eps, mv, nv)
    c_sharded = jax.device_put(
        c_int, NamedSharding(mesh, P(row_axis, col_axis))
    )
    state = _assign_solve_fn(mesh, row_axis, col_axis, float(eps))(
        c_sharded, mv, threshold)
    return assignment_epilogue(cm, scale, state, eps, row_ok, col_ok)


@lru_cache(maxsize=None)
def _assign_solve_fn(mesh: Mesh, row_axis: str, col_axis: str, eps: float):
    """One jitted sharded phase-loop per (mesh, axes, eps) — repeat calls
    (the distributed matrix placement loops over instances) hit the jit
    cache instead of re-tracing per call."""
    def _solve(ci, mv_, th_):
        return solve_assignment_int(ci, eps, m_valid=mv_, threshold=th_)

    return jax.jit(
        _solve,
        in_shardings=(NamedSharding(mesh, P(row_axis, col_axis)),
                      None, None),
    )


def solve_ot_sharded(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps: float,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    theta: float | None = None,
    guaranteed: bool = False,
):
    """General-OT solve with the cost matrix (and both flow matrices of the
    solver state) sharded across ``mesh`` - the GSPMD-auto counterpart of
    ``solve_assignment_sharded`` for the transport solver.

    The integer phase loop (``solve_ot_int``) is jitted with the cost
    matrix placed ``P(row_axis, col_axis)`` and masses placed along their
    natural axes; the SPMD partitioner turns the row-local grant rounds
    into per-shard work plus min/sum cross-shard reductions. All phase
    arithmetic is int32 in units of eps, so the distributed integer state
    is BIT-IDENTICAL to the single-device ``solve_ot`` state; the float
    epilogue then runs on the gathered state with the same eager op
    sequence as ``solve_ot``, so the plan/cost match bit for bit too."""
    from .transport import (
        ot_epilogue, ot_phase_cap, ot_prologue, ot_termination_threshold,
        solve_ot_int,
    )

    if guaranteed:
        eps = eps / 3.0
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    nb, na = c.shape
    if theta is None:
        theta = 4.0 * max(nb, na) / eps
    threshold = ot_termination_threshold(np.asarray(nu), theta, eps)
    c_int, s_int, d_int, scale = ot_prologue(c, nu, mu, theta, eps)

    sh_mat = NamedSharding(mesh, P(row_axis, col_axis))
    sh_row = NamedSharding(mesh, P(row_axis))
    sh_col = NamedSharding(mesh, P(col_axis))
    solve = _ot_solve_fn(mesh, row_axis, col_axis, float(eps),
                         int(nb + na + 2))
    state = solve(
        jax.device_put(c_int, sh_mat),
        jax.device_put(s_int, sh_row),
        jax.device_put(d_int, sh_col),
        jnp.int32(threshold),
    )
    # epilogue on the gathered state, op-for-op the eager solve_ot path
    state = jax.device_get(state)
    state = jax.tree_util.tree_map(jnp.asarray, state)
    res = ot_epilogue(c, nu, mu, theta, eps, scale, s_int, d_int, state)
    return res._replace(theta=float(res.theta))


@lru_cache(maxsize=None)
def _ot_solve_fn(mesh: Mesh, row_axis: str, col_axis: str, eps: float,
                 max_rounds: int):
    """One jitted sharded OT phase-loop per (mesh, axes, eps, round cap),
    mirroring ``_assign_solve_fn``."""
    from .transport import ot_phase_cap, solve_ot_int

    def _solve(ci, si, di, th):
        return solve_ot_int(ci, si, di, eps, ot_phase_cap(eps),
                            max_rounds, threshold=th)

    return jax.jit(
        _solve,
        in_shardings=(NamedSharding(mesh, P(row_axis, col_axis)),
                      NamedSharding(mesh, P(row_axis)),
                      NamedSharding(mesh, P(col_axis)), None),
    )


def lower_sharded_solver(n: int, eps: float, mesh: Mesh,
                         row_axis="data", col_axis="model"):
    """AOT artifact for the dry-run/roofline path: lower + compile the phase
    loop for an (n, n) cost matrix on `mesh` without allocating it."""
    sds = jax.ShapeDtypeStruct(
        (n, n), jnp.int32,
        sharding=NamedSharding(mesh, P(row_axis, col_axis)),
    )
    fn = jax.jit(partial(solve_assignment_int, eps=eps))
    return fn.lower(sds)


# ===========================================================================
# Explicit shard_map implementation - the paper's parallel schedule with
# hand-placed collectives (vs. the GSPMD-auto version above). Per round:
#   propose : row-local hash-argmin over the LOCAL column block, then two
#             lexicographic pmin's across the column axis (min key, then min
#             global column among blocks achieving it);
#   accept  : per column-block scatter-min of proposing global row ids, then
#             pmin across the row axis; one all_gather of the (n_loc,)
#             winners over the column axis so every row learns its verdict.
# Per phase, push/relabel are purely local except one all_gather of the
# displaced-partner ids. Cross-device traffic per round is O(m + n) ints -
# the n^2 work stays entirely shard-local, which is the whole point of the
# paper's O(log n / eps^2) parallel claim.
# ===========================================================================

from .matching import proposal_keys  # noqa: E402,F401  (hash must match exactly)

_BIG32 = jnp.int32(2**31 - 1)
_UMAX = jnp.uint32(0xFFFFFFFF)


def _propose_local(c_blk, y_b, y_a_blk, avail_blk, salt, r0, c0, m, n):
    """Per-row best (key, global col) within this block."""
    m_loc, n_loc = c_blk.shape
    adm = (y_b[:, None] + y_a_blk[None, :] == c_blk + 1) & avail_blk[None, :]
    # hash inputs must be pure uint32 (an int32 offset would promote and
    # change the keys vs the single-device proposal_keys)
    rows_g = (r0.astype(jnp.uint32)
              + jnp.arange(m_loc, dtype=jnp.uint32))[:, None]
    cols_g = (c0.astype(jnp.uint32)
              + jnp.arange(n_loc, dtype=jnp.uint32))[None, :]
    from .matching import _mix, _H1, _H2, _H3
    keys = _mix(rows_g * _H1 + cols_g * _H2
                + salt.astype(jnp.uint32) * _H3)
    keys = jnp.where(adm, keys, _UMAX)
    best_key = jnp.min(keys, axis=1)
    best_col = (c0 + jnp.argmin(keys, axis=1)).astype(jnp.int32)
    return best_key, jnp.where(best_key == _UMAX, _BIG32, best_col)


def _phase_shardmap(c_blk, carry, salt0, row_axis, col_axis, m, n,
                    m_loc, n_loc, max_rounds):
    y_b, y_a, match_ba, match_ab = carry
    r0 = jax.lax.axis_index(row_axis) * m_loc
    c0 = jax.lax.axis_index(col_axis) * n_loc
    rows_g = r0 + jnp.arange(m_loc, dtype=jnp.int32)
    cols_g = c0 + jnp.arange(n_loc, dtype=jnp.int32)
    in_bprime = match_ba < 0

    zero = jnp.sum(c_blk[:1, :1]) * 0

    def round_body(state):
        mprime_b, mprime_a, avail_blk, active_b, rounds, done = state
        salt = salt0 * jnp.int32(7919) + rounds
        bk, bc = _propose_local(c_blk, y_b, y_a, avail_blk, salt,
                                r0, c0, m, n)
        # pmin lowers unsigned to signed; use the order-preserving
        # uint32 -> int32 bijection (flip the sign bit) for the reduction.
        bks = jax.lax.bitcast_convert_type(
            bk ^ jnp.uint32(0x80000000), jnp.int32)
        # lexicographic min across column blocks: first the key...
        kmin = jax.lax.pmin(bks, col_axis)
        # ...then the smallest global column among blocks achieving kmin
        cand = jnp.where((bks == kmin) & (kmin != _BIG32), bc, _BIG32)
        prop = jax.lax.pmin(cand, col_axis)          # (m_loc,) global col
        prop = jnp.where(active_b & (prop != _BIG32), prop, -1)

        # accept: my column block scatters min proposing global row id
        local = (prop >= c0) & (prop < c0 + n_loc)
        tgt = jnp.where(local, prop - c0, n_loc)
        winners = jnp.full((n_loc,), _BIG32).at[tgt].min(
            jnp.where(local, rows_g, _BIG32), mode="drop")
        winners = jax.lax.pmin(winners, row_axis)     # (n_loc,) global rows
        # every row needs the winner of an arbitrary global column
        winners_all = jax.lax.all_gather(
            winners, col_axis, tiled=True)            # (n,)
        won = (prop >= 0) & (
            winners_all[jnp.clip(prop, 0, n - 1)] == rows_g)

        mprime_b = jnp.where(won, prop, mprime_b)
        won_col = (winners != _BIG32)
        mprime_a = jnp.where(won_col, winners, mprime_a)
        avail_blk = avail_blk & ~won_col
        active_b = active_b & ~won
        any_prop = jax.lax.pmax(
            jnp.any(prop >= 0).astype(jnp.int32), (row_axis, col_axis))
        done = _pvary(any_prop == 0, (row_axis, col_axis))
        return (mprime_b, mprime_a, avail_blk, active_b, rounds + 1, done)

    init = (jnp.full((m_loc,), -1) + zero, jnp.full((n_loc,), _BIG32) + zero,
            (zero == 0) & jnp.ones((n_loc,), bool),
            in_bprime, zero, zero != 0)
    mprime_b, mprime_a, avail_blk, active_b, rounds, _ = jax.lax.while_loop(
        lambda s: (~s[5]) & (s[4] < max_rounds), round_body, init)

    # (II) push - my columns know their new and old partners
    won_col = mprime_a != _BIG32
    displaced = jnp.where(won_col & (match_ab >= 0), match_ab, -1)
    displaced_all = jax.lax.all_gather(displaced, col_axis, tiled=True)
    freed_mask_global = jnp.zeros((m,), bool).at[
        jnp.where(displaced_all >= 0, displaced_all, m)
    ].set(True, mode="drop")
    freed_mine = jax.lax.dynamic_slice_in_dim(freed_mask_global, r0, m_loc)
    match_ba = jnp.where(freed_mine, -1, match_ba)
    match_ba = jnp.where(mprime_b >= 0, mprime_b, match_ba)
    match_ab = jnp.where(won_col, mprime_a, match_ab)

    # (III) relabel - all local
    y_a = y_a - won_col.astype(jnp.int32)
    still_free = in_bprime & active_b
    y_b = y_b + still_free.astype(jnp.int32)
    return (y_b, y_a, match_ba, match_ab), rounds


def solve_assignment_shardmap(
    c: jnp.ndarray,
    eps: float,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
) -> AssignmentResult:
    """Manual-collective distributed push-relabel; bit-identical to
    solve_assignment (same hashes, same lexicographic tie-breaks)."""
    c = jnp.asarray(c, jnp.float32)
    m, n = c.shape
    n_row = mesh.shape[row_axis]
    n_col = mesh.shape[col_axis]
    assert m % n_row == 0 and n % n_col == 0, (m, n, dict(mesh.shape))
    m_loc, n_loc = m // n_row, n // n_col
    scale = jnp.maximum(jnp.max(c), 1e-30)
    c_int = round_costs(c / scale, eps)
    threshold = jnp.int32(int(eps * m))
    from .pushrelabel import _max_phases
    max_phases = _max_phases(eps, m)
    max_rounds = min(m, n) + 1

    def body(c_blk):
        zero = jnp.sum(c_blk[:1, :1]) * 0
        init = (
            jnp.ones((m_loc,), jnp.int32) + zero,       # y_b
            jnp.zeros((n_loc,), jnp.int32) + zero,      # y_a
            jnp.full((m_loc,), -1, jnp.int32) + zero,   # match_ba
            jnp.full((n_loc,), -1, jnp.int32) + zero,   # match_ab
            zero,                                        # phases
            zero,                                        # rounds
        )

        def cond(s):
            free = jax.lax.psum(
                jnp.sum(s[2] < 0, dtype=jnp.int32), (row_axis,))
            return (free > threshold) & (s[4] < jnp.int32(max_phases))

        def phase(s):
            carry, rounds = _phase_shardmap(
                c_blk, s[:4], s[4], row_axis, col_axis, m, n,
                m_loc, n_loc, max_rounds)
            return carry + (s[4] + 1, s[5] + rounds)

        y_b, y_a, mba, mab, ph, rd = jax.lax.while_loop(cond, phase, init)
        # declare replication along the orthogonal axis (values are equal
        # across it by construction; pmax makes that visible to the vma
        # checker so the out_specs below are accepted)
        return (
            jax.lax.pmax(y_b, col_axis),
            jax.lax.pmax(y_a, row_axis),
            jax.lax.pmax(mba, col_axis),
            jax.lax.pmax(mab, row_axis),
            jax.lax.pmax(ph, (row_axis, col_axis)),
            jax.lax.pmax(rd, (row_axis, col_axis)),
        )

    out = jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=P(row_axis, col_axis),
        out_specs=(P(row_axis), P(col_axis), P(row_axis), P(col_axis),
                   P(), P()),
    ))(jax.device_put(c_int, NamedSharding(mesh, P(row_axis, col_axis))))
    y_b, y_a, match_ba, match_ab, phases, rounds = out

    matching = complete_matching(match_ba, match_ab)
    rows = jnp.arange(m)
    valid = matching >= 0
    cost = jnp.sum(
        jnp.where(valid, c[rows, jnp.clip(matching, 0, n - 1)], 0.0))
    return AssignmentResult(
        matching=matching,
        cost=cost,
        y_b=y_b.astype(jnp.float32) * eps * scale,
        y_a=y_a.astype(jnp.float32) * eps * scale,
        phases=phases,
        rounds=rounds,
        sum_ni=jnp.int32(-1),  # not tracked in the manual path
        matched_before_completion=jnp.sum(match_ba >= 0, dtype=jnp.int32),
    )
