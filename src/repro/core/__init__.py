"""The paper's primary contribution: push-relabel additive approximation
for assignment and optimal transport, integer-exact, jit-end-to-end."""
from .pushrelabel import solve_assignment, solve_assignment_int, AssignmentResult
from .transport import solve_ot, solve_ot_int, OTResult, northwest_corner
from .costs import build_cost_matrix
from .sinkhorn import sinkhorn

__all__ = [
    "solve_assignment", "solve_assignment_int", "AssignmentResult",
    "solve_ot", "solve_ot_int", "OTResult", "northwest_corner",
    "build_cost_matrix", "sinkhorn",
]
