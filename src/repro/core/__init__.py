"""The paper's primary contribution: push-relabel additive approximation
for assignment and optimal transport, integer-exact, jit-end-to-end."""
from .pushrelabel import solve_assignment, solve_assignment_int, AssignmentResult
from .transport import solve_ot, solve_ot_int, OTResult, northwest_corner
from .problem import ASSIGNMENT, OT, AssignmentSpec, OTSpec, ProblemSpec
from .api import DispatchPolicy, solve
from .solution import (
    ArtifactNotRequested,
    Solution,
    SolutionBatch,
    SolveStats,
    SparsePlan,
    SparsePlanBatch,
)
from .batched import (
    BatchedAssignmentResult,
    solve_assignment_batched,
    solve_assignment_ragged,
    solve_ot_batched,
    solve_ot_ragged,
)
from .compaction import (
    CompactionStats,
    solve_assignment_batched_compacting,
    solve_ot_batched_compacting,
)
from .distributed import (
    DistributedStats,
    choose_placement,
    solve_assignment_distributed,
    solve_ot_distributed,
)
from .costs import build_cost_matrix
from .sinkhorn import sinkhorn

__all__ = [
    "ASSIGNMENT", "OT", "AssignmentSpec", "OTSpec", "ProblemSpec",
    "DispatchPolicy", "solve",
    "ArtifactNotRequested", "Solution", "SolutionBatch", "SolveStats",
    "SparsePlan", "SparsePlanBatch",
    "solve_assignment", "solve_assignment_int", "AssignmentResult",
    "solve_ot", "solve_ot_int", "OTResult", "northwest_corner",
    "solve_assignment_batched", "solve_assignment_ragged",
    "solve_ot_batched", "solve_ot_ragged", "BatchedAssignmentResult",
    "CompactionStats", "solve_assignment_batched_compacting",
    "solve_ot_batched_compacting",
    "DistributedStats", "choose_placement",
    "solve_assignment_distributed", "solve_ot_distributed",
    "build_cost_matrix", "sinkhorn",
]
