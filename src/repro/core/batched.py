"""Batched fixed-shape push-relabel solvers: B instances as one XLA program.

The paper's headline bound is *parallel* time O(log n / eps^2); serving many
small/medium OT instances means the win comes from amortizing one dispatch
across a batch (cf. the matrix-batched formulations of Altschuler-Weed-
Rigollet).  This module vmaps the existing single-instance ``lax.while_loop``
solvers over a leading batch axis.  JAX's while-loop batching rule runs the
lockstep loop until every instance's own predicate is false and select-masks
the carries of finished instances, so each instance executes *exactly* the
phase sequence it would have executed alone - results are bit-identical to
unbatched solves (up to the static round cap, which is derived from the
padded bucket shape and never binds in practice).

Ragged batches are handled by a padding/bucketing layer:

  * instances are padded up to a shape bucket (next power-of-two-ish size);
  * padded supply rows get zero mass / are masked out of the free set B';
  * padded demand columns get zero capacity (OT) or a cost so large that no
    dual sum can ever make them admissible (assignment);

so a padded instance walks the same admissible subgraph, with the same
deterministic hash keys (keys depend only on *global* (row, col, salt), not
on the matrix shape), as its unpadded original.

The ragged front ends default to the convergence-compacting driver
(core/compaction.py, ``compact=True``): each bucket is solved as a sequence
of k-phase dispatches with converged instances retired between dispatches,
rather than one lockstep loop that runs every instance until the slowest
converges. Results are identical either way; the lockstep fixed-shape entry
points below remain the single-dispatch building blocks.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .pushrelabel import assignment_pipeline
from .transport import OTResult, ot_pipeline

DEFAULT_BUCKETS: tuple[int, ...] = (16, 32, 64, 128, 256, 512, 1024, 2048)


def next_bucket(k: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= k (k itself if it exceeds every bucket)."""
    for b in buckets:
        if b >= k:
            return b
    return int(k)


def _sizes_arrays(sizes, b, m, n):
    """Host-side (B,) m_valid / n_valid arrays (full shape when sizes=None)."""
    if sizes is None:
        return (np.full((b,), m, np.int32), np.full((b,), n, np.int32))
    sizes = np.asarray(sizes, np.int32)
    if sizes.shape != (b, 2):
        raise ValueError(f"sizes must be ({b}, 2), got {sizes.shape}")
    if (sizes[:, 0] > m).any() or (sizes[:, 1] > n).any():
        raise ValueError("instance size exceeds padded bucket shape")
    return sizes[:, 0].copy(), sizes[:, 1].copy()


# --------------------------------------------------------------------------
# Assignment
# --------------------------------------------------------------------------

class BatchedAssignmentResult(NamedTuple):
    matching: jnp.ndarray   # (B, M) int32, -1 beyond each instance's rows
    cost: jnp.ndarray       # (B,) float32
    y_b: jnp.ndarray        # (B, M) float32 scaled duals
    y_a: jnp.ndarray        # (B, N) float32 scaled duals
    phases: jnp.ndarray     # (B,) int32
    rounds: jnp.ndarray     # (B,) int32
    matched_before_completion: jnp.ndarray  # (B,) int32


@partial(jax.jit, static_argnames=("eps",))
def _solve_assignment_batched(c, m_valid, n_valid, threshold, eps: float):
    return jax.vmap(
        lambda ci, mv, nv, th: assignment_pipeline(
            ci, eps, m_valid=mv, n_valid=nv, threshold=th
        )
    )(c, m_valid, n_valid, threshold)


def solve_assignment_batched(
    c: jnp.ndarray,
    eps: float,
    *,
    sizes=None,
    guaranteed: bool = False,
) -> BatchedAssignmentResult:
    """Solve B assignment instances stacked as one (B, M, N) cost tensor.

    Args:
      c: (B, M, N) nonnegative float costs; instance i occupies the leading
        ``sizes[i] = (m_i, n_i)`` block (m_i <= n_i), the rest is padding.
      eps: additive error parameter (shared across the batch - bucket
        dispatches share one compiled program per (shape, eps)).
      sizes: optional host (B, 2) int array of true instance shapes.
    """
    if guaranteed:
        eps = eps / 3.0
    c = jnp.asarray(c, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    # Termination thresholds in host float64, matching the unbatched
    # int(eps * m) exactly (f32 rounding flips the floor for some eps).
    threshold = np.asarray([int(eps * int(mi)) for mi in m_valid], np.int32)
    r = _solve_assignment_batched(
        c, jnp.asarray(m_valid), jnp.asarray(n_valid),
        jnp.asarray(threshold), eps
    )
    return BatchedAssignmentResult(
        matching=r.matching,
        cost=r.cost,
        y_b=r.y_b,
        y_a=r.y_a,
        phases=r.phases,
        rounds=r.rounds,
        matched_before_completion=r.matched_before_completion,
    )


# --------------------------------------------------------------------------
# General OT
# --------------------------------------------------------------------------

def _theta_array(sizes_m, sizes_n, eps, theta) -> np.ndarray:
    """Per-instance theta = 4*max(m, n)/eps, computed on host in float64 and
    cast to f32 so it is bit-identical to the unbatched solve_ot default.
    ``eps`` may be a scalar or a (B,) array (compacting driver)."""
    if theta is not None:
        return np.broadcast_to(
            np.asarray(theta, np.float32), sizes_m.shape
        ).copy()
    eps = np.asarray(eps, np.float64)
    return (4.0 * np.maximum(sizes_m, sizes_n) / eps).astype(np.float32)


def _mask_ot_inputs(c, nu, mu, m_valid, n_valid, theta, eps):
    """Zero mass/cost outside each instance's block and compute the
    per-instance termination thresholds in host float64 from the masked
    masses — identical to the unbatched solve_ot (the on-device f32
    product rounds the wrong way for some (eps, total_mass) pairs).
    Shared by the lockstep and compacting paths so the two can never
    diverge on threshold/masking semantics. ``eps`` scalar or (B,)."""
    b, m, n = c.shape
    row_ok = np.arange(m)[None, :] < m_valid[:, None]
    col_ok = np.arange(n)[None, :] < n_valid[:, None]
    eps_b = np.broadcast_to(np.asarray(eps, np.float64), (b,))
    nu_h = np.where(row_ok, np.asarray(nu, np.float32), np.float32(0.0))
    # vectorized ot_termination_threshold: f32 floor(nu * theta) per entry
    # (the device rounding), f64 row sums, f64 eps product, truncation
    s_rows = np.floor(nu_h * np.asarray(theta, np.float32)[:, None])
    thr = (eps_b * s_rows.sum(axis=1, dtype=np.float64)).astype(np.int64) \
        .astype(np.int32)
    mask = jnp.asarray(row_ok[:, :, None] & col_ok[:, None, :])
    c = jnp.where(mask, c, 0.0)
    nu = jnp.where(jnp.asarray(row_ok), nu, 0.0)
    mu = jnp.where(jnp.asarray(col_ok), mu, 0.0)
    return c, nu, mu, thr


@partial(jax.jit, static_argnames=("eps",))
def _solve_ot_batched(c, nu, mu, theta, threshold, eps: float) -> OTResult:
    return jax.vmap(
        lambda ci, nui, mui, ti, thi: ot_pipeline(ci, nui, mui, ti, eps,
                                                  threshold=thi)
    )(c, nu, mu, theta, threshold)


def solve_ot_batched(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps: float,
    *,
    sizes=None,
    theta=None,
    guaranteed: bool = False,
) -> OTResult:
    """Solve B general OT instances stacked as one (B, M, N) program.

    Args:
      c: (B, M, N) costs; nu: (B, M) supplies; mu: (B, N) demands. Instance i
        occupies the leading ``sizes[i]`` block; padded rows/cols must carry
        zero mass (they are zeroed defensively from ``sizes`` regardless).
      eps: additive error parameter shared across the batch.
      sizes: optional host (B, 2) int array of true instance shapes - also
        sets the per-instance theta to the unbatched default 4*max(m,n)/eps.
      theta: optional scalar or (B,) override of the mass scaling.

    Returns an OTResult whose every leaf carries a leading batch axis.
    """
    if guaranteed:
        eps = eps / 3.0
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    th = _theta_array(m_valid, n_valid, eps, theta)
    c, nu, mu, thr = _mask_ot_inputs(c, nu, mu, m_valid, n_valid, th, eps)
    return _solve_ot_batched(c, nu, mu, jnp.asarray(th), jnp.asarray(thr),
                             eps)


# --------------------------------------------------------------------------
# Ragged front end: bucket, pad, dispatch, unpad
# --------------------------------------------------------------------------

class _Bucketed(NamedTuple):
    key: tuple            # bucket shape key
    indices: list         # original instance positions
    sizes: np.ndarray     # (Bg, 2)


def bucket_instances(shapes, buckets: Sequence[int] = DEFAULT_BUCKETS):
    """Group instance shapes [(m_i, n_i)] into shape buckets.

    Returns a list of _Bucketed groups; every instance appears in exactly
    one group and ``key = (M, N)`` is the padded dispatch shape."""
    groups: dict = {}
    for i, (mi, ni) in enumerate(shapes):
        key = (next_bucket(int(mi), buckets), next_bucket(int(ni), buckets))
        groups.setdefault(key, []).append(i)
    out = []
    for key, idx in sorted(groups.items()):
        sizes = np.asarray([shapes[i] for i in idx], np.int32)
        out.append(_Bucketed(key=key, indices=idx, sizes=sizes))
    return out


def pad_stack(arrays, shape) -> jnp.ndarray:
    """Zero-pad each array up to ``shape`` and stack on a new batch axis."""
    out = []
    for a in arrays:
        a = np.asarray(a, np.float32)
        pad = [(0, s - d) for s, d in zip(shape, a.shape)]
        out.append(np.pad(a, pad))
    return jnp.asarray(np.stack(out))


def solve_ot_ragged(
    instances,
    eps,
    *,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    guaranteed: bool = False,
    compact: bool = True,
    chunk: int | None = None,
    mesh=None,
):
    """Solve a ragged list of ``(c, nu, mu)`` OT instances via bucketed
    batched dispatch. Returns per-instance dicts (in input order) with the
    unpadded plan and scalar diagnostics.

    ``compact=True`` (default) routes each bucket through the convergence-
    compacting driver (core/compaction.py): converged instances retire
    between k-phase dispatches instead of riding lockstep until the slowest
    one finishes, and ``eps`` may be a per-instance sequence. ``compact=
    False`` restores the PR-1 lockstep dispatch (results are identical).
    Tradeoff: compaction wins on convergence-skewed buckets (2-4x on the
    in-repo bench) but its per-chunk converged-mask sync can lose ~20-50%
    on tiny or convergence-uniform buckets — pass ``compact=False`` there.

    ``mesh`` (a 1-D batch mesh, see ``launch.mesh.make_batch_mesh``)
    dispatches each bucket through the mesh-distributed compacting driver
    (core/distributed.py) — same results, batch axis sharded across
    devices. Requires ``compact=True``."""
    if mesh is not None and not compact:
        raise ValueError("mesh dispatch requires compact=True (the "
                         "distributed driver is the compacting driver)")
    shapes = [tuple(np.asarray(c).shape) for c, _, _ in instances]
    eps_arr = np.broadcast_to(np.asarray(eps, np.float64),
                              (len(instances),))
    if not compact and np.unique(eps_arr).size > 1:
        raise ValueError("per-instance eps requires compact=True")
    results: list = [None] * len(instances)
    for grp in bucket_instances(shapes, buckets):
        mb, nb = grp.key
        c = pad_stack([instances[i][0] for i in grp.indices], (mb, nb))
        nu = pad_stack([instances[i][1] for i in grp.indices], (mb,))
        mu = pad_stack([instances[i][2] for i in grp.indices], (nb,))
        stats = None
        if mesh is not None:
            from .distributed import solve_ot_distributed

            kw = {} if chunk is None else {"k": chunk}
            r, stats = solve_ot_distributed(
                c, nu, mu, eps_arr[grp.indices], mesh, sizes=grp.sizes,
                guaranteed=guaranteed, **kw
            )
        elif compact:
            from .compaction import solve_ot_batched_compacting

            kw = {} if chunk is None else {"k": chunk}
            r, stats = solve_ot_batched_compacting(
                c, nu, mu, eps_arr[grp.indices], sizes=grp.sizes,
                guaranteed=guaranteed, **kw
            )
        else:
            r = solve_ot_batched(c, nu, mu, float(eps_arr[0]),
                                 sizes=grp.sizes, guaranteed=guaranteed)
        # one device->host fetch per result array, not per instance
        plan, cost, phases, rounds, theta = (
            np.asarray(r.plan), np.asarray(r.cost), np.asarray(r.phases),
            np.asarray(r.rounds), np.asarray(r.theta),
        )
        for k, i in enumerate(grp.indices):
            mi, ni = shapes[i]
            results[i] = {
                "plan": plan[k, :mi, :ni],
                "cost": float(cost[k]),
                "phases": int(phases[k]),
                "rounds": int(rounds[k]),
                "theta": float(theta[k]),
                "batch_size": len(grp.indices),
                "bucket": grp.key,
            }
            if stats is not None:
                results[i]["dispatches"] = stats.dispatches
                if hasattr(stats, "devices"):
                    results[i]["devices"] = stats.devices
    return results


def solve_assignment_ragged(
    cs,
    eps,
    *,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    guaranteed: bool = False,
    compact: bool = True,
    chunk: int | None = None,
    mesh=None,
):
    """Solve a ragged list of assignment cost matrices via bucketed batched
    dispatch. Returns per-instance dicts (in input order). ``compact`` and
    ``mesh`` as in ``solve_ot_ragged``."""
    if mesh is not None and not compact:
        raise ValueError("mesh dispatch requires compact=True (the "
                         "distributed driver is the compacting driver)")
    shapes = [tuple(np.asarray(c).shape) for c in cs]
    eps_arr = np.broadcast_to(np.asarray(eps, np.float64), (len(cs),))
    if not compact and np.unique(eps_arr).size > 1:
        raise ValueError("per-instance eps requires compact=True")
    results: list = [None] * len(cs)
    for grp in bucket_instances(shapes, buckets):
        c = pad_stack([cs[i] for i in grp.indices], grp.key)
        stats = None
        if mesh is not None:
            from .distributed import solve_assignment_distributed

            kw = {} if chunk is None else {"k": chunk}
            r, stats = solve_assignment_distributed(
                c, eps_arr[grp.indices], mesh, sizes=grp.sizes,
                guaranteed=guaranteed, **kw
            )
        elif compact:
            from .compaction import solve_assignment_batched_compacting

            kw = {} if chunk is None else {"k": chunk}
            r, stats = solve_assignment_batched_compacting(
                c, eps_arr[grp.indices], sizes=grp.sizes,
                guaranteed=guaranteed, **kw
            )
        else:
            r = solve_assignment_batched(c, float(eps_arr[0]),
                                         sizes=grp.sizes,
                                         guaranteed=guaranteed)
        matching, cost, phases, rounds, y_b, y_a = (
            np.asarray(r.matching), np.asarray(r.cost), np.asarray(r.phases),
            np.asarray(r.rounds), np.asarray(r.y_b), np.asarray(r.y_a),
        )
        for k, i in enumerate(grp.indices):
            mi, ni = shapes[i]
            results[i] = {
                "matching": matching[k, :mi],
                "cost": float(cost[k]),
                "phases": int(phases[k]),
                "rounds": int(rounds[k]),
                "y_b": y_b[k, :mi],
                "y_a": y_a[k, :ni],
                "batch_size": len(grp.indices),
                "bucket": grp.key,
            }
            if stats is not None:
                results[i]["dispatches"] = stats.dispatches
                if hasattr(stats, "devices"):
                    results[i]["devices"] = stats.devices
    return results
