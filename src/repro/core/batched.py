"""Batched fixed-shape push-relabel solvers: B instances as one XLA program.

The paper's headline bound is *parallel* time O(log n / eps^2); serving many
small/medium OT instances means the win comes from amortizing one dispatch
across a batch (cf. the matrix-batched formulations of Altschuler-Weed-
Rigollet).  This module vmaps the existing single-instance ``lax.while_loop``
solvers over a leading batch axis.  JAX's while-loop batching rule runs the
lockstep loop until every instance's own predicate is false and select-masks
the carries of finished instances, so each instance executes *exactly* the
phase sequence it would have executed alone - results are bit-identical to
unbatched solves (up to the static round cap, which is derived from the
padded bucket shape and never binds in practice).

Ragged batches are handled by a padding/bucketing layer:

  * instances are padded up to a shape bucket (next power-of-two-ish size;
    shapes beyond the bucket table mint a ceil-pow2 bucket on the fly);
  * padded supply rows get zero mass / are masked out of the free set B';
  * padded demand columns get zero capacity (OT) or a cost so large that no
    dual sum can ever make them admissible (assignment);

so a padded instance walks the same admissible subgraph, with the same
deterministic hash keys (keys depend only on *global* (row, col, salt), not
on the matrix shape), as its unpadded original.

The ragged front ends are thin wrappers over the unified dispatch front
door (``core/api.solve``): ``compact``/``mesh`` arguments map onto a
:class:`~repro.core.api.DispatchPolicy`, and the lockstep fixed-shape
entry points below remain the single-dispatch building blocks the
``ASSIGNMENT``/``OT`` specs bind to.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .problem import (
    _mask_ot_inputs,
    _sizes_arrays,
    _theta_array,
    pow2_at_least,
)
from .pushrelabel import (
    assignment_epilogue,
    assignment_pipeline,
    assignment_prologue,
    solve_assignment_int,
)
from .transport import OTResult, ot_pipeline

DEFAULT_BUCKETS: tuple[int, ...] = (16, 32, 64, 128, 256, 512, 1024, 2048)


def next_bucket(k: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= k. Shapes beyond the biggest table entry mint a
    ceil-power-of-two bucket instead of a per-shape exact bucket, so a
    long tail of huge instances still shares compiled programs."""
    for b in buckets:
        if b >= k:
            return b
    return pow2_at_least(int(k))


# --------------------------------------------------------------------------
# Assignment
# --------------------------------------------------------------------------

class BatchedAssignmentResult(NamedTuple):
    matching: jnp.ndarray   # (B, M) int32, -1 beyond each instance's rows
    cost: jnp.ndarray       # (B,) float32
    y_b: jnp.ndarray        # (B, M) float32 scaled duals
    y_a: jnp.ndarray        # (B, N) float32 scaled duals
    phases: jnp.ndarray     # (B,) int32
    rounds: jnp.ndarray     # (B,) int32
    matched_before_completion: jnp.ndarray  # (B,) int32


@partial(jax.jit, static_argnames=("eps",))
def _solve_assignment_batched(c, m_valid, n_valid, threshold, eps: float):
    return jax.vmap(
        lambda ci, mv, nv, th: assignment_pipeline(
            ci, eps, m_valid=mv, n_valid=nv, threshold=th
        )
    )(c, m_valid, n_valid, threshold)


@partial(jax.jit, static_argnames=("eps",))
def _solve_assignment_batched_state(c, m_valid, n_valid, threshold,
                                    eps: float):
    """``_solve_assignment_batched`` that ALSO returns the pre-completion
    integer state — the same prologue -> solve_assignment_int -> epilogue
    composition ``assignment_pipeline`` is made of, so the per-instance
    trajectory (and the result) is identical; only the state escapes the
    program. Used when the Solution surface requests the ``state``
    artifact (want/keep_state) under lockstep dispatch."""

    def one(ci, mv, nv, th):
        cm, c_int, scale, row_ok, col_ok = assignment_prologue(
            ci, eps, mv, nv)
        st = solve_assignment_int(c_int, eps, m_valid=mv, threshold=th)
        return assignment_epilogue(cm, scale, st, eps, row_ok, col_ok), st

    return jax.vmap(one)(c, m_valid, n_valid, threshold)


def solve_assignment_batched(
    c: jnp.ndarray,
    eps: float,
    *,
    sizes=None,
    guaranteed: bool = False,
    keep_state: bool = False,
):
    """Solve B assignment instances stacked as one (B, M, N) cost tensor.

    Args:
      c: (B, M, N) nonnegative float costs; instance i occupies the leading
        ``sizes[i] = (m_i, n_i)`` block (m_i <= n_i), the rest is padding.
      eps: additive error parameter (shared across the batch - bucket
        dispatches share one compiled program per (shape, eps)).
      sizes: optional host (B, 2) int array of true instance shapes.
      keep_state: ALSO return the batched pre-completion integer state
        (``(BatchedAssignmentResult, PushRelabelState)`` instead of just
        the result) for feasibility certificates / the ``state``
        artifact of the Solution surface.
    """
    if guaranteed:
        eps = eps / 3.0
    c = jnp.asarray(c, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    # Termination thresholds in host float64, matching the unbatched
    # int(eps * m) exactly (f32 rounding flips the floor for some eps).
    threshold = np.asarray([int(eps * int(mi)) for mi in m_valid], np.int32)
    args = (c, jnp.asarray(m_valid), jnp.asarray(n_valid),
            jnp.asarray(threshold))
    state = None
    if keep_state:
        r, state = _solve_assignment_batched_state(*args, eps)
    else:
        r = _solve_assignment_batched(*args, eps)
    out = BatchedAssignmentResult(
        matching=r.matching,
        cost=r.cost,
        y_b=r.y_b,
        y_a=r.y_a,
        phases=r.phases,
        rounds=r.rounds,
        matched_before_completion=r.matched_before_completion,
    )
    return (out, state) if keep_state else out


# --------------------------------------------------------------------------
# General OT
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("eps",))
def _solve_ot_batched(c, nu, mu, theta, threshold, eps: float) -> OTResult:
    return jax.vmap(
        lambda ci, nui, mui, ti, thi: ot_pipeline(ci, nui, mui, ti, eps,
                                                  threshold=thi)
    )(c, nu, mu, theta, threshold)


def solve_ot_batched(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps: float,
    *,
    sizes=None,
    theta=None,
    guaranteed: bool = False,
) -> OTResult:
    """Solve B general OT instances stacked as one (B, M, N) program.

    Args:
      c: (B, M, N) costs; nu: (B, M) supplies; mu: (B, N) demands. Instance i
        occupies the leading ``sizes[i]`` block; padded rows/cols must carry
        zero mass (they are zeroed defensively from ``sizes`` regardless).
      eps: additive error parameter shared across the batch.
      sizes: optional host (B, 2) int array of true instance shapes - also
        sets the per-instance theta to the unbatched default 4*max(m,n)/eps.
      theta: optional scalar or (B,) override of the mass scaling.

    Returns an OTResult whose every leaf carries a leading batch axis.
    """
    if guaranteed:
        eps = eps / 3.0
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    th = _theta_array(m_valid, n_valid, eps, theta)
    c, nu, mu, thr = _mask_ot_inputs(c, nu, mu, m_valid, n_valid, th, eps)
    return _solve_ot_batched(c, nu, mu, jnp.asarray(th), jnp.asarray(thr),
                             eps)


# --------------------------------------------------------------------------
# Ragged front end: bucket, pad, dispatch, unpad
# --------------------------------------------------------------------------

class _Bucketed(NamedTuple):
    key: tuple            # bucket shape key
    indices: list         # original instance positions
    sizes: np.ndarray     # (Bg, 2)


def bucket_instances(shapes, buckets: Sequence[int] = DEFAULT_BUCKETS):
    """Group instance shapes [(m_i, n_i)] into shape buckets.

    Returns a list of _Bucketed groups; every instance appears in exactly
    one group and ``key = (M, N)`` is the padded dispatch shape. Shapes
    larger than the biggest bucket get ceil-pow2 minted buckets (see
    ``next_bucket``)."""
    groups: dict = {}
    for i, (mi, ni) in enumerate(shapes):
        key = (next_bucket(int(mi), buckets), next_bucket(int(ni), buckets))
        groups.setdefault(key, []).append(i)
    out = []
    for key, idx in sorted(groups.items()):
        sizes = np.asarray([shapes[i] for i in idx], np.int32)
        out.append(_Bucketed(key=key, indices=idx, sizes=sizes))
    return out


def pad_stack(arrays, shape) -> jnp.ndarray:
    """Zero-pad each array up to ``shape`` and stack on a new batch axis."""
    out = []
    for a in arrays:
        a = np.asarray(a, np.float32)
        pad = [(0, s - d) for s, d in zip(shape, a.shape)]
        out.append(np.pad(a, pad))
    return jnp.asarray(np.stack(out))


def _ragged_policy(compact: bool, chunk, mesh, buckets, guaranteed: bool):
    """Map the legacy ragged keyword surface onto a DispatchPolicy."""
    from .api import DispatchPolicy

    return DispatchPolicy.from_legacy(compact, mesh, chunk=chunk,
                                      buckets=buckets,
                                      guaranteed=guaranteed)


def solve_ot_ragged(
    instances,
    eps,
    *,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    guaranteed: bool = False,
    compact: bool = True,
    chunk: int | None = None,
    mesh=None,
):
    """Solve a ragged list of ``(c, nu, mu)`` OT instances via bucketed
    batched dispatch. Returns per-instance dicts (in input order) with the
    unpadded plan and scalar diagnostics.

    ``compact=True`` (default) routes each bucket through the convergence-
    compacting driver (core/compaction.py): converged instances retire
    between k-phase dispatches instead of riding lockstep until the slowest
    one finishes, and ``eps`` may be a per-instance sequence. ``compact=
    False`` restores the PR-1 lockstep dispatch (results are identical;
    mixed-eps sets are sub-grouped by eps value per bucket). Tradeoff:
    compaction wins on convergence-skewed buckets (2-4x on the in-repo
    bench) but its per-chunk converged-mask sync can lose ~20-50% on tiny
    or convergence-uniform buckets — pass ``compact=False`` there.

    ``mesh`` (a 1-D batch mesh, see ``launch.mesh.make_batch_mesh``)
    dispatches each bucket through the mesh-distributed compacting driver
    (core/distributed.py) — same results, batch axis sharded across
    devices. Requires ``compact=True``.

    Thin wrapper over ``core/api.solve(OT, ...)``."""
    from .api import solve
    from .problem import OT

    return solve(OT, instances, eps,
                 _ragged_policy(compact, chunk, mesh, buckets, guaranteed))


def solve_assignment_ragged(
    cs,
    eps,
    *,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    guaranteed: bool = False,
    compact: bool = True,
    chunk: int | None = None,
    mesh=None,
):
    """Solve a ragged list of assignment cost matrices via bucketed batched
    dispatch. Returns per-instance dicts (in input order). ``compact`` and
    ``mesh`` as in ``solve_ot_ragged``. Thin wrapper over
    ``core/api.solve(ASSIGNMENT, ...)``."""
    from .api import solve
    from .problem import ASSIGNMENT

    return solve(ASSIGNMENT, cs, eps,
                 _ragged_policy(compact, chunk, mesh, buckets, guaranteed))
