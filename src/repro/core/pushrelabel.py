"""Push-relabel additive epsilon-approximation for the assignment problem.

Implements Section 2.2 of Lahn-Raghvendra-Zhang (2022) exactly, in integer
units of eps so that feasibility/admissibility tests are exact:

    c_int      = floor(c / eps)            (costs scaled to [0, 1] first)
    admissible = y_b + y_a == c_int + 1    (relaxed feasibility (2) is tight)
    matched    = y_b + y_a == c_int        (feasibility (3))

Each phase: (I) greedy maximal matching M' on the admissible subgraph touching
the free supply set B' (parallel propose/accept, see matching.py); (II) push:
add M' to M, displacing conflicting old edges; (III) relabel: y_a -= 1 for
columns matched in M', y_b += 1 for rows of B' still free.

The algorithm terminates when |B'| <= eps * |B| and arbitrarily completes the
matching. Total additive error <= 3 * eps * n (rounding + completion +
eps-feasibility), per the paper's analysis; `guaranteed=True` runs with eps/3.

The full solve - phases, rounds, completion - is one jitted XLA program with
``lax.while_loop``; there is no host round-trip per phase (the paper's CuPy
implementation synchronizes every phase).

The solve is also exposed as a *resumable stepped core* for the compacting
batch driver (core/compaction.py):

    state = init_assignment_state(m, n)
    while not assignment_converged(state, threshold, phase_cap):
        state = run_assignment_phases(c_int, state, threshold, phase_cap, k)

``run_phases`` advances at most ``k`` phases of the identical phase body, so
a solve becomes a sequence of fixed-size dispatches whose state trajectory is
bit-identical to the one-shot ``solve_assignment_int`` for every ``k``.
``assignment_prologue`` / ``assignment_epilogue`` factor the float scaling
and the completion/cost steps of ``assignment_pipeline`` the same way; both
accept ``eps`` as a Python float or a traced f32 scalar (the compaction
driver vmaps them with a per-instance eps for mixed-accuracy batches).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .matching import greedy_maximal_matching


class PushRelabelState(NamedTuple):
    match_ba: jnp.ndarray  # (m,) int32 partner col of each row, -1 if free
    match_ab: jnp.ndarray  # (n,) int32 partner row of each col, -1 if free
    y_b: jnp.ndarray       # (m,) int32 supply duals (units of eps)
    y_a: jnp.ndarray       # (n,) int32 demand duals (units of eps)
    phases: jnp.ndarray    # () int32
    rounds: jnp.ndarray    # () int32 cumulative propose/accept rounds
    sum_ni: jnp.ndarray    # () int32 sum of |B'| over phases (eq. 4 check)


class AssignmentResult(NamedTuple):
    matching: jnp.ndarray   # (m,) int32 col assigned to each row
    cost: jnp.ndarray       # () float32 cost under the *original* costs
    y_b: jnp.ndarray        # (m,) float32 scaled dual weights
    y_a: jnp.ndarray        # (n,) float32 scaled dual weights
    phases: jnp.ndarray
    rounds: jnp.ndarray
    sum_ni: jnp.ndarray
    matched_before_completion: jnp.ndarray  # () int32


def _max_phases(eps: float, m: int) -> int:
    """Upper bound on phase count: t <= (1+2e)/e^2 when e*m >= 1, else each
    phase matches >= 1 row so t <= m*(1+2e)/e (sum n_i bound with n_i >= 1)."""
    if eps * m >= 1.0:
        return int((1.0 + 2.0 * eps) / (eps * eps)) + 4
    return int(m * (1.0 + 2.0 * eps) / eps) + 4


def round_costs(c: jnp.ndarray, eps: float) -> jnp.ndarray:
    """floor(c/eps) on costs pre-scaled to [0, 1]."""
    return jnp.floor(c / eps).astype(jnp.int32)


def init_assignment_state(m: int, n: int) -> PushRelabelState:
    """Paper initialization: everything free, y(b) = eps (1 unit), y(a) = 0."""
    return PushRelabelState(
        match_ba=jnp.full((m,), -1, jnp.int32),
        match_ab=jnp.full((n,), -1, jnp.int32),
        y_b=jnp.ones((m,), jnp.int32),   # y(b) = eps  -> 1 unit
        y_a=jnp.zeros((n,), jnp.int32),  # y(a) = 0
        phases=jnp.int32(0),
        rounds=jnp.int32(0),
        sum_ni=jnp.int32(0),
    )


def _row_mask(m: int, m_valid) -> jnp.ndarray:
    if m_valid is None:
        return jnp.ones((m,), bool)
    return jnp.arange(m, dtype=jnp.int32) < m_valid


def assignment_phase(c_int, s: PushRelabelState, row_ok, propose_fn=None
                     ) -> PushRelabelState:
    """One full phase: (I) greedy maximal matching M' on the admissible
    subgraph, (II) push, (III) relabel. This is the single state-transition
    shared by the one-shot loop and the chunked ``run_assignment_phases``."""
    m, n = c_int.shape
    in_bprime = (s.match_ba < 0) & row_ok
    mm = greedy_maximal_matching(
        c_int, s.y_b, s.y_a, in_bprime, s.phases, propose_fn=propose_fn
    )
    rows = jnp.arange(m, dtype=jnp.int32)
    won = mm.mprime_b >= 0
    tgt = jnp.where(won, mm.mprime_b, 0)
    # (II) push: displace old partner of each column matched in M'.
    old_partner = jnp.where(won, s.match_ab[tgt], -1)
    displaced = jnp.where(old_partner >= 0, old_partner, m)  # sentinel m
    match_ba = s.match_ba.at[displaced].set(-1, mode="drop")
    match_ba = jnp.where(won, mm.mprime_b, match_ba)
    match_ab = s.match_ab.at[jnp.where(won, tgt, n)].set(rows, mode="drop")
    # (III) relabel.
    y_a = s.y_a.at[jnp.where(won, tgt, n)].add(-1, mode="drop")
    still_free = in_bprime & ~won
    y_b = s.y_b + still_free.astype(jnp.int32)
    return PushRelabelState(
        match_ba=match_ba,
        match_ab=match_ab,
        y_b=y_b,
        y_a=y_a,
        phases=s.phases + 1,
        rounds=s.rounds + mm.rounds,
        sum_ni=s.sum_ni + jnp.sum(in_bprime, dtype=jnp.int32),
    )


@partial(jax.jit, static_argnames=("eps", "propose_fn", "track_stats"))
def solve_assignment_int(
    c_int: jnp.ndarray,
    eps: float,
    propose_fn=None,
    track_stats: bool = True,
    m_valid=None,
    threshold=None,
) -> PushRelabelState:
    """Run phases on integer costs until |B'| <= eps*m. No completion.

    ``m_valid`` (optional traced () int32) restricts B' and the termination
    count to the first ``m_valid`` rows — used by the batched solver, where
    instances are padded to a bucket shape and padded rows must never enter
    the free-supply set. Padded *columns* are excluded by the caller giving
    them a cost no dual sum can reach (see assignment_pipeline). ``threshold``
    (traced () int32) must accompany ``m_valid``: the caller computes
    int(eps * m_valid) on the host in float64, exactly as the unbatched
    default below, so batched and unbatched solves terminate identically
    (f32(eps) * m_valid rounds the wrong way for some (eps, m) pairs)."""
    m, n = c_int.shape
    if m_valid is None:
        threshold = jnp.int32(int(eps * m))
    elif threshold is None:
        raise ValueError("m_valid requires a host-computed threshold")
    else:
        threshold = jnp.asarray(threshold, jnp.int32)
    row_ok = _row_mask(m, m_valid)
    max_phases = _max_phases(eps, m)

    def cond(s: PushRelabelState):
        free = jnp.sum((s.match_ba < 0) & row_ok)
        return (free > threshold) & (s.phases < jnp.int32(max_phases))

    def body(s: PushRelabelState) -> PushRelabelState:
        return assignment_phase(c_int, s, row_ok, propose_fn)

    return jax.lax.while_loop(cond, body, init_assignment_state(m, n))


@partial(jax.jit, static_argnames=("k", "propose_fn"), donate_argnums=(1,))
def run_assignment_phases(
    c_int: jnp.ndarray,
    state: PushRelabelState,
    threshold,
    phase_cap,
    k: int,
    m_valid=None,
    propose_fn=None,
) -> PushRelabelState:
    """Advance the solve by at most ``k`` phases (fewer if it terminates).

    The resumable half of the stepped core: ``threshold`` and ``phase_cap``
    are traced () int32 (host-precomputed, per instance under vmap), ``k`` is
    the static chunk size. Chaining calls for any ``k`` reproduces the
    one-shot ``solve_assignment_int`` trajectory bit for bit, because the
    phase body is the identical ``assignment_phase`` and the termination
    predicate is evaluated on the same state.

    ``state`` is DONATED: the output state reuses the input buffers, so a
    chunked solve holds one copy of the solver state, not two. Callers must
    rebind (``state = run_assignment_phases(..., state, ...)``) and never
    touch the old reference afterwards."""
    m, n = c_int.shape
    row_ok = _row_mask(m, m_valid)
    threshold = jnp.asarray(threshold, jnp.int32)
    phase_cap = jnp.asarray(phase_cap, jnp.int32)
    start = state.phases

    def cond(s: PushRelabelState):
        free = jnp.sum((s.match_ba < 0) & row_ok)
        return ((free > threshold) & (s.phases < phase_cap)
                & (s.phases - start < jnp.int32(k)))

    def body(s: PushRelabelState) -> PushRelabelState:
        return assignment_phase(c_int, s, row_ok, propose_fn)

    return jax.lax.while_loop(cond, body, state)


def assignment_converged(state: PushRelabelState, threshold, phase_cap,
                         m_valid=None) -> jnp.ndarray:
    """() bool: the solve loop would not take another phase (free-supply
    target reached, or the phase-cap safety bound hit)."""
    row_ok = _row_mask(state.match_ba.shape[0], m_valid)
    free = jnp.sum((state.match_ba < 0) & row_ok)
    return ~((free > jnp.asarray(threshold, jnp.int32))
             & (state.phases < jnp.asarray(phase_cap, jnp.int32)))


def complete_matching(match_ba: jnp.ndarray, match_ab: jnp.ndarray,
                      valid_b: jnp.ndarray | None = None,
                      valid_a: jnp.ndarray | None = None):
    """Arbitrarily match remaining free rows to free cols (rank-align).

    Costs are <= 1 after scaling, so this adds <= eps*n to the cost.
    Rows beyond the number of free columns (unbalanced case) stay -1.
    ``valid_b``/``valid_a`` (optional bool masks) exclude padded rows/cols
    of a bucketed batch instance from the completion; invalid rows stay -1.
    """
    m = match_ba.shape[0]
    n = match_ab.shape[0]
    free_b = match_ba < 0
    free_a = match_ab < 0
    if valid_b is not None:
        free_b = free_b & valid_b
    if valid_a is not None:
        free_a = free_a & valid_a
    # rank of each free row among free rows / each free col among free cols
    rank_b = jnp.cumsum(free_b.astype(jnp.int32)) - 1
    rank_a = jnp.cumsum(free_a.astype(jnp.int32)) - 1
    n_free_a = jnp.sum(free_a, dtype=jnp.int32)
    # col index holding free-rank r
    free_cols = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(free_a, rank_a, n)
    ].set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    take = free_b & (rank_b < n_free_a)
    fill = jnp.where(take, free_cols[jnp.clip(rank_b, 0, n - 1)], -1)
    return jnp.where(free_b, fill, match_ba)


# Sentinel cost for padded columns/rows in a bucketed batch instance.
# Duals satisfy y_b + y_a <= max_phases + c_max << 2^26, so admissibility
# (y_b + y_a == c + 1) can never hold on a padded edge.
PAD_COST = 1 << 26


def assignment_prologue(c: jnp.ndarray, eps, m_valid=None, n_valid=None):
    """Scaling + rounding half of the pipeline, shared by the one-shot solve
    and the chunked/compacting drivers. ``eps`` may be a Python float or a
    traced f32 scalar (per-instance eps under vmap — f32(eps) division is
    bit-identical to the static-eps division). Returns
    ``(cm, c_int, scale, row_ok, col_ok)``; ``cm`` is the padding-masked
    float cost matrix the epilogue prices the final matching against."""
    c = jnp.asarray(c, jnp.float32)
    m, n = c.shape
    if m_valid is None:
        row_ok = col_ok = None
        cm = c
    else:
        row_ok = jnp.arange(m, dtype=jnp.int32) < m_valid
        col_ok = jnp.arange(n, dtype=jnp.int32) < n_valid
        mask = row_ok[:, None] & col_ok[None, :]
        cm = jnp.where(mask, c, 0.0)
    scale = jnp.maximum(jnp.max(cm), 1e-30)
    c_int = round_costs(cm / scale, eps)
    if m_valid is not None:
        c_int = jnp.where(mask, c_int, PAD_COST)
    return cm, c_int, scale, row_ok, col_ok


def assignment_epilogue(cm: jnp.ndarray, scale, state: PushRelabelState,
                        eps, row_ok=None, col_ok=None) -> AssignmentResult:
    """Completion + cost/dual half of the pipeline, applied to a terminated
    integer state. The compacting driver runs this once, in bulk, over the
    full batch of retired states."""
    m, n = cm.shape
    matched_before = jnp.sum(state.match_ba >= 0, dtype=jnp.int32)
    matching = complete_matching(state.match_ba, state.match_ab,
                                 row_ok, col_ok)
    rows = jnp.arange(m)
    valid = matching >= 0
    cost = jnp.sum(
        jnp.where(valid, cm[rows, jnp.clip(matching, 0, n - 1)], 0.0)
    )
    return AssignmentResult(
        matching=matching,
        cost=cost,
        y_b=state.y_b.astype(jnp.float32) * eps * scale,
        y_a=state.y_a.astype(jnp.float32) * eps * scale,
        phases=state.phases,
        rounds=state.rounds,
        sum_ni=state.sum_ni,
        matched_before_completion=matched_before,
    )


def assignment_pipeline(
    c: jnp.ndarray,
    eps: float,
    *,
    m_valid=None,
    n_valid=None,
    threshold=None,
    propose_fn=None,
) -> AssignmentResult:
    """Traceable solve pipeline: scaling -> rounding -> integer phases ->
    completion -> cost/duals. The batched solver vmaps this function with
    traced ``m_valid``/``n_valid``/``threshold`` (instances padded up to a
    bucket shape: padded edges get ``PAD_COST``, padded rows leave B', and
    the completion skips padding), which makes each padded solve identical
    to its unpadded original."""
    cm, c_int, scale, row_ok, col_ok = assignment_prologue(
        c, eps, m_valid, n_valid
    )
    state = solve_assignment_int(c_int, eps, propose_fn=propose_fn,
                                 m_valid=m_valid, threshold=threshold)
    return assignment_epilogue(cm, scale, state, eps, row_ok, col_ok)


def solve_assignment(
    c: jnp.ndarray,
    eps: float,
    *,
    guaranteed: bool = False,
    propose_fn=None,
) -> AssignmentResult:
    """Additive-approximation assignment on float costs.

    Args:
      c: (m, n) nonnegative float costs, m <= n (supplies = rows).
      eps: additive error parameter. The literal paper algorithm yields cost
        <= OPT + 3*eps*m (after internal rescaling of costs to [0,1]);
        pass ``guaranteed=True`` to run at eps/3 and get <= OPT + eps*m.
    Returns an AssignmentResult; ``matching[i]`` is the column of row i.
    """
    if guaranteed:
        eps = eps / 3.0
    return assignment_pipeline(c, eps, propose_fn=propose_fn)


# --------------------------------------------------------------------------
# Static-audit registration (repro.analysis): the stepped core is a solver
# entry point — the chunk dispatch donates its state and its termination
# operands must stay traced data (never baked constants).
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_assignment_chunk():
    m = n = 8
    return _audit.trace_entry(
        name="core.pushrelabel.run_assignment_phases",
        fn=lambda c_int, state, threshold, phase_cap, m_valid:
            run_assignment_phases(c_int, state, threshold, phase_cap, 4,
                                  m_valid=m_valid),
        args={
            "c_int": jnp.zeros((m, n), jnp.int32),
            "state": init_assignment_state(m, n),
            "threshold": jnp.int32(0),
            "phase_cap": jnp.int32(8),
            "m_valid": jnp.int32(m),
        },
        donated={"state"},
        must_trace={"threshold", "phase_cap", "m_valid"},
        tags={"stepped-core", "assignment"},
        source=__name__,
    )


_audit.register("core.pushrelabel.run_assignment_phases",
                _trace_assignment_chunk, source=__name__)
