"""Mesh-distributed convergence-compacting batch dispatch, generic over a
:class:`~repro.core.problem.ProblemSpec`.

The paper's bound is *parallel* time O(log n / eps^2); PR 1/2 exploited it
within one device (vmapped batches, compacting phase dispatch) while
core/sharded.py exploited it across devices for ONE instance (row/col
matrix sharding). This module unifies the two: a fleet of instances is
sharded along the BATCH axis of a 1-D device mesh, each k-phase dispatch
runs the spec's resumable stepped core under ``shard_map`` with every
operand placed ``NamedSharding(P(batch_axis))``, and the compacting driver
retires converged instances across the global batch between dispatches.
Each device runs its own vmapped phase loop over its local lanes — no
cross-device traffic inside a dispatch, so per-device lockstep waste is
bounded by the LOCAL max phase count, not the global one.

Like core/compaction.py, the driver exists ONCE: ``solve_mesh(spec, ...)``
and the generic matrix-placement loop are problem-agnostic; the public
``solve_assignment_distributed`` / ``solve_ot_distributed`` entry points
are thin spec bindings with their original signatures.

Device-put / re-bucketing policy (the distributed analogue of the
power-of-two bucket descent in core/compaction.py):

  * the dispatched batch starts at ``max(pow2_at_least(B), D)`` where
    ``D`` is the (power-of-two) device count along the batch axis, so the
    batch axis is always divisible by the mesh;
  * between dispatches the (B,) converged mask is fetched with one global
    gather; when occupancy has halved, ALL lanes are flushed into the
    full-size sharded result buffer and the survivors are gathered and
    EXPLICITLY ``device_put`` onto the next power-of-two bucket's
    ``NamedSharding(P(batch_axis))`` — re-bucketing is a host-driven
    re-shard, never an implicit layout change;
  * once the next bucket would drop below the device count
    (``pow2_at_least(live) < D``), the surviving lanes are collapsed onto
    a single device (replicated single-device dispatch) and the remaining
    descent continues exactly as the single-device compacting driver —
    a 2-lane tail is latency-bound, not throughput-bound, and spreading
    it over the mesh would only add dispatch overhead;
  * batches smaller than the mesh floor to begin with skip the mesh
    entirely and run the single-device driver.

A placement policy (``choose_placement``) picks per bucket between this
batch-axis sharding (many small instances) and the row/col MATRIX sharding
of core/sharded.py (few large instances, where batch sharding would leave
most of the mesh idle).

Under batch placement, per-lane results are BIT-IDENTICAL to the
single-device compacting driver (and hence to lockstep batched and
unbatched solves): shard_map lanes never interact, the proposal hash keys
depend only on the within-instance (row, col, phase), and
retirement/re-sharding of a neighbor cannot perturb a survivor. ``eps``
may be a per-instance (B,) array, as in the compacting driver. Under
matrix placement each instance solves at its own mesh-divisible padded
shape, so the INTEGER state (matching, duals, flows, phase counts) is
bit-identical but the float epilogue (plan/cost sums) may differ from the
batch-placement value by reassociation ulps (~1e-9 relative) — the same
caveat as any shape change of an XLA float reduction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compaction import (
    DEFAULT_CHUNK,
    CompactionStats,
    _gather,
    max_chunk_dispatches,
    solve_compacting,
    spec_fns,
)
from .problem import (
    ASSIGNMENT,
    OT,
    _sizes_arrays,
    eps_array,
    pow2_at_least,
)
from ..compat import shard_map as _shard_map
from ..obs.metrics import now as _now


@dataclass
class DistributedStats(CompactionStats):
    """CompactionStats plus mesh/placement accounting.

    ``slot_phases`` counts PER-DEVICE lockstep slots (each device's local
    vmapped loop runs its local lanes for the local max phase delta), so
    it is directly comparable with the single-device driver's number —
    the difference is the waste sharding itself removes."""
    devices: int = 1
    batch_axis: str = "data"
    placement: str = "batch"
    collapsed_at: Optional[int] = None      # bucket size at 1-device collapse
    devices_per_dispatch: List[int] = field(default_factory=list)

    def as_dict(self) -> dict:
        d = super().as_dict()
        d.update({
            "devices": self.devices,
            "batch_axis": self.batch_axis,
            "placement": self.placement,
            "collapsed_at": self.collapsed_at,
            "devices_per_dispatch": list(self.devices_per_dispatch),
        })
        return d


def choose_placement(b: int, m: int, n: int, n_devices: int,
                     *, matrix_min_size: int = 128) -> str:
    """Placement policy for one bucket: ``"batch"`` (shard the batch axis)
    vs ``"matrix"`` (row/col-shard each cost matrix, core/sharded.py).

    Batch sharding wins whenever there are enough instances to occupy the
    mesh (b >= devices) or the instances are too small for per-matrix
    collectives to pay off; matrix sharding wins for a few large
    instances, where batch sharding would leave most devices idle."""
    if n_devices <= 1 or b >= n_devices:
        return "batch"
    if min(m, n) >= matrix_min_size:
        return "matrix"
    return "batch"


def _require_pow2(d: int) -> None:
    if d & (d - 1):
        raise ValueError(
            f"batch-axis device count must be a power of two (got {d}); "
            "build the mesh with launch.mesh.make_batch_mesh"
        )


@lru_cache(maxsize=None)
def _matrix_mesh(mesh: Mesh) -> Tuple[Mesh, str, str]:
    """(mesh, row_axis, col_axis) for matrix placement: reuse a 2-D mesh's
    leading axes, or fold a 1-D batch mesh into the squarest (r, c) grid."""
    if len(mesh.axis_names) >= 2:
        return mesh, mesh.axis_names[0], mesh.axis_names[1]
    from ..launch.mesh import _make_mesh

    devs = list(mesh.devices.flat)
    d = len(devs)
    r = 1
    while r * 2 * r * 2 <= d:
        r *= 2
    return _make_mesh((r, d // r), ("data", "model"), devs), "data", "model"


# --------------------------------------------------------------------------
# shard_map-wrapped stepped core (one cache entry per (spec, mesh, axis, k))
# --------------------------------------------------------------------------

def _wrap(mesh: Mesh, axis: str, fn, donate=()):
    spec = P(axis)
    return jax.jit(
        _shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec),
        donate_argnums=donate,
    )


@lru_cache(maxsize=None)
def _mesh_fns(spec, mesh: Mesh, axis: str, k: int):
    """(prologue, init, chunk, conv, epilogue): the spec's per-instance
    stepped-core functions vmapped over the local batch shard and
    shard_map'ed over the mesh. Every operand/result is placed
    ``NamedSharding(P(axis))``; the chunk dispatch donates the state."""
    prologue = _wrap(mesh, axis, lambda ops: jax.vmap(spec.prologue)(ops))
    init = jax.jit(
        lambda data, ctx: jax.vmap(spec.init_state)(data, ctx),
        out_shardings=NamedSharding(mesh, P(axis)),
    )
    chunk = _wrap(
        mesh, axis,
        lambda data, state: jax.vmap(
            lambda d, s: spec.run_phases(d, s, k))(data, state),
        donate=(1,),
    )
    conv = _wrap(mesh, axis,
                 lambda data, state: (jax.vmap(spec.converged)(data, state),
                                      state.phases))
    epilogue = _wrap(mesh, axis,
                     lambda ctx, state: jax.vmap(spec.epilogue)(ctx, state))
    return prologue, init, chunk, conv, epilogue


@lru_cache(maxsize=None)
def _scatter_to(sh):
    """Scatter ``tree`` into ``buf`` at rows ``idx`` with the result pinned
    to ``sh`` (the full-size buffer keeps its batch sharding even when the
    incoming lanes live on a single collapsed device)."""
    return jax.jit(
        lambda buf, tree, idx: jax.tree_util.tree_map(
            lambda b, a: b.at[idx].set(a), buf, tree
        ),
        out_shardings=sh,
    )


def _put(tree, target):
    return jax.tree_util.tree_map(lambda a: jax.device_put(a, target), tree)


# --------------------------------------------------------------------------
# The distributed compacting drive
# --------------------------------------------------------------------------

def _drive_distributed(data, state, run_s, conv_s, run_1, conv_1,
                       max_chunks: int, stats: DistributedStats,
                       mesh: Mesh, axis: str,
                       deadline: Optional[float] = None, obs=None):
    """Mesh counterpart of compaction._drive. ``data``/``state`` arrive
    device_put onto ``NamedSharding(mesh, P(axis))``; ``run_s``/``conv_s``
    are the shard_map'ed chunk/converged dispatches and ``run_1``/``conv_1``
    the single-device ones used after the collapse. Chunk dispatches donate
    the state buffers (one copy of solver state per bucket, not two).
    ``deadline`` is an absolute monotonic (``repro.obs.now``) budget with
    the same best-so-far cut semantics as compaction._drive. ``obs`` is
    the same optional per-chunk event emitter as compaction._drive (the
    ``"chunk"`` events additionally carry the device count this dispatch
    ran on); events are host scalars only — no extra device syncs."""
    d0 = int(mesh.shape[axis])
    cache_fns = ({id(run_s): getattr(run_s, "_cache_size", None),
                  id(run_1): getattr(run_1, "_cache_size", None)}
                 if obs is not None else {})
    cache_prev = {k: (f() if f is not None else 0)
                  for k, f in cache_fns.items()}
    sh = NamedSharding(mesh, P(axis))
    sh_rep = NamedSharding(mesh, P())
    dev0 = next(iter(mesh.devices.flat))
    idx = np.arange(stats.dispatched_batch)
    buf = None          # born at the first flush (state is donated; see
                        # compaction._drive for the aliasing argument)
    cur_d, cur_s = data, state
    sharded = d0 > 1

    def flush(buf, tree, idx, sharded):
        if buf is None:
            # first flush: idx is still the identity, buf IS the state
            return tree
        if not sharded:
            # collapsed lanes live on one device; replicate them onto the
            # mesh so the scatter into the still-sharded buffer is one
            # mesh-wide program
            tree = _put(tree, sh_rep)
        return _scatter_to(sh)(buf, tree, jnp.asarray(idx))

    ph_prev = np.zeros((stats.dispatched_batch,), np.int64)
    for _ in range(max_chunks):
        t_chunk = _now()
        run_fn = run_s if sharded else run_1
        cur_s = run_fn(cur_d, cur_s)
        stats.dispatches += 1
        # global converged-mask + phase-counter gather: ONE (B,)
        # device->host sync per chunk (conv bundles both outputs, so the
        # phase counters don't cost a second blocking fetch — the
        # repro.analysis hot-loop sync audit pins this)
        conv, ph = jax.device_get((conv_s if sharded else conv_1)(cur_d,
                                                                  cur_s))
        t_chunk = _now() - t_chunk
        ph = ph.astype(np.int64)
        bb = int(conv.shape[0])
        d_now = d0 if sharded else 1
        stats.devices_per_dispatch.append(d_now)
        # per-device lockstep accounting: each device's vmapped while_loop
        # runs its local lanes for the LOCAL max phase delta
        per_dev = (ph - ph_prev).reshape(d_now, bb // d_now)
        stats.slot_phases += int(
            (per_dev.max(axis=1) * (bb // d_now)).sum()
        )
        ph_prev = ph
        live = int((~conv).sum())
        stats.occupancy.append((bb, live))
        if obs is not None:
            cf = cache_fns.get(id(run_fn))
            cache_now = cf() if cf is not None else 0
            obs.event("chunk", bucket=bb, live=live, chunk_s=t_chunk,
                      phases=int(per_dev.max(initial=0)),
                      devices=d_now,
                      compiled=cache_now - cache_prev.get(id(run_fn), 0))
            cache_prev[id(run_fn)] = cache_now
        if live == 0:
            buf = flush(buf, cur_s, idx, sharded)
            break
        if deadline is not None and _now() + t_chunk >= deadline:
            # earliest deadline at risk: stop dispatching, flush best-so-
            # far state, and mark the unconverged lanes (original batch
            # order) — same cut semantics as compaction._drive
            stats.deadline_hit = True
            un = np.zeros((stats.dispatched_batch,), bool)
            un[idx[~conv]] = True
            stats.unconverged = un
            if obs is not None:
                obs.event("deadline-cut", bucket=bb, live=live)
            buf = flush(buf, cur_s, idx, sharded)
            break
        nb = pow2_at_least(live)
        if nb <= bb // 2:
            # flush ALL lanes (fixed-length scatter; see compaction._drive),
            # then gather survivors + one inert converged filler lane and
            # re-bucket under the explicit device-put policy.
            buf = flush(buf, cur_s, idx, sharded)
            surv = np.flatnonzero(~conv)
            fill = np.flatnonzero(conv)[:1]
            sel = np.concatenate([surv, np.repeat(fill, nb - live)])
            sel_j = jnp.asarray(sel)
            cur_d = _gather(cur_d, sel_j)
            cur_s = _gather(cur_s, sel_j)
            if sharded and nb < d0:
                # below the mesh floor: replicated single-device dispatch
                cur_d = _put(cur_d, dev0)
                cur_s = _put(cur_s, dev0)
                sharded = False
                stats.collapsed_at = nb
            elif sharded:
                # explicit re-shard of the shrunken bucket across the mesh
                cur_d = _put(cur_d, sh)
                cur_s = _put(cur_s, sh)
            idx = idx[sel]
            ph_prev = ph[sel]
    else:
        buf = flush(buf, cur_s, idx, sharded)
    return buf


# --------------------------------------------------------------------------
# The generic distributed entry point
# --------------------------------------------------------------------------

def _resolve_mesh(mesh, batch_axis):
    if mesh is None:
        from ..launch.mesh import make_batch_mesh

        mesh = make_batch_mesh(axis=batch_axis)
    d = int(mesh.shape[batch_axis])
    _require_pow2(d)
    return mesh, d


def solve_mesh(
    spec,
    inputs,
    eps,
    mesh: Mesh | None = None,
    *,
    sizes=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    batch_axis: str = "data",
    placement: str = "auto",
    keep_state: bool = False,
    deadline: Optional[float] = None,
    obs=None,
    **prep_kw,
):
    """Mesh-distributed counterpart of ``compaction.solve_compacting`` —
    same contract (spec + batched input dict, scalar or (B,) eps), same
    bit-identical per-instance results, with the batch axis sharded across
    ``mesh`` (built by ``launch.mesh.make_batch_mesh`` when None).
    ``placement`` is "auto" (``choose_placement``), "batch", or "matrix".
    ``keep_state`` stashes the pre-completion integer state on the stats
    for feasibility certificates (batch placement only — the matrix path's
    epilogue consumes the state, so the combination raises).
    ``deadline`` (absolute monotonic, ``repro.obs.now``) gives the chunk
    loop a wall-clock budget with best-so-far cut semantics (see
    ``solve_compacting``); matrix placement solves instance-by-instance
    with no chunk loop to cut, so it ignores the budget (best-effort).
    ``obs`` threads a per-chunk event emitter into the drive (see
    ``solve_compacting``); matrix placement emits nothing.

    Returns ``(result, DistributedStats)``."""
    inputs = spec.canonicalize(inputs)
    b, m, n = spec.batch_shape(inputs)
    mesh, d = _resolve_mesh(mesh, batch_axis)
    mode = (choose_placement(b, m, n, d) if placement == "auto"
            else placement)
    if mode == "matrix" and b > 0:
        if keep_state and not getattr(spec, "state_on_result", False):
            # the matrix path discards the per-instance integer state
            # (the sharded epilogue consumes it) unless the spec's result
            # carries it (OT does); fail loudly rather than hand back
            # final_state=None
            raise ValueError("keep_state=True requires batch placement "
                             "(pass placement='batch')")
        return _solve_matrix(spec, inputs, eps, mesh, sizes, guaranteed,
                             k, batch_axis, **prep_kw)
    if b == 0 or pow2_at_least(b) < d:
        # below the mesh floor from the start: single-device dispatch
        out, cst = solve_compacting(
            spec, inputs, eps, sizes=sizes, k=k, guaranteed=guaranteed,
            keep_state=keep_state, deadline=deadline, obs=obs, **prep_kw)
        stats = _wrap_stats(cst, d, batch_axis, collapsed_at=cst.
                            dispatched_batch or None)
        return out, stats

    p = spec.prepare(inputs, eps, sizes=sizes, guaranteed=guaranteed,
                     min_batch=d, **prep_kw)
    sh = NamedSharding(mesh, P(batch_axis))
    prologue_s, init_s, chunk_s, conv_s, epilogue_s = _mesh_fns(
        spec, mesh, batch_axis, k)
    _, _, chunk_1, conv_1, _ = spec_fns(spec, k)
    ops = {kk: jax.device_put(jnp.asarray(v), sh)
           for kk, v in p.ops.items()}
    data, ctx = prologue_s(ops)
    # verbatim epilogue operands come straight from the sharded ops (see
    # compaction.solve_compacting for the second-copy argument)
    ctx = {**ctx, **{kk: ops[kk] for kk in spec.ctx_ops}}
    state0 = init_s(data, ctx)
    stats = DistributedStats(batch=b, dispatched_batch=p.bp, chunk=k,
                             devices=d, batch_axis=batch_axis,
                             placement="batch")
    final = _drive_distributed(
        data, state0, chunk_s, conv_s, chunk_1, conv_1,
        max_chunk_dispatches(p.phase_cap, k), stats, mesh, batch_axis,
        deadline=deadline, obs=obs,
    )
    r = epilogue_s(ctx, final)

    phases = np.asarray(final.phases[:b], np.int64)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    if keep_state:
        stats.final_state = jax.tree_util.tree_map(lambda a: a[:b], final)
    return spec.trim(r, b), stats


def _wrap_stats(cst: CompactionStats, devices: int, batch_axis: str,
                collapsed_at=None) -> DistributedStats:
    """Lift a single-device CompactionStats into DistributedStats (used
    when the whole solve ran below the mesh floor)."""
    st = DistributedStats(
        batch=cst.batch, dispatched_batch=cst.dispatched_batch,
        chunk=cst.chunk, dispatches=cst.dispatches,
        occupancy=cst.occupancy, slot_phases=cst.slot_phases,
        phases_needed=cst.phases_needed,
        lockstep_slot_phases=cst.lockstep_slot_phases,
        final_state=cst.final_state,
        deadline_hit=cst.deadline_hit, unconverged=cst.unconverged,
        devices=devices, batch_axis=batch_axis, placement="batch",
        collapsed_at=collapsed_at,
        devices_per_dispatch=[1] * cst.dispatches,
    )
    return st


# --------------------------------------------------------------------------
# Matrix placement: few large instances, row/col sharding per instance
# --------------------------------------------------------------------------

def _solve_matrix(spec, inputs, eps, mesh, sizes, guaranteed, k,
                  batch_axis, **prep_kw):
    """Generic matrix-placement loop: each instance padded up to
    mesh-divisible dims and solved row/col-sharded (core/sharded.py) via
    ``spec.matrix_instance``; ``spec.matrix_stack`` reassembles the
    batched result."""
    b, m, n = spec.batch_shape(inputs)
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    eps_arr = eps_array(eps, b, guaranteed)
    mesh2, row_axis, col_axis = _matrix_mesh(mesh)
    rdiv = int(mesh2.shape[row_axis])
    cdiv = int(mesh2.shape[col_axis])
    host = {kk: np.asarray(v) for kk, v in inputs.items()}
    rows = []
    for i in range(b):
        mi, ni = int(m_valid[i]), int(n_valid[i])
        mp = -(-mi // rdiv) * rdiv
        np_ = -(-ni // cdiv) * cdiv
        rows.append(spec.matrix_instance(
            host, i, mi, ni, mp, np_, float(eps_arr[i]), mesh2,
            row_axis, col_axis, **prep_kw))
    out = spec.matrix_stack(rows, m_valid, n_valid, m, n)
    stats = DistributedStats(
        batch=b, dispatched_batch=b, chunk=k,
        devices=int(np.prod(list(mesh2.shape.values()))),
        batch_axis=batch_axis, placement="matrix", dispatches=b)
    phases = np.asarray(out.phases, np.int64)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    return out, stats


# --------------------------------------------------------------------------
# Spec-binding wrappers (original public entry points, unchanged contracts)
# --------------------------------------------------------------------------

def solve_assignment_distributed(
    c: jnp.ndarray,
    eps,
    mesh: Mesh | None = None,
    *,
    sizes=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    batch_axis: str = "data",
    placement: str = "auto",
    keep_state: bool = False,
):
    """Mesh-distributed counterpart of
    ``solve_assignment_batched_compacting``; binds ``ASSIGNMENT`` to
    :func:`solve_mesh` (see there for the contract). Returns
    ``(BatchedAssignmentResult, DistributedStats)``."""
    return solve_mesh(ASSIGNMENT, {"c": c}, eps, mesh, sizes=sizes, k=k,
                      guaranteed=guaranteed, batch_axis=batch_axis,
                      placement=placement, keep_state=keep_state)


def solve_ot_distributed(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps,
    mesh: Mesh | None = None,
    *,
    sizes=None,
    theta=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    batch_axis: str = "data",
    placement: str = "auto",
):
    """Mesh-distributed counterpart of ``solve_ot_batched_compacting``;
    binds ``OT`` to :func:`solve_mesh` — same contract and bit-identical
    per-instance results. Returns ``(OTResult with leading batch axes,
    DistributedStats)``."""
    return solve_mesh(OT, {"c": c, "nu": nu, "mu": mu}, eps, mesh,
                      sizes=sizes, k=k, guaranteed=guaranteed,
                      batch_axis=batch_axis, placement=placement,
                      theta=theta)


# --------------------------------------------------------------------------
# repro.analysis registration: the shard_map'ed mesh chunk dispatch (the
# program `_drive_distributed` re-issues per bucket while sharded).
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_mesh_chunk(spec_name: str):
    from .compaction import _tiny_batch
    from ..launch.mesh import make_batch_mesh

    spec = ASSIGNMENT if spec_name == "assignment" else OT
    mesh = make_batch_mesh()
    _, _, chunk_s, conv_s, _ = _mesh_fns(spec, mesh, "data", 2)
    _, _, data, state = _tiny_batch(spec_name)
    return _audit.trace_entry(
        name=f"core.distributed.mesh_chunk[{spec_name}]",
        fn=chunk_s,
        args={"data": data, "state": state},
        donated={"state"},
        tags={"mesh-dispatch", spec_name},
        source=__name__,
    )


_audit.register("core.distributed.mesh_chunk[assignment]",
                lambda: _trace_mesh_chunk("assignment"), source=__name__)
_audit.register("core.distributed.mesh_chunk[ot]",
                lambda: _trace_mesh_chunk("ot"), source=__name__)
