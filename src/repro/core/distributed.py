"""Mesh-distributed convergence-compacting batch dispatch.

The paper's bound is *parallel* time O(log n / eps^2); PR 1/2 exploited it
within one device (vmapped batches, compacting phase dispatch) while
core/sharded.py exploited it across devices for ONE instance (row/col
matrix sharding). This module unifies the two: a fleet of instances is
sharded along the BATCH axis of a 1-D device mesh, each k-phase dispatch
runs the resumable stepped cores (``init_* / run_*_phases / *_converged``)
under ``shard_map`` with every operand placed ``NamedSharding(P(batch_
axis))``, and the compacting driver retires converged instances across the
global batch between dispatches. Each device runs its own vmapped phase
loop over its local lanes — no cross-device traffic inside a dispatch, so
per-device lockstep waste is bounded by the LOCAL max phase count, not the
global one.

Device-put / re-bucketing policy (the distributed analogue of the
power-of-two bucket descent in core/compaction.py):

  * the dispatched batch starts at ``max(pow2_at_least(B), D)`` where
    ``D`` is the (power-of-two) device count along the batch axis, so the
    batch axis is always divisible by the mesh;
  * between dispatches the (B,) converged mask is fetched with one global
    gather; when occupancy has halved, ALL lanes are flushed into the
    full-size sharded result buffer and the survivors are gathered and
    EXPLICITLY ``device_put`` onto the next power-of-two bucket's
    ``NamedSharding(P(batch_axis))`` — re-bucketing is a host-driven
    re-shard, never an implicit layout change;
  * once the next bucket would drop below the device count
    (``pow2_at_least(live) < D``), the surviving lanes are collapsed onto
    a single device (replicated single-device dispatch) and the remaining
    descent continues exactly as the single-device compacting driver —
    a 2-lane tail is latency-bound, not throughput-bound, and spreading
    it over the mesh would only add dispatch overhead;
  * batches smaller than the mesh floor to begin with skip the mesh
    entirely and run the single-device driver.

A placement policy (``choose_placement``) picks per bucket between this
batch-axis sharding (many small instances) and the row/col MATRIX sharding
of core/sharded.py (few large instances, where batch sharding would leave
most of the mesh idle): ``solve_assignment_distributed`` /
``solve_ot_distributed`` are the unified entry points over both.

Under batch placement, per-lane results are BIT-IDENTICAL to the
single-device compacting driver (and hence to lockstep batched and
unbatched solves): shard_map lanes never interact, the proposal hash keys
depend only on the within-instance (row, col, phase), and
retirement/re-sharding of a neighbor cannot perturb a survivor. ``eps``
may be a per-instance (B,) array, as in the compacting driver. Under
matrix placement each instance solves at its own mesh-divisible padded
shape, so the INTEGER state (matching, duals, flows, phase counts) is
bit-identical but the float epilogue (plan/cost sums) may differ from the
batch-placement value by reassociation ulps (~1e-9 relative) — the same
caveat as any shape change of an XLA float reduction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .batched import BatchedAssignmentResult, _sizes_arrays
from .compaction import (
    DEFAULT_CHUNK,
    CompactionStats,
    _assign_chunk,
    _assign_conv,
    _eps_array,
    _gather,
    _ot_chunk,
    _ot_conv,
    pow2_at_least,
    prepare_assignment_batch,
    prepare_ot_batch,
)
from .pushrelabel import (
    assignment_converged,
    assignment_epilogue,
    assignment_prologue,
    init_assignment_state,
    run_assignment_phases,
)
from ..compat import shard_map as _shard_map
from .sharded import solve_assignment_sharded, solve_ot_sharded
from .transport import (
    init_ot_state,
    ot_converged,
    ot_epilogue,
    ot_prologue,
    run_ot_phases,
)


@dataclass
class DistributedStats(CompactionStats):
    """CompactionStats plus mesh/placement accounting.

    ``slot_phases`` counts PER-DEVICE lockstep slots (each device's local
    vmapped loop runs its local lanes for the local max phase delta), so
    it is directly comparable with the single-device driver's number —
    the difference is the waste sharding itself removes."""
    devices: int = 1
    batch_axis: str = "data"
    placement: str = "batch"
    collapsed_at: Optional[int] = None      # bucket size at 1-device collapse
    devices_per_dispatch: List[int] = field(default_factory=list)

    def as_dict(self) -> dict:
        d = super().as_dict()
        d.update({
            "devices": self.devices,
            "batch_axis": self.batch_axis,
            "placement": self.placement,
            "collapsed_at": self.collapsed_at,
            "devices_per_dispatch": list(self.devices_per_dispatch),
        })
        return d


def choose_placement(b: int, m: int, n: int, n_devices: int,
                     *, matrix_min_size: int = 128) -> str:
    """Placement policy for one bucket: ``"batch"`` (shard the batch axis)
    vs ``"matrix"`` (row/col-shard each cost matrix, core/sharded.py).

    Batch sharding wins whenever there are enough instances to occupy the
    mesh (b >= devices) or the instances are too small for per-matrix
    collectives to pay off; matrix sharding wins for a few large
    instances, where batch sharding would leave most devices idle."""
    if n_devices <= 1 or b >= n_devices:
        return "batch"
    if min(m, n) >= matrix_min_size:
        return "matrix"
    return "batch"


def _require_pow2(d: int) -> None:
    if d & (d - 1):
        raise ValueError(
            f"batch-axis device count must be a power of two (got {d}); "
            "build the mesh with launch.mesh.make_batch_mesh"
        )


@lru_cache(maxsize=None)
def _matrix_mesh(mesh: Mesh) -> Tuple[Mesh, str, str]:
    """(mesh, row_axis, col_axis) for matrix placement: reuse a 2-D mesh's
    leading axes, or fold a 1-D batch mesh into the squarest (r, c) grid."""
    if len(mesh.axis_names) >= 2:
        return mesh, mesh.axis_names[0], mesh.axis_names[1]
    from ..launch.mesh import _make_mesh

    devs = list(mesh.devices.flat)
    d = len(devs)
    r = 1
    while r * 2 * r * 2 <= d:
        r *= 2
    return _make_mesh((r, d // r), ("data", "model"), devs), "data", "model"


# --------------------------------------------------------------------------
# shard_map-wrapped stepped cores (one cache entry per (mesh, axis, k))
# --------------------------------------------------------------------------

def _wrap(mesh: Mesh, axis: str, fn, donate=()):
    spec = P(axis)
    return jax.jit(
        _shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec),
        donate_argnums=donate,
    )


@lru_cache(maxsize=None)
def _assign_fns(mesh: Mesh, axis: str, k: int):
    def prologue(c, eps, mv, nv):
        return jax.vmap(assignment_prologue)(c, eps, mv, nv)

    def chunk(data, state):
        return jax.vmap(
            lambda d, s: run_assignment_phases(
                d["c_int"], s, d["threshold"], d["phase_cap"], k,
                m_valid=d["m_valid"],
            )
        )(data, state)

    def conv(data, state):
        return jax.vmap(
            lambda d, s: assignment_converged(
                s, d["threshold"], d["phase_cap"], m_valid=d["m_valid"]
            )
        )(data, state)

    def epilogue(cm, scale, state, eps, row_ok, col_ok):
        return jax.vmap(assignment_epilogue)(cm, scale, state, eps,
                                             row_ok, col_ok)

    return (_wrap(mesh, axis, prologue), _wrap(mesh, axis, chunk, (1,)),
            _wrap(mesh, axis, conv), _wrap(mesh, axis, epilogue))


@lru_cache(maxsize=None)
def _assign_init_fn(mesh: Mesh, axis: str, m: int, n: int):
    return jax.jit(jax.vmap(lambda _: init_assignment_state(m, n)),
                   out_shardings=NamedSharding(mesh, P(axis)))


@lru_cache(maxsize=None)
def _ot_fns(mesh: Mesh, axis: str, k: int, max_rounds: int):
    def prologue(c, nu, mu, th, eps):
        return jax.vmap(ot_prologue)(c, nu, mu, th, eps)

    def chunk(data, state):
        return jax.vmap(
            lambda d, s: run_ot_phases(d["c_int"], s, d["threshold"],
                                       d["phase_cap"], k, max_rounds)
        )(data, state)

    def conv(data, state):
        return jax.vmap(
            lambda d, s: ot_converged(s, d["threshold"], d["phase_cap"])
        )(data, state)

    def epilogue(c, nu, mu, th, eps, scale, s_int, d_int, state):
        return jax.vmap(ot_epilogue)(c, nu, mu, th, eps, scale, s_int,
                                     d_int, state)

    return (_wrap(mesh, axis, prologue), _wrap(mesh, axis, chunk, (1,)),
            _wrap(mesh, axis, conv), _wrap(mesh, axis, epilogue))


@lru_cache(maxsize=None)
def _ot_init_fn(mesh: Mesh, axis: str):
    return jax.jit(jax.vmap(init_ot_state),
                   out_shardings=NamedSharding(mesh, P(axis)))


@lru_cache(maxsize=None)
def _scatter_to(sh):
    """Scatter ``tree`` into ``buf`` at rows ``idx`` with the result pinned
    to ``sh`` (the full-size buffer keeps its batch sharding even when the
    incoming lanes live on a single collapsed device)."""
    return jax.jit(
        lambda buf, tree, idx: jax.tree_util.tree_map(
            lambda b, a: b.at[idx].set(a), buf, tree
        ),
        out_shardings=sh,
    )


def _put(tree, target):
    return jax.tree_util.tree_map(lambda a: jax.device_put(a, target), tree)


# --------------------------------------------------------------------------
# The distributed compacting drive
# --------------------------------------------------------------------------

def _drive_distributed(data, state, run_s, conv_s, run_1, conv_1,
                       max_chunks: int, stats: DistributedStats,
                       mesh: Mesh, axis: str):
    """Mesh counterpart of compaction._drive. ``data``/``state`` arrive
    device_put onto ``NamedSharding(mesh, P(axis))``; ``run_s``/``conv_s``
    are the shard_map'ed chunk/converged dispatches and ``run_1``/``conv_1``
    the single-device ones used after the collapse. Chunk dispatches donate
    the state buffers (one copy of solver state per bucket, not two)."""
    d0 = int(mesh.shape[axis])
    sh = NamedSharding(mesh, P(axis))
    sh_rep = NamedSharding(mesh, P())
    dev0 = next(iter(mesh.devices.flat))
    idx = np.arange(stats.dispatched_batch)
    buf = None          # born at the first flush (state is donated; see
                        # compaction._drive for the aliasing argument)
    cur_d, cur_s = data, state
    sharded = d0 > 1

    def flush(buf, tree, idx, sharded):
        if buf is None:
            # first flush: idx is still the identity, buf IS the state
            return tree
        if not sharded:
            # collapsed lanes live on one device; replicate them onto the
            # mesh so the scatter into the still-sharded buffer is one
            # mesh-wide program
            tree = _put(tree, sh_rep)
        return _scatter_to(sh)(buf, tree, jnp.asarray(idx))

    ph_prev = np.zeros((stats.dispatched_batch,), np.int64)
    for _ in range(max_chunks):
        cur_s = (run_s if sharded else run_1)(cur_d, cur_s)
        stats.dispatches += 1
        # global converged-mask gather: ONE (B,) device->host sync per chunk
        conv = np.asarray((conv_s if sharded else conv_1)(cur_d, cur_s))
        ph = np.asarray(cur_s.phases, np.int64)
        bb = int(conv.shape[0])
        d_now = d0 if sharded else 1
        stats.devices_per_dispatch.append(d_now)
        # per-device lockstep accounting: each device's vmapped while_loop
        # runs its local lanes for the LOCAL max phase delta
        per_dev = (ph - ph_prev).reshape(d_now, bb // d_now)
        stats.slot_phases += int(
            (per_dev.max(axis=1) * (bb // d_now)).sum()
        )
        ph_prev = ph
        live = int((~conv).sum())
        stats.occupancy.append((bb, live))
        if live == 0:
            buf = flush(buf, cur_s, idx, sharded)
            break
        nb = pow2_at_least(live)
        if nb <= bb // 2:
            # flush ALL lanes (fixed-length scatter; see compaction._drive),
            # then gather survivors + one inert converged filler lane and
            # re-bucket under the explicit device-put policy.
            buf = flush(buf, cur_s, idx, sharded)
            surv = np.flatnonzero(~conv)
            fill = np.flatnonzero(conv)[:1]
            sel = np.concatenate([surv, np.repeat(fill, nb - live)])
            sel_j = jnp.asarray(sel)
            cur_d = _gather(cur_d, sel_j)
            cur_s = _gather(cur_s, sel_j)
            if sharded and nb < d0:
                # below the mesh floor: replicated single-device dispatch
                cur_d = _put(cur_d, dev0)
                cur_s = _put(cur_s, dev0)
                sharded = False
                stats.collapsed_at = nb
            elif sharded:
                # explicit re-shard of the shrunken bucket across the mesh
                cur_d = _put(cur_d, sh)
                cur_s = _put(cur_s, sh)
            idx = idx[sel]
            ph_prev = ph[sel]
    else:
        buf = flush(buf, cur_s, idx, sharded)
    return buf


# --------------------------------------------------------------------------
# Unified entry points
# --------------------------------------------------------------------------

def _resolve_mesh(mesh, batch_axis):
    if mesh is None:
        from ..launch.mesh import make_batch_mesh

        mesh = make_batch_mesh(axis=batch_axis)
    d = int(mesh.shape[batch_axis])
    _require_pow2(d)
    return mesh, d


def solve_assignment_distributed(
    c: jnp.ndarray,
    eps,
    mesh: Mesh | None = None,
    *,
    sizes=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    batch_axis: str = "data",
    placement: str = "auto",
    keep_state: bool = False,
):
    """Mesh-distributed counterpart of
    ``solve_assignment_batched_compacting`` — same contract ((B, M, N)
    padded costs, scalar or (B,) eps), same bit-identical per-instance
    results, with the batch axis sharded across ``mesh`` (built by
    ``launch.mesh.make_batch_mesh`` when None). ``placement`` is "auto"
    (``choose_placement``), "batch", or "matrix". ``keep_state`` stashes
    the pre-completion integer state on the stats for feasibility
    certificates (batch placement only — the matrix path's epilogue
    consumes the state, so the combination raises).

    Returns ``(BatchedAssignmentResult, DistributedStats)``."""
    c = jnp.asarray(c, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    mesh, d = _resolve_mesh(mesh, batch_axis)
    mode = (choose_placement(b, m, n, d) if placement == "auto"
            else placement)
    if mode == "matrix" and b > 0:
        if keep_state:
            # the matrix path discards the per-instance integer state
            # (solve_assignment_sharded's epilogue consumes it); fail
            # loudly rather than hand back final_state=None
            raise ValueError("keep_state=True requires batch placement "
                             "(pass placement='batch')")
        return _solve_assignment_matrix(c, eps, mesh, sizes, guaranteed,
                                        k, batch_axis)
    if b == 0 or pow2_at_least(b) < d:
        # below the mesh floor from the start: single-device dispatch
        from .compaction import solve_assignment_batched_compacting

        out, cst = solve_assignment_batched_compacting(
            c, eps, sizes=sizes, k=k, guaranteed=guaranteed,
            keep_state=keep_state)
        stats = _wrap_stats(cst, d, batch_axis, collapsed_at=cst.
                            dispatched_batch or None)
        return out, stats

    p = prepare_assignment_batch(c, eps, sizes, guaranteed, min_batch=d)
    sh = NamedSharding(mesh, P(batch_axis))
    prologue_s, chunk_s, conv_s, epilogue_s = _assign_fns(mesh, batch_axis,
                                                          k)
    eps_j = jax.device_put(jnp.asarray(p.eps_arr, jnp.float32), sh)
    mv_j = jax.device_put(jnp.asarray(p.m_valid), sh)
    nv_j = jax.device_put(jnp.asarray(p.n_valid), sh)
    c_s = jax.device_put(p.c, sh)
    cm, c_int, scale, row_ok, col_ok = prologue_s(c_s, eps_j, mv_j, nv_j)
    data = {
        "c_int": c_int,
        "threshold": jax.device_put(jnp.asarray(p.threshold), sh),
        "phase_cap": jax.device_put(jnp.asarray(p.phase_cap), sh),
        "m_valid": mv_j,
    }
    state0 = _assign_init_fn(mesh, batch_axis, m, n)(
        jax.device_put(jnp.zeros((p.bp,), jnp.float32), sh)
    )
    stats = DistributedStats(batch=b, dispatched_batch=p.bp, chunk=k,
                             devices=d, batch_axis=batch_axis,
                             placement="batch")
    max_chunks = -(-int(p.phase_cap.max(initial=1)) // max(k, 1)) + 2
    final = _drive_distributed(
        data, state0, chunk_s, conv_s,
        partial(_assign_chunk, k=k), _assign_conv,
        max_chunks, stats, mesh, batch_axis,
    )
    r = epilogue_s(cm, scale, final, eps_j, row_ok, col_ok)

    phases = np.asarray(final.phases[:b], np.int64)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    if keep_state:
        stats.final_state = jax.tree_util.tree_map(lambda a: a[:b], final)
    out = BatchedAssignmentResult(
        matching=r.matching[:b],
        cost=r.cost[:b],
        y_b=r.y_b[:b],
        y_a=r.y_a[:b],
        phases=r.phases[:b],
        rounds=r.rounds[:b],
        matched_before_completion=r.matched_before_completion[:b],
    )
    return out, stats


def solve_ot_distributed(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps,
    mesh: Mesh | None = None,
    *,
    sizes=None,
    theta=None,
    k: int = DEFAULT_CHUNK,
    guaranteed: bool = False,
    batch_axis: str = "data",
    placement: str = "auto",
):
    """Mesh-distributed counterpart of ``solve_ot_batched_compacting``;
    same contract and bit-identical per-instance results. Returns
    ``(OTResult with leading batch axes, DistributedStats)``."""
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    if c.ndim != 3:
        raise ValueError(f"expected (B, M, N) costs, got shape {c.shape}")
    b, m, n = c.shape
    mesh, d = _resolve_mesh(mesh, batch_axis)
    mode = (choose_placement(b, m, n, d) if placement == "auto"
            else placement)
    if mode == "matrix" and b > 0:
        return _solve_ot_matrix(c, nu, mu, eps, mesh, sizes, theta,
                                guaranteed, k, batch_axis)
    if b == 0 or pow2_at_least(b) < d:
        from .compaction import solve_ot_batched_compacting

        out, cst = solve_ot_batched_compacting(
            c, nu, mu, eps, sizes=sizes, theta=theta, k=k,
            guaranteed=guaranteed)
        stats = _wrap_stats(cst, d, batch_axis, collapsed_at=cst.
                            dispatched_batch or None)
        return out, stats

    p = prepare_ot_batch(c, nu, mu, eps, sizes, theta, guaranteed,
                         min_batch=d)
    sh = NamedSharding(mesh, P(batch_axis))
    max_rounds = int(m + n + 2)
    prologue_s, chunk_s, conv_s, epilogue_s = _ot_fns(mesh, batch_axis, k,
                                                      max_rounds)
    eps_j = jax.device_put(jnp.asarray(p.eps_arr, jnp.float32), sh)
    th_j = jax.device_put(jnp.asarray(p.th), sh)
    c_s = jax.device_put(p.c, sh)
    nu_s = jax.device_put(p.nu, sh)
    mu_s = jax.device_put(p.mu, sh)
    c_int, s_int, d_int, scale = prologue_s(c_s, nu_s, mu_s, th_j, eps_j)
    data = {
        "c_int": c_int,
        "threshold": jax.device_put(jnp.asarray(p.threshold), sh),
        "phase_cap": jax.device_put(jnp.asarray(p.phase_cap), sh),
    }
    state0 = _ot_init_fn(mesh, batch_axis)(s_int, d_int)
    stats = DistributedStats(batch=b, dispatched_batch=p.bp, chunk=k,
                             devices=d, batch_axis=batch_axis,
                             placement="batch")
    max_chunks = -(-int(p.phase_cap.max(initial=1)) // max(k, 1)) + 2
    final = _drive_distributed(
        data, state0, chunk_s, conv_s,
        partial(_ot_chunk, k=k, max_rounds=max_rounds), _ot_conv,
        max_chunks, stats, mesh, batch_axis,
    )
    r = epilogue_s(c_s, nu_s, mu_s, th_j, eps_j, scale, s_int, d_int,
                   final)

    phases = np.asarray(final.phases[:b], np.int64)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    out = jax.tree_util.tree_map(lambda a: a[:b], r)
    return out, stats


def _wrap_stats(cst: CompactionStats, devices: int, batch_axis: str,
                collapsed_at=None) -> DistributedStats:
    """Lift a single-device CompactionStats into DistributedStats (used
    when the whole solve ran below the mesh floor)."""
    st = DistributedStats(
        batch=cst.batch, dispatched_batch=cst.dispatched_batch,
        chunk=cst.chunk, dispatches=cst.dispatches,
        occupancy=cst.occupancy, slot_phases=cst.slot_phases,
        phases_needed=cst.phases_needed,
        lockstep_slot_phases=cst.lockstep_slot_phases,
        final_state=cst.final_state,
        devices=devices, batch_axis=batch_axis, placement="batch",
        collapsed_at=collapsed_at,
        devices_per_dispatch=[1] * cst.dispatches,
    )
    return st


# --------------------------------------------------------------------------
# Matrix placement: few large instances, row/col sharding per instance
# --------------------------------------------------------------------------

def _solve_assignment_matrix(c, eps, mesh, sizes, guaranteed, k,
                             batch_axis):
    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    eps_arr = _eps_array(eps, b, guaranteed)
    mesh2, row_axis, col_axis = _matrix_mesh(mesh)
    matching = np.full((b, m), -1, np.int32)
    cost = np.zeros((b,), np.float32)
    y_b = np.zeros((b, m), np.float32)
    y_a = np.zeros((b, n), np.float32)
    phases = np.zeros((b,), np.int32)
    rounds = np.zeros((b,), np.int32)
    mbc = np.zeros((b,), np.int32)
    stats = DistributedStats(batch=b, dispatched_batch=b, chunk=k,
                             devices=int(np.prod(list(mesh2.shape.values()))),
                             batch_axis=batch_axis, placement="matrix",
                             dispatches=b)
    rdiv = int(mesh2.shape[row_axis])
    cdiv = int(mesh2.shape[col_axis])
    c_h = np.asarray(c)
    for i in range(b):
        mi, ni = int(m_valid[i]), int(n_valid[i])
        # pad each instance up to mesh-divisible dims (sharded dims must
        # divide the mesh); the PAD_COST/masked-completion machinery makes
        # the padded solve equal the unpadded one
        mp = -(-mi // rdiv) * rdiv
        npad = -(-ni // cdiv) * cdiv
        ci = np.zeros((mp, npad), np.float32)
        ci[:mi, :ni] = c_h[i, :mi, :ni]
        r = solve_assignment_sharded(
            ci, float(eps_arr[i]), mesh2, row_axis=row_axis,
            col_axis=col_axis, m_valid=mi, n_valid=ni,
        )
        matching[i, :mi] = np.asarray(r.matching)[:mi]
        cost[i] = float(r.cost)
        y_b[i, :mi] = np.asarray(r.y_b)[:mi]
        y_a[i, :ni] = np.asarray(r.y_a)[:ni]
        phases[i] = int(r.phases)
        rounds[i] = int(r.rounds)
        mbc[i] = int(r.matched_before_completion)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    out = BatchedAssignmentResult(
        matching=jnp.asarray(matching), cost=jnp.asarray(cost),
        y_b=jnp.asarray(y_b), y_a=jnp.asarray(y_a),
        phases=jnp.asarray(phases), rounds=jnp.asarray(rounds),
        matched_before_completion=jnp.asarray(mbc),
    )
    return out, stats


def _solve_ot_matrix(c, nu, mu, eps, mesh, sizes, theta, guaranteed, k,
                     batch_axis):
    from .transport import OTResult, OTState

    b, m, n = c.shape
    m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
    eps_arr = _eps_array(eps, b, guaranteed)
    mesh2, row_axis, col_axis = _matrix_mesh(mesh)
    plan = np.zeros((b, m, n), np.float32)
    cost = np.zeros((b,), np.float32)
    y_b = np.zeros((b, m), np.float32)
    y_a = np.zeros((b, n), np.float32)
    phases = np.zeros((b,), np.int32)
    rounds = np.zeros((b,), np.int32)
    thetas = np.zeros((b,), np.float32)
    s_int = np.zeros((b, m), np.int32)
    d_int = np.zeros((b, n), np.int32)
    st_leaves = {
        "y_b": np.zeros((b, m), np.int32),
        "ya_hi": np.zeros((b, n), np.int32),
        "free_b": np.zeros((b, m), np.int32),
        "free_a": np.zeros((b, n), np.int32),
        "f_hi": np.zeros((b, m, n), np.int32),
        "f_lo": np.zeros((b, m, n), np.int32),
        "phases": np.zeros((b,), np.int32),
        "rounds": np.zeros((b,), np.int32),
    }
    stats = DistributedStats(batch=b, dispatched_batch=b, chunk=k,
                             devices=int(np.prod(list(mesh2.shape.values()))),
                             batch_axis=batch_axis, placement="matrix",
                             dispatches=b)
    th_b = (None if theta is None
            else np.broadcast_to(np.asarray(theta, np.float32), (b,)))
    rdiv = int(mesh2.shape[row_axis])
    cdiv = int(mesh2.shape[col_axis])
    c_h, nu_h, mu_h = np.asarray(c), np.asarray(nu), np.asarray(mu)
    for i in range(b):
        mi, ni = int(m_valid[i]), int(n_valid[i])
        # pad to mesh-divisible dims with zero mass/cost (inert lanes:
        # zero supply never proposes, zero demand grants nothing); theta
        # comes from the TRUE size so the trajectory equals the unpadded
        # solve's (host float64 -> f32, as _theta_array)
        mp = -(-mi // rdiv) * rdiv
        npad = -(-ni // cdiv) * cdiv
        ci = np.zeros((mp, npad), np.float32)
        ci[:mi, :ni] = c_h[i, :mi, :ni]
        nui = np.zeros((mp,), np.float32)
        nui[:mi] = nu_h[i, :mi]
        mui = np.zeros((npad,), np.float32)
        mui[:ni] = mu_h[i, :ni]
        if th_b is None:
            th_i = float(np.float32(4.0 * max(mi, ni)
                                    / np.float64(eps_arr[i])))
        else:
            th_i = float(th_b[i])
        r = solve_ot_sharded(
            ci, nui, mui, float(eps_arr[i]),
            mesh2, row_axis=row_axis, col_axis=col_axis, theta=th_i,
        )
        plan[i, :mi, :ni] = np.asarray(r.plan)[:mi, :ni]
        cost[i] = float(r.cost)
        y_b[i, :mi] = np.asarray(r.y_b)[:mi]
        y_a[i, :ni] = np.asarray(r.y_a)[:ni]
        phases[i] = int(r.phases)
        rounds[i] = int(r.rounds)
        thetas[i] = float(r.theta)
        s_int[i, :mi] = np.asarray(r.s_int)[:mi]
        d_int[i, :ni] = np.asarray(r.d_int)[:ni]
        st_leaves["y_b"][i, :mi] = np.asarray(r.state.y_b)[:mi]
        st_leaves["ya_hi"][i, :ni] = np.asarray(r.state.ya_hi)[:ni]
        st_leaves["free_b"][i, :mi] = np.asarray(r.state.free_b)[:mi]
        st_leaves["free_a"][i, :ni] = np.asarray(r.state.free_a)[:ni]
        st_leaves["f_hi"][i, :mi, :ni] = np.asarray(r.state.f_hi)[:mi, :ni]
        st_leaves["f_lo"][i, :mi, :ni] = np.asarray(r.state.f_lo)[:mi, :ni]
        st_leaves["phases"][i] = int(r.state.phases)
        st_leaves["rounds"][i] = int(r.state.rounds)
    stats.phases_needed = int(phases.sum())
    stats.lockstep_slot_phases = b * int(phases.max(initial=0))
    state = OTState(**{k2: jnp.asarray(v) for k2, v in st_leaves.items()})
    out = OTResult(
        plan=jnp.asarray(plan), cost=jnp.asarray(cost),
        y_b=jnp.asarray(y_b), y_a=jnp.asarray(y_a),
        phases=jnp.asarray(phases), rounds=jnp.asarray(rounds),
        state=state, theta=jnp.asarray(thetas),
        s_int=jnp.asarray(s_int), d_int=jnp.asarray(d_int),
    )
    return out, stats
