"""Optimal transport via the push-relabel framework (paper Section 4).

The paper reduces OT to an unbalanced assignment instance: scale masses by
theta = 4n/eps, round supplies down / demands up to integers, and replace each
node by unit copies. Lemma 4.1 shows copies of one vertex carry at most TWO
distinct dual values (exactly eps apart), so copies are never materialized:

  per supply b : ``y_b``  - dual of b's free copies (== max over copies);
                 ``free_b`` units of free supply. Matched-copy duals are
                 implicit: a matched pair is tight, y(b-copy) = c - y(a-copy).
  per demand a : ``ya_hi`` - max dual value among a's copies (<= 0);
                 ``free_a`` units of unmatched demand (always at dual 0, which
                 forces ya_hi == 0 while free_a > 0).
  flows        : ``F_hi[b,a]`` / ``F_lo[b,a]`` - units matched to a-copies at
                 ``ya_hi[a]`` / ``ya_hi[a] - 1`` respectively.

Only the *hi* cluster of a is ever admissible from free supply (the lo cluster
sits at slack >= 1), so each phase is a capacity-respecting greedy maximal
matching from free supply onto hi-cluster capacity, followed by push
(displacement of old flow picked up by new partners) and relabel. When a
column's hi cluster is fully consumed by M', its value collapses one step down
- precisely the mechanism that preserves eps-feasibility after free supply
duals rise (paper invariant I2, case (ii)).

All arithmetic is int32 in units of eps; the solve is one jitted XLA program.

Like the assignment solver, the loop is also exposed as a resumable stepped
core (``init_ot_state`` / ``run_ot_phases`` / ``ot_converged``) plus a
``ot_prologue`` / ``ot_epilogue`` split of the float pipeline, so the
compacting batch driver (core/compaction.py) can run a solve as a sequence
of k-phase dispatches bit-identical to the one-shot ``solve_ot_int``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .matching import proposal_keys


class OTState(NamedTuple):
    y_b: jnp.ndarray      # (nb,) int32 dual of free supply copies
    ya_hi: jnp.ndarray    # (na,) int32 max dual among demand copies (<= 0)
    free_b: jnp.ndarray   # (nb,) int32 unmatched supply units
    free_a: jnp.ndarray   # (na,) int32 unmatched demand units
    f_hi: jnp.ndarray     # (nb, na) int32 flow matched at ya_hi
    f_lo: jnp.ndarray     # (nb, na) int32 flow matched at ya_hi - 1
    phases: jnp.ndarray
    rounds: jnp.ndarray


class OTResult(NamedTuple):
    plan: jnp.ndarray     # (nb, na) float32, exact marginals (nu rows, mu cols)
    cost: jnp.ndarray     # <plan, C> under original costs
    y_b: jnp.ndarray      # scaled approximate duals (supply side)
    y_a: jnp.ndarray      # scaled approximate duals (demand side)
    phases: jnp.ndarray
    rounds: jnp.ndarray
    state: OTState        # raw integer state (for invariant checks)
    theta: float
    s_int: jnp.ndarray    # integer supplies after rounding
    d_int: jnp.ndarray    # integer demands after rounding


def _grant_round(c_int, y_b, ya_hi, rem_b, cap_a, salt):
    """One propose/accept round. Every b with remaining free supply proposes
    all of it to one hash-random admissible column with remaining capacity;
    columns grant FIFO by row order via a segmented exclusive prefix sum."""
    nb, na = c_int.shape
    adm = (y_b[:, None] + ya_hi[None, :] == c_int + 1) & (cap_a[None, :] > 0)
    keys = proposal_keys(nb, na, salt)
    keys = jnp.where(adm, keys, jnp.uint32(0xFFFFFFFF))
    best = jnp.argmin(keys, axis=1).astype(jnp.int32)
    can = jnp.any(adm, axis=1) & (rem_b > 0)
    tgt = jnp.where(can, best, jnp.int32(-1))

    # Segmented exclusive prefix of proposal amounts, ordered by row index.
    amt = jnp.where(can, rem_b, 0)
    cums = jnp.cumsum(amt)
    excl = cums - amt
    big = jnp.iinfo(jnp.int32).max
    tgt_safe = jnp.where(can, tgt, na)
    base = jnp.full((na,), big, jnp.int32).at[tgt_safe].min(
        jnp.where(can, excl, big), mode="drop"
    )
    prefix = excl - jnp.where(can, base[jnp.clip(tgt, 0, na - 1)], 0)
    grant = jnp.clip(cap_a[jnp.clip(tgt, 0, na - 1)] - prefix, 0, amt)
    grant = jnp.where(can, grant, 0)
    return tgt_safe, grant, jnp.any(can)


def _phase(c_int, s: OTState, max_rounds: int) -> OTState:
    nb, na = c_int.shape
    free_b0, free_a0 = s.free_b, s.free_a
    # hi-cluster capacity available to M': free units (only live at value 0 ==
    # ya_hi) plus already-matched hi copies (displaceable).
    m_hi = jnp.sum(s.f_hi, axis=0)
    cap0 = jnp.where(s.ya_hi == 0, s.free_a, 0) + m_hi
    # Guard: free_a > 0 implies ya_hi == 0, so the where() is redundant by the
    # invariant but keeps the state safe if it is ever perturbed.
    granted0 = jnp.zeros((nb, na), jnp.int32)

    def cond(c):
        rem_b, cap_a, granted, rounds, done = c
        return (~done) & (rounds < max_rounds)

    def body(c):
        rem_b, cap_a, granted, rounds, _ = c
        salt = s.phases * jnp.int32(7919) + rounds
        tgt_safe, grant, any_prop = _grant_round(
            c_int, s.y_b, s.ya_hi, rem_b, cap_a, salt
        )
        rows = jnp.arange(nb, dtype=jnp.int32)
        granted = granted.at[rows, jnp.clip(tgt_safe, 0, na - 1)].add(
            jnp.where(tgt_safe < na, grant, 0)
        )
        cap_a = cap_a.at[tgt_safe].add(-grant, mode="drop")
        rem_b = rem_b - grant
        return (rem_b, cap_a, granted, rounds + 1, ~any_prop)

    # Derive loop-carry zeros from data so the carry's varying-axes match
    # under shard_map (a literal jnp.int32(0) is unvarying and trips the
    # vma check when the body mixes in sharded data).
    zero_s = jnp.sum(c_int[:1, :1]) * 0
    rem_b, cap_a, granted, rounds, _ = jax.lax.while_loop(
        cond,
        body,
        (free_b0, cap0, granted0 + zero_s, zero_s, zero_s != 0),
    )

    g_a = jnp.sum(granted, axis=0)                       # units matched in M'
    use_free = jnp.minimum(g_a, jnp.where(s.ya_hi == 0, free_a0, 0))
    disp = g_a - use_free                                # displaced hi flow
    # Victims: strip `disp` units off each column of f_hi, bottom rows first.
    suffix_excl = jnp.cumsum(s.f_hi[::-1], axis=0)[::-1] - s.f_hi
    take = jnp.clip(disp[None, :] - suffix_excl, 0, s.f_hi)
    f_hi = s.f_hi - take
    freed_b = jnp.sum(take, axis=1)

    # Relabel (III(a)): every M'-matched a-copy drops by one -> granted units
    # land at ya_hi - 1. If the hi cluster is now empty, the column collapses.
    free_a = free_a0 - use_free
    # Copies remaining at the hi value: surviving free units (they live at 0,
    # i.e. at ya_hi iff ya_hi == 0; free units are never displaced so a column
    # with free_a > 0 can never collapse) plus surviving matched-hi flow.
    hi_left = jnp.where(s.ya_hi == 0, free_a, 0) + jnp.sum(f_hi, axis=0)
    collapse = (hi_left == 0) & (g_a > 0)
    ya_hi = jnp.where(collapse, s.ya_hi - 1, s.ya_hi)
    f_hi_new = jnp.where(collapse[None, :], s.f_lo + granted, f_hi)
    f_lo_new = jnp.where(collapse[None, :], 0, s.f_lo + granted)

    # Relabel (III(b)): rows of B' with free supply left after M' rise by one.
    rem_after = rem_b
    y_b = s.y_b + ((free_b0 > 0) & (rem_after > 0)).astype(jnp.int32)
    free_b = rem_after + freed_b

    return OTState(
        y_b=y_b,
        ya_hi=ya_hi,
        free_b=free_b,
        free_a=free_a,
        f_hi=f_hi_new,
        f_lo=f_lo_new,
        phases=s.phases + 1,
        rounds=s.rounds + rounds,
    )


def init_ot_state(s_int: jnp.ndarray, d_int: jnp.ndarray) -> OTState:
    """Paper initialization: all mass free, y(b) = eps (1 unit), y(a) = 0.

    ``free_b``/``free_a`` are forced to FRESH buffers (``copy=True``): an
    eager int32 ``astype`` would alias the caller's ``s_int``/``d_int``,
    and the chunked ``run_ot_phases`` donates the state — an aliased init
    would delete the caller's rounded masses out from under the epilogue."""
    nb = s_int.shape[0]
    na = d_int.shape[0]
    return OTState(
        y_b=jnp.ones((nb,), jnp.int32),
        ya_hi=jnp.zeros((na,), jnp.int32),
        free_b=jnp.array(s_int, dtype=jnp.int32, copy=True),
        free_a=jnp.array(d_int, dtype=jnp.int32, copy=True),
        f_hi=jnp.zeros((nb, na), jnp.int32),
        f_lo=jnp.zeros((nb, na), jnp.int32),
        phases=jnp.int32(0),
        rounds=jnp.int32(0),
    )


def ot_termination_threshold(nu, theta, eps: float) -> int:
    """Host-side float64 termination threshold ``int(eps * sum(s_int))``.

    ``s_int = floor(f32(nu) * f32(theta))`` replicates the device rounding
    exactly (a single correctly-rounded f32 multiply on either side); the
    eps product is then taken in float64. Computing it on device as
    ``f32(eps) * f32(total)`` rounds the wrong way for some (eps, total)
    pairs — e.g. eps=0.3/3 (the guaranteed path), total=10: f32(0.1)*10 =
    1.0000000149 -> 1, but float64 gives 0.999... -> 0 — the same bug PR 1
    fixed for the assignment path's ``int(eps * m)``."""
    s_int = np.floor(np.asarray(nu, np.float32) * np.float32(theta))
    return int(float(eps) * int(s_int.sum(dtype=np.float64)))


@partial(jax.jit, static_argnames=("eps", "max_phases", "max_rounds"))
def solve_ot_int(
    c_int: jnp.ndarray,
    s_int: jnp.ndarray,
    d_int: jnp.ndarray,
    eps: float,
    max_phases: int,
    max_rounds: int,
    threshold=None,
) -> OTState:
    """Run phases until free supply <= threshold. ``threshold`` (traced ()
    int32) should be the host-computed ``ot_termination_threshold``; when
    None (nu/theta unavailable on host, e.g. under a caller's jit) it falls
    back to the on-device f32 product."""
    if threshold is None:
        total_s = jnp.sum(s_int)
        threshold = (jnp.float32(eps)
                     * total_s.astype(jnp.float32)).astype(jnp.int32)
    else:
        threshold = jnp.asarray(threshold, jnp.int32)

    def cond(s: OTState):
        return (jnp.sum(s.free_b) > threshold) & (s.phases < max_phases)

    return jax.lax.while_loop(cond, lambda s: _phase(c_int, s, max_rounds),
                              init_ot_state(s_int, d_int))


@partial(jax.jit, static_argnames=("k", "max_rounds"), donate_argnums=(1,))
def run_ot_phases(
    c_int: jnp.ndarray,
    state: OTState,
    threshold,
    phase_cap,
    k: int,
    max_rounds: int,
) -> OTState:
    """Advance the OT solve by at most ``k`` phases (fewer on termination).

    ``threshold``/``phase_cap`` are traced () int32 (per instance under
    vmap); ``k`` and ``max_rounds`` are static. Chaining calls reproduces
    the one-shot ``solve_ot_int`` state trajectory bit for bit for any k:
    the phase body is the identical ``_phase`` and the per-phase salt rides
    in ``state.phases``.

    ``state`` is DONATED (the dominant buffers are the two (nb, na) flow
    matrices): a chunked solve updates them in place instead of holding
    two copies. Callers must rebind and drop the old reference."""
    threshold = jnp.asarray(threshold, jnp.int32)
    phase_cap = jnp.asarray(phase_cap, jnp.int32)
    start = state.phases

    def cond(s: OTState):
        return ((jnp.sum(s.free_b) > threshold) & (s.phases < phase_cap)
                & (s.phases - start < jnp.int32(k)))

    return jax.lax.while_loop(cond, lambda s: _phase(c_int, s, max_rounds),
                              state)


def ot_converged(state: OTState, threshold, phase_cap) -> jnp.ndarray:
    """() bool: the solve loop would not take another phase."""
    return ~((jnp.sum(state.free_b) > jnp.asarray(threshold, jnp.int32))
             & (state.phases < jnp.asarray(phase_cap, jnp.int32)))


def northwest_corner(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Closed-form NW-corner plan: P[i,j] = (min(R_i,C_j) - max(R_{i-1},C_{j-1}))+"""
    cr = jnp.cumsum(r)
    cc = jnp.cumsum(c)
    cr0 = cr - r
    cc0 = cc - c
    return jnp.maximum(
        jnp.minimum(cr[:, None], cc[None, :])
        - jnp.maximum(cr0[:, None], cc0[None, :]),
        0.0,
    )


def ot_phase_cap(eps: float) -> int:
    """Static safety bound on the phase count (paper Lemma 4.2 analogue)."""
    return int((1.0 + 2.0 * eps) / (eps * eps)) + 8


def ot_prologue(c: jnp.ndarray, nu: jnp.ndarray, mu: jnp.ndarray, theta, eps):
    """Rounding half of the pipeline: float costs/masses -> integer instance.
    ``theta`` and ``eps`` may be Python floats or traced f32 scalars (the
    batched/compacting drivers vmap with per-instance values). Returns
    ``(c_int, s_int, d_int, scale)``."""
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    theta = jnp.asarray(theta, jnp.float32)
    scale = jnp.maximum(jnp.max(c), 1e-30)
    c_int = jnp.floor(c / scale / eps).astype(jnp.int32)
    s_int = jnp.floor(nu * theta).astype(jnp.int32)          # round down
    d_int = jnp.ceil(mu * theta).astype(jnp.int32)           # round up
    return c_int, s_int, d_int, scale


def ot_pipeline(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    theta,
    eps: float,
    threshold=None,
) -> OTResult:
    """Traceable solve pipeline: rounding -> integer solve -> completion ->
    marginal repair. ``theta`` may be a Python float or a traced f32 scalar
    (the batched solver vmaps this function with a per-instance theta);
    ``threshold`` the host-computed ``ot_termination_threshold`` (traced ()
    int32, falls back to the on-device f32 product when None)."""
    c = jnp.asarray(c, jnp.float32)
    nu = jnp.asarray(nu, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    nb, na = c.shape
    c_int, s_int, d_int, scale = ot_prologue(c, nu, mu, theta, eps)
    theta = jnp.asarray(theta, jnp.float32)
    state = solve_ot_int(
        c_int, s_int, d_int, eps, ot_phase_cap(eps),
        max_rounds=int(nb + na + 2), threshold=threshold,
    )
    return ot_epilogue(c, nu, mu, theta, eps, scale, s_int, d_int, state)


def ot_epilogue(c, nu, mu, theta, eps, scale, s_int, d_int,
                state: OTState) -> OTResult:
    """Completion + marginal-repair half of the pipeline, applied to a
    terminated integer state. The compacting driver runs this once, in
    bulk, over the full batch of retired states."""
    theta = jnp.asarray(theta, jnp.float32)
    flow = (state.f_hi + state.f_lo).astype(jnp.float32)
    # Integer completion: leftover free supply -> leftover demand capacity.
    comp = northwest_corner(
        state.free_b.astype(jnp.float32), state.free_a.astype(jnp.float32)
    )
    plan = (flow + comp) / theta
    # Repair marginals to the *original* (nu, mu): demand round-up can
    # overshoot a column by < 1/theta; rescale columns then NW-fill residuals.
    colsum = jnp.sum(plan, axis=0)
    col_scale = jnp.where(colsum > mu, mu / jnp.maximum(colsum, 1e-30), 1.0)
    plan = plan * col_scale[None, :]
    r = jnp.maximum(nu - jnp.sum(plan, axis=1), 0.0)
    cc = jnp.maximum(mu - jnp.sum(plan, axis=0), 0.0)
    # balance tiny float drift before the NW fill
    tot = jnp.minimum(jnp.sum(r), jnp.sum(cc))
    r = r * jnp.where(jnp.sum(r) > 0, tot / jnp.maximum(jnp.sum(r), 1e-30), 0.0)
    cc = cc * jnp.where(jnp.sum(cc) > 0, tot / jnp.maximum(jnp.sum(cc), 1e-30), 0.0)
    plan = plan + northwest_corner(r, cc)

    cost = jnp.sum(plan * c)
    return OTResult(
        plan=plan,
        cost=cost,
        y_b=state.y_b.astype(jnp.float32) * eps * scale,
        y_a=state.ya_hi.astype(jnp.float32) * eps * scale,
        phases=state.phases,
        rounds=state.rounds,
        state=state,
        theta=theta,
        s_int=s_int,
        d_int=d_int,
    )


def solve_ot(
    c: jnp.ndarray,
    nu: jnp.ndarray,
    mu: jnp.ndarray,
    eps: float,
    *,
    theta: float | None = None,
    guaranteed: bool = False,
) -> OTResult:
    """epsilon-additive approximate OT (rows = supplies nu, cols = demands mu).

    Cost error is measured against costs scaled to [0, 1] (paper convention):
    w(plan) <= w(opt) + O(eps) * max(c). ``guaranteed=True`` runs at eps/3.
    """
    if guaranteed:
        eps = eps / 3.0
    c = jnp.asarray(c, jnp.float32)
    nb, na = c.shape
    if theta is None:
        theta = 4.0 * max(nb, na) / eps
    threshold = None
    if not isinstance(nu, jax.core.Tracer) and \
            not isinstance(theta, jax.core.Tracer):
        # eager: exact float64 termination threshold (the on-device f32
        # fallback inside solve_ot_int rounds wrong for some (eps, total))
        threshold = ot_termination_threshold(np.asarray(nu), theta, eps)
    else:
        import warnings

        warnings.warn(
            "solve_ot traced under jit/vmap: the termination threshold "
            "falls back to the on-device f32 product, which rounds "
            "differently from the eager float64 path for rare "
            "(eps, total_mass) pairs. Prefer eager solve_ot, or "
            "solve_ot_batched / the compacting driver, which precompute "
            "exact host thresholds.",
            stacklevel=2,
        )
    res = ot_pipeline(c, nu, mu, theta, eps, threshold=threshold)
    if not isinstance(res.theta, jax.core.Tracer):
        # eager: keep the historical Python-float theta (and avoid forcing
        # a device sync when called under jit/vmap, where this is a tracer)
        res = res._replace(theta=float(res.theta))
    return res


# --------------------------------------------------------------------------
# Static-audit registration (repro.analysis): the OT stepped core donates
# its state (the PR-3 bug lived in its init chain, registered from
# core/problem.py), and the one-shot solve's threshold=None fallback is the
# PR-2 on-device f32 threshold — registered under the "threshold" tag so
# the dtype-drift rule keeps it visible as an explicit baseline entry.
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_ot_chunk():
    m = n = 8
    return _audit.trace_entry(
        name="core.transport.run_ot_phases",
        fn=lambda c_int, state, threshold, phase_cap:
            run_ot_phases(c_int, state, threshold, phase_cap, 4,
                          max_rounds=int(m + n + 2)),
        args={
            "c_int": jnp.zeros((m, n), jnp.int32),
            "state": init_ot_state(jnp.ones((m,), jnp.int32),
                                   jnp.ones((n,), jnp.int32)),
            "threshold": jnp.int32(0),
            "phase_cap": jnp.int32(8),
        },
        donated={"state"},
        must_trace={"threshold", "phase_cap"},
        tags={"stepped-core", "ot"},
        source=__name__,
    )


def _trace_solve_ot_int_fallback():
    m = n = 8
    return _audit.trace_entry(
        name="core.transport.solve_ot_int[threshold=None]",
        fn=lambda c_int, s_int, d_int:
            solve_ot_int(c_int, s_int, d_int, 0.25, 8, max_rounds=18,
                         threshold=None),
        args={
            "c_int": jnp.zeros((m, n), jnp.int32),
            "s_int": jnp.ones((m,), jnp.int32),
            "d_int": jnp.ones((n,), jnp.int32),
        },
        tags={"threshold", "ot"},
        source=__name__,
    )


_audit.register("core.transport.run_ot_phases", _trace_ot_chunk,
                source=__name__)
_audit.register("core.transport.solve_ot_int[threshold=None]",
                _trace_solve_ot_int_fallback, source=__name__)
