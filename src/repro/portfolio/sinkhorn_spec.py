"""SINKHORN: the log-domain Sinkhorn loop as a third ProblemSpec.

The paper's headline experiment compares the push-relabel solver against
Sinkhorn; this module makes that comparison a per-request dispatch choice
by wrapping Sinkhorn in the same stepped-core contract
(``core/problem.ProblemSpec``) the push-relabel specs implement, so every
batch driver — lockstep, convergence compaction, mesh — and every serving
layer runs it unchanged.

The additive-eps contract comes from Altschuler–Weed–Rigollet
(arXiv:1705.09634): with regularization reg = eps/(4 log n) and the
iterates stopped at L1 marginal violation eps/8, rounding the entropic
plan to the feasible polytope (their Algorithm 2) yields cost <= OPT +
eps * scale, so ``converged`` certifies the same additive target as the
push-relabel termination predicate. Both schedule constants (reg, tol)
and the AWR iteration cap 2 + 128 (log n)^2 / eps^2 are derived on host
in float64 per lane — the same device-f32 threshold bug class PR 2 fixed
for OT termination never gets a chance here — then shipped to the device
as f32 operands so distinct accuracies never recompile.

Mapping to the protocol:

  ``prepare``      host-f64 per-lane reg/tol/iteration-cap, padding masks,
                   power-of-two batch padding (padded lanes get cap 0:
                   born converged).
  ``prologue``     normalize: c_hat = c/max(c), nu_hat/mu_hat = masses
                   normalized to 1, log marginals floor-clamped.
  ``init_state``   f = g = 0, err = +inf.
  ``run_phases``   at most k Sinkhorn iterations (f-update then g-update,
                   then the row-marginal L1 violation); resumable —
                   chaining calls is bit-identical to one-shot for any k,
                   so deadlines, obs chunk events, and compaction compose
                   unchanged.
  ``converged``    err <= tol, or the AWR iteration cap hit.
  ``epilogue``     AWR Algorithm 2 rounding to the transport polytope
                   (row/col downscaling + a northwest-corner fill of the
                   residual marginals, shared with ``ot_epilogue``),
                   pricing against the float costs, and duals y = f*scale
                   / g*scale. After the g-update f_i + g_j <= c_hat_ij
                   holds exactly (log mu_hat <= 0), so the scaled duals
                   are 0-slack feasible and ``Solution.additive_gap()``
                   certifies the answer a posteriori like the
                   push-relabel duals do.

``SINKHORN_KERNEL`` swaps the row update for the flash-style Pallas
kernel (``kernels/sinkhorn_step.py``) at the block sizes of the
``kernel_blocks()`` backend table; it is the spec ``fused_variant``
resolves for ``DispatchPolicy(fused=True)``, and ``stepped`` points back
at ``SINKHORN`` for the checkify sanitizer (it cannot instrument the
inside of a Pallas kernel).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import logsumexp

from ..core.problem import (
    OTSpec,
    PreparedBatch,
    _pad_lanes,
    _sizes_arrays,
    eps_array,
    pow2_at_least,
)
from ..core.transport import northwest_corner

# Sinkhorn state floor: normalized masses are clamped here before the
# log, so empty (padded) marginals stay finite and inert. Must be a
# NORMAL f32 (min normal ~1.18e-38): subnormal floors get flushed to
# zero on FTZ backends, turning the clamp into log(0) = -inf in padded
# rows and 0/0 = NaN in the epilogue's rescale guards.
_LOG_FLOOR = 1e-30
# reg floor: below this the f32 exp/log arithmetic is pure noise anyway.
_REG_FLOOR = 1e-6


class SinkhornState(NamedTuple):
    """Per-instance Sinkhorn iterate. ``phases`` counts full (f, g)
    update sweeps — the driver-visible unit, same as push-relabel
    phases — and is the field the compaction driver's ``conv`` reads."""
    f: jnp.ndarray       # (m,) f32 row potentials, normalized domain
    g: jnp.ndarray       # (n,) f32 col potentials
    err: jnp.ndarray     # () f32 L1 row-marginal violation (after g-update)
    phases: jnp.ndarray  # () int32 iterations done


class SinkhornOTResult(NamedTuple):
    """Epilogue output; mirrors OTResult's artifact surface (no theta —
    Sinkhorn has no integer scaling) plus the schedule the certificate
    documentation wants (reg, final marginal err)."""
    plan: jnp.ndarray    # (m, n) f32, EXACT marginals (nu, mu) up to f32
    cost: jnp.ndarray    # () f32 <plan, c>
    y_b: jnp.ndarray     # (m,) f32 feasible duals (f * scale)
    y_a: jnp.ndarray     # (n,) f32 feasible duals (g * scale)
    phases: jnp.ndarray  # () int32
    rounds: jnp.ndarray  # () int32 == phases (one sweep per phase)
    err: jnp.ndarray     # () f32 marginal violation at termination
    reg: jnp.ndarray     # () f32 entropic regularization used


def sinkhorn_schedule(eps_arr, m_valid, n_valid, max_iters=None):
    """Host-float64 AWR schedule per lane: (reg, tol, cap).

    reg = eps/(4 log n) and tol = eps/8 make the rounded entropic plan
    eps-additive (AWR Thm 1 + Alg. 2); cap = 2 + 128 (log n)^2 / eps^2 is
    their iteration bound at that (reg, tol). Everything is computed in
    float64 on host and only then cast for the device, so the
    thresholds can never be distorted by device-f32 rounding."""
    eps_arr = np.asarray(eps_arr, np.float64)
    logn = np.log(np.maximum(np.maximum(m_valid, n_valid), 2)
                  .astype(np.float64))
    reg = np.maximum(eps_arr / (4.0 * logn), _REG_FLOOR)
    tol = eps_arr / 8.0
    cap = 2.0 + np.ceil(128.0 * logn ** 2 / eps_arr ** 2)
    if max_iters is not None:
        cap = np.minimum(cap, float(int(max_iters)))
    cap = np.minimum(cap, np.float64(np.iinfo(np.int32).max))
    return reg, tol, cap.astype(np.int32)


def _row_update_jnp(c_hat, g, log_nu, reg):
    """Pure-jnp log-domain f-update: the parity reference for the Pallas
    row kernel (tests/test_portfolio.py)."""
    return reg * (log_nu - logsumexp((g[None, :] - c_hat) / reg, axis=1))


@partial(jax.jit, static_argnames=("k", "kernel"), donate_argnums=(7,))
def run_sinkhorn_phases(c_hat, log_nu, log_mu, nu_hat, reg, tol, phase_cap,
                        state, k, kernel=False):
    """At most k Sinkhorn iterations from ``state``; resumable (chaining
    calls is bit-identical to one-shot for any k). Each iteration is one
    f-update, one g-update, then the L1 row-marginal violation of the
    current iterate — measured AFTER the g-update, where the column
    marginals are exact by construction, so err is the full constraint
    violation. ``kernel=True`` routes the f-update through the Pallas
    row kernel (bit-parity documented in tests/test_portfolio.py)."""
    start = state.phases

    def row_update(f, g):
        if kernel:
            from ..kernels import ops as _kops

            return _kops.sinkhorn_row_update(c_hat, g, log_nu, reg)
        return _row_update_jnp(c_hat, g, log_nu, reg)

    def one_iter(st):
        f = row_update(st.f, st.g)
        g = reg * (log_mu - logsumexp((f[:, None] - c_hat) / reg, axis=0))
        row = jnp.sum(jnp.exp((f[:, None] + g[None, :] - c_hat) / reg),
                      axis=1)
        err = jnp.sum(jnp.abs(row - nu_hat))
        return SinkhornState(f=f, g=g, err=err, phases=st.phases + 1)

    def cond(st):
        return ((st.err > tol) & (st.phases < phase_cap)
                & (st.phases - start < k))

    return jax.lax.while_loop(cond, one_iter, state)


def sinkhorn_epilogue(c, nu, mu, reg, scale, mass_nu, state):
    """AWR Algorithm 2: round the entropic plan onto the transport
    polytope of (nu, mu), then price. Row/col marginals are first scaled
    DOWN to never exceed their targets, then the leftover marginal mass
    (<= the tol violation) is filled with a northwest-corner plan of the
    residuals — the same closed-form completion ``ot_epilogue`` uses, so
    the two solvers' feasibility semantics are one code path."""
    c_hat = c / scale
    plan = jnp.exp((state.f[:, None] + state.g[None, :] - c_hat) / reg)
    plan = plan * mass_nu  # normalized rows ~ nu_hat -> mass units
    rs = jnp.minimum(1.0, nu / jnp.maximum(jnp.sum(plan, axis=1),
                                           _LOG_FLOOR))
    plan = plan * rs[:, None]
    cs = jnp.minimum(1.0, mu / jnp.maximum(jnp.sum(plan, axis=0),
                                           _LOG_FLOOR))
    plan = plan * cs[None, :]
    r = jnp.maximum(nu - jnp.sum(plan, axis=1), 0.0)
    cc = jnp.maximum(mu - jnp.sum(plan, axis=0), 0.0)
    tot = jnp.minimum(jnp.sum(r), jnp.sum(cc))
    r = r * (tot / jnp.maximum(jnp.sum(r), _LOG_FLOOR))
    cc = cc * (tot / jnp.maximum(jnp.sum(cc), _LOG_FLOOR))
    plan = plan + northwest_corner(r, cc)
    cost = jnp.sum(plan * c)
    return SinkhornOTResult(
        plan=plan, cost=cost,
        y_b=state.f * scale, y_a=state.g * scale,
        phases=state.phases, rounds=state.phases,
        err=state.err, reg=reg,
    )


class SinkhornSpec(OTSpec):
    """ProblemSpec for log-domain Sinkhorn over the same (c, nu, mu)
    inputs as ``OT``. Subclasses OTSpec for the input-shaping glue
    (canonicalize / pad_group / plan artifacts); every algorithmic
    method is overridden. Batch placement only: the row kernel is a
    whole-instance program, so mesh/matrix sharding raises."""

    name = "sinkhorn"
    fused = False

    def prepare(self, inputs, eps, *, sizes=None, guaranteed: bool = False,
                min_batch: int = 1, max_iters=None) -> PreparedBatch:
        c, nu, mu = inputs["c"], inputs["nu"], inputs["mu"]
        b, m, n = c.shape
        m_valid, n_valid = _sizes_arrays(sizes, b, m, n)
        eps_arr = eps_array(eps, b, guaranteed)
        reg, tol, cap = sinkhorn_schedule(eps_arr, m_valid, n_valid,
                                          max_iters)
        # zero mass/cost outside each instance's valid block (inert: the
        # clamped log marginals make padded rows/cols converge in one
        # iteration and carry ~0 plan mass)
        row_ok = np.arange(m)[None, :] < m_valid[:, None]
        col_ok = np.arange(n)[None, :] < n_valid[:, None]
        mask = jnp.asarray(row_ok[:, :, None] & col_ok[:, None, :])
        c = jnp.where(mask, c, 0.0)
        nu = jnp.where(jnp.asarray(row_ok), nu, 0.0)
        mu = jnp.where(jnp.asarray(col_ok), mu, 0.0)
        bp = max(pow2_at_least(b), pow2_at_least(min_batch))
        # padded lanes: cap 0 -> born converged; reg/tol pads stay
        # nonzero so the prologue/phase divisions remain finite
        ops = _pad_lanes(bp, b, {
            "c": c, "nu": nu, "mu": mu,
            "reg": reg.astype(np.float32),
            "tol": tol.astype(np.float32),
            "phase_cap": cap,
        }, fills={"reg": np.float32(reg[0]), "tol": np.float32(tol[0])})
        if bp > b:
            eps_arr = np.concatenate(
                [eps_arr, np.full((bp - b,), eps_arr[0])])
        return PreparedBatch(
            ops=ops, threshold=np.zeros((bp,), np.int32),
            phase_cap=np.asarray(ops["phase_cap"]), eps_arr=eps_arr, bp=bp)

    # epilogue operands taken verbatim from ops (outside the jit)
    ctx_ops = ("c", "nu", "mu", "reg")

    def prologue(self, ops):
        c, nu, mu = ops["c"], ops["nu"], ops["mu"]
        scale = jnp.maximum(jnp.max(c), 1e-30)  # == ot_prologue's clamp
        mass_nu = jnp.maximum(jnp.sum(nu), _LOG_FLOOR)
        mass_mu = jnp.maximum(jnp.sum(mu), _LOG_FLOOR)
        nu_hat = nu / mass_nu
        data = {
            "c_hat": c / scale,
            "log_nu": jnp.log(jnp.maximum(nu_hat, _LOG_FLOOR)),
            "log_mu": jnp.log(jnp.maximum(mu / mass_mu, _LOG_FLOOR)),
            "nu_hat": nu_hat,
            "reg": ops["reg"], "tol": ops["tol"],
            "phase_cap": ops["phase_cap"],
        }
        ctx = {"scale": scale, "mass_nu": mass_nu}
        return data, ctx

    def init_state(self, data, ctx):
        m, n = data["c_hat"].shape
        return SinkhornState(
            f=jnp.zeros((m,), jnp.float32),
            g=jnp.zeros((n,), jnp.float32),
            err=jnp.asarray(jnp.inf, jnp.float32),
            phases=jnp.zeros((), jnp.int32),
        )

    def run_phases(self, data, state, k: int):
        return run_sinkhorn_phases(
            data["c_hat"], data["log_nu"], data["log_mu"], data["nu_hat"],
            data["reg"], data["tol"], data["phase_cap"], state, k)

    def converged(self, data, state):
        return (state.err <= data["tol"]) | (state.phases
                                             >= data["phase_cap"])

    def epilogue(self, ctx, state):
        return sinkhorn_epilogue(ctx["c"], ctx["nu"], ctx["mu"],
                                 ctx["reg"], ctx["scale"], ctx["mass_nu"],
                                 state)

    # -- result shaping ------------------------------------------------

    def empty_result(self, m: int, n: int):
        zf = lambda *s: jnp.zeros(s, jnp.float32)
        zi = lambda *s: jnp.zeros(s, jnp.int32)
        return SinkhornOTResult(plan=zf(0, m, n), cost=zf(0),
                                y_b=zf(0, m), y_a=zf(0, n), phases=zi(0),
                                rounds=zi(0), err=zf(0), reg=zf(0))

    # trim: OTSpec's tree_map slice works on SinkhornOTResult unchanged

    # -- lockstep / matrix placement -----------------------------------

    def _lockstep_k(self, eps_arr, mn: int) -> int:
        _, _, cap = sinkhorn_schedule(eps_arr,
                                      np.full_like(eps_arr, mn, np.int64),
                                      np.full_like(eps_arr, mn, np.int64))
        return int(cap.max(initial=1)) + 1

    def solve_lockstep(self, inputs, eps: float, *, sizes=None,
                       guaranteed: bool = False, keep_state: bool = False,
                       max_iters=None):
        # one compacting dispatch with k above the iteration cap: genuine
        # lockstep semantics (no compaction ever fires) without teaching
        # core/batched about a third solver — same trick as the fused
        # push-relabel specs' _fused_lockstep
        from ..core.compaction import solve_compacting

        b, m, n = (int(s) for s in np.shape(inputs["c"]))
        eps_arr = eps_array(eps, b, guaranteed)
        k_all = (self._lockstep_k(eps_arr, max(m, n))
                 if max_iters is None else int(max_iters) + 1)
        r, stats = solve_compacting(
            self, inputs, eps, sizes=sizes, k=k_all, guaranteed=guaranteed,
            keep_state=keep_state, max_iters=max_iters)
        return r, (stats.final_state if keep_state else None)

    def matrix_instance(self, host, i, mi, ni, mp, np_, eps_i, mesh2,
                        row_axis, col_axis, **kw):
        raise NotImplementedError(
            "the sinkhorn spec supports batch placement only; use "
            "placement='batch' (or the push-relabel specs) for "
            "row/col-sharded single instances")

    def matrix_stack(self, rows, m_valid, n_valid, m: int, n: int):
        raise NotImplementedError(
            "the sinkhorn spec supports batch placement only")

    # -- per-artifact producers ----------------------------------------

    artifacts = ("cost", "duals", "plan", "plan_sparse", "state", "stats")
    state_on_result = False

    def artifact_device(self, name, r, state):
        if name == "cost":
            return {"cost": r.cost}
        if name == "scalars":
            # no theta: Sinkhorn has no integer scaling parameter
            return {"phases": r.phases, "rounds": r.rounds}
        if name == "duals":
            return {"y_b": r.y_b, "y_a": r.y_a}
        if name == "plan":
            return {"plan": r.plan}
        raise KeyError(name)

    def artifact_state(self, r, state):
        # SinkhornOTResult carries no state: it exists only when the
        # dispatch retained it (keep_state / want=("state",))
        return state

    def legacy_instance_dict(self, sol):
        return {
            "plan": sol.plan(),
            "cost": sol.cost,
            "phases": sol.phases,
            "rounds": sol.rounds,
        }


class KernelSinkhornSpec(SinkhornSpec):
    """SinkhornSpec whose f-update is the flash-style Pallas row kernel
    (online-logsumexp over column blocks, ``kernels/sinkhorn_step.py``)
    at the ``kernel_blocks()`` backend-table block sizes. Off-TPU the
    kernel runs in interpret mode — honest-labeling as everywhere else.
    Float tolerance vs the pure-jnp update is documented where it is
    asserted (tests/test_portfolio.py): both evaluate the same online
    logsumexp up to reassociation, ~1e-7 * |f| on f32."""

    fused = True

    def run_phases(self, data, state, k: int):
        return run_sinkhorn_phases(
            data["c_hat"], data["log_nu"], data["log_mu"], data["nu_hat"],
            data["reg"], data["tol"], data["phase_cap"], state, k,
            kernel=True)


SINKHORN = SinkhornSpec()
SINKHORN_KERNEL = KernelSinkhornSpec()
KernelSinkhornSpec.stepped = SINKHORN
# fused_variant() hook (core/problem.py): DispatchPolicy(fused=True)
# resolves SINKHORN -> SINKHORN_KERNEL without core importing portfolio
SinkhornSpec.fused_spec = SINKHORN_KERNEL


# --------------------------------------------------------------------------
# repro.analysis registration: the vmapped chunk/conv programs the
# compacting driver re-issues for this spec, plus the prologue ->
# init_state chain the donation-safety rule alias-checks (the PR-3 bug
# class: the donated state must not share buffers with retained operands).
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _tiny_sinkhorn_batch():
    """A deterministic (2, 4, 4) prepared batch for tracing dispatches."""
    from ..core.compaction import spec_fns

    b, mn = 2, 4
    c = np.linspace(0.0, 1.0, b * mn * mn, dtype=np.float32)
    inputs = {"c": c.reshape(b, mn, mn),
              "nu": np.full((b, mn), 1.0 / mn, np.float32),
              "mu": np.full((b, mn), 1.0 / mn, np.float32)}
    p = SINKHORN.prepare(SINKHORN.canonicalize(inputs), 0.25)
    prologue, init, chunk, conv, _ = spec_fns(SINKHORN, 2)
    ops = {kk: jnp.asarray(v) for kk, v in p.ops.items()}
    data, ctx = prologue(ops)
    state = init(data, ctx)
    return chunk, conv, data, state


def _trace_sinkhorn_chunk():
    chunk, _, data, state = _tiny_sinkhorn_batch()
    return _audit.trace_entry(
        name="portfolio.sinkhorn.chunk[sinkhorn]",
        fn=chunk,
        args={"data": data, "state": state},
        donated={"state"},
        tags={"chunk-dispatch", "sinkhorn"},
        source=__name__,
    )


def _trace_sinkhorn_conv():
    _, conv, data, state = _tiny_sinkhorn_batch()
    return _audit.trace_entry(
        name="portfolio.sinkhorn.conv[sinkhorn]",
        fn=conv,
        args={"data": data, "state": state},
        tags={"conv-dispatch", "sinkhorn"},
        source=__name__,
    )


def _trace_sinkhorn_state_chain():
    m = n = 8

    def chain(c, nu, mu, reg, tol):
        data, ctx = SINKHORN.prologue({
            "c": c, "nu": nu, "mu": mu, "reg": reg, "tol": tol,
            "phase_cap": jnp.int32(64)})
        state = SINKHORN.init_state(data, ctx)
        return {"state": state,
                "retained": {"c_hat": data["c_hat"],
                             "log_nu": data["log_nu"],
                             "nu_hat": data["nu_hat"],
                             "scale": ctx["scale"]}}

    return _audit.trace_entry(
        name="portfolio.sinkhorn.state_chain",
        fn=chain,
        args={
            "c": jnp.zeros((m, n), jnp.float32),
            "nu": jnp.full((m,), 1.0 / m, jnp.float32),
            "mu": jnp.full((n,), 1.0 / n, jnp.float32),
            "reg": jnp.float32(0.02),
            "tol": jnp.float32(0.01),
        },
        retained={"c", "nu", "mu"},
        tags={"state-init-chain", "sinkhorn"},
        source=__name__,
    )


def _trace_run_phases():
    """The stepped core itself, with the recompile-hazard contract: the
    host-f64-derived schedule (reg/tol/phase_cap) must arrive as TRACED
    operands — baking any of them into the program would recompile per
    accuracy, the hazard class ``must_trace`` exists to pin."""
    m = n = 8
    state = SinkhornState(
        f=jnp.zeros((m,), jnp.float32), g=jnp.zeros((n,), jnp.float32),
        err=jnp.asarray(jnp.inf, jnp.float32),
        phases=jnp.zeros((), jnp.int32))

    def run(c_hat, log_nu, log_mu, nu_hat, reg, tol, phase_cap, state):
        return run_sinkhorn_phases(c_hat, log_nu, log_mu, nu_hat, reg,
                                   tol, phase_cap, state, 3)

    return _audit.trace_entry(
        name="portfolio.sinkhorn.run_sinkhorn_phases",
        fn=run,
        args={
            "c_hat": jnp.zeros((m, n), jnp.float32),
            "log_nu": jnp.full((m,), -np.log(m), jnp.float32),
            "log_mu": jnp.full((n,), -np.log(n), jnp.float32),
            "nu_hat": jnp.full((m,), 1.0 / m, jnp.float32),
            "reg": jnp.float32(0.02),
            "tol": jnp.float32(0.01),
            "phase_cap": jnp.int32(64),
            "state": state,
        },
        donated={"state"},
        must_trace={"reg", "tol", "phase_cap"},
        tags={"stepped-core", "sinkhorn"},
        source=__name__,
    )


_audit.register("portfolio.sinkhorn.run_sinkhorn_phases",
                _trace_run_phases, source=__name__)
_audit.register("portfolio.sinkhorn.chunk[sinkhorn]",
                _trace_sinkhorn_chunk, source=__name__)
_audit.register("portfolio.sinkhorn.conv[sinkhorn]",
                _trace_sinkhorn_conv, source=__name__)
_audit.register("portfolio.sinkhorn.state_chain",
                _trace_sinkhorn_state_chain, source=__name__)
