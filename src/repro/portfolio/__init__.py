"""Solver portfolio: Sinkhorn as a ProblemSpec, a measured cost model
for ``DispatchPolicy(solver="auto")``, and the hybrid Sinkhorn ->
push-relabel warm start. ``core/api`` imports this package lazily when a
policy routes away from the default solver, so the core stays
import-light for pure push-relabel traffic."""
from .costmodel import (  # noqa: F401
    SOLVERS,
    CostModel,
    choose,
    fit,
    get_model,
    set_model,
)
from .hybrid import WARM_OT, dispatch_hybrid, round_duals  # noqa: F401
from .sinkhorn_spec import (  # noqa: F401
    SINKHORN,
    SINKHORN_KERNEL,
    SinkhornSpec,
    sinkhorn_schedule,
)
