"""Hybrid solver: coarse Sinkhorn duals warm-start the push-relabel core.

The portfolio's third solver exploits that the two base solvers price the
SAME dual: Sinkhorn's log-domain potentials (f, g) on the normalized
costs c_hat = c/max(c) are, after scaling, exactly the eps-units the
push-relabel integer duals live in. A cheap low-accuracy Sinkhorn run
(eps clamped loose, iteration-capped) therefore produces an initial
``y_b`` that starts the push-relabel solve much closer to termination
than the paper's cold y(b) = 1 — and because the finish IS the
push-relabel solver, the result keeps the paper's <= OPT + eps * m bound
(``guaranteed=True`` certifies exactly as a pure push-relabel solve).

Correctness does not rest on the Sinkhorn duals being any good:
``round_duals`` CLIPS the rounded warm duals into the invariant polytope

    1 <= y_b(b) <= min_{a live} c_int(b, a) + 1          (I1 + I2, y_a = 0)

so every invariant the paper's analysis needs (core/feasibility.py
checks them: I1, I2, the Lemma 3.2 dual bound) holds by construction no
matter what stage 1 returned — a garbage warm start only costs phases,
never correctness. tests/test_portfolio.py asserts this via
``check_ot_invariants`` on the warm state and via cost/feasibility
parity with the cold-start solver.

``WARM_OT`` is a four-line OTSpec subclass: same prologue, phases,
convergence, epilogue — only ``init_state`` seeds ``y_b`` from the extra
``y_b0`` operand. It rides every driver (lockstep / compact / mesh)
because the drivers forward ``**prep_kw`` and the spec pads the operand
like any other lane array.
"""
from __future__ import annotations

from dataclasses import replace as _dc_replace

import jax
import jax.numpy as jnp
import numpy as np

from ..core.compaction import DEFAULT_CHUNK, solve_compacting
from ..core.problem import (
    OTSpec,
    PreparedBatch,
    _pad_lanes,
    eps_array,
)
from ..core.transport import init_ot_state, ot_phase_cap
from .sinkhorn_spec import SINKHORN

# Columns with no demand never constrain the row dual; stand-in "+inf"
# for the int32 min-reduction over live columns.
_INT_BIG = np.int32(2 ** 30)
# Stage-1 accuracy/effort: the warm start needs direction, not
# convergence. eps is clamped to at least this ...
_COARSE_EPS = 0.25
# ... and the Sinkhorn sweep count is capped outright.
_WARM_ITERS = 64


def _round_duals_one(c, mu, f, g, eps):
    """One instance: scaled-integer feasible y_b from Sinkhorn (f, g).

    f, g live on the normalized costs (c/scale); integer duals live in
    units of eps on the same normalization, so f/eps is the natural
    rounding. The column potential is absorbed conservatively (g's max
    over live columns) and the result clipped to [1, min_live c_int + 1]
    — with y_a = 0 (the cold-start value) that clip alone implies I1,
    I2, and the Lemma 3.2 bound (c_hat <= 1 => c_int <= floor(1/eps)).
    All clamping happens in the integer domain: no int -> float -> int
    round-trip for the precision audit to flag."""
    scale = jnp.maximum(jnp.max(c), 1e-30)
    c_int = jnp.floor(c / scale / eps).astype(jnp.int32)  # == ot_prologue
    live = mu > 0
    any_live = jnp.any(live)
    gmax = jnp.max(jnp.where(live, g, -jnp.inf))
    y_raw = jnp.floor((f + gmax) / eps).astype(jnp.int32) + 1
    cap = jnp.min(jnp.where(live[None, :], c_int, _INT_BIG), axis=1) + 1
    y_b = jnp.clip(y_raw, jnp.int32(1), cap)
    # no live demand (empty padded lane): cold-start value
    return jnp.where(any_live, y_b, jnp.int32(1))


@jax.jit
def round_duals(c, mu, f, g, eps):
    """(B, m) int32 warm row duals from batched Sinkhorn potentials.
    ``eps`` is the (B,) INTERNAL accuracy of the finishing solve (i.e.
    already divided by 3 under ``guaranteed``) — the integer grid the
    push-relabel instance is rounded on."""
    return jax.vmap(_round_duals_one)(c, mu, f, g, eps)


class _WarmOTSpec(OTSpec):
    """OTSpec whose initial state takes ``y_b`` from a ``y_b0`` operand
    (cold-start 1s when absent, so the spec degrades to plain OT)."""

    name = "warm_ot"

    def prepare(self, inputs, eps, *, sizes=None, guaranteed: bool = False,
                min_batch: int = 1, theta=None, y_b0=None) -> PreparedBatch:
        p = super().prepare(inputs, eps, sizes=sizes, guaranteed=guaranteed,
                            min_batch=min_batch, theta=theta)
        b, m, _ = inputs["c"].shape
        if y_b0 is None:
            y_b0 = np.ones((b, m), np.int32)
        ops = dict(p.ops)
        # padded lanes warm-start at the cold value (they are born
        # converged; the fill just keeps the state invariant-clean)
        ops.update(_pad_lanes(p.bp, b,
                              {"y_b0": jnp.asarray(y_b0, jnp.int32)},
                              fills={"y_b0": np.int32(1)}))
        return PreparedBatch(ops=ops, threshold=p.threshold,
                             phase_cap=p.phase_cap, eps_arr=p.eps_arr,
                             bp=p.bp)

    ctx_ops = OTSpec.ctx_ops + ("y_b0",)

    def init_state(self, data, ctx):
        st = init_ot_state(ctx["s_int"], ctx["d_int"])
        # fresh buffer: the chunk dispatch donates the state, and
        # ctx["y_b0"] is retained for the epilogue's ctx pytree — an
        # aliased init would free it out from under that dispatch
        return st._replace(y_b=jnp.array(ctx["y_b0"], jnp.int32,
                                         copy=True))

    def solve_lockstep(self, inputs, eps: float, *, sizes=None,
                       guaranteed: bool = False, keep_state: bool = False,
                       theta=None, y_b0=None):
        # one compacting dispatch with k above the phase cap — lockstep
        # semantics without teaching core/batched a warm-start operand
        # (same trick as the fused and sinkhorn specs)
        b = int(np.shape(inputs["c"])[0])
        eps_arr = eps_array(eps, b, guaranteed)
        k_all = max(ot_phase_cap(float(e)) for e in eps_arr) + 1
        r, stats = solve_compacting(
            self, inputs, eps, sizes=sizes, k=k_all, guaranteed=guaranteed,
            keep_state=keep_state, theta=theta, y_b0=y_b0)
        return r, (stats.final_state if keep_state else None)


WARM_OT = _WarmOTSpec()


def dispatch_hybrid(
    inputs,
    eps,
    *,
    sizes=None,
    policy=None,
    keep_state: bool = False,
    deadline=None,
    obs=None,
    theta=None,
    warm_iters: int = _WARM_ITERS,
):
    """Solve one pre-batched OT bucket hybrid-style: a coarse
    iteration-capped Sinkhorn stage (always batch-compact — it is the
    cheap stage), dual rounding, then the push-relabel finish dispatched
    under ``policy``'s mode/mesh/chunk with the warm ``y_b0``. Returns
    ``(OTResult, stats)`` with the finish driver's stats; stage-1
    dispatches are folded into ``stats.dispatches``."""
    from ..core.api import DispatchPolicy, dispatch

    policy = policy or DispatchPolicy()
    inputs = WARM_OT.canonicalize(inputs)
    b = int(inputs["c"].shape[0])
    eps_user = np.broadcast_to(np.asarray(eps, np.float64), (b,)).copy()

    # stage 1: coarse Sinkhorn, capped sweeps, state retained
    eps_coarse = np.maximum(eps_user, _COARSE_EPS)
    _, st1 = solve_compacting(
        SINKHORN, inputs, eps_coarse, sizes=sizes,
        k=policy.chunk or DEFAULT_CHUNK, keep_state=True,
        deadline=deadline, obs=obs, max_iters=warm_iters)
    warm = st1.final_state

    # stage 2: round the potentials onto the finish solve's integer grid
    # (the INTERNAL eps: /3 under the guaranteed contract). The rounding
    # sees the same masked operands the specs' prepare builds, because
    # stage 1 ran on the canonicalized inputs whose padding the Sinkhorn
    # prologue already zeroed via its prepare masks — f/g outside the
    # valid block are inert and the clip bounds them anyway.
    eps_int = jnp.asarray(eps_array(eps_user, b, policy.guaranteed),
                          jnp.float32)
    y_b0 = round_duals(inputs["c"], inputs["mu"], warm.f, warm.g, eps_int)

    # stage 3: push-relabel finish under the caller's dispatch policy
    finish = _dc_replace(policy, solver="pushrelabel", fused=False)
    r, stats = dispatch(WARM_OT, inputs, eps, sizes=sizes, policy=finish,
                        keep_state=keep_state, deadline=deadline, obs=obs,
                        theta=theta, y_b0=y_b0)
    if stats is not None:
        try:
            stats.dispatches += int(st1.dispatches)
        except (AttributeError, TypeError):
            pass
    return r, stats


# --------------------------------------------------------------------------
# repro.analysis registration: the warm-start state chain (donation
# safety: the seeded y_b must be a fresh buffer, not an alias of the
# retained y_b0 operand) and the dual rounding itself (eps must stay a
# traced operand; int-domain clamps keep the precision rules clean).
# --------------------------------------------------------------------------

from ..analysis import registry as _audit  # noqa: E402


def _trace_round_duals():
    b, m, n = 2, 4, 4
    return _audit.trace_entry(
        name="portfolio.hybrid.round_duals",
        fn=lambda c, mu, f, g, eps: {"y_b0": round_duals(c, mu, f, g,
                                                         eps)},
        args={
            "c": jnp.linspace(0.0, 1.0, b * m * n).reshape(b, m, n)
                 .astype(jnp.float32),
            "mu": jnp.full((b, n), 1.0 / n, jnp.float32),
            "f": jnp.zeros((b, m), jnp.float32),
            "g": jnp.zeros((b, n), jnp.float32),
            "eps": jnp.full((b,), 0.1, jnp.float32),
        },
        must_trace={"eps"},
        tags={"hybrid"},
        source=__name__,
    )


def _trace_warm_state_chain():
    m = n = 8

    def chain(c, nu, mu, theta, eps, y_b0):
        data, ctx = WARM_OT.prologue({
            "c": c, "nu": nu, "mu": mu, "theta": theta, "eps": eps,
            "threshold": jnp.int32(0), "phase_cap": jnp.int32(64)})
        ctx = {**ctx, "y_b0": y_b0}
        state = WARM_OT.init_state(data, ctx)
        return {"state": state,
                "retained": {"c_int": data["c_int"],
                             "s_int": ctx["s_int"],
                             "d_int": ctx["d_int"],
                             "y_b0": y_b0}}

    return _audit.trace_entry(
        name="portfolio.hybrid.warm_state_chain",
        fn=chain,
        args={
            "c": jnp.zeros((m, n), jnp.float32),
            "nu": jnp.full((m,), 1.0 / m, jnp.float32),
            "mu": jnp.full((n,), 1.0 / n, jnp.float32),
            "theta": jnp.float32(320.0),
            "eps": jnp.float32(0.1),
            "y_b0": jnp.ones((m,), jnp.int32),
        },
        retained={"c", "nu", "mu", "y_b0"},
        tags={"state-init-chain", "hybrid"},
        source=__name__,
    )


_audit.register("portfolio.hybrid.round_duals", _trace_round_duals,
                source=__name__)
_audit.register("portfolio.hybrid.warm_state_chain",
                _trace_warm_state_chain, source=__name__)
