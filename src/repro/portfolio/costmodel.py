"""Measured cost model behind ``DispatchPolicy(solver="auto")``.

The paper's experimental finding is a CROSSOVER: Sinkhorn wins at loose
eps (few iterations, cheap dense updates), push-relabel wins as eps
tightens (Sinkhorn's 1/eps^2 iteration bound explodes while push-relabel
scales ~1/eps). Where exactly the crossover sits depends on hardware,
n, and whether the Pallas kernels run compiled or in interpret mode — so
this module does not hard-code a rule of thumb. It fits per-
(solver, n-bucket, eps-band) wall-time coefficients from an actual
calibration run (``benchmarks/bench_portfolio.py --calibrate``) and
persists them as JSON with an honest ``mode`` label; ``choose`` is then
a table lookup, deterministic for a given loaded model.

The committed default table (``costmodel_default.json``) was measured in
this repo's CI container (interpret-mode Pallas, CPU backend). Refresh
it on real hardware with::

    PYTHONPATH=src python benchmarks/bench_portfolio.py --calibrate \
        --json src/repro/portfolio/costmodel_default.json

A model measured in a different mode than the current process (e.g. a
compiled-TPU table loaded under interpret mode) still loads — relative
solver ordering is usually preserved — but ``CostModel.mode`` says what
was measured so callers can tell.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_SCHEMA = 1
_DEFAULT_PATH = os.path.join(os.path.dirname(__file__),
                             "costmodel_default.json")
# Solvers the table may price. "hybrid" rows are measured end-to-end
# (coarse Sinkhorn + warm-started push-relabel finish).
SOLVERS = ("pushrelabel", "sinkhorn", "hybrid")


def _log_nearest(value: float, grid: np.ndarray) -> float:
    """The grid point nearest in log-space (both strictly positive)."""
    grid = np.asarray(grid, np.float64)
    i = int(np.argmin(np.abs(np.log(grid) - np.log(max(value, 1e-30)))))
    return float(grid[i])


@dataclass(frozen=True)
class CostModel:
    """Per-(solver, n-bucket, eps-band) measured per-instance seconds.

    ``entries`` maps (solver, n_bucket, eps_band) -> seconds. Lookup
    snaps the query (n, eps) to the nearest measured bucket/band in
    log-space — wall time is roughly power-law in both — and never
    extrapolates a formula: an unmeasured solver is simply absent and
    ``choose`` falls back to push-relabel (the only solver with the
    paper's guarantee at every eps).
    """
    mode: str                      # "interpret" | "compiled" (honest label)
    backend: str                   # jax backend the measurements ran on
    entries: Dict[Tuple[str, int, float], float]
    n_buckets: Tuple[int, ...] = field(default_factory=tuple)
    eps_bands: Tuple[float, ...] = field(default_factory=tuple)

    def predict(self, solver: str, n: int, eps: float) -> Optional[float]:
        """Predicted per-instance seconds, or None if the solver has no
        measurement anywhere near (snapping is within the table only)."""
        if not self.n_buckets or not self.eps_bands:
            return None
        nb = int(_log_nearest(float(max(n, 1)),
                              np.asarray(self.n_buckets, np.float64)))
        eb = _log_nearest(float(eps), np.asarray(self.eps_bands,
                                                 np.float64))
        return self.entries.get((solver, nb, eb))

    def choose(self, n: int, eps: float,
               allowed: Tuple[str, ...] = SOLVERS
               ) -> Tuple[str, Optional[float]]:
        """(cheapest measured solver, its predicted seconds). Falls back
        to ("pushrelabel", its prediction or None) when nothing in
        ``allowed`` was measured."""
        best, best_s = None, None
        for s in allowed:
            p = self.predict(s, n, eps)
            if p is not None and (best_s is None or p < best_s):
                best, best_s = s, p
        if best is None:
            return "pushrelabel", self.predict("pushrelabel", n, eps)
        return best, best_s

    # -- persistence ---------------------------------------------------

    def as_dict(self) -> dict:
        return {
            "schema": _SCHEMA,
            "mode": self.mode,
            "backend": self.backend,
            "n_buckets": list(self.n_buckets),
            "eps_bands": list(self.eps_bands),
            "entries": [
                {"solver": s, "n_bucket": nb, "eps_band": eb,
                 "per_instance_s": sec}
                for (s, nb, eb), sec in sorted(self.entries.items())
            ],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.as_dict(), fh, indent=1, sort_keys=True)
            fh.write("\n")

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        if int(d.get("schema", -1)) != _SCHEMA:
            raise ValueError(
                f"cost-model schema {d.get('schema')!r} != {_SCHEMA}")
        entries = {
            (str(e["solver"]), int(e["n_bucket"]), float(e["eps_band"])):
                float(e["per_instance_s"])
            for e in d["entries"]
        }
        return cls(mode=str(d["mode"]), backend=str(d["backend"]),
                   entries=entries,
                   n_buckets=tuple(int(x) for x in d["n_buckets"]),
                   eps_bands=tuple(float(x) for x in d["eps_bands"]))

    @classmethod
    def load(cls, path: str) -> "CostModel":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


def fit(measurements: List[dict], *, mode: str, backend: str) -> CostModel:
    """Fit a table from calibration records
    ``{"solver", "n", "eps", "per_instance_s"}``: bucket n to the
    nearest measured power of two, band eps to the measured grid, and
    take the MEDIAN per cell (robust to a single slow outlier dispatch;
    every cell typically holds repeat measurements)."""
    n_buckets = sorted({1 << int(round(np.log2(max(int(r["n"]), 1))))
                        for r in measurements})
    eps_bands = sorted({float(r["eps"]) for r in measurements})
    cells: Dict[Tuple[str, int, float], List[float]] = {}
    for r in measurements:
        nb = int(_log_nearest(float(r["n"]),
                              np.asarray(n_buckets, np.float64)))
        eb = _log_nearest(float(r["eps"]),
                          np.asarray(eps_bands, np.float64))
        cells.setdefault((str(r["solver"]), nb, eb), []).append(
            float(r["per_instance_s"]))
    entries = {k: float(np.median(v)) for k, v in cells.items()}
    return CostModel(mode=mode, backend=backend, entries=entries,
                     n_buckets=tuple(n_buckets),
                     eps_bands=tuple(eps_bands))


_ACTIVE: Optional[CostModel] = None
_DEFAULT_LOADED = False


def set_model(model: Optional[CostModel]) -> None:
    """Install ``model`` as the process-wide table ``solver="auto"``
    consults (None -> revert to the committed default)."""
    global _ACTIVE, _DEFAULT_LOADED
    _ACTIVE = model
    _DEFAULT_LOADED = model is not None


def get_model() -> Optional[CostModel]:
    """The active cost model: an installed one, else the committed
    default table (loaded lazily, once), else None."""
    global _ACTIVE, _DEFAULT_LOADED
    if not _DEFAULT_LOADED:
        _DEFAULT_LOADED = True
        if os.path.exists(_DEFAULT_PATH):
            try:
                _ACTIVE = CostModel.load(_DEFAULT_PATH)
            except (ValueError, KeyError, json.JSONDecodeError):
                _ACTIVE = None
    return _ACTIVE


def choose(n: int, eps: float,
           allowed: Tuple[str, ...] = SOLVERS
           ) -> Tuple[str, Optional[float]]:
    """Module-level convenience: route via the active model; with no
    model at all, push-relabel (the guaranteed solver) wins by default."""
    model = get_model()
    if model is None:
        return "pushrelabel", None
    return model.choose(n, eps, allowed)
