"""Entry-point registry for the static audit.

Solver modules self-register every jitted entry point (stepped cores,
compaction chunk dispatches, mesh dispatches, Pallas kernel wrappers,
Solution certificate reductions) by calling :func:`register` at import
time with a *lazy builder*: a zero-argument callable that traces the
entry to a ClosedJaxpr over representative tiny operands and returns a
:class:`TracedEntry`. Building is deferred until the CLI (or a test)
iterates the registry, so registration itself costs nothing at import.

The registry records, per entry, the audit-relevant contracts the jaxpr
alone cannot express:

  * ``donated``    — argument roots whose buffers the dispatch donates;
  * ``retained``   — argument roots Python code still reads AFTER the
                     dispatch (the donation-safety rule cross-checks the
                     two: the PR-3 bug class);
  * ``must_trace`` — operands that must enter the program as traced data,
                     never baked constants (eps, theta, thresholds,
                     masks: the recompile-churn bug class);
  * ``tags``       — rule-selection labels ("threshold", "certificate",
                     "state-init-chain", ...).

This module must not import ``repro.core`` (core modules import it to
self-register); jax is imported lazily inside the trace helper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, FrozenSet, Iterable, List, Tuple

# Modules that self-register entry points on import. ``load_all`` imports
# them so iterating the registry sees every entry regardless of what the
# caller happened to import first.
BUILTIN_MODULES: Tuple[str, ...] = (
    "repro.core.pushrelabel",
    "repro.core.transport",
    "repro.core.problem",
    "repro.core.compaction",
    "repro.core.distributed",
    "repro.core.solution",
    "repro.core.validate",
    "repro.kernels.ops",
    "repro.core.sinkhorn",
    "repro.portfolio.sinkhorn_spec",
    "repro.portfolio.hybrid",
)


@dataclass(frozen=True)
class TracedEntry:
    """One audited entry point, traced to a ClosedJaxpr.

    ``in_names``/``out_names`` are flat leaf names aligned with the
    jaxpr's invars/outvars (``state.free_b``, ``ops['c']``, ...); the
    contract sets (``donated``/``retained``/``must_trace``) hold argument
    ROOT names and are matched against leaf names by prefix."""
    name: str
    jaxpr: Any                      # jax.core.ClosedJaxpr
    in_names: Tuple[str, ...]
    out_names: Tuple[str, ...]
    arg_roots: Tuple[str, ...]
    donated: FrozenSet[str] = frozenset()
    retained: FrozenSet[str] = frozenset()
    must_trace: FrozenSet[str] = frozenset()
    tags: FrozenSet[str] = frozenset()
    source: str = ""

    def leaves_of(self, root: str, names: Iterable[str]) -> List[int]:
        """Indices in ``names`` of the leaves belonging to arg ``root``."""
        out = []
        for i, n in enumerate(names):
            if n == root or n.startswith(root + ".") or \
                    n.startswith(root + "["):
                out.append(i)
        return out


@dataclass(frozen=True)
class EntrySpec:
    name: str
    build: Callable[[], TracedEntry]
    source: str = ""


_REGISTRY: Dict[str, EntrySpec] = {}
_LOADED = False


def register(name: str, build: Callable[[], TracedEntry],
             source: str = "") -> None:
    """Register (or re-register) a lazy entry builder under ``name``."""
    _REGISTRY[name] = EntrySpec(name=name, build=build, source=source)


def load_all() -> None:
    """Import every builtin self-registering module exactly once."""
    global _LOADED
    if _LOADED:
        return
    import importlib

    for mod in BUILTIN_MODULES:
        importlib.import_module(mod)
    _LOADED = True


def entry_specs() -> List[EntrySpec]:
    load_all()
    return [spec for _, spec in sorted(_REGISTRY.items())]


def build_entries() -> List[TracedEntry]:
    """Trace every registered entry (the expensive step; CLI/test only)."""
    return [spec.build() for spec in entry_specs()]


# --------------------------------------------------------------------------
# Trace helper
# --------------------------------------------------------------------------

def _leaf_names(root: str, val: Any) -> List[str]:
    """Flat leaf names for one argument, in jax tree-flatten order
    (dict keys sorted; NamedTuple fields by position, named)."""
    if isinstance(val, tuple) and hasattr(val, "_fields"):
        out: List[str] = []
        for f, v in zip(val._fields, val):
            out += _leaf_names(f"{root}.{f}", v)
        return out
    if isinstance(val, dict):
        out = []
        for k in sorted(val):
            out += _leaf_names(f"{root}[{k!r}]", val[k])
        return out
    if isinstance(val, (tuple, list)):
        out = []
        for i, v in enumerate(val):
            out += _leaf_names(f"{root}[{i}]", v)
        return out
    return [root]


def _inline_trivial_call(closed):
    """make_jaxpr of a jitted fn yields a single opaque ``pjit`` eqn;
    descend into it (invars/outvars permitting) so rules see the body."""
    jaxpr = closed.jaxpr
    while (len(jaxpr.eqns) == 1
           and jaxpr.eqns[0].primitive.name == "pjit"
           and list(jaxpr.eqns[0].invars) == list(jaxpr.invars)
           and list(jaxpr.outvars) == list(jaxpr.eqns[0].outvars)):
        closed = jaxpr.eqns[0].params["jaxpr"]
        jaxpr = closed.jaxpr
    return closed


def trace_entry(
    name: str,
    fn: Callable,
    args: Dict[str, Any],
    *,
    donated: Iterable[str] = (),
    retained: Iterable[str] = (),
    must_trace: Iterable[str] = (),
    tags: Iterable[str] = (),
    source: str = "",
) -> TracedEntry:
    """Trace ``fn(*args.values())`` to a ClosedJaxpr and wrap it as a
    :class:`TracedEntry`. ``args`` is an ORDERED name->value mapping (its
    order is the positional order). Output leaf names come from the traced
    output's own structure: a dict output names leaves by its keys (so
    chain builders returning ``{"state": ..., "retained": ...}`` get
    ``state.*``/``retained[...]`` out-names the rules can group on)."""
    import jax

    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args.values())
    closed = _inline_trivial_call(closed)

    in_names: List[str] = []
    for root, val in args.items():
        in_names += _leaf_names(root, val)
    if len(in_names) != len(closed.jaxpr.invars):
        raise ValueError(
            f"{name}: flattened arg names ({len(in_names)}) do not match "
            f"jaxpr invars ({len(closed.jaxpr.invars)})")

    if isinstance(out_shape, dict):
        out_names = tuple(sum((_leaf_names(k, out_shape[k])
                               for k in sorted(out_shape)), []))
    else:
        out_names = tuple(_leaf_names("out", out_shape))
    if len(out_names) != len(closed.jaxpr.outvars):
        raise ValueError(
            f"{name}: out names ({len(out_names)}) do not match jaxpr "
            f"outvars ({len(closed.jaxpr.outvars)})")

    return TracedEntry(
        name=name,
        jaxpr=closed,
        in_names=tuple(in_names),
        out_names=out_names,
        arg_roots=tuple(args.keys()),
        donated=frozenset(donated),
        retained=frozenset(retained),
        must_trace=frozenset(must_trace),
        tags=frozenset(tags),
        source=source,
    )
