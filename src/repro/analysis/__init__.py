"""repro.analysis: jaxpr-level static audit + sanitizer layer for the solver
entry points.

The three worst bugs in this repo's history were silent device-semantics
bugs (see each rule's docstring in ``rules.py`` for the mapping):

  * PR 2: the OT termination threshold computed on device in f32 rounded
    the wrong way for some (eps, total_mass) pairs;
  * PR 3: ``init_ot_state`` aliased the caller's rounded masses into the
    donated solver state, so the first chunk dispatch deleted them out
    from under the epilogue;
  * recompile churn when eps leaked into a jit cache key as a Python
    scalar instead of riding along as traced data.

This package catches those classes statically: every jitted entry point
self-registers into ``registry``, the CLI (``python -m repro.analysis``)
traces each one to a ClosedJaxpr and runs the rule passes in ``rules.py``,
plus an AST hot-loop sync audit (``syncaudit.py``) and a lock-discipline
scan (``locks.py``). ``checkified.py`` provides the runtime companion: a
checkify-instrumented variant of the chunked phase dispatch, enabled with
``set_debug_checks(True)`` or ``REPRO_DEBUG_CHECKS=1``.

This module stays import-light on purpose: core modules import it (and
``registry``) at import time to self-register, so nothing here may import
back into ``repro.core``.
"""
from __future__ import annotations

import os

from . import registry  # noqa: F401  (re-export: the self-registration hub)

_DEBUG_CHECKS: bool | None = None


def debug_checks_enabled() -> bool:
    """Whether drivers should dispatch the checkify-instrumented stepped
    cores (``checkified.py``) instead of the plain donated ones. Off by
    default; enable programmatically (``set_debug_checks``) or via the
    ``REPRO_DEBUG_CHECKS`` environment variable."""
    if _DEBUG_CHECKS is not None:
        return _DEBUG_CHECKS
    return os.environ.get("REPRO_DEBUG_CHECKS", "").lower() not in (
        "", "0", "false", "off")


def set_debug_checks(enabled: bool | None) -> None:
    """Override the debug-checks flag (None restores the env-var default)."""
    global _DEBUG_CHECKS
    _DEBUG_CHECKS = enabled
