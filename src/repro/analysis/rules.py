"""Rule-based audit passes over traced entry points.

Each rule guards against a bug class this repo has actually shipped:

``donation-safety``
    PR 3: ``init_ot_state`` built ``free_b = s_int.astype(int32)``; the
    same-dtype astype is elided, so the state output ALIASED the caller's
    rounded masses. ``run_ot_phases`` donates the state, so the first
    chunk dispatch deleted ``s_int`` out from under the epilogue. The rule
    flags (a) any state-init output that aliases a retained input/output
    at the jaxpr level (the buffer-sharing proxy: an output var reachable
    from the aliased var through identity-only equations), and (b) any
    entry whose registry contract both donates and retains an argument.

``dtype-drift``
    PR 2: the OT termination threshold computed ON DEVICE as
    ``f32(eps) * f32(total)`` rounds the wrong way for some (eps, total)
    pairs — e.g. eps=0.1, total=10 gives 1 in f32 but 0 in the host-f64
    contract. The rule flags float32 round-trips int -> f32 arithmetic ->
    int (the exact shape of that bug) in any entry, plus — for
    ``certificate``-tagged reductions — weak-typed float literals mixed
    into the arithmetic (silent promotion hazards) and f32 accumulations
    (reported so the accepted ones are explicit baseline entries).

``recompile-hazard``
    eps leaked as a Python scalar bakes a constant into the jaxpr: every
    distinct value compiles a fresh program and the pow2 bucket ladder
    churns the jit cache. The rule checks every ``must_trace`` operand is
    (a) an actual input of the traced program and (b) used by it. The
    dynamic half (one compiled program per (shape, k, B) across a bucket
    descent) lives in ``cli.audit_bucket_ladder``.

The hot-loop sync audit (rule 4) is AST-based and lives in
``syncaudit.py``; lock discipline in ``locks.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Set, Tuple

from .registry import TracedEntry


@dataclass(frozen=True)
class Finding:
    rule: str
    entry: str
    detail: str          # stable discriminator (no line numbers)
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.entry}:{self.detail}"

    def __str__(self) -> str:
        return f"[{self.rule}] {self.entry}: {self.message}"


# --------------------------------------------------------------------------
# jaxpr walking helpers
# --------------------------------------------------------------------------

_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr",
                  "branches")


def _sub_jaxprs(params: Dict[str, Any]):
    from jax.extend import core as jex_core  # noqa: F401

    for key in _SUBJAXPR_KEYS:
        if key not in params:
            continue
        val = params[key]
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            inner = getattr(v, "jaxpr", v)   # ClosedJaxpr -> Jaxpr
            if hasattr(inner, "eqns"):
                yield inner


def iter_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every nested sub-jaxpr (while/cond/pjit/scan
    bodies), depth-first."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    yield inner
    for eqn in inner.eqns:
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_jaxprs(sub)


def _is_literal(v) -> bool:
    return hasattr(v, "val") and not hasattr(v, "count")


def _identity_eqn(eqn) -> bool:
    """Equations XLA may lower to a buffer alias (identity chains for the
    donation-safety rule). ``copy`` is deliberately NOT here — inserting
    one is exactly how the PR-3 fix breaks the alias."""
    name = eqn.primitive.name
    if len(eqn.invars) != 1 or len(eqn.outvars) != 1 or \
            _is_literal(eqn.invars[0]):
        return False
    iv, ov = eqn.invars[0], eqn.outvars[0]
    if name == "convert_element_type":
        return iv.aval.dtype == ov.aval.dtype
    if name in ("reshape", "squeeze", "expand_dims"):
        return iv.aval.shape == ov.aval.shape
    if name == "broadcast_in_dim":
        return iv.aval.shape == ov.aval.shape
    return False


def _alias_origin(jaxpr) -> Dict[Any, Any]:
    """Map each var of the TOP-LEVEL jaxpr to the var it may alias:
    itself for invars, or the transitive source through identity-only
    equations. Vars produced by real computation map to themselves."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    origin: Dict[Any, Any] = {}
    for v in list(inner.invars) + list(inner.constvars):
        origin[v] = v
    for eqn in inner.eqns:
        if _identity_eqn(eqn):
            src = eqn.invars[0]
            origin[eqn.outvars[0]] = origin.get(src, src)
        else:
            for ov in eqn.outvars:
                origin[ov] = ov
    return origin


# --------------------------------------------------------------------------
# Rule 1: donation safety
# --------------------------------------------------------------------------

def rule_donation_safety(entry: TracedEntry) -> List[Finding]:
    findings: List[Finding] = []

    # (b) contract-level: a donated argument the caller also retains is
    # read-after-free by construction (the PR-3 symptom at the driver
    # level: Python code touching a donated buffer after dispatch).
    for root in sorted(entry.donated & entry.retained):
        findings.append(Finding(
            rule="donation-safety", entry=entry.name,
            detail=f"donated-retained:{root}",
            message=(f"argument '{root}' is DONATED by the dispatch but "
                     "declared retained (read by host code afterwards): "
                     "the dispatch deletes the buffer out from under the "
                     "reader"),
        ))

    # (a) jaxpr-level: in a state-init chain, a 'state.*' output aliasing
    # a retained input or a 'retained*' output shares its buffer with it;
    # the downstream donating run_phases then frees both.
    if "state-init-chain" in entry.tags:
        inner = entry.jaxpr.jaxpr
        origin = _alias_origin(entry.jaxpr)
        invar_of = {v: entry.in_names[i]
                    for i, v in enumerate(inner.invars)}
        retained_in = {v for i, v in enumerate(inner.invars)
                       for root in entry.retained
                       if i in entry.leaves_of(root, entry.in_names)}
        out_origin = [(entry.out_names[i], origin.get(v, v))
                      for i, v in enumerate(inner.outvars)
                      if not _is_literal(v)]
        retained_out_origins = {
            o for n, o in out_origin if n.startswith("retained")}
        for n, o in out_origin:
            if not n.startswith("state"):
                continue
            if o in retained_in:
                findings.append(Finding(
                    rule="donation-safety", entry=entry.name,
                    detail=f"alias:{n}",
                    message=(f"state output '{n}' aliases retained input "
                             f"'{invar_of[o]}' (identity chain, no copy): "
                             "the donating chunk dispatch will delete the "
                             "retained buffer — insert jnp.array(..., "
                             "copy=True) as in init_ot_state"),
                ))
            elif o in retained_out_origins:
                findings.append(Finding(
                    rule="donation-safety", entry=entry.name,
                    detail=f"alias:{n}",
                    message=(f"state output '{n}' aliases a retained "
                             "output of the same program (shared origin, "
                             "identity chain): the donating chunk "
                             "dispatch will delete the retained buffer — "
                             "insert jnp.array(..., copy=True) as in "
                             "init_ot_state"),
                ))
    return findings


# --------------------------------------------------------------------------
# Rule 2: dtype drift
# --------------------------------------------------------------------------

_F32_WALK_PRIMS = {"mul", "add", "sub", "div", "neg", "max", "min",
                   "reduce_sum", "reduce_max", "reduce_min", "floor",
                   "ceil", "round"}
_FLOATS = ("float16", "bfloat16", "float32")


def _producers(jaxpr) -> Dict[Any, Any]:
    prod = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            prod[ov] = eqn
    return prod


def _f32_roundtrips(jaxpr) -> Iterable[str]:
    """Yield descriptions of int -> small-float arithmetic -> int round
    trips within one jaxpr body (the PR-2 threshold bug shape)."""
    prod = _producers(jaxpr)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name != "convert_element_type":
            continue
        iv, ov = eqn.invars[0], eqn.outvars[0]
        if _is_literal(iv):
            continue
        if str(iv.aval.dtype) not in _FLOATS or \
                ov.aval.dtype.kind not in "iu":
            continue
        # walk float arithmetic upstream looking for an int->float convert
        seen: Set[Any] = set()
        frontier = [iv]
        passed_arith = False
        for _ in range(8):
            nxt = []
            for v in frontier:
                e = prod.get(v)
                if e is None or id(e) in seen:
                    continue
                seen.add(id(e))
                name = e.primitive.name
                if name == "convert_element_type":
                    src = e.invars[0]
                    if not _is_literal(src) and passed_arith and \
                            src.aval.dtype.kind in "iu":
                        yield (f"int -> {iv.aval.dtype} arithmetic -> "
                               f"{ov.aval.dtype} round trip")
                        return
                    if not _is_literal(src):
                        nxt.append(src)
                elif name in _F32_WALK_PRIMS:
                    passed_arith = True
                    nxt.extend(x for x in e.invars if not _is_literal(x))
            frontier = nxt
            if not frontier:
                break


def rule_dtype_drift(entry: TracedEntry) -> List[Finding]:
    findings: List[Finding] = []
    for sub in iter_jaxprs(entry.jaxpr):
        for desc in _f32_roundtrips(sub):
            findings.append(Finding(
                rule="dtype-drift", entry=entry.name,
                detail="f32-int-roundtrip",
                message=(f"{desc}: device small-float arithmetic feeding "
                         "an integer (termination-threshold shape) rounds "
                         "differently from the host-float64 contract for "
                         "some operand values — compute the threshold on "
                         "host in float64 (ot_termination_threshold) and "
                         "pass it in as traced data"),
            ))
            break   # one per entry is enough signal
        else:
            continue
        break

    # weakly-typed float outputs leak promotion behavior to callers
    for i, v in enumerate(entry.jaxpr.jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and getattr(aval, "weak_type", False) and \
                getattr(aval, "dtype", None) is not None and \
                aval.dtype.kind == "f":
            findings.append(Finding(
                rule="dtype-drift", entry=entry.name,
                detail=f"weak-out:{entry.out_names[i]}",
                message=(f"output '{entry.out_names[i]}' is weakly typed "
                         "float: downstream promotion depends on the "
                         "consumer — anchor the dtype explicitly"),
            ))

    if "certificate" in entry.tags:
        # weak float literals inside certificate arithmetic promote
        # silently if the operand dtype ever changes
        found_weak = set()
        for sub in iter_jaxprs(entry.jaxpr):
            for eqn in sub.eqns:
                for v in eqn.invars:
                    if _is_literal(v) and \
                            getattr(v.aval, "weak_type", False) and \
                            v.aval.dtype.kind == "f":
                        found_weak.add(eqn.primitive.name)
        for prim in sorted(found_weak):
            findings.append(Finding(
                rule="dtype-drift", entry=entry.name,
                detail=f"weak-literal:{prim}",
                message=(f"weakly-typed float literal feeds '{prim}' in a "
                         "certificate reduction: use jnp.float32(...) so "
                         "the arithmetic dtype cannot drift with the "
                         "operand"),
            ))
        # f32 accumulation: the certificate contract is host-f64; device
        # f32 sums are ACCEPTED (x64 is disabled on device) but must be
        # explicit baseline entries, not silent.
        for sub in iter_jaxprs(entry.jaxpr):
            if any(e.primitive.name == "reduce_sum"
                   and str(e.outvars[0].aval.dtype) in _FLOATS
                   for e in sub.eqns):
                findings.append(Finding(
                    rule="dtype-drift", entry=entry.name,
                    detail="f32-accum",
                    message=("certificate reduction accumulates in "
                             "float32 on device (host contract is "
                             "float64): acceptable only as an explicit "
                             "baseline entry"),
                ))
                break
    return findings


# --------------------------------------------------------------------------
# Rule 3: recompile hazard
# --------------------------------------------------------------------------

def rule_recompile_hazard(entry: TracedEntry) -> List[Finding]:
    findings: List[Finding] = []
    roots = set(entry.arg_roots)
    for name in sorted(entry.must_trace - roots):
        findings.append(Finding(
            rule="recompile-hazard", entry=entry.name,
            detail=f"baked:{name}",
            message=(f"must-trace operand '{name}' is not an input of the "
                     "traced program — it was baked in as a compile-time "
                     "constant, so every distinct value recompiles "
                     "(compile-cache churn across the bucket ladder)"),
        ))

    # a must-trace input that exists but is never consumed usually means
    # the kernel read a baked copy from somewhere else
    used: Set[Any] = set()
    for sub in iter_jaxprs(entry.jaxpr):
        for eqn in sub.eqns:
            used.update(v for v in eqn.invars if not _is_literal(v))
        used.update(v for v in sub.outvars if not _is_literal(v))
    inner = entry.jaxpr.jaxpr
    for root in sorted(entry.must_trace & roots):
        idxs = entry.leaves_of(root, entry.in_names)
        if idxs and not any(inner.invars[i] in used for i in idxs):
            findings.append(Finding(
                rule="recompile-hazard", entry=entry.name,
                detail=f"unused:{root}",
                message=(f"must-trace operand '{root}' enters the program "
                         "but is never used — the value most likely got "
                         "baked into the jaxpr elsewhere as a constant"),
            ))
    return findings


RULES = (rule_donation_safety, rule_dtype_drift, rule_recompile_hazard)


def audit_entry(entry: TracedEntry) -> List[Finding]:
    out: List[Finding] = []
    for rule in RULES:
        out.extend(rule(entry))
    return out


def audit_entries(entries: Iterable[TracedEntry]
                  ) -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    n = 0
    for e in entries:
        n += 1
        findings.extend(audit_entry(e))
    return findings, n
