"""Rule 4: hot-loop sync audit (AST-based).

The compacting drivers' value proposition is that the phase loops never
synchronize with the host: the ONLY device->host transfer allowed inside
a chunk loop is the per-chunk converged-mask fetch (which doubles as the
phase-counter fetch — the two ride one ``jax.device_get``). This repo
once paid a second hidden sync per chunk fetching ``state.phases``
separately; this audit pins the contract so it cannot regress.

The scan parses the driver module, finds the registered loop functions
(``compaction._drive``, ``distributed._drive_distributed``), and flags
every host-transfer marker inside a ``for``/``while`` body:

  * ``np.asarray(...)`` / ``np.array(...)`` on device values,
  * ``jax.device_get(...)``,
  * ``.block_until_ready()`` / ``.item()``.

Whitelisted: a ``jax.device_get`` whose result is unpacked as
``conv, ph = ...`` — the one sanctioned converged-mask (+ phases) fetch.
(``jnp.asarray`` / ``jax.device_put`` are host->device and stay legal.)
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from .rules import Finding

_NP_CALLS = {"asarray", "array"}
_METHOD_CALLS = {"item", "block_until_ready"}
_ALLOWED_TARGETS = (("conv", "ph"),)


@dataclass(frozen=True)
class SyncTarget:
    path: str           # module file path
    func: str           # function whose loops are audited
    label: str          # entry label used in finding keys


def _call_marker(node: ast.Call) -> Optional[str]:
    f = node.func
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name):
            if f.value.id == "np" and f.attr in _NP_CALLS:
                return f"np.{f.attr}"
            if f.value.id == "jax" and f.attr == "device_get":
                return "jax.device_get"
        if f.attr in _METHOD_CALLS:
            return f".{f.attr}()"
    if isinstance(f, ast.Name) and f.id == "device_get":
        return "device_get"
    return None


def _assign_targets(node: ast.Assign) -> Optional[Tuple[str, ...]]:
    if len(node.targets) != 1:
        return None
    t = node.targets[0]
    if isinstance(t, ast.Tuple) and all(
            isinstance(e, ast.Name) for e in t.elts):
        return tuple(e.id for e in t.elts)
    if isinstance(t, ast.Name):
        return (t.id,)
    return None


def _scan_loop_body(loop: ast.AST, label: str, func: str) -> List[Finding]:
    findings: List[Finding] = []
    whitelisted: set = set()
    for node in ast.walk(loop):
        if isinstance(node, ast.Assign):
            targets = _assign_targets(node)
            if targets in _ALLOWED_TARGETS and \
                    isinstance(node.value, ast.Call) and \
                    _call_marker(node.value) in ("jax.device_get",
                                                 "device_get"):
                whitelisted.add(id(node.value))
    for node in ast.walk(loop):
        if not isinstance(node, ast.Call):
            continue
        marker = _call_marker(node)
        if marker is None or id(node) in whitelisted:
            continue
        findings.append(Finding(
            rule="hot-loop-sync", entry=label,
            detail=f"{func}:{marker}:{ast.unparse(node)[:60]}",
            message=(f"host transfer '{ast.unparse(node)[:80]}' inside "
                     f"the chunk loop of {func} (line {node.lineno}): "
                     "only the converged-mask fetch (conv, ph = "
                     "jax.device_get(...)) is whitelisted — fold the "
                     "value into the conv dispatch or move it out of "
                     "the loop"),
        ))
    return findings


def audit_function_source(source: str, func: str, label: str
                          ) -> List[Finding]:
    """Audit every loop inside ``func`` of ``source``; also flags the
    function missing entirely (a rename must update the audit)."""
    tree = ast.parse(source)
    fn = next((n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
               and n.name == func), None)
    if fn is None:
        return [Finding(
            rule="hot-loop-sync", entry=label, detail=f"missing:{func}",
            message=(f"audited function '{func}' not found — update the "
                     "sync-audit target list to follow the rename"))]
    findings: List[Finding] = []
    seen: set = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.For, ast.While)):
            for f in _scan_loop_body(node, label, func):
                if f.key not in seen:      # nested loops are re-walked
                    seen.add(f.key)
                    findings.append(f)
    return findings


def audit_targets(targets: Sequence[SyncTarget]) -> List[Finding]:
    findings: List[Finding] = []
    for t in targets:
        with open(t.path, "r", encoding="utf-8") as fh:
            findings.extend(audit_function_source(fh.read(), t.func,
                                                  t.label))
    return findings


def default_targets() -> List[SyncTarget]:
    from repro.core import compaction, distributed

    return [
        SyncTarget(path=compaction.__file__, func="_drive",
                   label="core.compaction._drive"),
        SyncTarget(path=distributed.__file__, func="_drive_distributed",
                   label="core.distributed._drive_distributed"),
    ]
