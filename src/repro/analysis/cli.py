"""``python -m repro.analysis``: run every audit pass over the repo.

Passes, in order:

  1. jaxpr rules (``rules.py``) over every registered entry point;
  2. hot-loop sync audit (``syncaudit.py``) over the chunk-loop drivers;
  3. lock-discipline static scan (``locks.py``) over the serving layer;
  4. dynamic bucket-ladder audit: one compiled program per (shape, k, B)
     across a pow2 compaction descent, and eps-as-data (re-running with
     different eps values must not grow the jit cache).

Findings are filtered through the baseline suppressions
(``baseline.py``); ``--strict`` exits 1 on any unsuppressed finding or
stale baseline entry. This is the CI gate (the ``analysis`` job) and the
gate the upcoming fused-Pallas-kernel PR must pass.
"""
from __future__ import annotations

import argparse
from typing import List, Tuple

from . import registry
from .baseline import DEFAULT_BASELINE, apply_baseline, load_baseline
from .rules import Finding, audit_entries


def audit_bucket_ladder(spec_name: str = "assignment", b: int = 16,
                        mn: int = 8, k: int = 3) -> List[Finding]:
    """Dynamic recompile audit over a real compaction descent.

    Solves a skewed mixed-eps batch (half loose, half tight eps) with a
    DEDICATED chunk size ``k`` so the jit-cache deltas below are exact,
    then asserts:

      * exactly one compiled chunk program per batch bucket the pow2
        descent visited (one program per (shape, k, B));
      * a second identical solve compiles nothing new;
      * a third solve with DIFFERENT eps values compiles nothing new
        (eps is traced data, never a cache key).

    Debug checks are pinned OFF for the duration: the deltas below count
    the PLAIN chunk's programs, and under ``REPRO_DEBUG_CHECKS=1`` the
    driver dispatches the checkified cores instead (their cache
    discipline is covered by tests/test_checkify.py).
    """
    from . import _DEBUG_CHECKS, set_debug_checks

    prior = _DEBUG_CHECKS
    set_debug_checks(False)
    try:
        return _audit_bucket_ladder_plain(spec_name, b, mn, k)
    finally:
        set_debug_checks(prior)


def _audit_bucket_ladder_plain(spec_name: str, b: int, mn: int,
                               k: int) -> List[Finding]:
    import numpy as np

    from repro.core import compaction as C
    from repro.core.problem import ASSIGNMENT, OT

    spec = {"assignment": ASSIGNMENT, "ot": OT}[spec_name]
    fns = C.spec_fns(spec, k)
    chunk = fns[2]
    findings: List[Finding] = []

    rng = np.random.default_rng(0)
    c = rng.random((b, mn, mn)).astype(np.float32)
    eps = np.where(np.arange(b) < b // 2, 0.45, 0.02)
    inputs = {"c": c}
    if spec_name == "ot":
        inputs["nu"] = np.full((b, mn), 1.0 / mn, np.float32)
        inputs["mu"] = np.full((b, mn), 1.0 / mn, np.float32)

    base = chunk._cache_size()
    _, stats = C.solve_compacting(spec, inputs, eps, k=k)
    buckets = sorted({bb for bb, _ in stats.occupancy})
    compiled = chunk._cache_size() - base
    if len(buckets) < 2:
        findings.append(Finding(
            rule="recompile-hazard", entry=f"bucket-ladder[{spec_name}]",
            detail="no-descent",
            message=(f"the audit batch never descended (buckets "
                     f"{buckets}): the mixed-eps workload no longer "
                     "exercises the pow2 ladder — retune the audit"),
        ))
    if compiled != len(buckets):
        findings.append(Finding(
            rule="recompile-hazard", entry=f"bucket-ladder[{spec_name}]",
            detail="programs-per-bucket",
            message=(f"{compiled} chunk programs compiled for "
                     f"{len(buckets)} distinct batch buckets {buckets}: "
                     "expected exactly one program per (shape, k, B) — "
                     "something data-dependent leaked into the cache key"),
        ))
    for round_name, e in (("identical", eps),
                          ("different-eps", eps * 0.9)):
        before = chunk._cache_size()
        C.solve_compacting(spec, inputs, e, k=k)
        grew = chunk._cache_size() - before
        if grew:
            findings.append(Finding(
                rule="recompile-hazard",
                entry=f"bucket-ladder[{spec_name}]",
                detail=f"retrace:{round_name}",
                message=(f"re-solving ({round_name}) compiled {grew} new "
                         "chunk programs: the descent must reuse every "
                         "bucket's program — eps or another traced "
                         "operand leaked into the jit cache key"),
            ))
    return findings


def collect_findings(dynamic: bool = True
                     ) -> Tuple[List[Finding], List[str]]:
    """All findings plus human-readable coverage lines."""
    from . import locks, syncaudit

    report: List[str] = []
    findings: List[Finding] = []

    entries = registry.build_entries()
    fs, n = audit_entries(entries)
    findings += fs
    report.append(f"jaxpr rules: {n} entry points audited")

    sync_targets = syncaudit.default_targets()
    findings += syncaudit.audit_targets(sync_targets)
    report.append("hot-loop sync audit: "
                  + ", ".join(t.label for t in sync_targets))

    for t in locks.default_targets():
        fs = locks.scan_lock_discipline(t)
        findings += fs
        if t.lock_attr is None:
            report.append(f"lock scan: {t.class_name} exempt ({t.note})")
        else:
            report.append(f"lock scan: {t.class_name} "
                          f"({len(t.fields)} shared fields)")

    if dynamic:
        findings += audit_bucket_ladder()
        report.append("bucket-ladder audit: one program per (shape, k, B)")
    return findings, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level static audit of the solver entry points")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any unsuppressed finding or stale "
                         "baseline entry")
    ap.add_argument("--no-dynamic", action="store_true",
                    help="skip the dynamic bucket-ladder audit")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline suppressions file")
    ap.add_argument("--list", action="store_true",
                    help="list registered entry points and exit")
    args = ap.parse_args(argv)

    if args.list:
        for spec in registry.entry_specs():
            print(spec.name)
        return 0

    findings, report = collect_findings(dynamic=not args.no_dynamic)
    baseline = load_baseline(args.baseline)
    active, suppressed, stale = apply_baseline(findings, baseline)

    for line in report:
        print(f"  {line}")
    if suppressed:
        print(f"{len(suppressed)} suppressed (baselined) finding(s):")
        for f, reason in suppressed:
            print(f"  {f.key}\n      accepted: {reason}")
    if stale:
        print(f"{len(stale)} STALE baseline entr(ies) matched nothing:")
        for key in stale:
            print(f"  {key}")
    if active:
        print(f"{len(active)} finding(s):")
        for f in active:
            print(f"  {f.key}\n      {f.message}")
    else:
        print("no unsuppressed findings")

    if args.strict and (active or stale):
        return 1
    return 0
