"""Baseline suppressions for known-accepted findings.

Format of ``baseline_suppressions.txt`` (one entry per line):

    <finding-key> -- <justification>

where ``<finding-key>`` is ``rule:entry:detail`` as printed by the CLI.
A justification is MANDATORY: an accepted finding with no recorded
reason is indistinguishable from a rotted suppression. Unused baseline
entries are reported (and fail ``--strict``) so the file cannot
accumulate dead keys as the code evolves.
"""
from __future__ import annotations

import os
from typing import Dict, Iterable, List, Tuple

from .rules import Finding

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "baseline_suppressions.txt")
_SEP = " -- "


def load_baseline(path: str = DEFAULT_BASELINE) -> Dict[str, str]:
    """key -> justification; raises on entries missing a justification."""
    out: Dict[str, str] = {}
    if not os.path.exists(path):
        return out
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, raw in enumerate(fh, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if _SEP not in line:
                raise ValueError(
                    f"{path}:{lineno}: baseline entry has no "
                    f"justification (expected '<key>{_SEP}<reason>'): "
                    f"{line!r}")
            key, reason = line.split(_SEP, 1)
            key, reason = key.strip(), reason.strip()
            if not reason:
                raise ValueError(
                    f"{path}:{lineno}: empty justification for {key!r}")
            out[key] = reason
    return out


def apply_baseline(
    findings: Iterable[Finding], baseline: Dict[str, str]
) -> Tuple[List[Finding], List[Tuple[Finding, str]], List[str]]:
    """Split findings into (active, suppressed-with-reason) and report
    baseline keys that matched nothing (stale entries)."""
    active: List[Finding] = []
    suppressed: List[Tuple[Finding, str]] = []
    used: set = set()
    for f in findings:
        if f.key in baseline:
            suppressed.append((f, baseline[f.key]))
            used.add(f.key)
        else:
            active.append(f)
    stale = sorted(set(baseline) - used)
    return active, suppressed, stale
