"""Lock-discipline layer for the serving front end.

``AsyncOTScheduler`` (serve/scheduler.py) shares mutable state between
the caller, the collate worker, and the dispatch worker; every access to
a shared field must hold ``self._lock``. This repo shipped three
unguarded accesses (stats mutations in the dispatch loop, the stranded
re-check in ``flush``, the belt-and-braces check in ``close``); this
module pins the discipline two ways:

  * a STATIC scan (:func:`scan_lock_discipline`): attributes every
    ``self.<field>`` access in the class body to its lexically enclosing
    ``with self._lock:`` block and flags unguarded ones. ``__init__`` is
    exempt (no concurrent reader exists before the workers start).
  * a RUNTIME proxy (:class:`GuardedAttrProxy`): wraps a shared object
    so every attribute touch asserts lock ownership
    (``Condition._is_owned``), recording violations for stress tests to
    assert empty.

Since the observability rework, scheduler *stats* live in lock-free
``repro.obs`` instruments (per-thread cells) rather than under
``_lock`` — the scan covers the remaining locked scheduler state plus
the locked pieces of ``repro.obs`` itself (``MetricsRegistry``'s
instrument table, ``JSONLSink``'s file handle, ``History``'s ring,
``TraceCapture``'s arming state).  The deliberately lock-free
instruments (``Counter``/``Gauge``/``Histogram``, ``InMemorySink``,
``Tracer``) are recorded as empty-field exemption targets so the audit
names WHY each one needs no lock.

``serve/engine.py``'s ``Engine``/``OTService`` are single-threaded by
contract (no worker threads, no lock); they are scanned with an empty
field set so the audit records the exemption explicitly.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

from .rules import Finding


@dataclass(frozen=True)
class LockTarget:
    path: str
    class_name: str
    fields: Tuple[str, ...]          # shared attrs needing the lock
    lock_attr: Optional[str]         # None -> single-threaded contract
    exempt_methods: Tuple[str, ...] = ("__init__",)
    note: str = ""


def _attr_root_field(node: ast.Attribute) -> Optional[str]:
    """For ``self.a.b.c`` return ``a``; None when the chain's root is not
    ``self``."""
    chain = []
    cur: ast.AST = node
    while isinstance(cur, ast.Attribute):
        chain.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name) and cur.id == "self":
        return chain[-1]
    return None


def _is_lock_with(node: ast.With, lock_attr: str) -> bool:
    for item in node.items:
        e = item.context_expr
        if isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name) \
                and e.value.id == "self" and e.attr == lock_attr:
            return True
    return False


def _scan_stmt(node: ast.AST, guarded: bool, target: LockTarget,
               method: str, findings: List[Finding], seen: set) -> None:
    if isinstance(node, ast.With) and target.lock_attr and \
            _is_lock_with(node, target.lock_attr):
        for child in ast.iter_child_nodes(node):
            _scan_stmt(child, True, target, method, findings, seen)
        return
    if isinstance(node, ast.Attribute):
        root = _attr_root_field(node)
        if root in target.fields and not guarded:
            key = (method, root)
            if key not in seen:
                seen.add(key)
                findings.append(Finding(
                    rule="lock-discipline",
                    entry=f"{target.class_name}.{method}",
                    detail=f"unguarded:{root}",
                    message=(f"access to shared field 'self.{root}' in "
                             f"{target.class_name}.{method} (line "
                             f"{node.lineno}) without holding "
                             f"self.{target.lock_attr}"),
                ))
    for child in ast.iter_child_nodes(node):
        _scan_stmt(child, guarded, target, method, findings, seen)


def scan_lock_discipline(target: LockTarget) -> List[Finding]:
    with open(target.path, "r", encoding="utf-8") as fh:
        tree = ast.parse(fh.read())
    cls = next((n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
                and n.name == target.class_name), None)
    if cls is None:
        return [Finding(
            rule="lock-discipline", entry=target.class_name,
            detail="missing-class",
            message=(f"audited class '{target.class_name}' not found in "
                     f"{target.path} — update the lock-scan target list"))]
    if target.lock_attr is None or not target.fields:
        return []   # single-threaded contract, recorded by the caller
    findings: List[Finding] = []
    for node in cls.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name in target.exempt_methods:
            continue
        seen: set = set()
        for child in ast.iter_child_nodes(node):
            _scan_stmt(child, False, target, node.name, findings, seen)
    return findings


def scan_class_source(source: str, target: LockTarget) -> List[Finding]:
    """Scan ``source`` directly (test fixtures); same semantics as
    :func:`scan_lock_discipline` minus the file read."""
    import os
    import tempfile

    fd, path = tempfile.mkstemp(suffix=".py")
    try:
        with os.fdopen(fd, "w") as fh:
            fh.write(source)
        return scan_lock_discipline(LockTarget(
            path=path, class_name=target.class_name, fields=target.fields,
            lock_attr=target.lock_attr,
            exempt_methods=target.exempt_methods, note=target.note))
    finally:
        os.unlink(path)


def default_targets() -> List[LockTarget]:
    from repro.obs import metrics, profiler, tracing
    from repro.serve import engine, scheduler

    # NOTE: scheduler stats moved off this list — they are lock-free
    # repro.obs instruments now (per-thread cells), not locked state.
    shared = ("_outstanding", "_pending", "_closed",
              "_close_called", "_submit_seq")
    return [
        LockTarget(path=scheduler.__file__, class_name="AsyncOTScheduler",
                   fields=shared, lock_attr="_lock"),
        LockTarget(path=engine.__file__, class_name="Engine", fields=(),
                   lock_attr=None,
                   note="single-threaded by contract (no worker threads)"),
        LockTarget(path=engine.__file__, class_name="OTService", fields=(),
                   lock_attr=None,
                   note="single-threaded by contract (no worker threads; "
                        "stats live in lock-free obs instruments)"),
        # repro.obs: the locked pieces...
        LockTarget(path=metrics.__file__, class_name="MetricsRegistry",
                   fields=("_instruments",), lock_attr="_lock",
                   note="lock guards instrument creation only; "
                        "observations go through lock-free instruments"),
        LockTarget(path=metrics.__file__, class_name="JSONLSink",
                   fields=("_fh",), lock_attr="_lock",
                   note="serialization outside the lock, write under it"),
        LockTarget(path=metrics.__file__, class_name="History",
                   fields=("_items",), lock_attr="_lock"),
        LockTarget(path=profiler.__file__, class_name="TraceCapture",
                   fields=("_dir", "_match", "_remaining", "_env_checked"),
                   lock_attr="_lock",
                   exempt_methods=("__init__", "_check_env_locked"),
                   note="_check_env_locked is called with _lock held by "
                        "every caller (locked-suffix naming convention)"),
        # ...and the deliberately lock-free pieces, recorded as audited
        # exemptions so the scan output names why each needs no lock.
        LockTarget(path=metrics.__file__, class_name="Counter", fields=(),
                   lock_attr=None,
                   note="per-thread cells; single-key dict update is "
                        "atomic under the GIL"),
        LockTarget(path=metrics.__file__, class_name="Gauge", fields=(),
                   lock_attr=None,
                   note="single attribute rebind is atomic"),
        LockTarget(path=metrics.__file__, class_name="Histogram", fields=(),
                   lock_attr=None,
                   note="per-thread cells; aggregation copies the cell map"),
        LockTarget(path=metrics.__file__, class_name="InMemorySink",
                   fields=(), lock_attr=None,
                   note="deque.append is atomic; queries snapshot via "
                        "list() before filtering"),
        LockTarget(path=metrics.__file__, class_name="NullSink", fields=(),
                   lock_attr=None, note="stateless"),
        LockTarget(path=tracing.__file__, class_name="Tracer", fields=(),
                   lock_attr=None,
                   note="immutable after construction; span ids from "
                        "itertools.count (atomic in CPython)"),
        LockTarget(path=tracing.__file__, class_name="Span", fields=(),
                   lock_attr=None,
                   note="mutated only by the thread that ends it; emitted "
                        "once on end()"),
    ]


# --------------------------------------------------------------------------
# Runtime companion: instrumented shared-attribute proxy
# --------------------------------------------------------------------------

@dataclass
class LockViolation:
    attr: str
    op: str          # "get" | "set"
    thread: str

    def __str__(self) -> str:
        return f"{self.op} of '{self.attr}' without lock [{self.thread}]"


class GuardedAttrProxy:
    """Attribute-interception proxy over a shared object: every get/set
    asserts the guarding lock is held by the current thread and records a
    :class:`LockViolation` otherwise (recording, not raising, so a stress
    test observes ALL violations instead of dying on the first)."""

    __slots__ = ("_obj", "_lock", "_violations")

    def __init__(self, obj: Any, lock: Any,
                 violations: List[LockViolation]):
        object.__setattr__(self, "_obj", obj)
        object.__setattr__(self, "_lock", lock)
        object.__setattr__(self, "_violations", violations)

    def _check(self, attr: str, op: str) -> None:
        import threading

        lock = object.__getattribute__(self, "_lock")
        owned = getattr(lock, "_is_owned", lambda: False)()
        if not owned:
            object.__getattribute__(self, "_violations").append(
                LockViolation(attr=attr, op=op,
                              thread=threading.current_thread().name))

    def __getattr__(self, attr: str):
        self._check(attr, "get")
        return getattr(object.__getattribute__(self, "_obj"), attr)

    def __setattr__(self, attr: str, value: Any) -> None:
        self._check(attr, "set")
        setattr(object.__getattribute__(self, "_obj"), attr, value)
